#!/usr/bin/env python3
"""Perturb one metric in a flashpim-bench-v1 baseline document.

CI's campaign-gate job uses this to prove the regression gate actually
gates: it scales the baseline value of the first metric matching a
suffix so that a fresh (unchanged) campaign run reads as a regression,
then asserts `repro campaign --baseline <perturbed>` exits non-zero.

The default target is the first `/accepted` metric (higher-is-better);
doubling its baseline makes the identical current run look ~50% worse,
far outside the default 2% tolerance and robust to the metric's scale.

Matching is by *suffix*, so it is agnostic to the key prefix shape —
legacy `campaign/chat/slo-aware/event/r8/accepted` and fleet-segmented
`campaign/4xflash+1xgpu/chat/tier-aware/event/r8/accepted` both match
`/accepted`. `--self-test` proves that property against a fixture
document containing both shapes (no files touched).

Usage: perturb_baseline.py IN OUT [--suffix /accepted] [--scale 2.0]
       perturb_baseline.py --self-test
"""

import argparse
import json
import sys


def perturb(doc: dict, suffix: str, scale: float):
    """Scale the first non-zero metric whose name ends in `suffix`.

    Returns the (name, old, new) triple, or None if nothing matched.
    """
    for m in doc.get("metrics", []):
        name, value = m.get("name", ""), m.get("value")
        if name.endswith(suffix) and isinstance(value, (int, float)) and value != 0:
            m["value"] = value * scale
            return name, value, m["value"]
    return None


def self_test() -> int:
    """Exercise suffix matching on legacy and tier-segmented key shapes."""
    def fixture() -> dict:
        return {
            "schema": "flashpim-bench-v1",
            "metrics": [
                {"name": "campaign_scenarios", "value": 2.0, "unit": "scenarios"},
                # Legacy flash-only shape (no fleet segment).
                {"name": "campaign/chat/slo-aware/event/r8/accepted", "value": 1900.0, "unit": "requests"},
                {"name": "campaign/chat/slo-aware/event/r8/slo/chat", "value": 0.99, "unit": "fraction"},
                # Fleet-segmented shape, including the priced metrics.
                {"name": "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/accepted", "value": 1950.0, "unit": "requests"},
                {"name": "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/cost_per_mtok_usd", "value": 1.75, "unit": "usd/Mtok"},
                {"name": "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/energy_per_mtok_j", "value": 420.5, "unit": "J/Mtok"},
                # Wear-enabled shape (campaign --wear), lower-is-better keys.
                {"name": "campaign/chat/wear-aware/event/r8/wear_max_erases", "value": 37.0, "unit": "erases"},
                {"name": "campaign/chat/wear-aware/event/r8/wear_retirements", "value": 1.0, "unit": "devices"},
            ],
        }

    failures = []

    def check(cond, what):
        if not cond:
            failures.append(what)

    # /accepted matches the first metric in document order — the legacy
    # key — regardless of the fleet segment the later keys carry.
    hit = perturb(fixture(), "/accepted", 2.0)
    check(hit is not None and hit[0] == "campaign/chat/slo-aware/event/r8/accepted",
          f"/accepted resolved to {hit}")
    check(hit is not None and hit[2] == 3800.0, f"/accepted scaled to {hit}")

    # Tier-segmented priced metrics are reachable by their own suffixes.
    for suffix, want in [
        ("/cost_per_mtok_usd", "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/cost_per_mtok_usd"),
        ("/energy_per_mtok_j", "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/energy_per_mtok_j"),
        ("/slo/chat", "campaign/chat/slo-aware/event/r8/slo/chat"),
        # Wear metrics from `campaign --wear` runs are reachable too
        # (lower-is-better: scaling one *down* would fake a regression).
        ("/wear_max_erases", "campaign/chat/wear-aware/event/r8/wear_max_erases"),
        ("/wear_retirements", "campaign/chat/wear-aware/event/r8/wear_retirements"),
    ]:
        hit = perturb(fixture(), suffix, 2.0)
        check(hit is not None and hit[0] == want, f"{suffix} resolved to {hit}")

    # A full fleet-keyed path also works as a (maximally specific) suffix.
    hit = perturb(fixture(), "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/accepted", 0.5)
    check(hit is not None and hit[2] == 975.0, f"fleet-keyed suffix gave {hit}")

    # And a suffix present in no key shape still reports failure.
    check(perturb(fixture(), "/no_such_metric", 2.0) is None, "bogus suffix matched")

    if failures:
        for f in failures:
            print(f"self-test FAIL: {f}", file=sys.stderr)
        return 1
    print("self-test OK: 8 suffix-matching cases over legacy, fleet-segmented, and wear keys")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("infile", nargs="?")
    ap.add_argument("outfile", nargs="?")
    ap.add_argument("--suffix", default="/accepted", help="metric-name suffix to perturb")
    ap.add_argument("--scale", type=float, default=2.0, help="factor applied to the baseline value")
    ap.add_argument("--self-test", action="store_true",
                    help="verify suffix matching against legacy and fleet-segmented key fixtures")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if not args.infile or not args.outfile:
        ap.error("IN and OUT are required unless --self-test is given")

    with open(args.infile) as f:
        doc = json.load(f)
    if doc.get("schema") != "flashpim-bench-v1":
        print(f"error: {args.infile} is not a flashpim-bench-v1 document", file=sys.stderr)
        return 2

    hit = perturb(doc, args.suffix, args.scale)
    if hit is None:
        print(f"error: no non-zero metric ending in {args.suffix!r}", file=sys.stderr)
        return 2
    print(f"perturbed {hit[0]}: {hit[1]} -> {hit[2]}")

    with open(args.outfile, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
