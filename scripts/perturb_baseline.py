#!/usr/bin/env python3
"""Perturb one metric in a flashpim-bench-v1 baseline document.

CI's campaign-gate job uses this to prove the regression gate actually
gates: it scales the baseline value of the first metric matching a
suffix so that a fresh (unchanged) campaign run reads as a regression,
then asserts `repro campaign --baseline <perturbed>` exits non-zero.

The default target is the first `/accepted` metric (higher-is-better);
doubling its baseline makes the identical current run look ~50% worse,
far outside the default 2% tolerance and robust to the metric's scale.

Usage: perturb_baseline.py IN OUT [--suffix /accepted] [--scale 2.0]
"""

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("infile")
    ap.add_argument("outfile")
    ap.add_argument("--suffix", default="/accepted", help="metric-name suffix to perturb")
    ap.add_argument("--scale", type=float, default=2.0, help="factor applied to the baseline value")
    args = ap.parse_args()

    with open(args.infile) as f:
        doc = json.load(f)
    if doc.get("schema") != "flashpim-bench-v1":
        print(f"error: {args.infile} is not a flashpim-bench-v1 document", file=sys.stderr)
        return 2

    for m in doc.get("metrics", []):
        name, value = m.get("name", ""), m.get("value")
        if name.endswith(args.suffix) and isinstance(value, (int, float)) and value != 0:
            m["value"] = value * args.scale
            print(f"perturbed {name}: {value} -> {m['value']}")
            break
    else:
        print(f"error: no non-zero metric ending in {args.suffix!r}", file=sys.stderr)
        return 2

    with open(args.outfile, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
