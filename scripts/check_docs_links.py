#!/usr/bin/env python3
"""Offline checker for relative links and anchors in the repo's Markdown.

Scans README.md and docs/*.md for inline Markdown links `[text](target)`
and verifies that:

  * relative file targets exist (files or directories, after stripping a
    `#fragment` and URL-decoding `%20`-style escapes);
  * `#fragment` targets (same-file or cross-file) match a heading in the
    target document, using GitHub's anchor slugification.

External links (http/https/mailto) are ignored — this runs offline in CI
(`make check-docs-links`, wired into the docs job). Exit code 0 when every
link resolves, 1 otherwise, with one line per broken link.
"""

import re
import sys
import urllib.parse
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

# Inline links/images: [text](target) — tolerates one level of nested
# brackets in the text (e.g. [`foo [bar]`](x)); skips fenced code blocks.
LINK = re.compile(r"!?\[(?:[^\[\]]|\[[^\]]*\])*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")


def strip_fenced_code(text: str) -> str:
    """Blank out fenced code blocks so example links are not checked."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            out.append("")
            continue
        out.append("" if fenced else line)
    return "\n".join(out)


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor rule: strip markup-ish punctuation,
    lowercase, spaces to hyphens (hyphens kept, duplicates NOT collapsed)."""
    # Inline code/emphasis markers vanish; `[text](url)` keeps only text.
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    heading = heading.replace("`", "").replace("*", "")
    slug = []
    for ch in heading.lower():
        if ch.isalnum() or ch in "-_ ":
            slug.append("-" if ch == " " else ch)
    return "".join(slug)


def anchors_of(path: Path) -> set:
    seen, out = {}, set()
    for line in strip_fenced_code(path.read_text(encoding="utf-8")).splitlines():
        m = HEADING.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def main() -> int:
    anchor_cache = {}
    errors = []
    for doc in DOCS:
        if not doc.exists():
            errors.append(f"{doc}: listed document is missing")
            continue
        text = strip_fenced_code(doc.read_text(encoding="utf-8"))
        for m in LINK.finditer(text):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
                continue
            path_part, _, fragment = target.partition("#")
            path_part = urllib.parse.unquote(path_part)
            resolved = (doc.parent / path_part).resolve() if path_part else doc
            rel = doc.relative_to(REPO)
            if path_part and not resolved.exists():
                errors.append(f"{rel}: broken link -> {target}")
                continue
            if fragment:
                if resolved.is_dir() or resolved.suffix.lower() != ".md":
                    continue  # only Markdown targets carry heading anchors
                if resolved not in anchor_cache:
                    anchor_cache[resolved] = anchors_of(resolved)
                if fragment.lower() not in anchor_cache[resolved]:
                    errors.append(f"{rel}: broken anchor -> {target}")
    for e in errors:
        print(e)
    checked = ", ".join(str(d.relative_to(REPO)) for d in DOCS if d.exists())
    if errors:
        print(f"{len(errors)} broken link(s) across {checked}")
        return 1
    print(f"docs links OK ({checked})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
