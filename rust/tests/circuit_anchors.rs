//! Integration: every numeric anchor the paper publishes for the circuit
//! level, checked end-to-end through the public API (DESIGN.md
//! "Acceptance anchors").

use flashpim::circuit::{cell_density_gb_mm2, PlaneLatency, TechParams};
use flashpim::config::presets::*;
use flashpim::config::CellKind;

#[test]
fn anchor_size_a_latency_2us() {
    let lat = PlaneLatency::of(&size_a_plane(), &TechParams::default()).t_pim(8);
    assert!((1.7e-6..=2.3e-6).contains(&lat), "{lat}");
}

#[test]
fn anchor_size_a_density_12_84() {
    let d = cell_density_gb_mm2(&size_a_plane(), &TechParams::default());
    assert!((d - 12.84).abs() / 12.84 < 0.05, "{d}");
}

#[test]
fn anchor_density_ratio_a_over_b_is_2() {
    let t = TechParams::default();
    let r = cell_density_gb_mm2(&size_a_plane(), &t) / cell_density_gb_mm2(&size_b_plane(), &t);
    assert!((r - 2.0).abs() < 1e-6, "{r}");
}

#[test]
fn anchor_conventional_read_20_50us() {
    let t = TechParams::default();
    let lat = PlaneLatency::of(&conventional_plane(), &t).t_read(CellKind::Qlc, &t);
    assert!((20e-6..=50e-6).contains(&lat), "{lat}");
}

#[test]
fn anchor_dse_selects_size_a() {
    use flashpim::dse::select::{select_plane, SelectionCriteria};
    let (winner, _) = select_plane(&SelectionCriteria::default(), &TechParams::default()).unwrap();
    assert_eq!(winner.plane, size_a_plane());
}

#[test]
fn anchor_io_latency_example() {
    // Paper §III-C: 64 ns for 128 bytes at 2 GB/s.
    let bus = flashpim::bus::ChannelBus::new(2.0e9);
    assert_eq!(bus.transfer_time(128), flashpim::sim::SimTime::from_ns(64.0));
}

#[test]
fn anchor_area_table2_and_budget() {
    let b = flashpim::exp::table2::breakdown();
    let (hv, lv, rpu) = b.ratios();
    assert!((hv - 0.2162).abs() < 0.03);
    assert!((lv - 0.2316).abs() < 0.03);
    assert!((rpu - 0.0039).abs() < 0.002);
    let die = flashpim::exp::table2::die_array_mm2();
    assert!((die - 4.98).abs() / 4.98 < 0.03, "{die}");
    let (lo, hi) = flashpim::area::budget::die_budget_mm2();
    assert!(die < hi && (lo - 5.6).abs() < 0.4);
}

#[test]
fn anchor_kv_write_and_break_even() {
    use flashpim::kv::write_overhead::*;
    use flashpim::llm::model_config::OptModel;
    let t = initial_kv_write_time(&table1_system(), &OptModel::Opt30b.shape(), 1024);
    assert!((0.10..=0.14).contains(&t), "{t}");
    assert_eq!(break_even_tokens(0.120, 17e-3, 7e-3), 12);
}

#[test]
fn anchor_lifetime_beyond_warranty() {
    use flashpim::kv::lifetime::lifetime_years;
    use flashpim::llm::model_config::OptModel;
    assert!(lifetime_years(&OptModel::Opt30b.shape(), 7e-3).years > 5.0);
}
