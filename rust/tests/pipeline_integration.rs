//! Integration across the modeling stack: circuit → nand → bus → pim →
//! tiling → llm schedule, plus cross-model consistency checks.

use flashpim::circuit::TechParams;
use flashpim::config::presets::{table1_shared_bus, table1_system};
use flashpim::config::BusTopology;
use flashpim::llm::model_config::OptModel;
use flashpim::llm::schedule::TokenSchedule;
use flashpim::nand::NandTiming;
use flashpim::pim::op::MvmShape;
use flashpim::pim::smvm::SmvmPipeline;
use flashpim::tiling::{search_best, TilingCostModel};

#[test]
fn timing_flows_from_circuit_to_pipeline() {
    // The pipeline's PIM stage for a single tile equals the circuit
    // model's T_PIM exactly.
    let sys = table1_system();
    let tech = TechParams::default();
    let timing = NandTiming::of_system(&sys, &tech);
    let pipe = SmvmPipeline::new(&sys, timing.clone(), 64);
    let r = pipe.execute(MvmShape::new(128, 512)); // exactly one unit tile
    let pim_span = r.pim_done.saturating_sub(r.inbound_done.min(r.pim_done));
    assert!(pim_span <= timing.t_pim + flashpim::sim::SimTime::from_ns(1.0));
}

#[test]
fn tiling_best_uses_htree_benefit() {
    // The same shape costs less outbound under the H-tree than the
    // shared bus for the best scheme of each.
    let tech = TechParams::default();
    let h_sys = table1_system();
    let s_sys = table1_shared_bus();
    assert_eq!(h_sys.bus, BusTopology::HTree);
    let h_model = TilingCostModel::new(&h_sys, NandTiming::of_system(&h_sys, &tech));
    let s_model = TilingCostModel::new(&s_sys, NandTiming::of_system(&s_sys, &tech));
    let shape = MvmShape::new(7168, 7168);
    let h_best = &search_best(&h_model, shape)[0];
    let s_best = &search_best(&s_model, shape)[0];
    assert!(h_best.cost.total() <= s_best.cost.total());
}

#[test]
fn schedule_uses_best_tilings() {
    // The TPOT sMVM component must not exceed a naive per-op upper bound
    // (every MVM on one channel).
    let sys = table1_system();
    let mut sched = TokenSchedule::new(&sys, &TechParams::default(), OptModel::Opt13b.shape());
    let b = sched.token_breakdown(1024);
    assert!(b.smvm > 0.0);
    // 4 sMVMs + lm_head, all well under 100 µs each after tiling.
    let per_op = b.smvm / (OptModel::Opt13b.shape().layers as f64 * 4.0 + 1.0);
    assert!(per_op < 100e-6, "per-op smvm {per_op}");
}

#[test]
fn bigger_models_spend_more_on_smvm() {
    let sys = table1_system();
    let tech = TechParams::default();
    let mut small = TokenSchedule::new(&sys, &tech, OptModel::Opt6_7b.shape());
    let mut big = TokenSchedule::new(&sys, &tech, OptModel::Opt175b.shape());
    assert!(big.token_breakdown(1024).smvm > small.token_breakdown(1024).smvm);
}

#[test]
fn shared_bus_system_has_higher_tpot() {
    let tech = TechParams::default();
    let mut htree = TokenSchedule::new(&table1_system(), &tech, OptModel::Opt30b.shape());
    let mut shared = TokenSchedule::new(&table1_shared_bus(), &tech, OptModel::Opt30b.shape());
    assert!(shared.tpot(1024) > htree.tpot(1024));
}

#[test]
fn device_capacity_fits_all_benchmarked_models() {
    use flashpim::nand::FlashOrganization;
    let f = FlashOrganization::new(&table1_system());
    for m in OptModel::ALL {
        let need = m.shape().weight_bytes(1.0);
        assert!(
            (f.qlc_capacity_bytes() as f64) > need,
            "{} needs {need} > {}",
            m.shape().name,
            f.qlc_capacity_bytes()
        );
    }
}

#[test]
fn cli_experiments_run_end_to_end() {
    for cmd in ["fig1", "table2", "dse", "lifetime"] {
        flashpim::cli::run(vec![cmd.to_string()]).unwrap_or_else(|e| panic!("{cmd}: {e}"));
    }
}
