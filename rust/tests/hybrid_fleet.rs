//! Heterogeneous-fleet acceptance tests: typed device tiers behind one
//! scheduler.
//!
//! Covers the fleet refactor end to end: per-seed bit-identity on a
//! mixed flash+GPU fleet (including the per-token oracle), tier-aware
//! routing on the adversarial chat+summarize mix, GPU-only agreement
//! between the event and direct backends (the flash tier's historical
//! upload-pricing asymmetry does not exist on the GPU tier, so the two
//! backends agree pointwise there), and the GPU tier reproducing the
//! `gpu::roofline` numbers end to end through the serving stack.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{
    default_gpu_system, policy_from_name, run_traffic_events, run_traffic_events_mode,
    run_traffic_point, run_traffic_with_table, DecodeMode, DeviceModel, FleetSpec, LenRange,
    SweepPoint, Tier, TrafficConfig, WorkloadMix, GPU_PROMPT_SPLIT,
};
use flashpim::llm::model_config::OptModel;
use flashpim::llm::LatencyTable;
use flashpim::sim::SimTime;

type Fixtures =
    (flashpim::config::SystemConfig, flashpim::llm::model_config::ModelShape, LatencyTable);

fn fixtures() -> Fixtures {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    (sys, model, table)
}

/// A single-class config over a fleet spec; scalar shape fields are the
/// caller's to adjust.
fn fleet_cfg(spec: &str, requests: usize, rate: f64, seed: u64) -> TrafficConfig {
    let fleet = FleetSpec::parse(spec).expect("valid fleet spec");
    let mut cfg = TrafficConfig::default_for(fleet.n_devices());
    cfg.fleet = Some(fleet);
    cfg.requests = requests;
    cfg.rate = rate;
    cfg.seed = seed;
    cfg
}

#[test]
fn mixed_fleet_reports_are_bit_identical_and_coalescing_stays_exact() {
    let (sys, model, table) = fixtures();
    let mut cfg = fleet_cfg("2xflash+1xgpu", 160, 20.0, 7);
    // Prompts spanning the tier split so both tiers see traffic.
    cfg.input_tokens = LenRange::new(64, 1024);
    cfg.output_tokens = LenRange::new(4, 12);
    cfg.followup = 0.4;
    let run = |mode| {
        run_traffic_events_mode(
            &sys,
            &model,
            &table,
            policy_from_name("tier-aware").unwrap(),
            &cfg,
            mode,
        )
    };
    let a = run(DecodeMode::Coalesced);
    let b = run(DecodeMode::Coalesced);
    assert_eq!(a, b, "same seed must reproduce the mixed-fleet report byte for byte");
    let oracle = run(DecodeMode::PerToken);
    assert_eq!(a, oracle, "per-token oracle must match coalesced decode on every tier");
    assert_eq!(a.render(), oracle.render());

    // The fleet rollup is present, correctly shaped, and rendered.
    let fleet = a.fleet.as_ref().expect("fleet run carries a summary");
    assert_eq!(fleet.name, "2xflash+1xgpu");
    assert_eq!(fleet.tiers, vec![Tier::Flash, Tier::Flash, Tier::Gpu]);
    let r = a.render();
    assert!(r.contains("fleet: 2xflash+1xgpu"), "{r}");
    assert!(r.contains("/Mtok"), "{r}");

    // KV affinity: a session never changes device (hence never tier)
    // across its turns.
    let mut seen = std::collections::HashMap::new();
    let mut followups = 0;
    for o in a.outcomes.iter().filter(|o| !o.rejected) {
        if let Some(prev) = seen.get(&o.session) {
            followups += 1;
            assert_eq!(o.device, *prev, "follow-up of session {} switched devices", o.session);
        }
        seen.insert(o.session, o.device);
    }
    assert!(followups > 0, "trace produced no follow-up turns");
}

#[test]
fn tier_aware_splits_the_adversarial_mix_by_class() {
    let (sys, model, table) = fixtures();
    let mut cfg = fleet_cfg("2xflash+1xgpu", 240, 6.0, 11);
    // The adversarial blend: interactive chat (128-256-token prompts,
    // 150 ms TTFT) behind 1K+-token summarization prefills.
    let mix = WorkloadMix::preset("summarize-long").expect("built-in preset");
    let classes = mix.classes();
    assert_eq!(classes[0].name, "chat");
    assert_eq!(classes[1].name, "summarize-long");
    // Scenario preconditions that make the routing fully deterministic:
    // chat prompts sit below the prompt split AND their flash prefill
    // meets the chat TTFT target (so chat always prefers flash), while
    // every summarization prompt is at or past the split (prefers GPU).
    assert!(classes[0].input_tokens.hi < GPU_PROMPT_SPLIT);
    assert!(classes[1].input_tokens.lo >= GPU_PROMPT_SPLIT);
    let flash = DeviceModel::flash(&sys, &model, &table);
    assert!(
        flash.est_prefill(classes[0].input_tokens.hi) <= classes[0].slo.ttft,
        "chat flash prefill must fit its TTFT budget for this scenario"
    );
    cfg.workload = Some(mix);

    let rep = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("tier-aware").unwrap(),
        &cfg,
    );
    let tiers = cfg.fleet.as_ref().unwrap().tiers();
    let mut per_tier = [0usize; 2];
    for o in rep.outcomes.iter().filter(|o| !o.rejected) {
        let tier = tiers[o.device.expect("accepted outcome has a device")];
        // Fresh chat prefers flash and follow-ups pin to the session's
        // device, so the partition is exact: chat on flash, long
        // summarization prefills on the GPU node.
        let want = if o.class == 0 { Tier::Flash } else { Tier::Gpu };
        assert_eq!(tier, want, "class {} outcome ran on the wrong tier", o.class);
        per_tier[(tier == Tier::Gpu) as usize] += 1;
    }
    assert!(per_tier[0] > 0, "no chat turns reached the flash tier");
    assert!(per_tier[1] > 0, "no summarization turns reached the GPU tier");
    assert!(rep.device_jobs[2] > 0, "GPU device sat idle: {:?}", rep.device_jobs);
}

#[test]
fn gpu_only_fleet_agrees_across_backends_pointwise() {
    let (sys, model, table) = fixtures();
    let mut cfg = fleet_cfg("2xgpu", 80, 30.0, 13);
    cfg.input_tokens = LenRange::new(64, 128);
    cfg.output_tokens = LenRange::new(8, 16);
    // Follow-ups disabled: the two backends' idle-session timelines
    // differ slightly, which is the one statistical (not pointwise)
    // part of their contract.
    cfg.followup = 0.0;
    let event = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("least-loaded").unwrap(),
        &cfg,
    );
    let direct = run_traffic_with_table(
        &sys,
        &model,
        &table,
        policy_from_name("least-loaded").unwrap(),
        &cfg,
    );
    // GPU pricing defines the event and direct flavors identically (KV
    // is born in VRAM — no host upload to price), so the two backends
    // agree to the bit, outcome for outcome.
    assert_eq!(event.outcomes, direct.outcomes);
    assert_eq!(event.makespan, direct.makespan);
    assert_eq!(event.device_jobs, direct.device_jobs);
    assert_eq!(event.device_utilization, direct.device_utilization);
    let (ef, df) = (event.fleet.as_ref().unwrap(), direct.fleet.as_ref().unwrap());
    assert_eq!(ef.name, df.name);
    assert_eq!(ef.tiers, df.tiers);
    assert_eq!(ef.cost_per_hour, df.cost_per_hour);
    // Totals accumulate in each backend's record order; the per-outcome
    // terms are identical, so the sums agree up to float reassociation.
    assert!((ef.energy_j - df.energy_j).abs() <= 1e-9 * ef.energy_j.abs());
}

#[test]
fn gpu_tier_reproduces_the_roofline_end_to_end() {
    let (sys, model, table) = fixtures();
    let mut cfg = fleet_cfg("1xgpu", 1, 10.0, 3);
    cfg.input_tokens = LenRange::fixed(256);
    cfg.output_tokens = LenRange::fixed(4);
    cfg.followup = 0.0;
    let rep = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("least-loaded").unwrap(),
        &cfg,
    );
    assert_eq!(rep.outcomes.len(), 1);
    let o = &rep.outcomes[0];
    assert!(!o.rejected);

    // TTFT on an idle GPU device is exactly roofline prefill + the first
    // decode step at the prompt's context length.
    let g = default_gpu_system();
    let prefill = SimTime::from_secs(g.prefill(&model, 256));
    let first_step = SimTime::from_secs(g.tpot(&model, 1.0, 256).unwrap());
    assert_eq!(o.ttft().unwrap(), prefill + first_step);
    // The decode tail is the step-sum over the growing context.
    let mut tail = SimTime::ZERO;
    for ctx in 257..260 {
        tail += SimTime::from_secs(g.tpot(&model, 1.0, ctx).unwrap());
    }
    assert_eq!(o.completed - o.first_token.unwrap(), tail);

    // Fleet pricing: one A100 node at the cloud per-GPU rate.
    let fleet = rep.fleet.as_ref().unwrap();
    assert_eq!(fleet.cost_per_hour, g.n_gpus as f64 * 2.0);
    assert!(rep.render().contains("fleet: 1xgpu"));
}

#[test]
fn streamed_fleet_point_matches_the_materialized_report() {
    let (sys, model, table) = fixtures();
    let mut cfg = fleet_cfg("2xflash+1xgpu", 120, 18.0, 23);
    cfg.input_tokens = LenRange::new(64, 1024);
    cfg.output_tokens = LenRange::new(4, 12);
    let streamed = run_traffic_point(
        &sys,
        &model,
        &table,
        policy_from_name("tier-aware").unwrap(),
        &cfg,
    );
    let report = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("tier-aware").unwrap(),
        &cfg,
    );
    assert_eq!(streamed, SweepPoint::of(&report), "streamed fleet pricing must be exact");
    assert!(streamed.cost_per_mtok.is_some(), "fleet point carries $/Mtok");
    assert!(streamed.energy_per_mtok.is_some(), "fleet point carries J/Mtok");
}
