//! Deterministic fault injection, locked down end to end: inert specs
//! must change nothing, seeded fault schedules must reproduce
//! bit-identically per backend, both backends must agree on the scripted
//! fault timeline, the per-token decode chain must stay the coalesced
//! path's bit-identity oracle under storm dilation and device loss, the
//! request books must close (accepted + rejected == offered, failed and
//! shed subsets of rejected), and the recovery stack (retry + spare
//! hot-swap + KV failover) must restore at least 90% of fault-free
//! goodput after a mid-trace device loss.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::config::SystemConfig;
use flashpim::coordinator::{
    DecodeMode, LenRange, policy_from_name, PoolReport, run_traffic_events,
    run_traffic_events_mode, run_traffic_with_table, TrafficConfig, WorkloadMix,
};
use flashpim::fault::FaultConfig;
use flashpim::llm::model_config::{ModelShape, OptModel};
use flashpim::llm::LatencyTable;

fn fixtures() -> (SystemConfig, ModelShape, LatencyTable) {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    (sys, model, table)
}

fn cfg(
    devices: usize,
    rate: f64,
    requests: usize,
    seed: u64,
    faults: Option<FaultConfig>,
) -> TrafficConfig {
    TrafficConfig {
        devices,
        rate,
        requests,
        input_tokens: LenRange::new(64, 192),
        output_tokens: LenRange::new(8, 24),
        queue_capacity: 32,
        followup: 0.3,
        seed,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults,
    }
}

fn faults(spec: &str) -> FaultConfig {
    FaultConfig::parse(spec).expect("valid fault spec").active().expect("active fault spec")
}

/// Every arrival must be accounted for exactly once, and the failure /
/// brownout outcomes must be consistent subsets of the rejected books.
fn assert_books_close(rep: &PoolReport, offered: usize) {
    assert_eq!(rep.accepted() + rep.rejected(), offered, "{}: books must close", rep.backend);
    let f = rep.faults.as_ref().expect("fault-enabled run must attach a summary");
    assert_eq!(
        rep.failed() as u64,
        f.failed_requests,
        "{}: failed outcomes vs summary counter",
        rep.backend
    );
    assert!(
        f.failed_requests + f.shed_brownout <= rep.rejected() as u64,
        "{}: failed ({}) + shed ({}) must fit inside rejected ({})",
        rep.backend,
        f.failed_requests,
        f.shed_brownout,
        rep.rejected()
    );
    for o in rep.outcomes.iter().filter(|o| !o.rejected) {
        assert!(o.device.is_some(), "request {}: accepted without a device", o.id);
        assert!(!o.failed, "request {}: accepted requests cannot be failed", o.id);
        assert!(o.completed >= o.arrival, "request {}: time runs forward", o.id);
    }
    for o in rep.outcomes.iter().filter(|o| o.failed) {
        assert!(o.rejected, "request {}: failed implies rejected in the books", o.id);
    }
}

/// The byte-identity gate: a `fail=0` spec normalizes to `None`, and a
/// fault-free run must not betray that fault injection exists at all.
#[test]
fn inert_fault_specs_normalize_away_and_change_nothing() {
    assert!(FaultConfig::parse("fail=0").unwrap().active().is_none());
    assert!(FaultConfig::parse("storm=0:4x1").unwrap().active().is_none());
    assert!(FaultConfig::parse("retries=3,spares=2,brownout=0.9").unwrap().active().is_none());

    let (sys, model, table) = fixtures();
    let ll = || policy_from_name("least-loaded").unwrap();
    let plain = cfg(2, 10.0, 200, 7, None);
    let inert = cfg(2, 10.0, 200, 7, FaultConfig::parse("fail=0").unwrap().active());
    let a = run_traffic_events(&sys, &model, &table, ll(), &plain);
    let b = run_traffic_events(&sys, &model, &table, ll(), &inert);
    assert_eq!(a, b, "an inert spec must not move a single byte");
    assert!(a.faults.is_none());
    assert_eq!(a.failed(), 0);
    assert!(!a.render().contains("faults"), "fault-free render must not mention faults");
    let da = run_traffic_with_table(&sys, &model, &table, ll(), &plain);
    let db = run_traffic_with_table(&sys, &model, &table, ll(), &inert);
    assert_eq!(da, db, "direct backend: same invariant");
    assert!(da.faults.is_none());
}

/// Same seed, same spec => the whole report (trace, metrics, fault
/// summary) reproduces bit-for-bit, on each backend independently.
#[test]
fn same_seed_fault_runs_are_bit_identical_per_backend() {
    let (sys, model, table) = fixtures();
    let spec = "storm=1.0:3x0.5,fail_at=0@8,detect=0.25,retries=2,backoff=0.2,spares=1";
    let c = cfg(2, 8.0, 300, 11, Some(faults(spec)));
    let ll = || policy_from_name("least-loaded").unwrap();

    let ev_a = run_traffic_events(&sys, &model, &table, ll(), &c);
    let ev_b = run_traffic_events(&sys, &model, &table, ll(), &c);
    assert_eq!(ev_a, ev_b, "event backend: same seed must reproduce faults bit-for-bit");

    let di_a = run_traffic_with_table(&sys, &model, &table, ll(), &c);
    let di_b = run_traffic_with_table(&sys, &model, &table, ll(), &c);
    assert_eq!(di_a, di_b, "direct backend: same seed must reproduce faults bit-for-bit");

    let f = ev_a.faults.as_ref().expect("fault summary attached");
    assert_eq!(f.device_failures, 1, "the scripted failure must land");
    assert!(f.storms > 0, "storm process at 1/s over a ~40 s trace must fire");
    assert!(f.storm_s > 0.0);
    assert!(f.availability < 1.0, "a downed device must dent availability");
    assert!(f.degraded_s > 0.0);
    assert_books_close(&ev_a, c.requests);
    assert_books_close(&di_a, c.requests);
}

/// The fault *timeline* is a pure function of (seed, slot, spec), so the
/// two backends — which are not bit-identical to each other — must still
/// agree on what went wrong: same scripted failure, storms drawn from
/// the same per-device streams on both sides.
#[test]
fn backends_agree_on_the_fault_timeline() {
    let (sys, model, table) = fixtures();
    let spec = "storm=1.0:3x0.5,fail_at=0@8,detect=0.25,retries=2,backoff=0.2,spares=1";
    let c = cfg(2, 8.0, 300, 11, Some(faults(spec)));
    let ll = || policy_from_name("least-loaded").unwrap();
    let ev = run_traffic_events(&sys, &model, &table, ll(), &c);
    let di = run_traffic_with_table(&sys, &model, &table, ll(), &c);
    let (fe, fd) = (ev.faults.as_ref().unwrap(), di.faults.as_ref().unwrap());
    assert_eq!(fe.device_failures, fd.device_failures, "same scripted downs on both backends");
    assert!(fe.storms > 0 && fd.storms > 0, "both backends must draw the storm process");
    assert!(fe.availability < 1.0 && fd.availability < 1.0);
    // The spare (slot 2) must absorb post-failure traffic on both sides.
    for rep in [&ev, &di] {
        assert!(
            rep.outcomes.iter().any(|o| !o.rejected && o.device == Some(2)),
            "{}: activated spare must serve accepted requests",
            rep.backend
        );
    }
}

/// Storm dilation and mid-trace device loss must preserve the coalesced
/// decode path's bit-identity oracle: replaying the per-token event
/// chain yields the exact same report.
#[test]
fn per_token_oracle_stays_bit_identical_under_faults() {
    let (sys, model, table) = fixtures();
    let spec = "storm=1.0:3x0.5,fail_at=0@8,detect=0.25,retries=2,backoff=0.2,spares=1";
    let c = cfg(2, 8.0, 250, 13, Some(faults(spec)));
    let ll = || policy_from_name("least-loaded").unwrap();
    let coalesced =
        run_traffic_events_mode(&sys, &model, &table, ll(), &c, DecodeMode::Coalesced);
    let per_token =
        run_traffic_events_mode(&sys, &model, &table, ll(), &c, DecodeMode::PerToken);
    assert_eq!(coalesced, per_token, "per-token chain is the oracle, faults included");
    assert!(coalesced.faults.as_ref().unwrap().storms > 0);
}

/// A device loss with no recovery provisioned (no retries, no spares)
/// and a brownout threshold: in-flight victims fail, the surviving
/// capacity triggers shedding of lower-priority classes, and the books
/// still close exactly.
#[test]
fn unrecovered_loss_fails_victims_and_brownout_sheds_lower_classes() {
    let (sys, model, table) = fixtures();
    let mut c = cfg(2, 8.0, 400, 17, Some(faults("fail_at=0@10,detect=0.25,brownout=0.9")));
    c.workload = Some(WorkloadMix::preset("agentic-burst").expect("built-in preset"));
    let rep =
        run_traffic_events(&sys, &model, &table, policy_from_name("slo-aware").unwrap(), &c);
    assert_books_close(&rep, c.requests);
    let f = rep.faults.as_ref().unwrap();
    assert_eq!(f.device_failures, 1);
    assert!(
        f.failed_requests > 0,
        "a busy device lost with zero retry budget must strand its in-flight work"
    );
    assert!(
        f.shed_brownout > 0,
        "below 90% surviving capacity, non-priority arrivals must be shed"
    );
    assert_eq!(f.retries, 0, "no retry budget, no retry attempts");
    assert!(rep.render().contains("faults:"), "report must surface the reliability section");

    // The direct backend closes the same books under the same spec.
    let di =
        run_traffic_with_table(&sys, &model, &table, policy_from_name("slo-aware").unwrap(), &c);
    assert_books_close(&di, c.requests);
    assert!(di.faults.as_ref().unwrap().failed_requests > 0);
}

/// Acceptance: lose a primary mid-trace with the full recovery stack
/// provisioned (deadline detection, retry budget, a cold spare, KV
/// failover re-prefilling on survivors). No session may be permanently
/// stranded, and goodput must recover to >= 90% of the fault-free run —
/// and be no worse than the same fault with recovery disabled.
#[test]
fn recovery_restores_goodput_after_device_loss() {
    let (sys, model, table) = fixtures();
    let ll = || policy_from_name("least-loaded").unwrap();
    let free = cfg(3, 6.0, 300, 21, None);
    let recovered = cfg(
        3,
        6.0,
        300,
        21,
        Some(faults("fail_at=0@10,detect=0.25,retries=3,backoff=0.25,spares=1")),
    );
    let bare = cfg(3, 6.0, 300, 21, Some(faults("fail_at=0@10,detect=0.25")));

    let free_rep = run_traffic_events(&sys, &model, &table, ll(), &free);
    let rec_rep = run_traffic_events(&sys, &model, &table, ll(), &recovered);
    let bare_rep = run_traffic_events(&sys, &model, &table, ll(), &bare);

    assert_eq!(free_rep.accepted() + free_rep.rejected(), free.requests);
    assert_books_close(&rec_rep, recovered.requests);
    assert_books_close(&bare_rep, bare.requests);

    let f = rec_rep.faults.as_ref().unwrap();
    assert_eq!(f.device_failures, 1);
    assert!(f.availability < 1.0 && f.degraded_s > 0.0);
    assert!(
        rec_rep.outcomes.iter().any(|o| !o.rejected && o.device == Some(3)),
        "the cold spare (slot 3) must absorb post-failure traffic"
    );

    let goodput = |rep: &PoolReport| {
        rep.outcomes
            .iter()
            .filter(|o| !o.rejected)
            .map(|o| o.output_tokens as u64)
            .sum::<u64>()
    };
    let (g_free, g_rec, g_bare) = (goodput(&free_rep), goodput(&rec_rep), goodput(&bare_rep));
    assert!(g_free > 0);
    assert!(
        g_rec as f64 >= 0.9 * g_free as f64,
        "recovery must restore >= 90% of fault-free goodput: {g_rec} vs {g_free} tokens"
    );
    assert!(
        g_rec >= g_bare,
        "the recovery stack must not lose tokens vs no recovery: {g_rec} vs {g_bare}"
    );
    assert!(
        rec_rep.failed() <= bare_rep.failed(),
        "retries + spare must not strand more requests than no recovery"
    );
}

/// Fault sweeps surface through SweepPoint: the streamed point carries
/// the reliability columns, and fault-free points keep them absent.
#[test]
fn sweep_points_carry_the_gated_fault_columns() {
    use flashpim::coordinator::run_traffic_point;
    let (sys, model, table) = fixtures();
    let ll = || policy_from_name("least-loaded").unwrap();
    let plain = cfg(2, 8.0, 150, 23, None);
    let p = run_traffic_point(&sys, &model, &table, ll(), &plain);
    assert!(p.faults_availability.is_none() && p.faults_failed.is_none());

    let c = cfg(2, 8.0, 150, 23, Some(faults("fail_at=0@6,detect=0.25,retries=2,spares=1")));
    let p = run_traffic_point(&sys, &model, &table, ll(), &c);
    assert!(p.faults_availability.is_some());
    assert!(p.faults_availability.unwrap() < 1.0);
    assert!(p.faults_failed.is_some() && p.faults_degraded_s.is_some());

    // Bit-equality with the materialized report's summary-derived point.
    let rep = run_traffic_events(&sys, &model, &table, ll(), &c);
    let f = rep.faults.as_ref().unwrap();
    assert_eq!(p.faults_availability, Some(f.availability));
    assert_eq!(p.faults_failed, Some(f.failed_requests));
    assert_eq!(p.faults_retries, Some(f.retries));
    assert_eq!(p.faults_failovers, Some(f.failovers));
    assert_eq!(p.faults_shed, Some(f.shed_brownout));
    assert_eq!(p.faults_reprefill_tok, Some(f.re_prefill_tokens));
    assert_eq!(p.faults_degraded_s, Some(f.degraded_s));
}
