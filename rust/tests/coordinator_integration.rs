//! Integration: the serving coordinator under load — routing, admission
//! control, utilization accounting, saturation behaviour.

use flashpim::config::presets::table1_system;
use flashpim::coordinator::{simulate, Request, Route, Router, Workload};
use flashpim::gpu::rtx4090x4_vllm;
use flashpim::kv::cache::KvCacheManager;
use flashpim::llm::model_config::OptModel;
use flashpim::sim::SimTime;

#[test]
fn mixed_trace_completes_with_correct_split() {
    let wl = Workload::synthetic(40, 0.6, 0.3, 256, 32, 11);
    let gens = wl.requests.iter().filter(|r| r.is_generate()).count();
    let rep = simulate(&table1_system(), &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl);
    assert_eq!(rep.outcomes.len(), 40);
    let (flash, gpu) = rep.counts();
    assert_eq!(flash, gens);
    assert_eq!(gpu, 40 - gens);
}

#[test]
fn ttft_includes_prefill_and_kv_transfer() {
    let wl = Workload { requests: vec![Request::generate(0, SimTime::ZERO, 512, 8)] };
    let rep = simulate(&table1_system(), &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl);
    let o = &rep.outcomes[0];
    let ttft = o.ttft().unwrap().secs();
    // Prefill + PCIe + SLC write of 512 tokens is tens of ms.
    assert!(ttft > 10e-3, "ttft {ttft}");
    assert_eq!(o.tokens_out, 8);
}

#[test]
fn throughput_grows_with_generation_fraction() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let gpu = rtx4090x4_vllm();
    let low = simulate(&sys, &model, &gpu, &Workload::synthetic(30, 0.2, 0.3, 128, 64, 5));
    let high = simulate(&sys, &model, &gpu, &Workload::synthetic(30, 0.9, 0.3, 128, 64, 5));
    assert!(high.throughput() > low.throughput());
}

#[test]
fn router_respects_capacity_under_pressure() {
    let mut router = Router::new(KvCacheManager::new(&table1_system(), &OptModel::Opt175b.shape()));
    let cap_tokens = (router.kv.capacity / router.kv.per_token) as usize;
    // Fill to the brim.
    let big = Request::generate(1, SimTime::ZERO, cap_tokens - 10, 5);
    assert_eq!(router.route(&big), Route::Flash);
    router.admit(&big).unwrap();
    // Next request must queue, and flow again after release.
    let next = Request::generate(2, SimTime::ZERO, 100, 10);
    assert_eq!(router.route(&next), Route::Queue);
    router.finish(1).unwrap();
    assert_eq!(router.route(&next), Route::Flash);
}

#[test]
fn utilizations_bounded() {
    let wl = Workload::synthetic(25, 0.5, 0.2, 256, 32, 9);
    let rep = simulate(&table1_system(), &OptModel::Opt13b.shape(), &rtx4090x4_vllm(), &wl);
    assert!(rep.flash_utilization >= 0.0 && rep.flash_utilization <= 1.0);
    assert!(rep.gpu_utilization >= 0.0 && rep.gpu_utilization <= 1.0);
}

#[test]
fn report_renders() {
    let wl = Workload::synthetic(10, 0.5, 0.2, 128, 16, 1);
    let rep = simulate(&table1_system(), &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl);
    let s = rep.render();
    assert!(s.contains("TPOT"));
    assert!(s.contains("tok/s"));
}
