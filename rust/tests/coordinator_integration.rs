//! Integration: the serving coordinator under load — routing, admission
//! control, utilization accounting, saturation behaviour — and the
//! device-pool subsystem: pool scheduling, bounded-queue backpressure,
//! KV affinity, and the closed-loop traffic simulator.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{
    LeastLoaded, LenRange, policy_from_name, PoolReport, Request, RoundRobin, Route, Router,
    run_traffic, Scheduler, simulate, sweep_rates, TrafficConfig, Workload,
};
use flashpim::gpu::rtx4090x4_vllm;
use flashpim::kv::cache::KvCacheManager;
use flashpim::llm::LatencyTable;
use flashpim::llm::model_config::OptModel;
use flashpim::sim::SimTime;

#[test]
fn mixed_trace_completes_with_correct_split() {
    let wl = Workload::synthetic(40, 0.6, 0.3, 256, 32, 11);
    let gens = wl.requests.iter().filter(|r| r.is_generate()).count();
    let rep = simulate(&table1_system(), &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl);
    assert_eq!(rep.outcomes.len(), 40);
    let (flash, gpu) = rep.counts();
    assert_eq!(flash, gens);
    assert_eq!(gpu, 40 - gens);
}

#[test]
fn ttft_includes_prefill_and_kv_transfer() {
    let wl = Workload { requests: vec![Request::generate(0, SimTime::ZERO, 512, 8)] };
    let rep = simulate(&table1_system(), &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl);
    let o = &rep.outcomes[0];
    let ttft = o.ttft().unwrap().secs();
    // Prefill + PCIe + SLC write of 512 tokens is tens of ms.
    assert!(ttft > 10e-3, "ttft {ttft}");
    assert_eq!(o.tokens_out, 8);
}

#[test]
fn throughput_grows_with_generation_fraction() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let gpu = rtx4090x4_vllm();
    let low = simulate(&sys, &model, &gpu, &Workload::synthetic(30, 0.2, 0.3, 128, 64, 5));
    let high = simulate(&sys, &model, &gpu, &Workload::synthetic(30, 0.9, 0.3, 128, 64, 5));
    assert!(high.throughput() > low.throughput());
}

#[test]
fn router_respects_capacity_under_pressure() {
    let mut router = Router::new(KvCacheManager::new(&table1_system(), &OptModel::Opt175b.shape()));
    let cap_tokens = (router.kv.capacity / router.kv.per_token) as usize;
    // Fill to the brim.
    let big = Request::generate(1, SimTime::ZERO, cap_tokens - 10, 5);
    assert_eq!(router.route(&big), Route::Flash);
    router.admit(&big).unwrap();
    // Next request must queue, and flow again after release.
    let next = Request::generate(2, SimTime::ZERO, 100, 10);
    assert_eq!(router.route(&next), Route::Queue);
    router.finish(1).unwrap();
    assert_eq!(router.route(&next), Route::Flash);
}

#[test]
fn utilizations_bounded() {
    let wl = Workload::synthetic(25, 0.5, 0.2, 256, 32, 9);
    let rep = simulate(&table1_system(), &OptModel::Opt13b.shape(), &rtx4090x4_vllm(), &wl);
    assert!(rep.flash_utilization >= 0.0 && rep.flash_utilization <= 1.0);
    assert!(rep.gpu_utilization >= 0.0 && rep.gpu_utilization <= 1.0);
}

#[test]
fn report_renders() {
    let wl = Workload::synthetic(10, 0.5, 0.2, 128, 16, 1);
    let rep = simulate(&table1_system(), &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl);
    let s = rep.render();
    assert!(s.contains("TPOT"));
    assert!(s.contains("tok/s"));
}

// ---- device pool: scheduling, backpressure, KV affinity ----

fn traffic(devices: usize, rate: f64, requests: usize, seed: u64) -> TrafficConfig {
    TrafficConfig {
        devices,
        rate,
        requests,
        input_tokens: LenRange::new(96, 192),
        output_tokens: LenRange::new(16, 32),
        queue_capacity: 64,
        followup: 0.35,
        seed,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    }
}

fn run_pool(cfg: &TrafficConfig, policy: Box<dyn Scheduler + Send>) -> PoolReport {
    run_traffic(&table1_system(), &OptModel::Opt6_7b.shape(), policy, cfg)
}

#[test]
fn pool_serves_full_poisson_trace() {
    // Acceptance-shaped run: >= 4 devices, >= 200 Poisson arrivals, full
    // percentile + utilization report.
    let cfg = traffic(4, 10.0, 220, 17);
    let rep = run_pool(&cfg, policy_from_name("least-loaded").unwrap());
    assert_eq!(rep.outcomes.len(), 220);
    assert_eq!(rep.accepted(), 220, "pool must absorb the offered load");
    assert_eq!(rep.device_utilization.len(), 4);
    let rendered = rep.render();
    assert!(rendered.contains("p95") && rendered.contains("dev3"));
    // Every device participates under least-loaded scheduling.
    assert!(rep.device_jobs.iter().all(|&j| j > 0), "idle device: {:?}", rep.device_jobs);
}

#[test]
fn pool_scheduling_beats_single_device() {
    // Same offered Poisson rate; one device saturates (long queues) while
    // four devices under least-loaded scheduling keep waits near zero.
    let cfg = traffic(4, 25.0, 200, 23);
    let pool = run_pool(&cfg, Box::new(LeastLoaded::new()));
    let mut one = cfg.clone();
    one.devices = 1;
    let single = run_pool(&one, Box::new(LeastLoaded::new()));
    let (p_pool, p_one) = (pool.latency_summary().p95, single.latency_summary().p95);
    assert!(p_pool < p_one, "pool p95 {p_pool} vs single-device p95 {p_one}");
}

#[test]
fn bounded_queues_shed_load_instead_of_buffering() {
    let mut cfg = traffic(2, 500.0, 150, 29);
    cfg.queue_capacity = 3;
    cfg.followup = 0.0;
    let rep = run_pool(&cfg, Box::new(RoundRobin::new()));
    assert!(rep.rejected() > 0, "overload must surface as backpressure");
    assert_eq!(rep.accepted() + rep.rejected(), 150);
    for o in rep.outcomes.iter().filter(|o| o.rejected) {
        assert!(o.device.is_none() && o.first_token.is_none());
    }
}

#[test]
fn kv_affinity_keeps_sessions_on_their_device() {
    let mut cfg = traffic(4, 10.0, 120, 31);
    cfg.followup = 0.6;
    let rep = run_pool(&cfg, Box::new(LeastLoaded::new()));
    let mut device_of = std::collections::HashMap::new();
    let mut followups = 0;
    for o in rep.outcomes.iter().filter(|o| !o.rejected) {
        if let Some(prev) = device_of.get(&o.session) {
            followups += 1;
            assert_eq!(o.device, *prev, "session {} migrated devices", o.session);
            // The resident KV extends the context past the new prompt.
            assert!(o.context > o.input_tokens);
        }
        device_of.insert(o.session, o.device);
    }
    assert!(followups >= 10, "only {followups} follow-up turns in trace");
}

#[test]
fn rate_sweep_emits_monotone_curve_for_both_policies() {
    // Acceptance: `--sweep` produces, per scheduler policy, a block of
    // points with strictly ascending offered rates — the
    // throughput–latency curve shape of the paper's vLLM comparison.
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = traffic(2, 1.0, 80, 41);
    let rates = [24.0, 6.0, 12.0]; // unsorted input must come back sorted
    let points = sweep_rates(
        &sys,
        &model,
        &table,
        &cfg,
        &rates,
        &["round-robin", "least-loaded"],
    )
    .unwrap();
    assert_eq!(points.len(), 6);
    let policies: Vec<&str> = points.iter().map(|p| p.policy.as_str()).collect();
    assert_eq!(policies[..3], ["round-robin"; 3]);
    assert_eq!(policies[3..], ["least-loaded"; 3]);
    for block in points.chunks(3) {
        assert!(block.windows(2).all(|w| w[0].rate < w[1].rate), "rates must ascend");
        for p in block {
            assert_eq!(p.accepted + p.rejected, 80);
            assert!(p.throughput > 0.0 && p.latency_p50 > 0.0);
            assert!(p.latency_p50 <= p.latency_p95 && p.latency_p95 <= p.latency_p99);
        }
    }
    // 4× the offered load onto an un-saturated pool must push delivered
    // throughput well up: the curve's x-axis is real.
    for block in points.chunks(3) {
        assert!(
            block[2].throughput > 1.5 * block[0].throughput,
            "{}: throughput {} at 24 req/s vs {} at 6 req/s",
            block[0].policy,
            block[2].throughput,
            block[0].throughput
        );
    }
}

#[test]
fn policies_are_selectable_by_name() {
    let cfg = traffic(3, 10.0, 60, 37);
    let rr = run_pool(&cfg, policy_from_name("round-robin").unwrap());
    let ll = run_pool(&cfg, policy_from_name("least-loaded").unwrap());
    assert_eq!(rr.policy, "round-robin");
    assert_eq!(ll.policy, "least-loaded");
    assert_eq!(rr.accepted() + rr.rejected(), 60);
    assert_eq!(ll.accepted() + ll.rejected(), 60);
}
