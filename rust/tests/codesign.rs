//! The co-design campaign's contract: the generic k-objective frontier
//! is sound and permutation-invariant (seeded random vectors), the
//! campaign is deterministic (same seed → equal reports; parallel ≡
//! sequential), its single-geometry row reuses the `serve-sim --sweep`
//! SLO-frontier oracle exactly, and the paper's Size A sits on (or
//! within documented tolerance of) the {sustained rate, die mm²}
//! frontier under the chat preset.

use flashpim::circuit::TechParams;
use flashpim::config::presets::size_a_plane;
use flashpim::coordinator::{max_sustained_rates, sweep_rates, TrafficConfig, WorkloadMix};
use flashpim::dse::codesign::derive_system;
use flashpim::dse::{
    codesign_metrics, dominates, pareto_indices, run_codesign, run_codesign_seq, CodesignSpec,
    SelectionCriteria,
};
use flashpim::llm::model_config::OptModel;
use flashpim::llm::LatencyTable;
use flashpim::util::testkit::check;

/// Random objective vectors with deliberate ties, duplicates, and the
/// occasional +inf — discrete coordinates make equal values common, the
/// regime where frontier bugs live.
fn random_points(g: &mut flashpim::util::testkit::Gen, n: usize, k: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| {
            (0..k)
                .map(|_| {
                    if g.usize_in(0, 12) == 0 {
                        f64::INFINITY
                    } else {
                        g.usize_in(0, 6) as f64
                    }
                })
                .collect()
        })
        .collect()
}

#[test]
fn frontier_is_sound_across_dimensions() {
    // (a) Every returned point is non-dominated, and every dropped point
    // is dominated by some *frontier member* — across k ∈ {2, 3, 4},
    // which covers both the 2-D sort+scan fast path and the k-D fallback.
    check("frontier soundness", 300, |g| {
        let k = *g.pick(&[2usize, 3, 4]);
        let n = g.usize_in(1, 25);
        let pts = random_points(g, n, k);
        let keep = pareto_indices(&pts).map_err(|e| e.to_string())?;
        let kept = |i: usize| keep.binary_search(&i).is_ok();
        for i in 0..n {
            let dominated_by_frontier = keep.iter().any(|&j| dominates(&pts[j], &pts[i]));
            if kept(i) {
                if let Some(q) = pts.iter().position(|q| dominates(q, &pts[i])) {
                    return Err(format!("kept point {i} {:?} dominated by {q} {:?}", pts[i], pts[q]));
                }
            } else if !dominated_by_frontier {
                return Err(format!("dropped point {i} {:?} has no frontier dominator", pts[i]));
            }
        }
        if keep.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("indices not strictly ascending: {keep:?}"));
        }
        Ok(())
    });
}

#[test]
fn frontier_is_invariant_under_permutation() {
    // (b) The frontier is a pure function of the point multiset: shuffle
    // the input, map the indices back, and the same set comes out.
    check("frontier permutation invariance", 200, |g| {
        let k = *g.pick(&[2usize, 3, 4]);
        let n = g.usize_in(1, 25);
        let pts = random_points(g, n, k);
        // Fisher–Yates permutation from the case's seeded generator.
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            perm.swap(i, g.usize_in(0, i + 1));
        }
        let shuffled: Vec<Vec<f64>> = perm.iter().map(|&i| pts[i].clone()).collect();
        let base = pareto_indices(&pts).map_err(|e| e.to_string())?;
        let mut mapped: Vec<usize> = pareto_indices(&shuffled)
            .map_err(|e| e.to_string())?
            .into_iter()
            .map(|i| perm[i])
            .collect();
        mapped.sort_unstable();
        if base == mapped {
            Ok(())
        } else {
            Err(format!("frontier changed under permutation: {base:?} vs {mapped:?}"))
        }
    });
}

#[test]
fn frontier_rejects_nan_instead_of_panicking() {
    assert!(pareto_indices(&[vec![1.0, 2.0], vec![f64::NAN, 0.0]]).is_err());
    assert!(pareto_indices(&[vec![1.0, 2.0, 3.0], vec![1.0, 2.0]]).is_err());
}

/// A small two-candidate campaign spec for the determinism tests: two
/// column sizes at the Size-A row/stack counts, two rates, two policies.
fn small_spec() -> CodesignSpec {
    CodesignSpec {
        criteria: SelectionCriteria {
            rows: (256, 256),
            cols: (1024, 2048),
            stacks: (128, 128),
            ..Default::default()
        },
        rates: vec![4.0, 8.0],
        policies: vec!["least-loaded".to_string(), "slo-aware".to_string()],
        devices: 2,
        requests: 120,
        ..CodesignSpec::new(OptModel::Opt6_7b.shape())
    }
}

#[test]
fn same_seed_campaigns_are_identical_and_parallel_equals_sequential() {
    let tech = TechParams::default();
    let a = run_codesign(&small_spec(), &tech).unwrap();
    let b = run_codesign(&small_spec(), &tech).unwrap();
    // Same seed → the whole report is equal, field for field.
    assert_eq!(a, b);
    // Parallel fan-out lands results by grid index → byte-equal to the
    // plain sequential loop, including the rendered metrics document.
    let seq = run_codesign_seq(&small_spec(), &tech).unwrap();
    assert_eq!(a, seq);
    assert_eq!(codesign_metrics(&a).render(), codesign_metrics(&seq).render());
    // A different seed must actually change the simulated traffic.
    let mut other = small_spec();
    other.seed = 7;
    let c = run_codesign(&other, &tech).unwrap();
    assert_ne!(a, c, "different seeds must give different campaigns");
}

#[test]
fn single_geometry_row_matches_the_sweep_oracle() {
    // The codesign row for the default system must equal what
    // `serve-sim --sweep` computes for the same seed/rates — the same
    // sweep and reduction code ran under the fan-out, not a re-derivation.
    let tech = TechParams::default();
    let mut spec = small_spec();
    spec.criteria.cols = (2048, 2048); // exactly Size A
    let report = run_codesign(&spec, &tech).unwrap();
    assert_eq!(report.points.len(), 1);
    let row = &report.points[0];
    assert_eq!(row.plane, size_a_plane());

    let sys = derive_system(size_a_plane());
    let table = LatencyTable::build(&sys, &tech, spec.model.clone());
    let mut cfg = TrafficConfig::default_for(spec.devices);
    cfg.requests = spec.requests;
    cfg.seed = spec.seed;
    cfg.workload = Some(WorkloadMix::resolve(&spec.workload).unwrap());
    let policies: Vec<&str> = spec.policies.iter().map(String::as_str).collect();
    let points = sweep_rates(&sys, &spec.model, &table, &cfg, &spec.rates, &policies).unwrap();
    let oracle = max_sustained_rates(&points, spec.attainment);
    assert_eq!(row.frontiers, oracle, "codesign row diverged from the sweep oracle");

    // The scalar score is the documented reduction of those frontiers:
    // best policy's worst class.
    let best = spec
        .policies
        .iter()
        .map(|p| {
            oracle
                .iter()
                .filter(|f| f.policy == *p)
                .map(|f| f.max_rate.unwrap_or(0.0))
                .fold(f64::INFINITY, f64::min)
        })
        .fold(0.0, f64::max);
    assert_eq!(row.sustained_rate, best);
}

#[test]
fn paper_size_a_is_on_the_rate_area_frontier() {
    // (c) Paper anchor (§III-B): under the chat preset with default
    // TechParams, Size A (256×2048×128) must sit on — or within 10% of —
    // the {sustained rate ↑, die mm² ↓} frontier of a grid bracketing it.
    // The tolerance is documented in docs/CODESIGN.md: sustained rates
    // quantize to the swept grid, so "dominates Size A by more than one
    // 10% notch in both objectives" is the meaningful failure.
    let tech = TechParams::default();
    let spec = CodesignSpec {
        criteria: SelectionCriteria {
            rows: (256, 256),
            cols: (1024, 4096),
            stacks: (64, 128),
            ..Default::default()
        },
        rates: vec![2.0, 4.0, 8.0, 16.0],
        policies: vec!["least-loaded".to_string()],
        devices: 2,
        requests: 150,
        ..CodesignSpec::new(OptModel::Opt6_7b.shape())
    };
    let report = run_codesign(&spec, &tech).unwrap();
    assert_eq!(report.points.len(), 6, "3 column sizes x 2 stack counts");
    assert!(!report.frontier.is_empty(), "campaign frontier must be non-empty");
    let a = report
        .points
        .iter()
        .find(|p| p.plane == size_a_plane())
        .expect("Size A is in the grid");
    assert!(a.fits_budget, "Size A must fit the paper's die budget ({:.2} mm2)", a.die_mm2);
    assert!(a.sustained_rate > 0.0, "Size A must sustain some swept rate");
    for q in &report.points {
        let beats_rate = q.sustained_rate > a.sustained_rate * 1.1;
        let beats_area = q.die_mm2 < a.die_mm2 * 0.9;
        assert!(
            !(beats_rate && beats_area),
            "{} dominates Size A beyond tolerance: {:.1} req/s @ {:.2} mm2 vs {:.1} req/s @ {:.2} mm2",
            q.geometry(),
            q.sustained_rate,
            q.die_mm2,
            a.sustained_rate,
            a.die_mm2,
        );
    }
}
