//! Bit-identity regression suite for the serving perf re-architecture:
//!
//! * decode coalescing (one `DecodeDone` event per request) reproduces
//!   the per-token event chain byte for byte — reports *and* rendered
//!   text — across all three scheduler policies, single-class and
//!   multi-class (`agentic-burst`) traffic;
//! * the streaming metric path (`run_traffic_point` / `StreamingSink`,
//!   single-pass `class_reports`) is bit-identical to materializing
//!   every outcome and reducing afterwards;
//! * the parallel `sweep_rates` fan-out is byte-equal to the sequential
//!   point-by-point loop;
//! * the direct-replay backend is untouched by the metrics rewrite;
//! * (`--ignored`, `make perf-smoke`) a 1M-request trace completes.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::config::SystemConfig;
use flashpim::coordinator::{
    DecodeMode, LenRange, policy_from_name, render_sweep, run_traffic_events,
    run_traffic_events_counted, run_traffic_events_mode, run_traffic_point,
    run_traffic_with_table, sweep_rates, SweepPoint, TrafficConfig, WorkloadMix,
};
use flashpim::llm::model_config::{ModelShape, OptModel};
use flashpim::llm::LatencyTable;
use flashpim::util::stats::Summary;
use std::sync::OnceLock;

const POLICIES: [&str; 3] = ["round-robin", "least-loaded", "slo-aware"];

/// One shared (system, model, latency table) for the whole file — the
/// table build dominates test wall-clock and is identical everywhere.
fn setup() -> &'static (SystemConfig, ModelShape, LatencyTable) {
    static SHARED: OnceLock<(SystemConfig, ModelShape, LatencyTable)> = OnceLock::new();
    SHARED.get_or_init(|| {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        (sys, model, table)
    })
}

fn single_class_cfg(requests: usize, rate: f64, seed: u64) -> TrafficConfig {
    TrafficConfig {
        devices: 3,
        rate,
        requests,
        input_tokens: LenRange::new(64, 192),
        output_tokens: LenRange::new(8, 24),
        queue_capacity: 8, // tight enough that overload sheds some load
        followup: 0.4,
        seed,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    }
}

/// The two traffic shapes every equivalence below runs under: a legacy
/// single-class stream and the bursty two-class preset.
fn scenarios() -> Vec<(&'static str, TrafficConfig)> {
    let single = single_class_cfg(300, 25.0, 42);
    let mut burst = single_class_cfg(300, 25.0, 43);
    burst.workload = Some(WorkloadMix::preset("agentic-burst").expect("built-in preset"));
    vec![("single-class", single), ("agentic-burst", burst)]
}

#[test]
fn coalesced_decode_matches_per_token_oracle_byte_for_byte() {
    let (sys, model, table) = setup();
    for (name, cfg) in scenarios() {
        for policy in POLICIES {
            let p = || policy_from_name(policy).unwrap();
            let coalesced = run_traffic_events_mode(
                sys,
                model,
                table,
                p(),
                &cfg,
                DecodeMode::Coalesced,
            );
            let per_token =
                run_traffic_events_mode(sys, model, table, p(), &cfg, DecodeMode::PerToken);
            assert_eq!(
                coalesced, per_token,
                "{name}/{policy}: coalescing changed the simulated timeline"
            );
            assert_eq!(
                coalesced.render(),
                per_token.render(),
                "{name}/{policy}: rendered reports must be byte-equal"
            );
        }
    }
}

#[test]
fn coalescing_cuts_engine_events_at_least_10x_at_default_lengths() {
    // Acceptance: >= 10x fewer engine events per serving run at the
    // default output lengths (the `chat` class, 32-64 output tokens).
    let (sys, model, table) = setup();
    let mut cfg = TrafficConfig::default_for(4);
    cfg.requests = 400;
    cfg.rate = 12.0;
    let p = || policy_from_name("least-loaded").unwrap();
    let (rep_c, coalesced) =
        run_traffic_events_counted(sys, model, table, p(), &cfg, DecodeMode::Coalesced);
    let (rep_t, per_token) =
        run_traffic_events_counted(sys, model, table, p(), &cfg, DecodeMode::PerToken);
    assert_eq!(rep_c, rep_t);
    assert!(
        per_token >= 10 * coalesced,
        "event reduction below 10x: per-token {per_token} vs coalesced {coalesced}"
    );
    // The coalesced count is exactly accountable: one Arrive per arrival
    // plus DecodeDone + Retire per accepted turn.
    assert_eq!(coalesced, rep_c.outcomes.len() as u64 + 2 * rep_c.accepted() as u64);
}

#[test]
fn streamed_sweep_points_match_materialized_reports() {
    let (sys, model, table) = setup();
    for (name, cfg) in scenarios() {
        for policy in POLICIES {
            let p = || policy_from_name(policy).unwrap();
            let streamed = run_traffic_point(sys, model, table, p(), &cfg);
            let materialized = SweepPoint::of(&run_traffic_events(sys, model, table, p(), &cfg));
            assert_eq!(
                streamed, materialized,
                "{name}/{policy}: streaming sink drifted from the materialized reduction"
            );
        }
    }
}

#[test]
fn parallel_sweep_is_byte_equal_to_the_sequential_loop() {
    let (sys, model, table) = setup();
    for (name, cfg) in scenarios() {
        // Pre-sorted unique rates so the manual loop needs no dedup pass.
        let rates = [5.0, 15.0, 30.0];
        let parallel = sweep_rates(sys, model, table, &cfg, &rates, &POLICIES).unwrap();
        let mut sequential = Vec::new();
        for policy in POLICIES {
            for r in rates {
                let mut point_cfg = cfg.clone();
                point_cfg.rate = r;
                let p = policy_from_name(policy).unwrap();
                sequential
                    .push(SweepPoint::of(&run_traffic_events(sys, model, table, p, &point_cfg)));
            }
        }
        assert_eq!(parallel, sequential, "{name}: thread fan-out changed the sweep");
        assert_eq!(render_sweep(&parallel), render_sweep(&sequential));
    }
}

#[test]
fn single_pass_class_reports_match_naive_recomputation() {
    let (sys, model, table) = setup();
    let (_, cfg) = scenarios().remove(1);
    let rep =
        run_traffic_events(sys, model, table, policy_from_name("slo-aware").unwrap(), &cfg);
    let mix = rep.workload.clone().expect("scenario carries a mix");
    let classes = rep.class_reports();
    assert_eq!(classes.len(), mix.classes().len());
    for (i, (c, spec)) in classes.iter().zip(mix.classes()).enumerate() {
        assert_eq!(c.name, spec.name, "class {i} name");
        let of_class: Vec<_> = rep.outcomes.iter().filter(|o| o.class == i).collect();
        assert_eq!(c.arrivals, of_class.len());
        assert_eq!(c.rejected, of_class.iter().filter(|o| o.rejected).count());
        assert_eq!(c.accepted, c.arrivals - c.rejected);
        let met = of_class.iter().filter(|o| o.meets_slo(spec.slo)).count();
        let expect = if c.arrivals == 0 { 1.0 } else { met as f64 / c.arrivals as f64 };
        assert_eq!(c.slo_attainment, expect, "class {i} attainment");
        // The streamed summaries must equal collect-then-Summary::of
        // exactly (not approximately).
        let ttft: Vec<f64> =
            of_class.iter().filter_map(|o| o.ttft().map(|t| t.secs())).collect();
        let tpot: Vec<f64> = of_class.iter().filter_map(|o| o.tpot()).collect();
        let latency: Vec<f64> = of_class
            .iter()
            .filter(|o| !o.rejected)
            .map(|o| o.latency().secs())
            .collect();
        assert_eq!(c.ttft, Summary::of(&ttft), "class {i} TTFT summary");
        assert_eq!(c.tpot, Summary::of(&tpot), "class {i} TPOT summary");
        assert_eq!(c.latency, Summary::of(&latency), "class {i} latency summary");
    }
}

#[test]
fn direct_backend_reports_unchanged_by_the_metrics_rewrite() {
    let (sys, model, table) = setup();
    for (name, cfg) in scenarios() {
        let run = || {
            run_traffic_with_table(
                sys,
                model,
                table,
                policy_from_name("least-loaded").unwrap(),
                &cfg,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "{name}: direct backend lost determinism");
        assert_eq!(a.render(), b.render());
        assert_eq!(a.backend, "direct");
        assert_eq!(SweepPoint::of(&a), SweepPoint::of(&b));
    }
}

/// 1M-request smoke test — the scale the coalescing re-architecture
/// exists for. Ignored by default (seconds of release-mode work, far
/// more under `cargo test` debug builds); run via `make perf-smoke`.
#[test]
#[ignore = "1M-request smoke: run with --ignored (make perf-smoke)"]
fn million_request_trace_completes() {
    let (sys, model, table) = setup();
    let mut cfg = TrafficConfig::default_for(8);
    cfg.requests = 1_000_000;
    cfg.rate = 60.0;
    cfg.seed = 1;
    let (rep, events) = run_traffic_events_counted(
        sys,
        model,
        table,
        policy_from_name("least-loaded").unwrap(),
        &cfg,
        DecodeMode::Coalesced,
    );
    assert_eq!(rep.outcomes.len(), 1_000_000);
    assert_eq!(rep.accepted() + rep.rejected(), 1_000_000);
    assert!(rep.accepted() > 500_000, "only {} accepted", rep.accepted());
    assert_eq!(events, rep.outcomes.len() as u64 + 2 * rep.accepted() as u64);
    let lat = rep.latency_summary();
    assert!(lat.p50 > 0.0 && lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
}
