//! Campaign end-to-end: the baseline round trip through the on-disk
//! JSON (emit → write → read → diff == clean), perturbation gating, and
//! filtered scenario selection — the library-level version of what the
//! CI `campaign-gate` job proves with the real binary.

use flashpim::campaign::{
    Backend, campaign_metrics, CampaignOutcome, CampaignSpec, diff_metrics, Expr, run_campaign,
};
use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::llm::LatencyTable;
use flashpim::llm::model_config::OptModel;
use flashpim::util::benchkit::{Metric, read_metrics};

/// A 4-scenario slice small enough to run inside `cargo test`.
fn tiny_spec() -> CampaignSpec {
    CampaignSpec {
        policies: vec!["least-loaded".into(), "slo-aware".into()],
        workloads: vec!["chat".into()],
        backends: vec![Backend::Event],
        rates: vec![8.0, 16.0],
        fleets: Vec::new(),
        devices: 2,
        requests: 300,
        seed: 11,
        wear: None,
        faults: None,
    }
}

fn run_tiny() -> Vec<CampaignOutcome> {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    run_campaign(&sys, &model, &table, &tiny_spec(), None).expect("tiny campaign runs")
}

#[test]
fn baseline_round_trips_through_disk_as_a_clean_diff() {
    let outcomes = run_tiny();
    let doc = campaign_metrics(&outcomes, None);
    let dir = std::env::temp_dir().join("flashpim_campaign_roundtrip");
    let path = dir.join("nested").join("baseline.json");
    doc.write(&path).expect("write baseline (creating parent dirs)");
    let baseline = read_metrics(&path).expect("read baseline back");
    std::fs::remove_dir_all(&dir).ok();

    // The emitter renders floats shortest-round-trip, so even a zero
    // tolerance diffs clean after a trip through the file.
    let diff = diff_metrics(doc.metrics(), &baseline, 0.0, false);
    assert!(diff.gate().is_ok(), "{}", diff.render(true));
    assert_eq!(diff.improvements(), 0);
    assert_eq!(diff.rows.len(), doc.metrics().len(), "no missing, no new");
}

#[test]
fn perturbed_baseline_metric_gates_the_run() {
    let outcomes = run_tiny();
    let current = campaign_metrics(&outcomes, None);
    let mut baseline: Vec<Metric> = current.metrics().to_vec();
    let i = baseline
        .iter()
        .position(|m| m.name.ends_with("/accepted") && m.value > 0.0)
        .expect("an accepted count to perturb");
    // Doubling the baseline makes the identical current run read ~50%
    // worse — the same trick CI's gate self-test plays.
    baseline[i].value *= 2.0;

    let diff = diff_metrics(current.metrics(), &baseline, 0.02, false);
    assert!(diff.regressions() >= 1, "{}", diff.render(true));
    assert!(diff.gate().is_err());
    let table = diff.render(false);
    assert!(table.contains("REGRESS") && table.contains("/accepted"), "{table}");

    // The unperturbed baseline still passes under the same tolerance.
    let clean = diff_metrics(current.metrics(), current.metrics(), 0.02, false);
    assert!(clean.gate().is_ok());
}

#[test]
fn campaign_metrics_are_deterministic_across_runs() {
    let a = campaign_metrics(&run_tiny(), None).render();
    let b = campaign_metrics(&run_tiny(), None).render();
    assert_eq!(a, b, "same spec, same seed => byte-identical document");
}

#[test]
fn filters_select_the_matching_subset_of_the_default_matrix() {
    let spec = CampaignSpec::default();
    let all = spec.expand().expect("default matrix expands");

    // `summarize-long` is the only preset whose mix carries that class.
    let f = Expr::parse("policy(slo-aware) & class(summarize-long)").expect("valid filter");
    let selected = spec.select(Some(&f)).expect("filter matches something");
    assert!(!selected.is_empty() && selected.len() < all.len());
    for s in &selected {
        assert_eq!(s.policy, "slo-aware");
        assert_eq!(s.workload, "summarize-long");
    }
    // Selection is exactly the filter applied to the full expansion.
    let expected = all.iter().filter(|s| f.matches(&s.view())).count();
    assert_eq!(selected.len(), expected);

    // `class(chat)` is broader than `workload(chat)`: every preset mixes
    // a chat class in, only one *is* the chat preset.
    let by_class = spec.select(Some(&Expr::parse("class(chat)").unwrap())).unwrap();
    let by_workload = spec.select(Some(&Expr::parse("workload(chat)").unwrap())).unwrap();
    assert_eq!(by_class.len(), all.len());
    assert!(by_workload.len() < by_class.len());
    assert!(by_workload.iter().all(|s| s.workload == "chat"));

    // A filter matching nothing is a hard error, not an empty run.
    assert!(spec.select(Some(&Expr::parse("none").unwrap())).is_err());
}
