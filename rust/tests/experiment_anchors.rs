//! Integration: the headline system-level results of the paper, checked
//! through the experiment drivers the benches use.

use flashpim::exp;

#[test]
fn fig5_conventional_210x_and_gpu_2_4x() {
    let rows = exp::fig5::fig5();
    let conv = rows[0].1;
    let prop = rows[1].1;
    let gpu = rows[2].1;
    assert!((1.0..=1.9).contains(&conv), "conventional {conv}");
    assert!((4e-3..=10e-3).contains(&prop), "proposed {prop}");
    let improvement = conv / prop;
    assert!((150.0..=280.0).contains(&improvement), "{improvement}");
    let speedup = gpu / prop;
    assert!((1.9..=3.1).contains(&speedup), "{speedup}");
}

#[test]
fn fig9a_htree_reduction() {
    let rows = exp::fig9::fig9a();
    let mean = flashpim::util::stats::mean(
        &rows.iter().map(|(_, _, _, r)| *r).collect::<Vec<_>>(),
    );
    assert!((0.36..=0.58).contains(&mean), "mean reduction {mean}");
}

#[test]
fn fig9b_size_a_overhead_positive_modest() {
    let rows = exp::fig9::fig9b();
    let mean = flashpim::util::stats::mean(
        &rows.iter().map(|(_, _, _, o)| *o).collect::<Vec<_>>(),
    );
    assert!((0.02..=0.35).contains(&mean), "mean overhead {mean}");
}

#[test]
fn fig12_ordering_and_htree_win() {
    let cases = exp::fig12::fig12();
    let (nccr, ccnr, ccrr) = (&cases[0].1, &cases[1].1, &cases[2].1);
    // inbound + PIM identical; channel-Col slashes outbound; in-die
    // concentration (enabled by the H-tree) beats die-spreading.
    assert_eq!(nccr.pim, ccnr.pim);
    assert_eq!(ccnr.pim, ccrr.pim);
    assert!(nccr.outbound > ccrr.outbound);
    assert!(ccrr.outbound > ccnr.outbound);
    let reduction = 1.0 - ccnr.outbound.secs() / ccrr.outbound.secs();
    assert!((0.32..=0.62).contains(&reduction), "{reduction}");
}

#[test]
fn fig14a_summary_anchors() {
    let rows = exp::fig14::fig14a();
    let s = exp::fig14::fig14a_summary(&rows);
    assert!((1.9..=3.1).contains(&s.mean_speedup_vs_4090), "{}", s.mean_speedup_vs_4090);
    assert!((-0.05..=0.15).contains(&s.mean_overhead_vs_a100), "{}", s.mean_overhead_vs_a100);
    assert_eq!(s.oom_models.len(), 2);
}

#[test]
fn fig14b_scaling_shape() {
    let rows = exp::fig14::fig14b();
    // dMVM+softmax grow with lengths; sMVM+LN flat (paper §V-B).
    let first = &rows[0].1;
    let last = &rows[3].1;
    assert!((first.smvm - last.smvm).abs() < 1e-9);
    assert!((first.ln - last.ln).abs() < 1e-9);
    assert!(last.softmax > first.softmax);
    assert!(last.dmvm > first.dmvm);
}

#[test]
fn fig1_renders_and_anchors() {
    let s = exp::fig1::render();
    assert!(s.contains("GPT-3.5"));
    let (_, _, ratio) = exp::fig1::fig1b();
    assert!((30.0..=65.0).contains(&ratio), "{ratio}");
}

#[test]
fn opt30b_tpot_near_7ms() {
    use flashpim::config::presets::table1_system;
    use flashpim::llm::model_config::OptModel;
    let t = exp::fig14::flash_tpot(&table1_system(), OptModel::Opt30b, 1024, 1024);
    assert!((4e-3..=10e-3).contains(&t), "{t}");
}
