//! Property-based invariants over the core subsystems (mini-proptest kit
//! in `util::testkit`). Each property runs hundreds of randomized cases
//! with replayable seeds.

use flashpim::bus::{HTree, Rpu};
use flashpim::circuit::{cell_density_gb_mm2, PlaneLatency, TechParams};
use flashpim::config::presets::table1_system;
use flashpim::config::{CellKind, PlaneConfig, RpuConfig};
use flashpim::kv::cache::KvCacheManager;
use flashpim::llm::model_config::OptModel;
use flashpim::pim::op::MvmShape;
use flashpim::sim::{EventQueue, Resource, SimTime};
use flashpim::tiling::enumerate_schemes;
use flashpim::util::testkit::check;

fn random_plane(g: &mut flashpim::util::testkit::Gen) -> PlaneConfig {
    PlaneConfig::new(g.pow2(6, 11), g.pow2(8, 14), g.pow2(5, 9), CellKind::Qlc)
}

#[test]
fn prop_htree_reduction_equals_sequential_sum() {
    check("htree reduce == sum", 200, |g| {
        let leaves = g.pow2(1, 6);
        let n = g.usize_in(1, 64);
        let tree = HTree::new(leaves, Rpu::new(RpuConfig::default()), 2.0e9);
        let values: Vec<Vec<i32>> = (0..leaves)
            .map(|_| (0..n).map(|_| g.i64_in(-1000, 1000) as i32).collect())
            .collect();
        let got = tree.reduce_values(&values);
        for j in 0..n {
            let want: i32 = values.iter().map(|v| v[j]).sum();
            if got[j] != want {
                return Err(format!("col {j}: {} != {want}", got[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_latency_monotone_under_growth() {
    // Growing any dimension never reduces T_PIM (Fig. 6a's shape).
    let tech = TechParams::default();
    check("latency monotone", 150, |g| {
        let p = random_plane(g);
        let t0 = PlaneLatency::of(&p, &tech).t_pim(8);
        let grown = match g.usize_in(0, 3) {
            0 => PlaneConfig { n_row: p.n_row * 2, ..p },
            1 => PlaneConfig { n_col: p.n_col * 2, ..p },
            _ => PlaneConfig { n_stack: p.n_stack * 2, ..p },
        };
        let t1 = PlaneLatency::of(&grown, &tech).t_pim(8);
        if t1 >= t0 { Ok(()) } else { Err(format!("{p:?} {t0} -> {grown:?} {t1}")) }
    });
}

#[test]
fn prop_density_row_invariant() {
    let tech = TechParams::default();
    check("density row-invariant", 150, |g| {
        let p = random_plane(g);
        let d0 = cell_density_gb_mm2(&p, &tech);
        let d1 = cell_density_gb_mm2(&PlaneConfig { n_row: p.n_row * 2, ..p }, &tech);
        if (d0 - d1).abs() < 1e-9 { Ok(()) } else { Err(format!("{d0} vs {d1}")) }
    });
}

#[test]
fn prop_tiling_schemes_cover_grid_exactly() {
    // Every enumerated scheme covers the tile grid: Row product >= row
    // tiles, Col product >= col tiles, all counts within resources.
    let org = table1_system().org;
    check("tiling coverage", 60, |g| {
        let rt = g.usize_in(1, 64);
        let ct = g.usize_in(1, 32);
        for s in enumerate_schemes(&org, rt, ct) {
            if s.validate(&org, rt, ct).is_err() {
                return Err(format!("invalid scheme {} for {rt}x{ct}", s.notation_counts()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_event_queue_is_time_ordered() {
    check("event queue ordering", 100, |g| {
        let mut q = EventQueue::new();
        let n = g.usize_in(1, 200);
        for i in 0..n {
            q.schedule(SimTime(g.i64_in(0, 10_000) as u64), i);
        }
        let mut last = SimTime::ZERO;
        while let Some((t, _)) = q.pop() {
            if t < last {
                return Err(format!("time went backwards: {t:?} < {last:?}"));
            }
            last = t;
        }
        Ok(())
    });
}

#[test]
fn prop_resource_never_overlaps() {
    check("resource exclusivity", 100, |g| {
        let mut r = Resource::new();
        let n = g.usize_in(1, 100);
        let mut jobs: Vec<(SimTime, SimTime)> = Vec::new();
        for _ in 0..n {
            let at = SimTime(g.i64_in(0, 1000) as u64);
            let dur = SimTime(g.i64_in(1, 100) as u64);
            let start = r.acquire(at, dur);
            jobs.push((start, start + dur));
        }
        jobs.sort();
        for w in jobs.windows(2) {
            if w[1].0 < w[0].1 {
                return Err(format!("overlap: {:?} then {:?}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kv_manager_conserves_bytes() {
    check("kv conservation", 60, |g| {
        let mut m = KvCacheManager::new(&table1_system(), &OptModel::Opt6_7b.shape());
        let mut live: Vec<(u64, usize)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..g.usize_in(1, 60) {
            if g.bool() || live.is_empty() {
                let toks = g.usize_in(1, 512);
                if m.admit(next_id, toks).is_ok() {
                    live.push((next_id, toks));
                }
                next_id += 1;
            } else if g.bool() {
                let idx = g.usize_in(0, live.len());
                let (id, ref mut t) = live[idx];
                if m.append(id).is_ok() {
                    *t += 1;
                }
            } else {
                let idx = g.usize_in(0, live.len());
                let (id, _) = live.swap_remove(idx);
                m.release(id).map_err(|e| e.to_string())?;
            }
            let want: u64 = live.iter().map(|(_, t)| *t as u64 * m.per_token).sum();
            if m.used() != want {
                return Err(format!("used {} != expected {want}", m.used()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_smvm_total_bounds() {
    // Pipeline total is at least each stage and at most their sum (the
    // stages overlap but never create time).
    use flashpim::nand::NandTiming;
    use flashpim::pim::smvm::SmvmPipeline;
    let sys = table1_system();
    let timing = NandTiming::of_system(&sys, &TechParams::default());
    check("smvm pipeline bounds", 60, |g| {
        let pipe = SmvmPipeline::new(&sys, timing.clone(), g.pow2(4, 8));
        let shape = MvmShape::new(g.pow2(7, 13), g.pow2(7, 13));
        let r = pipe.execute(shape);
        if r.total < r.pim_done {
            return Err("total earlier than pim".into());
        }
        if r.total < r.inbound_done {
            return Err("total earlier than inbound".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rpu_vvm_matches_i64_dot() {
    check("rpu vvm", 200, |g| {
        let n = g.usize_in(1, 256);
        let a: Vec<i16> = (0..n).map(|_| g.i64_in(-32768, 32768) as i16).collect();
        let b: Vec<i16> = (0..n).map(|_| g.i64_in(-32768, 32768) as i16).collect();
        let got = Rpu::vvm(&a, &b) as i64;
        let want: i64 = a.iter().zip(&b).map(|(x, y)| *x as i64 * *y as i64).sum();
        // i32 accumulate can overflow for adversarial inputs; the model
        // matches exact math whenever the exact sum fits i32.
        if want.abs() <= i32::MAX as i64 && got != want {
            return Err(format!("{got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_quantized_tpot_positive_and_finite() {
    use flashpim::llm::schedule::TokenSchedule;
    let sys = table1_system();
    check("tpot sane", 10, |g| {
        let model = *g.pick(&OptModel::ALL);
        let mut s = TokenSchedule::new(&sys, &TechParams::default(), model.shape());
        let t = s.tpot(g.usize_in(64, 4096));
        if t.is_finite() && t > 0.0 && t < 1.0 { Ok(()) } else { Err(format!("{t}")) }
    });
}
