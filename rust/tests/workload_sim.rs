//! The multi-class workload subsystem end to end: same-seed bit-identical
//! per-class reports for every scheduler policy on both backends, class
//! shares tracking the mix weights on a 10k trace, the SLO-aware policy
//! beating round-robin on an adversarial chat + summarize-long blend, and
//! a custom mix round-tripping through its TOML file form.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::config::SystemConfig;
use flashpim::coordinator::{
    ClassReport, LenRange, policy_from_name, PoolReport, run_traffic_events,
    run_traffic_with_table, SloTarget, TrafficConfig, WorkloadClass, WorkloadMix,
};
use flashpim::llm::model_config::{ModelShape, OptModel};
use flashpim::llm::LatencyTable;
use std::sync::OnceLock;

/// One shared (system, model, latency table) for the whole file — the
/// table build dominates test wall-clock and is identical everywhere.
fn setup() -> &'static (SystemConfig, ModelShape, LatencyTable) {
    static SHARED: OnceLock<(SystemConfig, ModelShape, LatencyTable)> = OnceLock::new();
    SHARED.get_or_init(|| {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        (sys, model, table)
    })
}

fn run_events(cfg: &TrafficConfig, policy: &str) -> PoolReport {
    let (sys, model, table) = setup();
    run_traffic_events(sys, model, table, policy_from_name(policy).unwrap(), cfg)
}

fn base_cfg(mix: WorkloadMix, requests: usize, rate: f64, seed: u64) -> TrafficConfig {
    let mut cfg = TrafficConfig::default_for(4);
    cfg.requests = requests;
    cfg.rate = rate;
    cfg.seed = seed;
    cfg.workload = Some(mix);
    cfg
}

#[test]
fn per_class_reports_bit_identical_for_all_three_policies() {
    // Acceptance-shaped: a preset mix with follow-up chains, every
    // scheduler policy, same seed twice -> byte-identical reports with a
    // populated per-class section.
    let mix = WorkloadMix::preset("agentic-burst").expect("built-in preset");
    let cfg = base_cfg(mix, 300, 20.0, 9);
    for policy in ["round-robin", "least-loaded", "slo-aware"] {
        let a = run_events(&cfg, policy);
        let b = run_events(&cfg, policy);
        assert_eq!(a, b, "{policy}: same seed must reproduce the report byte for byte");
        assert_eq!(a.policy, policy);
        let classes = a.class_reports();
        assert_eq!(classes.len(), 2, "{policy}: agentic-burst has two classes");
        assert_eq!(classes.iter().map(|c| c.arrivals).sum::<usize>(), 300);
        for c in &classes {
            assert!(c.arrivals > 0, "{policy}: class {} never arrived", c.name);
            assert!(c.ttft.n > 0 && c.ttft.p95 > 0.0, "{policy}: {} has no TTFT", c.name);
            assert!(c.latency.p50 <= c.latency.p95, "{policy}: {} percentiles", c.name);
            assert!((0.0..=1.0).contains(&c.slo_attainment));
        }
        // The rendered report carries the per-class SLO section.
        let rendered = a.render();
        assert!(rendered.contains("workload mix: agentic-burst"));
        assert!(rendered.contains("SLO met") && rendered.contains("agentic"));
    }
    // A different seed must change the trace.
    let mut other = cfg.clone();
    other.seed = 10;
    assert_ne!(run_events(&cfg, "slo-aware"), run_events(&other, "slo-aware"));
}

#[test]
fn direct_backend_carries_classes_and_stays_deterministic() {
    let (sys, model, table) = setup();
    let mix = WorkloadMix::preset("chat").expect("built-in preset");
    let cfg = base_cfg(mix, 200, 15.0, 21);
    let run = || {
        run_traffic_with_table(sys, model, table, policy_from_name("slo-aware").unwrap(), &cfg)
    };
    let a = run();
    assert_eq!(a, run(), "direct backend must be deterministic under a workload");
    assert_eq!(a.backend, "direct");
    let classes = a.class_reports();
    assert_eq!(classes.len(), 1);
    assert_eq!(classes[0].name, "chat");
    assert_eq!(classes[0].arrivals, 200);
}

#[test]
fn class_shares_track_mix_weights_on_10k_trace() {
    // Tiny shapes keep a 10k-request trace fast; shares are what's under
    // test. 0.7/0.3 split, n = 10_000 -> sigma ~ 0.0046, so a 0.03
    // tolerance sits beyond 6 sigma of the deterministic draw.
    let mix = WorkloadMix::new(
        "split",
        vec![
            WorkloadClass::new(
                "heavy",
                0.7,
                LenRange::new(8, 16),
                LenRange::new(2, 4),
                0.0,
                SloTarget::NONE,
            ),
            WorkloadClass::new(
                "light",
                0.3,
                LenRange::new(16, 32),
                LenRange::new(2, 4),
                0.0,
                SloTarget::NONE,
            ),
        ],
    )
    .unwrap();
    let cfg = base_cfg(mix, 10_000, 400.0, 5);
    let rep = run_events(&cfg, "least-loaded");
    assert_eq!(rep.outcomes.len(), 10_000);
    let heavy = rep.outcomes.iter().filter(|o| o.class == 0).count() as f64 / 10_000.0;
    assert!((heavy - 0.7).abs() < 0.03, "class share drifted: {heavy} vs 0.7");
    // The per-class report sees the same partition.
    let classes = rep.class_reports();
    assert_eq!(classes[0].arrivals + classes[1].arrivals, 10_000);
    assert!((classes[0].share - 0.7).abs() < 1e-12);
    // Every outcome's lengths come from its class's ranges.
    for o in rep.outcomes.iter().filter(|r| !r.rejected) {
        let range = if o.class == 0 { 8..=16 } else { 16..=32 };
        assert!(range.contains(&o.input_tokens), "class {} drew {}", o.class, o.input_tokens);
    }
}

/// The adversarial scenario the SLO-aware policy exists for: interactive
/// chat turns (tight TTFT) blended with 1K+-token summarization prefills
/// (loose TTFT). Round-robin routinely parks a chat arrival behind a
/// ~400 ms summarize job and blows its 150 ms target; the SLO-aware
/// bin-packer concentrates the loose-deadline work and keeps chat-feasible
/// devices available.
#[test]
fn slo_aware_beats_round_robin_on_adversarial_mix() {
    let mix = WorkloadMix::new(
        "adversarial",
        vec![
            WorkloadClass::new(
                "chat",
                0.6,
                LenRange::new(64, 128),
                LenRange::new(16, 32),
                0.0,
                SloTarget { ttft: 0.150, tpot: 0.010 },
            ),
            WorkloadClass::new(
                "summarize-long",
                0.4,
                LenRange::new(1024, 1536),
                LenRange::new(96, 160),
                0.0,
                SloTarget { ttft: 5.0, tpot: 0.010 },
            ),
        ],
    )
    .unwrap();
    let cfg = base_cfg(mix, 2400, 14.0, 11);
    let rr = run_events(&cfg, "round-robin");
    let slo = run_events(&cfg, "slo-aware");
    fn chat(rep: &PoolReport) -> ClassReport<'_> {
        rep.class_reports()[0].clone()
    }
    let overall = |rep: &PoolReport| {
        let cs = rep.class_reports();
        cs.iter().map(|c| c.slo_attainment * c.arrivals as f64).sum::<f64>()
            / cs.iter().map(|c| c.arrivals as f64).sum::<f64>()
    };
    let (rr_chat, slo_chat) = (chat(&rr), chat(&slo));
    assert_eq!(rr_chat.name, "chat");
    assert!(
        slo_chat.slo_attainment > rr_chat.slo_attainment,
        "slo-aware chat attainment {:.3} must beat round-robin's {:.3}",
        slo_chat.slo_attainment,
        rr_chat.slo_attainment
    );
    assert!(
        overall(&slo) >= overall(&rr),
        "slo-aware overall attainment {:.3} must not trail round-robin's {:.3}",
        overall(&slo),
        overall(&rr)
    );
}

#[test]
fn custom_mix_round_trips_through_a_toml_file() {
    // Class names ascend so the parse (which orders sections) reproduces
    // the construction order exactly.
    let mix = WorkloadMix::new(
        "custom",
        vec![
            WorkloadClass::new(
                "alpha",
                2.0,
                LenRange::new(32, 64),
                LenRange::new(4, 8),
                0.25,
                SloTarget { ttft: 0.2, tpot: 0.005 },
            ),
            WorkloadClass::new(
                "beta",
                1.0,
                LenRange::new(256, 512),
                LenRange::new(32, 64),
                0.0,
                SloTarget::NONE,
            ),
        ],
    )
    .unwrap();
    let path = std::env::temp_dir().join("flashpim_workload_roundtrip.toml");
    std::fs::write(&path, mix.to_toml()).expect("write temp workload file");
    let loaded = WorkloadMix::from_file(&path).expect("parse written mix");
    std::fs::remove_file(&path).ok();
    assert_eq!(mix, loaded, "TOML round-trip must reproduce the mix exactly");
    // And a run under the loaded mix behaves identically to the original.
    let a = run_events(&base_cfg(mix, 80, 20.0, 3), "least-loaded");
    let b = run_events(&base_cfg(loaded, 80, 20.0, 3), "least-loaded");
    assert_eq!(a, b);
}
