//! Endurance-aware serving, locked down end to end: fleet wear totals
//! must match the analytic expectation recoverable from the accepted
//! request trace (the conservation law in [`flashpim::kv::wear`]), agree
//! across decode modes and serving backends, survive mid-trace device
//! retirement + spare hot-swap without losing accepted requests, and the
//! diurnal open-loop arrival schedule must shape the stream without
//! perturbing a single byte of wear-disabled or unit-multiplier runs.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::config::SystemConfig;
use flashpim::coordinator::{
    ArrivalProcess, DecodeMode, LenRange, policy_from_name, PoolReport, run_traffic_events,
    run_traffic_events_counted, run_traffic_events_mode, run_traffic_point, run_traffic_with_table,
    TrafficConfig, WearConfig, WorkloadMix,
};
use flashpim::kv::wear::expected_erases;
use flashpim::llm::model_config::{ModelShape, OptModel};
use flashpim::llm::LatencyTable;

fn fixtures() -> (SystemConfig, ModelShape, LatencyTable) {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    (sys, model, table)
}

/// The wear-conservation law: every per-slot meter in the summary must be
/// recoverable from the accepted request trace alone. Programs count the
/// KV tokens written ((l_in + l_out) per accepted turn on the slot),
/// bytes price those tokens at the model's KV footprint, and erases obey
/// [`expected_erases`] over the block-granular allocation count — for
/// *any* routing policy, follow-up share, eviction history, or
/// retirement schedule.
fn assert_wear_conserved(rep: &PoolReport, per_token: u64) {
    let w = rep.wear.as_ref().expect("wear-enabled run must attach a summary");
    for (d, stats) in w.devices.iter().enumerate() {
        let tokens: u64 = rep
            .outcomes
            .iter()
            .filter(|o| !o.rejected && o.device == Some(d))
            .map(|o| (o.input_tokens + o.output_tokens) as u64)
            .sum();
        assert_eq!(stats.programs, tokens, "device {d}: programs vs accepted trace");
        assert_eq!(stats.bytes_written, tokens * per_token, "device {d}: bytes vs programs");
        let allocations = stats.bytes_written / stats.block_bytes;
        assert_eq!(
            stats.erases,
            expected_erases(allocations, w.blocks_per_device as u64, w.pe_budget),
            "device {d}: erases vs the wear-leveler conservation law"
        );
    }
}

/// Large turns at low rate: enough KV volume to cycle every device's
/// erase blocks several times over, so the conservation law is exercised
/// with nonzero erase counts (not just the trivial sub-capacity case).
fn erase_heavy_cfg(seed: u64) -> TrafficConfig {
    TrafficConfig {
        devices: 2,
        rate: 0.4,
        requests: 1600,
        input_tokens: LenRange::new(1024, 1536),
        output_tokens: LenRange::new(4, 8),
        queue_capacity: 64,
        followup: 0.0,
        seed,
        workload: None,
        fleet: None,
        wear: Some(WearConfig::new(100_000)),
        arrival: None,
        faults: None,
    }
}

#[test]
fn wear_totals_match_the_accepted_trace_on_both_backends() {
    let (sys, model, table) = fixtures();
    let cfg = erase_heavy_cfg(7);
    let per_token = model.kv_bytes_per_token(1.0) as u64;
    let ev = run_traffic_events(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    let di = run_traffic_with_table(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    for rep in [&ev, &di] {
        assert_wear_conserved(rep, per_token);
        let w = rep.wear.as_ref().unwrap();
        assert!(w.total_erases() > 0, "{}: trace must overwrite the SLC region", rep.backend);
        assert_eq!(w.retirements, 0, "{}: ample budget must not retire", rep.backend);
        let accepted_tokens: u64 = rep
            .outcomes
            .iter()
            .filter(|o| !o.rejected)
            .map(|o| (o.input_tokens + o.output_tokens) as u64)
            .sum();
        assert_eq!(w.total_programs(), accepted_tokens, "{}: fleet rollup", rep.backend);
        assert_eq!(w.total_bytes_written(), accepted_tokens * per_token);
    }
}

#[test]
fn wear_meters_agree_across_decode_modes_and_reruns() {
    let (sys, model, table) = fixtures();
    let cfg = erase_heavy_cfg(13);
    let ll = || policy_from_name("least-loaded").unwrap();
    let coalesced =
        run_traffic_events_mode(&sys, &model, &table, ll(), &cfg, DecodeMode::Coalesced);
    let per_token = run_traffic_events_mode(&sys, &model, &table, ll(), &cfg, DecodeMode::PerToken);
    // The per-token chain is the coalesced path's bit-identity oracle —
    // including every wear meter, not just latencies.
    assert_eq!(coalesced, per_token);
    assert!(coalesced.wear.as_ref().unwrap().total_erases() > 0);
    let rerun = run_traffic_events_mode(&sys, &model, &table, ll(), &cfg, DecodeMode::Coalesced);
    assert_eq!(coalesced, rerun, "same seed must reproduce wear meters bit-for-bit");
}

/// Below KV pressure the two backends admit the exact same trace (no
/// eviction-timing skew), so their wear summaries must be *equal*, not
/// merely both self-consistent.
#[test]
fn event_and_direct_backends_charge_identical_wear_below_kv_pressure() {
    let (sys, model, table) = fixtures();
    let cfg = TrafficConfig {
        devices: 2,
        rate: 5.0,
        requests: 400,
        input_tokens: LenRange::new(64, 192),
        output_tokens: LenRange::new(8, 24),
        queue_capacity: 64,
        followup: 0.0,
        seed: 11,
        workload: None,
        fleet: None,
        wear: Some(WearConfig::new(1_000)),
        arrival: None,
        faults: None,
    };
    let per_token = model.kv_bytes_per_token(1.0) as u64;
    let ev = run_traffic_events(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    let di = run_traffic_with_table(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    assert_eq!(ev.rejected(), 0, "lightly loaded pool must accept everything");
    assert_eq!(di.rejected(), 0);
    assert_eq!(ev.wear, di.wear, "backends must charge identical meters");
    assert!(ev.wear.as_ref().unwrap().total_programs() > 0);
    assert_wear_conserved(&ev, per_token);
}

/// Exhaust the only primary device mid-trace: it must drain, its sessions
/// must re-home, the provisioned spare must take over the remainder of
/// the trace, and not a single arrival may be lost from the books.
#[test]
fn worn_device_retires_drains_and_hands_over_to_spare() {
    let (sys, model, table) = fixtures();
    let cfg = TrafficConfig {
        devices: 1,
        rate: 0.2,
        requests: 1500,
        input_tokens: LenRange::new(1024, 1536),
        output_tokens: LenRange::new(4, 8),
        queue_capacity: 32,
        followup: 0.3,
        seed: 21,
        workload: None,
        fleet: None,
        // 4 blocks x 1 P/E: the primary exhausts after rewriting its SLC
        // region once over; the spare sees less than that and survives.
        wear: Some(WearConfig { pe_budget: 1, blocks_per_device: 4, spares: 1 }),
        arrival: None,
        faults: None,
    };
    let per_token = model.kv_bytes_per_token(1.0) as u64;
    let policy = || policy_from_name("least-loaded").unwrap();
    let (rep, events) =
        run_traffic_events_counted(&sys, &model, &table, policy(), &cfg, DecodeMode::Coalesced);

    let w = rep.wear.as_ref().expect("wear summary");
    assert_eq!(w.retirements, 1, "exactly the primary must exhaust");
    assert_eq!(w.devices.len(), 2, "primary + spare in the summary");
    assert!(w.devices[0].retired_at_s.is_some(), "primary records its retirement time");
    assert!(!w.devices[0].spare);
    assert!(w.devices[1].spare);
    assert!(w.devices[1].retired_at_s.is_none(), "spare must outlive the trace");
    assert!(w.devices[1].programs > 0, "spare must absorb the post-retirement stream");
    assert_eq!(w.devices[0].erases, 4, "retired at blocks x P/E exactly");

    // No arrival lost: every request is accounted accepted or rejected,
    // accepted ones ran somewhere and finished after arriving.
    assert_eq!(rep.accepted() + rep.rejected(), cfg.requests);
    for o in rep.outcomes.iter().filter(|o| !o.rejected) {
        assert!(o.device.is_some(), "request {}: accepted without a device", o.id);
        assert!(o.first_token.is_some() && o.completed >= o.arrival, "request {}", o.id);
    }
    assert!(
        rep.outcomes.iter().any(|o| !o.rejected && o.device == Some(1)),
        "hot-swapped spare must serve accepted requests"
    );
    assert_eq!(rep.device_utilization.len(), 2, "report covers the spare slot");
    assert!(rep.device_utilization[1] > 0.0, "spare utilization shows up in the report");

    // The coalesced event budget is unchanged by retirement/hot-swap:
    // one arrival per request plus decode-done + retire per acceptance.
    assert_eq!(events, rep.outcomes.len() as u64 + 2 * rep.accepted() as u64);
    assert_wear_conserved(&rep, per_token);

    // The direct backend walks the same trace shape through the same
    // meters: same retirement, same conservation law.
    let di = run_traffic_with_table(&sys, &model, &table, policy(), &cfg);
    assert_eq!(di.wear.as_ref().unwrap().retirements, 1);
    assert!(di.wear.as_ref().unwrap().devices[0].retired_at_s.is_some());
    assert_wear_conserved(&di, per_token);
}

/// Multi-class traffic under wear accounting: per-class books must still
/// close (arrivals = accepted + rejected per class, attainment a valid
/// fraction) and the conservation law must hold with class-specific
/// token ranges in the mix.
#[test]
fn per_class_accounting_stays_consistent_under_wear() {
    let (sys, model, table) = fixtures();
    let cfg = TrafficConfig {
        devices: 2,
        rate: 4.0,
        requests: 600,
        input_tokens: LenRange::new(64, 128),
        output_tokens: LenRange::new(8, 16),
        queue_capacity: 32,
        followup: 0.2,
        seed: 5,
        workload: Some(WorkloadMix::preset("chat").expect("built-in preset")),
        fleet: None,
        wear: Some(WearConfig::new(10_000)),
        arrival: None,
        faults: None,
    };
    let per_token = model.kv_bytes_per_token(1.0) as u64;
    let rep =
        run_traffic_events(&sys, &model, &table, policy_from_name("slo-aware").unwrap(), &cfg);
    assert_wear_conserved(&rep, per_token);
    let classes = rep.class_reports();
    assert!(!classes.is_empty());
    assert_eq!(classes.iter().map(|c| c.arrivals).sum::<usize>(), rep.outcomes.len());
    assert_eq!(classes.iter().map(|c| c.accepted).sum::<usize>(), rep.accepted());
    for c in &classes {
        assert_eq!(c.arrivals, c.accepted + c.rejected, "class {}", c.name);
        assert!((0.0..=1.0).contains(&c.slo_attainment), "class {}", c.name);
    }
}

#[test]
fn diurnal_phases_shape_the_arrival_stream() {
    let (sys, model, table) = fixtures();
    let cfg = TrafficConfig {
        devices: 4,
        rate: 20.0,
        requests: 4000,
        input_tokens: LenRange::new(8, 16),
        output_tokens: LenRange::new(1, 4),
        queue_capacity: 64,
        followup: 0.0,
        seed: 33,
        workload: None,
        fleet: None,
        wear: None,
        arrival: Some(ArrivalProcess::parse("40:0.25,40:2.0").expect("valid schedule")),
        faults: None,
    };
    let rep = run_traffic_events(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    assert_eq!(rep.outcomes.len(), cfg.requests);
    let horizon =
        rep.outcomes.iter().map(|o| o.arrival.secs()).fold(0.0f64, f64::max);
    assert!(horizon > 160.0, "trace must span multiple 80 s cycles, got {horizon:.1} s");

    // Seconds of [0, horizon) covered by the phase window [lo, hi) of an
    // 80 s cycle, so per-phase empirical rates have exact denominators.
    let covered = |lo: f64, hi: f64| -> f64 {
        let cycles = (horizon / 80.0).floor();
        let rem = horizon - cycles * 80.0;
        cycles * (hi - lo) + (rem.min(hi) - lo).max(0.0)
    };
    for (lo, hi, mul) in [(0.0, 40.0, 0.25), (40.0, 80.0, 2.0)] {
        let n = rep
            .outcomes
            .iter()
            .filter(|o| {
                let t = o.arrival.secs().rem_euclid(80.0);
                (lo..hi).contains(&t)
            })
            .count() as f64;
        let expect = cfg.rate * mul * covered(lo, hi);
        let rel = (n - expect).abs() / expect;
        assert!(
            rel < 0.2,
            "phase x{mul}: {n} arrivals vs {expect:.0} expected ({:.0}% apart)",
            rel * 100.0
        );
    }
}

/// A schedule whose every phase multiplies by 1.0 must reproduce the
/// stationary Poisson stream *byte for byte* — the gating invariant that
/// keeps legacy invocations out of the new arrival-process code's blast
/// radius.
#[test]
fn unit_multiplier_schedule_is_byte_identical_to_legacy_poisson() {
    let (sys, model, table) = fixtures();
    let mut cfg = TrafficConfig {
        devices: 3,
        rate: 20.0,
        requests: 400,
        input_tokens: LenRange::new(64, 192),
        output_tokens: LenRange::new(8, 24),
        queue_capacity: 32,
        followup: 0.5,
        seed: 99,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let ll = || policy_from_name("least-loaded").unwrap();
    let legacy = run_traffic_events(&sys, &model, &table, ll(), &cfg);
    cfg.arrival = Some(ArrivalProcess::parse("25:1.0,35:1.0").expect("valid schedule"));
    let flat = run_traffic_events(&sys, &model, &table, ll(), &cfg);
    assert_eq!(legacy, flat, "x1.0 phases must not move a single byte");
    let di_legacy = {
        let mut c = cfg.clone();
        c.arrival = None;
        run_traffic_with_table(&sys, &model, &table, ll(), &c)
    };
    let di_flat = run_traffic_with_table(&sys, &model, &table, ll(), &cfg);
    assert_eq!(di_legacy, di_flat, "direct backend: same invariant");
}

/// The PR 7 regression guard: with wear off, nothing about a report —
/// struct, render, or sweep point — may betray that wear accounting
/// exists at all.
#[test]
fn wear_disabled_runs_report_exactly_as_before() {
    let (sys, model, table) = fixtures();
    let cfg = TrafficConfig {
        devices: 2,
        rate: 10.0,
        requests: 300,
        input_tokens: LenRange::new(32, 64),
        output_tokens: LenRange::new(4, 8),
        queue_capacity: 32,
        followup: 0.3,
        seed: 17,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let ll = || policy_from_name("least-loaded").unwrap();
    let rep = run_traffic_events(&sys, &model, &table, ll(), &cfg);
    assert!(rep.wear.is_none());
    assert!(!rep.render().contains("wear"), "wear-disabled render must not mention wear");
    let point = run_traffic_point(&sys, &model, &table, ll(), &cfg);
    assert!(point.wear_max_erases.is_none());
    assert!(point.wear_total_erases.is_none());
    assert!(point.wear_retirements.is_none());

    // Flipping wear on populates all three — and only changes *additions*
    // (the underlying trace is untouched: wear charges draw no RNG).
    let mut weared = cfg.clone();
    weared.wear = Some(WearConfig::new(100_000));
    let wrep = run_traffic_events(&sys, &model, &table, ll(), &weared);
    assert!(wrep.wear.is_some());
    assert!(wrep.render().contains("wear:"));
    assert_eq!(wrep.outcomes, rep.outcomes, "wear meters must not perturb the trace");
    let wpoint = run_traffic_point(&sys, &model, &table, ll(), &weared);
    assert!(wpoint.wear_max_erases.is_some() && wpoint.wear_retirements.is_some());
}

/// Acceptance: on a multi-day diurnal trace, `wear-aware` routing spreads
/// erase load where `least-loaded` concentrates it (post-eviction slack
/// makes the freshly-evicted device the standing KV minimum, so an idle
/// fleet funnels fresh sessions at whichever device is already churning),
/// extending fleet lifetime — max per-device erases strictly drop — for
/// bounded p95 cost.
#[test]
fn wear_aware_extends_fleet_lifetime_on_a_diurnal_trace() {
    let (sys, model, table) = fixtures();
    let cfg = TrafficConfig {
        devices: 4,
        rate: 0.05,
        requests: 9000,
        input_tokens: LenRange::new(1024, 1536),
        output_tokens: LenRange::new(4, 8),
        queue_capacity: 64,
        followup: 0.0,
        seed: 42,
        workload: None,
        fleet: None,
        wear: Some(WearConfig::new(1_000_000)),
        arrival: Some(ArrivalProcess::parse("43200:0.5,43200:1.5").expect("valid schedule")),
        faults: None,
    };
    let ll = run_traffic_events(&sys, &model, &table, policy_from_name("ll").unwrap(), &cfg);
    let wa =
        run_traffic_events(&sys, &model, &table, policy_from_name("wear-aware").unwrap(), &cfg);
    assert!(ll.makespan.secs() > 150_000.0, "trace must span multiple diurnal cycles");

    let (lw, ww) = (ll.wear.as_ref().unwrap(), wa.wear.as_ref().unwrap());
    assert!(lw.max_erases() > 0 && ww.max_erases() > 0, "both traces must reach erase volume");
    assert!(
        ww.max_erases() < lw.max_erases(),
        "wear-aware must lower the fleet-lifetime bound: {} vs {} max erases",
        ww.max_erases(),
        lw.max_erases()
    );
    // The stated latency bound for that lifetime win: p95 within 1.5x of
    // least-loaded's on the same trace.
    let (lp, wp) = (ll.latency_summary().p95, wa.latency_summary().p95);
    assert!(wp <= lp * 1.5, "wear-aware p95 {wp:.3} s vs least-loaded {lp:.3} s exceeds 1.5x");
}
