//! The precomputed `LatencyTable` against the exact `TokenSchedule`, and
//! end-to-end behaviour of the table-driven serving simulator: bit-exact
//! determinism for a seed, and completion of a 100k-request trace (the
//! scale the shared-table redesign exists to serve).

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::config::{CellKind, PlaneConfig};
use flashpim::coordinator::{
    policy_from_name, run_traffic, run_traffic_with_table, LenRange, TrafficConfig,
};
use flashpim::dse::codesign::derive_system;
use flashpim::llm::model_config::OptModel;
use flashpim::llm::{LatencyTable, TokenSchedule};
use flashpim::util::testkit::check;

#[test]
fn table_matches_exact_schedule_within_1pct() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let mut exact = TokenSchedule::new(&sys, &TechParams::default(), model);
    let max = table.max_context();
    // Random in-range context lengths: the dense default table must be
    // within 1% of the exact schedule (it is in fact bit-exact there —
    // the tolerance guards any future coarsening of the default stride).
    check("table tpot within 1% of exact schedule", 48, |g| {
        let l = g.usize_in(1, max + 1);
        let approx = table.tpot(l);
        let truth = exact.tpot(l);
        let err = (approx - truth).abs() / truth;
        if err < 0.01 {
            Ok(())
        } else {
            Err(format!("l={l}: table {approx} vs exact {truth} ({:.3}% off)", err * 100.0))
        }
    });
    // Past the trained context (long multi-turn sessions get there) the
    // table extrapolates a windowed slope through the dMVM staircase;
    // allow 5% pointwise.
    check("extrapolated tpot within 5% of exact schedule", 24, |g| {
        let l = g.usize_in(max + 1, 3 * max);
        let approx = table.tpot(l);
        let truth = exact.tpot(l);
        let err = (approx - truth).abs() / truth;
        if err < 0.05 {
            Ok(())
        } else {
            Err(format!("l={l}: table {approx} vs exact {truth} ({:.3}% off)", err * 100.0))
        }
    });
}

#[test]
fn table_matches_exact_schedule_on_extreme_grid_geometries() {
    // The co-design campaign trusts `LatencyTable::build` for every
    // geometry in the `SelectionCriteria` grid, not just Table I. Guard
    // the corners: the smallest (256×256×32) and largest (2048×16384×128)
    // in-grid planes must agree with the exact `TokenSchedule` pointwise,
    // like the default system does.
    let tech = TechParams::default();
    let model = OptModel::Opt6_7b.shape();
    for (r, c, s) in [(256, 256, 32), (2048, 16384, 128)] {
        let sys = derive_system(PlaneConfig::new(r, c, s, CellKind::Qlc));
        sys.validate().unwrap();
        let table = LatencyTable::build(&sys, &tech, model.clone());
        let mut exact = TokenSchedule::new(&sys, &tech, model.clone());
        let max = table.max_context();
        check(&format!("codesign geometry {r}x{c}x{s} table vs exact"), 32, |g| {
            let l = g.usize_in(1, max + 1);
            let approx = table.tpot(l);
            let truth = exact.tpot(l);
            if !(truth.is_finite() && truth > 0.0) {
                return Err(format!("l={l}: exact schedule gave {truth}"));
            }
            let err = (approx - truth).abs() / truth;
            if err < 0.01 {
                Ok(())
            } else {
                Err(format!("l={l}: table {approx} vs exact {truth} ({:.3}% off)", err * 100.0))
            }
        });
    }
}

#[test]
fn table_step_time_monotone_in_context() {
    let sys = table1_system();
    let table =
        LatencyTable::build(&sys, &TechParams::default(), OptModel::Opt13b.shape());
    let mut prev = 0.0;
    for l in (0..=3 * table.max_context()).step_by(97) {
        let t = table.tpot(l);
        assert!(t >= prev, "tpot regressed at l={l}: {t} < {prev}");
        assert!(t.is_finite(), "non-finite tpot at l={l}");
        prev = t;
    }
}

fn traffic(seed: u64) -> TrafficConfig {
    TrafficConfig {
        devices: 3,
        rate: 20.0,
        requests: 400,
        input_tokens: LenRange::new(64, 192),
        output_tokens: LenRange::new(8, 24),
        queue_capacity: 32,
        followup: 0.5,
        seed,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    }
}

#[test]
fn same_seed_reproduces_identical_pool_report() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let cfg = traffic(99);
    let a = run_traffic(&sys, &model, policy_from_name("least-loaded").unwrap(), &cfg);
    let b = run_traffic(&sys, &model, policy_from_name("least-loaded").unwrap(), &cfg);
    // Outcome-for-outcome equality, not just aggregate equality.
    assert_eq!(a, b);
    let mut other_seed = cfg.clone();
    other_seed.seed = 100;
    let c = run_traffic(&sys, &model, policy_from_name("least-loaded").unwrap(), &other_seed);
    assert_ne!(a, c, "different seeds must give different traces");
}

#[test]
fn serve_sim_completes_100k_requests() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = TrafficConfig {
        devices: 4,
        rate: 400.0,
        requests: 100_000,
        input_tokens: LenRange::new(8, 16),
        output_tokens: LenRange::new(1, 4),
        queue_capacity: 64,
        followup: 0.4,
        seed: 7,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let rep = run_traffic_with_table(
        &sys,
        &model,
        &table,
        policy_from_name("least-loaded").unwrap(),
        &cfg,
    );
    assert_eq!(rep.outcomes.len(), 100_000);
    assert_eq!(rep.accepted() + rep.rejected(), 100_000);
    assert!(rep.accepted() > 50_000, "only {} accepted", rep.accepted());
    assert!(rep.makespan.secs() > 0.0);
    let lat = rep.latency_summary();
    assert!(lat.p50 > 0.0 && lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
    for u in &rep.device_utilization {
        assert!((0.0..=1.0).contains(u), "utilization {u}");
    }
}
