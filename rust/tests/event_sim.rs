//! The event-driven serving backend end to end: bit-identical reports
//! for a seed, pointwise agreement with the legacy direct-replay backend
//! up to the PCIe prefill upload it adds, statistical parity on a
//! 10k-request trace, and completion of a 100k-request trace on a single
//! thread (the scale the event redesign exists to serve).

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::controller::PcieLink;
use flashpim::coordinator::{
    LenRange, policy_from_name, run_traffic_events, run_traffic_with_table, TrafficConfig,
};
use flashpim::kv::write_overhead::initial_kv_write_time;
use flashpim::llm::model_config::OptModel;
use flashpim::llm::LatencyTable;
use flashpim::sim::SimTime;

fn traffic(seed: u64) -> TrafficConfig {
    TrafficConfig {
        devices: 3,
        rate: 20.0,
        requests: 400,
        input_tokens: LenRange::new(64, 192),
        output_tokens: LenRange::new(8, 24),
        queue_capacity: 32,
        followup: 0.5,
        seed,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    }
}

#[test]
fn same_seed_reproduces_bit_identical_report() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = traffic(99);
    let ll = || policy_from_name("least-loaded").unwrap();
    let a = run_traffic_events(&sys, &model, &table, ll(), &cfg);
    let b = run_traffic_events(&sys, &model, &table, ll(), &cfg);
    // Outcome-for-outcome equality — every timestamp, device pick, and
    // flag — not just aggregate equality.
    assert_eq!(a, b);
    assert_eq!(a.backend, "event");
    let mut other_seed = cfg.clone();
    other_seed.seed = 100;
    let c = run_traffic_events(&sys, &model, &table, ll(), &other_seed);
    assert_ne!(a, c, "different seeds must give different traces");
}

/// With fresh sessions only and round-robin routing, both backends
/// consume identical RNG streams and route identically, so their traces
/// agree request for request — the event backend's timestamps exceed the
/// direct backend's by exactly the PCIe KV upload it prices (plus any
/// extra queueing that upload induces).
#[test]
fn event_backend_matches_direct_backend_plus_pcie_upload() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = TrafficConfig {
        devices: 2,
        rate: 5.0,
        requests: 100,
        input_tokens: LenRange::new(64, 128),
        output_tokens: LenRange::new(8, 16),
        queue_capacity: 64,
        followup: 0.0, // fresh sessions only: identical routing either way
        seed: 11,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let ev = run_traffic_events(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    let di = run_traffic_with_table(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    assert_eq!(ev.rejected(), 0, "lightly loaded pool must accept everything");
    assert_eq!(di.rejected(), 0);
    assert_eq!(ev.outcomes.len(), di.outcomes.len());

    let link = PcieLink::new(&sys.ctrl);
    let mut exact = 0usize;
    for (e, d) in ev.outcomes.iter().zip(&di.outcomes) {
        // The sampled trace and the routing are identical.
        assert_eq!((e.id, e.session, e.device), (d.id, d.session, d.device));
        assert_eq!(
            (e.input_tokens, e.output_tokens, e.context),
            (d.input_tokens, d.output_tokens, d.context)
        );
        assert_eq!(e.arrival, d.arrival);
        // The event backend adds the prefill PCIe upload to the service
        // path; queueing can only push it later still, never earlier.
        let upload = link.transfer_time(model.kv_bytes(e.input_tokens, 1.0));
        let (ev_ttft, di_ttft) = (e.ttft().unwrap(), d.ttft().unwrap());
        assert!(ev_ttft >= di_ttft + upload, "request {}: {ev_ttft:?} vs {di_ttft:?}", e.id);
        assert!(e.latency() >= d.latency() + upload, "request {}", e.id);
        if ev_ttft == di_ttft + upload {
            exact += 1;
        }
    }
    // At ~8% utilization most requests queue in neither backend, so the
    // difference is *exactly* the upload for the bulk of the trace.
    assert!(exact * 2 > ev.outcomes.len(), "only {exact}/{} exact matches", ev.outcomes.len());
}

/// Acceptance: on a 10k-request trace the event backend's end-to-end
/// latency percentiles sit within 5% of the legacy backend's — the PCIe
/// upload it adds is a small, correctly-bounded perturbation.
#[test]
fn latency_percentiles_within_5pct_of_direct_backend_on_10k_trace() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = TrafficConfig {
        devices: 4,
        rate: 12.0,
        requests: 10_000,
        input_tokens: LenRange::new(32, 64),
        output_tokens: LenRange::new(32, 64),
        queue_capacity: 64,
        followup: 0.3,
        seed: 123,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let ev = run_traffic_events(&sys, &model, &table, policy_from_name("ll").unwrap(), &cfg);
    let di = run_traffic_with_table(&sys, &model, &table, policy_from_name("ll").unwrap(), &cfg);
    assert_eq!(ev.outcomes.len(), 10_000);
    assert_eq!(di.outcomes.len(), 10_000);
    let (le, ld) = (ev.latency_summary(), di.latency_summary());
    for (name, a, b) in [("p50", le.p50, ld.p50), ("p95", le.p95, ld.p95)] {
        let rel = (a - b).abs() / b;
        assert!(rel < 0.05, "latency {name}: event {a} vs direct {b} ({:.2}% apart)", rel * 100.0);
    }
}

#[test]
fn event_backend_completes_100k_requests_single_threaded() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = TrafficConfig {
        devices: 4,
        rate: 400.0,
        requests: 100_000,
        input_tokens: LenRange::new(8, 16),
        output_tokens: LenRange::new(1, 4),
        queue_capacity: 64,
        followup: 0.4,
        seed: 7,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let rep =
        run_traffic_events(&sys, &model, &table, policy_from_name("least-loaded").unwrap(), &cfg);
    assert_eq!(rep.outcomes.len(), 100_000);
    assert_eq!(rep.accepted() + rep.rejected(), 100_000);
    assert!(rep.accepted() > 50_000, "only {} accepted", rep.accepted());
    assert!(rep.makespan.secs() > 0.0);
    let lat = rep.latency_summary();
    assert!(lat.p50 > 0.0 && lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
    for u in &rep.device_utilization {
        assert!((0.0..=1.0).contains(u), "utilization {u}");
    }
}

/// TTFT on the event backend includes queueing, the PCIe KV upload, the
/// SLC prompt write, and the first decode step — for an unqueued fresh
/// request that sum is exact and reconstructable from the components.
#[test]
fn ttft_decomposes_into_upload_write_and_first_step() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let cfg = TrafficConfig {
        devices: 1,
        rate: 1.0,
        requests: 1,
        input_tokens: LenRange::fixed(256),
        output_tokens: LenRange::fixed(8),
        queue_capacity: 4,
        followup: 0.0,
        seed: 3,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    let rep = run_traffic_events(&sys, &model, &table, policy_from_name("rr").unwrap(), &cfg);
    assert_eq!(rep.accepted(), 1);
    let o = &rep.outcomes[0];
    let link = PcieLink::new(&sys.ctrl);
    let expect = link.transfer_time(model.kv_bytes(256, 1.0))
        + SimTime::from_secs(initial_kv_write_time(&sys, &model, 256))
        + table.step_time(256);
    assert_eq!(o.ttft().unwrap(), expect);
    // The remaining 7 decode steps complete the turn.
    let mut rest = SimTime::ZERO;
    for step in 1..8 {
        rest += table.step_time(256 + step);
    }
    assert_eq!(o.latency(), expect + rest);
}
