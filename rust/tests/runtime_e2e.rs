//! End-to-end runtime tests over the real AOT artifacts. These require
//! `make artifacts` to have run; they skip (with a note) otherwise so
//! `cargo test` stays green on a fresh checkout.

use flashpim::coordinator::serve::{Coordinator, Engine, Job};
use flashpim::runtime::{ArtifactBundle, ByteTokenizer, DecodeExecutor};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = ArtifactBundle::default_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn bundle_loads_and_is_consistent() {
    let Some(dir) = artifacts() else { return };
    let b = ArtifactBundle::load(&dir).unwrap();
    assert_eq!(b.vocab, 256);
    assert!(b.weights.len() > 10);
    // First two weights are the embeddings with the manifest's dims.
    assert_eq!(b.weights[0].1.shape, vec![b.vocab, b.d_model]);
    assert_eq!(b.weights[1].1.shape, vec![b.max_seq, b.d_model]);
}

#[test]
fn decode_step_runs_and_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mut e1 = DecodeExecutor::load(&dir).unwrap();
    let mut e2 = DecodeExecutor::load(&dir).unwrap();
    let l1 = e1.step(104).unwrap();
    let l2 = e2.step(104).unwrap();
    assert_eq!(l1.len(), e1.bundle.vocab);
    assert_eq!(l1, l2, "decode must be deterministic");
}

#[test]
fn generation_continues_training_corpus() {
    let Some(dir) = artifacts() else { return };
    let tok = ByteTokenizer;
    let mut exec = DecodeExecutor::load(&dir).unwrap();
    let out = exec.generate(&tok.encode("the flash "), 24, &mut |_| {}).unwrap();
    let text = tok.decode(&out);
    // The trained char-LM must continue with corpus-like text: ascii,
    // mostly lowercase words.
    assert!(!text.is_empty());
    let alpha = text.chars().filter(|c| c.is_ascii_lowercase() || *c == ' ').count();
    assert!(
        alpha as f64 / text.len() as f64 > 0.8,
        "continuation does not look like corpus text: {text:?}"
    );
}

#[test]
fn kv_reset_between_sequences() {
    let Some(dir) = artifacts() else { return };
    let mut exec = DecodeExecutor::load(&dir).unwrap();
    let a = exec.generate(&[116, 104, 101, 32], 8, &mut |_| {}).unwrap(); // "the "
    let b = exec.generate(&[116, 104, 101, 32], 8, &mut |_| {}).unwrap();
    assert_eq!(a, b, "reset() must clear sequence state");
}

#[test]
fn coordinator_serves_functional_jobs() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::new(move || DecodeExecutor::load(&dir).unwrap());
    let tok = ByteTokenizer;
    let served = coord
        .run(Job { id: 9, prompt: tok.encode("a plane reads "), max_new: 12 })
        .unwrap();
    assert_eq!(served.tokens.len(), 12);
    assert!(served.wall > 0.0);
    assert!(served.ttft <= served.wall);
}

#[test]
fn max_seq_budget_respected() {
    let Some(dir) = artifacts() else { return };
    let mut exec = DecodeExecutor::load(&dir).unwrap();
    let max_seq = exec.bundle.max_seq;
    let prompt: Vec<u32> = (0..max_seq as u32 - 4).map(|i| 97 + (i % 26)).collect();
    let out = exec.generate(&prompt, 100, &mut |_| {}).unwrap();
    assert!(out.len() <= 4, "budget {} exceeded: {}", 4, out.len());
}
