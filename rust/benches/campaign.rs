//! Campaign-path bench: filter parsing/selection throughput and a small
//! end-to-end campaign slice over both backends — keeps the campaign
//! code path compiling under `cargo bench --no-run` and gives its cost a
//! number. Budget knob: `BENCH_CAMPAIGN_REQUESTS` (requests/scenario).

use flashpim::campaign::{Backend, CampaignSpec, Expr, run_campaign};
use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::llm::LatencyTable;
use flashpim::llm::model_config::OptModel;
use flashpim::util::benchkit::{quick, section};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    section("Campaign: filter DSL");

    let src = "policy(slo-aware) & (class(chat) | workload(agentic-burst)) & rate > 5 \
               & !backend(threaded)";
    quick("filter parse (5 atoms)", || Expr::parse(src).expect("valid filter"));

    let spec = CampaignSpec::default();
    let scenarios = spec.expand().expect("default matrix expands");
    let filter = Expr::parse(src).expect("valid filter");
    let r = quick("filter select over default matrix", || {
        scenarios.iter().filter(|s| filter.matches(&s.view())).count()
    });
    println!(
        "  -> {} of {} scenarios selected, {:.1} M scenario-matches/s",
        scenarios.iter().filter(|s| filter.matches(&s.view())).count(),
        scenarios.len(),
        scenarios.len() as f64 / r.summary.mean / 1e6
    );

    section("Campaign: small end-to-end slice");

    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let slice = CampaignSpec {
        policies: vec!["least-loaded".into(), "slo-aware".into()],
        workloads: vec!["chat".into()],
        backends: Backend::ALL.to_vec(),
        rates: vec![8.0, 32.0],
        fleets: Vec::new(),
        devices: 4,
        requests: env_usize("BENCH_CAMPAIGN_REQUESTS", 2000),
        seed: 7,
        wear: None,
        faults: None,
    };
    let n = slice.expand().expect("slice expands").len();
    let r = quick("campaign slice (2 policies x 2 rates x 2 backends)", || {
        run_campaign(&sys, &model, &table, &slice, None).expect("campaign runs")
    });
    println!("  -> {:.3} s per {n}-scenario slice", r.summary.mean);
}
