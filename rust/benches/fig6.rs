//! Bench: regenerate paper Fig. 6 (plane-size sweeps: latency, energy,
//! density) and time the circuit model + DSE.

use flashpim::circuit::TechParams;
use flashpim::config::presets::size_a_plane;
use flashpim::dse::select::{select_plane, SelectionCriteria};
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Fig 6 — plane configuration sweeps");
    print!("{}", flashpim::exp::fig6::render());

    section("timing");
    let tech = TechParams::default();
    quick("circuit model, one plane", || {
        flashpim::circuit::PlaneLatency::of(&size_a_plane(), &tech).t_pim(8)
    });
    quick("fig6 sweeps (3 axes)", || flashpim::dse::sweep::fig6_sweeps(&tech));
    quick("DSE full-grid selection", || select_plane(&SelectionCriteria::default(), &tech));
}
