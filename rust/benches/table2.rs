//! Bench: regenerate paper Table II (area breakdown) + §V-C die budget.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Table II — area breakdown per plane");
    print!("{}", flashpim::exp::table2::render());

    section("timing");
    let tech = TechParams::default();
    let sys = table1_system();
    quick("area model", || flashpim::area::peri::AreaModel::new(&tech).breakdown(&sys));
}
