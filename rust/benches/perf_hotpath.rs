//! Perf bench: the L3 hot paths — DES engine event throughput, resource
//! scheduling, tiling search, TPOT estimation, serving simulation, and
//! (when artifacts exist) the PJRT decode step. Tracked in
//! EXPERIMENTS.md §Perf.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{simulate, Workload};
use flashpim::gpu::rtx4090x4_vllm;
use flashpim::llm::model_config::OptModel;
use flashpim::llm::schedule::TokenSchedule;
use flashpim::sim::{Engine, EventQueue, Model, Resource, SimTime};
use flashpim::util::benchkit::{bench, quick, section, BenchConfig};

/// Self-scheduling event storm for raw queue throughput.
struct Storm {
    remaining: u64,
}

impl Model for Storm {
    type Event = u32;

    fn handle(&mut self, _now: SimTime, ev: u32, q: &mut EventQueue<u32>) {
        if self.remaining > 0 {
            self.remaining -= 1;
            // Fan out to keep the heap busy.
            q.schedule_in(SimTime(1 + (ev as u64 % 97)), ev.wrapping_mul(31));
            if ev % 4 == 0 {
                q.schedule_in(SimTime(5), ev.wrapping_add(7));
            }
        }
    }
}

fn main() {
    section("L3 hot paths");

    const EVENTS: u64 = 200_000;
    let r = bench("DES engine 200k events", &BenchConfig::default(), || {
        let mut e = Engine::new(Storm { remaining: EVENTS });
        e.seed(SimTime::ZERO, 1);
        e.run();
        e.events_processed()
    });
    r.print();
    println!(
        "  -> {:.1} M events/s",
        EVENTS as f64 / r.summary.mean / 1e6
    );

    let r = bench("resource timeline 1M acquires", &BenchConfig::default(), || {
        let mut res = Resource::new();
        for i in 0..1_000_000u64 {
            res.acquire(SimTime(i), SimTime(3));
        }
        res.free_at()
    });
    r.print();
    println!("  -> {:.1} M acquires/s", 1.0 / r.summary.mean);

    quick("tiling search d_m=7168", || {
        flashpim::tiling::search_best(
            &flashpim::exp::fig12::model(),
            flashpim::pim::op::MvmShape::new(7168, 7168),
        )
    });

    let sys = table1_system();
    let mut sched = TokenSchedule::new(&sys, &TechParams::default(), OptModel::Opt30b.shape());
    sched.tpot(1024); // warm the shape cache
    quick("TPOT estimate (warm)", || sched.tpot(1024));

    quick("serving sim: 64 requests", || {
        let wl = Workload::synthetic(64, 0.5, 0.4, 256, 64, 3);
        simulate(&sys, &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl)
    });

    // Functional decode step, only when artifacts are present.
    if flashpim::runtime::ArtifactBundle::available() {
        section("PJRT decode step (artifacts found)");
        let dir = flashpim::runtime::ArtifactBundle::default_dir();
        let mut exec = flashpim::runtime::DecodeExecutor::load(&dir).expect("load artifacts");
        let cfg = BenchConfig { warmup_iters: 3, iters: 50, ..Default::default() };
        let r = bench("decode step (1 token)", &cfg, || {
            if exec.position() + 1 >= exec.bundle.max_seq {
                exec.reset();
            }
            exec.step(104).unwrap()
        });
        r.print();
        println!("  -> {:.1} tok/s functional", 1.0 / r.summary.mean);
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT decode bench)");
    }
}
