//! Perf bench: the L3 hot paths — DES engine event throughput, resource
//! scheduling, tiling search, TPOT estimation, the serving event backend
//! (decode coalescing, million-request traces, parallel frontier sweeps),
//! and (when artifacts exist) the PJRT decode step. The design behind the
//! serving numbers is documented in docs/ARCHITECTURE.md §"Performance
//! architecture".
//!
//! Machine-readable output: pass `--json PATH` (as `make bench-json`
//! does) to write the headline metrics — events/s, requests/s, sweep
//! wall-clock — as `BENCH_serving.json` for per-PR tracking. Budget
//! knobs for CI: `BENCH_ITERS` (measured iterations of the serving
//! benches), `BENCH_REQUESTS` (big-trace size, default 1M),
//! `BENCH_SWEEP_REQUESTS` (requests per sweep point).

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{
    DecodeMode, policy_from_name, run_traffic_events_counted, simulate, sweep_rates,
    TrafficConfig, Workload, WorkloadMix,
};
use flashpim::gpu::rtx4090x4_vllm;
use flashpim::llm::model_config::OptModel;
use flashpim::llm::schedule::TokenSchedule;
use flashpim::llm::LatencyTable;
use flashpim::sim::{Engine, EventQueue, Model, Resource, SimTime};
use flashpim::util::benchkit::{bench, quick, section, BenchConfig, JsonEmitter};
use std::path::PathBuf;
use std::time::Duration;

/// Self-scheduling event storm for raw queue throughput.
struct Storm {
    remaining: u64,
}

impl Model for Storm {
    type Event = u32;

    fn handle(&mut self, _now: SimTime, ev: u32, q: &mut EventQueue<u32>) {
        if self.remaining > 0 {
            self.remaining -= 1;
            // Fan out to keep the heap busy.
            q.schedule_in(SimTime(1 + (ev as u64 % 97)), ev.wrapping_mul(31));
            if ev % 4 == 0 {
                q.schedule_in(SimTime(5), ev.wrapping_add(7));
            }
        }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `--json [PATH]` from the bench's own arguments; every other argument
/// (e.g. the `--bench` cargo appends) is ignored. A bare `--json` writes
/// to `BENCH_serving.json` in the current directory.
fn json_path() -> Option<PathBuf> {
    let mut args = std::env::args().skip(1).peekable();
    while let Some(a) = args.next() {
        if a == "--json" {
            let explicit = args.peek().filter(|next| !next.starts_with("--"));
            return Some(PathBuf::from(
                explicit.map(String::as_str).unwrap_or("BENCH_serving.json"),
            ));
        }
    }
    None
}

fn main() {
    let mut json = JsonEmitter::new();

    section("L3 hot paths");

    const EVENTS: u64 = 200_000;
    let r = bench("DES engine 200k events", &BenchConfig::default(), || {
        let mut e = Engine::new(Storm { remaining: EVENTS });
        e.seed(SimTime::ZERO, 1);
        e.run();
        e.events_processed()
    });
    r.print();
    println!(
        "  -> {:.1} M events/s",
        EVENTS as f64 / r.summary.mean / 1e6
    );
    json.metric("des_storm_events_per_s", EVENTS as f64 / r.summary.mean, "events/s");

    let r = bench("resource timeline 1M acquires", &BenchConfig::default(), || {
        let mut res = Resource::new();
        for i in 0..1_000_000u64 {
            res.acquire(SimTime(i), SimTime(3));
        }
        res.free_at()
    });
    r.print();
    println!("  -> {:.1} M acquires/s", 1.0 / r.summary.mean);

    quick("tiling search d_m=7168", || {
        flashpim::tiling::search_best(
            &flashpim::exp::fig12::model(),
            flashpim::pim::op::MvmShape::new(7168, 7168),
        )
    });

    let sys = table1_system();
    let mut sched = TokenSchedule::new(&sys, &TechParams::default(), OptModel::Opt30b.shape());
    sched.tpot(1024); // warm the shape cache
    quick("TPOT estimate (warm)", || sched.tpot(1024));

    quick("serving sim: 64 requests", || {
        let wl = Workload::synthetic(64, 0.5, 0.4, 256, 64, 3);
        simulate(&sys, &OptModel::Opt6_7b.shape(), &rtx4090x4_vllm(), &wl)
    });

    section("Serving event backend (decode coalescing, streaming sweeps)");

    let iters = env_usize("BENCH_ITERS", 5);
    let scfg = BenchConfig { warmup_iters: 1, iters, max_total: Duration::from_secs(60) };
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let ll = || policy_from_name("least-loaded").expect("known policy");

    // Event accounting: the same 20k-request trace under both decode
    // modes. The reports are bit-identical; only the event count differs.
    let mut acct = TrafficConfig::default_for(4);
    acct.requests = 20_000;
    acct.rate = 30.0;
    let (rep_c, ev_coalesced) =
        run_traffic_events_counted(&sys, &model, &table, ll(), &acct, DecodeMode::Coalesced);
    let (rep_t, ev_per_token) =
        run_traffic_events_counted(&sys, &model, &table, ll(), &acct, DecodeMode::PerToken);
    assert_eq!(rep_c, rep_t, "decode modes must agree bit for bit");
    let ratio = ev_per_token as f64 / ev_coalesced as f64;
    println!(
        "events per 20k-request run: coalesced {ev_coalesced} vs per-token {ev_per_token} \
         ({ratio:.1}x fewer)"
    );
    json.metric("serving_events_coalesced_per_run", ev_coalesced as f64, "events");
    json.metric("serving_events_per_token_per_run", ev_per_token as f64, "events");
    json.metric("serving_event_coalescing_ratio", ratio, "x");

    // Headline trace: BENCH_REQUESTS (default 1M) requests end to end.
    // The trace is deterministic, so the event count is captured from the
    // timed runs themselves — no extra untimed pass.
    let requests = env_usize("BENCH_REQUESTS", 1_000_000);
    let mut big = TrafficConfig::default_for(8);
    big.requests = requests;
    big.rate = 60.0;
    let mut big_events = 0u64;
    let name = format!("serving trace: {requests} requests (coalesced)");
    let r = bench(&name, &scfg, || {
        let (rep, ev) =
            run_traffic_events_counted(&sys, &model, &table, ll(), &big, DecodeMode::Coalesced);
        big_events = ev;
        rep
    });
    r.print();
    let req_per_s = requests as f64 / r.summary.mean;
    let ev_per_s = big_events as f64 / r.summary.mean;
    println!("  -> {:.2} M requests/s, {:.2} M engine events/s", req_per_s / 1e6, ev_per_s / 1e6);
    json.result(&r);
    json.metric("serving_trace_requests", requests as f64, "requests");
    json.metric("serving_trace_requests_per_s", req_per_s, "requests/s");
    json.metric("serving_trace_events_per_s", ev_per_s, "events/s");

    // Full SLO-frontier sweep: every policy x 8 rates on a multi-class
    // mix, fanned out on scoped threads with streaming sinks.
    let mut sw = TrafficConfig::default_for(4);
    sw.requests = env_usize("BENCH_SWEEP_REQUESTS", 20_000);
    sw.workload = Some(WorkloadMix::preset("agentic-burst").expect("built-in preset"));
    let rates: Vec<f64> = (1..=8).map(|i| 4.0 * i as f64).collect();
    let all = ["round-robin", "least-loaded", "slo-aware"];
    let name = format!("frontier sweep: 3 policies x 8 rates x {} req", sw.requests);
    let r = bench(&name, &scfg, || {
        sweep_rates(&sys, &model, &table, &sw, &rates, &all).expect("valid sweep grid")
    });
    r.print();
    println!("  -> {:.2} s per full 24-point sweep", r.summary.mean);
    json.result(&r);
    json.metric("sweep_frontier_wall_s", r.summary.mean, "s");
    json.metric("sweep_frontier_points", (rates.len() * all.len()) as f64, "points");

    // Functional decode step, only when artifacts are present.
    if flashpim::runtime::ArtifactBundle::available() {
        section("PJRT decode step (artifacts found)");
        let dir = flashpim::runtime::ArtifactBundle::default_dir();
        let mut exec = flashpim::runtime::DecodeExecutor::load(&dir).expect("load artifacts");
        let cfg = BenchConfig { warmup_iters: 3, iters: 50, ..Default::default() };
        let r = bench("decode step (1 token)", &cfg, || {
            if exec.position() + 1 >= exec.bundle.max_seq {
                exec.reset();
            }
            exec.step(104).unwrap()
        });
        r.print();
        println!("  -> {:.1} tok/s functional", 1.0 / r.summary.mean);
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT decode bench)");
    }

    if let Some(path) = json_path() {
        // Parent directories are created on demand; an unwritable path
        // (e.g. a read-only mount) is a clean error, not a panic.
        if let Err(e) = json.write(&path) {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
        println!("\nwrote {} bench metrics to {}", json.len(), path.display());
    }
}
