//! Perf bench for the serve-sim hot path: the offline `LatencyTable`
//! build (one exhaustive tiling search per distinct sMVM shape), the O(1)
//! immutable TPOT query that replaced per-thread `TokenSchedule` caches,
//! a single closed-loop run on each backend (the event-driven default vs
//! the legacy direct replay), and the arrival-rate sweep of
//! `serve-sim --sweep` in both its single-threaded event form and its
//! threaded direct cross-check form.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{
    LenRange, policy_from_name, run_traffic_events, run_traffic_with_table, sweep_rates,
    sweep_rates_threaded, TrafficConfig,
};
use flashpim::llm::LatencyTable;
use flashpim::llm::model_config::OptModel;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("serve-sim rate sweep");
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();

    quick("LatencyTable build (OPT-6.7B)", || {
        LatencyTable::build(&sys, &TechParams::default(), model.clone())
    });

    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    quick("LatencyTable::tpot query", || table.tpot(1536));

    let cfg = TrafficConfig {
        devices: 4,
        rate: 12.0,
        requests: 2000,
        input_tokens: LenRange::new(64, 128),
        output_tokens: LenRange::new(8, 16),
        queue_capacity: 64,
        followup: 0.3,
        seed: 42,
        workload: None,
        fleet: None,
        wear: None,
        arrival: None,
        faults: None,
    };
    quick("event run: 2k requests, 4 devices", || {
        run_traffic_events(
            &sys,
            &model,
            &table,
            policy_from_name("least-loaded").unwrap(),
            &cfg,
        )
    });
    quick("direct run: 2k requests, 4 devices", || {
        run_traffic_with_table(
            &sys,
            &model,
            &table,
            policy_from_name("least-loaded").unwrap(),
            &cfg,
        )
    });

    quick("event sweep: 2 policies x 3 rates x 2k requests", || {
        sweep_rates(
            &sys,
            &model,
            &table,
            &cfg,
            &[6.0, 12.0, 24.0],
            &["round-robin", "least-loaded"],
        )
        .expect("valid sweep")
    });
    quick("threaded sweep: 2 policies x 3 rates x 2k requests", || {
        sweep_rates_threaded(
            &sys,
            &model,
            &table,
            &cfg,
            &[6.0, 12.0, 24.0],
            &["round-robin", "least-loaded"],
        )
        .expect("valid sweep")
    });
}
