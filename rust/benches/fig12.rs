//! Bench: regenerate paper Fig. 12 (tiling-option latency breakdown) and
//! time the scheme enumeration + search.

use flashpim::pim::op::MvmShape;
use flashpim::tiling::search_best;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Fig 12 — sMVM tiling options");
    print!("{}", flashpim::exp::fig12::render());

    section("timing");
    let model = flashpim::exp::fig12::model();
    quick("enumerate+search d_m=7168", || search_best(&model, MvmShape::new(7168, 7168)));
    quick("enumerate+search FFN 7168x28672", || {
        search_best(&model, MvmShape::new(7168, 28672))
    });
}
