//! Bench: regenerate paper Fig. 5 (conventional vs proposed PIM TPOT,
//! OPT-30B) and time the two TPOT models.

use flashpim::llm::model_config::OptModel;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Fig 5 — TPOT: conventional vs proposed 3D NAND PIM (OPT-30B)");
    print!("{}", flashpim::exp::fig5::render());

    section("timing");
    quick("conventional TPOT model", || {
        flashpim::exp::fig5::conventional_tpot(OptModel::Opt30b, 1536)
    });
    quick("fig5 full", flashpim::exp::fig5::fig5);
}
