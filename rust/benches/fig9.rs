//! Bench: regenerate paper Fig. 9 (shared bus vs H-tree; Size A vs B)
//! and time the pipelined sMVM executor.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::nand::NandTiming;
use flashpim::pim::op::MvmShape;
use flashpim::pim::smvm::SmvmPipeline;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Fig 9 — intra-die bus architecture");
    print!("{}", flashpim::exp::fig9::render());

    section("timing");
    let sys = table1_system();
    let timing = NandTiming::of_system(&sys, &TechParams::default());
    let pipe = SmvmPipeline::new(&sys, timing, 64);
    quick("sMVM pipeline (1K,1K)", || pipe.execute(MvmShape::new(1024, 1024)));
    quick("sMVM pipeline (4K,4K)", || pipe.execute(MvmShape::new(4096, 4096)));
    quick("fig9 full (a+b)", || {
        (flashpim::exp::fig9::fig9a(), flashpim::exp::fig9::fig9b())
    });
}
