//! Bench: regenerate paper Fig. 14 (TPOT across OPT models vs GPU
//! baselines; execution-time breakdown vs token lengths) and time the
//! TPOT estimator.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::llm::model_config::OptModel;
use flashpim::llm::schedule::TokenSchedule;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Fig 14a — TPOT across OPT model sizes");
    let rows = flashpim::exp::fig14::fig14a();
    print!("{}", flashpim::exp::fig14::render_fig14a(&rows));

    section("Fig 14b — execution-time breakdown (OPT-30B)");
    print!("{}", flashpim::exp::fig14::render_fig14b(&flashpim::exp::fig14::fig14b()));

    section("timing");
    let sys = table1_system();
    quick("TokenSchedule::tpot OPT-30B (cold)", || {
        let mut s = TokenSchedule::new(&sys, &TechParams::default(), OptModel::Opt30b.shape());
        s.tpot(1024)
    });
    let mut warm = TokenSchedule::new(&sys, &TechParams::default(), OptModel::Opt30b.shape());
    warm.tpot(1024);
    quick("TokenSchedule::tpot OPT-30B (warm cache)", || warm.tpot(1024));
}
