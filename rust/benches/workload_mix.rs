//! Perf bench for the multi-class workload path: per-arrival class
//! sampling on top of the shared RNG stream (a preset mix vs the legacy
//! single-class stream), the SLO-aware scheduler against least-loaded at
//! the same offered load, and the per-class report reduction.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{policy_from_name, run_traffic_events, TrafficConfig, WorkloadMix};
use flashpim::llm::model_config::OptModel;
use flashpim::llm::LatencyTable;
use flashpim::util::benchkit::{quick, section};

fn main() {
    section("multi-class workload serving");
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());

    let mut cfg = TrafficConfig::default_for(4);
    cfg.rate = 12.0;
    cfg.requests = 2000;

    quick("single-class event run: 2k requests, 4 devices", || {
        run_traffic_events(&sys, &model, &table, policy_from_name("least-loaded").unwrap(), &cfg)
    });

    let mut mixed = cfg.clone();
    mixed.workload = Some(WorkloadMix::preset("agentic-burst").expect("built-in preset"));
    quick("agentic-burst event run: 2k requests, 4 devices", || {
        run_traffic_events(&sys, &model, &table, policy_from_name("least-loaded").unwrap(), &mixed)
    });
    quick("agentic-burst under slo-aware scheduling", || {
        run_traffic_events(&sys, &model, &table, policy_from_name("slo-aware").unwrap(), &mixed)
    });

    let report = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("slo-aware").unwrap(),
        &mixed,
    );
    quick("per-class report reduction over 2k outcomes", || report.class_reports());
}
