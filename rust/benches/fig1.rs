//! Bench: regenerate paper Fig. 1 (memory wall + generation/summarization
//! gap) and time the underlying models.

use flashpim::util::benchkit::{quick, section};

fn main() {
    section("Fig 1a/1b — memory requirements & latency gap");
    print!("{}", flashpim::exp::fig1::render());

    section("timing");
    quick("fig1a rows", flashpim::exp::fig1::fig1a);
    quick("fig1b roofline", flashpim::exp::fig1::fig1b);
}
