//! Minimal, dependency-free drop-in for the subset of the `anyhow` API this
//! workspace uses: [`Result`], [`Error`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the [`Context`] extension trait for `Result` and
//! `Option`.
//!
//! Vendored as a path dependency so `cargo build` needs no network access to
//! crates.io. Error values are plain message chains — no backtraces, no
//! downcasting — but the surface matches `anyhow` 1.x closely enough that
//! swapping back to the crates.io package is a one-line `Cargo.toml` change.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message with an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> Vec<&str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        items
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(c) = cur.cause.as_deref() {
            cur = c;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, `anyhow`-style.
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        let mut i = 0usize;
        while let Some(e) = cur {
            write!(f, "\n    {i}: {}", e.msg)?;
            cur = e.cause.as_deref();
            i += 1;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        // Flatten the std error's source chain into message form.
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn StdError + 'static)> = err.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut cause = None;
        for m in msgs.into_iter().rev() {
            cause = Some(Box::new(Error { msg: m, cause }));
        }
        Error { msg: err.to_string(), cause }
    }
}

#[doc(hidden)]
pub mod ext {
    use super::{Error, StdError};

    /// Errors that can absorb an outer context message: implemented for
    /// std errors and for [`Error`] itself (mirrors `anyhow`'s internal
    /// `ext_context` structure).
    pub trait ErrorLike {
        fn apply_context(self, msg: String) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> ErrorLike for E {
        fn apply_context(self, msg: String) -> Error {
            Error::from(self).context(msg)
        }
    }

    impl ErrorLike for Error {
        fn apply_context(self, msg: String) -> Error {
            self.context(msg)
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Attach a context message to the error (or `None`) case.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: ext::ErrorLike> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.apply_context(context.to_string()))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.apply_context(f().to_string()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn macro_formats() {
        let n = 3;
        let e = anyhow!("bad value {n}");
        assert_eq!(e.to_string(), "bad value 3");
        let e2 = anyhow!("pair {} and {}", 1, 2);
        assert_eq!(e2.to_string(), "pair 1 and 2");
    }

    #[test]
    fn bail_returns_error() {
        fn f() -> Result<()> {
            bail!("stop at {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop at 7");
    }

    #[test]
    fn ensure_checks_condition() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert!(f(-1).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config");
        assert_eq!(e.root_cause(), "missing file");
        assert_eq!(format!("{e:#}"), "loading config: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn context_chains_on_own_error() {
        let e = Error::msg("inner");
        let r: Result<()> = Err(e);
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.chain(), vec!["outer", "inner"]);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }
}
