//! Fleet-level fault state: per-slot health, spare activation on device
//! loss, brownout tracking, and the reliability summary.
//!
//! `FleetFaults` owns one [`FaultTimeline`] per slot (primaries and
//! spares alike) plus the recovery counters both serving backends feed.
//! It deliberately knows nothing about scheduling: backends ask it
//! whether a slot is schedulable, dilate service through it, and notify
//! it of hard failures and wear retirements so the two retirement
//! mechanisms share one dormant-spare pool.

use super::spec::FaultConfig;
use super::timeline::FaultTimeline;
use crate::sim::SimTime;

/// Lifecycle of one roster slot under fault injection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Health {
    /// In the schedulable pool.
    Serving,
    /// Provisioned cold spare, waiting for a failure or wear retirement.
    Dormant,
    /// Hard-failed and dropped; never returns.
    Down,
    /// Retired by the wear path (drained exit, not a fault).
    Retired,
}

/// What a `DeviceDown` notification amounted to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownAction {
    /// A serving device was lost; `activated` names the spare slot that
    /// took its place in the pool, if any was left.
    Fail { activated: Option<usize> },
    /// The slot was already out of the pool (dormant spare, wear-retired,
    /// or double failure) — nothing to do.
    Ignore,
}

/// Reliability metrics for one run, rendered in reports and exported as
/// sweep/campaign columns.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSummary {
    /// Read-retry storms that began before the makespan, fleet-wide.
    pub storms: u64,
    /// Total device-seconds spent inside storms (clipped to makespan).
    pub storm_s: f64,
    /// Hard device failures that struck serving devices.
    pub device_failures: usize,
    /// Requests permanently failed after exhausting the retry budget.
    pub failed_requests: u64,
    /// Retry attempts scheduled (successful or not).
    pub retries: u64,
    /// Requests re-admitted on a surviving device after losing their KV.
    pub failovers: u64,
    /// Tokens re-prefilled by failovers (full context, KV was lost).
    pub re_prefill_tokens: u64,
    /// Fresh arrivals shed by the brownout policy.
    pub shed_brownout: u64,
    /// Fraction of nominal device-seconds that were actually serving:
    /// `1 - lost_device_seconds / (nominal_devices * makespan)`.
    pub availability: f64,
    /// Seconds the fleet ran with at least one serving device lost
    /// (makespan minus the earliest failure instant).
    pub degraded_s: f64,
}

/// Per-fleet fault state threaded through a serving backend.
#[derive(Debug, Clone)]
pub struct FleetFaults {
    cfg: FaultConfig,
    timelines: Vec<FaultTimeline>,
    health: Vec<Health>,
    /// Primary roster size (denominator for availability/brownout).
    nominal: usize,
    /// Slots currently in the schedulable pool.
    serving: usize,
    /// Instants at which serving devices were lost.
    down_times: Vec<SimTime>,
    /// Hard failures that struck serving devices.
    pub device_failures: usize,
    /// Requests permanently failed after exhausting retries.
    pub failed_requests: u64,
    /// Retry attempts scheduled.
    pub retries: u64,
    /// Successful KV-loss failovers.
    pub failovers: u64,
    /// Tokens re-prefilled by failovers.
    pub re_prefill_tokens: u64,
    /// Fresh arrivals shed by brownout.
    pub shed_brownout: u64,
}

impl FleetFaults {
    /// Build the fleet's fault state. `flash[i]` says whether slot `i`
    /// is flash-tier (only flash slots storm or hard-fail); `nominal` is
    /// the primary roster size — slots at or past it start dormant.
    /// Wear spares and fault spares form one pool: whichever mechanism
    /// (hard failure or wear retirement) needs a replacement activates
    /// the lowest-index dormant slot.
    pub fn new(cfg: &FaultConfig, seed: u64, flash: &[bool], nominal: usize) -> FleetFaults {
        let timelines: Vec<FaultTimeline> = flash
            .iter()
            .enumerate()
            .map(|(slot, &fl)| FaultTimeline::new(cfg, seed, slot, fl))
            .collect();
        let health: Vec<Health> = (0..flash.len())
            .map(|slot| if slot < nominal { Health::Serving } else { Health::Dormant })
            .collect();
        FleetFaults {
            cfg: cfg.clone(),
            timelines,
            health,
            nominal,
            serving: nominal.min(flash.len()),
            down_times: Vec::new(),
            device_failures: 0,
            failed_requests: 0,
            retries: 0,
            failovers: 0,
            re_prefill_tokens: 0,
            shed_brownout: 0,
        }
    }

    /// Extra roster slots this config provisions as cold spares.
    pub fn spares(cfg: &FaultConfig) -> usize {
        cfg.spares
    }

    /// Retry budget per request.
    pub fn retry_budget(&self) -> u32 {
        self.cfg.retries
    }

    /// Delay before retry attempt `attempt` (1-based): exponential
    /// backoff doubling from the configured base.
    pub fn backoff(&self, attempt: u32) -> SimTime {
        let factor = 2.0f64.powi(attempt.saturating_sub(1).min(32) as i32);
        SimTime::from_secs(self.cfg.backoff_s * factor)
    }

    /// Whether slot `i` may take new work.
    pub fn schedulable(&self, i: usize) -> bool {
        self.health[i] == Health::Serving
    }

    /// All hard-failure drop instants, in slot order. Backends turn
    /// these into `DeviceDown` events before the trace starts, so the
    /// fault schedule is fixed before the first arrival is drawn.
    pub fn down_events(&self) -> Vec<(SimTime, usize)> {
        self.timelines
            .iter()
            .enumerate()
            .filter_map(|(slot, t)| t.down_at.map(|at| (at, slot)))
            .collect()
    }

    /// Dilate `work` starting at `start` on slot `slot` through its
    /// storm timeline (identity for non-flash or storm-free slots).
    pub fn dilate(&mut self, slot: usize, start: SimTime, work: SimTime) -> SimTime {
        self.timelines[slot].dilate(start, work)
    }

    /// Brownout: while fewer than `brownout * nominal` slots survive,
    /// fresh arrivals of every class but class 0 are shed. Retries are
    /// exempt — a session already admitted keeps its retry budget.
    pub fn brownout_active(&self) -> bool {
        self.cfg.brownout > 0.0
            && (self.serving as f64) < self.cfg.brownout * self.nominal as f64
    }

    /// A slot's deadline timer fired: drop it from the pool and activate
    /// the lowest-index dormant spare, if one remains.
    pub fn on_down(&mut self, slot: usize, now: SimTime) -> DownAction {
        match self.health[slot] {
            Health::Serving => {
                self.health[slot] = Health::Down;
                self.serving -= 1;
                self.down_times.push(now);
                self.device_failures += 1;
                let activated = self.activate_spare();
                DownAction::Fail { activated }
            }
            Health::Dormant => {
                // The spare died before it was ever activated: it simply
                // leaves the dormant pool.
                self.health[slot] = Health::Down;
                DownAction::Ignore
            }
            Health::Down | Health::Retired => DownAction::Ignore,
        }
    }

    /// The wear path retired `slot` (planned, drained exit) and, if
    /// `activated` is set, promoted that spare — mirror both transitions
    /// so the two mechanisms agree on which spares are left.
    pub fn on_wear_retire(&mut self, slot: usize, activated: Option<usize>) {
        if self.health[slot] == Health::Serving {
            self.health[slot] = Health::Retired;
            self.serving -= 1;
        }
        if let Some(s) = activated {
            if self.health[s] == Health::Dormant {
                self.health[s] = Health::Serving;
                self.serving += 1;
            }
        }
    }

    fn activate_spare(&mut self) -> Option<usize> {
        let slot = self.health.iter().position(|&h| h == Health::Dormant)?;
        self.health[slot] = Health::Serving;
        self.serving += 1;
        Some(slot)
    }

    /// Fold the run into its reliability summary. `makespan` clips storm
    /// statistics and down time.
    pub fn summary(&mut self, makespan: SimTime) -> FaultSummary {
        let mut storms = 0u64;
        let mut storm_s = 0.0f64;
        for t in &mut self.timelines {
            let (n, s) = t.storms_within(makespan);
            storms += n;
            storm_s += s;
        }
        let horizon = makespan.secs();
        let lost: f64 = self
            .down_times
            .iter()
            .map(|&d| (horizon - d.secs()).max(0.0))
            .sum();
        let availability = if horizon > 0.0 && self.nominal > 0 {
            (1.0 - lost / (self.nominal as f64 * horizon)).max(0.0)
        } else {
            1.0
        };
        let degraded_s = self
            .down_times
            .iter()
            .map(|&d| (horizon - d.secs()).max(0.0))
            .fold(0.0f64, f64::max);
        FaultSummary {
            storms,
            storm_s,
            device_failures: self.device_failures,
            failed_requests: self.failed_requests,
            retries: self.retries,
            failovers: self.failovers,
            re_prefill_tokens: self.re_prefill_tokens,
            shed_brownout: self.shed_brownout,
            availability,
            degraded_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with(spares: usize, brownout: f64) -> FaultConfig {
        FaultConfig {
            fail_at: vec![(0, 10.0)],
            spares,
            brownout,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn down_activates_lowest_dormant_spare_once() {
        let cfg = cfg_with(1, 0.0);
        // 2 primaries + 1 spare, all flash.
        let mut f = FleetFaults::new(&cfg, 7, &[true, true, true], 2);
        assert!(f.schedulable(0) && f.schedulable(1) && !f.schedulable(2));
        let t = SimTime::from_secs(10.0);
        assert_eq!(f.on_down(0, t), DownAction::Fail { activated: Some(2) });
        assert!(!f.schedulable(0) && f.schedulable(2));
        // Second notification for the same slot is a no-op.
        assert_eq!(f.on_down(0, t), DownAction::Ignore);
        // Next failure finds no spare left.
        assert_eq!(f.on_down(1, t), DownAction::Fail { activated: None });
        assert_eq!(f.device_failures, 2);
    }

    #[test]
    fn wear_retirement_shares_the_spare_pool() {
        let cfg = cfg_with(1, 0.0);
        let mut f = FleetFaults::new(&cfg, 7, &[true, true, true], 2);
        // Wear retires slot 1 and activates spare 2 on its side.
        f.on_wear_retire(1, Some(2));
        assert!(!f.schedulable(1) && f.schedulable(2));
        // A later hard failure has no spare left to activate.
        assert_eq!(f.on_down(0, SimTime::from_secs(10.0)), DownAction::Fail { activated: None });
    }

    #[test]
    fn brownout_trips_below_threshold() {
        let cfg = cfg_with(0, 0.75);
        let mut f = FleetFaults::new(&cfg, 7, &[true, true, true, true], 4);
        assert!(!f.brownout_active());
        f.on_down(0, SimTime::from_secs(10.0));
        // 3 of 4 serving = 0.75, not strictly below the threshold.
        assert!(!f.brownout_active());
        f.on_down(1, SimTime::from_secs(11.0));
        assert!(f.brownout_active());
    }

    #[test]
    fn summary_clips_availability_and_degraded_time() {
        let cfg = FaultConfig {
            fail_at: vec![(0, 10.0), (1, 15.0)],
            ..FaultConfig::default()
        };
        let mut f = FleetFaults::new(&cfg, 7, &[true, true], 2);
        f.on_down(0, SimTime::from_secs(10.0));
        f.on_down(1, SimTime::from_secs(15.0));
        let s = f.summary(SimTime::from_secs(20.0));
        // Lost: (20-10) + (20-15) = 15 device-seconds of 40 nominal.
        assert!((s.availability - (1.0 - 15.0 / 40.0)).abs() < 1e-12);
        assert!((s.degraded_s - 10.0).abs() < 1e-12);
        assert_eq!(s.device_failures, 2);
        assert_eq!(s.storms, 0);
        // Failure after makespan contributes nothing.
        let mut g = FleetFaults::new(&cfg, 7, &[true, true], 2);
        g.on_down(0, SimTime::from_secs(30.0));
        let sg = g.summary(SimTime::from_secs(20.0));
        assert_eq!(sg.availability, 1.0);
        assert_eq!(sg.degraded_s, 0.0);
    }

    #[test]
    fn backoff_doubles_per_attempt() {
        let cfg = FaultConfig { backoff_s: 0.5, fail_at: vec![(0, 1.0)], ..FaultConfig::default() };
        let f = FleetFaults::new(&cfg, 7, &[true], 1);
        assert_eq!(f.backoff(1), SimTime::from_secs(0.5));
        assert_eq!(f.backoff(2), SimTime::from_secs(1.0));
        assert_eq!(f.backoff(3), SimTime::from_secs(2.0));
    }

    #[test]
    fn down_events_fix_the_schedule_up_front() {
        let cfg = FaultConfig {
            fail_at: vec![(1, 5.0), (0, 9.0)],
            detect_s: 1.0,
            ..FaultConfig::default()
        };
        let f = FleetFaults::new(&cfg, 7, &[true, true, false], 3);
        let ev = f.down_events();
        assert_eq!(
            ev,
            vec![
                (SimTime::from_secs(10.0), 0),
                (SimTime::from_secs(6.0), 1),
            ]
        );
    }
}
