//! The `--faults` specification: grammar, parsing, and validation.
//!
//! A spec is a comma-separated list of `key=value` items:
//!
//! ```text
//! storm=RATE:MULTxDUR   read-retry storms: Poisson rate per flash
//!                       device (storms/s), service-time multiplier
//!                       while a storm is in force, mean duration (s)
//! fail=RATE             hard failures: Poisson rate per flash device
//!                       (each device fails at most once)
//! fail_at=DEV@SECS      scripted hard failure of one device
//!                       (repeatable; out-of-range slots are ignored)
//! detect=SECS           coordinator deadline-timer delay between a
//!                       device hanging and the pool dropping it
//! retries=N             per-request retry budget after device loss
//! backoff=SECS          base retry backoff; attempt k waits 2^(k-1)x
//! spares=N              cold spare slots provisioned for failover
//! brownout=FRAC         shed all but the highest-priority class when
//!                       fewer than FRAC x devices slots survive
//! ```
//!
//! Example: `storm=0.05:4x2,fail=0.001,detect=0.5,retries=2,spares=1`.
//! See `docs/FAULTS.md` for the full glossary.

use anyhow::{bail, Context, Result};

/// Parsed fault-injection specification (see the module docs for the
/// grammar). A config whose fault processes are all disabled is *inert*;
/// [`FaultConfig::active`] normalizes inert configs to `None` so that
/// `--faults` with rate 0 is byte-identical to no `--faults` at all.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Poisson read-retry-storm rate per flash device (storms/s).
    pub storm_rate: f64,
    /// Service-time multiplier while a storm is in force (>= 1).
    pub storm_mult: u32,
    /// Mean storm duration in seconds (durations draw exponentially).
    pub storm_dur_s: f64,
    /// Poisson hard-failure rate per flash device (failures/s); each
    /// device fails at most once.
    pub fail_rate: f64,
    /// Scripted hard failures: (slot index, seconds). Slots past the
    /// provisioned roster are ignored.
    pub fail_at: Vec<(usize, f64)>,
    /// Deadline-timer detection delay (s): a hung device is dropped from
    /// the pool this long after it stops making progress.
    pub detect_s: f64,
    /// Per-request retry budget after losing a device mid-flight.
    pub retries: u32,
    /// Base retry backoff (s); attempt k is delayed `backoff * 2^(k-1)`.
    pub backoff_s: f64,
    /// Cold spare slots provisioned beyond the primary roster, activated
    /// (no drain window) as devices hard-fail.
    pub spares: usize,
    /// Brownout threshold as a fraction of the nominal roster: while
    /// fewer than `brownout * devices` slots survive, fresh arrivals of
    /// every class but the highest-priority one (class 0) are shed.
    /// `0.0` disables shedding.
    pub brownout: f64,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            storm_rate: 0.0,
            storm_mult: 4,
            storm_dur_s: 1.0,
            fail_rate: 0.0,
            fail_at: Vec::new(),
            detect_s: 0.0,
            retries: 0,
            backoff_s: 0.5,
            spares: 0,
            brownout: 0.0,
        }
    }
}

impl FaultConfig {
    /// Parse a `--faults` spec (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<FaultConfig> {
        let mut cfg = FaultConfig::default();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, value) = item
                .split_once('=')
                .with_context(|| format!("fault spec item {item:?} is not key=value"))?;
            match key {
                "storm" => {
                    let (rate, rest) = value.split_once(':').with_context(|| {
                        format!("storm spec {value:?} is not RATE:MULTxDUR (e.g. 0.05:4x2)")
                    })?;
                    let (mult, dur) = rest.split_once('x').with_context(|| {
                        format!("storm spec {value:?} is not RATE:MULTxDUR (e.g. 0.05:4x2)")
                    })?;
                    cfg.storm_rate = parse_f64("storm rate", rate)?;
                    cfg.storm_mult = mult
                        .trim()
                        .parse()
                        .with_context(|| format!("bad storm multiplier {mult:?}"))?;
                    cfg.storm_dur_s = parse_f64("storm duration", dur)?;
                }
                "fail" => cfg.fail_rate = parse_f64("failure rate", value)?,
                "fail_at" => {
                    let (dev, at) = value.split_once('@').with_context(|| {
                        format!("fail_at spec {value:?} is not DEV@SECS (e.g. 0@30)")
                    })?;
                    let dev: usize = dev
                        .trim()
                        .parse()
                        .with_context(|| format!("bad fail_at device {dev:?}"))?;
                    cfg.fail_at.push((dev, parse_f64("fail_at time", at)?));
                }
                "detect" => cfg.detect_s = parse_f64("detection delay", value)?,
                "retries" => {
                    cfg.retries = value
                        .trim()
                        .parse()
                        .with_context(|| format!("bad retry budget {value:?}"))?;
                }
                "backoff" => cfg.backoff_s = parse_f64("retry backoff", value)?,
                "spares" => {
                    cfg.spares = value
                        .trim()
                        .parse()
                        .with_context(|| format!("bad spare count {value:?}"))?;
                }
                "brownout" => cfg.brownout = parse_f64("brownout threshold", value)?,
                _ => bail!(
                    "unknown fault spec key {key:?}; use \
                     storm|fail|fail_at|detect|retries|backoff|spares|brownout"
                ),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn validate(&self) -> Result<()> {
        if self.storm_rate < 0.0 || !self.storm_rate.is_finite() {
            bail!("storm rate must be finite and >= 0, got {}", self.storm_rate);
        }
        if !(1..=1024).contains(&self.storm_mult) {
            bail!("storm multiplier must be in 1..=1024, got {}", self.storm_mult);
        }
        if self.storm_dur_s <= 0.0 || !self.storm_dur_s.is_finite() {
            bail!("storm duration must be finite and > 0, got {}", self.storm_dur_s);
        }
        if self.fail_rate < 0.0 || !self.fail_rate.is_finite() {
            bail!("failure rate must be finite and >= 0, got {}", self.fail_rate);
        }
        for &(dev, at) in &self.fail_at {
            if at < 0.0 || !at.is_finite() {
                bail!("fail_at time for device {dev} must be finite and >= 0, got {at}");
            }
        }
        if self.detect_s < 0.0 || !self.detect_s.is_finite() {
            bail!("detection delay must be finite and >= 0, got {}", self.detect_s);
        }
        if self.backoff_s < 0.0 || !self.backoff_s.is_finite() {
            bail!("retry backoff must be finite and >= 0, got {}", self.backoff_s);
        }
        if self.spares > 64 {
            bail!("fault spares capped at 64, got {}", self.spares);
        }
        if !(0.0..=1.0).contains(&self.brownout) {
            bail!("brownout threshold must be in [0, 1], got {}", self.brownout);
        }
        Ok(())
    }

    /// No fault process is enabled: no storms, no drawn failures, no
    /// scripted failures. An inert config injects nothing, so callers
    /// normalize it away via [`Self::active`].
    pub fn is_inert(&self) -> bool {
        self.storm_rate <= 0.0 && self.fail_rate <= 0.0 && self.fail_at.is_empty()
    }

    /// Normalize: `None` when inert, so a rate-0 `--faults` spec takes
    /// exactly the fault-free code paths and stays byte-identical to an
    /// absent flag.
    pub fn active(self) -> Option<FaultConfig> {
        if self.is_inert() { None } else { Some(self) }
    }
}

fn parse_f64(what: &str, s: &str) -> Result<f64> {
    s.trim().parse().with_context(|| format!("bad {what} {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let c =
            FaultConfig::parse("storm=0.05:4x2, fail=0.001, fail_at=0@30, detect=0.5, retries=2, backoff=0.25, spares=1, brownout=0.5")
                .unwrap();
        assert_eq!(c.storm_rate, 0.05);
        assert_eq!(c.storm_mult, 4);
        assert_eq!(c.storm_dur_s, 2.0);
        assert_eq!(c.fail_rate, 0.001);
        assert_eq!(c.fail_at, vec![(0, 30.0)]);
        assert_eq!(c.detect_s, 0.5);
        assert_eq!(c.retries, 2);
        assert_eq!(c.backoff_s, 0.25);
        assert_eq!(c.spares, 1);
        assert_eq!(c.brownout, 0.5);
        assert!(!c.is_inert());
    }

    #[test]
    fn empty_and_recovery_only_specs_are_inert() {
        assert!(FaultConfig::parse("").unwrap().is_inert());
        assert!(FaultConfig::parse("retries=3,spares=2,brownout=0.5").unwrap().is_inert());
        assert!(FaultConfig::parse("storm=0:4x1").unwrap().is_inert());
        assert_eq!(FaultConfig::parse("fail=0").unwrap().active(), None);
        assert!(FaultConfig::parse("fail=0.01").unwrap().active().is_some());
        assert!(FaultConfig::parse("fail_at=1@5").unwrap().active().is_some());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "storm=0.05",
            "storm=0.05:4",
            "storm=x:4x1",
            "storm=0.05:0x1",
            "storm=0.05:4x0",
            "storm=0.05:2000x1",
            "fail=-1",
            "fail=nan",
            "fail_at=0",
            "fail_at=0@-5",
            "detect=-1",
            "retries=x",
            "backoff=-0.1",
            "spares=100",
            "brownout=1.5",
            "bogus=1",
            "storm",
        ] {
            assert!(FaultConfig::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn scripted_failures_accumulate() {
        let c = FaultConfig::parse("fail_at=0@10,fail_at=2@20").unwrap();
        assert_eq!(c.fail_at, vec![(0, 10.0), (2, 20.0)]);
    }
}
