//! Per-slot fault timelines: the device's hard-failure instant and its
//! lazily drawn read-retry-storm intervals.
//!
//! **Determinism invariant.** Each slot's timeline is drawn from its own
//! SplitMix64 stream, seeded by mixing the run seed with the slot index
//! (and a fault-stream tag) — *not* from the arrival RNG. The draw order
//! within a stream is fixed: the hard-failure instant first, then storm
//! (gap, duration) pairs strictly in time order, generated append-only on
//! demand. A timeline is therefore a pure function of `(seed, slot)`:
//! which backend runs, which requests land on the device, and in what
//! order service times are queried cannot change a single draw. Both
//! serving backends see bit-identical fault timelines for the same seed.

use super::spec::FaultConfig;
use crate::sim::SimTime;
use crate::util::rng::Rng;

/// Tag mixed into per-slot fault streams so they never collide with the
/// arrival stream (which seeds [`Rng`] with the run seed directly).
const FAULT_STREAM_TAG: u64 = 0xFA01_7D1C_0DD5_EED5;

/// Seed of slot `slot`'s fault stream: a SplitMix64-style finalizer over
/// (run seed, slot, tag), so neighbouring slots land far apart.
fn stream_seed(seed: u64, slot: u64) -> u64 {
    let mut z = seed
        .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(slot.wrapping_add(1)))
        ^ FAULT_STREAM_TAG;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Exponential draw with the given mean (seconds).
fn exp_secs(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean_s
}

/// One slot's fault timeline. Storms are disjoint `[start, end)`
/// picosecond intervals in ascending order; `down_at` is the instant the
/// coordinator drops the device (hang + detection delay), if the spec
/// ever hard-fails it. Non-flash slots draw nothing (faults model flash
/// phenomena).
#[derive(Debug, Clone)]
pub struct FaultTimeline {
    rng: Rng,
    mult: u64,
    storm_rate: f64,
    storm_dur_s: f64,
    /// Drawn storms, `[start, end)` in ps, ascending and disjoint.
    storms: Vec<(u64, u64)>,
    /// Everything before this instant (ps) is drawn; the next storm's
    /// gap starts here.
    horizon: u64,
    /// When the pool drops this slot, if its timeline hard-fails.
    pub down_at: Option<SimTime>,
}

impl FaultTimeline {
    /// Draw slot `slot`'s timeline head: the hard-failure instant (the
    /// earlier of the drawn Poisson failure and any scripted `fail_at`
    /// entry for this slot, plus the detection delay). Storms follow
    /// lazily. `flash` gates everything — GPU slots never fault.
    pub fn new(cfg: &FaultConfig, seed: u64, slot: usize, flash: bool) -> FaultTimeline {
        let mut rng = Rng::new(stream_seed(seed, slot as u64));
        // Fixed draw order: failure first, then storms — so lazy storm
        // generation can never perturb the failure draw.
        let drawn = if flash && cfg.fail_rate > 0.0 {
            exp_secs(&mut rng, 1.0 / cfg.fail_rate)
        } else {
            f64::INFINITY
        };
        let scripted = cfg
            .fail_at
            .iter()
            .filter(|&&(d, _)| d == slot)
            .map(|&(_, t)| t)
            .fold(f64::INFINITY, f64::min);
        let fail_s = if flash { drawn.min(scripted) } else { f64::INFINITY };
        let down_at =
            fail_s.is_finite().then(|| SimTime::from_secs(fail_s + cfg.detect_s));
        FaultTimeline {
            rng,
            mult: cfg.storm_mult as u64,
            storm_rate: if flash { cfg.storm_rate } else { 0.0 },
            storm_dur_s: cfg.storm_dur_s,
            storms: Vec::new(),
            horizon: 0,
            down_at,
        }
    }

    /// Append the next storm after the current horizon.
    fn grow_one(&mut self) {
        let gap = SimTime::from_secs(exp_secs(&mut self.rng, 1.0 / self.storm_rate)).0;
        let dur = SimTime::from_secs(exp_secs(&mut self.rng, self.storm_dur_s)).0.max(1);
        let start = self.horizon + gap;
        self.storms.push((start, start + dur));
        self.horizon = start + dur;
    }

    /// First storm with `end > t` (generating as needed): either the
    /// storm covering `t` or the next one after it.
    fn storm_after(&mut self, t: u64) -> (u64, u64) {
        loop {
            match self.storms.last() {
                Some(&(_, e)) if e > t => break,
                _ => self.grow_one(),
            }
        }
        let i = self.storms.partition_point(|&(_, e)| e <= t);
        self.storms[i]
    }

    /// Wall-clock instant at which `work` finishes when it starts at
    /// `start`: progress runs 1:1 outside storms and `1/mult` inside
    /// them. Identity for storm-free slots or a 1x multiplier.
    ///
    /// Compositional by construction —
    /// `dilate(dilate(t, a), b) == dilate(t, a + b)` — because in-storm
    /// progress is accounted in whole work units (the sub-unit sliver at
    /// a storm's edge is absorbed into the storm): that is exactly the
    /// property that lets the coalesced decode path price a request's
    /// first token and completion from the same start instant.
    pub fn dilate(&mut self, start: SimTime, work: SimTime) -> SimTime {
        if self.storm_rate <= 0.0 || self.mult <= 1 {
            return start + work;
        }
        let mut t = start.0;
        let mut rem = work.0;
        while rem > 0 {
            let (s, e) = self.storm_after(t);
            if t < s {
                // Normal region [t, s): 1:1 progress.
                let room = s - t;
                if rem <= room {
                    return SimTime(t + rem);
                }
                rem -= room;
                t = s;
            } else {
                // Inside the storm [s, e): each work unit costs `mult`
                // wall units; the storm affords `(e - t) / mult` units.
                let afford = (e - t) / self.mult;
                if rem <= afford {
                    return SimTime(t + rem * self.mult);
                }
                rem -= afford;
                t = e;
            }
        }
        SimTime(t)
    }

    /// Storms beginning before `until` (count, total in-horizon seconds),
    /// generating as needed — the fault summary's storm statistics.
    pub fn storms_within(&mut self, until: SimTime) -> (u64, f64) {
        if self.storm_rate <= 0.0 || until == SimTime::ZERO {
            return (0, 0.0);
        }
        while self.horizon < until.0 {
            self.grow_one();
        }
        let mut count = 0u64;
        let mut total = 0u64;
        for &(s, e) in &self.storms {
            if s >= until.0 {
                break;
            }
            count += 1;
            total += e.min(until.0) - s;
        }
        (count, SimTime(total).secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stormy() -> FaultConfig {
        FaultConfig {
            storm_rate: 2.0,
            storm_mult: 4,
            storm_dur_s: 0.5,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn timeline_is_a_pure_function_of_seed_and_slot() {
        let cfg = stormy();
        let mut a = FaultTimeline::new(&cfg, 7, 0, true);
        let mut b = FaultTimeline::new(&cfg, 7, 0, true);
        // Query b in a different order than a: same answers.
        let qa: Vec<SimTime> = (0..50)
            .map(|i| a.dilate(SimTime::from_secs(i as f64 * 0.1), SimTime::from_us(500.0)))
            .collect();
        let qb_late = b.dilate(SimTime::from_secs(4.9), SimTime::from_us(500.0));
        let qb: Vec<SimTime> = (0..50)
            .map(|i| b.dilate(SimTime::from_secs(i as f64 * 0.1), SimTime::from_us(500.0)))
            .collect();
        assert_eq!(qa, qb);
        assert_eq!(qb_late, qa[49]);
        // Different slots draw different streams.
        let mut c = FaultTimeline::new(&cfg, 7, 1, true);
        let qc = c.dilate(SimTime::from_secs(1.0), SimTime::from_secs(1.0));
        let qa1 = a.dilate(SimTime::from_secs(1.0), SimTime::from_secs(1.0));
        assert_ne!(qa1, qc, "slots 0 and 1 must not share a storm timeline");
    }

    #[test]
    fn dilation_is_compositional() {
        let cfg = stormy();
        let mut t = FaultTimeline::new(&cfg, 3, 0, true);
        for (start, a, b) in [
            (0.0, 0.2, 0.3),
            (0.7, 1.0, 0.01),
            (2.0, 0.0, 0.5),
            (5.0, 0.33, 0.67),
        ] {
            let start = SimTime::from_secs(start);
            let (a, b) = (SimTime::from_secs(a), SimTime::from_secs(b));
            let whole = t.dilate(start, a + b);
            let split = t.dilate(t.dilate(start, a), b);
            assert_eq!(whole, split, "dilate must compose at start {start}");
        }
    }

    #[test]
    fn dilation_never_shrinks_and_is_identity_without_storms() {
        let mut calm = FaultTimeline::new(&FaultConfig::default(), 1, 0, true);
        let start = SimTime::from_secs(1.0);
        let work = SimTime::from_secs(0.25);
        assert_eq!(calm.dilate(start, work), start + work);
        // GPU slots never storm even under a stormy spec.
        let mut gpu = FaultTimeline::new(&stormy(), 1, 0, false);
        assert_eq!(gpu.dilate(start, work), start + work);
        assert_eq!(gpu.down_at, None);
        let mut t = FaultTimeline::new(&stormy(), 1, 0, true);
        for i in 0..20 {
            let s = SimTime::from_secs(i as f64 * 0.3);
            let end = t.dilate(s, work);
            assert!(end >= s + work, "dilation can only stretch service");
            assert!(end <= s + SimTime(work.0 * 4), "bounded by the 4x multiplier");
        }
    }

    #[test]
    fn scripted_failure_beats_drawn_failure_and_adds_detection() {
        let cfg = FaultConfig {
            fail_at: vec![(2, 10.0), (2, 30.0)],
            detect_s: 0.5,
            ..FaultConfig::default()
        };
        let t = FaultTimeline::new(&cfg, 9, 2, true);
        assert_eq!(t.down_at, Some(SimTime::from_secs(10.5)), "earliest entry + detect");
        assert_eq!(FaultTimeline::new(&cfg, 9, 0, true).down_at, None);
        let drawn = FaultConfig { fail_rate: 0.5, ..FaultConfig::default() };
        assert!(FaultTimeline::new(&drawn, 9, 0, true).down_at.is_some());
    }

    #[test]
    fn storm_stats_clip_to_horizon() {
        let cfg = stormy();
        let mut t = FaultTimeline::new(&cfg, 11, 0, true);
        let (n10, s10) = t.storms_within(SimTime::from_secs(10.0));
        assert!(n10 > 0, "2 storms/s for 10 s must draw storms");
        assert!(s10 > 0.0 && s10 <= 10.0);
        let (n5, s5) = t.storms_within(SimTime::from_secs(5.0));
        assert!(n5 <= n10 && s5 <= s10);
        assert_eq!(t.storms_within(SimTime::ZERO), (0, 0.0));
    }
}
