//! Deterministic fault injection for the serving stack: read-retry
//! storms, hard device loss, and the recovery policies (retry budgets,
//! KV-loss failover, brownout shedding) that keep goodput defensible.
//!
//! # Determinism invariant
//!
//! Every fault draw comes from a per-slot RNG stream keyed by
//! `(run seed, slot index)` — never from the arrival stream, and never
//! in an order that depends on scheduling. Hard-failure instants are
//! drawn eagerly at construction; storm intervals are drawn lazily but
//! strictly in time order per slot. Consequently the complete fault
//! schedule is a pure function of `(seed, fault spec, roster)`: the
//! event backend and the direct-replay backend inject *bit-identical*
//! faults for the same seed, and reruns are reproducible byte-for-byte.
//!
//! Everything is `Option`-gated: a run without `--faults` (or with an
//! inert spec — see [`FaultConfig::active`]) carries `None` and takes
//! exactly the fault-free code paths, byte-identical to builds that
//! predate this module. See `docs/FAULTS.md` for the spec grammar and
//! metrics glossary.

pub mod roster;
pub mod spec;
pub mod timeline;

pub use roster::{DownAction, FaultSummary, FleetFaults};
pub use spec::FaultConfig;
pub use timeline::FaultTimeline;
