//! Intra-die interconnect (paper §III-C, Figs. 7–8): the conventional
//! shared bus, the proposed H-tree network with reconfigurable processing
//! units (RPUs) at its internal nodes, and the per-channel flash bus.

pub mod channel_bus;
pub mod htree;
pub mod rpu;
pub mod shared;

pub use channel_bus::ChannelBus;
pub use htree::HTree;
pub use rpu::{Rpu, RpuMode};
pub use shared::SharedBus;
