//! Reconfigurable processing unit (paper Fig. 8, Table I): 250 MHz,
//! 8× INT16 multipliers, 9× INT32 adders.
//!
//! * **ALU mode** — accumulates the outputs of its two child links on the
//!   way up the H-tree (sMVM partial sums), or multiplies operand pairs
//!   for dMVM (VVM/VSM).
//! * **Stream mode** — passes data through for regular reads/programs.

use crate::config::RpuConfig;
use crate::sim::SimTime;

/// Operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RpuMode {
    /// Element-wise combine of two input streams.
    Alu,
    /// Cut-through forwarding.
    Stream,
}

/// Timing + functional model of one RPU.
#[derive(Debug, Clone, Copy)]
pub struct Rpu {
    pub cfg: RpuConfig,
}

impl Rpu {
    pub fn new(cfg: RpuConfig) -> Rpu {
        Rpu { cfg }
    }

    /// Cycle time.
    pub fn cycle(&self) -> SimTime {
        SimTime::from_secs(1.0 / self.cfg.freq_hz)
    }

    /// Time to combine `n` element pairs in ALU mode: the adder array
    /// processes `int32_adders - 1` pairs per cycle (one adder reserved
    /// for the carry/accumulator path), pipelined.
    pub fn alu_time(&self, n: usize) -> SimTime {
        let lanes = (self.cfg.int32_adders - 1).max(1);
        let cycles = n.div_ceil(lanes) as u64;
        SimTime::from_secs(cycles as f64 / self.cfg.freq_hz)
    }

    /// Time to multiply `n` INT16 operand pairs (dMVM inner loop):
    /// `int16_mults` lanes, pipelined, plus the adder-tree reduction.
    pub fn mul_time(&self, n: usize) -> SimTime {
        let cycles = n.div_ceil(self.cfg.int16_mults) as u64 + 1; // +1: reduce
        SimTime::from_secs(cycles as f64 / self.cfg.freq_hz)
    }

    /// Stream-mode forwarding latency for `n` elements of `elem_bytes`
    /// at the given link bandwidth — one cycle of cut-through latency
    /// plus the serialization time.
    pub fn stream_time(&self, n: usize, elem_bytes: usize, link_bw: f64) -> SimTime {
        self.cycle() + SimTime::from_secs((n * elem_bytes) as f64 / link_bw)
    }

    /// Functional ALU combine: element-wise i32 saturating add of two
    /// partial-sum vectors (the H-tree reduction operator).
    pub fn alu_combine(a: &[i32], b: &[i32]) -> Vec<i32> {
        assert_eq!(a.len(), b.len(), "ALU operand length mismatch");
        a.iter().zip(b.iter()).map(|(x, y)| x.saturating_add(*y)).collect()
    }

    /// Functional dMVM multiply-accumulate: i16×i16 → i32 dot product
    /// (the VVM unit of Fig. 13c). The INT32 accumulator saturates, as
    /// the hardware adder would.
    pub fn vvm(a: &[i16], b: &[i16]) -> i32 {
        assert_eq!(a.len(), b.len());
        let wide: i64 = a.iter().zip(b.iter()).map(|(x, y)| *x as i64 * *y as i64).sum();
        wide.clamp(i32::MIN as i64, i32::MAX as i64) as i32
    }

    /// Functional vector-scalar multiply (the VSM unit of Fig. 13f).
    pub fn vsm(s: i16, v: &[i16]) -> Vec<i32> {
        v.iter().map(|x| s as i32 * *x as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RpuConfig;

    fn rpu() -> Rpu {
        Rpu::new(RpuConfig::default())
    }

    #[test]
    fn cycle_is_4ns_at_250mhz() {
        assert_eq!(rpu().cycle(), SimTime::from_ns(4.0));
    }

    #[test]
    fn alu_time_scales_with_lanes() {
        let r = rpu();
        // 8 usable lanes -> 512 elements = 64 cycles = 256 ns.
        assert_eq!(r.alu_time(512), SimTime::from_ns(64.0 * 4.0));
        assert_eq!(r.alu_time(1), SimTime::from_ns(4.0));
    }

    #[test]
    fn alu_combine_is_elementwise_sum() {
        let s = Rpu::alu_combine(&[1, 2, 3], &[10, 20, 30]);
        assert_eq!(s, vec![11, 22, 33]);
    }

    #[test]
    fn alu_combine_saturates() {
        let s = Rpu::alu_combine(&[i32::MAX], &[1]);
        assert_eq!(s, vec![i32::MAX]);
    }

    #[test]
    fn vvm_matches_scalar_dot() {
        let a: Vec<i16> = vec![1, -2, 3, 100];
        let b: Vec<i16> = vec![5, 6, -7, 100];
        assert_eq!(Rpu::vvm(&a, &b), 5 - 12 - 21 + 10_000);
    }

    #[test]
    fn vsm_scales_vector() {
        assert_eq!(Rpu::vsm(3, &[1, -2, 0]), vec![3, -6, 0]);
    }

    #[test]
    fn stream_time_includes_serialization() {
        let r = rpu();
        // 128 × 2 B at 2 GB/s = 128 ns + 4 ns cut-through.
        let t = r.stream_time(128, 2, 2.0e9);
        assert_eq!(t, SimTime::from_ns(132.0));
    }
}
