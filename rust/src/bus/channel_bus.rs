//! The per-channel flash bus between the SSD controller and the dies of
//! one channel (Table I: 2 GB/s, 1000 MT/s × 8-bit). Channels operate in
//! parallel; ways/dies within a channel share the channel bus.

use crate::sim::{Resource, SimTime};

/// One channel's bus.
#[derive(Debug, Clone)]
pub struct ChannelBus {
    pub bw: f64,
    timeline: Resource,
}

impl ChannelBus {
    pub fn new(bw: f64) -> ChannelBus {
        ChannelBus { bw, timeline: Resource::new() }
    }

    pub fn transfer_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs(bytes as f64 / self.bw)
    }

    /// Schedule a transfer ready at `ready`; returns (start, end).
    pub fn transfer(&mut self, ready: SimTime, bytes: usize) -> (SimTime, SimTime) {
        let dur = self.transfer_time(bytes);
        let start = self.timeline.acquire(ready, dur);
        (start, start + dur)
    }

    pub fn free_at(&self) -> SimTime {
        self.timeline.free_at()
    }

    pub fn busy_total(&self) -> SimTime {
        self.timeline.busy_total()
    }

    pub fn reset(&mut self) {
        self.timeline.reset();
    }
}

/// All channels of the device.
#[derive(Debug, Clone)]
pub struct ChannelSet {
    pub buses: Vec<ChannelBus>,
}

impl ChannelSet {
    pub fn new(channels: usize, bw: f64) -> ChannelSet {
        ChannelSet { buses: (0..channels).map(|_| ChannelBus::new(bw)).collect() }
    }

    pub fn bus(&mut self, channel: usize) -> &mut ChannelBus {
        &mut self.buses[channel]
    }

    /// Aggregate sequential bandwidth across channels.
    pub fn total_bw(&self) -> f64 {
        self.buses.iter().map(|b| b.bw).sum()
    }

    /// Latest completion across channels.
    pub fn makespan(&self) -> SimTime {
        self.buses.iter().map(|b| b.free_at()).max().unwrap_or(SimTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_io_example() {
        // Paper §III-C: moving 128 × 8-bit data at 2 GB/s takes 64 ns.
        let b = ChannelBus::new(2.0e9);
        assert_eq!(b.transfer_time(128), SimTime::from_ns(64.0));
    }

    #[test]
    fn channels_are_independent() {
        let mut s = ChannelSet::new(2, 2.0e9);
        let (_, e0) = s.bus(0).transfer(SimTime::ZERO, 2048);
        let (_, e1) = s.bus(1).transfer(SimTime::ZERO, 2048);
        assert_eq!(e0, e1); // parallel, not serialized
        assert_eq!(s.total_bw(), 4.0e9);
    }

    #[test]
    fn within_channel_serializes() {
        let mut s = ChannelSet::new(1, 2.0e9);
        let (_, e0) = s.bus(0).transfer(SimTime::ZERO, 1024);
        let (s1, e1) = s.bus(0).transfer(SimTime::ZERO, 1024);
        assert_eq!(s1, e0);
        assert!(e1 > e0);
    }
}
