//! The H-tree network within a die (paper Fig. 7b): a binary tree over
//! the planes with an RPU at every internal node. PIM outputs are
//! combined (ALU mode) or forwarded (stream mode) level by level, so an
//! all-plane reduction reaches the die port after `log2(P)` RPU hops
//! instead of `P` serialized bus transfers.

use super::rpu::Rpu;
use crate::sim::SimTime;

/// H-tree over `leaves` planes (power of two).
#[derive(Debug, Clone)]
pub struct HTree {
    pub leaves: usize,
    pub rpu: Rpu,
    /// Per-hop link bandwidth within the tree (bytes/s) — sized to the
    /// die's bus speed.
    pub link_bw: f64,
}

impl HTree {
    pub fn new(leaves: usize, rpu: Rpu, link_bw: f64) -> HTree {
        assert!(leaves.is_power_of_two(), "H-tree needs a power-of-two leaf count, got {leaves}");
        HTree { leaves, rpu, link_bw }
    }

    /// Tree depth (number of RPU levels).
    pub fn depth(&self) -> usize {
        self.leaves.trailing_zeros() as usize
    }

    /// Serialization time of `n` elements of `elem_bytes` over one link.
    fn link_time(&self, n: usize, elem_bytes: usize) -> SimTime {
        SimTime::from_secs((n * elem_bytes) as f64 / self.link_bw)
    }

    /// Latency for a full reduction of one output vector of `n` elements
    /// (i32 partial sums) from all leaves to the root, given each leaf's
    /// data-ready time. Internal nodes combine their two children with
    /// the RPU ALU and forward upward; levels are pipelined (a node
    /// starts combining as soon as both children delivered).
    pub fn reduce_ready_time(&self, leaf_ready: &[SimTime], n: usize, elem_bytes: usize) -> SimTime {
        assert_eq!(leaf_ready.len(), self.leaves, "one ready time per leaf");
        let hop = self.link_time(n, elem_bytes);
        let alu = self.rpu.alu_time(n);
        let mut level: Vec<SimTime> = leaf_ready.iter().map(|t| *t + hop).collect();
        while level.len() > 1 {
            level = level
                .chunks(2)
                .map(|pair| pair[0].max(pair[1]) + alu + hop)
                .collect();
        }
        level[0]
    }

    /// Latency for reduction over a subset: only `active` leaves hold
    /// partial sums; inactive subtrees forward in stream mode (no ALU
    /// work, negligible against link time). `active` is a ready-time per
    /// active leaf index.
    pub fn reduce_subset_ready_time(
        &self,
        active: &[(usize, SimTime)],
        n: usize,
        elem_bytes: usize,
    ) -> SimTime {
        assert!(!active.is_empty());
        let hop = self.link_time(n, elem_bytes);
        let alu = self.rpu.alu_time(n);
        // Walk levels: a map from node index (at current level) to ready time.
        let mut level: Vec<Option<SimTime>> = vec![None; self.leaves];
        for (idx, t) in active {
            assert!(*idx < self.leaves, "leaf {idx} out of range");
            assert!(level[*idx].is_none(), "duplicate leaf {idx}");
            level[*idx] = Some(*t + hop);
        }
        while level.len() > 1 {
            level = level
                .chunks(2)
                .map(|pair| match (pair[0], pair[1]) {
                    (Some(a), Some(b)) => Some(a.max(b) + alu + hop),
                    // One-sided: stream through (cut-through cycle + hop).
                    (Some(a), None) | (None, Some(a)) => Some(a + self.rpu.cycle() + hop),
                    (None, None) => None,
                })
                .collect();
        }
        level[0].expect("at least one active leaf")
    }

    /// Functional reduction: combine leaf partial-sum vectors with the
    /// RPU ALU operator, mirroring the timing model's topology exactly.
    pub fn reduce_values(&self, leaf_values: &[Vec<i32>]) -> Vec<i32> {
        assert_eq!(leaf_values.len(), self.leaves);
        let mut level: Vec<Vec<i32>> = leaf_values.to_vec();
        while level.len() > 1 {
            level = level.chunks(2).map(|p| Rpu::alu_combine(&p[0], &p[1])).collect();
        }
        level.into_iter().next().unwrap()
    }

    /// Total wire length of the H-tree in units of die side length —
    /// feeds the Table II area model. For an H-tree spanning a unit
    /// square with `P` leaves: `L ≈ Σ_level 2^(level/2)`-style recursion;
    /// we use the closed form `3·sqrt(P)/2 - 2` (standard H-tree result).
    pub fn wire_length_units(&self) -> f64 {
        1.5 * (self.leaves as f64).sqrt() - 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RpuConfig;

    fn tree(leaves: usize) -> HTree {
        HTree::new(leaves, Rpu::new(RpuConfig::default()), 2.0e9)
    }

    #[test]
    fn depth_is_log2() {
        assert_eq!(tree(256).depth(), 8);
        assert_eq!(tree(64).depth(), 6);
    }

    #[test]
    fn reduction_is_correct_sum() {
        let t = tree(8);
        let leaves: Vec<Vec<i32>> = (0..8).map(|i| vec![i, 10 * i, -i]).collect();
        let got = t.reduce_values(&leaves);
        assert_eq!(got, vec![28, 280, -28]);
    }

    #[test]
    fn reduce_latency_scales_with_depth_not_leaves() {
        // The point of the H-tree: latency ~ log2(P), not P.
        let n = 512;
        let t64 = tree(64);
        let t256 = tree(256);
        let r64 = t64.reduce_ready_time(&vec![SimTime::ZERO; 64], n, 4);
        let r256 = t256.reduce_ready_time(&vec![SimTime::ZERO; 256], n, 4);
        let per_level_64 = r64.secs() / (t64.depth() + 1) as f64;
        let per_level_256 = r256.secs() / (t256.depth() + 1) as f64;
        assert!((per_level_64 - per_level_256).abs() / per_level_64 < 0.05);
    }

    #[test]
    fn straggler_leaf_delays_root() {
        let t = tree(4);
        let mut ready = vec![SimTime::ZERO; 4];
        let base = t.reduce_ready_time(&ready, 128, 4);
        ready[3] = SimTime::from_us(5.0);
        let delayed = t.reduce_ready_time(&ready, 128, 4);
        assert!(delayed >= SimTime::from_us(5.0));
        assert!(delayed > base);
    }

    #[test]
    fn subset_reduction_matches_full_when_all_active() {
        let t = tree(8);
        let ready: Vec<(usize, SimTime)> = (0..8).map(|i| (i, SimTime(i as u64 * 100))).collect();
        let full: Vec<SimTime> = (0..8).map(|i| SimTime(i as u64 * 100)).collect();
        assert_eq!(
            t.reduce_subset_ready_time(&ready, 64, 4),
            t.reduce_ready_time(&full, 64, 4)
        );
    }

    #[test]
    fn subset_reduction_single_leaf_streams_through() {
        let t = tree(8);
        let r = t.reduce_subset_ready_time(&[(5, SimTime::ZERO)], 64, 4);
        // 3 levels of stream cycles + 4 hops, no ALU time.
        let hop = SimTime::from_secs(64.0 * 4.0 / 2.0e9);
        let expect = hop + SimTime::from_ns(4.0) + hop + SimTime::from_ns(4.0) + hop + SimTime::from_ns(4.0) + hop;
        assert_eq!(r, expect);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        tree(100);
    }

    #[test]
    fn wire_length_grows_sublinearly() {
        assert!(tree(256).wire_length_units() < 256.0 / 4.0);
        assert!(tree(256).wire_length_units() > tree(64).wire_length_units());
    }
}
