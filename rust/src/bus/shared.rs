//! The conventional shared intra-die bus (paper Fig. 7a): one transfer at
//! a time; every PIM output vector must individually travel to the die
//! port, and cross-plane accumulation happens *outside* the die.

use crate::sim::{Resource, SimTime};

/// A single shared bus serializing plane→port transfers.
#[derive(Debug, Clone)]
pub struct SharedBus {
    /// Bus bandwidth (bytes/s); paper: 1.6–2 GB/s die buses.
    pub bw: f64,
    timeline: Resource,
}

impl SharedBus {
    pub fn new(bw: f64) -> SharedBus {
        SharedBus { bw, timeline: Resource::new() }
    }

    /// Serialization time for a payload.
    pub fn transfer_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs(bytes as f64 / self.bw)
    }

    /// Enqueue a transfer that becomes *available* at `ready`; returns its
    /// completion time (waits for the bus if busy).
    pub fn transfer(&mut self, ready: SimTime, bytes: usize) -> SimTime {
        let dur = self.transfer_time(bytes);
        let start = self.timeline.acquire(ready, dur);
        start + dur
    }

    /// Completion time of draining many transfers, each becoming ready at
    /// its own time. Transfers are served in ready order (FIFO).
    pub fn drain(&mut self, mut ready_times: Vec<(SimTime, usize)>) -> SimTime {
        ready_times.sort();
        let mut last = SimTime::ZERO;
        for (ready, bytes) in ready_times {
            last = self.transfer(ready, bytes);
        }
        last
    }

    pub fn busy_total(&self) -> SimTime {
        self.timeline.busy_total()
    }

    pub fn reset(&mut self) {
        self.timeline.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfers_serialize() {
        let mut b = SharedBus::new(2.0e9);
        // 256 B at 2 GB/s = 128 ns each.
        let t1 = b.transfer(SimTime::ZERO, 256);
        let t2 = b.transfer(SimTime::ZERO, 256);
        assert_eq!(t1, SimTime::from_ns(128.0));
        assert_eq!(t2, SimTime::from_ns(256.0));
    }

    #[test]
    fn drain_many_equals_sum_when_all_ready() {
        let mut b = SharedBus::new(2.0e9);
        let jobs: Vec<(SimTime, usize)> = (0..64).map(|_| (SimTime::ZERO, 1024)).collect();
        let end = b.drain(jobs);
        // 64 × 1024 B at 2 GB/s = 32.768 µs.
        assert_eq!(end, SimTime::from_secs(64.0 * 1024.0 / 2.0e9));
    }

    #[test]
    fn bus_waits_for_late_producers() {
        let mut b = SharedBus::new(2.0e9);
        let end = b.drain(vec![(SimTime::from_us(10.0), 256), (SimTime::ZERO, 256)]);
        assert_eq!(end, SimTime::from_us(10.0) + SimTime::from_ns(128.0));
    }
}
