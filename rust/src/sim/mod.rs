//! Discrete-event simulation core.
//!
//! Plays the role SimpleSSD/Amber played for the paper: an event queue
//! with deterministic ordering, exclusive-resource timelines, and a small
//! engine driving model callbacks. Time is kept in integer picoseconds so
//! event ordering is exact and runs are bit-reproducible.

pub mod engine;
pub mod event;
pub mod resource;
pub mod time;
pub mod trace;

pub use engine::{Engine, Model};
pub use event::EventQueue;
pub use resource::{Resource, ResourceBank};
pub use time::SimTime;
pub use trace::{Trace, TraceEvent};
