//! Discrete-event simulation core.
//!
//! Plays the role SimpleSSD/Amber played for the paper: an event queue
//! with deterministic ordering, exclusive-resource timelines, and a small
//! engine driving model callbacks. Time is kept in integer picoseconds so
//! event ordering is exact and runs are bit-reproducible.
//!
//! Two styles of model build on this core:
//!
//! * **Timeline models** schedule work directly on [`Resource`] /
//!   [`ResourceBank`] busy-until timelines (the pipeline latency models
//!   in [`crate::pim`] and [`crate::bus`] work this way).
//! * **Event models** implement [`Model`] and let [`Engine`] drive them:
//!   every state change is an event on the deterministic [`EventQueue`]
//!   (min-heap on time with FIFO tie-breaks). The serving simulator
//!   [`crate::coordinator::event_sim`] is the flagship user.
//!
//! # Example
//!
//! A minimal self-rescheduling model, driven to completion:
//!
//! ```
//! use flashpim::sim::{Engine, EventQueue, Model, SimTime};
//!
//! struct Ticker {
//!     fired: u32,
//! }
//!
//! impl Model for Ticker {
//!     type Event = ();
//!
//!     fn handle(&mut self, _now: SimTime, _ev: (), queue: &mut EventQueue<()>) {
//!         self.fired += 1;
//!         if self.fired < 3 {
//!             queue.schedule_in(SimTime::from_ns(10.0), ());
//!         }
//!     }
//! }
//!
//! let mut engine = Engine::new(Ticker { fired: 0 });
//! engine.seed(SimTime::ZERO, ());
//! let end = engine.run(); // runs until the queue drains
//! assert_eq!(engine.model.fired, 3);
//! assert_eq!(end, SimTime::from_ns(20.0));
//! assert_eq!(engine.events_processed(), 3);
//! ```

pub mod engine;
pub mod event;
pub mod resource;
pub mod time;
pub mod trace;

pub use engine::{Engine, Model};
pub use event::EventQueue;
pub use resource::{Resource, ResourceBank};
pub use time::SimTime;
pub use trace::{Trace, TraceEvent};
