//! Exclusive-resource timelines: buses, planes, RPUs, and ARM cores are
//! all "one job at a time" servers. `acquire` implements the classic
//! busy-until scheduling used throughout the pipeline models.

use super::time::SimTime;

/// An exclusive resource with a busy-until timestamp and utilization
/// accounting.
#[derive(Debug, Clone, Default)]
pub struct Resource {
    free_at: SimTime,
    busy_total: SimTime,
    jobs: u64,
}

impl Resource {
    pub fn new() -> Resource {
        Resource::default()
    }

    /// Request the resource at `at` for `dur`. Returns the actual start
    /// time (`max(at, free_at)`) and marks the resource busy until
    /// `start + dur`.
    pub fn acquire(&mut self, at: SimTime, dur: SimTime) -> SimTime {
        let start = at.max(self.free_at);
        self.free_at = start + dur;
        self.busy_total += dur;
        self.jobs += 1;
        start
    }

    /// When the resource next becomes free.
    pub fn free_at(&self) -> SimTime {
        self.free_at
    }

    /// Completion time of the most recent job == `free_at`.
    pub fn last_completion(&self) -> SimTime {
        self.free_at
    }

    /// Total busy time accumulated.
    pub fn busy_total(&self) -> SimTime {
        self.busy_total
    }

    /// Jobs served.
    pub fn jobs(&self) -> u64 {
        self.jobs
    }

    /// Utilization over a horizon.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        self.busy_total.secs() / horizon.secs()
    }

    /// Reset to idle at t=0 (keeps nothing).
    pub fn reset(&mut self) {
        *self = Resource::default();
    }
}

/// A bank of identical exclusive resources (e.g. the 4 ARM cores):
/// `acquire` picks the earliest-free member.
#[derive(Debug, Clone)]
pub struct ResourceBank {
    members: Vec<Resource>,
}

impl ResourceBank {
    pub fn new(n: usize) -> ResourceBank {
        assert!(n > 0);
        ResourceBank { members: vec![Resource::new(); n] }
    }

    /// Acquire the earliest-available member; returns (member index, start).
    pub fn acquire(&mut self, at: SimTime, dur: SimTime) -> (usize, SimTime) {
        let (idx, _) = self
            .members
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.free_at())
            .expect("bank not empty");
        let start = self.members[idx].acquire(at, dur);
        (idx, start)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Latest completion across members.
    pub fn makespan(&self) -> SimTime {
        self.members.iter().map(|r| r.free_at()).max().unwrap_or(SimTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_overlapping_jobs() {
        let mut r = Resource::new();
        let s1 = r.acquire(SimTime(0), SimTime(100));
        let s2 = r.acquire(SimTime(50), SimTime(100));
        assert_eq!(s1, SimTime(0));
        assert_eq!(s2, SimTime(100)); // waits for the first job
        assert_eq!(r.free_at(), SimTime(200));
    }

    #[test]
    fn idle_gap_preserved() {
        let mut r = Resource::new();
        r.acquire(SimTime(0), SimTime(10));
        let s = r.acquire(SimTime(100), SimTime(10));
        assert_eq!(s, SimTime(100)); // starts when requested, not earlier
        assert_eq!(r.busy_total(), SimTime(20));
    }

    #[test]
    fn utilization_accounting() {
        let mut r = Resource::new();
        r.acquire(SimTime(0), SimTime(50));
        assert!((r.utilization(SimTime(100)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bank_balances_load() {
        let mut b = ResourceBank::new(2);
        let (i1, s1) = b.acquire(SimTime(0), SimTime(100));
        let (i2, s2) = b.acquire(SimTime(0), SimTime(100));
        let (_, s3) = b.acquire(SimTime(0), SimTime(100));
        assert_ne!(i1, i2);
        assert_eq!(s1, SimTime(0));
        assert_eq!(s2, SimTime(0));
        assert_eq!(s3, SimTime(100)); // third job queues
        assert_eq!(b.makespan(), SimTime(200));
    }
}
