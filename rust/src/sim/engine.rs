//! Generic event-driven engine: pops events, hands them to the model,
//! lets the model schedule more. Used by the serving-level simulations;
//! the pipeline latency models use [`super::resource`] timelines directly.

use super::event::EventQueue;
use super::time::SimTime;

/// A simulation model consumed by [`Engine`].
pub trait Model {
    /// Event payload type.
    type Event;

    /// Handle one event; schedule follow-ups through `queue`.
    fn handle(&mut self, now: SimTime, event: Self::Event, queue: &mut EventQueue<Self::Event>);

    /// Optional termination predicate checked after each event.
    fn done(&self) -> bool {
        false
    }
}

/// Drives a [`Model`] to completion.
pub struct Engine<M: Model> {
    pub model: M,
    pub queue: EventQueue<M::Event>,
    /// Safety valve against runaway models.
    pub max_events: u64,
    events_processed: u64,
}

impl<M: Model> Engine<M> {
    pub fn new(model: M) -> Engine<M> {
        Engine { model, queue: EventQueue::new(), max_events: 100_000_000, events_processed: 0 }
    }

    /// An engine whose queue is pre-sized for `capacity` pending events —
    /// use when the model's steady-state event population is known (the
    /// serving model keeps at most one in-flight event per device plus
    /// the next arrival).
    pub fn with_capacity(model: M, capacity: usize) -> Engine<M> {
        Engine {
            model,
            queue: EventQueue::with_capacity(capacity),
            max_events: 100_000_000,
            events_processed: 0,
        }
    }

    /// Seed an initial event.
    pub fn seed(&mut self, at: SimTime, ev: M::Event) {
        self.queue.schedule(at, ev);
    }

    /// Run until the queue drains, the model reports done, or the event
    /// cap trips. Returns the final simulation time.
    pub fn run(&mut self) -> SimTime {
        while let Some((now, ev)) = self.queue.pop() {
            self.model.handle(now, ev, &mut self.queue);
            self.events_processed += 1;
            if self.model.done() {
                break;
            }
            assert!(
                self.events_processed < self.max_events,
                "event cap {} exceeded — runaway model?",
                self.max_events
            );
        }
        self.queue.now()
    }

    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A counter that reschedules itself n times.
    struct Ticker {
        remaining: u32,
        fired_at: Vec<SimTime>,
    }

    impl Model for Ticker {
        type Event = ();

        fn handle(&mut self, now: SimTime, _ev: (), queue: &mut EventQueue<()>) {
            self.fired_at.push(now);
            if self.remaining > 0 {
                self.remaining -= 1;
                queue.schedule_in(SimTime(10), ());
            }
        }
    }

    #[test]
    fn ticker_fires_on_schedule() {
        let mut e = Engine::new(Ticker { remaining: 3, fired_at: vec![] });
        e.seed(SimTime(0), ());
        let end = e.run();
        assert_eq!(e.model.fired_at, vec![SimTime(0), SimTime(10), SimTime(20), SimTime(30)]);
        assert_eq!(end, SimTime(30));
        assert_eq!(e.events_processed(), 4);
    }

    struct Stopper {
        handled: u32,
    }

    impl Model for Stopper {
        type Event = u32;

        fn handle(&mut self, _now: SimTime, _ev: u32, queue: &mut EventQueue<u32>) {
            self.handled += 1;
            queue.schedule_in(SimTime(1), 0);
        }

        fn done(&self) -> bool {
            self.handled >= 5
        }
    }

    #[test]
    fn done_predicate_stops_engine() {
        let mut e = Engine::new(Stopper { handled: 0 });
        e.seed(SimTime(0), 0);
        e.run();
        assert_eq!(e.model.handled, 5);
    }
}
