//! Deterministic event queue: min-heap on (time, sequence number) so
//! simultaneous events pop in insertion order.
//!
//! Events scheduled at exactly the current time bypass the heap: they go
//! to a FIFO side queue (`immediate`) and pop without any sift cost — the
//! serving model's retirement events are all scheduled "at now", so the
//! fast path turns their heap push+pop into two `VecDeque` ends. The
//! (time, seq) pop order is preserved exactly: every immediate entry
//! carries its globally-assigned sequence number, and [`EventQueue::pop`]
//! compares the immediate front against the heap top on the same
//! `(time, seq)` key the single-heap design ordered by.

use super::time::SimTime;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

struct Entry<E> {
    key: Reverse<(SimTime, u64)>,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// A time-ordered event queue with FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    /// Pop-min fast path: events scheduled at exactly `now`, FIFO. The
    /// invariant that makes this sound: time only advances by popping the
    /// global minimum, and an immediate entry (at `now`) is never greater
    /// than a heap entry (at `>= now` by the scheduling assert) — so time
    /// cannot advance while this queue is non-empty, and every entry here
    /// is always timestamped exactly `now`.
    immediate: VecDeque<(u64, E)>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::new(),
            immediate: VecDeque::new(),
            seq: 0,
            now: SimTime::ZERO,
        }
    }

    /// A queue whose heap starts with room for `capacity` pending events —
    /// models that know their steady-state event population (e.g. one
    /// in-flight event per device plus one arrival) can skip the early
    /// growth reallocations.
    pub fn with_capacity(capacity: usize) -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::with_capacity(capacity),
            immediate: VecDeque::new(),
            seq: 0,
            now: SimTime::ZERO,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at`. Panics if `at` is in the
    /// past (events may be scheduled at exactly `now` — those take the
    /// heap-free fast path).
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        assert!(at >= self.now, "scheduling into the past: {at:?} < {:?}", self.now);
        let seq = self.seq;
        self.seq += 1;
        if at == self.now {
            self.immediate.push_back((seq, payload));
        } else {
            self.heap.push(Entry { key: Reverse((at, seq)), payload });
        }
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        self.schedule(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing `now`. Ties at the same
    /// timestamp break by sequence number (insertion order), whether the
    /// entries sit on the heap or the immediate fast path.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let take_immediate = match (self.immediate.front(), self.heap.peek()) {
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => return None,
            (Some(&(seq_i, _)), Some(top)) => {
                let Reverse((t_h, seq_h)) = top.key;
                (self.now, seq_i) < (t_h, seq_h)
            }
        };
        if take_immediate {
            let (_, payload) = self.immediate.pop_front().expect("checked non-empty");
            Some((self.now, payload))
        } else {
            self.heap.pop().map(|e| {
                let Reverse((t, _)) = e.key;
                self.now = t;
                (t, e.payload)
            })
        }
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty() && self.immediate.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len() + self.immediate.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(30), "c");
        q.schedule(SimTime(10), "a");
        q.schedule(SimTime(20), "b");
        assert_eq!(q.pop().unwrap(), (SimTime(10), "a"));
        assert_eq!(q.pop().unwrap(), (SimTime(20), "b"));
        assert_eq!(q.pop().unwrap(), (SimTime(30), "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(SimTime(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().1, i);
        }
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(10), ());
        q.pop();
        assert_eq!(q.now(), SimTime(10));
        q.schedule_in(SimTime(5), ());
        assert_eq!(q.pop().unwrap().0, SimTime(15));
    }

    #[test]
    fn immediate_fast_path_preserves_seq_order_against_heap() {
        // Heap entry at t=10 first, then two immediate entries at t=10
        // scheduled *after* popping to t=10 — but also a heap entry at
        // t=10 scheduled before them. Pop order must follow sequence
        // numbers exactly, interleaving heap and fast-path entries.
        let mut q = EventQueue::new();
        q.schedule(SimTime(10), "heap-a"); // seq 0
        q.pop(); // now = 10
        q.schedule(SimTime(10), "imm-b"); // seq 1, fast path
        q.schedule(SimTime(12), "heap-d"); // seq 2
        q.schedule(SimTime(10), "imm-c"); // seq 3, fast path
        assert_eq!(q.len(), 3);
        assert!(!q.is_empty());
        assert_eq!(q.pop().unwrap(), (SimTime(10), "imm-b"));
        assert_eq!(q.pop().unwrap(), (SimTime(10), "imm-c"));
        assert_eq!(q.pop().unwrap(), (SimTime(12), "heap-d"));
        assert!(q.is_empty());
    }

    #[test]
    fn immediate_entries_never_outlive_their_instant() {
        let mut q = EventQueue::with_capacity(8);
        q.schedule(SimTime(5), 0u32);
        q.pop(); // now = 5
        q.schedule(SimTime(7), 1); // heap
        q.schedule(SimTime(5), 2); // fast path, same instant
        // The fast-path entry (t=5) pops before the heap's t=7 entry even
        // though the heap entry was scheduled first.
        assert_eq!(q.pop().unwrap(), (SimTime(5), 2));
        assert_eq!(q.pop().unwrap(), (SimTime(7), 1));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(10), ());
        q.pop();
        q.schedule(SimTime(5), ());
    }
}
