//! Deterministic event queue: min-heap on (time, sequence number) so
//! simultaneous events pop in insertion order.

use super::time::SimTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

struct Entry<E> {
    key: Reverse<(SimTime, u64)>,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// A time-ordered event queue with FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: SimTime::ZERO }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at`. Panics if `at` is in the
    /// past (events may be scheduled at exactly `now`).
    pub fn schedule(&mut self, at: SimTime, payload: E) {
        assert!(at >= self.now, "scheduling into the past: {at:?} < {:?}", self.now);
        let key = Reverse((at, self.seq));
        self.seq += 1;
        self.heap.push(Entry { key, payload });
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        self.schedule(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            let Reverse((t, _)) = e.key;
            self.now = t;
            (t, e.payload)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(30), "c");
        q.schedule(SimTime(10), "a");
        q.schedule(SimTime(20), "b");
        assert_eq!(q.pop().unwrap(), (SimTime(10), "a"));
        assert_eq!(q.pop().unwrap(), (SimTime(20), "b"));
        assert_eq!(q.pop().unwrap(), (SimTime(30), "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(SimTime(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().1, i);
        }
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(10), ());
        q.pop();
        assert_eq!(q.now(), SimTime(10));
        q.schedule_in(SimTime(5), ());
        assert_eq!(q.pop().unwrap().0, SimTime(15));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(10), ());
        q.pop();
        q.schedule(SimTime(5), ());
    }
}
