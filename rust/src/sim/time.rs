//! Simulation time: integer picoseconds.
//!
//! f64 seconds are fine for analytic models, but event ordering must be
//! exact — equal-time events tie-break by insertion order, and repeated
//! float accumulation would make that fragile. 2^64 ps ≈ 213 days of
//! simulated time, far beyond any experiment here.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point (or span) in simulated time, in picoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    /// From seconds (f64, as produced by the circuit model). Rounds to the
    /// nearest picosecond.
    pub fn from_secs(s: f64) -> SimTime {
        assert!(s >= 0.0 && s.is_finite(), "bad duration {s}");
        SimTime((s * 1e12).round() as u64)
    }

    pub fn from_ns(ns: f64) -> SimTime {
        SimTime::from_secs(ns * 1e-9)
    }

    pub fn from_us(us: f64) -> SimTime {
        SimTime::from_secs(us * 1e-6)
    }

    /// To seconds.
    pub fn secs(self) -> f64 {
        self.0 as f64 * 1e-12
    }

    /// Saturating subtraction.
    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }

    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_add(rhs.0).expect("sim time overflow"))
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        *self = *self + rhs;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("sim time underflow"))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::units::fmt_time(self.secs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_seconds() {
        let t = SimTime::from_secs(1.79e-6);
        assert!((t.secs() - 1.79e-6).abs() < 1e-15);
    }

    #[test]
    fn ordering_is_exact() {
        let a = SimTime::from_ns(1.0);
        let b = SimTime::from_ns(1.0);
        assert_eq!(a, b);
        assert!(a + SimTime(1) > b);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_us(2.0);
        let b = SimTime::from_us(0.5);
        assert!(((a + b).secs() - 2.5e-6).abs() < 1e-15);
        assert!(((a - b).secs() - 1.5e-6).abs() < 1e-15);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "bad duration")]
    fn rejects_negative() {
        SimTime::from_secs(-1.0);
    }
}
