//! Execution tracing for the simulator: cheap, bounded, and queryable in
//! tests. Categories mirror the paper's pipeline stages so latency
//! breakdowns (Fig. 12, Fig. 14b) can be extracted from a trace.

use super::time::SimTime;

/// One trace record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub start: SimTime,
    pub end: SimTime,
    /// Stage/category, e.g. "inbound", "pim", "outbound", "rpu", "core".
    pub category: &'static str,
    /// Free-form label (resource id, op id).
    pub label: String,
}

/// A bounded trace buffer.
#[derive(Debug, Default)]
pub struct Trace {
    events: Vec<TraceEvent>,
    enabled: bool,
    cap: usize,
}

impl Trace {
    /// Disabled trace (zero overhead beyond the branch).
    pub fn disabled() -> Trace {
        Trace { events: Vec::new(), enabled: false, cap: 0 }
    }

    /// Enabled with a record cap (drops silently past the cap).
    pub fn enabled(cap: usize) -> Trace {
        Trace { events: Vec::new(), enabled: true, cap }
    }

    pub fn record(&mut self, start: SimTime, end: SimTime, category: &'static str, label: impl Into<String>) {
        if self.enabled && self.events.len() < self.cap {
            self.events.push(TraceEvent { start, end, category, label: label.into() });
        }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Total busy time in a category (sums overlapping records).
    pub fn category_time(&self, category: &str) -> SimTime {
        let mut total = SimTime::ZERO;
        for e in &self.events {
            if e.category == category {
                total += e.end - e.start;
            }
        }
        total
    }

    /// Count of records in a category.
    pub fn category_count(&self, category: &str) -> usize {
        self.events.iter().filter(|e| e.category == category).count()
    }

    /// Latest end time across all records.
    pub fn makespan(&self) -> SimTime {
        self.events.iter().map(|e| e.end).max().unwrap_or(SimTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_when_enabled() {
        let mut t = Trace::enabled(10);
        t.record(SimTime(0), SimTime(5), "pim", "p0");
        t.record(SimTime(5), SimTime(9), "pim", "p1");
        t.record(SimTime(2), SimTime(3), "inbound", "x");
        assert_eq!(t.category_count("pim"), 2);
        assert_eq!(t.category_time("pim"), SimTime(9));
        assert_eq!(t.makespan(), SimTime(9));
    }

    #[test]
    fn silent_when_disabled() {
        let mut t = Trace::disabled();
        t.record(SimTime(0), SimTime(5), "pim", "p0");
        assert!(t.events().is_empty());
    }

    #[test]
    fn respects_cap() {
        let mut t = Trace::enabled(2);
        for i in 0..5 {
            t.record(SimTime(i), SimTime(i + 1), "x", "");
        }
        assert_eq!(t.events().len(), 2);
    }
}
