//! Multi-class workload scenarios for the serving simulators.
//!
//! The paper evaluates single-batch generation against one request shape
//! at a time, but a deployed device pool sees a *blend*: short
//! interactive chat turns, 1K+-token summarization prefills, bursty
//! agentic follow-up chains, and offline batch fills — the heterogeneous
//! serving mixes PIM-AI (UPMEM) and Cambricon-LLM evaluate on-device.
//! This module models such blends:
//!
//! * [`WorkloadClass`] — one request class: arrival-share weight,
//!   prompt/output [`LenRange`]s, follow-up probability, and per-class
//!   [`SloTarget`]s (TTFT / TPOT).
//! * [`WorkloadMix`] — a named, weighted set of classes. Built-in
//!   scenario presets come from [`crate::config::presets::workload_preset`]
//!   (`chat`, `summarize-long`, `agentic-burst`, `batch-offline`); custom
//!   mixes load from TOML via [`crate::config::WorkloadSpec`]. Attach a
//!   mix to a run through [`TrafficConfig::workload`].
//! * `ArrivalSampler` *(crate-internal)* — the one piece of code both
//!   serving backends draw arrivals through, so the class pick, follow-up
//!   decision, session choice, and length draws consume the shared RNG
//!   stream in identical order: same seed → bit-identical traces on
//!   either backend, with or without a mix.
//!
//! Class identity rides each request into the report:
//! [`PoolReport::class_reports`][super::metrics::PoolReport::class_reports]
//! summarizes TTFT/TPOT/latency percentiles and SLO attainment per class,
//! and the `slo-aware` scheduler ([`super::router::SloAware`]) uses the
//! arriving class's TTFT target to place jobs. On heterogeneous fleets
//! ([`TrafficConfig::fleet`]) the `tier-aware` scheduler
//! ([`super::router::TierAware`]) additionally steers each *fresh* turn
//! by prompt length and TTFT budget — but only fresh turns: a follow-up
//! reuses the session's resident KV, so a session is pinned to the
//! device (and therefore the tier) that served its first turn for its
//! whole lifetime. Class→tier splits in reports are thus exact only
//! when every class's fresh turns prefer one tier.
//!
//! # Example
//!
//! Build a two-class mix, run a small event-driven simulation, and read
//! the per-class report:
//!
//! ```
//! use flashpim::circuit::TechParams;
//! use flashpim::config::presets::table1_system;
//! use flashpim::coordinator::{
//!     policy_from_name, run_traffic_events, LenRange, SloTarget, TrafficConfig, WorkloadClass,
//!     WorkloadMix,
//! };
//! use flashpim::llm::{model_config::OptModel, LatencyTable};
//!
//! let short = WorkloadClass::new(
//!     "short",
//!     0.75,
//!     LenRange::new(16, 32),
//!     LenRange::new(2, 4),
//!     0.0,
//!     SloTarget { ttft: 0.2, tpot: 0.01 },
//! );
//! let long = WorkloadClass::new(
//!     "long",
//!     0.25,
//!     LenRange::new(96, 128),
//!     LenRange::new(4, 8),
//!     0.0,
//!     SloTarget { ttft: 1.0, tpot: 0.01 },
//! );
//! let mix = WorkloadMix::new("demo", vec![short, long]).unwrap();
//!
//! let sys = table1_system();
//! let model = OptModel::Opt6_7b.shape();
//! let table = LatencyTable::build_spanning(&sys, &TechParams::default(), model.clone(), 256, 64);
//! let mut cfg = TrafficConfig::default_for(2);
//! cfg.requests = 40;
//! cfg.rate = 30.0;
//! cfg.workload = Some(mix);
//!
//! let policy = policy_from_name("slo-aware").unwrap();
//! let report = run_traffic_events(&sys, &model, &table, policy, &cfg);
//! let classes = report.class_reports();
//! assert_eq!(classes.len(), 2);
//! assert_eq!((classes[0].name, classes[1].name), ("short", "long"));
//! assert_eq!(classes[0].arrivals + classes[1].arrivals, 40);
//! for c in &classes {
//!     assert!((0.0..=1.0).contains(&c.slo_attainment), "{}: {}", c.name, c.slo_attainment);
//! }
//! ```

use super::loadgen::{LenRange, TrafficConfig};
use crate::config::presets;
use crate::config::schema::{WorkloadClassSpec, WorkloadSpec};
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// Per-class service-level objectives — absolute targets in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    /// Time-to-first-token target (seconds).
    pub ttft: f64,
    /// Time-per-output-token target (seconds per token).
    pub tpot: f64,
}

impl SloTarget {
    /// No objectives: every served request trivially attains.
    pub const NONE: SloTarget = SloTarget { ttft: f64::INFINITY, tpot: f64::INFINITY };

    /// Does a served request with these observed metrics meet the
    /// targets? `tpot` is `None` for single-token outputs, where TPOT is
    /// undefined — vacuously met.
    pub fn met(&self, ttft_secs: f64, tpot_secs: Option<f64>) -> bool {
        let tpot_ok = match tpot_secs {
            Some(t) => t <= self.tpot,
            None => true,
        };
        ttft_secs <= self.ttft && tpot_ok
    }
}

/// One request class of a serving mix — the runtime counterpart of
/// [`WorkloadClassSpec`] (typed ranges instead of plain tuples).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadClass {
    pub name: String,
    /// Relative arrival-rate share; [`WorkloadMix`] normalizes across
    /// classes, so shares need not sum to 1.
    pub share: f64,
    pub input_tokens: LenRange,
    pub output_tokens: LenRange,
    /// Probability that an arrival of this class is a follow-up turn of
    /// one of the class's own finished sessions (sessions never change
    /// class mid-life).
    pub followup: f64,
    pub slo: SloTarget,
}

impl WorkloadClass {
    pub fn new(
        name: &str,
        share: f64,
        input_tokens: LenRange,
        output_tokens: LenRange,
        followup: f64,
        slo: SloTarget,
    ) -> WorkloadClass {
        WorkloadClass { name: name.to_string(), share, input_tokens, output_tokens, followup, slo }
    }

    /// Convert a validated schema class into its runtime form.
    pub fn from_spec(spec: &WorkloadClassSpec) -> Result<WorkloadClass> {
        spec.validate()?;
        Ok(WorkloadClass {
            name: spec.name.clone(),
            share: spec.share,
            input_tokens: LenRange::new(spec.input.0, spec.input.1),
            output_tokens: LenRange::new(spec.output.0, spec.output.1),
            followup: spec.followup,
            slo: SloTarget { ttft: spec.ttft_slo, tpot: spec.tpot_slo },
        })
    }

    /// The `chat` class preset — also the single definition behind
    /// [`TrafficConfig::default_for`]'s traffic shape.
    pub fn chat() -> WorkloadClass {
        WorkloadClass::from_spec(&presets::chat_class()).expect("chat preset is valid")
    }

    fn to_spec(&self) -> WorkloadClassSpec {
        WorkloadClassSpec {
            name: self.name.clone(),
            share: self.share,
            input: (self.input_tokens.lo, self.input_tokens.hi),
            output: (self.output_tokens.lo, self.output_tokens.hi),
            followup: self.followup,
            ttft_slo: self.slo.ttft,
            tpot_slo: self.slo.tpot,
        }
    }
}

/// A named, weighted set of [`WorkloadClass`]es sampled per arrival.
///
/// Class shares are normalized once at construction into cumulative
/// bounds, so a mix costs at most one extra RNG draw per arrival (none
/// for single-class mixes — the legacy single-class RNG stream is
/// preserved bit for bit).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadMix {
    name: String,
    classes: Vec<WorkloadClass>,
    /// Cumulative normalized share bounds; the last entry is exactly 1.0
    /// so any `u < 1.0` draw lands in a class.
    cum: Vec<f64>,
}

impl WorkloadMix {
    /// Build and validate a mix (via the schema validation rules).
    pub fn new(name: &str, classes: Vec<WorkloadClass>) -> Result<WorkloadMix> {
        let spec = WorkloadSpec {
            name: name.to_string(),
            classes: classes.iter().map(WorkloadClass::to_spec).collect(),
        };
        spec.validate()?;
        let total: f64 = classes.iter().map(|c| c.share).sum();
        let mut acc = 0.0;
        let mut cum: Vec<f64> = classes
            .iter()
            .map(|c| {
                acc += c.share / total;
                acc
            })
            .collect();
        *cum.last_mut().expect("validated non-empty") = 1.0;
        Ok(WorkloadMix { name: name.to_string(), classes, cum })
    }

    /// Build from a validated schema spec.
    pub fn from_spec(spec: &WorkloadSpec) -> Result<WorkloadMix> {
        let classes =
            spec.classes.iter().map(WorkloadClass::from_spec).collect::<Result<Vec<_>>>()?;
        WorkloadMix::new(&spec.name, classes)
    }

    /// Load a custom mix from a TOML file (see [`WorkloadSpec`] for the
    /// format and `docs/WORKLOADS.md` for a walkthrough).
    pub fn from_file(path: &Path) -> Result<WorkloadMix> {
        WorkloadMix::from_spec(&WorkloadSpec::from_file(path)?)
    }

    /// A built-in scenario preset by name (see [`Self::preset_names`]).
    pub fn preset(name: &str) -> Option<WorkloadMix> {
        let spec = presets::workload_preset(name)?;
        Some(WorkloadMix::from_spec(&spec).expect("built-in presets are valid"))
    }

    /// Names accepted by [`Self::preset`] / `serve-sim --workload`.
    pub fn preset_names() -> &'static [&'static str] {
        presets::WORKLOAD_PRESETS
    }

    /// Resolve a `--workload` argument: a preset name, else a TOML path.
    pub fn resolve(arg: &str) -> Result<WorkloadMix> {
        if let Some(mix) = WorkloadMix::preset(arg) {
            return Ok(mix);
        }
        WorkloadMix::from_file(Path::new(arg)).with_context(|| {
            format!(
                "--workload {arg:?} is neither a built-in preset ({}) nor a readable TOML file",
                WorkloadMix::preset_names().join(", ")
            )
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn classes(&self) -> &[WorkloadClass] {
        &self.classes
    }

    /// Normalized arrival share of class `i`.
    pub fn share(&self, i: usize) -> f64 {
        self.cum[i] - if i == 0 { 0.0 } else { self.cum[i - 1] }
    }

    /// Largest output-length upper bound across classes — sizes the event
    /// budget of a run.
    pub fn max_output_tokens(&self) -> usize {
        self.classes.iter().map(|c| c.output_tokens.hi).max().expect("non-empty mix")
    }

    /// Render as the TOML the [`WorkloadSpec`] parser reads back.
    pub fn to_toml(&self) -> String {
        WorkloadSpec {
            name: self.name.clone(),
            classes: self.classes.iter().map(WorkloadClass::to_spec).collect(),
        }
        .to_toml()
    }

    /// Map a uniform `u ∈ [0, 1)` draw to a class index: the first class
    /// whose cumulative bound exceeds `u` (clamped for safety — `u` is
    /// always below the final bound of 1.0).
    fn pick_class(&self, u: f64) -> usize {
        self.cum.partition_point(|&c| u >= c).min(self.cum.len() - 1)
    }
}

/// One sampled arrival: the session it belongs to, its class, and its
/// drawn shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct Arrival {
    pub session: u64,
    pub class: usize,
    /// This arrival reuses a finished session of its class.
    pub followup: bool,
    pub input_tokens: usize,
    pub output_tokens: usize,
}

/// The single arrival-sampling path shared by both serving backends
/// (event-driven and direct replay), so their RNG streams stay in
/// lockstep by construction. Per arrival the draw order is fixed:
///
/// 1. class pick — one `f64` draw, **skipped for single-class mixes** so
///    legacy single-class configs keep their exact pre-workload streams;
/// 2. follow-up Bernoulli — unconditional (not short-circuited on an
///    empty idle set, whose timeline differs slightly between backends);
/// 3. idle-session pick within the class — only when reusing;
/// 4. prompt and output length draws from the class's ranges.
///
/// Sessions are filed per class: a follow-up turn continues a session of
/// the *same* class (an agentic chain stays agentic), which is also what
/// keeps the per-class report semantics clean.
#[derive(Debug, Clone)]
pub(super) struct ArrivalSampler {
    mix: WorkloadMix,
    /// Follow-up-eligible finished sessions, per class.
    idle: Vec<Vec<u64>>,
    next_session: u64,
}

impl ArrivalSampler {
    /// Build from a traffic config: its [`TrafficConfig::workload`] mix,
    /// or a synthetic single class from the legacy scalar fields. The
    /// scalar `followup` is clamped to `[0, 1]` — `Rng::chance` always
    /// saturated out-of-range probabilities, so library callers who
    /// relied on that keep working instead of tripping mix validation.
    pub fn new(cfg: &TrafficConfig) -> ArrivalSampler {
        // NaN behaves like "never" (`Rng::chance(NaN)` is false).
        let followup =
            if cfg.followup.is_nan() { 0.0 } else { cfg.followup.clamp(0.0, 1.0) };
        let mix = match &cfg.workload {
            Some(mix) => mix.clone(),
            None => WorkloadMix::new(
                "single",
                vec![WorkloadClass::new(
                    "default",
                    1.0,
                    cfg.input_tokens,
                    cfg.output_tokens,
                    followup,
                    SloTarget::NONE,
                )],
            )
            .expect("single-class mix is valid"),
        };
        let idle = vec![Vec::new(); mix.classes().len()];
        ArrivalSampler { mix, idle, next_session: 0 }
    }

    pub fn classes(&self) -> &[WorkloadClass] {
        self.mix.classes()
    }

    /// Draw one arrival (see the type-level doc for the draw order).
    pub fn sample(&mut self, rng: &mut Rng) -> Arrival {
        let class =
            if self.mix.classes().len() == 1 { 0 } else { self.mix.pick_class(rng.f64()) };
        let c = &self.mix.classes()[class];
        let chance = rng.chance(c.followup);
        let reuse = !self.idle[class].is_empty() && chance;
        let session = if reuse {
            let pick = rng.range(0, self.idle[class].len());
            self.idle[class].swap_remove(pick)
        } else {
            self.next_session += 1;
            self.next_session
        };
        let input_tokens = c.input_tokens.sample(rng);
        let output_tokens = c.output_tokens.sample(rng);
        Arrival { session, class, followup: reuse, input_tokens, output_tokens }
    }

    /// A session's turn retired (or its follow-up arrival was rejected):
    /// it becomes follow-up-eligible again.
    pub fn release(&mut self, session: u64, class: usize) {
        self.idle[class].push(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_class_mix() -> WorkloadMix {
        WorkloadMix::new(
            "two",
            vec![
                WorkloadClass::new(
                    "a",
                    3.0,
                    LenRange::new(8, 16),
                    LenRange::new(2, 4),
                    0.0,
                    SloTarget::NONE,
                ),
                WorkloadClass::new(
                    "b",
                    1.0,
                    LenRange::new(64, 128),
                    LenRange::new(8, 16),
                    0.5,
                    SloTarget { ttft: 0.5, tpot: 0.01 },
                ),
            ],
        )
        .unwrap()
    }

    #[test]
    fn shares_normalize_and_cumulate() {
        let mix = two_class_mix();
        assert!((mix.share(0) - 0.75).abs() < 1e-12);
        assert!((mix.share(1) - 0.25).abs() < 1e-12);
        assert_eq!(mix.pick_class(0.0), 0);
        assert_eq!(mix.pick_class(0.7499), 0);
        assert_eq!(mix.pick_class(0.7501), 1);
        assert_eq!(mix.pick_class(0.999_999), 1);
        assert_eq!(mix.max_output_tokens(), 16);
    }

    #[test]
    fn presets_resolve_and_reject() {
        for name in WorkloadMix::preset_names() {
            let mix = WorkloadMix::preset(name).expect("preset exists");
            assert_eq!(mix.name(), *name);
            let total: f64 = (0..mix.classes().len()).map(|i| mix.share(i)).sum();
            assert!((total - 1.0).abs() < 1e-12, "{name}: shares sum to {total}");
        }
        assert!(WorkloadMix::preset("bogus").is_none());
        assert!(WorkloadMix::resolve("chat").is_ok());
        assert!(WorkloadMix::resolve("/no/such/file.toml").is_err());
    }

    #[test]
    fn chat_class_backs_default_traffic() {
        let chat = WorkloadClass::chat();
        let cfg = TrafficConfig::default_for(4);
        assert_eq!(cfg.input_tokens, chat.input_tokens);
        assert_eq!(cfg.output_tokens, chat.output_tokens);
        assert_eq!(cfg.followup, chat.followup);
    }

    #[test]
    fn single_class_sampler_matches_legacy_stream() {
        // A sampler over a single-class mix must consume the RNG exactly
        // as the pre-workload sampler did: Bernoulli, conditional idle
        // pick, two length draws — and never a class draw.
        let cfg = TrafficConfig::default_for(2);
        let mut sampler = ArrivalSampler::new(&cfg);
        let mut rng = Rng::new(7);
        let mut reference = Rng::new(7);
        for turn in 0..200 {
            let arr = sampler.sample(&mut rng);
            // Replay the legacy draw order by hand.
            let chance = reference.chance(cfg.followup);
            let idle_len = sampler.idle[0].len() + usize::from(arr.followup);
            let reuse = chance && idle_len > 0;
            if reuse {
                reference.range(0, idle_len);
            }
            let l_in = cfg.input_tokens.sample(&mut reference);
            let l_out = cfg.output_tokens.sample(&mut reference);
            assert_eq!((arr.followup, arr.input_tokens, arr.output_tokens), (reuse, l_in, l_out));
            assert_eq!(arr.class, 0);
            // Retire every third turn so the idle set grows and follow-ups
            // actually occur.
            if turn % 3 == 0 {
                sampler.release(arr.session, arr.class);
            }
        }
    }

    #[test]
    fn followups_stay_within_their_class() {
        let mut cfg = TrafficConfig::default_for(2);
        cfg.workload = Some(two_class_mix());
        let mut sampler = ArrivalSampler::new(&cfg);
        let mut rng = Rng::new(42);
        let mut class_of = std::collections::HashMap::new();
        for _ in 0..2000 {
            let arr = sampler.sample(&mut rng);
            if let Some(prev) = class_of.get(&arr.session) {
                assert_eq!(*prev, arr.class, "session {} switched class", arr.session);
                assert!(arr.followup);
            }
            class_of.insert(arr.session, arr.class);
            sampler.release(arr.session, arr.class);
        }
        // Both fresh and follow-up paths were exercised for class b.
        assert!(class_of.values().filter(|c| **c == 1).count() > 50);
    }

    #[test]
    fn mix_toml_round_trips() {
        let mix = two_class_mix();
        let doc = crate::config::toml_lite::parse(&mix.to_toml()).unwrap();
        let back = WorkloadMix::from_spec(&WorkloadSpec::from_doc(&doc).unwrap()).unwrap();
        assert_eq!(mix, back);
    }

    #[test]
    fn slo_target_met_semantics() {
        let slo = SloTarget { ttft: 0.1, tpot: 0.01 };
        assert!(slo.met(0.1, Some(0.01)));
        assert!(!slo.met(0.11, Some(0.005)));
        assert!(!slo.met(0.05, Some(0.02)));
        assert!(slo.met(0.05, None), "single-token outputs have no TPOT");
        assert!(SloTarget::NONE.met(1e9, Some(1e9)));
    }
}
