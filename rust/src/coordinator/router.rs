//! The routing policy (paper §I): "Instead of pressing GPUs to handle
//! multi-batch summarization and generation, we propose to assign the
//! single-batch generation task to a flash PIM device so that GPUs are
//! released for other summarization requests."
//!
//! Admission control: a generation request needs SLC KV-region space for
//! its whole context before it is dispatched; otherwise it queues.

use super::request::{Request, RequestKind};
use crate::kv::cache::KvCacheManager;

/// Routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Run on the GPU pool (summarization / prefill).
    Gpu,
    /// Offload to the flash PIM device (single-batch generation).
    Flash,
    /// Hold in the admission queue (KV region full).
    Queue,
}

/// Router with KV admission control.
pub struct Router {
    pub kv: KvCacheManager,
}

impl Router {
    pub fn new(kv: KvCacheManager) -> Router {
        Router { kv }
    }

    /// Decide where a request goes right now.
    pub fn route(&self, req: &Request) -> Route {
        match req.kind {
            RequestKind::Summarize { .. } => Route::Gpu,
            RequestKind::Generate { input_tokens, output_tokens } => {
                let need = (input_tokens + output_tokens) as u64 * self.kv.per_token;
                if self.kv.used() + need <= self.kv.capacity {
                    Route::Flash
                } else {
                    Route::Queue
                }
            }
        }
    }

    /// Admit a generation request (reserve its initial KV).
    pub fn admit(&mut self, req: &Request) -> anyhow::Result<()> {
        match req.kind {
            RequestKind::Generate { input_tokens, .. } => self.kv.admit(req.id, input_tokens),
            _ => Ok(()),
        }
    }

    /// Record one generated token.
    pub fn on_token(&mut self, req_id: u64) -> anyhow::Result<()> {
        self.kv.append(req_id)
    }

    /// Release a finished generation request.
    pub fn finish(&mut self, req_id: u64) -> anyhow::Result<()> {
        self.kv.release(req_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;
    use crate::sim::SimTime;

    fn router() -> Router {
        Router::new(KvCacheManager::new(&table1_system(), &OptModel::Opt30b.shape()))
    }

    #[test]
    fn summaries_go_to_gpu() {
        let r = router();
        assert_eq!(r.route(&Request::summarize(1, SimTime::ZERO, 1024)), Route::Gpu);
    }

    #[test]
    fn generation_goes_to_flash() {
        let r = router();
        assert_eq!(r.route(&Request::generate(1, SimTime::ZERO, 1024, 1024)), Route::Flash);
    }

    #[test]
    fn oversize_generation_queues() {
        let r = router();
        let huge = (r.kv.capacity / r.kv.per_token + 1) as usize;
        assert_eq!(r.route(&Request::generate(1, SimTime::ZERO, huge, 1)), Route::Queue);
    }

    #[test]
    fn admission_lifecycle() {
        let mut r = router();
        let req = Request::generate(7, SimTime::ZERO, 100, 10);
        r.admit(&req).unwrap();
        for _ in 0..10 {
            r.on_token(7).unwrap();
        }
        r.finish(7).unwrap();
        assert_eq!(r.kv.used(), 0);
    }

    #[test]
    fn queue_admits_after_release() {
        let mut r = router();
        let max = (r.kv.capacity / r.kv.per_token) as usize;
        let big = Request::generate(1, SimTime::ZERO, max - 1, 1);
        r.admit(&big).unwrap();
        let next = Request::generate(2, SimTime::ZERO, 1024, 128);
        assert_eq!(r.route(&next), Route::Queue);
        r.finish(1).unwrap();
        assert_eq!(r.route(&next), Route::Flash);
    }
}
