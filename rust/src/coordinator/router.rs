//! The routing policy (paper §I): "Instead of pressing GPUs to handle
//! multi-batch summarization and generation, we propose to assign the
//! single-batch generation task to a flash PIM device so that GPUs are
//! released for other summarization requests."
//!
//! Admission control: a generation request needs SLC KV-region space for
//! its whole context before it is dispatched; otherwise it queues.
//!
//! With a device *pool* (N flash-PIM devices behind one scheduler) the
//! router additionally picks a device per job: [`Scheduler`] policies
//! ([`RoundRobin`], [`LeastLoaded`], [`SloAware`]) balance fresh
//! sessions, and [`DeviceRouter`] pins follow-up turns to the device
//! already holding the session's SLC KV cache (KV affinity, via
//! [`crate::kv::cache`]). Every pick sees per-device [`DeviceStatus`]
//! (queue depth, estimated wait, KV usage) plus the arriving job's
//! [`JobInfo`] (estimated prefill, the class's TTFT target), which is
//! what lets [`SloAware`] place a job by whether a queue endangers its
//! class's first-token deadline.

use super::device::{DeviceModel, Tier};
use super::request::{Request, RequestKind};
use crate::config::SystemConfig;
use crate::kv::cache::KvCacheManager;
use crate::llm::model_config::ModelShape;
use crate::sim::SimTime;
use std::cmp::Reverse;
use std::collections::HashMap;

/// Routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Run on the GPU pool (summarization / prefill).
    Gpu,
    /// Offload to the flash PIM device (single-batch generation).
    Flash,
    /// Hold in the admission queue (KV region full).
    Queue,
}

/// Router with KV admission control.
pub struct Router {
    pub kv: KvCacheManager,
}

impl Router {
    pub fn new(kv: KvCacheManager) -> Router {
        Router { kv }
    }

    /// Decide where a request goes right now.
    pub fn route(&self, req: &Request) -> Route {
        match req.kind {
            RequestKind::Summarize { .. } => Route::Gpu,
            RequestKind::Generate { input_tokens, output_tokens } => {
                let need = (input_tokens + output_tokens) as u64 * self.kv.per_token;
                if self.kv.used() + need <= self.kv.capacity {
                    Route::Flash
                } else {
                    Route::Queue
                }
            }
        }
    }

    /// Admit a generation request (reserve its initial KV).
    pub fn admit(&mut self, req: &Request) -> anyhow::Result<()> {
        match req.kind {
            RequestKind::Generate { input_tokens, .. } => self.kv.admit(req.id, input_tokens),
            _ => Ok(()),
        }
    }

    /// Record one generated token.
    pub fn on_token(&mut self, req_id: u64) -> anyhow::Result<()> {
        self.kv.append(req_id)
    }

    /// Release a finished generation request.
    pub fn finish(&mut self, req_id: u64) -> anyhow::Result<()> {
        self.kv.release(req_id)
    }
}

/// Snapshot of one pool device, fed to a [`Scheduler`] pick. Status slices
/// always cover every device in index order (`status[i].device == i`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceStatus {
    pub device: usize,
    /// Jobs queued or running on the device.
    pub queue_depth: usize,
    /// Time until the device would *start* a job enqueued now — the sum
    /// of the remaining service of everything queued or running. Both
    /// simulation backends supply it exactly (FIFO work-conserving
    /// queues); the functional pool reports zero, so time-based policies
    /// degrade to depth/index tie-breaks there.
    pub est_wait: SimTime,
    /// Bytes used in the device's SLC KV region.
    pub kv_used: u64,
    /// Capacity of the device's SLC KV region.
    pub kv_capacity: u64,
    /// Device tier — lets tier-sensitive policies ([`TierAware`]) and
    /// per-tier feasibility checks see what kind of device this is.
    pub tier: Tier,
    /// Erases charged against the device's P/E budget so far. Zero when
    /// wear accounting is disabled (and for GPU devices, which have no
    /// erase budget) — wear-blind policies never read it.
    pub wear_used: u64,
    /// Total erase capacity (`blocks × pe_budget`); zero when wear
    /// accounting is disabled.
    pub wear_budget: u64,
}

/// What a [`Scheduler`] knows about the arriving job beyond the pool
/// state: how long its prefill would take on an idle device and how
/// tight its class's first-token deadline is.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobInfo {
    /// Estimated prefill time on an idle *flash* device, seconds (KV
    /// upload + SLC prompt write + first decode step, for a fresh
    /// session). Single-tier callers fill only this field and
    /// [`JobInfo::est_prefill_gpu`] mirrors it.
    pub est_prefill: f64,
    /// Estimated prefill time on an idle *GPU* device, seconds. Equal to
    /// `est_prefill` on single-tier fleets so tier-blind policies behave
    /// identically either way.
    pub est_prefill_gpu: f64,
    /// Prompt length of the arriving turn — what [`TierAware`] splits on.
    pub prompt_tokens: usize,
    /// TTFT SLO target of the arriving class, seconds;
    /// `f64::INFINITY` when the class (or a classless run) has none.
    pub ttft_target: f64,
}

impl JobInfo {
    /// No deadline and no footprint — what callers outside the traffic
    /// simulators (e.g. the functional pool) pass.
    pub fn unconstrained() -> JobInfo {
        JobInfo {
            est_prefill: 0.0,
            est_prefill_gpu: 0.0,
            prompt_tokens: 0,
            ttft_target: f64::INFINITY,
        }
    }

    /// The prefill estimate that applies on a device of `tier`.
    pub fn est_prefill_on(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Flash => self.est_prefill,
            Tier::Gpu => self.est_prefill_gpu,
        }
    }
}

/// Device-selection policy for fresh sessions (follow-up turns bypass the
/// policy — KV affinity pins them, see [`DeviceRouter::assign`]).
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick a device index for a fresh job described by `job`. `status`
    /// is never empty.
    fn pick(&mut self, status: &[DeviceStatus], job: &JobInfo) -> usize;
}

/// Cycle through devices regardless of load.
#[derive(Debug, Clone, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, status: &[DeviceStatus], _job: &JobInfo) -> usize {
        assert!(!status.is_empty(), "pick over empty pool");
        let i = self.next % status.len();
        self.next = (i + 1) % status.len();
        status[i].device
    }
}

/// Pick the device with the shallowest queue; break ties by KV usage, then
/// by index (deterministic).
#[derive(Debug, Clone, Default)]
pub struct LeastLoaded;

impl LeastLoaded {
    pub fn new() -> LeastLoaded {
        LeastLoaded
    }
}

impl Scheduler for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, status: &[DeviceStatus], _job: &JobInfo) -> usize {
        status
            .iter()
            .min_by_key(|s| (s.queue_depth, s.kv_used, s.device))
            .expect("pick over empty pool")
            .device
    }
}

/// SLO-aware placement: among the devices whose current backlog would
/// still let the arriving job produce its first token within its class's
/// TTFT target (`est_wait + est_prefill <= ttft_target`), pick the one
/// with the **deepest feasible backlog**. That is deliberate bin-packing,
/// not load spreading: loose-deadline work (summarization, offline batch)
/// piles onto already-busy devices, which keeps lightly-loaded devices
/// free for the tight-deadline classes that cannot tolerate queueing
/// behind a 1K-token prefill. When no device can meet the target the
/// deadline is already lost, so it falls back to least-loaded-in-time
/// (minimum `est_wait`) to shed the damage minimally.
///
/// Ties break by queue depth (so callers whose status carries no time
/// estimate — the functional pool — still pack by real load instead of
/// collapsing onto device 0), then lower KV usage, then lower index —
/// fully deterministic, like every policy here.
#[derive(Debug, Clone, Default)]
pub struct SloAware;

impl SloAware {
    pub fn new() -> SloAware {
        SloAware
    }
}

impl Scheduler for SloAware {
    fn name(&self) -> &'static str {
        "slo-aware"
    }

    fn pick(&mut self, status: &[DeviceStatus], job: &JobInfo) -> usize {
        let feasible = status
            .iter()
            .filter(|s| s.est_wait.secs() + job.est_prefill_on(s.tier) <= job.ttft_target)
            // Deepest feasible backlog (by time, then by queue depth),
            // then least KV, then lowest index.
            .max_by_key(|s| {
                (s.est_wait, s.queue_depth, Reverse(s.kv_used), Reverse(s.device))
            });
        match feasible {
            Some(s) => s.device,
            None => status
                .iter()
                .min_by_key(|s| (s.est_wait, s.queue_depth, s.kv_used, s.device))
                .expect("pick over empty pool")
                .device,
        }
    }
}

/// Prompt length (tokens) at which [`TierAware`] starts preferring the
/// GPU tier: prefill is compute-bound and the GPU roofline wins long
/// prompts, while flash wins the per-token decode that dominates short
/// chat turns (the paper's §I split, as a scheduling policy).
pub const GPU_PROMPT_SPLIT: usize = 512;

/// Tier-splitting placement for heterogeneous fleets: long prefills (≥
/// [`GPU_PROMPT_SPLIT`] prompt tokens) and jobs whose flash prefill
/// alone would already blow the class TTFT target prefer the GPU tier;
/// everything else — short, decode-heavy chat — prefers flash. Within
/// the preferred tier it falls back to full [`SloAware`] bin-packing,
/// and when the preferred tier is absent (single-tier fleet) it degrades
/// to plain `SloAware` over the whole pool.
#[derive(Debug, Clone, Default)]
pub struct TierAware {
    inner: SloAware,
}

impl TierAware {
    pub fn new() -> TierAware {
        TierAware::default()
    }

    /// Which tier this job wants, before availability is considered.
    pub fn preferred_tier(job: &JobInfo) -> Tier {
        if job.prompt_tokens >= GPU_PROMPT_SPLIT || job.est_prefill > job.ttft_target {
            Tier::Gpu
        } else {
            Tier::Flash
        }
    }
}

impl Scheduler for TierAware {
    fn name(&self) -> &'static str {
        "tier-aware"
    }

    fn pick(&mut self, status: &[DeviceStatus], job: &JobInfo) -> usize {
        assert!(!status.is_empty(), "pick over empty pool");
        let want = TierAware::preferred_tier(job);
        let subset: Vec<DeviceStatus> =
            status.iter().copied().filter(|s| s.tier == want).collect();
        if subset.is_empty() {
            self.inner.pick(status, job)
        } else {
            // `pick` returns the chosen row's `.device`, so filtering the
            // slice is safe — indices survive the subset.
            self.inner.pick(&subset, job)
        }
    }
}

/// Endurance-first placement for wear-budgeted fleets: among the devices
/// whose backlog still lets the arriving job meet its class TTFT target
/// (the same feasibility test as [`SloAware`]), pick the one with the
/// **fewest erases charged** so the program/erase budget drains evenly
/// across the fleet instead of concentrating on whichever device the
/// load balancer favours. Latency is bounded — infeasible devices are
/// never preferred — but within the feasible set wear spread wins over
/// queue depth, trading a little p95 for fleet lifetime. When no device
/// is feasible it sheds damage minimally, exactly like `SloAware`.
///
/// Ties break by queue depth, then KV usage, then index — deterministic.
#[derive(Debug, Clone, Default)]
pub struct WearAware;

impl WearAware {
    pub fn new() -> WearAware {
        WearAware
    }
}

impl Scheduler for WearAware {
    fn name(&self) -> &'static str {
        "wear-aware"
    }

    fn pick(&mut self, status: &[DeviceStatus], job: &JobInfo) -> usize {
        let feasible = status
            .iter()
            .filter(|s| s.est_wait.secs() + job.est_prefill_on(s.tier) <= job.ttft_target)
            .min_by_key(|s| (s.wear_used, s.queue_depth, s.kv_used, s.device));
        match feasible {
            Some(s) => s.device,
            None => status
                .iter()
                .min_by_key(|s| (s.est_wait, s.queue_depth, s.kv_used, s.device))
                .expect("pick over empty pool")
                .device,
        }
    }
}

/// Canonical names of every scheduling policy, ascending — the sweep and
/// campaign matrices iterate this list so "all policies" has exactly one
/// definition. Excludes [`TierAware`], which only makes sense on a
/// heterogeneous fleet — tiered callers iterate [`TIERED_POLICY_NAMES`] —
/// and [`WearAware`], which needs wear accounting enabled to differ from
/// `least-loaded` (opt in by name via [`policy_from_name`]).
pub const POLICY_NAMES: &[&str] = &["least-loaded", "round-robin", "slo-aware"];

/// Every policy including [`TierAware`] — the "all policies" list for
/// sweeps and campaigns that carry a fleet axis.
pub const TIERED_POLICY_NAMES: &[&str] =
    &["least-loaded", "round-robin", "slo-aware", "tier-aware"];

/// Build a scheduling policy from its CLI name.
pub fn policy_from_name(name: &str) -> Option<Box<dyn Scheduler + Send>> {
    match name {
        "round-robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least-loaded" | "ll" => Some(Box::new(LeastLoaded::new())),
        "slo-aware" | "slo" => Some(Box::new(SloAware::new())),
        "tier-aware" | "tier" => Some(Box::new(TierAware::new())),
        "wear-aware" | "wear" => Some(Box::new(WearAware::new())),
        _ => None,
    }
}

/// Multi-device router: owns one [`KvCacheManager`] per pool device and a
/// session → device placement map. A follow-up turn for a session whose KV
/// is still resident lands on the same device (affinity); fresh sessions go
/// through the [`Scheduler`] policy.
pub struct DeviceRouter {
    devices: Vec<KvCacheManager>,
    sessions: HashMap<u64, usize>,
    policy: Box<dyn Scheduler + Send>,
}

impl DeviceRouter {
    pub fn new(
        n_devices: usize,
        sys: &SystemConfig,
        model: &ModelShape,
        policy: Box<dyn Scheduler + Send>,
    ) -> DeviceRouter {
        assert!(n_devices > 0, "pool needs at least one device");
        let devices = (0..n_devices).map(|_| KvCacheManager::new(sys, model)).collect();
        DeviceRouter { devices, sessions: HashMap::new(), policy }
    }

    /// Router over a heterogeneous fleet: each device's KV region is
    /// sized by its [`DeviceModel`] (SLC geometry for flash, the VRAM
    /// budget for GPU), so capacity-fit is per tier.
    pub fn with_fleet(models: &[DeviceModel], policy: Box<dyn Scheduler + Send>) -> DeviceRouter {
        assert!(!models.is_empty(), "pool needs at least one device");
        let devices = models
            .iter()
            .map(|m| KvCacheManager::with_capacity(m.kv_capacity(), m.kv_per_token()))
            .collect();
        DeviceRouter { devices, sessions: HashMap::new(), policy }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Device holding this session's KV, if still resident.
    pub fn device_for(&self, session: u64) -> Option<usize> {
        self.sessions.get(&session).copied()
    }

    /// Pick the device for `session`: KV affinity first, else the policy
    /// (which sees the arriving job's [`JobInfo`]). Records the placement
    /// so later turns stick to the same device.
    pub fn assign(&mut self, session: u64, status: &[DeviceStatus], job: &JobInfo) -> usize {
        if let Some(d) = self.sessions.get(&session) {
            return *d;
        }
        let d = self.policy.pick(status, job);
        self.sessions.insert(session, d);
        d
    }

    pub fn kv(&self, device: usize) -> &KvCacheManager {
        &self.devices[device]
    }

    pub fn kv_mut(&mut self, device: usize) -> &mut KvCacheManager {
        &mut self.devices[device]
    }

    /// Sessions currently placed on `device`.
    pub fn sessions_on(&self, device: usize) -> Vec<u64> {
        self.sessions.iter().filter(|(_, d)| **d == device).map(|(s, _)| *s).collect()
    }

    /// Drop a session's KV residency (capacity eviction or session close).
    pub fn evict(&mut self, session: u64) -> anyhow::Result<()> {
        let d = self
            .sessions
            .remove(&session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session}"))?;
        self.devices[d].release(session)
    }

    /// Remove a placement that never admitted KV (e.g. rejected job).
    pub fn forget(&mut self, session: u64) {
        self.sessions.remove(&session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;
    use crate::sim::SimTime;

    fn router() -> Router {
        Router::new(KvCacheManager::new(&table1_system(), &OptModel::Opt30b.shape()))
    }

    #[test]
    fn summaries_go_to_gpu() {
        let r = router();
        assert_eq!(r.route(&Request::summarize(1, SimTime::ZERO, 1024)), Route::Gpu);
    }

    #[test]
    fn generation_goes_to_flash() {
        let r = router();
        assert_eq!(r.route(&Request::generate(1, SimTime::ZERO, 1024, 1024)), Route::Flash);
    }

    #[test]
    fn oversize_generation_queues() {
        let r = router();
        let huge = (r.kv.capacity / r.kv.per_token + 1) as usize;
        assert_eq!(r.route(&Request::generate(1, SimTime::ZERO, huge, 1)), Route::Queue);
    }

    #[test]
    fn admission_lifecycle() {
        let mut r = router();
        let req = Request::generate(7, SimTime::ZERO, 100, 10);
        r.admit(&req).unwrap();
        for _ in 0..10 {
            r.on_token(7).unwrap();
        }
        r.finish(7).unwrap();
        assert_eq!(r.kv.used(), 0);
    }

    #[test]
    fn queue_admits_after_release() {
        let mut r = router();
        let max = (r.kv.capacity / r.kv.per_token) as usize;
        let big = Request::generate(1, SimTime::ZERO, max - 1, 1);
        r.admit(&big).unwrap();
        let next = Request::generate(2, SimTime::ZERO, 1024, 128);
        assert_eq!(r.route(&next), Route::Queue);
        r.finish(1).unwrap();
        assert_eq!(r.route(&next), Route::Flash);
    }

    fn status(depths: &[usize]) -> Vec<DeviceStatus> {
        depths
            .iter()
            .enumerate()
            .map(|(i, &q)| DeviceStatus {
                device: i,
                queue_depth: q,
                // One second of estimated wait per queued job keeps the
                // depth- and time-based views consistent in these tests.
                est_wait: SimTime::from_secs(q as f64),
                kv_used: 0,
                kv_capacity: 1 << 30,
                tier: Tier::Flash,
                wear_used: 0,
                wear_budget: 0,
            })
            .collect()
    }

    fn any_job() -> JobInfo {
        JobInfo::unconstrained()
    }

    /// A single-tier job: both tier estimates carry the same value, as
    /// the traffic simulators produce for flash-only fleets.
    fn job(est_prefill: f64, ttft_target: f64) -> JobInfo {
        JobInfo { est_prefill, est_prefill_gpu: est_prefill, prompt_tokens: 0, ttft_target }
    }

    #[test]
    fn round_robin_is_fair() {
        let mut rr = RoundRobin::new();
        let s = status(&[0, 0, 0, 0]);
        let picks: Vec<usize> = (0..8).map(|_| rr.pick(&s, &any_job())).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        let mut counts = [0usize; 4];
        for p in picks {
            counts[p] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2), "uneven round-robin: {counts:?}");
    }

    #[test]
    fn round_robin_ignores_load() {
        let mut rr = RoundRobin::new();
        let s = status(&[9, 0]);
        assert_eq!(rr.pick(&s, &any_job()), 0); // cycles even onto the busy device
        assert_eq!(rr.pick(&s, &any_job()), 1);
    }

    #[test]
    fn least_loaded_prefers_emptier_device() {
        let mut ll = LeastLoaded::new();
        // Skewed job sizes: device 0 has a deep backlog, device 1 is almost
        // idle, device 2 in between.
        assert_eq!(ll.pick(&status(&[5, 1, 3]), &any_job()), 1);
        assert_eq!(ll.pick(&status(&[0, 1, 3]), &any_job()), 0);
        // Ties break by KV usage, then index.
        let mut s = status(&[2, 2]);
        s[0].kv_used = 100;
        assert_eq!(ll.pick(&s, &any_job()), 1);
        assert_eq!(ll.pick(&status(&[2, 2]), &any_job()), 0);
    }

    #[test]
    fn slo_aware_packs_feasible_and_sheds_infeasible() {
        let mut slo = SloAware::new();
        // Deadline admits devices waiting <= 2.5 s (prefill 0.5, target 3).
        let loose = job(0.5, 3.0);
        // Feasible: waits 0, 1, 2 (devices 0, 1, 2); device 3 (wait 5) is
        // not. Bin-packing picks the *deepest* feasible backlog: device 2.
        assert_eq!(slo.pick(&status(&[0, 1, 2, 5]), &loose), 2);
        // A tight deadline shrinks the feasible set to the idle device.
        let tight = job(0.5, 0.6);
        assert_eq!(slo.pick(&status(&[0, 1, 2, 5]), &tight), 0);
        // No device feasible: fall back to least wait (device 1 here).
        let hopeless = job(0.5, 0.1);
        assert_eq!(slo.pick(&status(&[3, 1, 2, 5]), &hopeless), 1);
        // Without a deadline every device is feasible: pack onto the
        // busiest outright.
        assert_eq!(slo.pick(&status(&[0, 1, 2, 5]), &any_job()), 3);
        // Feasibility ties break by KV usage, then index.
        let mut s = status(&[2, 2]);
        s[0].kv_used = 100;
        assert_eq!(slo.pick(&s, &loose), 1);
        assert_eq!(slo.pick(&status(&[2, 2]), &loose), 0);
        // A status source with no time estimate (the functional pool
        // reports est_wait zero) still packs by real queue depth instead
        // of collapsing onto device 0.
        let mut flat = status(&[1, 3, 2]);
        for d in &mut flat {
            d.est_wait = SimTime::ZERO;
        }
        assert_eq!(slo.pick(&flat, &any_job()), 1);
    }

    /// Mixed-fleet status: first `flash` devices flash, rest GPU.
    fn mixed_status(depths: &[usize], flash: usize) -> Vec<DeviceStatus> {
        let mut s = status(depths);
        for d in &mut s[flash..] {
            d.tier = Tier::Gpu;
        }
        s
    }

    #[test]
    fn tier_aware_splits_by_prompt_length_and_deadline() {
        let mut ta = TierAware::new();
        // Short prompt, loose deadline: prefers flash (devices 0–1).
        let chat =
            JobInfo { est_prefill: 0.1, est_prefill_gpu: 0.2, prompt_tokens: 128, ttft_target: 3.0 };
        let s = mixed_status(&[1, 0, 0], 2);
        assert!(ta.pick(&s, &chat) < 2, "chat goes to a flash device");
        // Long prompt: prefers the GPU tier even though it is busier.
        let long =
            JobInfo { est_prefill: 2.0, est_prefill_gpu: 0.3, prompt_tokens: 1024, ttft_target: 3.0 };
        let s = mixed_status(&[0, 0, 4], 2);
        assert_eq!(ta.pick(&s, &long), 2, "long prefill goes to the GPU device");
        // Short prompt whose flash prefill blows the deadline also prefers GPU.
        let tight =
            JobInfo { est_prefill: 2.0, est_prefill_gpu: 0.3, prompt_tokens: 64, ttft_target: 1.0 };
        assert_eq!(TierAware::preferred_tier(&tight), Tier::Gpu);
        // Preferred tier absent (flash-only pool): degrades to SloAware
        // over the whole pool instead of panicking.
        let flash_only = status(&[0, 1]);
        assert_eq!(ta.pick(&flash_only, &long), SloAware::new().pick(&flash_only, &long));
        // Within the preferred tier, SloAware bin-packing applies: the
        // deepest feasible flash backlog wins.
        let s = mixed_status(&[0, 2, 9], 2);
        assert_eq!(ta.pick(&s, &chat), 1);
    }

    #[test]
    fn device_router_with_fleet_sizes_kv_per_tier() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = crate::llm::LatencyTable::build(
            &sys,
            &crate::circuit::TechParams::default(),
            model.clone(),
        );
        let spec = super::super::device::FleetSpec::parse("1xflash+1xgpu").unwrap();
        let models = DeviceModel::fleet(&spec, &sys, &model, &table);
        let dr = DeviceRouter::with_fleet(&models, Box::new(TierAware::new()));
        assert_eq!(dr.n_devices(), 2);
        assert_eq!(dr.policy_name(), "tier-aware");
        // Flash slot matches the SLC geometry capacity; GPU slot the VRAM budget.
        assert_eq!(dr.kv(0).capacity, KvCacheManager::new(&sys, &model).capacity);
        assert_eq!(dr.kv(1).capacity, models[1].kv_capacity());
        assert_eq!(dr.kv(1).per_token, models[1].kv_per_token());
    }

    #[test]
    fn policy_names_resolve() {
        assert_eq!(policy_from_name("round-robin").unwrap().name(), "round-robin");
        assert_eq!(policy_from_name("rr").unwrap().name(), "round-robin");
        assert_eq!(policy_from_name("least-loaded").unwrap().name(), "least-loaded");
        assert_eq!(policy_from_name("slo-aware").unwrap().name(), "slo-aware");
        assert_eq!(policy_from_name("slo").unwrap().name(), "slo-aware");
        assert_eq!(policy_from_name("tier-aware").unwrap().name(), "tier-aware");
        assert_eq!(policy_from_name("tier").unwrap().name(), "tier-aware");
        assert_eq!(policy_from_name("wear-aware").unwrap().name(), "wear-aware");
        assert_eq!(policy_from_name("wear").unwrap().name(), "wear-aware");
        assert!(policy_from_name("bogus").is_none());
        // Wear-aware is opt-in only: never part of the sweep matrices.
        assert!(!TIERED_POLICY_NAMES.contains(&"wear-aware"));
        // The tiered list is the base list plus tier-aware.
        assert_eq!(&TIERED_POLICY_NAMES[..POLICY_NAMES.len()], POLICY_NAMES);
        assert_eq!(TIERED_POLICY_NAMES.last(), Some(&"tier-aware"));
    }

    #[test]
    fn wear_aware_spreads_budget_within_feasible_set() {
        let mut wa = WearAware::new();
        // All feasible (no deadline): the least-worn device wins even when
        // it is not the least loaded.
        let mut s = status(&[0, 2, 1]);
        s[0].wear_used = 50;
        s[1].wear_used = 10;
        s[2].wear_used = 30;
        for d in &mut s {
            d.wear_budget = 100;
        }
        assert_eq!(wa.pick(&s, &any_job()), 1);
        // A deadline excludes the least-worn device (wait 2 s > 1 s slack):
        // next-least-worn feasible device wins.
        let tight = job(0.5, 1.5);
        assert_eq!(wa.pick(&s, &tight), 2);
        // No device feasible: sheds like SloAware (minimum est_wait).
        let hopeless = job(0.5, 0.1);
        assert_eq!(wa.pick(&s, &hopeless), 0);
        // Wear ties break by queue depth, then index.
        let flat = status(&[3, 1, 1]);
        assert_eq!(wa.pick(&flat, &any_job()), 1);
    }

    #[test]
    fn device_router_affinity_overrides_policy() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let mut dr = DeviceRouter::new(3, &sys, &model, Box::new(LeastLoaded::new()));
        // Fresh session goes to the least-loaded device (index 0 on ties).
        let d = dr.assign(7, &status(&[0, 0, 0]), &any_job());
        assert_eq!(d, 0);
        dr.kv_mut(d).admit(7, 128).unwrap();
        // Device 0 is now the busiest — a follow-up turn still lands there.
        assert_eq!(dr.assign(7, &status(&[9, 0, 0]), &any_job()), 0);
        assert_eq!(dr.device_for(7), Some(0));
        // A fresh session avoids it.
        assert_ne!(dr.assign(8, &status(&[9, 0, 0]), &any_job()), 0);
        // Eviction drops residency; the session re-places like a fresh one.
        dr.evict(7).unwrap();
        assert_eq!(dr.device_for(7), None);
        assert_eq!(dr.kv(0).used(), 0);
        assert_ne!(dr.assign(7, &status(&[9, 0, 0]), &any_job()), 0);
    }

    #[test]
    fn sessions_on_tracks_placements() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let mut dr = DeviceRouter::new(2, &sys, &model, Box::new(RoundRobin::new()));
        let s = status(&[0, 0]);
        assert_eq!(dr.assign(1, &s, &any_job()), 0);
        assert_eq!(dr.assign(2, &s, &any_job()), 1);
        assert_eq!(dr.assign(3, &s, &any_job()), 0);
        let mut on0 = dr.sessions_on(0);
        on0.sort_unstable();
        assert_eq!(on0, vec![1, 3]);
        dr.forget(3);
        assert_eq!(dr.sessions_on(0), vec![1]);
    }
}
