//! Serving metrics: latency/TPOT summaries and device utilization — for
//! the single-device trace ([`ServingReport`]) and the device-pool
//! closed-loop simulator ([`PoolReport`], including per-class
//! percentiles and SLO attainment via [`ClassReport`] when the run
//! carried a [`WorkloadMix`]).

use super::device::{FleetSummary, Tier};
use super::loadgen::SimRequest;
use super::request::RequestOutcome;
use super::workload::{SloTarget, WorkloadMix};
use crate::fault::FaultSummary;
use crate::sim::SimTime;
use crate::util::stats::{Streaming, Summary};
use crate::util::table::Table;
use crate::util::units::fmt_time;

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub outcomes: Vec<RequestOutcome>,
    /// End of the simulated horizon.
    pub makespan: SimTime,
    /// Busy fraction of the flash device over the horizon.
    pub flash_utilization: f64,
    /// Busy fraction of the GPU pool over the horizon.
    pub gpu_utilization: f64,
}

impl ServingReport {
    /// Latency summary over completed requests (seconds).
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.outcomes.iter().map(|o| o.latency().secs()).collect::<Vec<_>>())
    }

    /// TPOT summary over generation requests (seconds/token).
    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.outcomes.iter().filter_map(|o| o.tpot()).collect::<Vec<_>>())
    }

    /// Output tokens per second across the run.
    pub fn throughput(&self) -> f64 {
        let tokens: usize = self.outcomes.iter().map(|o| o.tokens_out).sum();
        if self.makespan == SimTime::ZERO {
            return 0.0;
        }
        tokens as f64 / self.makespan.secs()
    }

    /// Requests finished on each device.
    pub fn counts(&self) -> (usize, usize) {
        let flash = self.outcomes.iter().filter(|o| o.executed_on == "flash").count();
        let gpu = self.outcomes.iter().filter(|o| o.executed_on == "gpu").count();
        (flash, gpu)
    }

    pub fn render(&self) -> String {
        let lat = self.latency_summary();
        let tpot = self.tpot_summary();
        let (flash, gpu) = self.counts();
        format!(
            "requests: {} flash / {} gpu   makespan {}\n\
             latency  mean {} p50 {} p99 {}\n\
             TPOT     mean {} p50 {} p99 {}\n\
             throughput {:.1} tok/s   util flash {:.0}% gpu {:.0}%\n",
            flash,
            gpu,
            self.makespan,
            crate::util::units::fmt_time(lat.mean),
            crate::util::units::fmt_time(lat.p50),
            crate::util::units::fmt_time(lat.p99),
            crate::util::units::fmt_time(tpot.mean),
            crate::util::units::fmt_time(tpot.p50),
            crate::util::units::fmt_time(tpot.p99),
            self.throughput(),
            self.flash_utilization * 100.0,
            self.gpu_utilization * 100.0,
        )
    }
}

/// Aggregate report of one closed-loop device-pool run
/// (see [`crate::coordinator::event_sim::run_traffic_events`] and the
/// legacy [`crate::coordinator::loadgen::run_traffic`]). `PartialEq` so
/// determinism tests can compare whole runs outcome-for-outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolReport {
    /// Simulation backend that produced the report: `"event"` for the
    /// event-driven default, `"direct"` for the legacy replay loop.
    pub backend: &'static str,
    /// Scheduler policy name ("round-robin" / "least-loaded" /
    /// "slo-aware").
    pub policy: String,
    /// Devices in the pool.
    pub devices: usize,
    /// Offered Poisson arrival rate (requests/second).
    pub offered_rate: f64,
    /// The run's multi-class scenario, when it had one — maps each
    /// outcome's class index to a name and SLO targets, and switches on
    /// the per-class section of [`Self::render`].
    pub workload: Option<WorkloadMix>,
    pub outcomes: Vec<SimRequest>,
    /// End of the simulated horizon (last accepted completion).
    pub makespan: SimTime,
    /// Busy fraction of each device over the horizon.
    pub device_utilization: Vec<f64>,
    /// Jobs served per device.
    pub device_jobs: Vec<usize>,
    /// Fleet composition and pricing, when the run was launched with a
    /// heterogeneous [`FleetSpec`][super::device::FleetSpec]. `None` for
    /// legacy flash-only runs, which keeps their rendered reports
    /// byte-identical to pre-fleet builds.
    pub fleet: Option<FleetSummary>,
    /// Write-wear accounting, when the run was launched with a
    /// [`WearConfig`][super::loadgen::WearConfig]. `None` for
    /// wear-disabled runs, which keeps their rendered reports
    /// byte-identical to pre-wear builds.
    pub wear: Option<WearSummary>,
    /// Reliability accounting, when the run was launched with a
    /// [`FaultConfig`][crate::fault::FaultConfig]. `None` for
    /// fault-disabled runs, which keeps their rendered reports
    /// byte-identical to pre-fault builds.
    pub faults: Option<FaultSummary>,
}

/// One pool slot's wear meters (see
/// [`crate::kv::wear::DeviceWear`]), snapshotted into the report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceWearStats {
    /// KV token programs charged (one per token written).
    pub programs: u64,
    /// Total KV bytes written.
    pub bytes_written: u64,
    /// Erase operations charged through the wear leveler.
    pub erases: u64,
    /// Idle-session KV evictions on this slot.
    pub evictions: u64,
    /// Bytes per erase block on this slot.
    pub block_bytes: u64,
    /// When the slot's P/E budget exhausted (seconds), if it did.
    pub retired_at_s: Option<f64>,
    /// Was the slot provisioned as a spare (index past the primary
    /// roster)?
    pub spare: bool,
}

/// Fleet-wide wear rollup attached to a [`PoolReport`] of a
/// wear-enabled run: per-slot meters (primaries then spares), the
/// budget they were charged against, and the retirement count. Both
/// serving backends charge identical meters from identical admission
/// bookkeeping, so two backends' summaries for the same trace agree
/// exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct WearSummary {
    /// P/E-cycle budget per erase block.
    pub pe_budget: u64,
    /// Erase blocks per device.
    pub blocks_per_device: usize,
    /// Spare slots provisioned.
    pub spares: usize,
    /// Devices that exhausted their budget mid-trace.
    pub retirements: usize,
    /// Per-slot meters, device-index order (primaries then spares).
    pub devices: Vec<DeviceWearStats>,
}

impl WearSummary {
    /// Total erases across the fleet.
    pub fn total_erases(&self) -> u64 {
        self.devices.iter().map(|d| d.erases).sum()
    }

    /// Worst per-device erase count — the fleet-lifetime metric a
    /// wear-spreading scheduler minimizes.
    pub fn max_erases(&self) -> u64 {
        self.devices.iter().map(|d| d.erases).max().unwrap_or(0)
    }

    /// Total KV token programs across the fleet.
    pub fn total_programs(&self) -> u64 {
        self.devices.iter().map(|d| d.programs).sum()
    }

    /// Total KV bytes written across the fleet.
    pub fn total_bytes_written(&self) -> u64 {
        self.devices.iter().map(|d| d.bytes_written).sum()
    }

    /// Projected fleet lifetime (years) at the trace's observed write
    /// rate: total erase endurance (every slot's blocks × block bytes ×
    /// P/E budget) over bytes written per second. Infinite for an idle
    /// trace or a zero-length makespan.
    pub fn projected_years(&self, makespan_s: f64) -> f64 {
        if makespan_s <= 0.0 {
            return f64::INFINITY;
        }
        let capacity: u64 = self
            .devices
            .iter()
            .map(|d| d.block_bytes * self.blocks_per_device as u64)
            .sum();
        let rate = self.total_bytes_written() as f64 / makespan_s;
        crate::kv::lifetime::lifetime_years_at_rate(capacity, self.pe_budget, rate)
    }
}

/// Per-class slice of a [`PoolReport`]: the class's traffic counts,
/// latency summaries, and SLO attainment. Borrows the class name from
/// the report's [`WorkloadMix`] — building the per-class section
/// allocates no name `String`s (callers that need owned names, like the
/// sweep's [`ClassAttainment`][super::sweep::ClassAttainment], clone
/// exactly once at the edge).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassReport<'a> {
    pub name: &'a str,
    /// Normalized arrival share the mix assigns the class.
    pub share: f64,
    /// Arrivals of this class (accepted + rejected).
    pub arrivals: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// TTFT summary over the class's accepted requests (seconds).
    pub ttft: Summary,
    /// TPOT summary over the class's accepted multi-token requests.
    pub tpot: Summary,
    /// End-to-end latency summary over the class's accepted requests.
    pub latency: Summary,
    pub slo: SloTarget,
    /// Fraction of the class's **arrivals** meeting both SLO targets —
    /// a rejected request counts as a miss (the client got nothing), and
    /// a class with no arrivals vacuously attains 1.0.
    pub slo_attainment: f64,
}

impl PoolReport {
    pub fn accepted(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.rejected).count()
    }

    /// Arrivals shed by backpressure (bounded queues / KV region full).
    pub fn rejected(&self) -> usize {
        self.outcomes.iter().filter(|o| o.rejected).count()
    }

    /// Requests permanently failed by fault injection (a subset of
    /// [`Self::rejected`]: they exhausted their retry budget after a
    /// device loss). Zero for fault-free runs.
    pub fn failed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.failed).count()
    }

    /// End-to-end latency summary over accepted requests (seconds).
    pub fn latency_summary(&self) -> Summary {
        Summary::of(
            &self
                .outcomes
                .iter()
                .filter(|o| !o.rejected)
                .map(|o| o.latency().secs())
                .collect::<Vec<_>>(),
        )
    }

    /// Time-to-first-token summary (seconds).
    pub fn ttft_summary(&self) -> Summary {
        Summary::of(
            &self.outcomes.iter().filter_map(|o| o.ttft().map(|t| t.secs())).collect::<Vec<_>>(),
        )
    }

    /// Time-per-output-token summary (seconds/token).
    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.outcomes.iter().filter_map(|o| o.tpot()).collect::<Vec<_>>())
    }

    /// Output tokens per second across the run.
    pub fn throughput(&self) -> f64 {
        let tokens: usize = self.outcomes.iter().map(|o| o.output_tokens).sum();
        if self.makespan == SimTime::ZERO {
            return 0.0;
        }
        tokens as f64 / self.makespan.secs()
    }

    /// One [`ClassReport`] per mix class, in mix order; empty for
    /// single-class runs without a workload.
    ///
    /// Single pass over the outcomes: every class's counts and metric
    /// samples accumulate in one sweep (the old shape re-filtered the
    /// whole outcome vector six times *per class*), then each metric
    /// flushes through one sort ([`Streaming::finish`]) — bit-identical
    /// to the old collect-and-`Summary::of` values by construction.
    pub fn class_reports(&self) -> Vec<ClassReport<'_>> {
        let Some(mix) = &self.workload else {
            return Vec::new();
        };
        #[derive(Default)]
        struct Acc {
            arrivals: usize,
            rejected: usize,
            met: usize,
            ttft: Streaming,
            tpot: Streaming,
            latency: Streaming,
        }
        let classes = mix.classes();
        let mut accs: Vec<Acc> = (0..classes.len()).map(|_| Acc::default()).collect();
        for o in &self.outcomes {
            // Out-of-range class indices (a hand-built report) are ignored,
            // as the old per-class filter ignored them.
            let Some(a) = accs.get_mut(o.class) else {
                continue;
            };
            a.arrivals += 1;
            if o.rejected {
                a.rejected += 1;
            } else {
                a.latency.push(o.latency().secs());
            }
            if o.meets_slo(classes[o.class].slo) {
                a.met += 1;
            }
            if let Some(t) = o.ttft() {
                a.ttft.push(t.secs());
            }
            if let Some(t) = o.tpot() {
                a.tpot.push(t);
            }
        }
        classes
            .iter()
            .zip(accs)
            .enumerate()
            .map(|(i, (c, a))| ClassReport {
                name: &c.name,
                share: mix.share(i),
                arrivals: a.arrivals,
                accepted: a.arrivals - a.rejected,
                rejected: a.rejected,
                ttft: a.ttft.finish(),
                tpot: a.tpot.finish(),
                latency: a.latency.finish(),
                slo: c.slo,
                slo_attainment: if a.arrivals == 0 {
                    1.0
                } else {
                    a.met as f64 / a.arrivals as f64
                },
            })
            .collect()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "pool: {} device(s), {} scheduling, {:.1} req/s offered ({} backend)\n\
             requests: {} accepted / {} rejected   makespan {}   throughput {:.1} tok/s\n\n",
            self.devices,
            self.policy,
            self.offered_rate,
            self.backend,
            self.accepted(),
            self.rejected(),
            self.makespan,
            self.throughput(),
        );
        let mut t = Table::new(&["metric", "mean", "p50", "p95", "p99"]);
        for (name, s) in [
            ("TTFT", self.ttft_summary()),
            ("TPOT", self.tpot_summary()),
            ("latency", self.latency_summary()),
        ] {
            t.row(&[
                name.to_string(),
                fmt_time(s.mean),
                fmt_time(s.p50),
                fmt_time(s.p95),
                fmt_time(s.p99),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
        let mut d = Table::new(&["device", "jobs", "utilization"]);
        for (i, (u, j)) in self.device_utilization.iter().zip(&self.device_jobs).enumerate() {
            d.row(&[format!("dev{i}"), j.to_string(), format!("{:.1}%", u * 100.0)]);
        }
        out.push_str(&d.render());
        if let Some(f) = &self.fleet {
            out.push_str(&format!("\nfleet: {}   ${:.2}/h\n", f.name, f.cost_per_hour));
            let mut t = Table::new(&["tier", "devices", "jobs", "utilization"]);
            for tier in [Tier::Flash, Tier::Gpu] {
                let idx: Vec<usize> =
                    (0..f.tiers.len()).filter(|&i| f.tiers[i] == tier).collect();
                if idx.is_empty() {
                    continue;
                }
                let jobs: usize =
                    idx.iter().map(|&i| self.device_jobs.get(i).copied().unwrap_or(0)).sum();
                let util = idx
                    .iter()
                    .map(|&i| self.device_utilization.get(i).copied().unwrap_or(0.0))
                    .sum::<f64>()
                    / idx.len() as f64;
                t.row(&[
                    tier.as_str().to_string(),
                    idx.len().to_string(),
                    jobs.to_string(),
                    format!("{:.1}%", util * 100.0),
                ]);
            }
            out.push_str(&t.render());
            let tokens: u64 = self.outcomes.iter().map(|o| o.output_tokens as u64).sum();
            if let (Some(cost), Some(energy)) =
                (f.cost_per_mtok(tokens, self.makespan.secs()), f.energy_per_mtok(tokens))
            {
                out.push_str(&format!("cost ${cost:.2}/Mtok   energy {energy:.1} J/Mtok\n"));
            }
        }
        if let Some(w) = &self.wear {
            let years = w.projected_years(self.makespan.secs());
            out.push_str(&format!(
                "\nwear: {} P/E x {} blocks/device   {} retirement(s), {} spare(s)   \
                 projected lifetime {}\n",
                w.pe_budget,
                w.blocks_per_device,
                w.retirements,
                w.spares,
                if years.is_finite() { format!("{years:.2} yr") } else { "-".to_string() },
            ));
            let cols = ["device", "programs", "MiB written", "erases", "evictions", "retired"];
            let mut t = Table::new(&cols);
            for (i, d) in w.devices.iter().enumerate() {
                t.row(&[
                    if d.spare { format!("dev{i} (spare)") } else { format!("dev{i}") },
                    d.programs.to_string(),
                    format!("{:.1}", d.bytes_written as f64 / (1u64 << 20) as f64),
                    d.erases.to_string(),
                    d.evictions.to_string(),
                    match d.retired_at_s {
                        Some(t) => fmt_time(t),
                        None => "-".to_string(),
                    },
                ]);
            }
            out.push_str(&t.render());
        }
        if let Some(fa) = &self.faults {
            out.push_str(&format!(
                "\nfaults: availability {:.4}   {} device failure(s)   degraded {}\n",
                fa.availability,
                fa.device_failures,
                fmt_time(fa.degraded_s),
            ));
            let mut t = Table::new(&["reliability metric", "value"]);
            t.row(&["read-retry storms".to_string(), fa.storms.to_string()]);
            t.row(&["storm device-seconds".to_string(), format!("{:.2}", fa.storm_s)]);
            t.row(&["retries".to_string(), fa.retries.to_string()]);
            t.row(&["failovers".to_string(), fa.failovers.to_string()]);
            t.row(&["re-prefilled tokens".to_string(), fa.re_prefill_tokens.to_string()]);
            t.row(&["failed requests".to_string(), fa.failed_requests.to_string()]);
            t.row(&["brownout shed".to_string(), fa.shed_brownout.to_string()]);
            out.push_str(&t.render());
        }
        if let Some(mix) = &self.workload {
            out.push_str(&format!("\nworkload mix: {}\n", mix.name()));
            let mut c = Table::new(&[
                "class",
                "share",
                "arrive",
                "reject",
                "TTFT p95",
                "ttft slo",
                "TPOT p95",
                "tpot slo",
                "lat p95",
                "SLO met",
            ]);
            for r in self.class_reports() {
                c.row(&[
                    r.name.to_string(),
                    format!("{:.0}%", r.share * 100.0),
                    r.arrivals.to_string(),
                    r.rejected.to_string(),
                    fmt_time(r.ttft.p95),
                    fmt_slo(r.slo.ttft),
                    fmt_time(r.tpot.p95),
                    fmt_slo(r.slo.tpot),
                    fmt_time(r.latency.p95),
                    format!("{:.1}%", r.slo_attainment * 100.0),
                ]);
            }
            out.push_str(&c.render());
        }
        out
    }
}

/// Format an SLO target; infinite targets ("no objective") render as `-`.
fn fmt_slo(target: f64) -> String {
    if target.is_finite() { fmt_time(target) } else { "-".to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, on: &'static str, tokens: usize) -> RequestOutcome {
        RequestOutcome {
            id,
            arrival: SimTime::ZERO,
            first_token: Some(SimTime::from_us(10.0)),
            completed: SimTime::from_us(10.0 + tokens as f64),
            tokens_out: tokens,
            executed_on: on,
        }
    }

    #[test]
    fn counts_and_throughput() {
        let r = ServingReport {
            outcomes: vec![outcome(1, "flash", 100), outcome(2, "gpu", 0), outcome(3, "flash", 50)],
            makespan: SimTime::from_secs(1.0),
            flash_utilization: 0.5,
            gpu_utilization: 0.25,
        };
        assert_eq!(r.counts(), (2, 1));
        assert!((r.throughput() - 150.0).abs() < 1e-9);
        assert!(r.render().contains("tok/s"));
    }

    fn sim_request(id: u64, device: Option<usize>, tokens: usize) -> SimRequest {
        SimRequest {
            id,
            session: id,
            class: (id % 2) as usize,
            device,
            arrival: SimTime::ZERO,
            first_token: device.map(|_| SimTime::from_us(50.0)),
            completed: SimTime::from_us(50.0 + 10.0 * tokens as f64),
            input_tokens: 64,
            output_tokens: tokens,
            context: 64,
            rejected: device.is_none(),
            failed: false,
            followup: false,
            energy_j: 0.0,
        }
    }

    #[test]
    fn pool_report_counts_and_render() {
        let r = PoolReport {
            backend: "event",
            policy: "least-loaded".to_string(),
            devices: 2,
            offered_rate: 8.0,
            workload: None,
            outcomes: vec![
                sim_request(1, Some(0), 10),
                sim_request(2, Some(1), 20),
                sim_request(3, None, 0),
            ],
            makespan: SimTime::from_secs(1.0),
            device_utilization: vec![0.5, 0.25],
            device_jobs: vec![1, 1],
            fleet: None,
            wear: None,
            faults: None,
        };
        assert_eq!(r.accepted(), 2);
        assert_eq!(r.rejected(), 1);
        assert!((r.throughput() - 30.0).abs() < 1e-9);
        assert!(r.class_reports().is_empty(), "no workload, no per-class section");
        let s = r.render();
        assert!(s.contains("least-loaded"));
        assert!(s.contains("event backend"));
        assert!(s.contains("p95"));
        assert!(s.contains("dev1"));
        assert!(!s.contains("workload mix"));
        let lat = r.latency_summary();
        assert_eq!(lat.n, 2);
        assert!(lat.p95 <= lat.p99 + 1e-15);
    }

    #[test]
    fn class_reports_split_attainment_by_class() {
        use crate::coordinator::loadgen::LenRange;
        use crate::coordinator::workload::WorkloadClass;

        // `sim_request` classes by id parity: class 0 gets the even ids,
        // class 1 the odd ones.
        // Class 0 "even": an impossible 1 µs TTFT — nothing attains.
        // Class 1 "odd": loose targets — every *served* request attains.
        let mix = WorkloadMix::new(
            "t",
            vec![
                WorkloadClass::new(
                    "even",
                    0.5,
                    LenRange::fixed(64),
                    LenRange::new(2, 32),
                    0.0,
                    SloTarget { ttft: 1e-6, tpot: 1.0 },
                ),
                WorkloadClass::new(
                    "odd",
                    0.5,
                    LenRange::fixed(64),
                    LenRange::new(2, 32),
                    0.0,
                    SloTarget { ttft: 1.0, tpot: 1.0 },
                ),
            ],
        )
        .unwrap();
        let r = PoolReport {
            backend: "event",
            policy: "slo-aware".to_string(),
            devices: 2,
            offered_rate: 8.0,
            workload: Some(mix),
            outcomes: vec![
                sim_request(1, Some(0), 10), // odd, served -> attains
                sim_request(2, Some(1), 20), // even, served -> misses TTFT
                sim_request(3, None, 0),     // odd, rejected -> misses
                sim_request(4, Some(0), 5),  // even, served -> misses TTFT
            ],
            makespan: SimTime::from_secs(1.0),
            device_utilization: vec![0.5, 0.25],
            device_jobs: vec![2, 1],
            fleet: None,
            wear: None,
            faults: None,
        };
        let classes = r.class_reports();
        assert_eq!(classes.len(), 2);
        let (even, odd) = (&classes[0], &classes[1]);
        assert_eq!((even.name, even.arrivals, even.rejected), ("even", 2, 0));
        assert_eq!((odd.name, odd.arrivals, odd.rejected), ("odd", 2, 1));
        assert_eq!(even.slo_attainment, 0.0, "1 µs TTFT is unattainable");
        assert!((odd.slo_attainment - 0.5).abs() < 1e-12, "served odd attains, rejected misses");
        assert!(odd.ttft.n == 1 && odd.latency.n == 1, "summaries cover accepted only");
        let s = r.render();
        assert!(s.contains("workload mix: t"));
        assert!(s.contains("SLO met") && s.contains("odd") && s.contains("even"));
    }

    #[test]
    fn wear_summary_rollups_and_render_section() {
        let stats = |erases, spare| DeviceWearStats {
            programs: 100,
            bytes_written: 2 << 20,
            erases,
            evictions: 1,
            block_bytes: 1 << 20,
            retired_at_s: if spare { None } else { Some(0.5) },
            spare,
        };
        let w = WearSummary {
            pe_budget: 10,
            blocks_per_device: 4,
            spares: 1,
            retirements: 1,
            devices: vec![stats(7, false), stats(3, true)],
        };
        assert_eq!(w.total_erases(), 10);
        assert_eq!(w.max_erases(), 7);
        assert_eq!(w.total_programs(), 200);
        assert_eq!(w.total_bytes_written(), 4 << 20);
        // Capacity 2 devices × 4 MiB × 10 P/E = 80 MiB endurance; the
        // trace wrote 4 MiB over 2 s → 20× the trace horizon remains.
        let years = w.projected_years(2.0);
        assert!((years - 40.0 / (365.25 * 24.0 * 3600.0)).abs() < 1e-12, "{years}");
        assert_eq!(w.projected_years(0.0), f64::INFINITY);

        let mut r = PoolReport {
            backend: "event",
            policy: "wear-aware".to_string(),
            devices: 1,
            offered_rate: 8.0,
            workload: None,
            outcomes: vec![sim_request(1, Some(0), 10)],
            makespan: SimTime::from_secs(2.0),
            device_utilization: vec![0.5, 0.0],
            device_jobs: vec![1, 0],
            fleet: None,
            wear: None,
            faults: None,
        };
        let plain = r.render();
        assert!(!plain.contains("wear:"), "wear-disabled reports carry no wear section");
        r.wear = Some(w);
        let s = r.render();
        assert!(s.contains("wear: 10 P/E x 4 blocks/device"), "{s}");
        assert!(s.contains("1 retirement(s), 1 spare(s)"), "{s}");
        assert!(s.contains("(spare)"), "{s}");
        assert!(s.contains("projected lifetime"), "{s}");
    }
}
