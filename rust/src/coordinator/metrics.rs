//! Serving metrics: latency/TPOT summaries and device utilization.

use super::request::RequestOutcome;
use crate::sim::SimTime;
use crate::util::stats::Summary;

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub outcomes: Vec<RequestOutcome>,
    /// End of the simulated horizon.
    pub makespan: SimTime,
    /// Busy fraction of the flash device over the horizon.
    pub flash_utilization: f64,
    /// Busy fraction of the GPU pool over the horizon.
    pub gpu_utilization: f64,
}

impl ServingReport {
    /// Latency summary over completed requests (seconds).
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.outcomes.iter().map(|o| o.latency().secs()).collect::<Vec<_>>())
    }

    /// TPOT summary over generation requests (seconds/token).
    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.outcomes.iter().filter_map(|o| o.tpot()).collect::<Vec<_>>())
    }

    /// Output tokens per second across the run.
    pub fn throughput(&self) -> f64 {
        let tokens: usize = self.outcomes.iter().map(|o| o.tokens_out).sum();
        if self.makespan == SimTime::ZERO {
            return 0.0;
        }
        tokens as f64 / self.makespan.secs()
    }

    /// Requests finished on each device.
    pub fn counts(&self) -> (usize, usize) {
        let flash = self.outcomes.iter().filter(|o| o.executed_on == "flash").count();
        let gpu = self.outcomes.iter().filter(|o| o.executed_on == "gpu").count();
        (flash, gpu)
    }

    pub fn render(&self) -> String {
        let lat = self.latency_summary();
        let tpot = self.tpot_summary();
        let (flash, gpu) = self.counts();
        format!(
            "requests: {} flash / {} gpu   makespan {}\n\
             latency  mean {} p50 {} p99 {}\n\
             TPOT     mean {} p50 {} p99 {}\n\
             throughput {:.1} tok/s   util flash {:.0}% gpu {:.0}%\n",
            flash,
            gpu,
            self.makespan,
            crate::util::units::fmt_time(lat.mean),
            crate::util::units::fmt_time(lat.p50),
            crate::util::units::fmt_time(lat.p99),
            crate::util::units::fmt_time(tpot.mean),
            crate::util::units::fmt_time(tpot.p50),
            crate::util::units::fmt_time(tpot.p99),
            self.throughput(),
            self.flash_utilization * 100.0,
            self.gpu_utilization * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, on: &'static str, tokens: usize) -> RequestOutcome {
        RequestOutcome {
            id,
            arrival: SimTime::ZERO,
            first_token: Some(SimTime::from_us(10.0)),
            completed: SimTime::from_us(10.0 + tokens as f64),
            tokens_out: tokens,
            executed_on: on,
        }
    }

    #[test]
    fn counts_and_throughput() {
        let r = ServingReport {
            outcomes: vec![outcome(1, "flash", 100), outcome(2, "gpu", 0), outcome(3, "flash", 50)],
            makespan: SimTime::from_secs(1.0),
            flash_utilization: 0.5,
            gpu_utilization: 0.25,
        };
        assert_eq!(r.counts(), (2, 1));
        assert!((r.throughput() - 150.0).abs() < 1e-9);
        assert!(r.render().contains("tok/s"));
    }
}
