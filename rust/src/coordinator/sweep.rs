//! Arrival-rate sweep: the throughput–latency curve of the device pool
//! (the shape of the paper's vLLM comparison — §V-B serves single-batch
//! generation at 2.4× four RTX4090s, and a serving system is judged by
//! where its latency knee sits as offered load grows).
//!
//! One immutable [`LatencyTable`] is built by the caller and shared by
//! every sweep point. The default [`sweep_rates`] fans the deterministic
//! event-driven model out on scoped threads: every point owns its RNG,
//! model, and a streaming [`StreamingSink`][super::sink::StreamingSink]
//! (no per-point outcome vectors), workers pull (policy, rate) pairs from
//! a shared index, and results land by index — so the sweep uses every
//! core yet its output is **byte-equal to the sequential loop** (each
//! point is an independent deterministic computation; asserted in
//! `tests/perf_equivalence.rs`). [`sweep_rates_threaded`] keeps the
//! legacy cross-check: the direct-replay backend over the same worker
//! scaffold.
//!
//! When the base config carries a [`WorkloadMix`][wl], every point also
//! records per-class SLO attainment, and [`max_sustained_rates`] /
//! [`render_slo_frontier`] reduce the sweep to the serving question the
//! mixes exist for: *the highest offered rate at which each class still
//! attains its SLOs ≥ X% of the time, per scheduling policy*.
//!
//! [wl]: super::workload::WorkloadMix

use super::event_sim::run_traffic_point;
use super::loadgen::{run_traffic_with_table, TrafficConfig};
use super::metrics::PoolReport;
use super::router::policy_from_name;
use crate::config::SystemConfig;
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use crate::util::table::Table;
use crate::util::units::fmt_time;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count for a sweep of `points` points: all available cores,
/// clamped to the number of points so tiny grids never spawn idle scoped
/// threads, and at least 1. Shared by [`sweep_rates`] and
/// [`sweep_rates_threaded`].
fn clamped_width(points: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    cores.min(points.max(1))
}

/// Fan `items` out over a clamped-width pool of scoped workers, running
/// `f` per item and collecting results by index — the worker scaffold the
/// sweep backends and the campaign runner share. Each item must be an
/// independent deterministic computation (own RNG seeded from its
/// config), so the output is identical to the sequential loop regardless
/// of thread scheduling.
pub(crate) fn fan_out_indexed<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..clamped_width(items.len()))
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(item) = items.get(i) else {
                            break;
                        };
                        local.push((i, f(item)));
                    }
                    local
                })
            })
            .collect();
        for w in workers {
            for (i, r) in w.join().expect("fan-out worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|r| r.expect("every fan-out item ran")).collect()
}

/// [`fan_out_indexed`] specialized to the sweep's (policy, rate) pairs.
fn sweep_indexed<F>(pairs: &[(&str, f64)], point: F) -> Vec<SweepPoint>
where
    F: Fn(&str, f64) -> SweepPoint + Sync,
{
    fan_out_indexed(pairs, |&(p, r)| point(p, r))
}

/// SLO attainment of one workload class at one sweep point.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassAttainment {
    pub class: String,
    /// Fraction of the class's arrivals meeting both SLO targets
    /// (rejections count as misses).
    pub attainment: f64,
}

/// One (policy, rate) point of a sweep, reduced to the curve metrics so a
/// long sweep does not hold every per-request outcome in memory.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub policy: String,
    /// Offered Poisson arrival rate (requests/second).
    pub rate: f64,
    pub accepted: usize,
    pub rejected: usize,
    /// Output tokens per second over the run.
    pub throughput: f64,
    pub ttft_p95: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    /// USD per million generated tokens at this point's makespan; `None`
    /// for legacy runs without a [`FleetSpec`][super::device::FleetSpec].
    pub cost_per_mtok: Option<f64>,
    /// Joules of decode energy per million generated tokens; `None`
    /// without a fleet spec.
    pub energy_per_mtok: Option<f64>,
    /// Maximum per-device erase count across the fleet — the wear-spread
    /// quality metric [`WearAware`][super::router::WearAware] minimizes;
    /// `None` when wear accounting is disabled.
    pub wear_max_erases: Option<u64>,
    /// Total erases charged across the fleet; `None` without wear.
    pub wear_total_erases: Option<u64>,
    /// Devices retired mid-trace; `None` without wear.
    pub wear_retirements: Option<u64>,
    /// Fraction of nominal device-seconds actually serving; `None` when
    /// fault injection is disabled (as are all `faults_*` columns).
    pub faults_availability: Option<f64>,
    /// Requests permanently failed after exhausting their retry budget.
    pub faults_failed: Option<u64>,
    /// Retry attempts scheduled after device losses.
    pub faults_retries: Option<u64>,
    /// Requests re-admitted on a survivor after losing their KV.
    pub faults_failovers: Option<u64>,
    /// Fresh arrivals shed by the brownout policy.
    pub faults_shed: Option<u64>,
    /// Tokens re-prefilled by failovers (lost KV, full context).
    pub faults_reprefill_tok: Option<u64>,
    /// Seconds the fleet ran with at least one serving device lost.
    pub faults_degraded_s: Option<f64>,
    /// Per-class SLO attainment, in mix order; empty without a workload.
    pub class_attainment: Vec<ClassAttainment>,
}

impl SweepPoint {
    /// Reduce a materialized report to its sweep point. The streaming
    /// path ([`run_traffic_point`]) produces bit-identical points without
    /// ever materializing the report — `tests/perf_equivalence.rs` holds
    /// the two together.
    pub fn of(report: &PoolReport) -> SweepPoint {
        let lat = report.latency_summary();
        let tokens: u64 = report.outcomes.iter().map(|o| o.output_tokens as u64).sum();
        let fleet = report.fleet.as_ref();
        let wear = report.wear.as_ref();
        let faults = report.faults.as_ref();
        SweepPoint {
            policy: report.policy.clone(),
            rate: report.offered_rate,
            accepted: report.accepted(),
            rejected: report.rejected(),
            throughput: report.throughput(),
            ttft_p95: report.ttft_summary().p95,
            latency_p50: lat.p50,
            latency_p95: lat.p95,
            latency_p99: lat.p99,
            cost_per_mtok: fleet.and_then(|f| f.cost_per_mtok(tokens, report.makespan.secs())),
            energy_per_mtok: fleet.and_then(|f| f.energy_per_mtok(tokens)),
            wear_max_erases: wear.map(|w| w.max_erases()),
            wear_total_erases: wear.map(|w| w.total_erases()),
            wear_retirements: wear.map(|w| w.retirements as u64),
            faults_availability: faults.map(|f| f.availability),
            faults_failed: faults.map(|f| f.failed_requests),
            faults_retries: faults.map(|f| f.retries),
            faults_failovers: faults.map(|f| f.failovers),
            faults_shed: faults.map(|f| f.shed_brownout),
            faults_reprefill_tok: faults.map(|f| f.re_prefill_tokens),
            faults_degraded_s: faults.map(|f| f.degraded_s),
            class_attainment: report
                .class_reports()
                .into_iter()
                .map(|c| ClassAttainment {
                    class: c.name.to_string(),
                    attainment: c.slo_attainment,
                })
                .collect(),
        }
    }

    /// Worst per-class attainment at this point (`None` without classes).
    pub fn min_attainment(&self) -> Option<f64> {
        self.class_attainment.iter().map(|c| c.attainment).min_by(f64::total_cmp)
    }
}

/// Validate a sweep rate list: non-empty, positive, finite, and within
/// the point cap. Shared by [`sweep_rates`] and the CLI's flag parsing so
/// the CLI can fail fast, before paying for a latency-table build.
pub fn validate_rates(rates: &[f64]) -> Result<()> {
    if rates.is_empty() {
        bail!("rate sweep needs at least one rate");
    }
    if rates.len() > 64 {
        bail!("rate sweep capped at 64 rates, got {}", rates.len());
    }
    if rates.iter().any(|r| !r.is_finite() || *r <= 0.0) {
        bail!("sweep rates must be positive and finite: {rates:?}");
    }
    Ok(())
}

/// Validate inputs and expand them into the ordered (policy, rate) pairs
/// a sweep runs: rates sorted ascending and deduplicated within each
/// policy's block, policies in caller order.
fn sweep_pairs<'a>(rates: &[f64], policies: &[&'a str]) -> Result<Vec<(&'a str, f64)>> {
    validate_rates(rates)?;
    if policies.is_empty() {
        bail!("rate sweep needs at least one policy");
    }
    for p in policies {
        if policy_from_name(p).is_none() {
            bail!(
                "unknown policy {p:?}; use round-robin|least-loaded|slo-aware|tier-aware|wear-aware"
            );
        }
    }
    let mut rates = rates.to_vec();
    rates.sort_by(f64::total_cmp);
    rates.dedup();
    Ok(policies.iter().flat_map(|&p| rates.iter().map(move |&r| (p, r))).collect())
}

/// Run `base` at every arrival rate in `rates` for every policy in
/// `policies` on the event-driven backend, sharing one prebuilt latency
/// table. Points fan out over scoped threads (width clamped to the point
/// count); each point seeds its own RNG and folds outcomes through the
/// streaming sink ([`run_traffic_point`]) — no per-point outcome vectors
/// — and results are collected by index, so the output is byte-equal to
/// running the same points in a sequential loop. Rates are sorted
/// ascending and deduplicated, so each policy's block of the result is a
/// monotone-rate throughput–latency curve.
pub fn sweep_rates(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    base: &TrafficConfig,
    rates: &[f64],
    policies: &[&str],
) -> Result<Vec<SweepPoint>> {
    let pairs = sweep_pairs(rates, policies)?;
    Ok(sweep_indexed(&pairs, |p, r| {
        let mut cfg = base.clone();
        cfg.rate = r;
        let policy = policy_from_name(p).expect("policy validated above");
        run_traffic_point(sys, model, table, policy, &cfg)
    }))
}

/// Sequential twin of [`sweep_rates`]: the same validated (policy, rate)
/// pairs through the same streaming event backend, in a plain loop. Each
/// point is an independent deterministic computation, so the result is
/// byte-equal to [`sweep_rates`] — asserted in this module's tests. The
/// co-design campaign ([`crate::dse::codesign`]) uses this inside its
/// per-candidate fan-out so parallelism lives at exactly one level
/// (candidates, not candidate × point), avoiding nested scoped-thread
/// oversubscription.
pub fn sweep_rates_seq(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    base: &TrafficConfig,
    rates: &[f64],
    policies: &[&str],
) -> Result<Vec<SweepPoint>> {
    let pairs = sweep_pairs(rates, policies)?;
    Ok(pairs
        .iter()
        .map(|&(p, r)| {
            let mut cfg = base.clone();
            cfg.rate = r;
            let policy = policy_from_name(p).expect("policy validated above");
            run_traffic_point(sys, model, table, policy, &cfg)
        })
        .collect())
}

/// Cross-check sweep: the direct-replay backend
/// ([`run_traffic_with_table`]) over the same clamped-width worker
/// scaffold, behind `serve-sim --sweep --threaded`. The two backends
/// deliberately share their arrival-sampling and eviction code (lockstep
/// by construction), so this cross-checks the *independent* parts —
/// inline `Resource` timing versus the event timeline — not the shared
/// sampling.
pub fn sweep_rates_threaded(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    base: &TrafficConfig,
    rates: &[f64],
    policies: &[&str],
) -> Result<Vec<SweepPoint>> {
    let pairs = sweep_pairs(rates, policies)?;
    Ok(sweep_indexed(&pairs, |p, r| {
        let mut cfg = base.clone();
        cfg.rate = r;
        let policy = policy_from_name(p).expect("policy validated above");
        SweepPoint::of(&run_traffic_with_table(sys, model, table, policy, &cfg))
    }))
}

/// Render sweep points as an ASCII throughput–latency table. The final
/// column is the worst per-class SLO attainment (`-` without a workload).
/// Fleet-priced sweeps (any point carrying cost/energy) gain `$/Mtok`
/// and `J/Mtok` columns, wear-enabled sweeps gain `max erases` and
/// `retired`, fault-injected sweeps gain `avail`/`failed`/`shed`;
/// flash-only wear-free fault-free sweeps render byte-identically to
/// pre-fleet builds.
pub fn render_sweep(points: &[SweepPoint]) -> String {
    let priced =
        points.iter().any(|p| p.cost_per_mtok.is_some() || p.energy_per_mtok.is_some());
    let weared = points.iter().any(|p| p.wear_max_erases.is_some());
    let faulted = points.iter().any(|p| p.faults_availability.is_some());
    let mut headers = vec![
        "policy",
        "rate req/s",
        "accepted",
        "rejected",
        "tok/s",
        "TTFT p95",
        "lat p50",
        "lat p95",
        "lat p99",
    ];
    if priced {
        headers.push("$/Mtok");
        headers.push("J/Mtok");
    }
    if weared {
        headers.push("max erases");
        headers.push("retired");
    }
    if faulted {
        headers.push("avail");
        headers.push("failed");
        headers.push("shed");
    }
    headers.push("min SLO");
    let mut t = Table::new(&headers);
    for p in points {
        let mut cells = vec![
            p.policy.clone(),
            format!("{:.1}", p.rate),
            p.accepted.to_string(),
            p.rejected.to_string(),
            format!("{:.1}", p.throughput),
            fmt_time(p.ttft_p95),
            fmt_time(p.latency_p50),
            fmt_time(p.latency_p95),
            fmt_time(p.latency_p99),
        ];
        if priced {
            cells.push(match p.cost_per_mtok {
                Some(c) => format!("{c:.2}"),
                None => "-".to_string(),
            });
            cells.push(match p.energy_per_mtok {
                Some(e) => format!("{e:.1}"),
                None => "-".to_string(),
            });
        }
        if weared {
            cells.push(match p.wear_max_erases {
                Some(e) => e.to_string(),
                None => "-".to_string(),
            });
            cells.push(match p.wear_retirements {
                Some(r) => r.to_string(),
                None => "-".to_string(),
            });
        }
        if faulted {
            cells.push(match p.faults_availability {
                Some(a) => format!("{:.4}", a),
                None => "-".to_string(),
            });
            cells.push(match p.faults_failed {
                Some(f) => f.to_string(),
                None => "-".to_string(),
            });
            cells.push(match p.faults_shed {
                Some(s) => s.to_string(),
                None => "-".to_string(),
            });
        }
        cells.push(match p.min_attainment() {
            Some(a) => format!("{:.1}%", a * 100.0),
            None => "-".to_string(),
        });
        t.row(&cells);
    }
    t.render()
}

/// The SLO frontier of one (policy, class) pair: the highest swept rate
/// at which the class still attained its targets at least as often as
/// the threshold, and the attainment observed there.
#[derive(Debug, Clone, PartialEq)]
pub struct SloFrontier {
    pub policy: String,
    pub class: String,
    /// `None` when no swept rate sustained the threshold.
    pub max_rate: Option<f64>,
    /// Attainment at `max_rate` (0.0 when `max_rate` is `None`).
    pub attainment: f64,
}

/// Reduce workload sweep points to per-(policy, class) SLO frontiers:
/// the maximum swept rate sustaining `min_attainment`. Pairs appear in
/// first-encounter order (policy blocks, mix class order); the result is
/// empty when the points carry no per-class data.
pub fn max_sustained_rates(points: &[SweepPoint], min_attainment: f64) -> Vec<SloFrontier> {
    let mut frontiers: Vec<SloFrontier> = Vec::new();
    for p in points {
        for c in &p.class_attainment {
            let found = frontiers.iter().position(|f| f.policy == p.policy && f.class == c.class);
            let idx = match found {
                Some(i) => i,
                None => {
                    frontiers.push(SloFrontier {
                        policy: p.policy.clone(),
                        class: c.class.clone(),
                        max_rate: None,
                        attainment: 0.0,
                    });
                    frontiers.len() - 1
                }
            };
            let entry = &mut frontiers[idx];
            let sustained = c.attainment >= min_attainment;
            let improves = entry.max_rate.is_none() || entry.max_rate < Some(p.rate);
            if sustained && improves {
                entry.max_rate = Some(p.rate);
                entry.attainment = c.attainment;
            }
        }
    }
    frontiers
}

/// Render the SLO frontier table for a workload sweep.
pub fn render_slo_frontier(points: &[SweepPoint], min_attainment: f64) -> String {
    let mut t = Table::new(&["policy", "class", "max rate req/s", "SLO met there"]);
    for f in max_sustained_rates(points, min_attainment) {
        t.row(&[
            f.policy,
            f.class,
            match f.max_rate {
                Some(r) => format!("{r:.1}"),
                None => "none".to_string(),
            },
            if f.max_rate.is_some() { format!("{:.1}%", f.attainment * 100.0) } else { "-".into() },
        ]);
    }
    format!(
        "max offered rate sustaining >= {:.0}% SLO attainment per class:\n{}",
        min_attainment * 100.0,
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::config::presets::table1_system;
    use crate::coordinator::loadgen::LenRange;
    use crate::llm::model_config::OptModel;

    fn base_cfg() -> TrafficConfig {
        TrafficConfig {
            devices: 2,
            rate: 1.0, // overridden per point
            requests: 40,
            input_tokens: LenRange::new(32, 64),
            output_tokens: LenRange::new(4, 8),
            queue_capacity: 16,
            followup: 0.3,
            seed: 5,
            workload: None,
            fleet: None,
            wear: None,
            arrival: None,
            faults: None,
        }
    }

    fn check_points(points: &[SweepPoint]) {
        assert_eq!(points.len(), 6);
        for block in points.chunks(3) {
            assert!(block.windows(2).all(|w| w[0].rate < w[1].rate), "rates must ascend");
            assert!(block.windows(2).all(|w| w[0].policy == w[1].policy));
            for p in block {
                assert_eq!(p.accepted + p.rejected, 40);
                assert!(p.throughput > 0.0);
            }
        }
        assert_eq!(points[0].policy, "round-robin");
        assert_eq!(points[3].policy, "least-loaded");
    }

    #[test]
    fn sweep_covers_policies_and_sorts_rates() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let points = sweep_rates(
            &sys,
            &model,
            &table,
            &base_cfg(),
            &[20.0, 5.0, 10.0], // unsorted on purpose
            &["round-robin", "least-loaded"],
        )
        .unwrap();
        check_points(&points);
        let rendered = render_sweep(&points);
        assert!(rendered.contains("least-loaded") && rendered.contains("TTFT p95"));
        // The whole sweep is one deterministic computation.
        let again = sweep_rates(
            &sys,
            &model,
            &table,
            &base_cfg(),
            &[20.0, 5.0, 10.0],
            &["round-robin", "least-loaded"],
        )
        .unwrap();
        assert_eq!(points, again);
    }

    #[test]
    fn sequential_sweep_is_byte_equal_to_parallel() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let rates = [20.0, 5.0, 10.0];
        let policies = ["round-robin", "least-loaded"];
        let par = sweep_rates(&sys, &model, &table, &base_cfg(), &rates, &policies).unwrap();
        let seq = sweep_rates_seq(&sys, &model, &table, &base_cfg(), &rates, &policies).unwrap();
        assert_eq!(par, seq);
        check_points(&seq);
        assert!(sweep_rates_seq(&sys, &model, &table, &base_cfg(), &[], &["rr"]).is_err());
    }

    #[test]
    fn threaded_cross_check_covers_the_same_grid() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let points = sweep_rates_threaded(
            &sys,
            &model,
            &table,
            &base_cfg(),
            &[20.0, 5.0, 10.0],
            &["round-robin", "least-loaded"],
        )
        .unwrap();
        check_points(&points);
    }

    #[test]
    fn worker_width_clamps_to_point_count() {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        assert_eq!(clamped_width(1), 1, "a 1-point grid gets exactly one worker");
        assert_eq!(clamped_width(2), cores.min(2));
        assert_eq!(clamped_width(10_000), cores, "wide grids use every core");
        assert_eq!(clamped_width(0), 1, "degenerate grids still clamp to >= 1");
    }

    #[test]
    fn frontier_picks_max_sustained_rate_per_policy_and_class() {
        let point = |policy: &str, rate: f64, chat: f64, batch: f64| SweepPoint {
            policy: policy.to_string(),
            rate,
            accepted: 10,
            rejected: 0,
            throughput: 1.0,
            ttft_p95: 0.1,
            latency_p50: 0.1,
            latency_p95: 0.2,
            latency_p99: 0.3,
            cost_per_mtok: None,
            energy_per_mtok: None,
            wear_max_erases: None,
            wear_total_erases: None,
            wear_retirements: None,
            faults_availability: None,
            faults_failed: None,
            faults_retries: None,
            faults_failovers: None,
            faults_shed: None,
            faults_reprefill_tok: None,
            faults_degraded_s: None,
            class_attainment: vec![
                ClassAttainment { class: "chat".into(), attainment: chat },
                ClassAttainment { class: "batch".into(), attainment: batch },
            ],
        };
        let points = vec![
            point("rr", 4.0, 1.0, 1.0),
            point("rr", 8.0, 0.995, 1.0),
            point("rr", 16.0, 0.80, 0.97),
            point("slo", 4.0, 1.0, 1.0),
            point("slo", 8.0, 1.0, 1.0),
            point("slo", 16.0, 0.999, 0.95),
        ];
        assert_eq!(points[0].min_attainment(), Some(1.0));
        let f = max_sustained_rates(&points, 0.99);
        assert_eq!(f.len(), 4);
        let get = |policy: &str, class: &str| {
            f.iter().find(|x| x.policy == policy && x.class == class).unwrap().max_rate
        };
        assert_eq!(get("rr", "chat"), Some(8.0));
        assert_eq!(get("rr", "batch"), Some(8.0), "16.0 dips below 99%");
        assert_eq!(get("slo", "chat"), Some(16.0));
        assert_eq!(get("slo", "batch"), Some(8.0));
        let rendered = render_slo_frontier(&points, 0.99);
        assert!(rendered.contains("99%") && rendered.contains("slo") && rendered.contains("chat"));
        // A threshold nothing sustains renders "none".
        let none = max_sustained_rates(&points[2..3], 0.99);
        assert_eq!(none[0].max_rate, None);
        assert!(render_slo_frontier(&points[2..3], 0.99).contains("none"));
    }

    #[test]
    fn sweep_rejects_bad_input() {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let cfg = base_cfg();
        assert!(sweep_rates(&sys, &model, &table, &cfg, &[], &["rr"]).is_err());
        assert!(sweep_rates(&sys, &model, &table, &cfg, &[1.0], &[]).is_err());
        assert!(sweep_rates(&sys, &model, &table, &cfg, &[-1.0], &["rr"]).is_err());
        assert!(sweep_rates(&sys, &model, &table, &cfg, &[f64::NAN], &["rr"]).is_err());
        assert!(sweep_rates(&sys, &model, &table, &cfg, &[1.0], &["fifo"]).is_err());
        assert!(sweep_rates_threaded(&sys, &model, &table, &cfg, &[1.0], &["fifo"]).is_err());
    }
}
