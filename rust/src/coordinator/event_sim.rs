//! Deterministic event-driven serving simulation on [`crate::sim::Engine`].
//!
//! This is the default backend behind `serve-sim` and the rate sweep. The
//! closed-loop Poisson traffic model is expressed as a discrete-event
//! [`Model`]: every state change is an explicit event on the engine's
//! deterministic queue (integer-picosecond timestamps, FIFO tie-breaks),
//! so two runs with the same seed produce **bit-identical**
//! [`PoolReport`]s, and a single thread replays million-request traces —
//! no locks, no thread-timing jitter, no per-worker state.
//!
//! Events, in the life of one request (default [`DecodeMode::Coalesced`]):
//!
//! 1. [`ServingEvent::Arrive`] — Poisson arrival. Samples the session
//!    (fresh or follow-up), prompt/output lengths, then runs admission:
//!    scheduler pick through the shared [`Scheduler`]-driven
//!    [`DeviceRouter`] (KV affinity first, then policy), the bounded-queue
//!    backpressure check, and SLC KV admission with idle-LRU eviction.
//!    Rejected arrivals surface immediately as shed load. The handler
//!    reschedules the next arrival, closing the loop.
//! 2. [`ServingEvent::DecodeDone`] — the **whole turn** finished: PCIe KV
//!    upload ([`PcieLink::transfer_time`]), SLC prompt write
//!    ([`initial_kv_write_time`]), and every decode step. Once service
//!    starts, each remaining token time is a pure function of the
//!    immutable [`LatencyTable`] and the FIFO device discipline, so the
//!    first-token instant and the total service time are computed
//!    analytically at service start and carried on this one event —
//!    instead of one [`ServingEvent::TokenDone`] heap event per token.
//!    Engine events drop from `Σ output_tokens` (hundreds per request)
//!    to O(1) per request; see `docs/ARCHITECTURE.md` §Performance
//!    architecture for the invariant that makes this sound and exact.
//! 3. [`ServingEvent::Retire`] — the session's turn is over: the outcome
//!    is recorded, the session becomes eligible for follow-up turns (and
//!    for idle eviction), and the device starts its next queued job.
//!
//! [`DecodeMode::PerToken`] keeps the original event chain —
//! [`ServingEvent::PrefillDone`] then one [`ServingEvent::TokenDone`] per
//! remaining token — as the cross-check oracle: `tests/perf_equivalence.rs`
//! asserts both modes produce byte-identical reports, and
//! `serve-sim --per-token` exposes the oracle on the CLI.
//!
//! The legacy direct-replay loop
//! ([`run_traffic_with_table`][super::loadgen::run_traffic_with_table])
//! is kept as a second cross-check backend (`serve-sim --threaded`
//! selects it). Both backends draw from the RNG in the same structural
//! order (gap, class pick, follow-up chance, session pick, lengths — one
//! shared `workload::ArrivalSampler`), so with follow-ups disabled their
//! traces agree *pointwise* up to the PCIe upload term the event model
//! adds (asserted in `tests/event_sim.rs`); with follow-ups enabled the
//! two idle-session sets evolve on slightly different timelines, so
//! agreement is statistical (percentiles within a few percent), not
//! pointwise.
//!
//! Multi-class workloads ([`super::workload::WorkloadMix`] via
//! [`TrafficConfig::workload`]) ride the same machinery: the sampler
//! draws each arrival's class, class identity lands in every
//! [`SimRequest`], and the report gains per-class percentiles and SLO
//! attainment.
//!
//! Outcomes flow through an [`OutcomeSink`]: [`run_traffic_events`]
//! materializes them ([`CollectSink`]) into a full report, while the
//! rate sweep's [`run_traffic_point`] folds them incrementally
//! ([`StreamingSink`]) into one [`SweepPoint`] per (policy, rate) pair —
//! no per-point outcome vectors.

use super::device::{tier_estimates, DeviceModel, FleetSummary, Tier};
use super::loadgen::{arrival_gap, rehome_sessions, FleetWear, SimRequest, TrafficConfig};
use super::metrics::PoolReport;
use super::router::{DeviceRouter, DeviceStatus, JobInfo, Scheduler};
use super::sink::{CollectSink, OutcomeSink, StreamingSink};
use super::sweep::SweepPoint;
use super::workload::ArrivalSampler;
use crate::config::SystemConfig;
use crate::fault::{DownAction, FleetFaults};
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use crate::sim::{Engine, EventQueue, Model, SimTime};
use crate::util::rng::Rng;
use std::collections::{HashMap, VecDeque};

/// How the decode phase is driven on the event queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeMode {
    /// One [`ServingEvent::DecodeDone`] per request, carrying the
    /// analytically precomputed first-token time — O(1) engine events
    /// per request. The default.
    #[default]
    Coalesced,
    /// One [`ServingEvent::TokenDone`] per decoded token — the original
    /// event chain, kept as the bit-identity cross-check oracle.
    PerToken,
}

/// Event payload of the serving model. One variant per state change in a
/// request's life; `device` indexes the pool (each device runs at most
/// one job, so the index identifies the job).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingEvent {
    /// Next Poisson arrival (self-rescheduling).
    Arrive,
    /// PCIe KV upload + SLC write + first decode step finished
    /// ([`DecodeMode::PerToken`] only).
    PrefillDone { device: usize },
    /// One decode step finished ([`DecodeMode::PerToken`] only).
    TokenDone { device: usize },
    /// The whole service finished ([`DecodeMode::Coalesced`] only):
    /// `first` is the precomputed first-token instant (upload + SLC
    /// write + first decode step after service start).
    DecodeDone { device: usize, first: SimTime },
    /// Turn complete: record the outcome, free the device.
    Retire { device: usize },
    /// A device's deadline timer fired: drop it from the pool, activate
    /// a spare, lose its in-flight work and flash-resident KV
    /// (fault-injection runs only; seeded before the trace starts).
    DeviceDown { device: usize },
    /// Retry attempt for a fault victim, after exponential backoff
    /// (fault-injection runs only).
    Retry { id: u64 },
}

/// An admitted request waiting in (or at the head of) a device queue.
#[derive(Debug, Clone)]
struct Pending {
    id: u64,
    session: u64,
    /// Workload-class index (0 for single-class runs).
    class: usize,
    arrival: SimTime,
    l_in: usize,
    l_out: usize,
    /// Context length at the first decode step (resident KV + new prompt).
    ctx0: usize,
    followup: bool,
    /// Fault-retry attempt this admission belongs to (0 = the original
    /// arrival; only fault-injection runs ever re-admit).
    attempt: u32,
}

/// A fault victim waiting out its retry backoff, keyed by request id.
#[derive(Debug, Clone)]
struct RetryJob {
    session: u64,
    class: usize,
    arrival: SimTime,
    /// Tokens the attempt must re-prefill: the victim's full context
    /// (its flash-resident KV died with the device).
    l_in: usize,
    l_out: usize,
    followup: bool,
    /// Attempt number this retry will execute (1-based).
    attempt: u32,
}

/// The request currently being served by a device.
#[derive(Debug, Clone)]
struct Active {
    req: Pending,
    /// Service start (prefill begin) — busy-time accounting.
    started: SimTime,
    first_token: Option<SimTime>,
    tokens_done: usize,
}

/// One pool device: a bounded FIFO of admitted jobs and at most one
/// active job. Pricing (prefill, per-token decode, energy) lives in the
/// device's [`DeviceModel`], held in `ServingModel::models`.
#[derive(Debug, Clone)]
struct Device {
    queue: VecDeque<Pending>,
    active: Option<Active>,
    busy: SimTime,
    jobs: usize,
    /// When the device drains everything admitted so far. Every admitted
    /// job's full service is priced from stateless models at admission,
    /// and the queue is FIFO and work-conserving, so this *prediction*
    /// tracks the event timeline exactly (debug-asserted at retirement) —
    /// it is what schedulers see as [`DeviceStatus::est_wait`]. The same
    /// property is what makes [`DecodeMode::Coalesced`] exact.
    free_at: SimTime,
}

impl Device {
    /// Jobs queued or running — the quantity the bounded-queue admission
    /// check and the [`Scheduler`] policies see.
    fn depth(&self) -> usize {
        self.queue.len() + self.active.is_some() as usize
    }
}

/// The closed-loop serving simulation as a [`Model`] for [`Engine`],
/// generic over where finished outcomes go ([`OutcomeSink`]).
///
/// Use [`run_traffic_events`] (full report) or [`run_traffic_point`]
/// (streamed sweep aggregates) unless you need to drive the engine
/// yourself (e.g. to interleave other models or stop early).
pub struct ServingModel<'a, S: OutcomeSink = CollectSink> {
    cfg: TrafficConfig,
    router: DeviceRouter,
    rng: Rng,
    /// Shared arrival-sampling path (class pick, follow-up decision,
    /// session choice, lengths) — also owns the per-class idle lists.
    sampler: ArrivalSampler,
    mode: DecodeMode,
    devices: Vec<Device>,
    /// Per-device pricing model — flash for every slot unless
    /// [`TrafficConfig::fleet`] says otherwise.
    models: Vec<DeviceModel<'a>>,
    /// Per-slot wear meters + roster state when wear accounting is
    /// enabled ([`TrafficConfig::wear`]); `None` leaves every serving
    /// path byte-identical to the wear-free simulator.
    wear: Option<FleetWear>,
    /// Fleet fault state when fault injection is enabled
    /// ([`TrafficConfig::faults`]); `None` leaves every serving path
    /// byte-identical to the fault-free simulator.
    faults: Option<FleetFaults>,
    /// Completion events to swallow per slot: a downed device's
    /// in-flight job already has its completion on the queue.
    poisoned: Vec<u32>,
    /// Victims waiting out retry backoff, keyed by request id.
    retry_jobs: HashMap<u64, RetryJob>,
    /// Total decode energy (J) accumulated at retirement, in record
    /// order — the single source both report paths read.
    energy_j: f64,
    /// Arrival clock accumulated in f64 seconds — the same accumulation
    /// the direct backend uses, so both backends sample identical
    /// arrival instants from identical seeds.
    clock: f64,
    arrivals: usize,
    /// Retirement time per finished session; entries are removed when the
    /// session starts a new turn. Feeds oldest-first idle eviction.
    completed_at: HashMap<u64, SimTime>,
    sink: S,
}

impl<'a> ServingModel<'a, CollectSink> {
    /// The default model: coalesced decode, every outcome materialized.
    pub fn new(
        sys: &'a SystemConfig,
        model: &'a ModelShape,
        table: &'a LatencyTable,
        policy: Box<dyn Scheduler + Send>,
        cfg: &TrafficConfig,
    ) -> ServingModel<'a, CollectSink> {
        ServingModel::with_sink(
            sys,
            model,
            table,
            policy,
            cfg,
            DecodeMode::Coalesced,
            CollectSink::with_capacity(cfg.requests),
        )
    }

    /// Reduce the finished simulation to a [`PoolReport`]. Outcomes are
    /// sorted into arrival (id) order to match the direct backend.
    pub fn into_report(mut self) -> PoolReport {
        self.sink.outcomes.sort_by_key(|o| o.id);
        let makespan = self
            .sink
            .outcomes
            .iter()
            .filter(|o| !o.rejected)
            .map(|o| o.completed)
            .max()
            .unwrap_or(SimTime::ZERO);
        let device_utilization = self
            .devices
            .iter()
            .map(|d| if makespan == SimTime::ZERO { 0.0 } else { d.busy.secs() / makespan.secs() })
            .collect();
        let device_jobs = self.devices.iter().map(|d| d.jobs).collect();
        let fleet = self.fleet_summary();
        let wear = self.wear.as_ref().map(|w| w.summary());
        let faults = self.faults.take().map(|mut f| f.summary(makespan));
        PoolReport {
            backend: "event",
            policy: self.router.policy_name().to_string(),
            devices: self.cfg.devices,
            offered_rate: self.cfg.rate,
            workload: self.cfg.workload.clone(),
            outcomes: self.sink.outcomes,
            makespan,
            device_utilization,
            device_jobs,
            fleet,
            wear,
            faults,
        }
    }
}

impl ServingModel<'_, StreamingSink> {
    /// Reduce the finished simulation's streamed aggregates to one
    /// [`SweepPoint`].
    pub fn into_point(mut self) -> SweepPoint {
        let policy = self.router.policy_name().to_string();
        let fleet = self.fleet_summary();
        let wear = self.wear.as_ref().map(|w| w.summary());
        let faults = self.faults.take().map(|mut f| f.summary(self.sink.makespan()));
        self.sink.finish(policy, self.cfg.rate, fleet, wear, faults)
    }
}

impl<'a, S: OutcomeSink> ServingModel<'a, S> {
    /// Build with an explicit [`DecodeMode`] and [`OutcomeSink`].
    pub fn with_sink(
        sys: &'a SystemConfig,
        model: &'a ModelShape,
        table: &'a LatencyTable,
        policy: Box<dyn Scheduler + Send>,
        cfg: &TrafficConfig,
        mode: DecodeMode,
        sink: S,
    ) -> ServingModel<'a, S> {
        assert!(cfg.devices > 0, "pool needs at least one device");
        assert!(cfg.rate > 0.0, "arrival rate must be positive");
        assert!(cfg.queue_capacity > 0, "queue capacity must be at least 1");
        assert_eq!(table.model_name(), model.name, "latency table built for a different model");
        assert_eq!(table.system_name(), sys.name, "latency table built for a different system");
        let models = match &cfg.fleet {
            Some(spec) => {
                assert_eq!(
                    spec.n_devices(),
                    cfg.devices,
                    "fleet spec {} sizes {} devices but cfg.devices = {}",
                    spec.name(),
                    spec.n_devices(),
                    cfg.devices
                );
                DeviceModel::fleet(spec, sys, model, table)
            }
            None => (0..cfg.devices).map(|_| DeviceModel::flash(sys, model, table)).collect(),
        };
        let mut models = models;
        // Spares are flash slots (flash is the tier that wears out and
        // hard-fails), provisioned up front and activated as devices
        // retire or fail. Wear spares and fault spares form one pool.
        for _ in cfg.devices..cfg.n_slots() {
            models.push(DeviceModel::flash(sys, model, table));
        }
        let router = match &cfg.fleet {
            Some(_) => DeviceRouter::with_fleet(&models, policy),
            None => DeviceRouter::new(cfg.n_slots(), sys, model, policy),
        };
        let wear = cfg.wear.as_ref().map(|w| FleetWear::new(w, &models, cfg.devices));
        let faults = cfg.faults.as_ref().map(|f| {
            let flash: Vec<bool> = models.iter().map(|m| m.tier() == Tier::Flash).collect();
            FleetFaults::new(f, cfg.seed, &flash, cfg.devices)
        });
        let devices = (0..cfg.n_slots())
            .map(|_| Device {
                queue: VecDeque::new(),
                active: None,
                busy: SimTime::ZERO,
                jobs: 0,
                free_at: SimTime::ZERO,
            })
            .collect();
        ServingModel {
            cfg: cfg.clone(),
            router,
            rng: Rng::new(cfg.seed),
            sampler: ArrivalSampler::new(cfg),
            mode,
            devices,
            models,
            wear,
            faults,
            poisoned: vec![0; cfg.n_slots()],
            retry_jobs: HashMap::new(),
            energy_j: 0.0,
            clock: 0.0,
            arrivals: 0,
            completed_at: HashMap::new(),
            sink,
        }
    }

    /// Fleet rollup for reports — present only when a fleet spec was
    /// given, so flash-only runs render byte-identically to the
    /// pre-tier output.
    fn fleet_summary(&self) -> Option<FleetSummary> {
        self.cfg
            .fleet
            .as_ref()
            .map(|spec| FleetSummary::of(spec, &self.models[..self.cfg.devices], self.energy_j))
    }

    fn on_arrive(&mut self, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let id = self.arrivals as u64;
        self.arrivals += 1;
        self.admit(id, now, queue);
        // Close the loop *after* this arrival's draws — the exact order
        // the direct backend consumes the stream in.
        if self.arrivals < self.cfg.requests {
            let u = self.rng.f64();
            self.clock += arrival_gap(&self.cfg, self.clock, u); // exponential gap
            queue.schedule(SimTime::from_secs(self.clock), ServingEvent::Arrive);
        }
    }

    /// Admission control for one arrival: session sampling, scheduler
    /// pick, bounded-queue check, KV admission with idle eviction, and —
    /// if everything passes — enqueue on the picked device.
    fn admit(&mut self, id: u64, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        // Follow-up turns reuse a retired session of the same class. The
        // sampling sequence is the one [`ArrivalSampler`] both backends
        // share, so the RNG streams stay in lockstep by construction.
        let arr = self.sampler.sample(&mut self.rng);
        let (session, class, reuse) = (arr.session, arr.class, arr.followup);
        let (l_in, l_out) = (arr.input_tokens, arr.output_tokens);

        // Brownout: while surviving capacity sits below the configured
        // fraction of the nominal fleet, fresh arrivals of every class
        // but the highest-priority one (class 0) are shed at the door.
        // Retries bypass admission and are exempt.
        if class > 0 {
            if let Some(f) = self.faults.as_mut() {
                if f.brownout_active() {
                    f.shed_brownout += 1;
                    if reuse {
                        self.sampler.release(session, class);
                    }
                    self.sink.record(SimRequest {
                        id,
                        session,
                        class,
                        device: None,
                        arrival: now,
                        first_token: None,
                        completed: now,
                        input_tokens: l_in,
                        output_tokens: 0,
                        context: 0,
                        rejected: true,
                        failed: false,
                        followup: reuse,
                        energy_j: 0.0,
                    });
                    return;
                }
            }
        }

        let status: Vec<DeviceStatus> = self
            .devices
            .iter()
            .enumerate()
            .filter(|(i, _)| match &self.wear {
                Some(w) => w.eligible(*i),
                None => true,
            })
            .filter(|(i, _)| match &self.faults {
                Some(f) => f.schedulable(*i),
                None => true,
            })
            .map(|(i, d)| DeviceStatus {
                device: i,
                queue_depth: d.depth(),
                est_wait: d.free_at.saturating_sub(now),
                kv_used: self.router.kv(i).used(),
                kv_capacity: self.router.kv(i).capacity,
                tier: self.models[i].tier(),
                wear_used: self.wear.as_ref().map_or(0, |w| w.devices[i].erases()),
                wear_budget: self.wear.as_ref().map_or(0, |w| w.erase_capacity()),
            })
            .collect();
        // Graceful end of fleet life: every device retired and no spare
        // left. Shed the arrival instead of panicking in the scheduler.
        if status.is_empty() {
            if reuse {
                self.sampler.release(session, class);
            }
            self.router.forget(session);
            self.sink.record(SimRequest {
                id,
                session,
                class,
                device: None,
                arrival: now,
                first_token: None,
                completed: now,
                input_tokens: l_in,
                output_tokens: 0,
                context: 0,
                rejected: true,
                failed: false,
                followup: reuse,
                energy_j: 0.0,
            });
            return;
        }
        // Fresh-session prefill estimates per tier (the policy never sees
        // pinned follow-ups): for flash, PCIe KV upload + SLC prompt
        // write + first step; for GPU, roofline prefill + first step.
        let (est_flash, est_gpu) = tier_estimates(&self.models, l_in);
        let job = JobInfo {
            est_prefill: est_flash,
            est_prefill_gpu: est_gpu,
            prompt_tokens: l_in,
            ttft_target: self.sampler.classes()[class].slo.ttft,
        };
        let dev = self.router.assign(session, &status, &job);

        // Bounded admission: the picked device's queue may be full. The
        // status vector excludes retired slots, so look the device up by
        // id rather than by index.
        let depth = status.iter().find(|s| s.device == dev).map(|s| s.queue_depth);
        let queue_full = match depth {
            Some(d) => d >= self.cfg.queue_capacity,
            None => true, // assigned slot left the roster: shed the arrival
        };
        if queue_full {
            self.reject(id, now, session, class, dev, l_in, reuse);
            return;
        }

        // SLC KV admission, evicting retired resident sessions (oldest
        // first) when the region is full.
        let per_token = self.router.kv(dev).per_token;
        let resident = self.router.kv(dev).context_len(session);
        let needed = (l_in + l_out) as u64 * per_token;
        if self.router.kv(dev).used() + needed > self.router.kv(dev).capacity {
            let before = self.router.kv(dev).active_sequences();
            self.evict_idle(dev, session, needed);
            if let Some(w) = self.wear.as_mut() {
                for _ in self.router.kv(dev).active_sequences()..before {
                    w.devices[dev].note_eviction();
                }
            }
        }
        if self.router.kv(dev).used() + needed > self.router.kv(dev).capacity {
            self.reject(id, now, session, class, dev, l_in, reuse);
            return;
        }
        match resident {
            // Fresh (or evicted-and-returning) session: admit the prompt.
            None => {
                self.router.kv_mut(dev).admit(session, l_in).expect("admission after space check");
            }
            // Follow-up with resident KV: append the new prompt tokens.
            Some(_) => {
                self.router
                    .kv_mut(dev)
                    .append_n(session, l_in)
                    .expect("append after space check");
            }
        }
        let ctx0 = resident.unwrap_or(0) + l_in;
        self.router.kv_mut(dev).append_n(session, l_out).expect("append after space check");
        // Running again: no longer an idle-eviction candidate.
        self.completed_at.remove(&session);
        // Wear: the turn wrote `needed` KV bytes ((l_in + l_out) tokens)
        // to the device. GPU slots hold KV in DRAM and never wear. A
        // newly exhausted device retires inline — its queue (including
        // this job) drains normally, its sessions re-home, and the next
        // spare joins the roster — so no extra engine events are spent
        // and the coalesced event-count invariant holds.
        if let Some(w) = self.wear.as_mut() {
            if self.models[dev].tier() == Tier::Flash
                && w.charge(dev, (l_in + l_out) as u64, needed, now)
            {
                rehome_sessions(&mut self.router, dev);
                let activated = w.retire(dev, now);
                if let Some(f) = self.faults.as_mut() {
                    f.on_wear_retire(dev, activated);
                }
            }
        }

        // Price the whole service now (stateless models, FIFO queue), so
        // `free_at` predicts this job's completion exactly — the
        // scheduler-visible backlog clock. Pricing is per the assigned
        // device's tier.
        let service =
            self.models[dev].prefill_cost(l_in) + self.models[dev].decode_time(ctx0, l_out);
        let begin = self.devices[dev].free_at.max(now);
        let end = match self.faults.as_mut() {
            // Storm dilation is compositional, so dilating the whole
            // service from `begin` lands on the same instant the event
            // chain will: `free_at` stays an exact prediction.
            Some(f) => f.dilate(dev, begin, service),
            None => begin + service,
        };
        let d = &mut self.devices[dev];
        d.free_at = end;

        let was_idle = d.active.is_none();
        d.queue.push_back(Pending {
            id,
            session,
            class,
            arrival: now,
            l_in,
            l_out,
            ctx0,
            followup: reuse,
            attempt: 0,
        });
        if was_idle {
            self.start_service(dev, now, queue);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn reject(
        &mut self,
        id: u64,
        now: SimTime,
        session: u64,
        class: usize,
        dev: usize,
        l_in: usize,
        reuse: bool,
    ) {
        if reuse {
            // The session stays eligible for follow-ups of its class.
            self.sampler.release(session, class);
        }
        if self.router.kv(dev).context_len(session).is_none() {
            self.router.forget(session); // placement without resident KV
        }
        self.sink.record(SimRequest {
            id,
            session,
            class,
            device: None,
            arrival: now,
            first_token: None,
            completed: now,
            input_tokens: l_in,
            output_tokens: 0,
            context: 0,
            rejected: true,
            failed: false,
            followup: reuse,
            energy_j: 0.0,
        });
    }

    /// Evict retired resident sessions on `dev` (never the current
    /// session), oldest retirement first, via the eviction core shared
    /// with the direct backend (`loadgen::evict_oldest_idle`).
    fn evict_idle(&mut self, dev: usize, keep: u64, needed: u64) {
        let idle: Vec<(SimTime, u64)> = self
            .router
            .sessions_on(dev)
            .into_iter()
            .filter(|s| *s != keep)
            .filter_map(|s| self.completed_at.get(&s).map(|done| (*done, s)))
            .collect();
        super::loadgen::evict_oldest_idle(&mut self.router, dev, idle, needed);
    }

    /// Begin serving the next queued job on `dev`.
    ///
    /// Every term of the service is a pure function of immutable inputs
    /// (the shared [`LatencyTable`], the link model, the job's lengths),
    /// so both the first-token instant and the completion instant are
    /// known *now*. [`DecodeMode::Coalesced`] therefore schedules one
    /// [`ServingEvent::DecodeDone`] carrying that precomputed pair;
    /// [`DecodeMode::PerToken`] schedules the original
    /// [`ServingEvent::PrefillDone`] + per-token chain, which sums the
    /// same integer-picosecond terms in the same order and lands on the
    /// same instants (u64 addition is associative) — the oracle the
    /// bit-identity suite replays.
    fn start_service(&mut self, d: usize, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let m = &self.models[d];
        debug_assert!(self.devices[d].active.is_none(), "device {d} already serving");
        let Some(req) = self.devices[d].queue.pop_front() else {
            return;
        };
        // Read-retry storms dilate service piecewise; dilation composes
        // (`dilate(t, a + b) == dilate(dilate(t, a), b)`), so per-token
        // and coalesced schedules still land on identical instants, and
        // the admission-time `free_at` prediction stays exact.
        let head = m.prefill_cost(req.l_in) + m.step_time(req.ctx0);
        let rest = m.decode_time(req.ctx0 + 1, req.l_out - 1);
        let first = match self.faults.as_mut() {
            Some(f) => f.dilate(d, now, head),
            None => now + head,
        };
        match self.mode {
            DecodeMode::Coalesced => {
                // Steps after the first: ctx0+1 .. ctx0+l_out-1 (l_out >= 1
                // by LenRange's invariant).
                let end = match self.faults.as_mut() {
                    Some(f) => f.dilate(d, first, rest),
                    None => first + rest,
                };
                self.devices[d].active =
                    Some(Active { req, started: now, first_token: None, tokens_done: 0 });
                queue.schedule(end, ServingEvent::DecodeDone { device: d, first });
            }
            DecodeMode::PerToken => {
                self.devices[d].active =
                    Some(Active { req, started: now, first_token: None, tokens_done: 0 });
                queue.schedule(first, ServingEvent::PrefillDone { device: d });
            }
        }
    }

    /// Per-token oracle only: schedule the next decode step, or
    /// retirement when the turn is done.
    fn advance(&mut self, d: usize, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let a = self.devices[d].active.as_ref().expect("advance without active job");
        if a.tokens_done == a.req.l_out {
            queue.schedule(now, ServingEvent::Retire { device: d });
        } else {
            let step = self.models[d].step_time(a.req.ctx0 + a.tokens_done);
            let at = match self.faults.as_mut() {
                Some(f) => f.dilate(d, now, step),
                None => now + step,
            };
            queue.schedule(at, ServingEvent::TokenDone { device: d });
        }
    }

    fn on_retire(&mut self, d: usize, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let dev = &mut self.devices[d];
        let a = dev.active.take().expect("retire without active job");
        dev.busy += now - a.started;
        dev.jobs += 1;
        // The admission-time completion prediction must track the event
        // timeline exactly: equal once the device drains, never behind.
        debug_assert!(dev.free_at >= now, "free_at prediction fell behind the timeline");
        debug_assert!(
            !dev.queue.is_empty() || dev.free_at == now,
            "drained device predicted busy until {} at {}",
            dev.free_at,
            now
        );
        let r = a.req;
        // Per-request decode energy is a pure function of the device
        // tier and the turn's shape, so it is identical across backends;
        // the running total feeds the fleet rollup.
        let energy = self.models[d].decode_energy(r.ctx0, r.l_out);
        self.energy_j += energy;
        self.completed_at.insert(r.session, now);
        self.sampler.release(r.session, r.class);
        self.sink.record(SimRequest {
            id: r.id,
            session: r.session,
            class: r.class,
            device: Some(d),
            arrival: r.arrival,
            first_token: a.first_token,
            completed: now,
            input_tokens: r.l_in,
            output_tokens: r.l_out,
            context: r.ctx0,
            rejected: false,
            failed: false,
            followup: r.followup,
            energy_j: energy,
        });
        self.start_service(d, now, queue);
    }

    /// A device's deadline timer fired: drop it from the roster, promote
    /// a spare, and route every in-flight and queued victim into the
    /// retry/fail path. The victims' flash-resident KV dies with the
    /// device, so a later successful retry re-prefills the full context.
    fn on_device_down(&mut self, d: usize, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let Some(f) = self.faults.as_mut() else {
            return;
        };
        let DownAction::Fail { activated } = f.on_down(d, now) else {
            return;
        };
        if let Some(w) = self.wear.as_mut() {
            w.fault_retire(d, now);
            if let Some(s) = activated {
                w.activate(s);
            }
        }
        // Evict every session homed on the dead device (their KV is gone).
        rehome_sessions(&mut self.router, d);
        let dev = &mut self.devices[d];
        let mut victims: Vec<Pending> = Vec::new();
        if let Some(a) = dev.active.take() {
            // The in-flight job dies mid-service; its completion event is
            // already on the queue and must be swallowed when it fires.
            self.poisoned[d] += 1;
            dev.busy += now - a.started;
            victims.push(a.req);
        }
        victims.extend(dev.queue.drain(..));
        for req in victims {
            self.fail_or_retry(req, now, queue);
        }
    }

    /// Burn one retry attempt for a fault victim: schedule re-admission
    /// after exponential backoff, or fail the request permanently once
    /// the budget is exhausted. Failed sessions die — they are never
    /// released back to the follow-up pool.
    fn fail_or_retry(&mut self, req: Pending, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let f = self.faults.as_mut().expect("fault recovery without fault state");
        let next = req.attempt + 1;
        if next > f.retry_budget() {
            f.failed_requests += 1;
            self.sink.record(SimRequest {
                id: req.id,
                session: req.session,
                class: req.class,
                device: None,
                arrival: req.arrival,
                first_token: None,
                completed: now,
                input_tokens: req.ctx0,
                output_tokens: 0,
                context: 0,
                rejected: true,
                failed: true,
                followup: req.followup,
                energy_j: 0.0,
            });
            return;
        }
        f.retries += 1;
        let at = now + f.backoff(next);
        self.retry_jobs.insert(
            req.id,
            RetryJob {
                session: req.session,
                class: req.class,
                arrival: req.arrival,
                l_in: req.ctx0,
                l_out: req.l_out,
                followup: req.followup,
                attempt: next,
            },
        );
        queue.schedule(at, ServingEvent::Retry { id: req.id });
    }

    /// Re-admit a fault victim on the surviving roster: same placement
    /// flow as a fresh arrival (scheduler pick, bounded queue, KV
    /// admission with idle eviction), but no sampling and no brownout —
    /// the request was already admitted once. Placement failures burn
    /// further retry attempts; success re-prefills the full context and
    /// counts a failover.
    fn on_retry(&mut self, id: u64, now: SimTime, queue: &mut EventQueue<ServingEvent>) {
        let Some(job) = self.retry_jobs.remove(&id) else {
            return;
        };
        let (session, l_in, l_out) = (job.session, job.l_in, job.l_out);
        let as_pending = |j: &RetryJob| Pending {
            id,
            session: j.session,
            class: j.class,
            arrival: j.arrival,
            l_in: j.l_in,
            l_out: j.l_out,
            ctx0: j.l_in,
            followup: j.followup,
            attempt: j.attempt,
        };
        let status: Vec<DeviceStatus> = self
            .devices
            .iter()
            .enumerate()
            .filter(|(i, _)| match &self.wear {
                Some(w) => w.eligible(*i),
                None => true,
            })
            .filter(|(i, _)| self.faults.as_ref().is_some_and(|f| f.schedulable(*i)))
            .map(|(i, d)| DeviceStatus {
                device: i,
                queue_depth: d.depth(),
                est_wait: d.free_at.saturating_sub(now),
                kv_used: self.router.kv(i).used(),
                kv_capacity: self.router.kv(i).capacity,
                tier: self.models[i].tier(),
                wear_used: self.wear.as_ref().map_or(0, |w| w.devices[i].erases()),
                wear_budget: self.wear.as_ref().map_or(0, |w| w.erase_capacity()),
            })
            .collect();
        if status.is_empty() {
            let p = as_pending(&job);
            self.fail_or_retry(p, now, queue);
            return;
        }
        let (est_flash, est_gpu) = tier_estimates(&self.models, l_in);
        let info = JobInfo {
            est_prefill: est_flash,
            est_prefill_gpu: est_gpu,
            prompt_tokens: l_in,
            ttft_target: self.sampler.classes()[job.class].slo.ttft,
        };
        let dev = self.router.assign(session, &status, &info);
        let depth = status.iter().find(|s| s.device == dev).map(|s| s.queue_depth);
        let queue_full = match depth {
            Some(d) => d >= self.cfg.queue_capacity,
            None => true,
        };
        let per_token = self.router.kv(dev).per_token;
        let needed = (l_in + l_out) as u64 * per_token;
        if !queue_full && self.router.kv(dev).used() + needed > self.router.kv(dev).capacity {
            let before = self.router.kv(dev).active_sequences();
            self.evict_idle(dev, session, needed);
            if let Some(w) = self.wear.as_mut() {
                for _ in self.router.kv(dev).active_sequences()..before {
                    w.devices[dev].note_eviction();
                }
            }
        }
        if queue_full || self.router.kv(dev).used() + needed > self.router.kv(dev).capacity {
            if self.router.kv(dev).context_len(session).is_none() {
                self.router.forget(session);
            }
            let p = as_pending(&job);
            self.fail_or_retry(p, now, queue);
            return;
        }
        let resident = self.router.kv(dev).context_len(session);
        match resident {
            None => {
                self.router.kv_mut(dev).admit(session, l_in).expect("admission after space check");
            }
            Some(_) => {
                self.router
                    .kv_mut(dev)
                    .append_n(session, l_in)
                    .expect("append after space check");
            }
        }
        let ctx0 = resident.unwrap_or(0) + l_in;
        self.router.kv_mut(dev).append_n(session, l_out).expect("append after space check");
        self.completed_at.remove(&session);
        if let Some(w) = self.wear.as_mut() {
            if self.models[dev].tier() == Tier::Flash
                && w.charge(dev, (l_in + l_out) as u64, needed, now)
            {
                rehome_sessions(&mut self.router, dev);
                let activated = w.retire(dev, now);
                if let Some(f) = self.faults.as_mut() {
                    f.on_wear_retire(dev, activated);
                }
            }
        }
        let service =
            self.models[dev].prefill_cost(l_in) + self.models[dev].decode_time(ctx0, l_out);
        let begin = self.devices[dev].free_at.max(now);
        let end = {
            let f = self.faults.as_mut().expect("retry without fault state");
            f.failovers += 1;
            f.re_prefill_tokens += l_in as u64;
            f.dilate(dev, begin, service)
        };
        let d = &mut self.devices[dev];
        d.free_at = end;
        let was_idle = d.active.is_none();
        d.queue.push_back(Pending {
            id,
            session,
            class: job.class,
            arrival: job.arrival,
            l_in,
            l_out,
            ctx0,
            followup: job.followup,
            attempt: job.attempt,
        });
        if was_idle {
            self.start_service(dev, now, queue);
        }
    }
}

impl<S: OutcomeSink> Model for ServingModel<'_, S> {
    type Event = ServingEvent;

    fn handle(&mut self, now: SimTime, ev: ServingEvent, queue: &mut EventQueue<ServingEvent>) {
        // A downed device's in-flight job already had its completion on
        // the queue when the device dropped; swallow exactly that one
        // event (the device takes no new work afterwards, so the next
        // completion-flavored event for the slot is the stale one).
        if let ServingEvent::PrefillDone { device }
        | ServingEvent::TokenDone { device }
        | ServingEvent::DecodeDone { device, .. }
        | ServingEvent::Retire { device } = ev
        {
            if self.poisoned[device] > 0 {
                self.poisoned[device] -= 1;
                return;
            }
        }
        match ev {
            ServingEvent::Arrive => self.on_arrive(now, queue),
            ServingEvent::PrefillDone { device } => {
                let a = self.devices[device].active.as_mut().expect("prefill without active job");
                a.first_token = Some(now);
                a.tokens_done = 1;
                self.advance(device, now, queue);
            }
            ServingEvent::TokenDone { device } => {
                let a = self.devices[device].active.as_mut().expect("token without active job");
                a.tokens_done += 1;
                self.advance(device, now, queue);
            }
            ServingEvent::DecodeDone { device, first } => {
                let a = self.devices[device].active.as_mut().expect("decode without active job");
                a.first_token = Some(first);
                a.tokens_done = a.req.l_out;
                // Retire at `now`, exactly as the final TokenDone would —
                // the event-queue fast path makes this heap-free.
                queue.schedule(now, ServingEvent::Retire { device });
            }
            ServingEvent::Retire { device } => self.on_retire(device, now, queue),
            ServingEvent::DeviceDown { device } => self.on_device_down(device, now, queue),
            ServingEvent::Retry { id } => self.on_retry(id, now, queue),
        }
    }
}

/// Engine event budget for one run: coalesced traces cost at most 3
/// events per arrival (Arrive + DecodeDone + Retire); the per-token
/// oracle pays one more per decoded token. Fault injection adds up to
/// one `DeviceDown` per slot, and each request may re-run its full
/// service once per retry attempt (plus the `Retry` event itself).
fn event_budget(cfg: &TrafficConfig, mode: DecodeMode) -> u64 {
    let per_request = match mode {
        DecodeMode::Coalesced => 3u64,
        DecodeMode::PerToken => cfg.max_output_tokens() as u64 + 4,
    };
    let base = (cfg.requests as u64).saturating_mul(per_request);
    let fault_overhead = match &cfg.faults {
        Some(f) => (cfg.requests as u64)
            .saturating_mul((per_request + 1).saturating_mul(f.retries as u64 + 1))
            .saturating_add(cfg.n_slots() as u64),
        None => 0,
    };
    base.saturating_add(fault_overhead).saturating_add(16)
}

/// Build, seed, and drain one serving run; returns the finished model and
/// the number of engine events it took.
fn run_serving<'a, S: OutcomeSink>(
    sys: &'a SystemConfig,
    model: &'a ModelShape,
    table: &'a LatencyTable,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
    mode: DecodeMode,
    sink: S,
) -> (ServingModel<'a, S>, u64) {
    let serving = ServingModel::with_sink(sys, model, table, policy, cfg, mode, sink);
    // Steady-state pending events: at most one per device plus the next
    // arrival — the capacity hint makes the heap allocation-free after
    // startup.
    let mut engine = Engine::with_capacity(serving, cfg.devices + 4);
    engine.max_events = event_budget(cfg, mode);
    // Hard-failure instants are fixed before the first arrival is even
    // drawn (per-slot streams, drawn at construction), so the whole
    // fault schedule goes on the queue up front. Seeding them first
    // gives them earlier sequence numbers: a DeviceDown that ties an
    // arrival to the picosecond fires before it — the same order the
    // direct backend's drain-then-arrive loop imposes.
    let downs = engine.model.faults.as_ref().map(|f| f.down_events()).unwrap_or_default();
    for (at, slot) in downs {
        engine.seed(at, ServingEvent::DeviceDown { device: slot });
    }
    if cfg.requests > 0 {
        let u = engine.model.rng.f64();
        let gap = arrival_gap(cfg, 0.0, u);
        engine.model.clock = gap;
        engine.seed(SimTime::from_secs(gap), ServingEvent::Arrive);
    }
    engine.run();
    let events = engine.events_processed();
    (engine.model, events)
}

/// Run a closed-loop Poisson trace on the event-driven backend. Same
/// inputs as [`run_traffic_with_table`][super::loadgen::run_traffic_with_table];
/// the report additionally prices the prefill PCIe KV upload and is
/// **bit-identical** across runs with the same configuration
/// (single-threaded, deterministic event order). Decodes are coalesced
/// ([`DecodeMode::Coalesced`]); use [`run_traffic_events_mode`] to select
/// the per-token oracle.
pub fn run_traffic_events(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
) -> PoolReport {
    run_traffic_events_mode(sys, model, table, policy, cfg, DecodeMode::Coalesced)
}

/// [`run_traffic_events`] with an explicit [`DecodeMode`]. Both modes
/// produce byte-identical reports for the same configuration (asserted
/// in `tests/perf_equivalence.rs`); coalescing is strictly a change in
/// how many engine events the same timeline costs. (Caveat, for
/// completeness: a picosecond-exact tie between an arrival and a
/// completion could tie-break differently across modes because the two
/// schedules consume different sequence numbers — f64-derived arrival
/// instants never collide with summed table steps in practice, and the
/// equivalence suite compares whole traces.)
pub fn run_traffic_events_mode(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
    mode: DecodeMode,
) -> PoolReport {
    run_traffic_events_counted(sys, model, table, policy, cfg, mode).0
}

/// [`run_traffic_events_mode`] plus the engine event count — the
/// instrumented entry point behind the `perf_hotpath` bench's
/// events-per-request accounting.
pub fn run_traffic_events_counted(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
    mode: DecodeMode,
) -> (PoolReport, u64) {
    let sink = CollectSink::with_capacity(cfg.requests);
    let (serving, events) = run_serving(sys, model, table, policy, cfg, mode, sink);
    (serving.into_report(), events)
}

/// Run one sweep point on the event backend with the streaming sink: no
/// outcome vector is ever materialized, and the returned [`SweepPoint`]
/// is bit-identical to `SweepPoint::of` over the same run's full report
/// (asserted in `tests/perf_equivalence.rs`).
pub fn run_traffic_point(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
) -> SweepPoint {
    let classes = cfg
        .workload
        .as_ref()
        .map(|mix| mix.classes().iter().map(|c| (c.name.clone(), c.slo)).collect())
        .unwrap_or_default();
    let sink = StreamingSink::new(classes);
    let (serving, _) = run_serving(sys, model, table, policy, cfg, DecodeMode::Coalesced, sink);
    serving.into_point()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::config::presets::table1_system;
    use crate::coordinator::loadgen::LenRange;
    use crate::coordinator::router::{LeastLoaded, RoundRobin};
    use crate::llm::model_config::OptModel;

    fn quick_cfg(devices: usize, requests: usize, rate: f64, seed: u64) -> TrafficConfig {
        TrafficConfig {
            devices,
            rate,
            requests,
            input_tokens: LenRange::new(64, 128),
            output_tokens: LenRange::new(8, 16),
            queue_capacity: 64,
            followup: 0.3,
            seed,
            workload: None,
            fleet: None,
            wear: None,
            arrival: None,
            faults: None,
        }
    }

    fn run(cfg: &TrafficConfig, least_loaded: bool) -> PoolReport {
        run_mode(cfg, least_loaded, DecodeMode::Coalesced).0
    }

    fn run_mode(cfg: &TrafficConfig, least_loaded: bool, mode: DecodeMode) -> (PoolReport, u64) {
        let policy: Box<dyn Scheduler + Send> = if least_loaded {
            Box::new(LeastLoaded::new())
        } else {
            Box::new(RoundRobin::new())
        };
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        run_traffic_events_counted(&sys, &model, &table, policy, cfg, mode)
    }

    #[test]
    fn all_arrivals_accounted_for() {
        let cfg = quick_cfg(2, 40, 10.0, 3);
        let rep = run(&cfg, true);
        assert_eq!(rep.backend, "event");
        assert_eq!(rep.outcomes.len(), 40);
        assert_eq!(rep.accepted() + rep.rejected(), 40);
        assert_eq!(rep.device_utilization.len(), 2);
        // Outcomes come back in arrival order despite completion-order
        // retirement events.
        assert!(rep.outcomes.windows(2).all(|w| w[0].id < w[1].id));
    }

    #[test]
    fn bit_identical_given_seed() {
        let cfg = quick_cfg(3, 60, 15.0, 7);
        let a = run(&cfg, true);
        let b = run(&cfg, true);
        assert_eq!(a, b, "same seed must reproduce the report byte for byte");
        let mut other = cfg.clone();
        other.seed = 8;
        assert_ne!(a, run(&other, true), "different seeds must differ");
    }

    #[test]
    fn per_token_oracle_matches_coalesced_bit_for_bit() {
        let mut cfg = quick_cfg(3, 80, 25.0, 17);
        cfg.followup = 0.5;
        cfg.queue_capacity = 4; // force some rejections into the trace
        let (coalesced, ev_c) = run_mode(&cfg, true, DecodeMode::Coalesced);
        let (per_token, ev_t) = run_mode(&cfg, true, DecodeMode::PerToken);
        assert_eq!(coalesced, per_token, "coalescing must not change the timeline");
        assert_eq!(coalesced.render(), per_token.render());
        assert!(ev_t > ev_c, "oracle must pay per-token events ({ev_t} vs {ev_c})");
    }

    #[test]
    fn coalesced_event_count_is_three_per_accepted_request() {
        let cfg = quick_cfg(2, 60, 12.0, 19);
        let (rep, events) = run_mode(&cfg, false, DecodeMode::Coalesced);
        // One Arrive per arrival; DecodeDone + Retire per accepted turn.
        let expect = rep.outcomes.len() as u64 + 2 * rep.accepted() as u64;
        assert_eq!(events, expect);
    }

    #[test]
    fn followups_share_devices_with_their_sessions() {
        let mut cfg = quick_cfg(4, 60, 10.0, 5);
        cfg.followup = 0.6;
        let rep = run(&cfg, true);
        let mut seen = std::collections::HashMap::new();
        let mut followups = 0;
        for o in rep.outcomes.iter().filter(|o| !o.rejected) {
            if let Some(prev) = seen.get(&o.session) {
                followups += 1;
                assert_eq!(o.device, *prev, "follow-up of session {} moved devices", o.session);
                assert!(o.context > o.input_tokens, "resident KV must extend the context");
            }
            seen.insert(o.session, o.device);
        }
        assert!(followups > 0, "trace produced no follow-up turns");
    }

    #[test]
    fn saturated_single_device_rejects_arrivals() {
        let mut cfg = quick_cfg(1, 80, 200.0, 9);
        cfg.queue_capacity = 4;
        cfg.output_tokens = LenRange::new(32, 64);
        let rep = run(&cfg, true);
        assert!(rep.rejected() > 0, "200 req/s into one bounded device must shed load");
        for o in rep.outcomes.iter().filter(|o| o.rejected) {
            assert_eq!(o.device, None);
            assert_eq!(o.output_tokens, 0);
        }
    }

    #[test]
    fn utilization_and_latency_sane() {
        let cfg = quick_cfg(4, 80, 10.0, 11);
        let rep = run(&cfg, true);
        for u in &rep.device_utilization {
            assert!((0.0..=1.0).contains(u), "utilization {u}");
        }
        let lat = rep.latency_summary();
        let ttft = rep.ttft_summary();
        assert!(lat.p50 > 0.0 && lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
        assert!(ttft.p50 > 0.0);
        assert_eq!(rep.device_jobs.iter().sum::<usize>(), rep.accepted());
    }

    #[test]
    fn empty_trace_reports_empty() {
        let mut cfg = quick_cfg(2, 1, 10.0, 1);
        cfg.requests = 0;
        let rep = run(&cfg, false);
        assert_eq!(rep.outcomes.len(), 0);
        assert_eq!(rep.makespan, SimTime::ZERO);
        assert!(rep.device_utilization.iter().all(|u| *u == 0.0));
    }

    #[test]
    fn round_robin_spreads_jobs_evenly() {
        let mut cfg = quick_cfg(4, 80, 6.0, 13);
        cfg.followup = 0.0; // fresh sessions only: pure policy routing
        let rep = run(&cfg, false);
        assert_eq!(rep.rejected(), 0);
        let min = rep.device_jobs.iter().min().unwrap();
        let max = rep.device_jobs.iter().max().unwrap();
        assert_eq!(rep.device_jobs.iter().sum::<usize>(), 80);
        assert!(max - min <= 1, "round-robin imbalance: {:?}", rep.device_jobs);
    }

    #[test]
    fn streamed_point_matches_materialized_sweep_point() {
        let cfg = quick_cfg(2, 50, 18.0, 23);
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let streamed =
            run_traffic_point(&sys, &model, &table, Box::new(LeastLoaded::new()), &cfg);
        let report =
            run_traffic_events(&sys, &model, &table, Box::new(LeastLoaded::new()), &cfg);
        assert_eq!(streamed, SweepPoint::of(&report), "streamed aggregates must be exact");
    }
}
