//! Outcome sinks: where the serving simulators put each finished
//! [`SimRequest`].
//!
//! The event backend produces one outcome per arrival; what the caller
//! wants to *keep* differs by use case. A single `serve-sim` run renders
//! a full [`PoolReport`][super::metrics::PoolReport] and needs every
//! outcome materialized; a rate sweep only needs one
//! [`SweepPoint`]-worth of aggregates per (policy, rate) pair, and
//! holding a million `SimRequest`s per point just to reduce them at the
//! end is what made long sweeps memory- and cache-hungry. The
//! [`OutcomeSink`] trait lets
//! [`ServingModel`][super::event_sim::ServingModel] fold outcomes as they
//! retire:
//!
//! * [`CollectSink`] — materialize everything (the report path).
//! * [`StreamingSink`] — incremental counts, token totals, makespan, and
//!   per-class SLO attainment, plus per-metric sample accumulators
//!   ([`Streaming`]: running count/mean/M2, one sorted flush for
//!   percentiles). The flush reduces each metric exactly as
//!   [`Summary::of`][crate::util::stats::Summary::of] would, so a
//!   streamed [`SweepPoint`] is **bit-identical** to one computed from a
//!   materialized report — asserted in `tests/perf_equivalence.rs`.

use super::device::FleetSummary;
use super::loadgen::SimRequest;
use super::metrics::WearSummary;
use crate::fault::FaultSummary;
use super::sweep::{ClassAttainment, SweepPoint};
use super::workload::SloTarget;
use crate::sim::SimTime;
use crate::util::stats::Streaming;

/// Consumes each finished (served or rejected) request of a serving
/// simulation, in retirement order.
pub trait OutcomeSink {
    fn record(&mut self, outcome: SimRequest);
}

/// Materializes every outcome — the sink behind full
/// [`PoolReport`][super::metrics::PoolReport]s.
#[derive(Debug, Clone, Default)]
pub struct CollectSink {
    pub outcomes: Vec<SimRequest>,
}

impl CollectSink {
    pub fn with_capacity(n: usize) -> CollectSink {
        CollectSink { outcomes: Vec::with_capacity(n) }
    }
}

impl OutcomeSink for CollectSink {
    fn record(&mut self, outcome: SimRequest) {
        self.outcomes.push(outcome);
    }
}

/// Per-class accumulator of the streaming sink: arrival/rejection/SLO
/// counts only — class percentiles are a report-path (materialized)
/// concern, the sweep needs attainment.
#[derive(Debug, Clone)]
struct ClassAcc {
    name: String,
    slo: SloTarget,
    arrivals: usize,
    met: usize,
}

/// Folds outcomes straight into the aggregates one [`SweepPoint`] needs,
/// without retaining any `SimRequest`. Per outcome it keeps three `f64`
/// samples at most (TTFT, latency — TPOT is not a sweep column) instead
/// of the full record, and per class only counters.
#[derive(Debug, Clone)]
pub struct StreamingSink {
    accepted: usize,
    rejected: usize,
    /// Output tokens across all outcomes (rejected contribute 0).
    tokens: usize,
    /// Latest accepted completion — the horizon throughput divides by.
    makespan: SimTime,
    ttft: Streaming,
    latency: Streaming,
    /// One entry per workload-mix class, in mix order; empty for
    /// single-class runs without a mix (matching
    /// [`class_reports`][super::metrics::PoolReport::class_reports]).
    classes: Vec<ClassAcc>,
}

impl StreamingSink {
    /// Build for a run. `classes` carries the workload mix's (name, SLO)
    /// pairs in mix order, or is empty for runs without a mix.
    pub fn new(classes: Vec<(String, SloTarget)>) -> StreamingSink {
        StreamingSink {
            accepted: 0,
            rejected: 0,
            tokens: 0,
            makespan: SimTime::ZERO,
            ttft: Streaming::new(),
            latency: Streaming::new(),
            classes: classes
                .into_iter()
                .map(|(name, slo)| ClassAcc { name, slo, arrivals: 0, met: 0 })
                .collect(),
        }
    }

    /// Latest accepted completion folded so far — the same horizon a
    /// materialized report computes, exposed so the caller can clip
    /// fault summaries to it before [`Self::finish`].
    pub fn makespan(&self) -> SimTime {
        self.makespan
    }

    /// Reduce to a sweep point. Bit-identical to
    /// `SweepPoint::of(&report)` over the same run's materialized report
    /// — including the fleet-priced columns, which both paths derive
    /// from the same token total and makespan through the same
    /// [`FleetSummary`] methods, and the wear and fault columns, which
    /// both paths fold from the same [`WearSummary`] / [`FaultSummary`].
    pub fn finish(
        self,
        policy: String,
        rate: f64,
        fleet: Option<FleetSummary>,
        wear: Option<WearSummary>,
        faults: Option<FaultSummary>,
    ) -> SweepPoint {
        let throughput = if self.makespan == SimTime::ZERO {
            0.0
        } else {
            self.tokens as f64 / self.makespan.secs()
        };
        let tokens = self.tokens as u64;
        let cost_per_mtok =
            fleet.as_ref().and_then(|f| f.cost_per_mtok(tokens, self.makespan.secs()));
        let energy_per_mtok = fleet.as_ref().and_then(|f| f.energy_per_mtok(tokens));
        let lat = self.latency.finish();
        SweepPoint {
            policy,
            rate,
            accepted: self.accepted,
            rejected: self.rejected,
            throughput,
            ttft_p95: self.ttft.finish().p95,
            latency_p50: lat.p50,
            latency_p95: lat.p95,
            latency_p99: lat.p99,
            cost_per_mtok,
            energy_per_mtok,
            wear_max_erases: wear.as_ref().map(|w| w.max_erases()),
            wear_total_erases: wear.as_ref().map(|w| w.total_erases()),
            wear_retirements: wear.as_ref().map(|w| w.retirements as u64),
            faults_availability: faults.as_ref().map(|f| f.availability),
            faults_failed: faults.as_ref().map(|f| f.failed_requests),
            faults_retries: faults.as_ref().map(|f| f.retries),
            faults_failovers: faults.as_ref().map(|f| f.failovers),
            faults_shed: faults.as_ref().map(|f| f.shed_brownout),
            faults_reprefill_tok: faults.as_ref().map(|f| f.re_prefill_tokens),
            faults_degraded_s: faults.as_ref().map(|f| f.degraded_s),
            class_attainment: self
                .classes
                .into_iter()
                .map(|c| ClassAttainment {
                    class: c.name,
                    attainment: if c.arrivals == 0 {
                        1.0
                    } else {
                        c.met as f64 / c.arrivals as f64
                    },
                })
                .collect(),
        }
    }
}

impl OutcomeSink for StreamingSink {
    fn record(&mut self, o: SimRequest) {
        if o.rejected {
            self.rejected += 1;
        } else {
            self.accepted += 1;
            self.makespan = self.makespan.max(o.completed);
            self.latency.push(o.latency().secs());
        }
        self.tokens += o.output_tokens;
        if let Some(t) = o.ttft() {
            self.ttft.push(t.secs());
        }
        if let Some(c) = self.classes.get_mut(o.class) {
            c.arrivals += 1;
            if o.meets_slo(c.slo) {
                c.met += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, class: usize, device: Option<usize>, tokens: usize) -> SimRequest {
        SimRequest {
            id,
            session: id,
            class,
            device,
            arrival: SimTime::ZERO,
            first_token: device.map(|_| SimTime::from_us(50.0)),
            completed: SimTime::from_us(50.0 + 10.0 * tokens as f64),
            input_tokens: 64,
            output_tokens: tokens,
            context: 64,
            rejected: device.is_none(),
            failed: false,
            followup: false,
            energy_j: 0.0,
        }
    }

    #[test]
    fn collect_sink_materializes_in_order() {
        let mut sink = CollectSink::with_capacity(2);
        sink.record(outcome(1, 0, Some(0), 4));
        sink.record(outcome(0, 0, None, 0));
        assert_eq!(sink.outcomes.len(), 2);
        assert_eq!(sink.outcomes[0].id, 1, "sinks preserve record order");
    }

    #[test]
    fn streaming_sink_counts_and_attainment() {
        let tight = SloTarget { ttft: 1e-9, tpot: 1e-9 }; // unattainable
        let mut sink = StreamingSink::new(vec![
            ("loose".to_string(), SloTarget::NONE),
            ("tight".to_string(), tight),
        ]);
        sink.record(outcome(0, 0, Some(0), 10)); // loose, served: attains
        sink.record(outcome(1, 1, Some(1), 10)); // tight, served: misses
        sink.record(outcome(2, 0, None, 0)); // loose, rejected: misses
        let p = sink.finish("rr".to_string(), 4.0, None, None, None);
        assert_eq!((p.accepted, p.rejected), (2, 1));
        assert!(p.throughput > 0.0);
        assert!(p.ttft_p95 > 0.0 && p.latency_p95 > 0.0);
        assert_eq!(p.class_attainment.len(), 2);
        assert!((p.class_attainment[0].attainment - 0.5).abs() < 1e-12);
        assert_eq!(p.class_attainment[1].attainment, 0.0);
    }

    #[test]
    fn streaming_sink_empty_run() {
        let p = StreamingSink::new(Vec::new()).finish("ll".to_string(), 2.0, None, None, None);
        assert_eq!((p.accepted, p.rejected), (0, 0));
        assert_eq!(p.throughput, 0.0);
        assert!(p.class_attainment.is_empty());
    }
}
