//! Serving request/response types.

use crate::sim::SimTime;

/// What the client asked for.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestKind {
    /// Summarize/prefill `input_tokens` of context (stays on the GPUs).
    Summarize { input_tokens: usize },
    /// Generate `output_tokens` after an `input_tokens` prompt
    /// (offloaded to the flash PIM device).
    Generate { input_tokens: usize, output_tokens: usize },
}

/// One serving request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub kind: RequestKind,
    /// Arrival time in the simulated trace.
    pub arrival: SimTime,
}

impl Request {
    pub fn summarize(id: u64, arrival: SimTime, input_tokens: usize) -> Request {
        Request { id, kind: RequestKind::Summarize { input_tokens }, arrival }
    }

    pub fn generate(id: u64, arrival: SimTime, input_tokens: usize, output_tokens: usize) -> Request {
        Request { id, kind: RequestKind::Generate { input_tokens, output_tokens }, arrival }
    }

    pub fn is_generate(&self) -> bool {
        matches!(self.kind, RequestKind::Generate { .. })
    }
}

/// Completion record.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestOutcome {
    pub id: u64,
    pub arrival: SimTime,
    pub first_token: Option<SimTime>,
    pub completed: SimTime,
    pub tokens_out: usize,
    /// Where it ran ("gpu" / "flash").
    pub executed_on: &'static str,
}

impl RequestOutcome {
    /// End-to-end latency.
    pub fn latency(&self) -> SimTime {
        self.completed - self.arrival
    }

    /// Time to first token (generation requests).
    pub fn ttft(&self) -> Option<SimTime> {
        self.first_token.map(|t| t - self.arrival)
    }

    /// Mean TPOT over the request.
    pub fn tpot(&self) -> Option<f64> {
        let first = self.first_token?;
        if self.tokens_out <= 1 {
            return None;
        }
        Some((self.completed - first).secs() / (self.tokens_out - 1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_metrics() {
        let o = RequestOutcome {
            id: 1,
            arrival: SimTime::from_us(100.0),
            first_token: Some(SimTime::from_us(300.0)),
            completed: SimTime::from_us(1300.0),
            tokens_out: 11,
            executed_on: "flash",
        };
        assert_eq!(o.latency(), SimTime::from_us(1200.0));
        assert_eq!(o.ttft(), Some(SimTime::from_us(200.0)));
        let tpot = o.tpot().unwrap();
        assert!((tpot - 100e-6 / 1.0).abs() < 1e-12); // 1 ms over 10 tokens
    }

    #[test]
    fn kind_predicates() {
        let r = Request::generate(1, SimTime::ZERO, 128, 32);
        assert!(r.is_generate());
        let s = Request::summarize(2, SimTime::ZERO, 128);
        assert!(!s.is_generate());
    }
}
