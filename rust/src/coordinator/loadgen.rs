//! Closed-loop traffic simulation for the device pool — the **direct
//! replay** backend: Poisson arrivals at a configurable rate,
//! prompt/output lengths drawn from [`crate::util::rng`] distributions,
//! device service time taken from an immutable precomputed
//! [`LatencyTable`] — so *simulated flash latency*, not mock wall-clock,
//! drives every reported number, and the exhaustive §V-A tiling search
//! behind it runs once per (model, system), not once per run or thread.
//!
//! The serving default is the event-driven backend
//! ([`super::event_sim::run_traffic_events`]), which expresses the same
//! model as explicit events on [`crate::sim::Engine`] and additionally
//! prices the prefill PCIe KV upload. This loop computes each request's
//! whole service inline at arrival time instead; it is kept as the
//! `serve-sim --threaded` cross-check path (its rate sweep fans out on
//! scoped threads) and samples arrivals through the same
//! `workload::ArrivalSampler` as the event backend — single-class configs and
//! multi-class [`WorkloadMix`] scenarios alike — so the two backends'
//! RNG streams stay in lockstep by construction and fresh-session traces
//! line up request for request.
//!
//! The loop models the full serving path per request: scheduler pick
//! ([`DeviceRouter`]: KV affinity first, then policy), bounded per-device
//! admission (arrivals beyond the queue capacity are rejected —
//! backpressure), SLC KV admission with idle-LRU eviction, the initial KV
//! write, and the per-token decode latency. Results aggregate into a
//! [`PoolReport`] (TTFT/TPOT/latency p50/p95/p99, per-device utilization).
//!
//! Session bookkeeping is heap/hash-based, so traces of 100k+ requests
//! run in seconds — the old per-arrival scans over every session ever
//! seen capped the simulator at toy request counts.

use super::device::{tier_estimates_direct, DeviceModel, FleetSpec, FleetSummary, Tier};
use super::metrics::{DeviceWearStats, PoolReport, WearSummary};
use super::router::{DeviceRouter, DeviceStatus, JobInfo, Scheduler};
use super::workload::{ArrivalSampler, SloTarget, WorkloadClass, WorkloadMix};
use crate::circuit::TechParams;
use crate::config::SystemConfig;
use crate::fault::{DownAction, FaultConfig, FleetFaults};
use crate::kv::wear::DeviceWear;
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use crate::sim::{Resource, SimTime};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// Uniform token-length distribution over `[lo, hi]` (inclusive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LenRange {
    pub lo: usize,
    pub hi: usize,
}

impl LenRange {
    pub fn new(lo: usize, hi: usize) -> LenRange {
        assert!(lo >= 1 && hi >= lo, "bad length range [{lo}, {hi}]");
        LenRange { lo, hi }
    }

    pub fn fixed(n: usize) -> LenRange {
        LenRange::new(n, n)
    }

    /// Draw one length. Exactly one RNG draw when the range is non-trivial
    /// (and none when `lo == hi`) — both serving backends rely on this to
    /// consume identical RNG streams.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        if self.lo == self.hi {
            self.lo
        } else {
            rng.range(self.lo, self.hi + 1)
        }
    }
}

/// Per-device write-wear budget for a wear-enabled serving run. When
/// attached to a [`TrafficConfig`], every accepted request charges its
/// KV writes (prompt admit + output append) against the assigned
/// device's erase budget through a [`DeviceWear`] meter; a device whose
/// budget exhausts mid-trace retires (drains its queue, re-homes its
/// sessions' KV affinity) and the next provisioned spare joins the
/// roster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WearConfig {
    /// P/E-cycle budget per erase block before a device retires.
    pub pe_budget: u64,
    /// Erase blocks the per-device wear leveler rotates over.
    pub blocks_per_device: usize,
    /// Spare devices provisioned beyond [`TrafficConfig::devices`],
    /// activated one at a time as worn devices retire.
    pub spares: usize,
}

impl WearConfig {
    /// Budget with the default block count and no spares.
    pub fn new(pe_budget: u64) -> WearConfig {
        WearConfig { pe_budget, blocks_per_device: 64, spares: 0 }
    }
}

/// One phase of an open-loop diurnal arrival schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPhase {
    /// Phase length in seconds of simulated time.
    pub duration_s: f64,
    /// Rate multiplier applied to [`TrafficConfig::rate`] while the
    /// phase is in force.
    pub rate_mul: f64,
}

/// Open-loop arrival-rate modulation layered on the Poisson sampler: the
/// schedule cycles through its phases by simulated clock time, scaling
/// the configured mean rate by each phase's multiplier (a Markov-
/// modulated Poisson process with a deterministic phase chain — the
/// diurnal shape production traffic has and a stationary lab load does
/// not). The modulation reuses the *same single uniform draw* per
/// arrival as the legacy sampler, so a schedule whose multipliers are
/// all `1.0` reproduces the legacy Poisson stream bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalProcess {
    pub phases: Vec<ArrivalPhase>,
}

impl ArrivalProcess {
    pub fn new(phases: Vec<ArrivalPhase>) -> Result<ArrivalProcess> {
        if phases.is_empty() {
            bail!("arrival process needs at least one phase");
        }
        for p in &phases {
            let good = |x: f64| x.is_finite() && x > 0.0;
            if !good(p.duration_s) || !good(p.rate_mul) {
                bail!(
                    "arrival phase needs positive duration and multiplier (got {}s x{})",
                    p.duration_s,
                    p.rate_mul
                );
            }
        }
        Ok(ArrivalProcess { phases })
    }

    /// Parse a `DURATION_S:MULT(,DURATION_S:MULT)*` schedule, e.g.
    /// `3600:0.5,3600:2.0` for alternating hour-long trough/peak phases.
    pub fn parse(spec: &str) -> Result<ArrivalProcess> {
        let mut phases = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let Some((dur, mul)) = part.split_once(':') else {
                bail!("bad arrival phase {part:?} (use DURATION_S:MULT, e.g. 3600:0.5)");
            };
            let duration_s: f64 = dur
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad phase duration {dur:?} in {spec:?}"))?;
            let rate_mul: f64 = mul
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad rate multiplier {mul:?} in {spec:?}"))?;
            phases.push(ArrivalPhase { duration_s, rate_mul });
        }
        ArrivalProcess::new(phases)
    }

    /// Total cycle length (seconds).
    pub fn cycle_s(&self) -> f64 {
        self.phases.iter().map(|p| p.duration_s).sum()
    }

    /// Rate multiplier in force at simulated time `clock_s`.
    pub fn multiplier_at(&self, clock_s: f64) -> f64 {
        let mut t = clock_s.rem_euclid(self.cycle_s());
        for p in &self.phases {
            if t < p.duration_s {
                return p.rate_mul;
            }
            t -= p.duration_s;
        }
        self.phases[self.phases.len() - 1].rate_mul
    }
}

/// Draw one open-loop inter-arrival gap (seconds) from the uniform
/// sample `u`: an exponential at the rate in force at simulated time
/// `clock`. All three arrival sites (both event-backend draws and the
/// direct loop) share this one helper so diurnal modulation cannot
/// drift between backends. With no arrival process — or one whose
/// multipliers are all `1.0` — the expression reduces bit-for-bit to
/// the legacy `-(1 - u).ln() / rate`.
pub(super) fn arrival_gap(cfg: &TrafficConfig, clock: f64, u: f64) -> f64 {
    let rate = match &cfg.arrival {
        Some(a) => cfg.rate * a.multiplier_at(clock),
        None => cfg.rate,
    };
    -(1.0 - u).ln() / rate
}

/// Traffic and pool configuration for one closed-loop run.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Number of flash-PIM devices in the pool.
    pub devices: usize,
    /// Mean Poisson arrival rate (requests/second).
    pub rate: f64,
    /// Total arrivals to generate.
    pub requests: usize,
    /// Prompt-length distribution (single-class runs; ignored when
    /// [`Self::workload`] is set — each class brings its own ranges).
    pub input_tokens: LenRange,
    /// Output-length distribution (single-class runs; see above).
    pub output_tokens: LenRange,
    /// Per-device bound on queued + running jobs; arrivals beyond it are
    /// rejected (backpressure).
    pub queue_capacity: usize,
    /// Probability that an arrival is a follow-up turn of a finished
    /// session (single-class runs; exercises KV affinity).
    pub followup: f64,
    pub seed: u64,
    /// Multi-class scenario ([`WorkloadMix`]): when set, per-arrival
    /// class sampling replaces the three scalar shape fields above, class
    /// identity rides each request into the report, and
    /// [`PoolReport::class_reports`][super::metrics::PoolReport::class_reports]
    /// gains per-class percentiles and SLO attainment.
    pub workload: Option<WorkloadMix>,
    /// Heterogeneous fleet composition (e.g. `4xflash+1xgpu`). When set,
    /// [`Self::devices`] must equal the spec's device count, each device
    /// is priced by its tier's [`DeviceModel`], and reports gain a
    /// [`FleetSummary`] (per-tier utilization, cost and energy per
    /// million tokens). `None` keeps the legacy all-flash pool —
    /// byte-identical behavior to pre-fleet versions.
    pub fleet: Option<FleetSpec>,
    /// Per-device P/E budgets, retirement + hot-swap, and wear columns in
    /// the report. `None` (the default) disables all wear accounting —
    /// wear-disabled runs stay byte-identical to pre-wear versions.
    pub wear: Option<WearConfig>,
    /// Open-loop diurnal/MMPP rate modulation. `None` (the default)
    /// keeps the stationary Poisson stream, byte-identically.
    pub arrival: Option<ArrivalProcess>,
    /// Deterministic fault injection — read-retry storms, hard device
    /// loss, and the retry/failover/brownout recovery policies
    /// (`serve-sim --faults`, see `docs/FAULTS.md`). `None` (the
    /// default) disables injection; fault-free runs stay byte-identical
    /// to pre-fault versions.
    pub faults: Option<FaultConfig>,
}

impl TrafficConfig {
    /// Sensible single-class defaults, delegating the traffic shape to
    /// the `chat` [`WorkloadClass`] preset — the default path and the
    /// workload path share one definition instead of silently diverging
    /// constants.
    pub fn default_for(devices: usize) -> TrafficConfig {
        let chat = WorkloadClass::chat();
        TrafficConfig {
            devices,
            rate: 8.0,
            requests: 1000,
            input_tokens: chat.input_tokens,
            output_tokens: chat.output_tokens,
            queue_capacity: 64,
            followup: chat.followup,
            seed: 42,
            workload: None,
            fleet: None,
            wear: None,
            arrival: None,
            faults: None,
        }
    }

    /// Pool slots the run actually provisions: the primary devices plus
    /// any wear or fault spares (one unified cold-spare pool — whichever
    /// mechanism retires a device activates the lowest-index spare).
    pub fn n_slots(&self) -> usize {
        self.devices
            + self.wear.as_ref().map_or(0, |w| w.spares)
            + self.faults.as_ref().map_or(0, |f| f.spares)
    }

    /// Largest output-length upper bound an arrival can draw — sizes the
    /// event budget of the event-driven backend.
    pub fn max_output_tokens(&self) -> usize {
        match &self.workload {
            Some(mix) => mix.max_output_tokens(),
            None => self.output_tokens.hi,
        }
    }
}

/// Per-request record produced by the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct SimRequest {
    pub id: u64,
    pub session: u64,
    /// Workload-class index in the run's [`WorkloadMix`] (0 for
    /// single-class runs).
    pub class: usize,
    /// Device the request ran on (`None` when rejected).
    pub device: Option<usize>,
    pub arrival: SimTime,
    pub first_token: Option<SimTime>,
    pub completed: SimTime,
    pub input_tokens: usize,
    pub output_tokens: usize,
    /// Context length at the first decode step (larger than `input_tokens`
    /// on follow-up turns whose KV stayed resident).
    pub context: usize,
    pub rejected: bool,
    /// Permanently failed by fault injection: the request was in flight
    /// on a device that hard-failed and its retry budget ran out. A
    /// subset of `rejected`, so `accepted + rejected == offered` holds
    /// with and without faults.
    pub failed: bool,
    pub followup: bool,
    /// Decode energy of the turn (J) — a pure function of the assigned
    /// device's tier and the turn's shape (zero for rejections), so it is
    /// identical across simulation backends.
    pub energy_j: f64,
}

impl SimRequest {
    /// End-to-end latency (accepted requests).
    pub fn latency(&self) -> SimTime {
        self.completed - self.arrival
    }

    /// Time to first token, including queueing and the initial KV write.
    pub fn ttft(&self) -> Option<SimTime> {
        self.first_token.map(|t| t - self.arrival)
    }

    /// Mean time per output token after the first.
    pub fn tpot(&self) -> Option<f64> {
        let first = self.first_token?;
        if self.output_tokens <= 1 {
            return None;
        }
        Some((self.completed - first).secs() / (self.output_tokens - 1) as f64)
    }

    /// Did this outcome meet `slo`? Rejections always miss (the client
    /// got nothing); served requests need TTFT and TPOT both within
    /// target (TPOT vacuously for single-token outputs). One definition
    /// shared by [`PoolReport::class_reports`][super::metrics::PoolReport::class_reports]
    /// and the streaming sweep sink, so attainment cannot drift between
    /// the materialized and streamed metric paths.
    pub fn meets_slo(&self, slo: SloTarget) -> bool {
        match self.ttft() {
            Some(ttft) => !self.rejected && slo.met(ttft.secs(), self.tpot()),
            None => false,
        }
    }
}

#[derive(Debug, Clone, Default)]
struct DeviceState {
    res: Resource,
    /// Completion times of assigned jobs, FIFO (monotone — one server).
    inflight: VecDeque<SimTime>,
}

impl DeviceState {
    fn depth(&mut self, now: SimTime) -> usize {
        while let Some(front) = self.inflight.front() {
            if *front <= now {
                self.inflight.pop_front();
            } else {
                break;
            }
        }
        self.inflight.len()
    }
}

/// Which role a pool slot currently plays in a wear-enabled run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    /// In the roster: receives traffic and wear charges.
    Active,
    /// Provisioned but idle; joins the roster when a device retires.
    Spare,
    /// Budget exhausted: queue drained, no new traffic.
    Retired,
}

/// Fleet-wide wear state shared by both serving backends: one
/// [`DeviceWear`] meter per pool slot (primaries then spares), slot
/// roles, and the retirement counter. Charging and retirement decisions
/// live here so the two backends cannot drift.
#[derive(Debug)]
pub(super) struct FleetWear {
    cfg: WearConfig,
    pub devices: Vec<DeviceWear>,
    state: Vec<SlotState>,
    /// Primary roster size (slots `>= primary` were provisioned spare).
    primary: usize,
    pub retirements: usize,
}

impl FleetWear {
    /// Build meters for `models` (primaries first, then spares): each
    /// slot's erase blocks split its KV capacity evenly.
    pub fn new(cfg: &WearConfig, models: &[DeviceModel], primary: usize) -> FleetWear {
        let devices = models
            .iter()
            .map(|m| {
                let block_bytes = m.kv_capacity() / cfg.blocks_per_device.max(1) as u64;
                DeviceWear::new(cfg.blocks_per_device, cfg.pe_budget, block_bytes)
            })
            .collect::<Vec<_>>();
        let state = (0..models.len())
            .map(|i| if i < primary { SlotState::Active } else { SlotState::Spare })
            .collect();
        FleetWear { cfg: *cfg, devices, state, primary, retirements: 0 }
    }

    /// Is this slot in the roster (schedulable for fresh sessions)?
    pub fn eligible(&self, dev: usize) -> bool {
        self.state[dev] == SlotState::Active
    }

    /// Total erase budget of one slot (blocks × per-block P/E).
    pub fn erase_capacity(&self) -> u64 {
        self.cfg.blocks_per_device as u64 * self.cfg.pe_budget
    }

    /// Charge `tokens` KV token writes totalling `bytes` to `dev`;
    /// returns `true` when the charge newly exhausted the device.
    pub fn charge(&mut self, dev: usize, tokens: u64, bytes: u64, now: SimTime) -> bool {
        self.devices[dev].charge(tokens, bytes, now) && self.state[dev] == SlotState::Active
    }

    /// Retire `dev` and activate the next provisioned spare, if any.
    pub fn retire(&mut self, dev: usize, now: SimTime) -> Option<usize> {
        self.state[dev] = SlotState::Retired;
        self.devices[dev].retire(now);
        self.retirements += 1;
        let spare = self.state.iter().position(|s| *s == SlotState::Spare)?;
        self.state[spare] = SlotState::Active;
        Some(spare)
    }

    /// A hard fault dropped `dev`: take it out of the roster without
    /// counting a wear retirement or consuming a spare — the fault path
    /// activates its replacement explicitly via [`Self::activate`].
    pub fn fault_retire(&mut self, dev: usize, now: SimTime) {
        self.state[dev] = SlotState::Retired;
        self.devices[dev].retire(now);
    }

    /// Promote a provisioned spare into the roster (fault-path spare
    /// activation; a no-op unless the slot is still a dormant spare).
    pub fn activate(&mut self, dev: usize) {
        if self.state[dev] == SlotState::Spare {
            self.state[dev] = SlotState::Active;
        }
    }

    /// Fold the meters into the report-facing rollup.
    pub fn summary(&self) -> WearSummary {
        WearSummary {
            pe_budget: self.cfg.pe_budget,
            blocks_per_device: self.cfg.blocks_per_device,
            spares: self.cfg.spares,
            retirements: self.retirements,
            devices: self
                .devices
                .iter()
                .enumerate()
                .map(|(i, d)| DeviceWearStats {
                    programs: d.programs,
                    bytes_written: d.bytes_written,
                    erases: d.erases(),
                    evictions: d.evictions,
                    block_bytes: d.block_bytes,
                    retired_at_s: d.retired_at.map(|t| t.secs()),
                    spare: i >= self.primary,
                })
                .collect(),
        }
    }
}

/// Re-home every session pinned to `dev`: release its resident KV and
/// clear its placement, so follow-up turns re-enter the scheduler as
/// fresh sessions on the surviving roster. Queued and in-flight requests
/// on `dev` are untouched — the queue drains at its own pace; only
/// *future* affinity moves.
pub(super) fn rehome_sessions(router: &mut DeviceRouter, dev: usize) {
    let mut sessions = router.sessions_on(dev);
    // Deterministic order (sessions_on iterates a HashMap).
    sessions.sort_unstable();
    for s in sessions {
        let _ = router.evict(s);
    }
}

/// Run a closed-loop Poisson trace against a simulated device pool,
/// building the per-token latency table internally. Deterministic for a
/// given config. Prefer [`run_traffic_with_table`] when running several
/// configurations (pool sizes, policies, rate sweeps): the table builds
/// once and is shared.
pub fn run_traffic(
    sys: &SystemConfig,
    model: &ModelShape,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
) -> PoolReport {
    let table = LatencyTable::build(sys, &TechParams::default(), model.clone());
    run_traffic_with_table(sys, model, &table, policy, cfg)
}

/// Run a closed-loop Poisson trace using a prebuilt immutable
/// [`LatencyTable`] (`&self` queries only — share one table across
/// threads via `Arc`). Deterministic for a given config.
pub fn run_traffic_with_table(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    policy: Box<dyn Scheduler + Send>,
    cfg: &TrafficConfig,
) -> PoolReport {
    assert!(cfg.devices > 0, "pool needs at least one device");
    assert!(cfg.rate > 0.0, "arrival rate must be positive");
    assert!(cfg.queue_capacity > 0, "queue capacity must be at least 1");
    assert_eq!(table.model_name(), model.name, "latency table built for a different model");
    assert_eq!(table.system_name(), sys.name, "latency table built for a different system");
    let policy_name = policy.name().to_string();
    let models = match &cfg.fleet {
        Some(spec) => {
            assert_eq!(
                spec.n_devices(),
                cfg.devices,
                "fleet spec {} sizes {} devices but cfg.devices = {}",
                spec.name(),
                spec.n_devices(),
                cfg.devices
            );
            DeviceModel::fleet(spec, sys, model, table)
        }
        None => (0..cfg.devices).map(|_| DeviceModel::flash(sys, model, table)).collect(),
    };
    let mut models = models;
    // Wear and fault spares are flash slots (flash is the tier that
    // wears out and faults), provisioned up front and activated as
    // devices retire or hard-fail.
    for _ in cfg.devices..cfg.n_slots() {
        models.push(DeviceModel::flash(sys, model, table));
    }
    let mut router = match &cfg.fleet {
        Some(_) => DeviceRouter::with_fleet(&models, policy),
        None => DeviceRouter::new(cfg.n_slots(), sys, model, policy),
    };
    let mut wear = cfg.wear.as_ref().map(|w| FleetWear::new(w, &models, cfg.devices));
    let mut faults = cfg.faults.as_ref().map(|f| {
        let flash: Vec<bool> = models.iter().map(|m| m.tier() == Tier::Flash).collect();
        let fleet = FleetFaults::new(f, cfg.seed, &flash, cfg.devices);
        let mut fs = DirectFaultState {
            fleet,
            heap: BinaryHeap::new(),
            seq: 0,
            jobs: HashMap::new(),
            attempts: HashMap::new(),
            on_device: vec![Vec::new(); cfg.n_slots()],
        };
        for (at, slot) in fs.fleet.down_events() {
            fs.push(at, EV_DOWN, slot as u64);
        }
        fs
    });
    let mut rng = Rng::new(cfg.seed);
    let mut sampler = ArrivalSampler::new(cfg);
    let mut devices: Vec<DeviceState> = vec![DeviceState::default(); cfg.n_slots()];
    // Latest-turn completion per session ever scheduled.
    let mut completion: HashMap<u64, SimTime> = HashMap::new();
    // Sessions whose latest turn is still running, keyed by completion
    // (class rides along for the per-class idle lists); drained into the
    // sampler's idle sets as the arrival clock passes them. Constant-ish
    // per-arrival cost — the old design re-scanned every session ever
    // seen on each arrival, which capped traces at toy sizes.
    let mut busy: BinaryHeap<Reverse<(SimTime, u64, usize)>> = BinaryHeap::new();
    let mut outcomes: Vec<SimRequest> = Vec::with_capacity(cfg.requests);
    let mut energy_total = 0.0f64;
    let mut clock = 0.0f64;

    for id in 0..cfg.requests as u64 {
        let u = rng.f64();
        clock += arrival_gap(cfg, clock, u); // exponential gap
        let now = SimTime::from_secs(clock);
        // Fault events (device loss, retries) that precede this arrival
        // fire first — the event backend gets the same interleaving from
        // its time-ordered engine queue.
        if let Some(fs) = faults.as_mut() {
            drain_fault_events(
                Some(now),
                fs,
                cfg,
                &models,
                &sampler,
                &mut router,
                &mut devices,
                &mut wear,
                &mut completion,
                &mut busy,
                &mut outcomes,
                &mut energy_total,
            );
        }
        while let Some(Reverse((done, s, c))) = busy.peek().copied() {
            if done > now {
                break;
            }
            busy.pop();
            // Fault victims' completions are revoked: release the
            // session only if its latest turn still matches this entry.
            if completion.get(&s) == Some(&done) {
                sampler.release(s, c);
            }
        }

        // Follow-up turns reuse a finished session of the same class.
        let arr = sampler.sample(&mut rng);
        let (session, class, reuse) = (arr.session, arr.class, arr.followup);
        let (l_in, l_out) = (arr.input_tokens, arr.output_tokens);

        // Brownout shedding: while surviving capacity sits below the
        // configured fraction of the nominal roster, only the
        // highest-priority class (class 0) is admitted. Retries are
        // exempt — they re-enter via the fault event path above.
        if let Some(fs) = faults.as_mut() {
            if class > 0 && fs.fleet.brownout_active() {
                fs.fleet.shed_brownout += 1;
                if reuse {
                    sampler.release(session, class);
                }
                outcomes.push(SimRequest {
                    id,
                    session,
                    class,
                    device: None,
                    arrival: now,
                    first_token: None,
                    completed: now,
                    input_tokens: l_in,
                    output_tokens: 0,
                    context: 0,
                    rejected: true,
                    failed: false,
                    followup: reuse,
                    energy_j: 0.0,
                });
                continue;
            }
        }

        let status: Vec<DeviceStatus> = devices
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| {
                let wear_ok = match &wear {
                    Some(w) => w.eligible(*i),
                    None => true,
                };
                let fault_ok = match &faults {
                    Some(f) => f.fleet.schedulable(*i),
                    None => true,
                };
                wear_ok && fault_ok
            })
            .map(|(i, d)| DeviceStatus {
                device: i,
                queue_depth: d.depth(now),
                est_wait: d.res.free_at().saturating_sub(now),
                kv_used: router.kv(i).used(),
                kv_capacity: router.kv(i).capacity,
                tier: models[i].tier(),
                wear_used: wear.as_ref().map_or(0, |w| w.devices[i].erases()),
                wear_budget: wear.as_ref().map_or(0, |w| w.erase_capacity()),
            })
            .collect();
        // Graceful end of fleet life: every device retired and no spare
        // left. Shed the arrival instead of panicking in the scheduler.
        if status.is_empty() {
            if reuse {
                sampler.release(session, class);
            }
            router.forget(session);
            outcomes.push(SimRequest {
                id,
                session,
                class,
                device: None,
                arrival: now,
                first_token: None,
                completed: now,
                input_tokens: l_in,
                output_tokens: 0,
                context: 0,
                rejected: true,
                failed: false,
                followup: reuse,
                energy_j: 0.0,
            });
            continue;
        }
        // Prefill estimates per tier for a fresh session (the policy only
        // runs for those — follow-ups are pinned by KV affinity). This
        // backend's flash estimate does not price the PCIe upload, so
        // neither does its pricing below.
        let (est_flash, est_gpu) = tier_estimates_direct(&models, l_in);
        let job = JobInfo {
            est_prefill: est_flash,
            est_prefill_gpu: est_gpu,
            prompt_tokens: l_in,
            ttft_target: sampler.classes()[class].slo.ttft,
        };
        let dev = router.assign(session, &status, &job);

        let reject = |router: &mut DeviceRouter,
                      sampler: &mut ArrivalSampler,
                      outcomes: &mut Vec<SimRequest>| {
            if reuse {
                sampler.release(session, class); // stays follow-up-eligible
            }
            if router.kv(dev).context_len(session).is_none() {
                router.forget(session); // placement without resident KV
            }
            outcomes.push(SimRequest {
                id,
                session,
                class,
                device: None,
                arrival: now,
                first_token: None,
                completed: now,
                input_tokens: l_in,
                output_tokens: 0,
                context: 0,
                rejected: true,
                failed: false,
                followup: reuse,
                energy_j: 0.0,
            });
        };

        // Bounded admission: the picked device's queue may be full. The
        // status vector excludes retired slots, so look the device up by
        // id rather than by index.
        let depth = status.iter().find(|s| s.device == dev).map(|s| s.queue_depth);
        let queue_full = match depth {
            Some(d) => d >= cfg.queue_capacity,
            None => true, // assigned slot left the roster: shed the arrival
        };
        if queue_full {
            reject(&mut router, &mut sampler, &mut outcomes);
            continue;
        }

        // SLC KV admission, evicting idle resident sessions (oldest first)
        // when the region is full.
        let per_token = router.kv(dev).per_token;
        let resident = router.kv(dev).context_len(session);
        let needed = (l_in + l_out) as u64 * per_token;
        if router.kv(dev).used() + needed > router.kv(dev).capacity {
            let before = router.kv(dev).active_sequences();
            evict_idle(&mut router, dev, &completion, now, session, needed);
            if let Some(w) = wear.as_mut() {
                for _ in router.kv(dev).active_sequences()..before {
                    w.devices[dev].note_eviction();
                }
            }
        }
        if router.kv(dev).used() + needed > router.kv(dev).capacity {
            reject(&mut router, &mut sampler, &mut outcomes);
            continue;
        }
        match resident {
            // Fresh (or evicted-and-returning) session: admit the prompt.
            None => {
                router.kv_mut(dev).admit(session, l_in).expect("admission after space check");
            }
            // Follow-up with resident KV: append the new prompt tokens.
            Some(_) => {
                router.kv_mut(dev).append_n(session, l_in).expect("append after space check");
            }
        }
        let l_ctx0 = resident.unwrap_or(0) + l_in;

        // Service time per the assigned device's tier: its prefill cost
        // (flash: initial SLC write of the new prompt KV; GPU: roofline
        // prefill), then the per-token decode latency (O(1) per step).
        let m = &models[dev];
        let mut service = m.prefill_cost_direct(l_in);
        let mut first_offset = SimTime::ZERO;
        for step in 0..l_out {
            service += m.step_time(l_ctx0 + step);
            if step == 0 {
                first_offset = service;
            }
        }
        router.kv_mut(dev).append_n(session, l_out).expect("append after space check");
        // Wear: the turn wrote `needed` KV bytes ((l_in + l_out) tokens)
        // to the device. GPU slots hold KV in DRAM and never wear.
        if let Some(w) = wear.as_mut() {
            if models[dev].tier() == Tier::Flash
                && w.charge(dev, (l_in + l_out) as u64, needed, now)
            {
                rehome_sessions(&mut router, dev);
                let activated = w.retire(dev, now);
                if let Some(fs) = faults.as_mut() {
                    fs.fleet.on_wear_retire(dev, activated);
                }
            }
        }
        let (first, completed) = match faults.as_mut() {
            None => {
                let start = devices[dev].res.acquire(now, service);
                (start + first_offset, start + service)
            }
            Some(fs) => {
                // Storm dilation: the wall-clock service stretches
                // through the device's fault timeline from its predicted
                // start instant. Dilation is compositional, so the first
                // token and the completion price from the same start.
                let begin = devices[dev].res.free_at().max(now);
                let completed = fs.fleet.dilate(dev, begin, service);
                let _started = devices[dev].res.acquire(now, completed - begin);
                debug_assert_eq!(_started, begin);
                fs.on_device[dev].push(outcomes.len());
                (fs.fleet.dilate(dev, begin, first_offset), completed)
            }
        };
        devices[dev].inflight.push_back(completed);
        completion.insert(session, completed);
        busy.push(Reverse((completed, session, class)));
        let energy = m.decode_energy(l_ctx0, l_out);
        energy_total += energy;
        outcomes.push(SimRequest {
            id,
            session,
            class,
            device: Some(dev),
            arrival: now,
            first_token: Some(first),
            completed,
            input_tokens: l_in,
            output_tokens: l_out,
            context: l_ctx0,
            rejected: false,
            failed: false,
            followup: reuse,
            energy_j: energy,
        });
    }
    // Fault events past the last arrival (late scripted failures, tail
    // retries) still fire so the two backends agree on the full fault
    // timeline.
    if let Some(fs) = faults.as_mut() {
        drain_fault_events(
            None,
            fs,
            cfg,
            &models,
            &sampler,
            &mut router,
            &mut devices,
            &mut wear,
            &mut completion,
            &mut busy,
            &mut outcomes,
            &mut energy_total,
        );
    }

    let makespan =
        outcomes.iter().filter(|o| !o.rejected).map(|o| o.completed).max().unwrap_or(SimTime::ZERO);
    let device_utilization =
        devices.iter().map(|d| d.res.utilization(makespan)).collect::<Vec<_>>();
    let device_jobs = devices.iter().map(|d| d.res.jobs() as usize).collect::<Vec<_>>();
    let fleet = cfg
        .fleet
        .as_ref()
        .map(|spec| FleetSummary::of(spec, &models[..cfg.devices], energy_total));
    PoolReport {
        backend: "direct",
        policy: policy_name,
        devices: cfg.devices,
        offered_rate: cfg.rate,
        workload: cfg.workload.clone(),
        outcomes,
        makespan,
        device_utilization,
        device_jobs,
        fleet,
        wear: wear.map(|w| w.summary()),
        faults: faults.map(|mut fs| fs.fleet.summary(makespan)),
    }
}

/// Evict idle resident sessions on `dev` (latest turn finished, not the
/// current session), oldest completion first, until `needed` bytes fit.
fn evict_idle(
    router: &mut DeviceRouter,
    dev: usize,
    completion: &HashMap<u64, SimTime>,
    now: SimTime,
    keep: u64,
    needed: u64,
) {
    let idle: Vec<(SimTime, u64)> = router
        .sessions_on(dev)
        .into_iter()
        .filter(|s| *s != keep)
        .filter_map(|s| {
            completion.get(&s).and_then(|done| if *done <= now { Some((*done, s)) } else { None })
        })
        .collect();
    evict_oldest_idle(router, dev, idle, needed);
}

/// Shared eviction core for both serving backends: evict `candidates`
/// (idle sessions resident on `dev`, tagged with their completion time)
/// oldest first until `needed` bytes fit — plus a 1/64-capacity
/// overshoot: under steady overload, freeing only `needed` would
/// re-trigger the candidate scan on the very next arrival, so the batch
/// amortizes it across many arrivals. One implementation keeps the two
/// backends' eviction policies in lockstep by construction.
pub(super) fn evict_oldest_idle(
    router: &mut DeviceRouter,
    dev: usize,
    mut candidates: Vec<(SimTime, u64)>,
    needed: u64,
) {
    let capacity = router.kv(dev).capacity;
    let target = needed.max(capacity / 64).min(capacity);
    // Sorted order (not HashMap iteration order) keeps eviction — and the
    // whole trace — deterministic for a given seed.
    candidates.sort_unstable();
    for (_, s) in candidates {
        if router.kv(dev).used() + target <= capacity {
            break;
        }
        let _ = router.evict(s);
    }
}

/// Fault-event kinds on the direct backend's pending heap.
const EV_DOWN: u8 = 0;
const EV_RETRY: u8 = 1;

/// One pending retry on the direct backend: which outcome record to
/// overwrite and the re-admission shape of the attempt.
#[derive(Debug, Clone)]
struct DirectRetry {
    /// Index of the victim's outcome record — overwritten in place so
    /// the trace keeps exactly one record per offered request.
    idx: usize,
    session: u64,
    class: usize,
    arrival: SimTime,
    /// Tokens the attempt must re-prefill: the victim's full context
    /// (its flash-resident KV died with the device).
    l_in: usize,
    l_out: usize,
    followup: bool,
    /// Attempt number this retry will execute (1-based).
    attempt: u32,
}

/// Direct-backend fault machinery: the fleet fault state plus a pending
/// Down/Retry event heap drained against the arrival clock, so fault
/// handling interleaves with arrivals in time order (the event backend
/// gets the same interleaving from its engine queue).
struct DirectFaultState {
    fleet: FleetFaults,
    /// Pending events ordered by (time, seq): [`EV_DOWN`] carries a
    /// slot index, [`EV_RETRY`] a request id.
    heap: BinaryHeap<Reverse<(SimTime, u64, u8, u64)>>,
    seq: u64,
    jobs: HashMap<u64, DirectRetry>,
    /// Attempt number of the last successful failover per request id —
    /// a second device loss resumes the budget, not restarts it.
    attempts: HashMap<u64, u32>,
    /// Accepted-outcome indices per slot (victim lookup on device loss).
    on_device: Vec<Vec<usize>>,
}

impl DirectFaultState {
    fn push(&mut self, at: SimTime, kind: u8, payload: u64) {
        self.heap.push(Reverse((at, self.seq, kind, payload)));
        self.seq += 1;
    }

    /// Attempt `job.attempt` just failed (0 = the original admission):
    /// schedule the next attempt after exponential backoff, or exhaust
    /// the budget and permanently fail the request, overwriting its
    /// outcome record in place.
    fn retry_or_fail(&mut self, id: u64, now: SimTime, mut job: DirectRetry, outcomes: &mut [SimRequest]) {
        let next = job.attempt + 1;
        if next > self.fleet.retry_budget() {
            self.fleet.failed_requests += 1;
            outcomes[job.idx] = SimRequest {
                id,
                session: job.session,
                class: job.class,
                device: None,
                arrival: job.arrival,
                first_token: None,
                completed: now,
                input_tokens: job.l_in,
                output_tokens: 0,
                context: 0,
                rejected: true,
                failed: true,
                followup: job.followup,
                energy_j: 0.0,
            };
        } else {
            self.fleet.retries += 1;
            let at = now + self.fleet.backoff(next);
            job.attempt = next;
            self.jobs.insert(id, job);
            self.push(at, EV_RETRY, id);
        }
    }
}

/// Drain pending fault events with time `<= until` (all of them when
/// `until` is `None`).
#[allow(clippy::too_many_arguments)]
fn drain_fault_events(
    until: Option<SimTime>,
    fs: &mut DirectFaultState,
    cfg: &TrafficConfig,
    models: &[DeviceModel],
    sampler: &ArrivalSampler,
    router: &mut DeviceRouter,
    devices: &mut [DeviceState],
    wear: &mut Option<FleetWear>,
    completion: &mut HashMap<u64, SimTime>,
    busy: &mut BinaryHeap<Reverse<(SimTime, u64, usize)>>,
    outcomes: &mut Vec<SimRequest>,
    energy_total: &mut f64,
) {
    while let Some(&Reverse((t, _, kind, payload))) = fs.heap.peek() {
        if matches!(until, Some(limit) if t > limit) {
            break;
        }
        fs.heap.pop();
        if kind == EV_DOWN {
            device_down(payload as usize, t, fs, router, wear, completion, outcomes, energy_total);
        } else {
            run_retry(
                payload,
                t,
                fs,
                cfg,
                models,
                sampler,
                router,
                devices,
                wear,
                completion,
                busy,
                outcomes,
                energy_total,
            );
        }
    }
}

/// A device's deadline timer fired at `t`: drop it from the pool,
/// activate a spare (no drain window), lose its in-flight work and
/// flash-resident KV, and route every victim into the retry/fail path.
#[allow(clippy::too_many_arguments)]
fn device_down(
    slot: usize,
    t: SimTime,
    fs: &mut DirectFaultState,
    router: &mut DeviceRouter,
    wear: &mut Option<FleetWear>,
    completion: &mut HashMap<u64, SimTime>,
    outcomes: &mut Vec<SimRequest>,
    energy_total: &mut f64,
) {
    let DownAction::Fail { activated } = fs.fleet.on_down(slot, t) else {
        return;
    };
    if let Some(w) = wear.as_mut() {
        w.fault_retire(slot, t);
        if let Some(s) = activated {
            w.activate(s);
        }
    }
    // The device's flash-resident KV is gone: every session homed here
    // re-enters the scheduler as a fresh session on the survivors.
    rehome_sessions(router, slot);
    // Victims: accepted requests still finishing after t. Their outcome
    // records are overwritten by the retry/fail path. (The slot's
    // Resource keeps the reserved time, so direct-backend utilization
    // counts the work the failure wasted.)
    let records = std::mem::take(&mut fs.on_device[slot]);
    for idx in records {
        let o = &outcomes[idx];
        if o.rejected || o.completed <= t {
            fs.on_device[slot].push(idx);
            continue;
        }
        *energy_total -= o.energy_j;
        if completion.get(&o.session) == Some(&o.completed) {
            completion.remove(&o.session);
        }
        let attempt = fs.attempts.get(&o.id).copied().unwrap_or(0);
        let job = DirectRetry {
            idx,
            session: o.session,
            class: o.class,
            arrival: o.arrival,
            l_in: o.context,
            l_out: o.output_tokens,
            followup: o.followup,
            attempt,
        };
        let id = o.id;
        fs.retry_or_fail(id, t, job, outcomes);
    }
}

/// Execute retry attempt `job.attempt` for request `id` at `t`: re-admit
/// the session on the surviving roster, charging full re-prefill latency
/// and wear (its KV was lost). Placement failure burns another attempt
/// or exhausts the budget.
#[allow(clippy::too_many_arguments)]
fn run_retry(
    id: u64,
    t: SimTime,
    fs: &mut DirectFaultState,
    cfg: &TrafficConfig,
    models: &[DeviceModel],
    sampler: &ArrivalSampler,
    router: &mut DeviceRouter,
    devices: &mut [DeviceState],
    wear: &mut Option<FleetWear>,
    completion: &mut HashMap<u64, SimTime>,
    busy: &mut BinaryHeap<Reverse<(SimTime, u64, usize)>>,
    outcomes: &mut Vec<SimRequest>,
    energy_total: &mut f64,
) {
    let Some(job) = fs.jobs.remove(&id) else {
        return;
    };
    let (session, l_in, l_out) = (job.session, job.l_in, job.l_out);
    let status: Vec<DeviceStatus> = devices
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| {
            fs.fleet.schedulable(*i)
                && match &wear {
                    Some(w) => w.eligible(*i),
                    None => true,
                }
        })
        .map(|(i, d)| DeviceStatus {
            device: i,
            queue_depth: d.depth(t),
            est_wait: d.res.free_at().saturating_sub(t),
            kv_used: router.kv(i).used(),
            kv_capacity: router.kv(i).capacity,
            tier: models[i].tier(),
            wear_used: wear.as_ref().map_or(0, |w| w.devices[i].erases()),
            wear_budget: wear.as_ref().map_or(0, |w| w.erase_capacity()),
        })
        .collect();
    if status.is_empty() {
        fs.retry_or_fail(id, t, job, outcomes);
        return;
    }
    let (est_flash, est_gpu) = tier_estimates_direct(models, l_in);
    let info = JobInfo {
        est_prefill: est_flash,
        est_prefill_gpu: est_gpu,
        prompt_tokens: l_in,
        ttft_target: sampler.classes()[job.class].slo.ttft,
    };
    let dev = router.assign(session, &status, &info);
    let depth = status.iter().find(|s| s.device == dev).map(|s| s.queue_depth);
    let queue_full = match depth {
        Some(d) => d >= cfg.queue_capacity,
        None => true,
    };
    let per_token = router.kv(dev).per_token;
    let needed = (l_in + l_out) as u64 * per_token;
    if !queue_full && router.kv(dev).used() + needed > router.kv(dev).capacity {
        let before = router.kv(dev).active_sequences();
        evict_idle(router, dev, completion, t, session, needed);
        if let Some(w) = wear.as_mut() {
            for _ in router.kv(dev).active_sequences()..before {
                w.devices[dev].note_eviction();
            }
        }
    }
    if queue_full || router.kv(dev).used() + needed > router.kv(dev).capacity {
        if router.kv(dev).context_len(session).is_none() {
            router.forget(session);
        }
        fs.retry_or_fail(id, t, job, outcomes);
        return;
    }
    let resident = router.kv(dev).context_len(session);
    match resident {
        None => router.kv_mut(dev).admit(session, l_in).expect("admission after space check"),
        Some(_) => router.kv_mut(dev).append_n(session, l_in).expect("append after space check"),
    }
    let ctx0 = resident.unwrap_or(0) + l_in;
    let m = &models[dev];
    let mut service = m.prefill_cost_direct(l_in);
    let mut first_offset = SimTime::ZERO;
    for step in 0..l_out {
        service += m.step_time(ctx0 + step);
        if step == 0 {
            first_offset = service;
        }
    }
    router.kv_mut(dev).append_n(session, l_out).expect("append after space check");
    if let Some(w) = wear.as_mut() {
        if models[dev].tier() == Tier::Flash && w.charge(dev, (l_in + l_out) as u64, needed, t) {
            rehome_sessions(router, dev);
            let activated = w.retire(dev, t);
            fs.fleet.on_wear_retire(dev, activated);
        }
    }
    let begin = devices[dev].res.free_at().max(t);
    let completed = fs.fleet.dilate(dev, begin, service);
    let _started = devices[dev].res.acquire(t, completed - begin);
    debug_assert_eq!(_started, begin);
    let first = fs.fleet.dilate(dev, begin, first_offset);
    devices[dev].inflight.push_back(completed);
    completion.insert(session, completed);
    busy.push(Reverse((completed, session, job.class)));
    let energy = m.decode_energy(ctx0, l_out);
    *energy_total += energy;
    fs.on_device[dev].push(job.idx);
    fs.attempts.insert(id, job.attempt);
    fs.fleet.failovers += 1;
    fs.fleet.re_prefill_tokens += l_in as u64;
    outcomes[job.idx] = SimRequest {
        id,
        session,
        class: job.class,
        device: Some(dev),
        arrival: job.arrival,
        first_token: Some(first),
        completed,
        input_tokens: l_in,
        output_tokens: l_out,
        context: ctx0,
        rejected: false,
        failed: false,
        followup: job.followup,
        energy_j: energy,
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::coordinator::router::{LeastLoaded, RoundRobin};
    use crate::llm::model_config::OptModel;

    fn quick_cfg(devices: usize, requests: usize, rate: f64, seed: u64) -> TrafficConfig {
        TrafficConfig {
            devices,
            rate,
            requests,
            input_tokens: LenRange::new(64, 128),
            output_tokens: LenRange::new(8, 16),
            queue_capacity: 64,
            followup: 0.3,
            seed,
            workload: None,
            fleet: None,
            wear: None,
            arrival: None,
            faults: None,
        }
    }

    fn run(cfg: &TrafficConfig, least_loaded: bool) -> PoolReport {
        let policy: Box<dyn Scheduler + Send> = if least_loaded {
            Box::new(LeastLoaded::new())
        } else {
            Box::new(RoundRobin::new())
        };
        run_traffic(&table1_system(), &OptModel::Opt6_7b.shape(), policy, cfg)
    }

    #[test]
    fn arrival_process_parses_and_cycles() {
        let a = ArrivalProcess::parse("10:0.5, 20:2").unwrap();
        assert_eq!(a.phases.len(), 2);
        assert_eq!(a.cycle_s(), 30.0);
        assert_eq!(a.multiplier_at(0.0), 0.5);
        assert_eq!(a.multiplier_at(9.999), 0.5);
        assert_eq!(a.multiplier_at(10.0), 2.0);
        assert_eq!(a.multiplier_at(31.0), 0.5, "schedule wraps around the cycle");
        for bad in ["", "10", "x:1", "10:x", "-5:1", "10:0", "10:nan"] {
            assert!(ArrivalProcess::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn unit_multiplier_gap_is_bitwise_legacy() {
        let mut cfg = quick_cfg(1, 1, 8.0, 1);
        for u in [0.1, 0.5, 0.9999] {
            let legacy = -(1.0f64 - u).ln() / cfg.rate;
            assert_eq!(arrival_gap(&cfg, 123.0, u), legacy);
            cfg.arrival = Some(ArrivalProcess::parse("3600:1.0").unwrap());
            assert_eq!(arrival_gap(&cfg, 123.0, u), legacy, "x1.0 schedule is bit-identical");
            cfg.arrival = Some(ArrivalProcess::parse("60:2.0").unwrap());
            assert_eq!(arrival_gap(&cfg, 30.0, u), legacy / 2.0);
            cfg.arrival = None;
        }
    }

    #[test]
    fn all_arrivals_accounted_for() {
        let cfg = quick_cfg(2, 40, 10.0, 3);
        let rep = run(&cfg, true);
        assert_eq!(rep.outcomes.len(), 40);
        assert_eq!(rep.accepted() + rep.rejected(), 40);
        assert_eq!(rep.device_utilization.len(), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(2, 30, 10.0, 7);
        let a = run(&cfg, true);
        let b = run(&cfg, true);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.accepted(), b.accepted());
    }

    #[test]
    fn followups_share_devices_with_their_sessions() {
        let mut cfg = quick_cfg(4, 60, 10.0, 5);
        cfg.followup = 0.6;
        let rep = run(&cfg, true);
        let mut seen = std::collections::HashMap::new();
        let mut followups = 0;
        for o in rep.outcomes.iter().filter(|o| !o.rejected) {
            if let Some(prev) = seen.get(&o.session) {
                followups += 1;
                assert_eq!(
                    o.device, *prev,
                    "follow-up turn of session {} moved devices",
                    o.session
                );
                assert!(o.context > o.input_tokens, "resident KV must extend the context");
            }
            seen.insert(o.session, o.device);
        }
        assert!(followups > 0, "trace produced no follow-up turns");
    }

    #[test]
    fn saturated_single_device_rejects_arrivals() {
        let mut cfg = quick_cfg(1, 80, 200.0, 9);
        cfg.queue_capacity = 4;
        cfg.output_tokens = LenRange::new(32, 64);
        let rep = run(&cfg, true);
        assert!(rep.rejected() > 0, "200 req/s into one bounded device must shed load");
        // Rejected arrivals produce no tokens and no device assignment.
        for o in rep.outcomes.iter().filter(|o| o.rejected) {
            assert_eq!(o.device, None);
            assert_eq!(o.output_tokens, 0);
        }
    }

    #[test]
    fn utilization_and_latency_sane() {
        let cfg = quick_cfg(4, 80, 10.0, 11);
        let rep = run(&cfg, true);
        for u in &rep.device_utilization {
            assert!((0.0..=1.0).contains(u), "utilization {u}");
        }
        let lat = rep.latency_summary();
        let ttft = rep.ttft_summary();
        assert!(lat.p50 > 0.0 && lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
        assert!(ttft.p50 > 0.0);
        // TPOT must track the table's per-token estimate.
        let table = LatencyTable::build(
            &table1_system(),
            &TechParams::default(),
            OptModel::Opt6_7b.shape(),
        );
        let expect = table.tpot(128);
        let tpot = rep.tpot_summary().p50;
        assert!(tpot > 0.5 * expect && tpot < 3.0 * expect, "TPOT {tpot} vs table {expect}");
    }

    #[test]
    fn prebuilt_table_matches_internal_build() {
        let cfg = quick_cfg(2, 40, 10.0, 3);
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let a = run_traffic(&sys, &model, Box::new(LeastLoaded::new()), &cfg);
        let b = run_traffic_with_table(&sys, &model, &table, Box::new(LeastLoaded::new()), &cfg);
        assert_eq!(a, b, "shared-table run must reproduce the internal-build run exactly");
    }

    #[test]
    fn pool_beats_single_device_p95_at_same_rate() {
        // Acceptance: at the same Poisson arrival rate, a 4-device pool
        // under least-loaded scheduling must deliver strictly lower p95
        // latency than a single device.
        let mut cfg = TrafficConfig::default_for(4);
        cfg.rate = 12.0;
        cfg.requests = 250;
        let pool = run(&cfg, true);
        assert_eq!(pool.rejected(), 0, "4-device pool must absorb the offered load");
        let mut single = cfg.clone();
        single.devices = 1;
        let one = run(&single, true);
        let p95_pool = pool.latency_summary().p95;
        let p95_one = one.latency_summary().p95;
        assert!(
            p95_pool < p95_one,
            "pool p95 {p95_pool} must beat single-device p95 {p95_one}"
        );
    }

    #[test]
    fn round_robin_spreads_jobs_evenly() {
        let mut cfg = quick_cfg(4, 80, 6.0, 13);
        cfg.followup = 0.0; // fresh sessions only: pure policy routing
        let rep = run(&cfg, false);
        assert_eq!(rep.rejected(), 0);
        let min = rep.device_jobs.iter().min().unwrap();
        let max = rep.device_jobs.iter().max().unwrap();
        assert_eq!(rep.device_jobs.iter().sum::<usize>(), 80);
        assert!(max - min <= 1, "round-robin imbalance: {:?}", rep.device_jobs);
    }
}
