//! Typed device tiers for heterogeneous serving fleets.
//!
//! The serving stack historically assumed every device in the pool was a
//! flash-PIM card priced by one [`LatencyTable`]. The paper's headline
//! claims are comparative, though — flash decode vs 4×RTX4090 (vLLM) and
//! 4×A100 (AttAcc) — and the interesting production shape is a *hybrid*
//! fleet that sends long prefills to GPUs and long-tail single-batch
//! decode to flash. [`DeviceModel`] is the seam that makes that
//! expressible: one enum giving prefill time, per-token decode time,
//! KV-upload pricing, capacity fit, and per-token energy/cost for each
//! tier, backed by the existing flash path ([`LatencyTable`] +
//! [`PcieLink`] + [`initial_kv_write_time`]) and an adapter over
//! [`GpuSystem`]'s roofline (`prefill`/`tpot`/`fits`).
//!
//! # Backend-exact pricing
//!
//! Both serving backends must keep producing bit-identical reports, and
//! flash-only fleets must stay byte-identical with the pre-tier code, so
//! the flash arm reproduces each backend's historical expressions
//! *exactly* — including their asymmetry: the event backend prices the
//! host-side PCIe KV upload and estimates TTFT via a `SimTime`
//! round-trip, while the threaded backend prices only the NAND KV write
//! and estimates TTFT in raw `f64`. Hence the paired methods
//! ([`DeviceModel::prefill_cost`] / [`DeviceModel::prefill_cost_direct`]
//! and [`DeviceModel::est_prefill`] / [`DeviceModel::est_prefill_direct`]).
//! The GPU arm defines the event and direct flavors identically (KV is
//! born in VRAM; there is no host upload), which is what makes GPU-only
//! fleets agree across backends to the bit.
//!
//! # Capacity-fit and totality
//!
//! [`GpuSystem::tpot`] returns `None` on OOM. Rather than threading that
//! option through the hot decode path, the GPU tier derives its KV
//! capacity from the same VRAM inequality `fits` checks
//! (`0.90·n·vram − weights·overhead − workspace`), so any context the
//! KV-cache manager admits is a context the roofline prices: `tpot` is
//! total over admitted requests by construction, and a model that does
//! not fit at all yields capacity 0 (every request rejected — the OOM
//! rows of Fig. 14a, in serving form).

use anyhow::{bail, Result};

use crate::circuit::TechParams;
use crate::config::SystemConfig;
use crate::controller::PcieLink;
use crate::gpu::{a100x4_attacc, GpuSystem};
use crate::kv::write_overhead::initial_kv_write_time;
use crate::llm::energy::EnergySchedule;
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use crate::sim::SimTime;

/// Amortized cost of one flash-PIM card (USD/hour) — PIM-AI-style TCO
/// framing: an enterprise SSD-class device amortized over 5 years.
const FLASH_COST_PER_DEVICE_HOUR: f64 = 0.40;
/// Cloud-rate cost per data-center GPU (USD/hour per GPU in the node).
const GPU_COST_PER_GPU_HOUR: f64 = 2.0;
/// Board power per data-center GPU during decode (W) — the baseline the
/// energy comparison in [`EnergySchedule::gpu_energy_per_token`] uses.
const GPU_POWER_W_PER_GPU: f64 = 400.0;

/// Device tier of one pool slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// Flash-PIM card priced by a [`LatencyTable`].
    Flash,
    /// Tensor-parallel GPU node priced by a [`GpuSystem`] roofline.
    Gpu,
}

impl Tier {
    pub fn as_str(self) -> &'static str {
        match self {
            Tier::Flash => "flash",
            Tier::Gpu => "gpu",
        }
    }

    pub fn from_name(name: &str) -> Option<Tier> {
        match name {
            "flash" => Some(Tier::Flash),
            "gpu" => Some(Tier::Gpu),
            _ => None,
        }
    }
}

/// The GPU system a `gpu` fleet slot models. A100s fit every OPT model,
/// so hybrid sweeps exercise routing rather than OOM rejections.
pub fn default_gpu_system() -> GpuSystem {
    a100x4_attacc()
}

/// A fleet composition: ordered groups of same-tier devices, parsed from
/// specs like `8xflash` or `4xflash+1xgpu`. Device indices follow spec
/// order, so `4xflash+1xgpu` puts flash at devices 0–3 and GPU at 4.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FleetSpec {
    groups: Vec<(usize, Tier)>,
}

impl FleetSpec {
    /// All-flash fleet of `n` devices (the legacy pool shape).
    pub fn flash_only(n: usize) -> FleetSpec {
        FleetSpec { groups: vec![(n.max(1), Tier::Flash)] }
    }

    /// Parse a `COUNTxTIER(+COUNTxTIER)*` spec; a bare tier name means
    /// one device (`gpu` ≡ `1xgpu`).
    pub fn parse(spec: &str) -> Result<FleetSpec> {
        let mut groups = Vec::new();
        for part in spec.split('+') {
            let part = part.trim();
            if part.is_empty() {
                bail!("empty fleet group in {spec:?} (use e.g. 4xflash+1xgpu)");
            }
            let (count, tier_name) = match part.split_once('x') {
                Some((n, t)) => {
                    let n: usize = n.trim().parse().map_err(|_| {
                        anyhow::anyhow!("bad device count {n:?} in fleet spec {spec:?}")
                    })?;
                    (n, t.trim())
                }
                None => (1, part),
            };
            if count == 0 {
                bail!("zero-device group {part:?} in fleet spec {spec:?}");
            }
            let Some(tier) = Tier::from_name(tier_name) else {
                bail!("unknown tier {tier_name:?} in fleet spec {spec:?}; use flash|gpu");
            };
            groups.push((count, tier));
        }
        Ok(FleetSpec { groups })
    }

    /// Canonical name (`4xflash+1xgpu`) — stable for metric keys.
    pub fn name(&self) -> String {
        self.groups
            .iter()
            .map(|(n, t)| format!("{n}x{}", t.as_str()))
            .collect::<Vec<_>>()
            .join("+")
    }

    pub fn n_devices(&self) -> usize {
        self.groups.iter().map(|(n, _)| n).sum()
    }

    /// Per-device tier, in device-index order.
    pub fn tiers(&self) -> Vec<Tier> {
        let mut out = Vec::with_capacity(self.n_devices());
        for &(n, t) in &self.groups {
            out.extend(std::iter::repeat(t).take(n));
        }
        out
    }

    /// Does the fleet contain this tier?
    pub fn has_tier(&self, tier: Tier) -> bool {
        self.groups.iter().any(|&(_, t)| t == tier)
    }
}

/// Flash-tier pricing: the exact expressions the serving backends used
/// before tiers existed, plus a per-context energy table.
#[derive(Debug, Clone)]
pub struct FlashDevice<'a> {
    sys: &'a SystemConfig,
    model: &'a ModelShape,
    table: &'a LatencyTable,
    pcie: PcieLink,
    /// `token_energy(ctx).total()` for ctx 0..=max_context (clamped above).
    energy_at: Vec<f64>,
}

/// GPU-tier pricing over the [`GpuSystem`] roofline.
#[derive(Debug, Clone)]
pub struct GpuDevice<'a> {
    gpu: GpuSystem,
    model: &'a ModelShape,
}

/// One pool slot's pricing model. See the module docs for the
/// backend-exact contract each method upholds.
#[derive(Debug, Clone)]
pub enum DeviceModel<'a> {
    Flash(FlashDevice<'a>),
    Gpu(GpuDevice<'a>),
}

impl<'a> DeviceModel<'a> {
    pub fn flash(
        sys: &'a SystemConfig,
        model: &'a ModelShape,
        table: &'a LatencyTable,
    ) -> DeviceModel<'a> {
        let sched = EnergySchedule::new(sys, &TechParams::default(), model.clone());
        let energy_at =
            (0..=table.max_context()).map(|c| sched.token_energy(c).total()).collect();
        DeviceModel::Flash(FlashDevice {
            sys,
            model,
            table,
            pcie: PcieLink::new(&sys.ctrl),
            energy_at,
        })
    }

    pub fn gpu(gpu: GpuSystem, model: &'a ModelShape) -> DeviceModel<'a> {
        DeviceModel::Gpu(GpuDevice { gpu, model })
    }

    /// Build one model per device for a fleet over a shared flash system
    /// and latency table; GPU slots use [`default_gpu_system`].
    pub fn fleet(
        spec: &FleetSpec,
        sys: &'a SystemConfig,
        model: &'a ModelShape,
        table: &'a LatencyTable,
    ) -> Vec<DeviceModel<'a>> {
        spec.tiers()
            .into_iter()
            .map(|t| match t {
                Tier::Flash => DeviceModel::flash(sys, model, table),
                Tier::Gpu => DeviceModel::gpu(default_gpu_system(), model),
            })
            .collect()
    }

    pub fn tier(&self) -> Tier {
        match self {
            DeviceModel::Flash(_) => Tier::Flash,
            DeviceModel::Gpu(_) => Tier::Gpu,
        }
    }

    /// Prefill cost charged on the service timeline by the event backend:
    /// flash pays the host→device KV upload plus the NAND KV write; GPU
    /// runs the compute-roofline prefill (KV is born in VRAM).
    pub fn prefill_cost(&self, l_in: usize) -> SimTime {
        match self {
            DeviceModel::Flash(d) => {
                let upload = d.pcie.transfer_time(d.model.kv_bytes(l_in, 1.0));
                let kv_write =
                    SimTime::from_secs(initial_kv_write_time(d.sys, d.model, l_in));
                upload + kv_write
            }
            DeviceModel::Gpu(d) => SimTime::from_secs(d.gpu.prefill(d.model, l_in)),
        }
    }

    /// Prefill cost as the threaded (direct) backend prices it: the flash
    /// path historically charged only the NAND KV write (no host upload);
    /// the GPU path is identical to the event flavor by design.
    pub fn prefill_cost_direct(&self, l_in: usize) -> SimTime {
        match self {
            DeviceModel::Flash(d) => {
                SimTime::from_secs(initial_kv_write_time(d.sys, d.model, l_in))
            }
            DeviceModel::Gpu(_) => self.prefill_cost(l_in),
        }
    }

    /// Scheduler's TTFT estimate (seconds), event-backend flavor: prefill
    /// cost plus the first decode step.
    pub fn est_prefill(&self, l_in: usize) -> f64 {
        match self {
            DeviceModel::Flash(d) => self.prefill_cost(l_in).secs() + d.table.tpot(l_in),
            DeviceModel::Gpu(d) => d.gpu.prefill(d.model, l_in) + self.tpot(l_in),
        }
    }

    /// Scheduler's TTFT estimate, threaded-backend flavor: the flash path
    /// historically summed raw `f64` terms with no `SimTime` round-trip
    /// (and no upload term); GPU is identical to [`Self::est_prefill`].
    pub fn est_prefill_direct(&self, l_in: usize) -> f64 {
        match self {
            DeviceModel::Flash(d) => {
                initial_kv_write_time(d.sys, d.model, l_in) + d.table.tpot(l_in)
            }
            DeviceModel::Gpu(_) => self.est_prefill(l_in),
        }
    }

    /// Per-token decode time (seconds) at context length `ctx`.
    pub fn tpot(&self, ctx: usize) -> f64 {
        match self {
            DeviceModel::Flash(d) => d.table.tpot(ctx),
            DeviceModel::Gpu(d) => d
                .gpu
                .tpot(d.model, 1.0, ctx)
                .expect("context fits the GPU KV budget by construction"),
        }
    }

    /// One decode step on the integer timeline.
    pub fn step_time(&self, ctx: usize) -> SimTime {
        match self {
            DeviceModel::Flash(d) => d.table.step_time(ctx),
            DeviceModel::Gpu(_) => SimTime::from_secs(self.tpot(ctx)),
        }
    }

    /// Decode `l_out` tokens starting from context `ctx0` — the same
    /// step-sum both backends use, so coalescing stays exact per tier.
    pub fn decode_time(&self, ctx0: usize, l_out: usize) -> SimTime {
        match self {
            DeviceModel::Flash(d) => d.table.decode_time(ctx0, l_out),
            DeviceModel::Gpu(_) => {
                let mut total = SimTime::ZERO;
                for step in 0..l_out {
                    total += self.step_time(ctx0 + step);
                }
                total
            }
        }
    }

    /// Energy (J) to decode `l_out` tokens from context `ctx0`: the PIM
    /// energy rollup per flash token, HBM traffic plus board power per
    /// GPU token.
    pub fn decode_energy(&self, ctx0: usize, l_out: usize) -> f64 {
        match self {
            DeviceModel::Flash(d) => {
                let mut total = 0.0;
                for step in 0..l_out {
                    let ctx = (ctx0 + step).min(d.energy_at.len() - 1);
                    total += d.energy_at[ctx];
                }
                total
            }
            DeviceModel::Gpu(d) => {
                let power = d.gpu.n_gpus as f64 * GPU_POWER_W_PER_GPU;
                let traffic = d.model.weight_bytes(1.0) * 7.0e-12;
                let mut total = 0.0;
                for step in 0..l_out {
                    total += traffic + power * self.tpot(ctx0 + step);
                }
                total
            }
        }
    }

    /// KV capacity (bytes) this device can hold. Flash uses the SLC
    /// region (same math as [`crate::kv::KvCacheManager::new`]); GPU uses
    /// the VRAM left after weights and workspace under the same 0.90
    /// ceiling [`GpuSystem::fits`] checks.
    pub fn kv_capacity(&self) -> u64 {
        match self {
            DeviceModel::Flash(d) => crate::kv::KvCacheManager::new(d.sys, d.model).capacity,
            DeviceModel::Gpu(d) => {
                let usable = d.gpu.n_gpus as f64 * d.gpu.vram * 0.90;
                let fixed = d.model.weight_bytes(1.0) * d.gpu.weight_overhead
                    + d.gpu.workspace;
                (usable - fixed).max(0.0) as u64
            }
        }
    }

    /// KV bytes per token (same model shape on every tier).
    pub fn kv_per_token(&self) -> u64 {
        match self {
            DeviceModel::Flash(d) => d.model.kv_bytes_per_token(1.0) as u64,
            DeviceModel::Gpu(d) => d.model.kv_bytes_per_token(1.0) as u64,
        }
    }

    /// Amortized device cost (USD/hour) — a GPU slot is a whole
    /// tensor-parallel node.
    pub fn cost_per_hour(&self) -> f64 {
        match self {
            DeviceModel::Flash(_) => FLASH_COST_PER_DEVICE_HOUR,
            DeviceModel::Gpu(d) => d.gpu.n_gpus as f64 * GPU_COST_PER_GPU_HOUR,
        }
    }
}

/// Per-tier TTFT estimates for a [`super::router::JobInfo`], event
/// flavor: `(flash, gpu)` seconds from the first device of each tier; a
/// missing tier mirrors the other so single-tier fleets see one value.
pub fn tier_estimates(models: &[DeviceModel], l_in: usize) -> (f64, f64) {
    let flash = models.iter().find(|m| m.tier() == Tier::Flash);
    let gpu = models.iter().find(|m| m.tier() == Tier::Gpu);
    let f = flash.map(|m| m.est_prefill(l_in));
    let g = gpu.map(|m| m.est_prefill(l_in));
    (f.or(g).unwrap_or(0.0), g.or(f).unwrap_or(0.0))
}

/// Threaded-backend flavor of [`tier_estimates`].
pub fn tier_estimates_direct(models: &[DeviceModel], l_in: usize) -> (f64, f64) {
    let flash = models.iter().find(|m| m.tier() == Tier::Flash);
    let gpu = models.iter().find(|m| m.tier() == Tier::Gpu);
    let f = flash.map(|m| m.est_prefill_direct(l_in));
    let g = gpu.map(|m| m.est_prefill_direct(l_in));
    (f.or(g).unwrap_or(0.0), g.or(f).unwrap_or(0.0))
}

/// Fleet-level rollup attached to a `PoolReport` when a fleet spec is in
/// play: composition, fleet cost rate, and total decode energy. Both the
/// materialized and streaming report paths derive cost/energy per
/// million tokens through the same two methods, so the two stay
/// bit-identical for the same run.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSummary {
    /// Canonical fleet name (`4xflash+1xgpu`).
    pub name: String,
    /// Per-device tier, in device-index order.
    pub tiers: Vec<Tier>,
    /// Summed amortized fleet cost (USD/hour).
    pub cost_per_hour: f64,
    /// Total decode energy over the run (J).
    pub energy_j: f64,
}

impl FleetSummary {
    /// Build from the fleet spec and the per-device models it produced.
    pub fn of(spec: &FleetSpec, models: &[DeviceModel], energy_j: f64) -> FleetSummary {
        FleetSummary {
            name: spec.name(),
            tiers: models.iter().map(|m| m.tier()).collect(),
            cost_per_hour: models.iter().map(|m| m.cost_per_hour()).sum(),
            energy_j,
        }
    }

    /// USD per million generated tokens at the run's makespan.
    pub fn cost_per_mtok(&self, tokens: u64, makespan_s: f64) -> Option<f64> {
        if tokens == 0 {
            return None;
        }
        Some(self.cost_per_hour / 3600.0 * makespan_s / tokens as f64 * 1e6)
    }

    /// Joules per million generated tokens.
    pub fn energy_per_mtok(&self, tokens: u64) -> Option<f64> {
        if tokens == 0 {
            return None;
        }
        Some(self.energy_j / tokens as f64 * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    fn fixtures() -> (SystemConfig, ModelShape, LatencyTable) {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        (sys, model, table)
    }

    #[test]
    fn fleet_spec_parses_and_round_trips() {
        let f = FleetSpec::parse("4xflash+1xgpu").unwrap();
        assert_eq!(f.name(), "4xflash+1xgpu");
        assert_eq!(f.n_devices(), 5);
        assert_eq!(
            f.tiers(),
            vec![Tier::Flash, Tier::Flash, Tier::Flash, Tier::Flash, Tier::Gpu]
        );
        assert!(f.has_tier(Tier::Gpu) && f.has_tier(Tier::Flash));
        // Bare tier names mean one device.
        let g = FleetSpec::parse("gpu").unwrap();
        assert_eq!(g.name(), "1xgpu");
        assert_eq!(g.tiers(), vec![Tier::Gpu]);
        assert!(!g.has_tier(Tier::Flash));
        assert_eq!(FleetSpec::flash_only(8).name(), "8xflash");
    }

    #[test]
    fn fleet_spec_rejects_malformed_input() {
        for bad in ["", "+", "0xflash", "4xtpu", "4flash+1xgpu", "x", "-1xgpu"] {
            assert!(FleetSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn flash_estimates_match_the_legacy_expressions() {
        let (sys, model, table) = fixtures();
        let d = DeviceModel::flash(&sys, &model, &table);
        let l_in = 256;
        // Event flavor: PCIe upload + KV write, rounded through SimTime.
        let pcie = PcieLink::new(&sys.ctrl);
        let upload = pcie.transfer_time(model.kv_bytes(l_in, 1.0));
        let kv_write = SimTime::from_secs(initial_kv_write_time(&sys, &model, l_in));
        assert_eq!(d.prefill_cost(l_in), upload + kv_write);
        assert_eq!(d.est_prefill(l_in), (upload + kv_write).secs() + table.tpot(l_in));
        // Direct flavor: KV write only, raw f64 sum.
        assert_eq!(d.prefill_cost_direct(l_in), kv_write);
        assert_eq!(
            d.est_prefill_direct(l_in),
            initial_kv_write_time(&sys, &model, l_in) + table.tpot(l_in)
        );
        assert_eq!(d.decode_time(100, 8), table.decode_time(100, 8));
        assert_eq!(d.tier(), Tier::Flash);
    }

    #[test]
    fn gpu_pricing_matches_the_roofline_and_both_backends_agree() {
        let (_, model, _) = fixtures();
        let g = default_gpu_system();
        let d = DeviceModel::gpu(g.clone(), &model);
        assert_eq!(d.tier(), Tier::Gpu);
        assert_eq!(d.prefill_cost(1024), SimTime::from_secs(g.prefill(&model, 1024)));
        assert_eq!(d.prefill_cost_direct(1024), d.prefill_cost(1024));
        assert_eq!(d.est_prefill_direct(1024), d.est_prefill(1024));
        assert_eq!(d.tpot(512), g.tpot(&model, 1.0, 512).unwrap());
        // decode_time is the step-sum, so coalescing stays exact.
        let sum = d.step_time(100) + d.step_time(101) + d.step_time(102);
        assert_eq!(d.decode_time(100, 3), sum);
    }

    #[test]
    fn gpu_kv_capacity_guarantees_tpot_is_total() {
        let (_, model, _) = fixtures();
        let g = default_gpu_system();
        let d = DeviceModel::gpu(g.clone(), &model);
        let max_tokens = (d.kv_capacity() / d.kv_per_token()) as usize;
        assert!(max_tokens > 1024, "A100 node holds a long context");
        assert!(g.fits(&model, 1.0, max_tokens), "admitted contexts always fit");
        // A model that does not fit at all yields zero capacity.
        let big = OptModel::Opt175b.shape();
        let small = crate::gpu::rtx4090x4_vllm();
        assert_eq!(DeviceModel::gpu(small, &big).kv_capacity(), 0);
    }

    #[test]
    fn energy_and_cost_separate_the_tiers() {
        let (sys, model, table) = fixtures();
        let flash = DeviceModel::flash(&sys, &model, &table);
        let gpu = DeviceModel::gpu(default_gpu_system(), &model);
        let ef = flash.decode_energy(1024, 16);
        let eg = gpu.decode_energy(1024, 16);
        assert!(ef > 0.0 && eg > ef * 10.0, "flash {ef:e} vs gpu {eg:e}");
        assert!(gpu.cost_per_hour() > 10.0 * flash.cost_per_hour());
        // Context beyond the table clamps instead of panicking.
        let clamped = flash.decode_energy(table.max_context() + 10, 4);
        assert!(clamped > 0.0);
    }

    #[test]
    fn tier_estimates_mirror_missing_tiers() {
        let (sys, model, table) = fixtures();
        let spec = FleetSpec::parse("2xflash+1xgpu").unwrap();
        let models = DeviceModel::fleet(&spec, &sys, &model, &table);
        assert_eq!(models.len(), 3);
        let (f, g) = tier_estimates(&models, 512);
        assert_eq!(f, models[0].est_prefill(512));
        assert_eq!(g, models[2].est_prefill(512));
        // Flash-only: the GPU slot mirrors flash, so schedulers that read
        // either field behave identically to the pre-tier code.
        let flash_only = &models[..2];
        assert_eq!(tier_estimates(flash_only, 512), (f, f));
        let gpu_only = &models[2..];
        assert_eq!(tier_estimates_direct(gpu_only, 512), (g, g));
    }

    #[test]
    fn fleet_summary_cost_and_energy_per_mtok() {
        let (sys, model, table) = fixtures();
        let spec = FleetSpec::parse("4xflash+1xgpu").unwrap();
        let models = DeviceModel::fleet(&spec, &sys, &model, &table);
        let s = FleetSummary::of(&spec, &models, 123.0);
        assert_eq!(s.name, "4xflash+1xgpu");
        assert_eq!(s.tiers.len(), 5);
        let node = default_gpu_system().n_gpus as f64 * GPU_COST_PER_GPU_HOUR;
        assert_eq!(s.cost_per_hour, 0.40 * 4.0 + node);
        // 1M tokens in an hour costs exactly the fleet-hour rate.
        let c = s.cost_per_mtok(1_000_000, 3600.0).unwrap();
        assert!((c - s.cost_per_hour).abs() < 1e-9);
        assert_eq!(s.energy_per_mtok(1_000_000).unwrap(), 123.0);
        assert_eq!(s.cost_per_mtok(0, 10.0), None);
        assert_eq!(s.energy_per_mtok(0), None);
    }
}
