//! The functional serving coordinator: a worker thread owns the flash
//! generation engine (the PJRT executor in production, a mock in tests)
//! and serves generation jobs from a channel, streaming tokens back.
//! Wall-clock latency is measured per request; the simulated flash-PIM
//! timing runs alongside via a precomputed immutable
//! [`crate::llm::latency_table::LatencyTable`].

use crate::sim::SimTime;
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A token-generation engine (implemented by `runtime::DecodeExecutor`).
/// Engines need not be `Send` — the coordinator constructs the engine
/// *inside* its worker thread from a `Send` factory (PJRT handles hold
/// raw pointers).
pub trait Engine: 'static {
    /// Generate up to `max_new` tokens after `prompt`; calls `on_token`
    /// for each produced token; returns the generated ids.
    fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>>;

    /// Simulated flash latency for a whole job (`n_out` tokens generated
    /// from a context of `l_in`), when the engine models device timing —
    /// e.g. [`super::pool::SimFlashEngine`] answering from a shared
    /// [`crate::llm::latency_table::LatencyTable`]. Purely functional
    /// engines return `None` (the default).
    fn sim_job_time(&self, _l_in: usize, _n_out: usize) -> Option<SimTime> {
        None
    }
}

/// A generation job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Result of a served job.
#[derive(Debug, Clone)]
pub struct Served {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Wall-clock time of the whole job.
    pub wall: f64,
    /// Wall-clock time to first token.
    pub ttft: f64,
}

enum Msg {
    Run(Job, mpsc::Sender<Result<Served>>),
    Stop,
}

/// Single-batch serving loop over one engine (the paper's flash device
/// serves one sequence at a time by design).
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Build with an engine factory; the factory runs on the worker
    /// thread so the engine itself never crosses threads.
    pub fn new<E: Engine>(factory: impl FnOnce() -> E + Send + 'static) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let mut engine = factory();
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Stop => break,
                    Msg::Run(job, reply) => {
                        let start = Instant::now();
                        let mut first: Option<f64> = None;
                        let result = engine
                            .generate(&job.prompt, job.max_new, &mut |_t| {
                                if first.is_none() {
                                    first = Some(start.elapsed().as_secs_f64());
                                }
                            })
                            .map(|tokens| Served {
                                id: job.id,
                                tokens,
                                wall: start.elapsed().as_secs_f64(),
                                ttft: first.unwrap_or_else(|| start.elapsed().as_secs_f64()),
                            });
                        let _ = reply.send(result);
                    }
                }
            }
        });
        Coordinator { tx, worker: Some(worker) }
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit(&self, job: Job) -> mpsc::Receiver<Result<Served>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Msg::Run(job, reply_tx)).expect("worker alive");
        reply_rx
    }

    /// Submit and wait.
    pub fn run(&self, job: Job) -> Result<Served> {
        self.submit(job).recv().expect("worker reply")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo engine: repeats the last prompt token, then counts up.
    struct MockEngine;

    impl Engine for MockEngine {
        fn generate(
            &mut self,
            prompt: &[u32],
            max_new: usize,
            on_token: &mut dyn FnMut(u32),
        ) -> Result<Vec<u32>> {
            let base = *prompt.last().unwrap_or(&0);
            let out: Vec<u32> = (0..max_new as u32).map(|i| base + i).collect();
            for t in &out {
                on_token(*t);
            }
            Ok(out)
        }
    }

    #[test]
    fn serves_jobs_in_order() {
        let c = Coordinator::new(|| MockEngine);
        let a = c.run(Job { id: 1, prompt: vec![10], max_new: 3 }).unwrap();
        let b = c.run(Job { id: 2, prompt: vec![100], max_new: 2 }).unwrap();
        assert_eq!(a.tokens, vec![10, 11, 12]);
        assert_eq!(b.tokens, vec![100, 101]);
        assert!(a.wall >= a.ttft);
    }

    #[test]
    fn concurrent_submissions_serialize() {
        let c = Coordinator::new(|| MockEngine);
        let r1 = c.submit(Job { id: 1, prompt: vec![1], max_new: 4 });
        let r2 = c.submit(Job { id: 2, prompt: vec![2], max_new: 4 });
        let s1 = r1.recv().unwrap().unwrap();
        let s2 = r2.recv().unwrap().unwrap();
        assert_eq!(s1.id, 1);
        assert_eq!(s2.id, 2);
    }

    #[test]
    fn drop_stops_worker() {
        let c = Coordinator::new(|| MockEngine);
        drop(c); // must not hang
    }
}
