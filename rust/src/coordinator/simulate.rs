//! Discrete-event simulation of the serving system: GPU pool for
//! summarization, flash PIM for generation, PCIe for the initial KV
//! transfer. Reproduces the paper's deployment argument (offloading
//! frees the GPUs; flash TPOT holds under concurrent load — the device
//! serves one sequence at a time, single-batch by design).

use super::metrics::ServingReport;
use super::request::{Request, RequestKind, RequestOutcome};
use super::router::{Route, Router};
use crate::circuit::TechParams;
use crate::config::SystemConfig;
use crate::controller::PcieLink;
use crate::gpu::GpuSystem;
use crate::kv::cache::KvCacheManager;
use crate::kv::write_overhead::initial_kv_write_time;
use crate::llm::model_config::ModelShape;
use crate::llm::schedule::TokenSchedule;
use crate::sim::{Resource, SimTime};
use std::collections::VecDeque;

/// A request trace.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub requests: Vec<Request>,
}

impl Workload {
    /// Synthetic mixed workload: Poisson-ish arrivals of summarization
    /// and generation requests.
    pub fn synthetic(
        n_requests: usize,
        gen_fraction: f64,
        mean_interarrival: f64,
        input_tokens: usize,
        output_tokens: usize,
        seed: u64,
    ) -> Workload {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut t = 0.0;
        let mut requests = Vec::new();
        for id in 0..n_requests as u64 {
            t += -mean_interarrival * (1.0 - rng.f64()).ln(); // exponential gap
            let arrival = SimTime::from_secs(t);
            if rng.chance(gen_fraction) {
                requests.push(Request::generate(id, arrival, input_tokens, output_tokens));
            } else {
                requests.push(Request::summarize(id, arrival, input_tokens));
            }
        }
        Workload { requests }
    }
}

/// Run the trace to completion; deterministic.
pub fn simulate(
    sys: &SystemConfig,
    model: &ModelShape,
    gpu: &GpuSystem,
    workload: &Workload,
) -> ServingReport {
    let tech = TechParams::default();
    let mut sched = TokenSchedule::new(sys, &tech, model.clone());
    let mut router = Router::new(KvCacheManager::new(sys, model));
    let mut pcie = PcieLink::new(&sys.ctrl);
    let mut flash = Resource::new();
    let mut gpu_pool = Resource::new();
    let mut outcomes = Vec::new();
    let mut queue: VecDeque<Request> = VecDeque::new();

    let mut pending: Vec<Request> = workload.requests.clone();
    pending.sort_by_key(|r| r.arrival);

    // Event-free sequential admission: process arrivals in order; after
    // each completion, retry the queue. (Single-batch devices make the
    // timeline a simple resource schedule.)
    let process = |req: &Request,
                       router: &mut Router,
                       sched: &mut TokenSchedule,
                       flash: &mut Resource,
                       gpu_pool: &mut Resource,
                       pcie: &mut PcieLink|
     -> Option<RequestOutcome> {
        match req.kind {
            RequestKind::Summarize { input_tokens } => {
                let dur = SimTime::from_secs(gpu.prefill(model, input_tokens));
                let start = gpu_pool.acquire(req.arrival, dur);
                Some(RequestOutcome {
                    id: req.id,
                    arrival: req.arrival,
                    first_token: None,
                    completed: start + dur,
                    tokens_out: 0,
                    executed_on: "gpu",
                })
            }
            RequestKind::Generate { input_tokens, output_tokens } => {
                match router.route(req) {
                    Route::Queue => return None,
                    _ => {}
                }
                router.admit(req).expect("admission after route check");
                // Prefill on the GPU, then ship the initial KV over PCIe
                // and the channel buses into SLC.
                let prefill = SimTime::from_secs(gpu.prefill(model, input_tokens));
                let pstart = gpu_pool.acquire(req.arrival, prefill);
                let kv_bytes = model.kv_bytes(input_tokens, 1.0);
                let pcie_done = pcie.transfer(pstart + prefill, kv_bytes);
                let kv_write =
                    SimTime::from_secs(initial_kv_write_time(sys, model, input_tokens));
                let ready = pcie_done + kv_write;
                // Token loop on the flash device.
                let mut now = ready;
                let mut first_token = None;
                for step in 0..output_tokens {
                    let l_ctx = input_tokens + step;
                    let dur = sched.step_time(l_ctx);
                    let start = flash.acquire(now, dur);
                    now = start + dur;
                    if first_token.is_none() {
                        first_token = Some(now);
                    }
                    router.on_token(req.id).expect("kv append");
                }
                router.finish(req.id).expect("kv release");
                Some(RequestOutcome {
                    id: req.id,
                    arrival: req.arrival,
                    first_token,
                    completed: now,
                    tokens_out: output_tokens,
                    executed_on: "flash",
                })
            }
        }
    };

    for req in &pending {
        match process(req, &mut router, &mut sched, &mut flash, &mut gpu_pool, &mut pcie) {
            Some(o) => outcomes.push(o),
            None => queue.push_back(req.clone()),
        }
        // Retry queued requests greedily after each completion.
        let mut still_queued = VecDeque::new();
        while let Some(q) = queue.pop_front() {
            match process(&q, &mut router, &mut sched, &mut flash, &mut gpu_pool, &mut pcie) {
                Some(o) => outcomes.push(o),
                None => still_queued.push_back(q),
            }
        }
        queue = still_queued;
    }
    // Final drain: anything still queued is force-processed in order.
    while let Some(q) = queue.pop_front() {
        if let Some(o) = process(&q, &mut router, &mut sched, &mut flash, &mut gpu_pool, &mut pcie)
        {
            outcomes.push(o);
        } else {
            // Whole-trace capacity exceeded: report as dropped by ending
            // the loop (tests never hit this with sane traces).
            break;
        }
    }

    let makespan = outcomes.iter().map(|o| o.completed).max().unwrap_or(SimTime::ZERO);
    ServingReport {
        flash_utilization: flash.utilization(makespan),
        gpu_utilization: gpu_pool.utilization(makespan),
        outcomes,
        makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::gpu::rtx4090x4_vllm;
    use crate::llm::model_config::OptModel;

    fn run(n: usize, gen_frac: f64) -> ServingReport {
        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let wl = Workload::synthetic(n, gen_frac, 0.5, 256, 64, 42);
        simulate(&sys, &model, &rtx4090x4_vllm(), &wl)
    }

    #[test]
    fn all_requests_complete() {
        let r = run(20, 0.5);
        assert_eq!(r.outcomes.len(), 20);
    }

    #[test]
    fn generation_runs_on_flash_summaries_on_gpu() {
        let r = run(30, 0.5);
        let (flash, gpu) = r.counts();
        assert!(flash > 0 && gpu > 0);
        for o in &r.outcomes {
            if o.tokens_out > 0 {
                assert_eq!(o.executed_on, "flash");
            }
        }
    }

    #[test]
    fn tpot_matches_schedule() {
        // Serving TPOT ≈ the schedule's per-token estimate for the model.
        let r = run(10, 1.0);
        let tpot = r.tpot_summary().mean;
        let sys = table1_system();
        let mut sched = TokenSchedule::new(
            &sys,
            &crate::circuit::TechParams::default(),
            OptModel::Opt6_7b.shape(),
        );
        let expect = sched.tpot(256 + 32);
        assert!(
            (tpot - expect).abs() / expect < 0.15,
            "serving TPOT {tpot} vs schedule {expect}"
        );
    }

    #[test]
    fn offload_frees_gpu_time() {
        // With generation offloaded, GPU busy time is prefill-only: the
        // GPU pool utilization stays below the flash device's when the
        // mix is generation-heavy.
        let r = run(30, 0.9);
        assert!(r.flash_utilization > r.gpu_utilization);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(15, 0.5).makespan;
        let b = run(15, 0.5).makespan;
        assert_eq!(a, b);
    }
}
