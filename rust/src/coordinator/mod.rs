//! The serving coordinator — the paper's deployment model (§I, §IV):
//! GPUs handle multi-batch summarization (prefill); **single-batch token
//! generation offloads to the flash-PIM device**, paying a one-time
//! initial-KV transfer over PCIe and freeing the GPUs for further
//! summarization requests.
//!
//! Two execution modes share the same router/scheduler logic:
//! * [`simulate`] — discrete-event simulation of a request trace
//!   (latency/throughput reports, utilization);
//! * the functional path used by `examples/token_generation.rs`, where
//!   the PJRT runtime actually generates tokens while this module keeps
//!   the simulated device timing alongside.

pub mod metrics;
pub mod request;
pub mod router;
pub mod serve;
pub mod simulate;

pub use metrics::ServingReport;
pub use request::{Request, RequestKind, RequestOutcome};
pub use router::{Route, Router};
pub use serve::Coordinator;
pub use simulate::{simulate, Workload};
