//! The serving coordinator — the paper's deployment model (§I, §IV) scaled
//! out: GPUs handle multi-batch summarization (prefill); **single-batch
//! token generation offloads to flash-PIM devices**, paying a one-time
//! initial-KV transfer over PCIe and freeing the GPUs for further
//! summarization requests.
//!
//! The production-scale path is a *device pool*: N flash-PIM devices
//! behind one scheduler. [`router`] hosts the [`Scheduler`] policies
//! (round-robin, least-loaded) plus [`DeviceRouter`] — KV affinity pins a
//! session's follow-up turns to the device holding its SLC KV cache — and
//! every device queue is bounded, so overload is surfaced as backpressure
//! instead of unbounded buffering.
//!
//! Three execution modes share that router/scheduler logic:
//! * [`simulate`] — discrete-event simulation of a mixed GPU + flash
//!   request trace (latency/throughput reports, utilization);
//! * [`loadgen`] — closed-loop Poisson traffic against the device pool,
//!   with per-request device time from a shared precomputed
//!   [`crate::llm::latency_table::LatencyTable`] (the `serve-sim` CLI
//!   subcommand), plus [`sweep`] for arrival-rate throughput–latency
//!   curves (`serve-sim --sweep`);
//! * the functional path ([`serve`] for one engine, [`pool`] for N), where
//!   the PJRT runtime actually generates tokens while the simulated device
//!   timing runs alongside.

pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;
pub mod serve;
pub mod simulate;
pub mod sweep;

pub use loadgen::{LenRange, run_traffic, run_traffic_with_table, SimRequest, TrafficConfig};
pub use metrics::{PoolReport, ServingReport};
pub use pool::{DevicePool, PoolJob, PoolServed, SimFlashEngine, SubmitError};
pub use request::{Request, RequestKind, RequestOutcome};
pub use router::{
    DeviceRouter, DeviceStatus, LeastLoaded, policy_from_name, RoundRobin, Route, Router,
    Scheduler,
};
pub use serve::Coordinator;
pub use simulate::{simulate, Workload};
pub use sweep::{render_sweep, sweep_rates, SweepPoint};
