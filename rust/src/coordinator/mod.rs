//! The serving coordinator — the paper's deployment model (§I, §IV) scaled
//! out: GPUs handle multi-batch summarization (prefill); **single-batch
//! token generation offloads to flash-PIM devices**, paying a one-time
//! initial-KV transfer over PCIe and freeing the GPUs for further
//! summarization requests.
//!
//! The production-scale path is a *device pool*: N devices behind one
//! scheduler. A pool need not be homogeneous — [`device`] defines the
//! [`DeviceModel`] tier abstraction (flash-PIM cards priced by the
//! latency table, GPU nodes priced by the
//! [`gpu::roofline`][crate::gpu::roofline] model) and [`FleetSpec`]
//! compositions like `4xflash+1xgpu`. [`router`] hosts the
//! [`Scheduler`] policies (round-robin, least-loaded, the SLO-aware
//! bin-packer [`SloAware`], the tier-splitting [`TierAware`], and the
//! erase-budget-spreading [`WearAware`]) plus [`DeviceRouter`] — KV
//! affinity pins a session's follow-up turns to the device holding its
//! KV cache — and every device queue is bounded, so overload is
//! surfaced as backpressure instead of unbounded buffering.
//!
//! Traffic need not be one homogeneous stream: [`workload`] defines
//! multi-class scenarios ([`WorkloadMix`] — chat, long-context
//! summarization, agentic bursts, offline batch, or custom TOML mixes),
//! sampled per arrival from the shared RNG stream, with per-class
//! TTFT/TPOT SLO targets reported as attainment in every [`PoolReport`]
//! (see `docs/WORKLOADS.md`).
//!
//! Execution modes sharing that router/scheduler logic:
//!
//! * [`event_sim`] — **the serving default**: the closed-loop Poisson
//!   traffic model as a deterministic discrete-event [`crate::sim::Model`]
//!   on [`crate::sim::Engine`]. Single-threaded, bit-reproducible
//!   [`PoolReport`]s, and the prefill path prices the PCIe KV upload.
//!   Decode is *coalesced* — one precomputed event per request instead of
//!   one per token, with the per-token chain kept as a bit-identity
//!   oracle ([`DecodeMode`]) — and outcomes fold through an
//!   [`OutcomeSink`] ([`sink`]), so sweeps stream aggregates instead of
//!   materializing every request. Backs `serve-sim` and the [`sweep`]
//!   rate sweeps (which fan points out on scoped threads,
//!   bit-reproducibly).
//! * [`loadgen`] — the legacy direct-replay loop over the same traffic
//!   model (each request's service computed inline at arrival). Kept as
//!   the `serve-sim --threaded` cross-check; its sweep fans out on scoped
//!   threads.
//! * [`mod@simulate`] — discrete-event simulation of a mixed GPU + flash
//!   request trace (latency/throughput reports, utilization) for the
//!   offload argument itself.
//! * the functional path ([`serve`] for one engine, [`pool`] for N), where
//!   the PJRT runtime actually generates tokens while the simulated device
//!   timing runs alongside.
//!
//! Per-token decode latency always comes from a shared precomputed
//! [`crate::llm::latency_table::LatencyTable`], built once per
//! (model, system) and queried immutably.
//!
//! # Examples
//!
//! Build a latency table (a small span keeps the example fast; serving
//! uses [`LatencyTable::build`][crate::llm::LatencyTable::build], which
//! spans the model's trained context) and query it:
//!
//! ```
//! use flashpim::circuit::TechParams;
//! use flashpim::config::presets::table1_system;
//! use flashpim::llm::{model_config::OptModel, LatencyTable};
//!
//! let sys = table1_system();
//! let table = LatencyTable::build_spanning(
//!     &sys,
//!     &TechParams::default(),
//!     OptModel::Opt6_7b.shape(),
//!     256, // max tabulated context
//!     64,  // bucket stride
//! );
//! assert!(table.tpot(128) > 0.0, "per-token latency must be positive");
//! assert!(table.tpot(256) >= table.tpot(0), "longer context is never faster");
//! ```
//!
//! Run a tiny event-driven serving simulation twice and observe that the
//! reports are bit-identical for the same seed:
//!
//! ```
//! use flashpim::circuit::TechParams;
//! use flashpim::config::presets::table1_system;
//! use flashpim::coordinator::{policy_from_name, run_traffic_events, LenRange, TrafficConfig};
//! use flashpim::llm::{model_config::OptModel, LatencyTable};
//!
//! let sys = table1_system();
//! let model = OptModel::Opt6_7b.shape();
//! let table = LatencyTable::build_spanning(&sys, &TechParams::default(), model.clone(), 256, 64);
//! let cfg = TrafficConfig {
//!     devices: 2,
//!     rate: 20.0,
//!     requests: 10,
//!     input_tokens: LenRange::new(16, 32),
//!     output_tokens: LenRange::new(2, 4),
//!     queue_capacity: 8,
//!     followup: 0.0,
//!     seed: 1,
//!     workload: None,
//!     fleet: None,
//!     wear: None,
//!     arrival: None,
//!     faults: None,
//! };
//! let policy = || policy_from_name("least-loaded").unwrap();
//! let a = run_traffic_events(&sys, &model, &table, policy(), &cfg);
//! let b = run_traffic_events(&sys, &model, &table, policy(), &cfg);
//! assert_eq!(a, b, "same seed, same bytes");
//! assert_eq!(a.accepted() + a.rejected(), 10);
//! ```

pub mod device;
pub mod event_sim;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;
pub mod serve;
pub mod simulate;
pub mod sink;
pub mod sweep;
pub mod workload;

pub use device::{default_gpu_system, DeviceModel, FleetSpec, FleetSummary, Tier};
pub use event_sim::{
    DecodeMode, run_traffic_events, run_traffic_events_counted, run_traffic_events_mode,
    run_traffic_point, ServingEvent, ServingModel,
};
pub use loadgen::{
    ArrivalPhase, ArrivalProcess, LenRange, run_traffic, run_traffic_with_table, SimRequest,
    TrafficConfig, WearConfig,
};
pub use metrics::{ClassReport, DeviceWearStats, PoolReport, ServingReport, WearSummary};
pub use pool::{
    DevicePool, PoolJob, PoolServed, SimFlashEngine, SimGpuEngine, SimPoolEngine, SubmitError,
};
pub use request::{Request, RequestKind, RequestOutcome};
pub use router::{
    DeviceRouter, DeviceStatus, JobInfo, LeastLoaded, policy_from_name, RoundRobin, Route, Router,
    Scheduler, SloAware, TierAware, WearAware, GPU_PROMPT_SPLIT, TIERED_POLICY_NAMES,
};
pub use serve::Coordinator;
pub use simulate::{simulate, Workload};
pub use sink::{CollectSink, OutcomeSink, StreamingSink};
pub use sweep::{
    ClassAttainment, max_sustained_rates, render_slo_frontier, render_sweep, SloFrontier,
    sweep_rates, sweep_rates_seq, sweep_rates_threaded, SweepPoint,
};
pub use workload::{SloTarget, WorkloadClass, WorkloadMix};
