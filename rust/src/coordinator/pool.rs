//! Device-pool serving: N flash-PIM engine workers behind one scheduler.
//!
//! Scales the single-engine [`super::serve::Coordinator`] to a pool: each
//! device owns its engine thread (engines need not be `Send` — they are
//! built inside the worker from a `Send + Sync` factory), a [`Scheduler`]
//! policy picks a device per job, session-tagged jobs stick to the device
//! that served their earlier turns (KV affinity), and every device queue is
//! *bounded* — a full queue refuses the job with [`SubmitError::QueueFull`]
//! instead of buffering without limit, so overload surfaces as backpressure
//! at the admission edge.
//!
//! This is the *functional* pool (threads run real engines and report
//! wall-clock). Traffic *simulation* does not run here: it runs on the
//! deterministic event-driven backend
//! ([`super::event_sim`]), which reuses this module's admission semantics
//! (bounded queues, [`Scheduler`] policies, KV affinity) on a simulated
//! timeline.

use super::device::{FleetSpec, Tier};
use super::router::{DeviceStatus, JobInfo, Scheduler};
use super::serve::{Engine, Job};
use crate::gpu::GpuSystem;
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use crate::sim::SimTime;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A pool job: the generation request plus an optional session tag used for
/// KV affinity (follow-up turns of a session land on the same device).
pub struct PoolJob {
    pub job: Job,
    pub session: Option<u64>,
}

impl PoolJob {
    pub fn new(job: Job) -> PoolJob {
        PoolJob { job, session: None }
    }

    pub fn with_session(job: Job, session: u64) -> PoolJob {
        PoolJob { job, session: Some(session) }
    }
}

/// Result of a job served by a pool device.
#[derive(Debug, Clone)]
pub struct PoolServed {
    /// Device that ran the job.
    pub device: usize,
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Wall-clock time of the whole job.
    pub wall: f64,
    /// Wall-clock time to first token.
    pub ttft: f64,
    /// Simulated flash latency of the job, when the engine models device
    /// timing (see [`Engine::sim_job_time`]); `None` for purely
    /// functional engines.
    pub sim: Option<SimTime>,
}

/// Why a submission was refused (bounded queues, not unbounded `mpsc`).
#[derive(Debug)]
pub enum SubmitError {
    /// The picked device's queue is at capacity; the job is handed back so
    /// the caller can retry, shed, or route elsewhere.
    QueueFull { device: usize, job: Job },
    /// The pool is shutting down.
    Stopped(Job),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { device, job } => {
                write!(f, "device {device} queue full (job {})", job.id)
            }
            SubmitError::Stopped(job) => write!(f, "pool stopped (job {})", job.id),
        }
    }
}

enum Msg {
    Run(Job, mpsc::Sender<Result<PoolServed>>),
    Stop,
}

struct WorkerHandle {
    tx: SyncSender<Msg>,
    /// Jobs queued or running on this device.
    pending: Arc<AtomicUsize>,
    handle: Option<JoinHandle<()>>,
}

/// A pool of single-batch flash-PIM serving devices.
pub struct DevicePool {
    workers: Vec<WorkerHandle>,
    policy: Mutex<Box<dyn Scheduler + Send>>,
    affinity: Mutex<HashMap<u64, usize>>,
    /// Reused [`DeviceStatus`] buffer for policy picks — the submit hot
    /// path refills it in place instead of allocating a Vec per job.
    /// Lock order on every path: affinity → status_scratch → policy.
    status_scratch: Mutex<Vec<DeviceStatus>>,
    queue_capacity: usize,
    /// Per-device tier, in worker order. [`DevicePool::new`] builds an
    /// all-flash pool; [`DevicePool::simulated_fleet`] follows its
    /// [`FleetSpec`], so tier-aware policies can split traffic.
    tiers: Vec<Tier>,
}

impl DevicePool {
    /// Build a pool of `n_devices` workers. `factory(device)` runs on each
    /// worker thread to construct that device's engine, so the engine never
    /// crosses threads. `queue_capacity` bounds each device's queue
    /// (queued + running jobs); it must be at least 1.
    pub fn new<E: Engine>(
        n_devices: usize,
        queue_capacity: usize,
        policy: Box<dyn Scheduler + Send>,
        factory: impl Fn(usize) -> E + Send + Sync + 'static,
    ) -> DevicePool {
        assert!(n_devices > 0, "pool needs at least one device");
        assert!(queue_capacity > 0, "queue capacity must be at least 1");
        let factory = Arc::new(factory);
        let workers = (0..n_devices)
            .map(|device| {
                let (tx, rx) = mpsc::sync_channel::<Msg>(queue_capacity);
                let pending = Arc::new(AtomicUsize::new(0));
                let worker_pending = Arc::clone(&pending);
                let make = Arc::clone(&factory);
                let handle = std::thread::spawn(move || {
                    let mut engine = make(device);
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            Msg::Stop => break,
                            Msg::Run(job, reply) => {
                                let start = Instant::now();
                                let mut first: Option<f64> = None;
                                let l_in = job.prompt.len();
                                let generated =
                                    engine.generate(&job.prompt, job.max_new, &mut |_t| {
                                        if first.is_none() {
                                            first = Some(start.elapsed().as_secs_f64());
                                        }
                                    });
                                let result = generated.map(|tokens| PoolServed {
                                    device,
                                    id: job.id,
                                    sim: engine.sim_job_time(l_in, tokens.len()),
                                    tokens,
                                    wall: start.elapsed().as_secs_f64(),
                                    ttft: first.unwrap_or_else(|| start.elapsed().as_secs_f64()),
                                });
                                worker_pending.fetch_sub(1, Ordering::SeqCst);
                                let _ = reply.send(result);
                            }
                        }
                    }
                });
                WorkerHandle { tx, pending, handle: Some(handle) }
            })
            .collect();
        DevicePool {
            workers,
            policy: Mutex::new(policy),
            affinity: Mutex::new(HashMap::new()),
            status_scratch: Mutex::new(Vec::with_capacity(n_devices)),
            queue_capacity,
            tiers: vec![Tier::Flash; n_devices],
        }
    }

    /// Pool of simulated flash devices: every worker's engine is a
    /// [`SimFlashEngine`] holding a clone of **one** shared
    /// `Arc<LatencyTable>` — there are no per-thread `TokenSchedule`
    /// caches to build or warm, and adding devices adds no schedule work.
    pub fn simulated(
        n_devices: usize,
        queue_capacity: usize,
        policy: Box<dyn Scheduler + Send>,
        table: Arc<LatencyTable>,
    ) -> DevicePool {
        DevicePool::new(n_devices, queue_capacity, policy, move |_| {
            SimFlashEngine::new(Arc::clone(&table))
        })
    }

    /// Heterogeneous pool following a [`FleetSpec`]: flash workers run
    /// [`SimFlashEngine`]s over one shared table, GPU workers run
    /// [`SimGpuEngine`]s priced by the roofline, and the pool's status
    /// rows carry each device's tier so tier-aware policies can split.
    pub fn simulated_fleet(
        spec: &FleetSpec,
        queue_capacity: usize,
        policy: Box<dyn Scheduler + Send>,
        table: Arc<LatencyTable>,
        gpu: GpuSystem,
        model: ModelShape,
    ) -> DevicePool {
        let tiers = spec.tiers();
        let factory_tiers = tiers.clone();
        let mut pool =
            DevicePool::new(spec.n_devices(), queue_capacity, policy, move |device| {
                match factory_tiers[device] {
                    Tier::Flash => SimPoolEngine::Flash(SimFlashEngine::new(Arc::clone(&table))),
                    Tier::Gpu => {
                        SimPoolEngine::Gpu(SimGpuEngine::new(gpu.clone(), model.clone()))
                    }
                }
            });
        pool.tiers = tiers;
        pool
    }

    /// Per-device tier, in worker order.
    pub fn tiers(&self) -> &[Tier] {
        &self.tiers
    }

    pub fn n_devices(&self) -> usize {
        self.workers.len()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    fn device_status(&self, i: usize) -> DeviceStatus {
        DeviceStatus {
            device: i,
            queue_depth: self.workers[i].pending.load(Ordering::SeqCst),
            est_wait: SimTime::ZERO,
            kv_used: 0,
            kv_capacity: 0,
            tier: self.tiers[i],
            wear_used: 0,
            wear_budget: 0,
        }
    }

    /// Current per-device status (queue depths; the functional pool does
    /// not track KV bytes or per-job service estimates — the simulators'
    /// `DeviceRouter` does — so `est_wait` reads zero here and time-based
    /// policies fall through to their queue-depth/index tie-breaks).
    pub fn status(&self) -> Vec<DeviceStatus> {
        (0..self.workers.len()).map(|i| self.device_status(i)).collect()
    }

    /// Device an affine session is pinned to, if any.
    pub fn device_for(&self, session: u64) -> Option<usize> {
        self.affinity.lock().expect("affinity lock").get(&session).copied()
    }

    fn pick_device(&self, session: Option<u64>) -> usize {
        let Some(s) = session else {
            return self.pick_by_policy();
        };
        let mut aff = self.affinity.lock().expect("affinity lock");
        if let Some(&d) = aff.get(&s) {
            return d;
        }
        let d = self.pick_by_policy();
        aff.insert(s, d);
        d
    }

    fn pick_by_policy(&self) -> usize {
        let mut status = self.status_scratch.lock().expect("status lock");
        status.clear();
        status.extend((0..self.workers.len()).map(|i| self.device_status(i)));
        self.policy.lock().expect("policy lock").pick(&status, &JobInfo::unconstrained())
    }

    /// Submit a job; returns a receiver for its result, or hands the job
    /// back when the picked device's bounded queue is full (backpressure).
    pub fn submit(&self, pj: PoolJob) -> Result<Receiver<Result<PoolServed>>, SubmitError> {
        let device = self.pick_device(pj.session);
        let w = &self.workers[device];
        // Reserve a slot atomically (fetch_add, not load-then-add) so
        // concurrent submitters cannot jointly exceed the bound.
        if w.pending.fetch_add(1, Ordering::SeqCst) >= self.queue_capacity {
            w.pending.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitError::QueueFull { device, job: pj.job });
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        match w.tx.try_send(Msg::Run(pj.job, reply_tx)) {
            Ok(()) => Ok(reply_rx),
            Err(e) => {
                w.pending.fetch_sub(1, Ordering::SeqCst);
                let (msg, stopped) = match e {
                    TrySendError::Full(m) => (m, false),
                    TrySendError::Disconnected(m) => (m, true),
                };
                match msg {
                    Msg::Run(job, _) if stopped => Err(SubmitError::Stopped(job)),
                    Msg::Run(job, _) => Err(SubmitError::QueueFull { device, job }),
                    Msg::Stop => unreachable!("stop messages are only sent on drop"),
                }
            }
        }
    }

    /// Submit and wait for the result.
    pub fn run(&self, pj: PoolJob) -> Result<PoolServed> {
        match self.submit(pj) {
            Ok(rx) => rx.recv().expect("worker reply"),
            Err(e) => Err(anyhow::anyhow!("{e}")),
        }
    }
}

/// Engine whose device timing comes from a shared immutable
/// [`LatencyTable`]: token values are an echo stream (last prompt token,
/// counting up) and [`Engine::sim_job_time`] answers from the table, so
/// a pool of these measures scheduler/queueing behaviour against
/// simulated flash latency without any per-thread schedule state.
pub struct SimFlashEngine {
    table: Arc<LatencyTable>,
}

impl SimFlashEngine {
    pub fn new(table: Arc<LatencyTable>) -> SimFlashEngine {
        SimFlashEngine { table }
    }
}

impl Engine for SimFlashEngine {
    fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>> {
        let base = *prompt.last().unwrap_or(&0);
        let out: Vec<u32> = (0..max_new as u32).map(|i| base.wrapping_add(i)).collect();
        for t in &out {
            on_token(*t);
        }
        Ok(out)
    }

    fn sim_job_time(&self, l_in: usize, n_out: usize) -> Option<SimTime> {
        Some(self.table.decode_time(l_in, n_out))
    }
}

/// GPU-tier counterpart of [`SimFlashEngine`]: the same echo token
/// stream, with simulated timing answered by the [`GpuSystem`] roofline
/// (per-step `tpot` over the growing context). `sim_job_time` is `None`
/// when the model does not fit the node — the pool-level analogue of the
/// roofline's OOM rows.
pub struct SimGpuEngine {
    gpu: GpuSystem,
    model: ModelShape,
}

impl SimGpuEngine {
    pub fn new(gpu: GpuSystem, model: ModelShape) -> SimGpuEngine {
        SimGpuEngine { gpu, model }
    }
}

impl Engine for SimGpuEngine {
    fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>> {
        let base = *prompt.last().unwrap_or(&0);
        let out: Vec<u32> = (0..max_new as u32).map(|i| base.wrapping_add(i)).collect();
        for t in &out {
            on_token(*t);
        }
        Ok(out)
    }

    fn sim_job_time(&self, l_in: usize, n_out: usize) -> Option<SimTime> {
        let mut total = 0.0;
        for step in 0..n_out {
            total += self.gpu.tpot(&self.model, 1.0, l_in + step)?;
        }
        Some(SimTime::from_secs(total))
    }
}

/// Worker-engine sum type for heterogeneous pools — the factory must
/// return one concrete type, and a fleet mixes flash and GPU workers.
pub enum SimPoolEngine {
    Flash(SimFlashEngine),
    Gpu(SimGpuEngine),
}

impl Engine for SimPoolEngine {
    fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>> {
        match self {
            SimPoolEngine::Flash(e) => e.generate(prompt, max_new, on_token),
            SimPoolEngine::Gpu(e) => e.generate(prompt, max_new, on_token),
        }
    }

    fn sim_job_time(&self, l_in: usize, n_out: usize) -> Option<SimTime> {
        match self {
            SimPoolEngine::Flash(e) => e.sim_job_time(l_in, n_out),
            SimPoolEngine::Gpu(e) => e.sim_job_time(l_in, n_out),
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Stop);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::{LeastLoaded, RoundRobin};
    use std::sync::atomic::AtomicBool;
    use std::time::Duration;

    /// Echo engine: repeats the last prompt token, then counts up.
    struct MockEngine;

    impl Engine for MockEngine {
        fn generate(
            &mut self,
            prompt: &[u32],
            max_new: usize,
            on_token: &mut dyn FnMut(u32),
        ) -> Result<Vec<u32>> {
            let base = *prompt.last().unwrap_or(&0);
            let out: Vec<u32> = (0..max_new as u32).map(|i| base + i).collect();
            for t in &out {
                on_token(*t);
            }
            Ok(out)
        }
    }

    /// Engine that blocks until its gate opens — used to pin down queue
    /// depths deterministically.
    struct GateEngine {
        gate: Arc<AtomicBool>,
    }

    impl Engine for GateEngine {
        fn generate(
            &mut self,
            prompt: &[u32],
            max_new: usize,
            on_token: &mut dyn FnMut(u32),
        ) -> Result<Vec<u32>> {
            while !self.gate.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(1));
            }
            MockEngine.generate(prompt, max_new, on_token)
        }
    }

    fn job(id: u64) -> Job {
        Job { id, prompt: vec![10 * id as u32], max_new: 2 }
    }

    #[test]
    fn round_robin_spreads_jobs() {
        let pool = DevicePool::new(3, 4, Box::new(RoundRobin::new()), |_| MockEngine);
        let devices: Vec<usize> =
            (0..6).map(|i| pool.run(PoolJob::new(job(i))).unwrap().device).collect();
        assert_eq!(devices, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn session_jobs_stick_to_one_device() {
        let pool = DevicePool::new(4, 8, Box::new(RoundRobin::new()), |_| MockEngine);
        let first = pool.run(PoolJob::with_session(job(0), 42)).unwrap();
        // Interleave anonymous jobs to advance the round-robin cursor, then
        // confirm the session still lands on its original device.
        for i in 1..5 {
            pool.run(PoolJob::new(job(i))).unwrap();
        }
        for i in 5..8 {
            let served = pool.run(PoolJob::with_session(job(i), 42)).unwrap();
            assert_eq!(served.device, first.device, "session moved devices");
        }
        assert_eq!(pool.device_for(42), Some(first.device));
    }

    #[test]
    fn full_queue_applies_backpressure() {
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let pool =
            DevicePool::new(1, 2, Box::new(RoundRobin::new()), move |_| GateEngine {
                gate: Arc::clone(&g),
            });
        let r1 = pool.submit(PoolJob::new(job(1))).unwrap();
        let r2 = pool.submit(PoolJob::new(job(2))).unwrap();
        // Queue (queued + running) is at capacity: the next job bounces.
        match pool.submit(PoolJob::new(job(3))) {
            Err(SubmitError::QueueFull { device: 0, job }) => assert_eq!(job.id, 3),
            other => panic!("expected QueueFull, got {:?}", other.is_ok()),
        }
        gate.store(true, Ordering::SeqCst);
        r1.recv().unwrap().unwrap();
        r2.recv().unwrap().unwrap();
        // Capacity freed: the retry is admitted.
        pool.run(PoolJob::new(job(3))).unwrap();
    }

    #[test]
    fn least_loaded_avoids_busy_device() {
        // Device 0's engine blocks until the gate opens; device 1 is free.
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let pool = DevicePool::new(2, 4, Box::new(LeastLoaded::new()), move |device| {
            let gate =
                if device == 0 { Arc::clone(&g) } else { Arc::new(AtomicBool::new(true)) };
            GateEngine { gate }
        });
        // First job ties at depth 0 and takes device 0, where it blocks.
        let r0 = pool.submit(PoolJob::new(job(0))).unwrap();
        // Later jobs see device 0 busy and land on device 1 (run() waits
        // for completion, so each submission observes settled depths).
        let s1 = pool.run(PoolJob::new(job(1))).unwrap();
        let s2 = pool.run(PoolJob::new(job(2))).unwrap();
        assert_eq!(s1.device, 1);
        assert_eq!(s2.device, 1);
        gate.store(true, Ordering::SeqCst);
        assert_eq!(r0.recv().unwrap().unwrap().device, 0);
    }

    #[test]
    fn drop_stops_workers() {
        let pool = DevicePool::new(2, 2, Box::new(RoundRobin::new()), |_| MockEngine);
        pool.run(PoolJob::new(job(1))).unwrap();
        drop(pool); // must not hang
    }

    #[test]
    fn functional_engines_report_no_sim_time() {
        let pool = DevicePool::new(1, 2, Box::new(RoundRobin::new()), |_| MockEngine);
        assert_eq!(pool.run(PoolJob::new(job(1))).unwrap().sim, None);
    }

    #[test]
    fn simulated_pool_workers_share_one_table() {
        use crate::circuit::TechParams;
        use crate::config::presets::table1_system;
        use crate::llm::model_config::OptModel;

        let table = Arc::new(LatencyTable::build(
            &table1_system(),
            &TechParams::default(),
            OptModel::Opt6_7b.shape(),
        ));
        let pool = DevicePool::simulated(2, 4, Box::new(RoundRobin::new()), Arc::clone(&table));
        let a = pool.run(PoolJob::new(job(1))).unwrap();
        let b = pool.run(PoolJob::new(job(2))).unwrap();
        assert_eq!((a.device, b.device), (0, 1), "round-robin across both workers");
        // Both workers answer from the same shared table: identical jobs
        // (1 prompt token, 2 generated) report identical simulated time.
        let expect = table.decode_time(1, 2);
        assert!(expect > SimTime::ZERO);
        assert_eq!(a.sim, Some(expect));
        assert_eq!(b.sim, Some(expect));
    }

    #[test]
    fn simulated_fleet_mixes_engine_tiers() {
        use crate::circuit::TechParams;
        use crate::config::presets::table1_system;
        use crate::coordinator::device::default_gpu_system;
        use crate::llm::model_config::OptModel;

        let model = OptModel::Opt6_7b.shape();
        let table = Arc::new(LatencyTable::build(
            &table1_system(),
            &TechParams::default(),
            model.clone(),
        ));
        let spec = FleetSpec::parse("1xflash+1xgpu").unwrap();
        let pool = DevicePool::simulated_fleet(
            &spec,
            4,
            Box::new(RoundRobin::new()),
            Arc::clone(&table),
            default_gpu_system(),
            model.clone(),
        );
        assert_eq!(pool.tiers(), &[Tier::Flash, Tier::Gpu]);
        let a = pool.run(PoolJob::new(job(1))).unwrap();
        let b = pool.run(PoolJob::new(job(2))).unwrap();
        assert_eq!((a.device, b.device), (0, 1), "round-robin across the fleet");
        assert_eq!(a.sim, Some(table.decode_time(1, 2)), "flash worker answers from the table");
        let gpu = default_gpu_system();
        let expect = gpu.tpot(&model, 1.0, 1).unwrap() + gpu.tpot(&model, 1.0, 2).unwrap();
        assert_eq!(b.sim, Some(SimTime::from_secs(expect)), "gpu worker answers the roofline");
    }
}
