//! Calibrated GPU baselines (paper §V-B).
//!
//! Single-batch token generation is memory-bandwidth-bound (paper Fig. 1b
//! discussion), so TPOT reduces to weight traffic over aggregate HBM
//! bandwidth at a measured efficiency, plus tensor-parallel all-reduce
//! overhead per layer. Prefill (summarization) is compute-bound and uses
//! the FLOP roofline. A VRAM check reproduces the OOM entries of
//! Fig. 14a.
//!
//! Substitution note (DESIGN.md): we have no GPUs in this environment;
//! the efficiencies are calibrated to the paper's anchors (2.4× flash
//! speedup over 4×RTX4090, 46× generation/summarization gap, 4.9 % flash
//! overhead vs 4×A100 AttAcc).

use crate::llm::model_config::ModelShape;

/// A multi-GPU tensor-parallel serving system.
#[derive(Debug, Clone)]
pub struct GpuSystem {
    pub name: String,
    pub n_gpus: usize,
    /// HBM bandwidth per GPU (bytes/s).
    pub hbm_bw: f64,
    /// Dense FP16 throughput per GPU (FLOP/s).
    pub flops: f64,
    /// VRAM per GPU (bytes).
    pub vram: f64,
    /// Decode-path bandwidth efficiency (vLLM/AttAcc measured fraction).
    pub decode_eff: f64,
    /// Prefill FLOP efficiency.
    pub prefill_eff: f64,
    /// Per-layer tensor-parallel all-reduce latency (two per block).
    pub allreduce_lat: f64,
    /// Fixed per-token serving overhead (scheduler, kernel launches,
    /// sampling — dominant for small models in single-batch decode).
    pub per_token_overhead: f64,
    /// Fixed serving workspace (CUDA context, activations, vLLM pool).
    pub workspace: f64,
    /// Weight storage overhead factor (scales, fragmentation).
    pub weight_overhead: f64,
}

impl GpuSystem {
    /// Aggregate decode bandwidth.
    pub fn agg_bw(&self) -> f64 {
        self.n_gpus as f64 * self.hbm_bw * self.decode_eff
    }

    /// Does the model fit? (weights + KV pool + workspace vs usable VRAM).
    pub fn fits(&self, m: &ModelShape, bytes_per_param: f64, kv_tokens: usize) -> bool {
        let need = m.weight_bytes(bytes_per_param) * self.weight_overhead
            + m.kv_bytes(kv_tokens, bytes_per_param)
            + self.workspace;
        let usable = self.n_gpus as f64 * self.vram * 0.90;
        need <= usable
    }

    /// Decode TPOT; `None` when the model does not fit (OOM in Fig. 14a).
    pub fn tpot(&self, m: &ModelShape, bytes_per_param: f64, kv_tokens: usize) -> Option<f64> {
        if !self.fits(m, bytes_per_param, kv_tokens) {
            return None;
        }
        let traffic = m.weight_bytes(bytes_per_param) + m.kv_bytes(kv_tokens, bytes_per_param);
        let comm = m.layers as f64 * 2.0 * self.allreduce_lat;
        Some(traffic / self.agg_bw() + comm + self.per_token_overhead)
    }

    /// Prefill (summarization) latency for `tokens` input tokens.
    pub fn prefill(&self, m: &ModelShape, tokens: usize) -> f64 {
        let flop = 2.0 * m.params() as f64 * tokens as f64;
        flop / (self.n_gpus as f64 * self.flops * self.prefill_eff)
    }

    /// Generation latency for `tokens` output tokens after `kv_in` cached.
    pub fn generate(&self, m: &ModelShape, bytes_per_param: f64, kv_in: usize, tokens: usize) -> Option<f64> {
        // Context grows; integrate the affine TPOT via the midpoint.
        let mid = self.tpot(m, bytes_per_param, kv_in + tokens / 2)?;
        Some(mid * tokens as f64)
    }
}

/// 4× RTX4090 with vLLM (paper's commodity baseline).
pub fn rtx4090x4_vllm() -> GpuSystem {
    GpuSystem {
        name: "4xRTX4090 (vLLM)".into(),
        n_gpus: 4,
        hbm_bw: 1008e9,
        flops: 82.6e12, // dense FP16/BF16
        vram: 24e9,
        decode_eff: 0.47, // vLLM single-batch decode over PCIe-P2P TP
        prefill_eff: 0.25, // TP-4 prefill MFU over PCIe (no NVLink)
        allreduce_lat: 12e-6, // PCIe all-reduce, no NVLink
        per_token_overhead: 2.0e-3, // vLLM scheduler + launch overhead
        workspace: 10e9,
        weight_overhead: 1.15,
    }
}

/// 4× A100-80G through the AttAcc simulator (paper's high-end baseline).
pub fn a100x4_attacc() -> GpuSystem {
    GpuSystem {
        name: "4xA100 (AttAcc)".into(),
        n_gpus: 4,
        hbm_bw: 2039e9,
        flops: 312e12,
        vram: 80e9,
        decode_eff: 0.58, // AttAcc offloads attention to HBM-PIM
        prefill_eff: 0.55,
        allreduce_lat: 4e-6, // NVLink
        per_token_overhead: 0.5e-3, // AttAcc-simulated host overhead
        workspace: 10e9,
        weight_overhead: 1.15,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::model_config::OptModel;

    #[test]
    fn opt66b_and_175b_oom_on_4090s_w8a8() {
        // Paper Fig. 14a: OOM for OPT-66B/175B on 4×RTX4090 in W8A8.
        let g = rtx4090x4_vllm();
        assert!(g.tpot(&OptModel::Opt66b.shape(), 1.0, 1024).is_none());
        assert!(g.tpot(&OptModel::Opt175b.shape(), 1.0, 1024).is_none());
        assert!(g.tpot(&OptModel::Opt30b.shape(), 1.0, 1024).is_some());
    }

    #[test]
    fn a100s_fit_all_opt_models() {
        let g = a100x4_attacc();
        for m in OptModel::ALL {
            assert!(g.tpot(&m.shape(), 1.0, 1024).is_some(), "{}", m.shape().name);
        }
    }

    #[test]
    fn fig1b_generation_much_slower_than_summarization() {
        // Paper Fig. 1b: generating 1K tokens ≈ 46× slower than
        // summarizing 1K tokens (OPT-30B on 4×RTX4090). Tolerance 30–65×.
        let g = rtx4090x4_vllm();
        let m = OptModel::Opt30b.shape();
        let prefill = g.prefill(&m, 1024);
        let generate = g.generate(&m, 2.0, 1024, 1024).unwrap();
        let ratio = generate / prefill;
        assert!((30.0..=65.0).contains(&ratio), "ratio = {ratio:.1} (prefill {prefill:.3}s gen {generate:.3}s)");
    }

    #[test]
    fn a100_faster_than_4090() {
        let m = OptModel::Opt30b.shape();
        let a = a100x4_attacc().tpot(&m, 1.0, 1024).unwrap();
        let r = rtx4090x4_vllm().tpot(&m, 1.0, 1024).unwrap();
        assert!(a < r);
    }

    #[test]
    fn tpot_scales_with_model() {
        let g = a100x4_attacc();
        let small = g.tpot(&OptModel::Opt6_7b.shape(), 1.0, 1024).unwrap();
        let big = g.tpot(&OptModel::Opt175b.shape(), 1.0, 1024).unwrap();
        assert!(big > 10.0 * small);
    }
}
