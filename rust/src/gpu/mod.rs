//! GPU baseline models (paper §V-B): 4×RTX4090 running vLLM and 4×A100
//! driven through the AttAcc simulator. Single-batch decode is memory-
//! bandwidth-bound, so both reduce to calibrated rooflines with
//! tensor-parallel communication overhead and a VRAM-capacity (OOM) check.

pub mod roofline;

pub use roofline::{a100x4_attacc, rtx4090x4_vllm, GpuSystem};
