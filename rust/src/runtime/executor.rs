//! The decode-step executor: owns the compiled decode HLO, keeps the
//! weights resident as device buffers (uploaded once — the hot path
//! re-uploads only the token/pos/KV state), and implements the
//! [`crate::coordinator::serve::Engine`] trait for the serving loop.
//!
//! Decode-step signature (fixed by `python/compile/aot.py`):
//! `(token i32[1], pos i32[1], kv f32[L,2,S,D], w_0 … w_{n-1}) →
//!  (logits f32[V], kv' f32[L,2,S,D])` — greedy argmax sampling here.

use super::artifact::ArtifactBundle;
use super::client::{i32_literal, RuntimeClient};
use crate::coordinator::serve::Engine;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Compiled, weight-resident decode executor.
pub struct DecodeExecutor {
    #[allow(dead_code)]
    rt: RuntimeClient,
    exe: xla::PjRtLoadedExecutable,
    /// Host-pinned weight literals in positional order (uploaded per
    /// execute; the PJRT CPU client aliases host memory).
    weight_lits: Vec<xla::Literal>,
    pub bundle: ArtifactBundle,
    /// Host-side KV state (f32, `[L,2,S,D]` row-major).
    kv: Vec<f32>,
    /// Next position to write.
    pos: usize,
}

impl DecodeExecutor {
    /// Load + compile from an artifacts directory.
    pub fn load(dir: &Path) -> Result<DecodeExecutor> {
        let bundle = ArtifactBundle::load(dir)?;
        let rt = RuntimeClient::cpu()?;
        let exe = rt.compile_hlo_text(&bundle.decode_hlo)?;
        let mut weight_lits = Vec::with_capacity(bundle.weights.len());
        for (name, arr) in &bundle.weights {
            let vals = arr.as_f32().with_context(|| format!("weight {name} must be f32"))?;
            let dims: Vec<i64> = arr.shape.iter().map(|d| *d as i64).collect();
            weight_lits.push(super::client::f32_literal(&vals, &dims)?);
        }
        let kv = vec![0.0f32; bundle.kv_len()];
        Ok(DecodeExecutor { rt, exe, weight_lits, bundle, kv, pos: 0 })
    }

    /// Reset the sequence state.
    pub fn reset(&mut self) {
        self.kv.fill(0.0);
        self.pos = 0;
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// Run one decode step for `token`; returns the logits.
    pub fn step(&mut self, token: u32) -> Result<Vec<f32>> {
        if self.pos >= self.bundle.max_seq {
            bail!("sequence exceeds max_seq={}", self.bundle.max_seq);
        }
        let [l, two, s, d] = self.bundle.kv_shape();
        let token_lit = i32_literal(&[token as i32], &[1])?;
        let pos_lit = i32_literal(&[self.pos as i32], &[1])?;
        let kv_lit = super::client::f32_literal(
            &self.kv,
            &[l as i64, two as i64, s as i64, d as i64],
        )?;
        // Literal args: state re-marshalled per step, weights borrowed
        // from the resident pool.
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 + self.weight_lits.len());
        args.push(&token_lit);
        args.push(&pos_lit);
        args.push(&kv_lit);
        for w in &self.weight_lits {
            args.push(w);
        }
        let result = self.exe.execute::<&xla::Literal>(&args).context("decode step execute")?;
        let out = result[0][0].to_literal_sync()?;
        let (logits_lit, kv_lit_out) = out.to_tuple2()?;
        let logits = logits_lit.to_vec::<f32>()?;
        let kv_new = kv_lit_out.to_vec::<f32>()?;
        if kv_new.len() != self.kv.len() {
            bail!("kv size mismatch: {} vs {}", kv_new.len(), self.kv.len());
        }
        self.kv = kv_new;
        self.pos += 1;
        Ok(logits)
    }

    /// Greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, v) in logits.iter().enumerate() {
            if *v > best_v {
                best_v = *v;
                best = i;
            }
        }
        best as u32
    }
}

impl Engine for DecodeExecutor {
    fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>> {
        self.reset();
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        // Prefill = sequential decode over the prompt (single AOT graph).
        let mut logits = Vec::new();
        for t in prompt {
            logits = self.step(*t)?;
        }
        let mut out = Vec::with_capacity(max_new);
        let budget = max_new.min(self.bundle.max_seq.saturating_sub(self.pos));
        let mut next = Self::argmax(&logits);
        for _ in 0..budget {
            out.push(next);
            on_token(next);
            logits = self.step(next)?;
            next = Self::argmax(&logits);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(DecodeExecutor::argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(DecodeExecutor::argmax(&[-5.0, -1.0, -3.0]), 1);
        assert_eq!(DecodeExecutor::argmax(&[2.0]), 0);
    }
    // Full executor tests live in rust/tests/runtime_e2e.rs (they need
    // `make artifacts` to have run).
}
