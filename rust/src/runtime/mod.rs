//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + npy weights + manifest, see `python/compile/aot.py`) and
//! executes the functional decode step from the rust serving path.
//! Python never runs at serving time.

pub mod artifact;
pub mod client;
pub mod executor;
pub mod tokenizer;

pub use artifact::ArtifactBundle;
pub use client::RuntimeClient;
pub use executor::DecodeExecutor;
pub use tokenizer::ByteTokenizer;
