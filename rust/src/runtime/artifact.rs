//! Artifact bundle layout (written by `python/compile/aot.py`):
//!
//! ```text
//! artifacts/
//!   manifest.txt          # TOML-lite: model dims + file names
//!   decode_step.hlo.txt   # HLO text of one decode step
//!   weights/NNN_name.npy  # ordered weight tensors (f32)
//!   loss_curve.txt        # optional: training log
//! ```
//!
//! The decode-step argument order is `token, pos, kv, w_0 … w_{n-1}`
//! with the weights in the sorted order of their file names — the same
//! order `aot.py` passed them to `jax.jit(...).lower(...)`.

use crate::config::toml_lite;
use crate::util::npy::{self, NpyArray};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed manifest + loaded weights.
pub struct ArtifactBundle {
    pub dir: PathBuf,
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub max_seq: usize,
    pub decode_hlo: PathBuf,
    /// (name, tensor) in positional-argument order.
    pub weights: Vec<(String, NpyArray)>,
}

impl ArtifactBundle {
    /// Load from an artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactBundle> {
        let manifest = dir.join("manifest.txt");
        let doc = toml_lite::parse_file(&manifest)
            .with_context(|| format!("loading manifest {}", manifest.display()))?;
        let name = doc.str_or("model", "name", "unknown")?;
        let vocab = doc.require("model", "vocab")?.as_usize()?;
        let d_model = doc.require("model", "d_model")?.as_usize()?;
        let layers = doc.require("model", "layers")?.as_usize()?;
        let heads = doc.require("model", "heads")?.as_usize()?;
        let max_seq = doc.require("model", "max_seq")?.as_usize()?;
        let decode_hlo = dir.join(doc.str_or("artifacts", "decode_hlo", "decode_step.hlo.txt")?);
        if !decode_hlo.exists() {
            bail!("decode HLO missing: {}", decode_hlo.display());
        }
        let weights_dir = dir.join(doc.str_or("artifacts", "weights_dir", "weights")?);
        let weights = npy::read_dir(&weights_dir)
            .with_context(|| format!("loading weights from {}", weights_dir.display()))?;
        if weights.is_empty() {
            bail!("no weights in {}", weights_dir.display());
        }
        Ok(ArtifactBundle { dir: dir.to_path_buf(), name, vocab, d_model, layers, heads, max_seq, decode_hlo, weights })
    }

    /// KV cache element count: `[layers, 2, max_seq, d_model]`.
    pub fn kv_len(&self) -> usize {
        self.layers * 2 * self.max_seq * self.d_model
    }

    /// KV cache shape.
    pub fn kv_shape(&self) -> [usize; 4] {
        [self.layers, 2, self.max_seq, self.d_model]
    }

    /// The default artifacts directory (`$REPRO_ARTIFACTS` or `artifacts/`
    /// next to the workspace root).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("REPRO_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from("artifacts")
    }

    /// Whether a usable bundle exists at the default location.
    pub fn available() -> bool {
        Self::default_dir().join("manifest.txt").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::npy::NpyArray;

    fn write_fake_bundle(dir: &Path) {
        std::fs::create_dir_all(dir.join("weights")).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            r#"
[model]
name = "toy"
vocab = 256
d_model = 64
layers = 2
heads = 4
max_seq = 32
[artifacts]
decode_hlo = "decode_step.hlo.txt"
weights_dir = "weights"
"#,
        )
        .unwrap();
        std::fs::write(dir.join("decode_step.hlo.txt"), "HloModule fake").unwrap();
        npy::write(
            &dir.join("weights/000_emb.npy"),
            &NpyArray::from_f32(&vec![0.0; 64], &[1, 64]),
        )
        .unwrap();
        npy::write(
            &dir.join("weights/001_w.npy"),
            &NpyArray::from_f32(&vec![0.0; 8], &[2, 4]),
        )
        .unwrap();
    }

    #[test]
    fn loads_manifest_and_ordered_weights() {
        let dir = std::env::temp_dir().join("flashpim_artifact_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_fake_bundle(&dir);
        let b = ArtifactBundle::load(&dir).unwrap();
        assert_eq!(b.vocab, 256);
        assert_eq!(b.kv_shape(), [2, 2, 32, 64]);
        assert_eq!(b.weights.len(), 2);
        assert_eq!(b.weights[0].0, "000_emb");
        assert_eq!(b.weights[1].0, "001_w");
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("flashpim_artifact_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ArtifactBundle::load(&dir).is_err());
    }
}
