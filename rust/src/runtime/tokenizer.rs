//! Byte-level tokenizer for the functional OPT-toy model: ids 0–255 are
//! raw bytes (vocab 256). Keeps the E2E path dependency-free.

/// Byte-level tokenizer.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|t| (*t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let ids = t.encode("hello flash");
        assert_eq!(ids.len(), 11);
        assert_eq!(t.decode(&ids), "hello flash");
    }

    #[test]
    fn ids_below_vocab() {
        let t = ByteTokenizer;
        for id in t.encode("any UTF-8 ✓ text") {
            assert!(id < ByteTokenizer::VOCAB as u32);
        }
    }

    #[test]
    fn decode_clamps() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[72, 105]), "Hi");
    }
}
