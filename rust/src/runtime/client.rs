//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile, expose buffer helpers. (HLO *text* is the interchange format
//! — the image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos;
//! see /opt/xla-example/README.md.)

use anyhow::{Context, Result};
use std::path::Path;

/// PJRT client + compile cache.
pub struct RuntimeClient {
    pub client: xla::PjRtClient,
}

impl RuntimeClient {
    /// CPU client.
    pub fn cpu() -> Result<RuntimeClient> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
    }

    /// Upload a literal to a device-resident buffer (device 0).
    pub fn to_buffer(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_literal(None, lit).context("uploading literal")
    }
}

/// Build an f32 literal with a shape.
pub fn f32_literal(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}

/// Build an i32 literal with a shape.
pub fn i32_literal(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise the real PJRT CPU plugin — they are the
    // "runtime substrate works" smoke checks.
    #[test]
    fn cpu_client_boots() {
        let c = RuntimeClient::cpu().unwrap();
        assert_eq!(c.platform(), "cpu");
    }

    #[test]
    fn literal_shapes() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let i = i32_literal(&[7], &[1]).unwrap();
        assert_eq!(i.element_count(), 1);
    }

    #[test]
    fn compile_and_run_builder_computation() {
        // End-to-end PJRT sanity without artifacts: builder → compile →
        // execute → readback.
        let c = RuntimeClient::cpu().unwrap();
        let b = xla::XlaBuilder::new("t");
        let x = b.parameter_s(0, &xla::Shape::array::<f32>(vec![2]), "x").unwrap();
        let comp = (x.clone() + x).unwrap().build().unwrap();
        let exe = c.client.compile(&comp).unwrap();
        let arg = xla::Literal::vec1(&[1.5f32, 2.5f32]);
        let out = exe.execute::<xla::Literal>(&[arg]).unwrap()[0][0].to_literal_sync().unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![3.0f32, 5.0f32]);
    }
}
