//! `repro` CLI — entry point for the flashpim experiments.
fn main() -> anyhow::Result<()> {
    flashpim::cli::run(std::env::args().skip(1).collect())
}
