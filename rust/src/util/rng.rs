//! Deterministic pseudo-random number generation (SplitMix64 core).
//!
//! Used by workload generators, the property-testing kit, and synthetic
//! weight/activation generation. Deterministic seeding keeps every
//! experiment reproducible from the CLI seed.

/// SplitMix64 PRNG. Small state, excellent statistical quality for
/// simulation workloads, and trivially seedable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a new generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform value in `[0, n)` without modulo bias (Lemire's
    /// multiply-shift with rejection). `n` must be non-zero.
    fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "below(0)");
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut low = m as u64;
        if low < n {
            // Rejection threshold: 2^64 mod n. Values below it belong to
            // the truncated final stripe and would bias the low outputs.
            let threshold = n.wrapping_neg() % n;
            while low < threshold {
                m = (self.next_u64() as u128) * (n as u128);
                low = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)` (`hi > lo`), bias-free.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range [{lo}, {hi})");
        lo + self.below(hi - lo)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform i64 in `[lo, hi)`.
    ///
    /// The width is computed with `wrapping_sub` as a `u64`: `hi - lo`
    /// overflows `i64` for spans wider than `i64::MAX` (e.g.
    /// `lo = i64::MIN`), and two's-complement wraparound makes both the
    /// width and the `lo + offset` re-shift exact.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo, "empty range [{lo}, {hi})");
        let width = hi.wrapping_sub(lo) as u64;
        lo.wrapping_add(self.below(width) as i64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choice on empty slice");
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// A vector of `n` i8 values uniform over the full range — synthetic
    /// W8A8 tensor data.
    pub fn vec_i8(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| (self.next_u64() & 0xFF) as u8 as i8).collect()
    }

    /// A vector of `n` f32 values uniform in `[lo, hi)`.
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + self.f64() as f32 * (hi - lo)).collect()
    }

    /// Fork a child generator (stream-split) — used by the property kit so
    /// each case gets an independent stream.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(9);
        assert!(!r.chance(0.0));
        assert!(r.chance(1.0));
    }

    #[test]
    fn range_i64_extreme_bounds() {
        // Regression: `(hi - lo)` used to overflow i64 (panic in debug)
        // for spans wider than i64::MAX.
        let mut r = Rng::new(1234);
        for _ in 0..1000 {
            let x = r.range_i64(i64::MIN, i64::MAX);
            assert!(x < i64::MAX);
        }
        for _ in 0..100 {
            assert_eq!(r.range_i64(i64::MAX - 1, i64::MAX), i64::MAX - 1);
            assert_eq!(r.range_i64(i64::MIN, i64::MIN + 1), i64::MIN);
        }
        let x = r.range_i64(-3, 4);
        assert!((-3..4).contains(&x));
    }

    #[test]
    fn range_u64_full_width() {
        let mut r = Rng::new(4321);
        for _ in 0..1000 {
            let x = r.range_u64(0, u64::MAX);
            assert!(x < u64::MAX);
        }
        assert_eq!(r.range_u64(7, 8), 7);
    }

    #[test]
    fn range_u64_unbiased_over_small_width() {
        // Width 3 does not divide 2^64; the old `% width` draw was biased.
        // Rejection sampling keeps each bucket near n/3 (σ ≈ 82 here; the
        // stream is deterministic, so this either always passes or never).
        let mut r = Rng::new(77);
        let mut counts = [0u32; 3];
        let n = 30_000;
        for _ in 0..n {
            counts[r.range_u64(0, 3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - n as f64 / 3.0).abs() < 500.0, "skewed: {counts:?}");
        }
    }
}
