//! Minimal ASCII table rendering for CLI/bench output — the benches print
//! the same rows/series the paper's tables and figures report.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// An ASCII table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given headers; all columns right-aligned
    /// except the first.
    pub fn new(headers: &[&str]) -> Table {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), aligns, rows: Vec::new() }
    }

    /// Override the alignment of a column.
    pub fn align(mut self, col: usize, a: Align) -> Table {
        self.aligns[col] = a;
        self
    }

    /// Append a row. Panics if the arity differs from the header.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Append a row of string slices.
    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Render to a string with a header separator.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut out = String::new();
            for i in 0..ncol {
                if i > 0 {
                    out.push_str("  ");
                }
                let w = widths[i];
                let c = &cells[i];
                let pad = w - c.chars().count();
                match self.aligns[i] {
                    Align::Left => {
                        out.push_str(c);
                        out.extend(std::iter::repeat(' ').take(pad));
                    }
                    Align::Right => {
                        out.extend(std::iter::repeat(' ').take(pad));
                        out.push_str(c);
                    }
                }
            }
            out.trim_end().to_string()
        };
        let mut s = fmt_row(&self.headers);
        s.push('\n');
        s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row));
            s.push('\n');
        }
        s
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["long-name", "12345"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].ends_with("12345"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
