//! Descriptive statistics over f64 samples — used by the bench harness and
//! the serving metrics.

/// Summary statistics of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Population standard deviation (÷ n), not the sample estimator
    /// (÷ n−1): the serving reports summarize complete runs, not draws
    /// from a larger population.
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Empty input yields
    /// an all-zero summary.
    ///
    /// NaN samples are rejected up front with a clear panic (they used to
    /// surface as an opaque `partial_cmp` failure deep inside the sort
    /// comparator, and a NaN would silently poison mean/stddev anyway).
    pub fn of(samples: &[f64]) -> Summary {
        let nan = samples.iter().filter(|x| x.is_nan()).count();
        assert!(nan == 0, "Summary::of: {nan} NaN sample(s) among {} values", samples.len());
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary::of_sorted(&sorted)
    }

    /// Compute a summary from **already sorted** (ascending, NaN-free)
    /// samples without re-sorting. `Summary::of(xs)` and
    /// `Summary::of_sorted(&sorted(xs))` are bit-identical by construction
    /// (both reduce the same sorted array, in the same order), which is
    /// what lets streaming collectors sort once per metric and still
    /// reproduce the materialized path byte for byte. Empty input yields
    /// an all-zero summary.
    pub fn of_sorted(sorted: &[f64]) -> Summary {
        debug_assert!(
            sorted.windows(2).all(|w| w[0] <= w[1]),
            "Summary::of_sorted requires ascending, NaN-free input"
        );
        if sorted.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(sorted, 50.0),
            p90: percentile_sorted(sorted, 90.0),
            p95: percentile_sorted(sorted, 95.0),
            p99: percentile_sorted(sorted, 99.0),
        }
    }
}

/// Streaming sample accumulator: O(1) running count/mean/M2 (Welford) for
/// mid-stream reads, with the samples retained so [`Self::finish`] can do
/// one sorted flush into an **exact** [`Summary`] — identical, bit for
/// bit, to `Summary::of` over the same multiset (percentiles need order
/// statistics, and a bounded sketch would break the bit-identity the
/// serving metrics guarantee).
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Streaming {
    pub fn new() -> Streaming {
        Streaming::default()
    }

    /// Fold one sample in. Panics on NaN (mirrors [`Summary::of`]).
    pub fn push(&mut self, x: f64) {
        assert!(!x.is_nan(), "Streaming::push: NaN sample");
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Running mean — O(1), no flush. (May differ from the flushed
    /// `Summary::mean` in the last few ulps: Welford folds in insertion
    /// order, the flush sums in sorted order.)
    pub fn running_mean(&self) -> f64 {
        if self.samples.is_empty() { 0.0 } else { self.mean }
    }

    /// Running population standard deviation (÷ n) — O(1), no flush.
    pub fn running_stddev(&self) -> f64 {
        if self.samples.is_empty() { 0.0 } else { (self.m2 / self.samples.len() as f64).sqrt() }
    }

    /// Sort the retained samples once and reduce them exactly as
    /// [`Summary::of`] would.
    pub fn finish(mut self) -> Summary {
        self.samples.sort_by(f64::total_cmp);
        Summary::of_sorted(&self.samples)
    }
}

/// Percentile (nearest-rank with linear interpolation) over a sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "percentile_sorted requires ascending, NaN-free input"
    );
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Geometric mean; 0 for empty input. Panics on non-positive entries.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean over non-positive value {x}");
            x.ln()
        })
        .sum();
    (s / xs.len() as f64).exp()
}

/// Relative difference `|a-b| / max(|a|,|b|)`; 0 when both are 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs());
    if denom == 0.0 { 0.0 } else { (a - b).abs() / denom }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    #[should_panic(expected = "NaN sample(s)")]
    fn summary_rejects_nan_up_front() {
        Summary::of(&[1.0, f64::NAN, 3.0]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "ascending")]
    fn percentile_rejects_unsorted_in_debug() {
        percentile_sorted(&[3.0, 1.0, 2.0], 50.0);
    }

    #[test]
    fn of_sorted_matches_of_bit_for_bit() {
        let samples = [5.0, 1.0, 4.0, 1.5, 3.0, 2.0, 2.0, 9.5];
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        assert_eq!(Summary::of(&samples), Summary::of_sorted(&sorted));
        assert_eq!(Summary::of(&[]), Summary::of_sorted(&[]));
    }

    #[test]
    fn streaming_flush_matches_summary_of() {
        let samples = [0.25, 7.0, 3.5, 3.5, 1.0, 0.125, 42.0];
        let mut s = Streaming::new();
        for x in samples {
            s.push(x);
        }
        assert_eq!(s.n(), samples.len());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((s.running_mean() - mean).abs() < 1e-12);
        assert!(s.running_stddev() > 0.0);
        assert_eq!(s.finish(), Summary::of(&samples), "flush must be bit-identical");
        let empty = Streaming::new();
        assert_eq!(empty.running_mean(), 0.0);
        assert_eq!(empty.finish(), Summary::of(&[]));
    }

    #[test]
    #[should_panic(expected = "NaN sample")]
    fn streaming_rejects_nan() {
        Streaming::new().push(f64::NAN);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 4.0, 16.0]);
        assert!((g - 4.0).abs() < 1e-9, "g={g}");
    }

    #[test]
    fn rel_diff_symmetry() {
        assert!((rel_diff(10.0, 12.0) - rel_diff(12.0, 10.0)).abs() < 1e-15);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }
}
