//! A miniature property-based testing kit (the session registry has no
//! `proptest`). Each property runs many cases from a deterministic seed
//! sequence; failures report the seed so the case replays exactly.
//!
//! ```no_run
//! use flashpim::util::testkit::check;
//! check("addition commutes", 256, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handle wrapping a forked RNG.
pub struct Gen {
    rng: Rng,
    /// Seed for this case — printed on failure for replay.
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one item from a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choice(xs)
    }

    /// A power of two in `[2^lo_exp, 2^hi_exp]`.
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> usize {
        1usize << self.rng.range(lo_exp as usize, hi_exp as usize + 1)
    }

    /// Vector of i8 of the given length.
    pub fn vec_i8(&mut self, n: usize) -> Vec<i8> {
        self.rng.vec_i8(n)
    }

    /// Vector of f64 in range.
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    /// Access the underlying RNG for custom generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the test) on the
/// first case returning `Err`, printing the case seed and message.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, SEED_BASE, &mut prop);
}

/// Default base seed for [`check`].
const SEED_BASE: u64 = 0xF1A5_4B1D_5EED_0001;

/// Like [`check`] but with an explicit base seed (replay a failure).
pub fn check_seeded<F>(name: &str, cases: usize, base_seed: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut master = Rng::new(base_seed);
    for case in 0..cases {
        let seed = master.next_u64();
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!("property {name:?} failed at case {case} (seed=0x{seed:016x}): {msg}");
        }
    }
}

/// Replay a single case by seed — paste the seed from a failure message.
pub fn replay<F>(name: &str, seed: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Rng::new(seed), seed };
    if let Err(msg) = prop(&mut g) {
        panic!("property {name:?} replay failed (seed=0x{seed:016x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum nonneg", 64, |g| {
            let v = g.vec_f64(8, 0.0, 1.0);
            if v.iter().sum::<f64>() >= 0.0 { Ok(()) } else { Err("negative".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_seed() {
        check("always fails eventually", 16, |g| {
            if g.usize_in(0, 4) < 3 { Ok(()) } else { Err("hit".into()) }
        });
    }

    #[test]
    fn pow2_in_range() {
        check("pow2 bounds", 128, |g| {
            let x = g.pow2(3, 10);
            if x >= 8 && x <= 1024 && x.is_power_of_two() { Ok(()) } else { Err(format!("{x}")) }
        });
    }
}
