//! A criterion-less micro/macro benchmark harness (the session registry has
//! no `criterion`). Benches under `rust/benches/` use this to time closures
//! and print both timing rows and the paper's figure/table series.
//!
//! For machine-readable perf tracking, [`JsonEmitter`] collects named
//! metrics (timing summaries and derived rates like events/s) and writes
//! them as a dependency-free JSON document — `make bench-json` uses it to
//! produce `BENCH_serving.json`, which CI uploads per PR so the serving
//! hot path's trajectory is visible across changes.

use super::stats::Summary;
use std::io::Write;
use std::path::Path;
use std::time::{Duration, Instant};

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup_iters: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Hard cap on total measured time; the runner stops early past this.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 30, max_total: Duration::from_secs(10) }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            self.name,
            super::units::fmt_time(self.summary.mean),
            super::units::fmt_time(self.summary.p50),
            super::units::fmt_time(self.summary.p99),
            self.summary.n
        );
    }
}

/// Time `f` under `cfg`, returning per-iteration statistics. The closure's
/// return value is passed through `std::hint::black_box` to prevent the
/// optimizer from deleting the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let start_all = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if start_all.elapsed() > cfg.max_total {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Convenience: run with default config and print immediately.
pub fn quick<T>(name: &str, f: impl FnMut() -> T) -> BenchResult {
    let r = bench(name, &BenchConfig::default(), f);
    r.print();
    r
}

/// Section header for bench output, mirroring the paper artifact the bench
/// regenerates (e.g. "Fig 6a — PIM latency vs N_row").
pub fn section(title: &str) {
    println!();
    println!("== {title} ==");
}

/// One named metric of a bench run: a value and its unit (`"s"`,
/// `"events/s"`, `"requests/s"`, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Collects metrics and writes them as JSON — no serde in the registry,
/// so the document is emitted by hand (flat schema, numbers and strings
/// only). Non-finite values serialize as `null` (JSON has no NaN/inf).
#[derive(Debug, Clone, Default)]
pub struct JsonEmitter {
    metrics: Vec<Metric>,
}

impl JsonEmitter {
    pub fn new() -> JsonEmitter {
        JsonEmitter::default()
    }

    /// Record one named metric.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        self.metrics.push(Metric { name: name.to_string(), value, unit: unit.to_string() });
    }

    /// Record a [`BenchResult`]'s timing summary: `<name>_mean_s` and
    /// `<name>_p50_s` (seconds per iteration).
    pub fn result(&mut self, r: &BenchResult) {
        let slug: String = r
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        self.metric(&format!("{slug}_mean_s"), r.summary.mean, "s");
        self.metric(&format!("{slug}_p50_s"), r.summary.p50, "s");
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// Render the JSON document.
    pub fn render(&self) -> String {
        let mut out = String::from("{\n  \"schema\": \"flashpim-bench-v1\",\n  \"metrics\": [\n");
        for (i, m) in self.metrics.iter().enumerate() {
            let value = if m.value.is_finite() {
                format!("{:e}", m.value)
            } else {
                "null".to_string()
            };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"value\": {}, \"unit\": \"{}\"}}{}\n",
                escape_json(&m.name),
                value,
                escape_json(&m.unit),
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the document to `path` (truncating).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// metric names and units are code-controlled, but stay well-formed
/// regardless.
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, max_total: Duration::from_secs(2) };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.n >= 1);
    }

    #[test]
    fn json_emitter_renders_and_writes_valid_document() {
        let mut j = JsonEmitter::new();
        assert!(j.is_empty());
        j.metric("serving_events_per_s", 1.25e6, "events/s");
        j.metric("sweep_wall_s", 2.5, "s");
        j.metric("bad \"name\"\\", f64::INFINITY, "s");
        let doc = j.render();
        assert!(doc.contains("\"schema\": \"flashpim-bench-v1\""));
        assert!(doc.contains("\"serving_events_per_s\""));
        assert!(doc.contains("\"events/s\""));
        assert!(doc.contains("\\\"name\\\"\\\\"), "quotes and backslashes escape");
        assert!(doc.contains("null"), "non-finite values serialize as null");
        // Commas separate entries; the last entry has none.
        assert_eq!(doc.matches("},\n").count(), 2);
        let path = std::env::temp_dir().join("flashpim_bench_emit_test.json");
        j.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), doc);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_emitter_slugs_result_names() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 2, max_total: Duration::from_secs(1) };
        let r = bench("serving: 1M requests", &cfg, || 1 + 1);
        let mut j = JsonEmitter::new();
        j.result(&r);
        let doc = j.render();
        assert!(doc.contains("serving__1m_requests_mean_s"), "doc: {doc}");
        assert!(doc.contains("serving__1m_requests_p50_s"));
    }

    #[test]
    fn respects_max_total() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 1_000_000, max_total: Duration::from_millis(50) };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.summary.n < 1_000_000);
    }
}
