//! A criterion-less micro/macro benchmark harness (the session registry has
//! no `criterion`). Benches under `rust/benches/` use this to time closures
//! and print both timing rows and the paper's figure/table series.

use super::stats::Summary;
use std::time::{Duration, Instant};

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup_iters: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Hard cap on total measured time; the runner stops early past this.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 30, max_total: Duration::from_secs(10) }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            self.name,
            super::units::fmt_time(self.summary.mean),
            super::units::fmt_time(self.summary.p50),
            super::units::fmt_time(self.summary.p99),
            self.summary.n
        );
    }
}

/// Time `f` under `cfg`, returning per-iteration statistics. The closure's
/// return value is passed through `std::hint::black_box` to prevent the
/// optimizer from deleting the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let start_all = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if start_all.elapsed() > cfg.max_total {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Convenience: run with default config and print immediately.
pub fn quick<T>(name: &str, f: impl FnMut() -> T) -> BenchResult {
    let r = bench(name, &BenchConfig::default(), f);
    r.print();
    r
}

/// Section header for bench output, mirroring the paper artifact the bench
/// regenerates (e.g. "Fig 6a — PIM latency vs N_row").
pub fn section(title: &str) {
    println!();
    println!("== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, max_total: Duration::from_secs(2) };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.n >= 1);
    }

    #[test]
    fn respects_max_total() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 1_000_000, max_total: Duration::from_millis(50) };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.summary.n < 1_000_000);
    }
}
