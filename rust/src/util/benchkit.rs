//! A criterion-less micro/macro benchmark harness (the session registry has
//! no `criterion`). Benches under `rust/benches/` use this to time closures
//! and print both timing rows and the paper's figure/table series.
//!
//! For machine-readable perf tracking, [`JsonEmitter`] collects named
//! metrics (timing summaries and derived rates like events/s) and writes
//! them as a dependency-free JSON document — `make bench-json` uses it to
//! produce `BENCH_serving.json`, which CI uploads per PR so the serving
//! hot path's trajectory is visible across changes.

use super::stats::Summary;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;
use std::time::{Duration, Instant};

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup_iters: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Hard cap on total measured time; the runner stops early past this.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 30, max_total: Duration::from_secs(10) }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            self.name,
            super::units::fmt_time(self.summary.mean),
            super::units::fmt_time(self.summary.p50),
            super::units::fmt_time(self.summary.p99),
            self.summary.n
        );
    }
}

/// Time `f` under `cfg`, returning per-iteration statistics. The closure's
/// return value is passed through `std::hint::black_box` to prevent the
/// optimizer from deleting the work.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let start_all = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if start_all.elapsed() > cfg.max_total {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Convenience: run with default config and print immediately.
pub fn quick<T>(name: &str, f: impl FnMut() -> T) -> BenchResult {
    let r = bench(name, &BenchConfig::default(), f);
    r.print();
    r
}

/// Section header for bench output, mirroring the paper artifact the bench
/// regenerates (e.g. "Fig 6a — PIM latency vs N_row").
pub fn section(title: &str) {
    println!();
    println!("== {title} ==");
}

/// One named metric of a bench run: a value and its unit (`"s"`,
/// `"events/s"`, `"requests/s"`, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Collects metrics and writes them as JSON — no serde in the registry,
/// so the document is emitted by hand (flat schema, numbers and strings
/// only). Non-finite values serialize as `null` (JSON has no NaN/inf).
#[derive(Debug, Clone, Default)]
pub struct JsonEmitter {
    metrics: Vec<Metric>,
}

impl JsonEmitter {
    pub fn new() -> JsonEmitter {
        JsonEmitter::default()
    }

    /// Record one named metric.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        self.metrics.push(Metric { name: name.to_string(), value, unit: unit.to_string() });
    }

    /// Record a [`BenchResult`]'s timing summary: `<name>_mean_s` and
    /// `<name>_p50_s` (seconds per iteration).
    pub fn result(&mut self, r: &BenchResult) {
        let slug: String = r
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        self.metric(&format!("{slug}_mean_s"), r.summary.mean, "s");
        self.metric(&format!("{slug}_p50_s"), r.summary.p50, "s");
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// The collected metrics, in emission order.
    pub fn metrics(&self) -> &[Metric] {
        &self.metrics
    }

    /// Render the JSON document.
    pub fn render(&self) -> String {
        let mut out = String::from("{\n  \"schema\": \"flashpim-bench-v1\",\n  \"metrics\": [\n");
        for (i, m) in self.metrics.iter().enumerate() {
            let value = if m.value.is_finite() {
                format!("{:e}", m.value)
            } else {
                "null".to_string()
            };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"value\": {}, \"unit\": \"{}\"}}{}\n",
                escape_json(&m.name),
                value,
                escape_json(&m.unit),
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the document to `path` (truncating), creating missing
    /// parent directories, with the failing path named in any error.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating output directory {}", dir.display()))?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating bench JSON {}", path.display()))?;
        f.write_all(self.render().as_bytes())
            .with_context(|| format!("writing bench JSON {}", path.display()))
    }
}

/// Read a metrics document written by [`JsonEmitter`] (or any JSON with
/// the same `{"schema", "metrics": [{name, value, unit}]}` shape) back
/// into [`Metric`]s — the reader half the campaign baseline differ pairs
/// with the emitter. `null` values (the emitter's encoding for non-finite
/// numbers) come back as NaN.
pub fn read_metrics(path: &Path) -> Result<Vec<Metric>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench JSON {}", path.display()))?;
    parse_metrics(&text).with_context(|| format!("parsing bench JSON {}", path.display()))
}

/// Parse the emitter's document shape from a string (see [`read_metrics`]).
pub fn parse_metrics(text: &str) -> Result<Vec<Metric>> {
    let doc = json::parse(text)?;
    let metrics = doc
        .get("metrics")
        .and_then(json::Value::as_array)
        .context("document has no \"metrics\" array")?;
    let mut out = Vec::with_capacity(metrics.len());
    for (i, m) in metrics.iter().enumerate() {
        let field = |key: &str| {
            m.get(key).with_context(|| format!("metric {i} is missing field {key:?}"))
        };
        let name = field("name")?.as_str().with_context(|| format!("metric {i}: name"))?;
        let unit = field("unit")?.as_str().with_context(|| format!("metric {i}: unit"))?;
        let value = match field("value")? {
            json::Value::Null => f64::NAN,
            v => v.as_f64().with_context(|| format!("metric {i} ({name}): numeric value"))?,
        };
        out.push(Metric { name: name.to_string(), value, unit: unit.to_string() });
    }
    Ok(out)
}

/// Minimal recursive-descent JSON reader — no serde in the registry, so
/// the [`JsonEmitter`] documents are read back by hand. Full JSON value
/// grammar (objects, arrays, strings with escapes, numbers, literals);
/// errors carry the byte offset.
mod json {
    use anyhow::{bail, Result};

    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        /// Object field lookup (first match; `None` on non-objects).
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }

        pub fn as_array(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(items) => Some(items),
                _ => None,
            }
        }

        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing content at byte {}", p.pos);
        }
        Ok(v)
    }

    struct Parser<'a> {
        bytes: &'a [u8],
        pos: usize,
    }

    impl Parser<'_> {
        fn skip_ws(&mut self) {
            while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.pos += 1;
            }
        }

        fn eat(&mut self, b: u8) -> Result<()> {
            if self.bytes.get(self.pos) == Some(&b) {
                self.pos += 1;
                Ok(())
            } else {
                bail!("expected {:?} at byte {}", b as char, self.pos)
            }
        }

        fn value(&mut self) -> Result<Value> {
            match self.bytes.get(self.pos) {
                Some(b'{') => self.object(),
                Some(b'[') => self.array(),
                Some(b'"') => Ok(Value::Str(self.string()?)),
                Some(b't') => self.literal("true", Value::Bool(true)),
                Some(b'f') => self.literal("false", Value::Bool(false)),
                Some(b'n') => self.literal("null", Value::Null),
                Some(_) => self.number(),
                None => bail!("unexpected end of document"),
            }
        }

        fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
            if self.bytes[self.pos..].starts_with(word.as_bytes()) {
                self.pos += word.len();
                Ok(v)
            } else {
                bail!("invalid literal at byte {}", self.pos)
            }
        }

        fn number(&mut self) -> Result<Value> {
            let start = self.pos;
            while matches!(
                self.bytes.get(self.pos),
                Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            ) {
                self.pos += 1;
            }
            let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
            match text.parse::<f64>() {
                Ok(n) => Ok(Value::Num(n)),
                Err(_) => bail!("invalid number {text:?} at byte {start}"),
            }
        }

        fn string(&mut self) -> Result<String> {
            self.eat(b'"')?;
            let mut out = String::new();
            loop {
                match self.bytes.get(self.pos) {
                    None => bail!("unterminated string at byte {}", self.pos),
                    Some(b'"') => {
                        self.pos += 1;
                        return Ok(out);
                    }
                    Some(b'\\') => {
                        self.pos += 1;
                        match self.bytes.get(self.pos) {
                            Some(b'"') => out.push('"'),
                            Some(b'\\') => out.push('\\'),
                            Some(b'/') => out.push('/'),
                            Some(b'n') => out.push('\n'),
                            Some(b't') => out.push('\t'),
                            Some(b'r') => out.push('\r'),
                            Some(b'b') => out.push('\u{8}'),
                            Some(b'f') => out.push('\u{c}'),
                            Some(b'u') => {
                                let hex = self
                                    .bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .and_then(|h| std::str::from_utf8(h).ok())
                                    .and_then(|h| u32::from_str_radix(h, 16).ok())
                                    .and_then(char::from_u32);
                                match hex {
                                    Some(c) => {
                                        out.push(c);
                                        self.pos += 4;
                                    }
                                    None => bail!("invalid \\u escape at byte {}", self.pos),
                                }
                            }
                            _ => bail!("invalid escape at byte {}", self.pos),
                        }
                        self.pos += 1;
                    }
                    Some(&b) => {
                        // Multi-byte UTF-8 sequences pass through verbatim.
                        let len = match b {
                            0..=0x7f => 1,
                            0xc0..=0xdf => 2,
                            0xe0..=0xef => 3,
                            _ => 4,
                        };
                        let end = (self.pos + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[self.pos..end]);
                        match chunk {
                            Ok(s) => out.push_str(s),
                            Err(_) => bail!("invalid UTF-8 in string at byte {}", self.pos),
                        }
                        self.pos = end;
                    }
                }
            }
        }

        fn array(&mut self) -> Result<Value> {
            self.eat(b'[')?;
            let mut items = Vec::new();
            self.skip_ws();
            if self.bytes.get(self.pos) == Some(&b']') {
                self.pos += 1;
                return Ok(Value::Arr(items));
            }
            loop {
                self.skip_ws();
                items.push(self.value()?);
                self.skip_ws();
                match self.bytes.get(self.pos) {
                    Some(b',') => self.pos += 1,
                    Some(b']') => {
                        self.pos += 1;
                        return Ok(Value::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' at byte {}", self.pos),
                }
            }
        }

        fn object(&mut self) -> Result<Value> {
            self.eat(b'{')?;
            let mut fields = Vec::new();
            self.skip_ws();
            if self.bytes.get(self.pos) == Some(&b'}') {
                self.pos += 1;
                return Ok(Value::Obj(fields));
            }
            loop {
                self.skip_ws();
                let key = self.string()?;
                self.skip_ws();
                self.eat(b':')?;
                self.skip_ws();
                let value = self.value()?;
                fields.push((key, value));
                self.skip_ws();
                match self.bytes.get(self.pos) {
                    Some(b',') => self.pos += 1,
                    Some(b'}') => {
                        self.pos += 1;
                        return Ok(Value::Obj(fields));
                    }
                    _ => bail!("expected ',' or '}}' at byte {}", self.pos),
                }
            }
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// metric names and units are code-controlled, but stay well-formed
/// regardless.
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 5, max_total: Duration::from_secs(2) };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.n >= 1);
    }

    #[test]
    fn json_emitter_renders_and_writes_valid_document() {
        let mut j = JsonEmitter::new();
        assert!(j.is_empty());
        j.metric("serving_events_per_s", 1.25e6, "events/s");
        j.metric("sweep_wall_s", 2.5, "s");
        j.metric("bad \"name\"\\", f64::INFINITY, "s");
        let doc = j.render();
        assert!(doc.contains("\"schema\": \"flashpim-bench-v1\""));
        assert!(doc.contains("\"serving_events_per_s\""));
        assert!(doc.contains("\"events/s\""));
        assert!(doc.contains("\\\"name\\\"\\\\"), "quotes and backslashes escape");
        assert!(doc.contains("null"), "non-finite values serialize as null");
        // Commas separate entries; the last entry has none.
        assert_eq!(doc.matches("},\n").count(), 2);
        let path = std::env::temp_dir().join("flashpim_bench_emit_test.json");
        j.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), doc);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_emitter_slugs_result_names() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 2, max_total: Duration::from_secs(1) };
        let r = bench("serving: 1M requests", &cfg, || 1 + 1);
        let mut j = JsonEmitter::new();
        j.result(&r);
        let doc = j.render();
        assert!(doc.contains("serving__1m_requests_mean_s"), "doc: {doc}");
        assert!(doc.contains("serving__1m_requests_p50_s"));
    }

    #[test]
    fn metrics_round_trip_through_reader() {
        let mut j = JsonEmitter::new();
        j.metric("campaign/chat/slo-aware/event/r8/ttft_p95_s", 0.0123, "s");
        j.metric("weird \"name\"", f64::NAN, "x");
        let back = parse_metrics(&j.render()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], j.metrics[0]);
        assert_eq!(back[1].name, "weird \"name\"");
        assert!(back[1].value.is_nan(), "null reads back as NaN");

        let dir = std::env::temp_dir().join("flashpim_benchkit_reader/nested");
        let path = dir.join("doc.json");
        std::fs::remove_dir_all(&dir).ok();
        j.write(&path).unwrap(); // parent dirs are created on demand
        assert_eq!(read_metrics(&path).unwrap()[0], j.metrics[0]);
        std::fs::remove_dir_all(std::env::temp_dir().join("flashpim_benchkit_reader")).ok();
    }

    #[test]
    fn reader_rejects_malformed_documents() {
        assert!(parse_metrics("").is_err());
        assert!(parse_metrics("{\"metrics\": 4}").is_err());
        assert!(parse_metrics("{\"metrics\": [{\"name\": \"x\"}]}").is_err(), "missing fields");
        assert!(parse_metrics("{\"metrics\": []} trailing").is_err());
        assert!(read_metrics(Path::new("/no/such/bench.json")).is_err());
    }

    #[test]
    fn write_errors_name_the_path() {
        let j = JsonEmitter::new();
        let err = j.write(Path::new("/proc/version/not-a-dir/out.json")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("out.json") || msg.contains("not-a-dir"), "{msg}");
    }

    #[test]
    fn respects_max_total() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 1_000_000, max_total: Duration::from_millis(50) };
        let r = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.summary.n < 1_000_000);
    }
}
