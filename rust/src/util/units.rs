//! Unit constants and human-readable formatting. All internal quantities
//! are SI (seconds, bytes, joules, meters, hertz) stored as f64.

pub const NS: f64 = 1e-9;
pub const US: f64 = 1e-6;
pub const MS: f64 = 1e-3;

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
pub const GB: f64 = 1e9;

pub const NM: f64 = 1e-9;
pub const UM: f64 = 1e-6;
pub const MM: f64 = 1e-3;

pub const MHZ: f64 = 1e6;
pub const GHZ: f64 = 1e9;

pub const PJ: f64 = 1e-12;
pub const NJ: f64 = 1e-9;

pub const FF: f64 = 1e-15; // femtofarad
pub const PF: f64 = 1e-12; // picofarad

/// Format a duration in seconds with an auto-selected unit.
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs / MS)
    } else if a >= 1e-6 {
        format!("{:.3} µs", secs / US)
    } else if a >= 1e-9 {
        format!("{:.1} ns", secs / NS)
    } else if a == 0.0 {
        "0 s".to_string()
    } else {
        format!("{:.1} ps", secs / 1e-12)
    }
}

/// Format a byte count with an auto-selected binary unit.
pub fn fmt_bytes(bytes: f64) -> String {
    let a = bytes.abs();
    if a >= GIB {
        format!("{:.2} GiB", bytes / GIB)
    } else if a >= MIB {
        format!("{:.2} MiB", bytes / MIB)
    } else if a >= KIB {
        format!("{:.2} KiB", bytes / KIB)
    } else {
        format!("{bytes:.0} B")
    }
}

/// Format an energy in joules with an auto-selected unit.
pub fn fmt_energy(joules: f64) -> String {
    let a = joules.abs();
    if a >= 1e-3 {
        format!("{:.3} mJ", joules * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µJ", joules * 1e6)
    } else if a >= 1e-9 {
        format!("{:.3} nJ", joules / NJ)
    } else {
        format!("{:.3} pJ", joules / PJ)
    }
}

/// Format a rate (things per second).
pub fn fmt_rate(per_sec: f64, what: &str) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G{what}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{what}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K{what}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} {what}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_units_pick_scale() {
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(1.4), "1.400 s");
        assert_eq!(fmt_time(7e-3), "7.000 ms");
        assert_eq!(fmt_time(64e-9), "64.0 ns");
    }

    #[test]
    fn byte_units_pick_scale() {
        assert_eq!(fmt_bytes(94.0 * GIB), "94.00 GiB");
        assert_eq!(fmt_bytes(256.0), "256 B");
    }

    #[test]
    fn energy_units_pick_scale() {
        assert_eq!(fmt_energy(3.5e-9), "3.500 nJ");
        assert_eq!(fmt_energy(2e-12), "2.000 pJ");
    }
}
