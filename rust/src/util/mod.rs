//! Small self-contained utilities: deterministic RNG, statistics, unit
//! formatting, ASCII tables, `.npy` IO, a bench harness, and a miniature
//! property-testing kit.
//!
//! The session registry is offline, so these replace `rand`, `criterion`,
//! and `proptest`.

pub mod benchkit;
pub mod npy;
pub mod rng;
pub mod stats;
pub mod table;
pub mod testkit;
pub mod units;
