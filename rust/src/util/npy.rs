//! Minimal NumPy `.npy` (format version 1.0) reader/writer.
//!
//! The AOT step (`python/compile/aot.py`) exports model weights as `.npy`
//! files next to the HLO text; the [`crate::runtime`] loads them here and
//! feeds them to the compiled executable as PJRT literals. Supports the
//! dtypes the pipeline uses: `f32`, `i32`, `i64`, `u8`.

use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Element type of an array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I64,
    U8,
}

impl DType {
    pub fn descr(self) -> &'static str {
        match self {
            DType::F32 => "<f4",
            DType::I32 => "<i4",
            DType::I64 => "<i8",
            DType::U8 => "|u1",
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    fn from_descr(d: &str) -> Result<DType> {
        match d {
            "<f4" | "=f4" => Ok(DType::F32),
            "<i4" | "=i4" => Ok(DType::I32),
            "<i8" | "=i8" => Ok(DType::I64),
            "|u1" | "<u1" | "=u1" => Ok(DType::U8),
            other => bail!("unsupported npy dtype {other:?}"),
        }
    }
}

/// A loaded array: raw little-endian bytes plus shape and dtype.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl NpyArray {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Interpret the payload as f32 (must match dtype).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("dtype is {:?}, not F32", self.dtype);
        }
        Ok(self.data.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Interpret the payload as i32 (must match dtype).
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("dtype is {:?}, not I32", self.dtype);
        }
        Ok(self.data.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Interpret the payload as i64 (must match dtype).
    pub fn as_i64(&self) -> Result<Vec<i64>> {
        if self.dtype != DType::I64 {
            bail!("dtype is {:?}, not I64", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    /// Build an f32 array from values + shape.
    pub fn from_f32(values: &[f32], shape: &[usize]) -> NpyArray {
        assert_eq!(values.len(), shape.iter().product::<usize>());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        NpyArray { dtype: DType::F32, shape: shape.to_vec(), data }
    }

    /// Build an i32 array from values + shape.
    pub fn from_i32(values: &[i32], shape: &[usize]) -> NpyArray {
        assert_eq!(values.len(), shape.iter().product::<usize>());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        NpyArray { dtype: DType::I32, shape: shape.to_vec(), data }
    }
}

/// Read a `.npy` file.
pub fn read(path: &Path) -> Result<NpyArray> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Parse `.npy` bytes.
pub fn parse(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        bail!("not an npy file (bad magic)");
    }
    let major = bytes[6];
    if major != 1 && major != 2 {
        bail!("unsupported npy version {major}");
    }
    let (header_len, header_start) = if major == 1 {
        (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10usize)
    } else {
        (u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize, 12usize)
    };
    let header_end = header_start + header_len;
    if bytes.len() < header_end {
        bail!("truncated npy header");
    }
    let header = std::str::from_utf8(&bytes[header_start..header_end]).context("npy header not utf8")?;
    let descr = extract_str_field(header, "descr")?;
    let fortran = extract_bool_field(header, "fortran_order")?;
    if fortran {
        bail!("fortran-order npy not supported");
    }
    let shape = extract_shape_field(header)?;
    let dtype = DType::from_descr(&descr)?;
    let count: usize = shape.iter().product();
    let need = count * dtype.size();
    let payload = &bytes[header_end..];
    if payload.len() < need {
        bail!("npy payload too short: have {} need {need}", payload.len());
    }
    Ok(NpyArray { dtype, shape, data: payload[..need].to_vec() })
}

/// Write a `.npy` file (format 1.0).
pub fn write(path: &Path, arr: &NpyArray) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let shape_str = match arr.shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", arr.shape[0]),
        _ => format!("({})", arr.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")),
    };
    let mut header =
        format!("{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}", arr.dtype.descr(), shape_str);
    // Pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64, ending in \n.
    let base = 10 + header.len() + 1;
    let pad = (64 - base % 64) % 64;
    header.extend(std::iter::repeat(' ').take(pad));
    header.push('\n');
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(&arr.data)?;
    Ok(())
}

fn extract_str_field(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let idx = header.find(&pat).ok_or_else(|| anyhow!("missing {key} in npy header"))?;
    let rest = &header[idx + pat.len()..];
    let q1 = rest.find('\'').ok_or_else(|| anyhow!("malformed {key}"))?;
    let rest2 = &rest[q1 + 1..];
    let q2 = rest2.find('\'').ok_or_else(|| anyhow!("malformed {key}"))?;
    Ok(rest2[..q2].to_string())
}

fn extract_bool_field(header: &str, key: &str) -> Result<bool> {
    let pat = format!("'{key}':");
    let idx = header.find(&pat).ok_or_else(|| anyhow!("missing {key} in npy header"))?;
    let rest = header[idx + pat.len()..].trim_start();
    if rest.starts_with("True") {
        Ok(true)
    } else if rest.starts_with("False") {
        Ok(false)
    } else {
        bail!("malformed bool field {key}")
    }
}

fn extract_shape_field(header: &str) -> Result<Vec<usize>> {
    let pat = "'shape':";
    let idx = header.find(pat).ok_or_else(|| anyhow!("missing shape in npy header"))?;
    let rest = &header[idx + pat.len()..];
    let open = rest.find('(').ok_or_else(|| anyhow!("malformed shape"))?;
    let close = rest.find(')').ok_or_else(|| anyhow!("malformed shape"))?;
    let inner = &rest[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        shape.push(p.parse::<usize>().with_context(|| format!("bad shape dim {p:?}"))?);
    }
    Ok(shape)
}

/// Read every `.npy` under a directory, keyed by file stem.
pub fn read_dir(dir: &Path) -> Result<Vec<(String, NpyArray)>> {
    let mut out = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "npy").unwrap_or(false))
        .collect();
    entries.sort();
    for p in entries {
        let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or_default().to_string();
        out.push((stem, read(&p)?));
    }
    Ok(out)
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("flashpim_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.npy");
        let arr = NpyArray::from_f32(&[1.0, -2.5, 3.25, 0.0, 7.0, 8.0], &[2, 3]);
        write(&path, &arr).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(back.as_f32().unwrap(), vec![1.0, -2.5, 3.25, 0.0, 7.0, 8.0]);
    }

    #[test]
    fn roundtrip_i32_scalar_shapes() {
        let dir = std::env::temp_dir().join("flashpim_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.npy");
        let arr = NpyArray::from_i32(&[42], &[1]);
        write(&path, &arr).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.as_i32().unwrap(), vec![42]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(b"not an npy file at all").is_err());
    }

    #[test]
    fn header_parse_tolerates_spacing() {
        let arr = NpyArray::from_f32(&[5.0], &[1]);
        let dir = std::env::temp_dir().join("flashpim_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.npy");
        write(&path, &arr).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Header must be 64-byte aligned per the numpy spec.
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }
}
