//! MVM operation descriptors. The paper classifies every LLM layer that
//! is not handled by the controller cores into static MVMs (weights live
//! in QLC flash cells) and dynamic MVMs (both operands generated at
//! runtime: `QK^T`, `SV`).

/// Shape of a matrix-vector multiply `(1, M) × (M, N)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MvmShape {
    /// Input (contraction) dimension.
    pub m: usize,
    /// Output dimension.
    pub n: usize,
}

impl MvmShape {
    pub const fn new(m: usize, n: usize) -> MvmShape {
        MvmShape { m, n }
    }

    /// Weight count.
    pub fn weights(&self) -> usize {
        self.m * self.n
    }

    /// Row tiles with `u` rows per tile.
    pub fn row_tiles(&self, u: usize) -> usize {
        self.m.div_ceil(u)
    }

    /// Column tiles with `c` output columns per tile.
    pub fn col_tiles(&self, c: usize) -> usize {
        self.n.div_ceil(c)
    }

    /// Total unit tiles.
    pub fn tiles(&self, u: usize, c: usize) -> usize {
        self.row_tiles(u) * self.col_tiles(c)
    }
}

/// Operation class (paper Fig. 10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MvmKind {
    /// Weights resident in QLC PIM arrays; no writes involved.
    Static,
    /// Operands generated per token (`Q`, `K`, `V`); executed in the SLC
    /// region's RPUs because SLC programs 19× faster than QLC.
    Dynamic,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt30b_row_tiles_are_56() {
        // Paper Fig. 12: d_m = 7168, u = 128 → 56 row tiles.
        let s = MvmShape::new(7168, 7168);
        assert_eq!(s.row_tiles(128), 56);
        assert_eq!(s.col_tiles(512), 14);
        assert_eq!(s.tiles(128, 512), 56 * 14);
    }

    #[test]
    fn ceil_division() {
        let s = MvmShape::new(1000, 1000);
        assert_eq!(s.row_tiles(128), 8);
        assert_eq!(s.col_tiles(512), 2);
    }
}
