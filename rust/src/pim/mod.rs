//! PIM operation execution models (paper §III-C, §IV-B).
//!
//! * [`op`] — MVM operation descriptors (static vs dynamic).
//! * [`smvm`] — the pipelined static-MVM execution over a die's planes,
//!   comparing the shared bus against the H-tree (Figs. 7, 9).
//! * [`dmvm`] — dynamic MVM (`QK^T`, `SV`) on the SLC region's RPUs with
//!   the row-wise-product dataflow (Fig. 13).

pub mod dmvm;
pub mod op;
pub mod smvm;

pub use dmvm::{DmvmEngine, DmvmReport};
pub use op::{MvmKind, MvmShape};
pub use smvm::{ExecReport, SmvmPipeline};
