//! Dynamic MVM (`QK^T` and `SV`) on the SLC region (paper §IV-B, Fig. 13).
//!
//! Per head, `QK^T` is broadcast-`q` against the rows of the non-transposed
//! `K` (L vector–vector multiplies), and `SV` uses the row-wise product
//! (each element of `S` scattered for a vector-scalar multiply against a
//! row of `V`), which keeps the dataflow insensitive to the growing
//! context length `L`. Operand rows live in SLC page buffers; RPU pairs in
//! the H-tree do the INT16 arithmetic. The three-stage pipeline replaces
//! the PIM stage with KV-cache page reads (paper §V-A).

use crate::bus::Rpu;
use crate::config::SystemConfig;
use crate::nand::NandTiming;
use crate::sim::SimTime;

/// One head's dMVM timing report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmvmReport {
    /// Inbound: deliver q (or S) to the SLC dies.
    pub inbound: SimTime,
    /// KV page reads (the "PIM-stage" replacement).
    pub kv_read: SimTime,
    /// RPU compute + outbound through the H-tree.
    pub compute_out: SimTime,
    /// End-to-end (stages pipelined; inbound overlaps reads).
    pub total: SimTime,
}

/// dMVM executor for the SLC region of one die.
pub struct DmvmEngine {
    pub sys: SystemConfig,
    pub timing: NandTiming,
    /// SLC planes participating per die.
    pub planes: usize,
    /// RPUs available in the die's H-tree (internal nodes = planes - 1;
    /// the engine uses the leaf-adjacent level, planes/2 of them).
    pub rpus: usize,
    pub link_bw: f64,
}

impl DmvmEngine {
    pub fn new(sys: &SystemConfig, timing: NandTiming, planes: usize) -> DmvmEngine {
        DmvmEngine {
            sys: sys.clone(),
            timing,
            planes,
            rpus: (planes / 2).max(1),
            link_bw: sys.ctrl.channel_bus_bw,
        }
    }

    /// SLC page payload bytes (one BLS activation of the SLC plane).
    fn page_bytes(&self) -> usize {
        self.sys.plane.n_col / 8 // SLC: 1 bit/cell
    }

    /// `QK^T` for one head: `q ∈ INT8[d_h]`, `K ∈ INT8[L, d_h]`.
    /// K rows are striped across the SLC planes' page buffers; every RPU
    /// computes VVMs for its pair of planes in parallel.
    pub fn qk(&self, l: usize, d_h: usize) -> DmvmReport {
        // Inbound: broadcast q (d_h bytes) onto the die link.
        let inbound = SimTime::from_secs(d_h as f64 / self.link_bw);

        // KV read: K occupies L×d_h bytes; pages striped over planes; a
        // plane reads its pages sequentially, planes in parallel.
        let total_bytes = l * d_h;
        let pages = total_bytes.div_ceil(self.page_bytes());
        let pages_per_plane = pages.div_ceil(self.planes);
        let kv_read = SimTime::from_secs(pages_per_plane as f64 * self.timing.t_read_slc.secs());

        // Compute: L VVMs of d_h MACs spread over the RPU bank, each
        // starting when operands are in page buffers (overlapped with
        // later reads). All jobs are identical and ready together, so
        // the bank drain has the closed form `ceil(L / rpus) × t_vvm`
        // (§Perf: replaces an O(L) resource loop on the TPOT hot path).
        let rpu = Rpu::new(self.sys.rpu);
        let vvm = rpu.mul_time(d_h);
        let first_ready = inbound.max(SimTime::from_secs(self.timing.t_read_slc.secs()));
        let waves = l.div_ceil(self.rpus) as u64;
        let bank_makespan = first_ready + SimTime(vvm.0 * waves);
        // Outbound: L INT16 scores exit the die port.
        let out = SimTime::from_secs((l * 2) as f64 / self.link_bw);
        let compute_done = bank_makespan.max(inbound + kv_read);
        let total = compute_done + out;
        DmvmReport { inbound, kv_read, compute_out: compute_done + out - first_ready, total }
    }

    /// `SV` for one head with the row-wise product: `S ∈ INT16[L]`,
    /// `V ∈ INT8[L, d_h]`; each S element scales a row of V (VSM), the
    /// partial rows accumulate in the H-tree.
    pub fn sv(&self, l: usize, d_h: usize) -> DmvmReport {
        // Inbound: scatter S (2 bytes per element).
        let inbound = SimTime::from_secs((l * 2) as f64 / self.link_bw);

        let total_bytes = l * d_h;
        let pages = total_bytes.div_ceil(self.page_bytes());
        let pages_per_plane = pages.div_ceil(self.planes);
        let kv_read = SimTime::from_secs(pages_per_plane as f64 * self.timing.t_read_slc.secs());

        let rpu = Rpu::new(self.sys.rpu);
        let vsm = rpu.mul_time(d_h);
        let first_ready = inbound.max(SimTime::from_secs(self.timing.t_read_slc.secs()));
        let waves = l.div_ceil(self.rpus) as u64;
        let bank_makespan = first_ready + SimTime(vsm.0 * waves);
        // H-tree accumulation of the scaled rows down to one d_h vector,
        // then the INT16 result exits.
        let levels = (self.planes as f64).log2().ceil() as usize;
        let tree_accum = SimTime::from_secs(levels as f64 * rpu.alu_time(d_h).secs());
        let out = SimTime::from_secs((d_h * 2) as f64 / self.link_bw);
        let compute_done = bank_makespan.max(inbound + kv_read) + tree_accum;
        let total = compute_done + out;
        DmvmReport { inbound, kv_read, compute_out: compute_done + out - first_ready, total }
    }

    /// Full attention score+context path for one head: QK^T then SV
    /// (softmax happens on the controller cores in between and is
    /// accounted separately).
    pub fn head_total(&self, l: usize, d_h: usize) -> SimTime {
        self.qk(l, d_h).total + self.sv(l, d_h).total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::config::presets::table1_system;

    fn engine() -> DmvmEngine {
        let sys = table1_system();
        let timing = NandTiming::of_system(&sys, &TechParams::default());
        DmvmEngine::new(&sys, timing, 256)
    }

    #[test]
    fn qk_scales_sublinearly_with_context() {
        // Paper Fig. 14b: dMVM scales gracefully with token length thanks
        // to head parallelism + striping. 4× the context should cost
        // well under 4× the time.
        let e = engine();
        let t1 = e.qk(1024, 128).total.secs();
        let t4 = e.qk(4096, 128).total.secs();
        assert!(t4 > t1);
        assert!(t4 / t1 < 4.0, "ratio {}", t4 / t1);
    }

    #[test]
    fn sv_total_exceeds_qk_due_to_tree_accum() {
        let e = engine();
        let qk = e.qk(1024, 128).total;
        let sv = e.sv(1024, 128).total;
        assert!(sv >= qk);
    }

    #[test]
    fn longer_context_reads_more_pages() {
        let e = engine();
        let a = e.qk(512, 128);
        let b = e.qk(8192, 128);
        assert!(b.kv_read >= a.kv_read);
    }

    #[test]
    fn head_total_is_sum() {
        let e = engine();
        let t = e.head_total(1024, 128);
        assert_eq!(t, e.qk(1024, 128).total + e.sv(1024, 128).total);
    }

    #[test]
    fn dmvm_head_in_tens_of_microseconds() {
        // Sanity envelope: one head at L=1K should be in the 1–100 µs
        // range for the TPOT budget (48 blocks × ~2 dies-per-head pipeline
        // must land near the paper's ~7 ms).
        let e = engine();
        let t = e.head_total(1024, 128).secs();
        assert!((1e-6..=100e-6).contains(&t), "head total = {t}");
    }
}
