//! Pipelined sMVM execution over one die's planes (paper Figs. 7 & 9).
//!
//! Three stages: **inbound I/O** (scatter the input vector to the planes
//! over the die's input link), **PIM** (each plane runs its unit tiles),
//! **outbound I/O** (partial sums leave the die). Inbound overlaps PIM
//! (paper §V-A); the outbound path is where the shared bus and the H-tree
//! differ:
//!
//! * shared bus — every tile's partial-sum vector individually travels to
//!   the die port (accumulation happens at the channel controller);
//! * H-tree — tiles of the same column group are accumulated on the way
//!   up by the RPUs, so only one vector per column group exits.

use super::op::MvmShape;
use crate::bus::{HTree, Rpu, SharedBus};
use crate::config::{BusTopology, SystemConfig};
use crate::nand::NandTiming;
use crate::sim::{Resource, SimTime};

/// Bytes per PIM output element leaving a plane (INT16 partial sums after
/// the shift-adder; paper Table I RPUs operate on INT16).
pub const OUT_ELEM_BYTES: usize = 2;

/// Result of one sMVM execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecReport {
    /// Time the last input byte reached a plane.
    pub inbound_done: SimTime,
    /// Time the last plane finished PIM work.
    pub pim_done: SimTime,
    /// Time the last output left the die (total execution time).
    pub total: SimTime,
}

impl ExecReport {
    /// Outbound span beyond the PIM stage (the exposed outbound latency).
    pub fn outbound_exposed(&self) -> SimTime {
        self.total.saturating_sub(self.pim_done)
    }
}

/// sMVM executor over `planes` planes of one die.
pub struct SmvmPipeline {
    pub sys: SystemConfig,
    pub timing: NandTiming,
    /// Planes available for this op.
    pub planes: usize,
    /// Die input/output link bandwidth (bytes/s).
    pub link_bw: f64,
}

impl SmvmPipeline {
    pub fn new(sys: &SystemConfig, timing: NandTiming, planes: usize) -> SmvmPipeline {
        SmvmPipeline { sys: sys.clone(), timing, planes, link_bw: sys.ctrl.channel_bus_bw }
    }

    /// Execute `(1,M)×(M,N)` with the configured topology.
    pub fn execute(&self, shape: MvmShape) -> ExecReport {
        match self.sys.bus {
            BusTopology::Shared => self.execute_shared(shape),
            BusTopology::HTree => self.execute_htree(shape),
        }
    }

    /// Tile grid for the shape under this plane geometry.
    fn grid(&self, shape: MvmShape) -> (usize, usize) {
        (shape.row_tiles(self.sys.tile_rows()), shape.col_tiles(self.sys.tile_cols()))
    }

    /// Inbound: the input vector is cut into row-tile chunks (u bytes of
    /// INT8 activations each) and streamed over the die input link; chunk
    /// r is available once its bytes arrived. Returns per-row-tile ready
    /// times and the final inbound completion.
    fn inbound_schedule(&self, shape: MvmShape) -> (Vec<SimTime>, SimTime) {
        let (rt, _) = self.grid(shape);
        let u = self.sys.tile_rows();
        let mut ready = Vec::with_capacity(rt);
        let mut t = SimTime::ZERO;
        for r in 0..rt {
            let chunk = u.min(shape.m - r * u); // bytes (INT8 input)
            t += SimTime::from_secs(chunk as f64 / self.link_bw);
            ready.push(t);
        }
        (ready, t)
    }

    /// Assign tile (r, c) to a plane: column-group-major round robin so
    /// tiles of one column group land in distinct planes (they reduce
    /// together in the H-tree).
    fn plane_of(&self, r: usize, c: usize, rt: usize) -> usize {
        (c * rt + r) % self.planes
    }

    /// PIM stage: every tile occupies its plane for `t_pim` once its
    /// input chunk arrived. Returns per-tile completion times indexed
    /// `[c][r]` plus the PIM makespan.
    fn pim_schedule(&self, shape: MvmShape, inbound: &[SimTime]) -> (Vec<Vec<SimTime>>, SimTime) {
        let (rt, ct) = self.grid(shape);
        let mut plane_busy: Vec<Resource> = (0..self.planes).map(|_| Resource::new()).collect();
        let mut done = vec![vec![SimTime::ZERO; rt]; ct];
        let mut makespan = SimTime::ZERO;
        for c in 0..ct {
            for r in 0..rt {
                let p = self.plane_of(r, c, rt);
                let start = plane_busy[p].acquire(inbound[r], self.timing.t_pim);
                let end = start + self.timing.t_pim;
                done[c][r] = end;
                makespan = makespan.max(end);
            }
        }
        (done, makespan)
    }

    /// Output bytes of one tile (INT16 partial sums over the tile's
    /// column span).
    fn tile_out_bytes(&self, shape: MvmShape, c: usize, ct: usize) -> usize {
        let cols = self.sys.tile_cols();
        let span = if c + 1 == ct { shape.n - c * cols } else { cols };
        span * OUT_ELEM_BYTES
    }

    fn execute_shared(&self, shape: MvmShape) -> ExecReport {
        let (inbound, inbound_done) = self.inbound_schedule(shape);
        let (done, pim_done) = self.pim_schedule(shape, &inbound);
        let (_, ct) = self.grid(shape);
        // Every tile's vector individually crosses the shared bus.
        let mut bus = SharedBus::new(self.link_bw);
        let mut jobs = Vec::new();
        for (c, row) in done.iter().enumerate() {
            let bytes = self.tile_out_bytes(shape, c, ct);
            for t in row {
                jobs.push((*t, bytes));
            }
        }
        let total = bus.drain(jobs);
        ExecReport { inbound_done, pim_done, total }
    }

    fn execute_htree(&self, shape: MvmShape) -> ExecReport {
        let (inbound, inbound_done) = self.inbound_schedule(shape);
        let (done, pim_done) = self.pim_schedule(shape, &inbound);
        let (rt, ct) = self.grid(shape);
        let tree = HTree::new(self.planes, Rpu::new(self.sys.rpu), self.link_bw);
        // Column groups reduce through the tree level by level (store-and-
        // forward at each RPU: receive both children, combine, forward);
        // successive groups pipeline behind one another through the root
        // egress port.
        let mut root = Resource::new();
        let mut total = SimTime::ZERO;
        for (c, row) in done.iter().enumerate() {
            let bytes = self.tile_out_bytes(shape, c, ct);
            let n_elems = bytes / OUT_ELEM_BYTES;
            // Group row tiles by plane (a plane holding several tiles of
            // the group contributes once, at its last completion).
            let mut per_plane: std::collections::BTreeMap<usize, SimTime> = Default::default();
            for r in 0..rt {
                let p = self.plane_of(r, c, rt);
                let e = per_plane.entry(p).or_insert(SimTime::ZERO);
                *e = (*e).max(row[r]);
            }
            let leaves: Vec<(usize, SimTime)> = per_plane.into_iter().collect();
            let ready = tree.reduce_subset_ready_time(&leaves, n_elems, OUT_ELEM_BYTES);
            let dur = SimTime::from_secs(bytes as f64 / self.link_bw);
            let start = root.acquire(ready, dur);
            total = total.max(start + dur);
        }
        ExecReport { inbound_done, pim_done, total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::config::presets::{table1_shared_bus, table1_system};

    fn pipeline(sys: &crate::config::SystemConfig, planes: usize) -> SmvmPipeline {
        let timing = NandTiming::of_system(sys, &TechParams::default());
        SmvmPipeline::new(sys, timing, planes)
    }

    /// The paper's Fig. 9 evaluation shapes.
    fn fig9_shapes() -> [MvmShape; 3] {
        [MvmShape::new(1024, 1024), MvmShape::new(1024, 4096), MvmShape::new(4096, 1024)]
    }

    #[test]
    fn htree_beats_shared_on_all_fig9_shapes() {
        let htree = pipeline(&table1_system(), 64);
        let shared = pipeline(&table1_shared_bus(), 64);
        for s in fig9_shapes() {
            let h = htree.execute(s).total;
            let b = shared.execute(s).total;
            assert!(h < b, "shape {s:?}: htree {h} !< shared {b}");
        }
    }

    #[test]
    fn fig9a_mean_reduction_near_46pct() {
        // Paper Fig. 9a: 46 % mean execution-time reduction. Our H-tree
        // store-and-forward model measures ~55 % (per-case 23/69/72 —
        // the ordering and who-wins match; see EXPERIMENTS.md), so the
        // anchor tolerates 36–58 %.
        let htree = pipeline(&table1_system(), 64);
        let shared = pipeline(&table1_shared_bus(), 64);
        let mut reductions = Vec::new();
        for s in fig9_shapes() {
            let h = htree.execute(s).total.secs();
            let b = shared.execute(s).total.secs();
            reductions.push(1.0 - h / b);
        }
        let mean = crate::util::stats::mean(&reductions);
        assert!(
            (0.36..=0.58).contains(&mean),
            "mean reduction {:.1}% (cases {:?})",
            mean * 100.0,
            reductions.iter().map(|r| (r * 100.0).round()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fig9b_size_a_vs_size_b_overhead() {
        // Paper Fig. 9b: Size A (64 planes) costs ~17 % more execution
        // time than Size B (128 planes, throughput-matched) while doubling
        // cell density. Tolerance: 2–35 %.
        use crate::config::presets::table1_size_b;
        let a = pipeline(&table1_system(), 64);
        let b = pipeline(&table1_size_b(), 128);
        let mut overheads = Vec::new();
        for s in fig9_shapes() {
            let ta = a.execute(s).total.secs();
            let tb = b.execute(s).total.secs();
            overheads.push(ta / tb - 1.0);
        }
        let mean = crate::util::stats::mean(&overheads);
        assert!(
            (0.02..=0.35).contains(&mean),
            "Size A mean overhead {:.1}% (cases {:?})",
            mean * 100.0,
            overheads.iter().map(|r| (r * 100.0).round()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn inbound_overlaps_pim() {
        let p = pipeline(&table1_system(), 64);
        let r = p.execute(MvmShape::new(4096, 1024));
        // PIM finishes well before inbound+pim serialized sum would.
        assert!(r.pim_done < r.inbound_done + SimTime::from_secs(32.0 * p.timing.t_pim.secs()));
        assert!(r.inbound_done < r.pim_done);
    }

    #[test]
    fn report_total_after_pim() {
        let p = pipeline(&table1_system(), 64);
        let r = p.execute(MvmShape::new(1024, 1024));
        assert!(r.total >= r.pim_done);
        assert!(r.outbound_exposed() > SimTime::ZERO);
    }

    #[test]
    fn more_planes_do_not_hurt() {
        let sys = table1_system();
        let p64 = pipeline(&sys, 64);
        let p128 = pipeline(&sys, 128);
        let s = MvmShape::new(4096, 4096);
        assert!(p128.execute(s).total <= p64.execute(s).total);
    }
}
