//! The decoder-block operation graph (paper Fig. 10a–c) and its mapping
//! onto compute units: sMVMs to the QLC PIM arrays, dMVMs to the SLC
//! region's RPUs, LN/softmax to the controller cores.

use super::model_config::ModelShape;
use crate::pim::op::MvmShape;

/// One operation in a decoder block's sequential schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BlockOp {
    /// LayerNorm over `d` elements (controller cores, FP16).
    LayerNorm { d: usize },
    /// Static MVM on the QLC PIM arrays.
    Smvm { shape: MvmShape, label: &'static str },
    /// `QK^T` over all heads (SLC RPUs); `l` is the current context length.
    DmvmQk { heads: usize, d_head: usize },
    /// Softmax over each head's `l` scores (controller cores, FP16).
    Softmax { heads: usize },
    /// `SV` row-wise product over all heads (SLC RPUs).
    DmvmSv { heads: usize, d_head: usize },
}

impl BlockOp {
    /// Short category used by the Fig. 14b breakdown.
    pub fn category(&self) -> &'static str {
        match self {
            BlockOp::LayerNorm { .. } => "ln",
            BlockOp::Smvm { .. } => "smvm",
            BlockOp::DmvmQk { .. } | BlockOp::DmvmSv { .. } => "dmvm",
            BlockOp::Softmax { .. } => "softmax",
        }
    }
}

/// The sequential op list of one decoder block (pre-LN OPT ordering):
/// LN → QKV → QK^T → softmax → SV → O-proj → LN → FFN1 → FFN2.
/// Residual adds ride along with the projections (negligible time on the
/// cores, absorbed into LN accounting as in the paper's Fig. 14b).
pub fn decoder_block_ops(m: &ModelShape) -> Vec<BlockOp> {
    let d = m.d_model;
    vec![
        BlockOp::LayerNorm { d },
        BlockOp::Smvm { shape: MvmShape::new(d, 3 * d), label: "qkv" },
        BlockOp::DmvmQk { heads: m.heads, d_head: m.d_head() },
        BlockOp::Softmax { heads: m.heads },
        BlockOp::DmvmSv { heads: m.heads, d_head: m.d_head() },
        BlockOp::Smvm { shape: MvmShape::new(d, d), label: "o_proj" },
        BlockOp::LayerNorm { d },
        BlockOp::Smvm { shape: MvmShape::new(d, m.d_ffn), label: "ffn1" },
        BlockOp::Smvm { shape: MvmShape::new(m.d_ffn, d), label: "ffn2" },
    ]
}

/// Final ops after the last block: closing LN + LM head projection.
pub fn head_ops(m: &ModelShape) -> Vec<BlockOp> {
    vec![
        BlockOp::LayerNorm { d: m.d_model },
        BlockOp::Smvm { shape: MvmShape::new(m.d_model, m.vocab), label: "lm_head" },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::model_config::OptModel;

    #[test]
    fn block_has_four_smvms() {
        let ops = decoder_block_ops(&OptModel::Opt30b.shape());
        let smvms = ops.iter().filter(|o| o.category() == "smvm").count();
        assert_eq!(smvms, 4); // qkv, o, ffn1, ffn2
    }

    #[test]
    fn block_weight_total_matches_shape_params() {
        // Sum of sMVM weights × layers + vocab ≈ params().
        let m = OptModel::Opt30b.shape();
        let per_block: usize = decoder_block_ops(&m)
            .iter()
            .filter_map(|o| match o {
                BlockOp::Smvm { shape, .. } => Some(shape.weights()),
                _ => None,
            })
            .sum();
        let total = per_block as u64 * m.layers as u64
            + head_ops(&m)
                .iter()
                .filter_map(|o| match o {
                    BlockOp::Smvm { shape, .. } => Some(shape.weights() as u64),
                    _ => None,
                })
                .sum::<u64>();
        assert_eq!(total, m.params());
    }

    #[test]
    fn attention_ops_in_order() {
        let ops = decoder_block_ops(&OptModel::Opt6_7b.shape());
        let cats: Vec<&str> = ops.iter().map(|o| o.category()).collect();
        let qk = cats.iter().position(|c| *c == "dmvm").unwrap();
        assert_eq!(cats[qk + 1], "softmax");
        assert_eq!(cats[qk + 2], "dmvm");
    }
}
