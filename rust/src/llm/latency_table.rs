//! Offline-precomputed per-token latency for the serving pool.
//!
//! The closed-loop simulator and the device-pool workers need millions of
//! "TPOT at context length l" queries, but [`TokenSchedule`] answers them
//! through `&mut self` memoized caches — one exhaustive §V-A tiling
//! search per cold shape, duplicated in every thread that owns a
//! schedule. `LatencyTable` splits that into two phases:
//!
//! 1. **Build** (offline, once per model × system): run the exact
//!    `TokenSchedule` over evenly-strided context-length buckets up to
//!    the model's max trained context. The default stride is 1 — a dense
//!    table is only `max_context + 1` f64s (16 KiB for OPT), build cost
//!    is dominated by the one-off tiling searches anyway, and density
//!    makes in-range queries *exact*: the dMVM cost model is a staircase
//!    in context length (`div_ceil` page reads), which no interpolation
//!    stride can track pointwise through a jump.
//! 2. **Query** (hot path): immutable `&self` O(1) lookups — linear
//!    interpolation between buckets for coarser strides, windowed-slope
//!    extrapolation beyond the last bucket (the window spans the trailing
//!    quarter of the table, averaging over staircase periods).
//!
//! One `Arc<LatencyTable>` is shared by every serving backend, sweep
//! point, and pool worker; there is no per-thread cache to warm and no
//! lock to take.
//!
//! Nothing here assumes the Table-I plane: the co-design campaign
//! ([`crate::dse::codesign`]) builds one table per candidate geometry in
//! its grid, and `tests/latency_table.rs` pins table-vs-exact-schedule
//! agreement at the grid's corner geometries (smallest and largest), not
//! just the default system.

use super::model_config::ModelShape;
use super::schedule::TokenSchedule;
use crate::circuit::TechParams;
use crate::config::SystemConfig;
use crate::sim::SimTime;

/// Immutable per-token latency table (seconds per output token as a
/// function of context length). Cheap to clone the `Arc`, `Send + Sync`.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyTable {
    /// Name of the model the table was built for.
    model: String,
    /// Name of the system configuration the table was built for.
    system: String,
    /// Bucket spacing in tokens.
    stride: usize,
    /// `tpot[i]` = exact TPOT at context length `i * stride`.
    tpot: Vec<f64>,
    /// d(TPOT)/d(l) used past the last bucket, averaged over the trailing
    /// quarter of the table so the dMVM staircase does not bias it.
    tail_slope: f64,
}

impl LatencyTable {
    /// Default bucket spacing: dense. In-range queries are exact table
    /// hits; pass a coarser stride to [`Self::build_spanning`] to trade
    /// accuracy near the dMVM staircase jumps for a smaller build.
    pub const DEFAULT_STRIDE: usize = 1;

    /// Build with default stride, spanning the model's trained context.
    pub fn build(sys: &SystemConfig, tech: &TechParams, model: ModelShape) -> LatencyTable {
        let max_context = model.max_context;
        Self::build_spanning(sys, tech, model, max_context, Self::DEFAULT_STRIDE)
    }

    /// Build a table spanning `[0, max_context]` with the given bucket
    /// stride. Runs the exact `TokenSchedule` once per bucket; the
    /// schedule's own shape memoization makes every bucket after the
    /// first cost only the context-dependent (dMVM/softmax) models.
    pub fn build_spanning(
        sys: &SystemConfig,
        tech: &TechParams,
        model: ModelShape,
        max_context: usize,
        stride: usize,
    ) -> LatencyTable {
        assert!(stride >= 1, "bucket stride must be at least 1");
        assert!(max_context >= stride, "max context {max_context} below stride {stride}");
        let mut sched = TokenSchedule::new(sys, tech, model);
        let segments = max_context.div_ceil(stride);
        let tpot: Vec<f64> = (0..=segments).map(|i| sched.tpot(i * stride)).collect();
        let window = (segments / 4).max(1);
        let tail_slope =
            ((tpot[segments] - tpot[segments - window]) / (window * stride) as f64).max(0.0);
        LatencyTable {
            model: sched.model.name.clone(),
            system: sys.name.clone(),
            stride,
            tpot,
            tail_slope,
        }
    }

    /// Model the table was built for.
    pub fn model_name(&self) -> &str {
        &self.model
    }

    /// System configuration the table was built for.
    pub fn system_name(&self) -> &str {
        &self.system
    }

    /// Bucket spacing in tokens.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Largest tabulated context length; queries beyond it extrapolate.
    pub fn max_context(&self) -> usize {
        (self.tpot.len() - 1) * self.stride
    }

    /// Time-per-output-token (seconds) at context length `l_ctx` — O(1).
    pub fn tpot(&self, l_ctx: usize) -> f64 {
        let i = l_ctx / self.stride;
        let last = self.tpot.len() - 1;
        if i >= last {
            let beyond = (l_ctx - last * self.stride) as f64;
            return self.tpot[last] + self.tail_slope * beyond;
        }
        let frac = (l_ctx - i * self.stride) as f64 / self.stride as f64;
        self.tpot[i] + (self.tpot[i + 1] - self.tpot[i]) * frac
    }

    /// Simulated wall-clock of one decode step at context length `l_ctx`.
    pub fn step_time(&self, l_ctx: usize) -> SimTime {
        SimTime::from_secs(self.tpot(l_ctx))
    }

    /// Simulated flash latency of a whole decode: `l_out` tokens starting
    /// from context `l_ctx0` (the context grows one token per step).
    pub fn decode_time(&self, l_ctx0: usize, l_out: usize) -> SimTime {
        let mut total = SimTime::ZERO;
        for step in 0..l_out {
            total += self.step_time(l_ctx0 + step);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    fn table(m: OptModel) -> LatencyTable {
        LatencyTable::build(&table1_system(), &TechParams::default(), m.shape())
    }

    #[test]
    fn dense_default_is_exact_in_range() {
        let t = table(OptModel::Opt6_7b);
        let mut exact = TokenSchedule::new(
            &table1_system(),
            &TechParams::default(),
            OptModel::Opt6_7b.shape(),
        );
        // Stride 1: every in-range context length is a bucket point,
        // including lengths just past the dMVM staircase jumps.
        for l in [0, 7, 100, 513, 1023, 1024, 2047, 2048] {
            assert_eq!(t.tpot(l), exact.tpot(l), "l={l}");
        }
    }

    #[test]
    fn coarse_tables_interpolate_between_buckets() {
        let t = LatencyTable::build_spanning(
            &table1_system(),
            &TechParams::default(),
            OptModel::Opt30b.shape(),
            2048,
            64,
        );
        let (lo, mid, hi) = (t.tpot(1024), t.tpot(1056), t.tpot(1088));
        assert!(lo <= mid && mid <= hi, "{lo} {mid} {hi}");
        assert!((mid - (lo + hi) / 2.0).abs() < 1e-12, "linear within a segment");
        // A coarse table agrees with the dense one at shared bucket points.
        let dense = table(OptModel::Opt30b);
        for l in [0, 512, 1024, 2048] {
            assert_eq!(t.tpot(l), dense.tpot(l), "l={l}");
        }
    }

    #[test]
    fn extrapolates_monotonically_beyond_max() {
        let t = table(OptModel::Opt6_7b);
        let max = t.max_context();
        assert_eq!(max, 2048);
        assert!(t.tpot(4 * max) >= t.tpot(2 * max));
        assert!(t.tpot(2 * max) >= t.tpot(max));
    }

    #[test]
    fn decode_time_sums_steps() {
        let t = table(OptModel::Opt6_7b);
        let by_hand = t.step_time(100) + t.step_time(101) + t.step_time(102);
        assert_eq!(t.decode_time(100, 3), by_hand);
        assert_eq!(t.decode_time(100, 0), SimTime::ZERO);
    }

    #[test]
    fn spanning_build_respects_bounds() {
        let t = LatencyTable::build_spanning(
            &table1_system(),
            &TechParams::default(),
            OptModel::Opt6_7b.shape(),
            1000,
            128,
        );
        // 1000 rounds up to 8 segments of 128.
        assert_eq!(t.max_context(), 1024);
        assert_eq!(t.stride(), 128);
        assert_eq!(t.model_name(), "OPT-6.7B");
        assert_eq!(t.system_name(), "table1");
    }
}
