//! LLM model shapes and the per-token operation schedule (paper §IV,
//! Fig. 10): OPT-family configurations, the decoder-block layer graph,
//! and the W8A8 quantization scheme the PIM arrays assume.

pub mod energy;
pub mod latency_table;
pub mod layers;
pub mod model_config;
pub mod quant;
pub mod schedule;

pub use energy::{EnergySchedule, TokenEnergy};
pub use latency_table::LatencyTable;
pub use layers::{BlockOp, decoder_block_ops};
pub use model_config::{ModelShape, OptModel};
pub use quant::QuantSpec;
pub use schedule::TokenSchedule;
