//! Per-token execution schedule and TPOT estimation on the flash PIM
//! device (paper Fig. 14). Chains the decoder-block ops through the
//! per-op cost models:
//!
//! * sMVM → best tiling scheme from [`crate::tiling::search_min`]
//! * dMVM → [`crate::pim::DmvmEngine`] with head-level die parallelism
//! * LN / softmax → [`crate::controller::ArmCores`]
//!
//! Ops within a block are data-dependent and run sequentially; the
//! breakdown by category reproduces Fig. 14b.

use super::layers::{decoder_block_ops, head_ops, BlockOp};
use super::model_config::ModelShape;
use crate::circuit::TechParams;
use crate::config::SystemConfig;
use crate::controller::ArmCores;
use crate::nand::NandTiming;
use crate::pim::dmvm::DmvmEngine;
use crate::pim::op::MvmShape;
use crate::sim::SimTime;
use crate::tiling::{search_min, TilingCostModel};
use std::collections::HashMap;

/// Per-category time breakdown of one generated token (Fig. 14b).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TokenBreakdown {
    pub smvm: f64,
    pub dmvm: f64,
    pub ln: f64,
    pub softmax: f64,
}

impl TokenBreakdown {
    pub fn total(&self) -> f64 {
        self.smvm + self.dmvm + self.ln + self.softmax
    }
}

/// TPOT estimator for one model on one system configuration.
pub struct TokenSchedule {
    pub sys: SystemConfig,
    pub model: ModelShape,
    cost_model: TilingCostModel,
    dmvm: DmvmEngine,
    cores: ArmCores,
    /// Memoized best-scheme total per sMVM shape.
    smvm_cache: HashMap<MvmShape, f64>,
    /// Memoized full-token breakdown per context length. The serving pool
    /// does not query this directly any more — it precomputes an immutable
    /// [`super::latency_table::LatencyTable`] once and shares it across
    /// threads; this cache just keeps the table build (and ad-hoc exact
    /// queries) cheap.
    token_cache: HashMap<usize, TokenBreakdown>,
    /// SLC dies available for dMVM head parallelism.
    slc_dies: usize,
}

impl TokenSchedule {
    pub fn new(sys: &SystemConfig, tech: &TechParams, model: ModelShape) -> TokenSchedule {
        let timing = NandTiming::of_system(sys, tech);
        let slc_timing = timing.clone();
        TokenSchedule {
            cost_model: TilingCostModel::new(sys, timing),
            dmvm: DmvmEngine::new(sys, slc_timing, sys.org.planes_per_die),
            cores: ArmCores::new(sys.ctrl),
            smvm_cache: HashMap::new(),
            token_cache: HashMap::new(),
            slc_dies: sys.org.channels * sys.org.ways_per_channel * sys.org.slc_dies_per_way,
            sys: sys.clone(),
            model,
        }
    }

    /// Best-mapping sMVM latency for a shape (memoized). Uses the
    /// [`search_min`] fast path — cold shapes cost one O(n) scan over the
    /// legal schemes, not a full ranking sort.
    pub fn smvm_time(&mut self, shape: MvmShape) -> f64 {
        if let Some(t) = self.smvm_cache.get(&shape) {
            return *t;
        }
        let t = search_min(&self.cost_model, shape)
            .map(|r| r.cost.total().secs())
            .expect("shape must be mappable on the Table-I organization");
        self.smvm_cache.insert(shape, t);
        t
    }

    /// dMVM (QK^T or SV) latency for all heads at context length `l`:
    /// heads are spread one-or-two-per-die over the SLC dies (paper
    /// §IV-B) and run in parallel; a die with several heads serializes.
    fn dmvm_time(&self, heads: usize, d_head: usize, l: usize, is_sv: bool) -> f64 {
        let heads_per_die = heads.div_ceil(self.slc_dies).max(1);
        let one = if is_sv { self.dmvm.sv(l, d_head).total } else { self.dmvm.qk(l, d_head).total };
        heads_per_die as f64 * one.secs()
    }

    /// Per-token breakdown at context length `l_ctx` (Fig. 14b).
    /// Memoized: the breakdown is a pure function of `l_ctx`.
    pub fn token_breakdown(&mut self, l_ctx: usize) -> TokenBreakdown {
        if let Some(b) = self.token_cache.get(&l_ctx) {
            return b.clone();
        }
        let mut b = TokenBreakdown::default();
        let model = self.model.clone();
        let blocks = decoder_block_ops(&model);
        // One block accumulated once, scaled by the layer count — every
        // block is identical at a given context length (§Perf).
        for op in &blocks {
            self.accumulate(op, l_ctx, &mut b);
        }
        b.smvm *= model.layers as f64;
        b.dmvm *= model.layers as f64;
        b.ln *= model.layers as f64;
        b.softmax *= model.layers as f64;
        for op in head_ops(&model) {
            self.accumulate(&op, l_ctx, &mut b);
        }
        self.token_cache.insert(l_ctx, b.clone());
        b
    }

    fn accumulate(&mut self, op: &BlockOp, l_ctx: usize, b: &mut TokenBreakdown) {
        match op {
            BlockOp::LayerNorm { d } => b.ln += self.cores.ln_time(*d).secs(),
            BlockOp::Smvm { shape, .. } => b.smvm += self.smvm_time(*shape),
            BlockOp::DmvmQk { heads, d_head } => {
                b.dmvm += self.dmvm_time(*heads, *d_head, l_ctx, false)
            }
            BlockOp::DmvmSv { heads, d_head } => {
                b.dmvm += self.dmvm_time(*heads, *d_head, l_ctx, true)
            }
            BlockOp::Softmax { heads } => {
                b.softmax += self.cores.softmax_time(*heads, l_ctx).secs()
            }
        }
    }

    /// Time-per-output-token at context length `l_ctx`.
    pub fn tpot(&mut self, l_ctx: usize) -> f64 {
        self.token_breakdown(l_ctx).total()
    }

    /// Mean TPOT over a generation run: prefill of `l_in` tokens already
    /// cached, generating `l_out` tokens (context grows each step).
    /// Sampled geometrically to stay fast.
    pub fn mean_tpot(&mut self, l_in: usize, l_out: usize) -> f64 {
        // Context grows linearly; TPOT is affine in l, so the midpoint is
        // exact for the mean — sample three points to be safe.
        let l0 = l_in;
        let l1 = l_in + l_out / 2;
        let l2 = l_in + l_out;
        (self.tpot(l0) + 2.0 * self.tpot(l1) + self.tpot(l2)) / 4.0
    }

    /// Simulated wall-clock for one decode step (used by the serving
    /// coordinator).
    pub fn step_time(&mut self, l_ctx: usize) -> SimTime {
        SimTime::from_secs(self.tpot(l_ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    fn sched(m: OptModel) -> TokenSchedule {
        TokenSchedule::new(&table1_system(), &TechParams::default(), m.shape())
    }

    #[test]
    fn opt30b_tpot_near_7ms() {
        // Paper Fig. 5: TPOT of OPT-30B ≈ 7 ms on the proposed PIM.
        let mut s = sched(OptModel::Opt30b);
        let t = s.tpot(1024);
        assert!((4.0e-3..=10.0e-3).contains(&t), "TPOT = {}", crate::util::units::fmt_time(t));
    }

    #[test]
    fn smvm_component_independent_of_context() {
        // Fig. 14b: sMVM and LN depend on model dims, not token length.
        let mut s = sched(OptModel::Opt30b);
        let b1 = s.token_breakdown(1024);
        let b2 = s.token_breakdown(2048);
        assert!((b1.smvm - b2.smvm).abs() < 1e-9);
        assert!((b1.ln - b2.ln).abs() < 1e-9);
    }

    #[test]
    fn dmvm_and_softmax_grow_with_context() {
        let mut s = sched(OptModel::Opt30b);
        let b1 = s.token_breakdown(1024);
        let b2 = s.token_breakdown(4096);
        assert!(b2.dmvm > b1.dmvm);
        assert!(b2.softmax > 2.0 * b1.softmax);
    }

    #[test]
    fn tpot_monotone_in_model_size() {
        let mut prev = 0.0;
        for m in OptModel::ALL {
            let t = sched(m).tpot(1024);
            assert!(t > prev, "{}: {t}", m.shape().name);
            prev = t;
        }
    }

    #[test]
    fn mean_tpot_between_endpoints() {
        let mut s = sched(OptModel::Opt6_7b);
        let lo = s.tpot(1024);
        let hi = s.tpot(2048);
        let mean = s.mean_tpot(1024, 1024);
        assert!(mean >= lo && mean <= hi);
    }
}
