//! Per-token energy rollup — the energy counterpart of
//! [`super::schedule::TokenSchedule`]. Combines the circuit model's
//! per-op PIM energy (Eq. 6, Fig. 6b) with bus-transfer and controller
//! energy to estimate J/token, and compares against a GPU baseline —
//! the paper's cost argument in energy terms.

use super::layers::{decoder_block_ops, head_ops, BlockOp};
use super::model_config::ModelShape;
use crate::circuit::{PimEnergy, TechParams};
use crate::config::SystemConfig;
use crate::pim::op::MvmShape;

/// Energy constants beyond the plane model.
#[derive(Debug, Clone, Copy)]
pub struct EnergyCosts {
    /// Bus transfer energy per byte (J/B) — on-package flash bus.
    pub bus_per_byte: f64,
    /// ARM-core energy per element pass (J) for LN/softmax in FP16.
    pub core_per_elem: f64,
    /// RPU energy per INT16 MAC (J).
    pub rpu_per_mac: f64,
}

impl Default for EnergyCosts {
    fn default() -> Self {
        EnergyCosts { bus_per_byte: 5.0e-12, core_per_elem: 50.0e-12, rpu_per_mac: 0.4e-12 }
    }
}

/// Per-token energy breakdown (joules).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TokenEnergy {
    pub pim: f64,
    pub bus: f64,
    pub rpu: f64,
    pub cores: f64,
}

impl TokenEnergy {
    pub fn total(&self) -> f64 {
        self.pim + self.bus + self.rpu + self.cores
    }
}

/// Energy estimator.
pub struct EnergySchedule {
    pub sys: SystemConfig,
    pub model: ModelShape,
    pub costs: EnergyCosts,
    /// Per-8-bit-op plane energy (memoized once; Eq. 6 at α = 0.5).
    e_op: f64,
}

impl EnergySchedule {
    pub fn new(sys: &SystemConfig, tech: &TechParams, model: ModelShape) -> EnergySchedule {
        let e_op = PimEnergy::of(&sys.plane, tech, 128, 0.5).total_op(sys.input_bits);
        EnergySchedule { sys: sys.clone(), model, costs: EnergyCosts::default(), e_op }
    }

    fn smvm_energy(&self, shape: MvmShape) -> TokenEnergy {
        let tiles = shape.tiles(self.sys.tile_rows(), self.sys.tile_cols()) as f64;
        let pim = tiles * self.e_op;
        // Input broadcast + output vectors over the channel buses.
        let bytes = shape.m as f64 + 2.0 * shape.n as f64;
        TokenEnergy { pim, bus: bytes * self.costs.bus_per_byte, ..Default::default() }
    }

    fn op_energy(&self, op: &BlockOp, l_ctx: usize) -> TokenEnergy {
        let mut e = TokenEnergy::default();
        match op {
            BlockOp::Smvm { shape, .. } => {
                let s = self.smvm_energy(*shape);
                e.pim += s.pim;
                e.bus += s.bus;
            }
            BlockOp::DmvmQk { heads, d_head } | BlockOp::DmvmSv { heads, d_head } => {
                let macs = (*heads * l_ctx * d_head) as f64;
                e.rpu += macs * self.costs.rpu_per_mac;
                e.bus += (*heads * l_ctx) as f64 * 2.0 * self.costs.bus_per_byte;
            }
            BlockOp::Softmax { heads } => {
                e.cores += (*heads * l_ctx) as f64 * self.costs.core_per_elem;
            }
            BlockOp::LayerNorm { d } => {
                e.cores += *d as f64 * self.costs.core_per_elem;
            }
        }
        e
    }

    /// Full-token energy at context length `l_ctx`.
    pub fn token_energy(&self, l_ctx: usize) -> TokenEnergy {
        let mut e = TokenEnergy::default();
        for op in decoder_block_ops(&self.model) {
            let o = self.op_energy(&op, l_ctx);
            e.pim += o.pim;
            e.bus += o.bus;
            e.rpu += o.rpu;
            e.cores += o.cores;
        }
        let layers = self.model.layers as f64;
        e.pim *= layers;
        e.bus *= layers;
        e.rpu *= layers;
        e.cores *= layers;
        for op in head_ops(&self.model) {
            let o = self.op_energy(&op, l_ctx);
            e.pim += o.pim;
            e.bus += o.bus;
            e.rpu += o.rpu;
            e.cores += o.cores;
        }
        e
    }

    /// GPU-side energy per token for comparison: HBM traffic at
    /// ~7 pJ/byte plus baseline board power over the TPOT.
    pub fn gpu_energy_per_token(&self, tpot: f64, idle_power_w: f64) -> f64 {
        let traffic = self.model.weight_bytes(1.0);
        traffic * 7.0e-12 + idle_power_w * tpot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    fn sched(m: OptModel) -> EnergySchedule {
        EnergySchedule::new(&table1_system(), &TechParams::default(), m.shape())
    }

    #[test]
    fn opt30b_token_energy_sub_joule() {
        // ~58K tiles × ~20 nJ ≈ 1 mJ of PIM energy — orders below a GPU.
        let e = sched(OptModel::Opt30b).token_energy(1024);
        assert!(e.total() > 1e-5 && e.total() < 1e-1, "total {:e}", e.total());
        assert!(e.pim > 0.0 && e.bus > 0.0 && e.rpu > 0.0 && e.cores > 0.0);
    }

    #[test]
    fn energy_scales_with_model_size() {
        let small = sched(OptModel::Opt6_7b).token_energy(1024).total();
        let big = sched(OptModel::Opt175b).token_energy(1024).total();
        assert!(big > 4.0 * small);
    }

    #[test]
    fn dmvm_and_softmax_energy_grow_with_context() {
        let s = sched(OptModel::Opt30b);
        let a = s.token_energy(512);
        let b = s.token_energy(4096);
        assert!(b.rpu > a.rpu);
        assert!(b.cores > a.cores);
        assert!((b.pim - a.pim).abs() < 1e-12, "sMVM energy is context-free");
    }

    #[test]
    fn flash_beats_gpu_energy_per_token() {
        // The cost argument: flash PIM energy/token ≪ 4×RTX4090
        // (4 × ~450 W board power over a ~17 ms token).
        let s = sched(OptModel::Opt30b);
        let flash = s.token_energy(1536).total();
        let gpu = s.gpu_energy_per_token(17e-3, 4.0 * 450.0);
        assert!(flash < gpu / 10.0, "flash {flash:e} vs gpu {gpu:e}");
    }
}
