//! W8A8 quantization spec (paper §IV-A adopts SmoothQuant-style W8A8 for
//! the PIM arrays; RPUs compute `QK^T`/`SV` in INT16; controller cores run
//! softmax/LN in FP16).
//!
//! The functional counterpart (scales, nibble decomposition) lives in
//! `python/compile/quant.py`; this module carries the storage/bandwidth
//! accounting the simulators need.

/// Datatype widths used across the system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantSpec {
    /// Weight bits stored in flash (8 → two QLC cells per weight).
    pub weight_bits: usize,
    /// Activation bits streamed bit-serially into the arrays.
    pub act_bits: usize,
    /// KV-cache element bits stored in SLC.
    pub kv_bits: usize,
    /// RPU operand bits (Table I: INT16).
    pub rpu_bits: usize,
    /// Controller-core element bits (FP16).
    pub core_bits: usize,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec { weight_bits: 8, act_bits: 8, kv_bits: 8, rpu_bits: 16, core_bits: 16 }
    }
}

impl QuantSpec {
    pub fn w8a8() -> QuantSpec {
        QuantSpec::default()
    }

    /// Bytes per weight.
    pub fn weight_bytes(&self) -> f64 {
        self.weight_bits as f64 / 8.0
    }

    /// QLC cells needed per weight.
    pub fn cells_per_weight(&self, bits_per_cell: usize) -> usize {
        self.weight_bits.div_ceil(bits_per_cell)
    }

    /// Bit-serial input passes per activation.
    pub fn input_passes(&self) -> usize {
        self.act_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn w8a8_uses_two_qlc_cells_per_weight() {
        // Paper §II-B: an 8-bit weight spans two QLC cells (two BLs).
        assert_eq!(QuantSpec::w8a8().cells_per_weight(4), 2);
    }

    #[test]
    fn eight_input_passes() {
        assert_eq!(QuantSpec::w8a8().input_passes(), 8);
    }

    #[test]
    fn weight_byte_accounting() {
        assert_eq!(QuantSpec::w8a8().weight_bytes(), 1.0);
    }
}
