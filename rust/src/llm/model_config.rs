//! OPT-family model shapes (paper §V-B benchmarks: OPT-6.7B … OPT-175B)
//! plus the reference models of Fig. 1a.

/// Architectural shape of a decoder-only LLM.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelShape {
    pub name: String,
    /// Decoder blocks (`N_B`).
    pub layers: usize,
    /// Hidden dimension (`d_m`).
    pub d_model: usize,
    /// Attention heads (`N_H`).
    pub heads: usize,
    /// FFN inner dimension (4 × d_m for OPT).
    pub d_ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum trained context length (learned positions; 2048 for the
    /// OPT family). Latency tables tabulate up to here and extrapolate
    /// linearly beyond.
    pub max_context: usize,
}

impl ModelShape {
    /// Head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.heads
    }

    /// Total parameter count (decoder blocks + embeddings/LM head).
    ///
    /// Per block: QKV (3 d²) + O (d²) + FFN (2 · d · d_ffn) + LN/bias
    /// (≈ small, ignored); embeddings: vocab × d (tied LM head).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let per_block = 4 * d * d + 2 * d * self.d_ffn as u64;
        self.layers as u64 * per_block + self.vocab as u64 * d
    }

    /// Weight bytes at `bytes_per_param` (2 for FP16, 1 for W8A8).
    pub fn weight_bytes(&self, bytes_per_param: f64) -> f64 {
        self.params() as f64 * bytes_per_param
    }

    /// KV-cache bytes for `tokens` context at `bytes_per_elem`.
    pub fn kv_bytes(&self, tokens: usize, bytes_per_elem: f64) -> f64 {
        2.0 * self.layers as f64 * self.d_model as f64 * tokens as f64 * bytes_per_elem
    }

    /// KV bytes appended per generated token.
    pub fn kv_bytes_per_token(&self, bytes_per_elem: f64) -> f64 {
        2.0 * self.layers as f64 * self.d_model as f64 * bytes_per_elem
    }
}

/// The OPT family used in Fig. 14a.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptModel {
    Opt6_7b,
    Opt13b,
    Opt30b,
    Opt66b,
    Opt175b,
}

impl OptModel {
    pub const ALL: [OptModel; 5] =
        [OptModel::Opt6_7b, OptModel::Opt13b, OptModel::Opt30b, OptModel::Opt66b, OptModel::Opt175b];

    pub fn shape(self) -> ModelShape {
        // (layers, d_model, heads) from the OPT paper (Zhang et al. 2022).
        let (name, layers, d_model, heads) = match self {
            OptModel::Opt6_7b => ("OPT-6.7B", 32, 4096, 32),
            OptModel::Opt13b => ("OPT-13B", 40, 5120, 40),
            OptModel::Opt30b => ("OPT-30B", 48, 7168, 56),
            OptModel::Opt66b => ("OPT-66B", 64, 9216, 72),
            OptModel::Opt175b => ("OPT-175B", 96, 12288, 96),
        };
        ModelShape {
            name: name.to_string(),
            layers,
            d_model,
            heads,
            d_ffn: 4 * d_model,
            vocab: 50272,
            max_context: 2048,
        }
    }

    pub fn from_name(s: &str) -> Option<OptModel> {
        let k = s.to_ascii_lowercase();
        Some(match k.as_str() {
            "opt-6.7b" | "6.7b" => OptModel::Opt6_7b,
            "opt-13b" | "13b" => OptModel::Opt13b,
            "opt-30b" | "30b" => OptModel::Opt30b,
            "opt-66b" | "66b" => OptModel::Opt66b,
            "opt-175b" | "175b" => OptModel::Opt175b,
            _ => return None,
        })
    }
}

/// Reference (non-OPT) shapes quoted in Fig. 1a / §I.
pub fn fig1a_models() -> Vec<(String, f64)> {
    // (name, parameter count)
    vec![
        ("Mistral-7B".into(), 7.0e9),
        ("OPT-30B".into(), OptModel::Opt30b.shape().params() as f64),
        ("Mixtral-8x7B (47B)".into(), 47.0e9),
        ("OPT-66B".into(), OptModel::Opt66b.shape().params() as f64),
        ("GPT-3.5 (175B)".into(), 175.0e9),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt30b_shape_matches_paper() {
        // Paper §IV-A: N_B = 48, d_m = 7168 for OPT-30B.
        let s = OptModel::Opt30b.shape();
        assert_eq!(s.layers, 48);
        assert_eq!(s.d_model, 7168);
        assert_eq!(s.heads, 56);
        assert_eq!(s.d_head(), 128);
    }

    #[test]
    fn param_counts_near_nominal() {
        // Each model's computed parameter count is within 15 % of its name.
        let nominal = [6.7e9, 13e9, 30e9, 66e9, 175e9];
        for (m, n) in OptModel::ALL.iter().zip(nominal) {
            let p = m.shape().params() as f64;
            let err = (p - n).abs() / n;
            assert!(err < 0.15, "{}: {p:.3e} vs {n:.1e} ({:.1}%)", m.shape().name, err * 100.0);
        }
    }

    #[test]
    fn fig1a_mixtral_needs_94gib_fp16() {
        // Paper §I: 47B params × 2 B = 94 GiB-ish (they quote GiB loosely).
        let bytes = 47.0e9 * 2.0;
        assert!(bytes > 80e9 && bytes < 100e9);
    }

    #[test]
    fn kv_accounting() {
        let s = OptModel::Opt30b.shape();
        // Per-token KV (INT8): 2 × 48 × 7168 = 688,128 B.
        assert_eq!(s.kv_bytes_per_token(1.0) as u64, 688_128);
        assert_eq!(s.kv_bytes(1024, 1.0) as u64, 688_128 * 1024);
    }

    #[test]
    fn name_parsing() {
        assert_eq!(OptModel::from_name("OPT-30B"), Some(OptModel::Opt30b));
        assert_eq!(OptModel::from_name("175b"), Some(OptModel::Opt175b));
        assert_eq!(OptModel::from_name("bert"), None);
    }

    #[test]
    fn d_head_is_128_for_all() {
        for m in OptModel::ALL {
            assert_eq!(m.shape().d_head(), 128, "{}", m.shape().name);
        }
    }
}
