//! # flashpim
//!
//! A reproduction of *"Dissecting and Re-architecting 3D NAND Flash PIM
//! Arrays for Efficient Single-Batch Token Generation in LLMs"* (CS.AR 2025).
//!
//! The crate implements, from scratch, every system the paper describes or
//! depends on:
//!
//! * [`circuit`] — the RC/Horowitz circuit model behind the plane-size
//!   design-space exploration (paper Eqs. 3–6, Fig. 6).
//! * [`dse`] — the design-space sweep and plane selection (`256×2048×128`).
//! * [`sim`] — the discrete-event simulation core: integer-picosecond
//!   time, a deterministic event queue/engine, and resource timelines.
//! * [`nand`] — the 3D NAND hierarchy (channel/way/die/plane, SLC/QLC).
//! * [`bus`] — shared-bus and H-tree intra-die interconnects with RPUs
//!   (Figs. 7–9).
//! * [`pim`] — sMVM/dMVM execution pipelines (inbound I/O, PIM, outbound).
//! * [`tiling`] — the tiling/mapping search across the flash hierarchy
//!   (Fig. 11–12).
//! * [`llm`] — OPT-family model shapes and the decoder-block operation
//!   schedule for token generation (Fig. 10).
//! * [`kv`] — the SLC KV-cache manager, endurance, and lifetime analysis.
//! * [`fault`] — deterministic fault injection for serving: read-retry
//!   storms, hard device loss, and the retry/failover/brownout recovery
//!   policies (`serve-sim --faults`, see `docs/FAULTS.md`).
//! * [`gpu`] — the GPU baselines (4×RTX4090 + vLLM, 4×A100 + AttAcc).
//! * [`area`] — the peri-under-array area model (Table II).
//! * [`controller`] — SSD-controller ARM cores (LN/softmax) and PCIe.
//! * [`coordinator`] — the serving subsystem: a *pool* of flash-PIM
//!   devices behind a scheduler (round-robin / least-loaded / SLO-aware
//!   policies, KV affinity, bounded queues with backpressure), the
//!   request router and offload logic, a deterministic event-driven
//!   closed-loop Poisson traffic simulator (`serve-sim`, bit-identical
//!   reports per seed) with a legacy direct-replay cross-check,
//!   multi-class workload mixes with per-class SLO targets
//!   (`serve-sim --workload`, see `docs/WORKLOADS.md`), arrival-rate
//!   sweeps with SLO frontiers, the functional generation loop, and
//!   serving metrics (TTFT/TPOT/latency percentiles, per-class SLO
//!   attainment, per-device utilization).
//! * [`runtime`] — the PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   artifacts (HLO text) and executes the functional model.
//! * [`exp`] — one driver per paper figure/table, shared by the CLI and the
//!   benches.
//!
//! See `docs/ARCHITECTURE.md` for the module map, the data flow of a
//! request through the serving stack, and the paper-section → source-file
//! index.

pub mod area;
pub mod bus;
pub mod campaign;
pub mod circuit;
pub mod cli;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod dse;
pub mod exp;
pub mod fault;
pub mod gpu;
pub mod kv;
pub mod llm;
pub mod nand;
pub mod pim;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
