//! Fig. 12 — latency breakdown (inbound I/O, PIM, outbound I/O) of the
//! three named tiling options for the OPT-30B `d_m × d_m` sMVM, plus the
//! search-best scheme.

use crate::circuit::TechParams;
use crate::config::presets::table1_system;
use crate::nand::NandTiming;
use crate::pim::op::MvmShape;
use crate::tiling::cost::{fig12_cases, TilingCost, TilingCostModel};
use crate::tiling::search_best;
use crate::util::table::Table;
use crate::util::units::fmt_time;

pub fn model() -> TilingCostModel {
    let sys = table1_system();
    let timing = NandTiming::of_system(&sys, &TechParams::default());
    TilingCostModel::new(&sys, timing)
}

/// OPT-30B projection shape (d_m = 7168).
pub fn shape() -> MvmShape {
    MvmShape::new(7168, 7168)
}

/// The three named cases with costs.
pub fn fig12() -> Vec<(String, TilingCost)> {
    let m = model();
    fig12_cases(&m, shape())
        .into_iter()
        .map(|(name, s)| (format!("{} [{}]", name, s.notation_counts()), m.cost(&s, shape())))
        .collect()
}

/// The best scheme: exhaustive search pool plus the named Fig. 12 cases
/// (whose ceil-covering counts are outside the exact-factor enumeration).
pub fn best() -> (String, TilingCost) {
    let m = model();
    let mut pool: Vec<(String, TilingCost)> = search_best(&m, shape())
        .into_iter()
        .map(|r| (r.scheme.notation_counts(), r.cost))
        .collect();
    pool.extend(
        fig12_cases(&m, shape()).into_iter().map(|(_, s)| (s.notation_counts(), m.cost(&s, shape()))),
    );
    pool.into_iter()
        .min_by(|a, b| a.1.total().cmp(&b.1.total()))
        .expect("non-empty pool")
}

pub fn render() -> String {
    let mut t = Table::new(&["tiling (ch/way/die/plane)", "inbound", "PIM", "outbound", "total"]);
    for (name, c) in fig12() {
        t.row(&[
            name,
            fmt_time(c.inbound.secs()),
            fmt_time(c.pim.secs()),
            fmt_time(c.outbound.secs()),
            fmt_time(c.total().secs()),
        ]);
    }
    let (bname, bc) = best();
    format!(
        "Fig 12 — sMVM tiling options (OPT-30B d_m=7168):\n{}search best: {} total {}\n",
        t.render(),
        bname,
        fmt_time(bc.total().secs())
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_cases_reported() {
        assert_eq!(fig12().len(), 3);
    }

    #[test]
    fn best_no_worse_than_named_cases() {
        let (_, bc) = best();
        for (name, c) in fig12() {
            assert!(bc.total() <= c.total(), "search best worse than {name}");
        }
    }
}
