//! Fig. 5 — TPOT of OPT-30B on the *conventional* 3D NAND PIM (naïve:
//! conventional plane size, shared bus, ONFI-style per-channel command
//! serialization) vs the proposed architecture: ~1.4 s vs ~7 ms (≈210×),
//! and ≈2.4–2.5× faster than 4×RTX4090 with vLLM.

use crate::circuit::{PlaneLatency, TechParams};
use crate::config::presets::{conventional_plane, table1_system};
use crate::controller::ArmCores;
use crate::exp::fig14::flash_tpot;
use crate::llm::layers::{decoder_block_ops, head_ops, BlockOp};
use crate::llm::model_config::OptModel;
use crate::pim::op::MvmShape;

/// Conventional-PIM TPOT model: tile ops execute at the conventional
/// plane's `T_PIM` and serialize per channel — the conventional ONFI
/// command protocol issues one synchronous PIM op per channel at a time
/// (results must be accumulated at the channel controller before the
/// next op can be issued), so only channel-level parallelism survives.
pub fn conventional_tpot(model: OptModel, l_ctx: usize) -> f64 {
    let sys = table1_system();
    let tech = TechParams::default();
    let plane = conventional_plane();
    let lat = PlaneLatency::of(&plane, &tech);
    let t_pim = lat.t_pim(sys.input_bits);

    // Conventional unit tile: u rows × (page/4) columns.
    let u = sys.tile_rows();
    let tile_cols = plane.n_col / sys.col_mux;

    let shape = model.shape();
    let count_shape =
        |s: MvmShape| -> u64 { (s.row_tiles(u) * s.col_tiles(tile_cols)) as u64 };
    let per_block_tiles: u64 = decoder_block_ops(&shape)
        .into_iter()
        .filter_map(|op| match op {
            BlockOp::Smvm { shape: s, .. } => Some(count_shape(s)),
            _ => None,
        })
        .sum();
    let head_tiles: u64 = head_ops(&shape)
        .into_iter()
        .filter_map(|op| match op {
            BlockOp::Smvm { shape: s, .. } => Some(count_shape(s)),
            _ => None,
        })
        .sum();
    let tiles = per_block_tiles * shape.layers as u64 + head_tiles;

    let per_channel = tiles.div_ceil(sys.org.channels as u64);
    let smvm = per_channel as f64 * t_pim;

    // LN/softmax still run on the controller cores; dMVM reads pay the
    // conventional page-read latency (minor next to the sMVM serial wall).
    let cores = ArmCores::new(sys.ctrl);
    let mut other = 0.0;
    for _ in 0..shape.layers {
        other += 2.0 * cores.ln_time(shape.d_model).secs();
        other += cores.softmax_time(shape.heads, l_ctx).secs();
    }
    smvm + other
}

/// The Fig. 5 comparison rows: (label, TPOT seconds).
pub fn fig5() -> Vec<(String, f64)> {
    let sys = table1_system();
    let conv = conventional_tpot(OptModel::Opt30b, 1024 + 512);
    let prop = flash_tpot(&sys, OptModel::Opt30b, 1024, 1024);
    let gpu = crate::gpu::rtx4090x4_vllm()
        .tpot(&OptModel::Opt30b.shape(), 1.0, 1024 + 512)
        .expect("OPT-30B W8A8 fits");
    vec![
        ("conventional 3D NAND PIM".into(), conv),
        ("proposed 3D NAND PIM".into(), prop),
        ("4xRTX4090 (vLLM)".into(), gpu),
    ]
}

pub fn render() -> String {
    let rows = fig5();
    let conv = rows[0].1;
    let prop = rows[1].1;
    let gpu = rows[2].1;
    let mut t = crate::util::table::Table::new(&["configuration", "TPOT"]);
    for (name, v) in &rows {
        t.row(&[name.clone(), crate::util::units::fmt_time(*v)]);
    }
    format!(
        "{}\nimprovement over conventional: {:.0}x   speedup vs 4xRTX4090: {:.2}x\n",
        t.render(),
        conv / prop,
        gpu / prop
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conventional_near_1_4s() {
        // Paper Fig. 5: 1.4 s per token with the naïve conventional PIM.
        let t = conventional_tpot(OptModel::Opt30b, 1536);
        assert!((1.0..=1.9).contains(&t), "conventional TPOT = {t:.3} s");
    }

    #[test]
    fn improvement_near_210x() {
        // Paper: "we can significantly improve the time required to
        // generate an output token by 210×". Tolerance: 150–280×.
        let rows = fig5();
        let ratio = rows[0].1 / rows[1].1;
        assert!((150.0..=280.0).contains(&ratio), "improvement = {ratio:.0}x");
    }

    #[test]
    fn speedup_vs_4090_near_2_5x() {
        // Paper Fig. 5: ≈2.5× faster than 4×RTX4090 + vLLM.
        let rows = fig5();
        let speedup = rows[2].1 / rows[1].1;
        assert!((1.9..=3.1).contains(&speedup), "speedup = {speedup:.2}x");
    }

    #[test]
    fn gpu_advantage_near_10ms_for_break_even() {
        // §IV-B uses "generating a token on 4×RTX4090 takes 10 ms longer
        // than our flash PIM" for the 12-token break-even.
        let rows = fig5();
        let diff = rows[2].1 - rows[1].1;
        assert!((5e-3..=15e-3).contains(&diff), "diff = {diff:.4} s");
    }
}
