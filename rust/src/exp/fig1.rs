//! Fig. 1 — (a) LLM memory requirements vs GPU DRAM capacity;
//! (b) token-generation latency vs summarization latency (OPT-30B on
//! 4×RTX4090, 1K tokens each way).

use crate::gpu::rtx4090x4_vllm;
use crate::llm::model_config::{fig1a_models, OptModel};
use crate::util::table::Table;
use crate::util::units::fmt_bytes;

/// Fig. 1a rows: model → FP16 bytes → H100s (80 GiB) needed.
pub fn fig1a() -> Vec<(String, f64, usize)> {
    fig1a_models()
        .into_iter()
        .map(|(name, params)| {
            let bytes = params * 2.0;
            let h100 = (bytes / (80.0 * 1e9)).ceil() as usize;
            (name, bytes, h100)
        })
        .collect()
}

/// Fig. 1b: (summarization latency, generation latency, ratio) for
/// OPT-30B FP16 on 4×RTX4090 with 1K input / 1K output tokens.
pub fn fig1b() -> (f64, f64, f64) {
    let g = rtx4090x4_vllm();
    let m = OptModel::Opt30b.shape();
    let summarize = g.prefill(&m, 1024);
    let generate = g.generate(&m, 2.0, 1024, 1024).expect("OPT-30B FP16 fits 4x4090 for timing");
    (summarize, generate, generate / summarize)
}

pub fn render() -> String {
    let mut t = Table::new(&["model", "FP16 memory", "H100s (80GB)"]);
    for (name, bytes, h100) in fig1a() {
        t.row(&[name, fmt_bytes(bytes), h100.to_string()]);
    }
    let (s, g, r) = fig1b();
    format!(
        "{}\nFig1b (OPT-30B, 4xRTX4090): summarize 1K = {:.3} s, generate 1K = {:.2} s, ratio = {:.0}x\n",
        t.render(),
        s,
        g,
        r
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt35_needs_five_h100s() {
        // Paper §I: 175B → 350 GB → five H100 GPUs.
        let rows = fig1a();
        let gpt = rows.iter().find(|(n, _, _)| n.contains("GPT-3.5")).unwrap();
        assert_eq!(gpt.2, 5);
    }

    #[test]
    fn mixtral_exceeds_single_h100() {
        let rows = fig1a();
        let mix = rows.iter().find(|(n, _, _)| n.contains("Mixtral")).unwrap();
        assert!(mix.1 > 80.0 * 1e9);
        assert_eq!(mix.2, 2);
    }

    #[test]
    fn fig1b_ratio_near_46x() {
        // Paper Fig. 1b: generation is ~46× slower than summarization.
        let (_, _, r) = fig1b();
        assert!((30.0..=65.0).contains(&r), "ratio = {r:.1}");
    }
}
