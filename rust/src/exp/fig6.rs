//! Fig. 6 — latency (a), energy (b), and cell density (c) across the
//! 3D NAND PIM plane configuration sweep, plus the §III-B selection.

use crate::circuit::TechParams;
use crate::dse::select::{select_plane, SelectionCriteria};
use crate::dse::sweep::{fig6_sweeps, DsePoint, SweepAxis};
use crate::util::table::Table;
use crate::util::units::{fmt_energy, fmt_time};

/// All three sweeps.
pub fn fig6() -> Vec<(SweepAxis, Vec<DsePoint>)> {
    fig6_sweeps(&TechParams::default())
}

/// The §III-B selection result.
pub fn selection() -> DsePoint {
    select_plane(&SelectionCriteria::default(), &TechParams::default())
        .expect("default budget feasible")
        .0
}

pub fn render() -> String {
    let mut out = String::new();
    for (axis, points) in fig6() {
        let mut t = Table::new(&[axis.label(), "T_PIM (8b)", "energy/op", "density Gb/mm2"]);
        for p in &points {
            let v = match axis {
                SweepAxis::Rows => p.plane.n_row,
                SweepAxis::Cols => p.plane.n_col,
                SweepAxis::Stacks => p.plane.n_stack,
            };
            t.row(&[
                v.to_string(),
                fmt_time(p.t_pim),
                fmt_energy(p.energy),
                format!("{:.2}", p.density),
            ]);
        }
        out.push_str(&format!("Fig 6 — sweep over {}:\n{}\n", axis.label(), t.render()));
    }
    let sel = selection();
    out.push_str(&format!(
        "selected plane: {}x{}x{}  (T_PIM {}, density {:.2} Gb/mm2)\n",
        sel.plane.n_row,
        sel.plane.n_col,
        sel.plane.n_stack,
        fmt_time(sel.t_pim),
        sel.density
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::size_a_plane;

    #[test]
    fn selection_is_size_a() {
        assert_eq!(selection().plane, size_a_plane());
    }

    #[test]
    fn selected_latency_near_2us() {
        let s = selection();
        assert!((1.7e-6..=2.1e-6).contains(&s.t_pim), "{}", s.t_pim);
    }

    #[test]
    fn sweeps_have_paper_ranges() {
        let sweeps = fig6();
        assert_eq!(sweeps.len(), 3);
        for (_, pts) in sweeps {
            assert!(pts.len() >= 5);
        }
    }
}
