//! Fig. 9 — (a) shared bus vs H-tree execution time on the three MVM
//! shapes; (b) Size A (64 planes) vs Size B (128 planes, throughput-
//! matched) with the H-tree.

use crate::circuit::TechParams;
use crate::config::presets::{table1_shared_bus, table1_size_b, table1_system};
use crate::config::SystemConfig;
use crate::nand::NandTiming;
use crate::pim::op::MvmShape;
use crate::pim::smvm::{ExecReport, SmvmPipeline};
use crate::util::table::Table;
use crate::util::units::fmt_time;

/// The paper's three evaluation shapes `(1,M)×(M,N)`.
pub fn shapes() -> [MvmShape; 3] {
    [MvmShape::new(1024, 1024), MvmShape::new(1024, 4096), MvmShape::new(4096, 1024)]
}

fn pipeline(sys: &SystemConfig, planes: usize) -> SmvmPipeline {
    let timing = NandTiming::of_system(sys, &TechParams::default());
    SmvmPipeline::new(sys, timing, planes)
}

/// Fig. 9a rows: per shape, (shared total, htree total, reduction).
pub fn fig9a() -> Vec<(MvmShape, ExecReport, ExecReport, f64)> {
    let shared = pipeline(&table1_shared_bus(), 64);
    let htree = pipeline(&table1_system(), 64);
    shapes()
        .into_iter()
        .map(|s| {
            let a = shared.execute(s);
            let b = htree.execute(s);
            let red = 1.0 - b.total.secs() / a.total.secs();
            (s, a, b, red)
        })
        .collect()
}

/// Fig. 9b rows: per shape, (Size B total @128 planes, Size A total @64
/// planes, overhead of A).
pub fn fig9b() -> Vec<(MvmShape, ExecReport, ExecReport, f64)> {
    let a = pipeline(&table1_system(), 64);
    let b = pipeline(&table1_size_b(), 128);
    shapes()
        .into_iter()
        .map(|s| {
            let rb = b.execute(s);
            let ra = a.execute(s);
            let overhead = ra.total.secs() / rb.total.secs() - 1.0;
            (s, rb, ra, overhead)
        })
        .collect()
}

pub fn render() -> String {
    let mut t = Table::new(&["MVM (M,N)", "shared bus", "H-tree", "reduction"]);
    let mut reds = Vec::new();
    for (s, a, b, r) in fig9a() {
        reds.push(r);
        t.row(&[
            format!("({},{})", s.m, s.n),
            fmt_time(a.total.secs()),
            fmt_time(b.total.secs()),
            format!("{:.0}%", r * 100.0),
        ]);
    }
    let mut t2 = Table::new(&["MVM (M,N)", "Size B (128 pl)", "Size A (64 pl)", "A overhead"]);
    let mut ovs = Vec::new();
    for (s, rb, ra, o) in fig9b() {
        ovs.push(o);
        t2.row(&[
            format!("({},{})", s.m, s.n),
            fmt_time(rb.total.secs()),
            fmt_time(ra.total.secs()),
            format!("{:+.0}%", o * 100.0),
        ]);
    }
    format!(
        "Fig 9a — shared vs H-tree (64 planes, Size A):\n{}mean reduction: {:.1}%\n\nFig 9b — plane size (H-tree, throughput-matched):\n{}mean Size-A overhead: {:+.1}% (2x cell density)\n",
        t.render(),
        crate::util::stats::mean(&reds) * 100.0,
        t2.render(),
        crate::util::stats::mean(&ovs) * 100.0
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn htree_wins_every_shape() {
        for (s, shared, htree, _) in fig9a() {
            assert!(htree.total < shared.total, "{s:?}");
        }
    }

    #[test]
    fn size_a_slower_but_denser() {
        // Every shape: Size A costs more time (it buys 2× density).
        for (s, _b, _a, overhead) in fig9b() {
            assert!(overhead > -0.05, "{s:?}: overhead {overhead}");
        }
    }
}
