//! Experiment drivers — one per paper table/figure, shared by the CLI
//! (`repro <exp>`) and the benches (`cargo bench --bench <exp>`).
//! Each driver returns the same rows/series the paper reports and can
//! render them as an ASCII table.

pub mod fig1;
pub mod fig5;
pub mod fig6;
pub mod fig9;
pub mod fig12;
pub mod fig14;
pub mod table2;

pub use fig14::flash_tpot;
