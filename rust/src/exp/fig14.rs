//! Fig. 14 — (a) TPOT across OPT model sizes: flash PIM vs 4×RTX4090
//! (vLLM) vs 4×A100 (AttAcc); (b) flash-PIM execution-time breakdown by
//! input/output token lengths.

use crate::circuit::TechParams;
use crate::config::presets::table1_system;
use crate::config::SystemConfig;
use crate::gpu::{a100x4_attacc, rtx4090x4_vllm};
use crate::llm::model_config::OptModel;
use crate::llm::schedule::{TokenBreakdown, TokenSchedule};
use crate::util::table::Table;
use crate::util::units::fmt_time;

/// One Fig. 14a row.
#[derive(Debug, Clone)]
pub struct Fig14aRow {
    pub model: String,
    pub flash: f64,
    /// `None` = OOM.
    pub rtx4090: Option<f64>,
    pub a100: Option<f64>,
}

/// Flash-PIM mean TPOT for a model at the paper's 1K-in/1K-out setting.
pub fn flash_tpot(sys: &SystemConfig, model: OptModel, l_in: usize, l_out: usize) -> f64 {
    let mut sched = TokenSchedule::new(sys, &TechParams::default(), model.shape());
    sched.mean_tpot(l_in, l_out)
}

/// Fig. 14a rows (1K input + 1K output tokens, W8A8).
pub fn fig14a() -> Vec<Fig14aRow> {
    let sys = table1_system();
    let g4090 = rtx4090x4_vllm();
    let ga100 = a100x4_attacc();
    OptModel::ALL
        .iter()
        .map(|m| {
            let shape = m.shape();
            let mid_ctx = 1024 + 512;
            Fig14aRow {
                model: shape.name.clone(),
                flash: flash_tpot(&sys, *m, 1024, 1024),
                rtx4090: g4090.tpot(&shape, 1.0, mid_ctx),
                a100: ga100.tpot(&shape, 1.0, mid_ctx),
            }
        })
        .collect()
}

/// Summary stats for the Fig. 14a acceptance anchors.
pub struct Fig14aSummary {
    /// Mean speedup of flash over 4×RTX4090 across models that fit.
    pub mean_speedup_vs_4090: f64,
    /// Mean latency overhead of flash vs 4×A100 across all models.
    pub mean_overhead_vs_a100: f64,
    /// Models that OOM on the 4090 setup.
    pub oom_models: Vec<String>,
}

pub fn fig14a_summary(rows: &[Fig14aRow]) -> Fig14aSummary {
    let speedups: Vec<f64> =
        rows.iter().filter_map(|r| r.rtx4090.map(|g| g / r.flash)).collect();
    let overheads: Vec<f64> =
        rows.iter().filter_map(|r| r.a100.map(|a| r.flash / a - 1.0)).collect();
    Fig14aSummary {
        mean_speedup_vs_4090: crate::util::stats::mean(&speedups),
        mean_overhead_vs_a100: crate::util::stats::mean(&overheads),
        oom_models: rows
            .iter()
            .filter(|r| r.rtx4090.is_none())
            .map(|r| r.model.clone())
            .collect(),
    }
}

/// Fig. 14b: breakdown at the four (input, output) length combinations.
pub fn fig14b() -> Vec<((usize, usize), TokenBreakdown)> {
    let sys = table1_system();
    let mut sched =
        TokenSchedule::new(&sys, &TechParams::default(), OptModel::Opt30b.shape());
    [(1024, 1024), (1024, 2048), (2048, 1024), (2048, 2048)]
        .into_iter()
        .map(|(l_in, l_out)| {
            // Breakdown at the mid-generation context.
            let b = sched.token_breakdown(l_in + l_out / 2);
            ((l_in, l_out), b)
        })
        .collect()
}

/// Render Fig. 14a as the paper's table.
pub fn render_fig14a(rows: &[Fig14aRow]) -> String {
    let mut t = Table::new(&["model", "flash PIM", "4xRTX4090 (vLLM)", "4xA100 (AttAcc)"]);
    for r in rows {
        t.row(&[
            r.model.clone(),
            fmt_time(r.flash),
            r.rtx4090.map(fmt_time).unwrap_or_else(|| "OOM".into()),
            r.a100.map(fmt_time).unwrap_or_else(|| "OOM".into()),
        ]);
    }
    let s = fig14a_summary(rows);
    format!(
        "{}\nmean speedup vs 4xRTX4090: {:.2}x   mean overhead vs 4xA100: {:.1}%   OOM: {:?}\n",
        t.render(),
        s.mean_speedup_vs_4090,
        s.mean_overhead_vs_a100 * 100.0,
        s.oom_models
    )
}

/// Render Fig. 14b.
pub fn render_fig14b(rows: &[((usize, usize), TokenBreakdown)]) -> String {
    let mut t = Table::new(&["in/out", "sMVM", "dMVM", "LN", "softmax", "total"]);
    for ((li, lo), b) in rows {
        t.row(&[
            format!("{li}/{lo}"),
            fmt_time(b.smvm),
            fmt_time(b.dmvm),
            fmt_time(b.ln),
            fmt_time(b.softmax),
            fmt_time(b.total()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14a_anchors() {
        // Paper: 2.4× mean speedup over 4×RTX4090; 4.9 % mean overhead
        // vs 4×A100; OPT-66B/175B OOM on the 4090s.
        let rows = fig14a();
        let s = fig14a_summary(&rows);
        assert!(
            (1.9..=3.1).contains(&s.mean_speedup_vs_4090),
            "speedup {:.2} — rows: {}",
            s.mean_speedup_vs_4090,
            render_fig14a(&rows)
        );
        assert!(
            (-0.05..=0.15).contains(&s.mean_overhead_vs_a100),
            "overhead {:.3} — rows: {}",
            s.mean_overhead_vs_a100,
            render_fig14a(&rows)
        );
        assert_eq!(s.oom_models, vec!["OPT-66B".to_string(), "OPT-175B".to_string()]);
    }

    #[test]
    fn fig14a_flash_beats_4090_everywhere_it_fits() {
        for r in fig14a() {
            if let Some(g) = r.rtx4090 {
                assert!(r.flash < g, "{}: flash {} vs 4090 {}", r.model, r.flash, g);
            }
        }
    }

    #[test]
    fn fig14b_smvm_flat_softmax_grows() {
        let rows = fig14b();
        // sMVM identical across all four length combos.
        let s0 = rows[0].1.smvm;
        for (_, b) in &rows {
            assert!((b.smvm - s0).abs() < 1e-9);
        }
        // softmax at 2048/2048 > softmax at 1024/1024.
        assert!(rows[3].1.softmax > rows[0].1.softmax);
    }
}
