//! Table II — per-plane area breakdown of the peripheral circuits and the
//! H-tree network with RPUs, plus the §V-C die-budget feasibility check.

use crate::area::budget::die_budget_mm2;
use crate::area::peri::{AreaBreakdown, AreaModel};
use crate::circuit::TechParams;
use crate::config::presets::table1_system;
use crate::util::table::Table;

pub fn breakdown() -> AreaBreakdown {
    AreaModel::new(&TechParams::default()).breakdown(&table1_system())
}

pub fn die_array_mm2() -> f64 {
    AreaModel::new(&TechParams::default()).die_array_mm2(&table1_system())
}

pub fn render() -> String {
    let b = breakdown();
    let (hv, lv, rpu) = b.ratios();
    let mut t = Table::new(&["component", "area [mm2/plane]", "ratio in plane"]);
    t.row(&["HV-peri + cap".into(), format!("{:.6}", b.hv_peri * 1e6), format!("{:.2}%", hv * 100.0)]);
    t.row(&["LV-peri".into(), format!("{:.6}", b.lv_peri * 1e6), format!("{:.2}%", lv * 100.0)]);
    t.row(&["RPU + H-tree".into(), format!("{:.6}", b.rpu_htree * 1e6), format!("{:.2}%", rpu * 100.0)]);
    let (lo, hi) = die_budget_mm2();
    format!(
        "Table II — area breakdown per plane:\n{}\n256-plane die array: {:.2} mm2 (budget {:.1}-{:.1} mm2) — fits under array: {}\n",
        t.render(),
        die_array_mm2(),
        lo,
        hi,
        b.fits_under_array()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_all_rows() {
        let s = render();
        assert!(s.contains("HV-peri"));
        assert!(s.contains("LV-peri"));
        assert!(s.contains("RPU + H-tree"));
        assert!(s.contains("fits under array: true"));
    }

    #[test]
    fn die_within_budget() {
        let (lo, hi) = die_budget_mm2();
        let a = die_array_mm2();
        assert!(a < hi, "array {a:.2} exceeds budget high {hi:.2}");
        assert!(a < lo * 1.2, "array should sit near/below the low budget");
    }
}
