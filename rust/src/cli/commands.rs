//! CLI command dispatch.

use super::args::Args;
use crate::campaign::{
    Backend, campaign_metrics, CampaignSpec, diff_metrics, Expr, render_campaign, run_campaign,
};
use crate::circuit::TechParams;
use crate::config::presets::table1_system;
use crate::coordinator::router::{POLICY_NAMES, TIERED_POLICY_NAMES};
use crate::dse::{codesign_metrics, render_codesign, run_codesign, run_codesign_seq, CodesignSpec};
use crate::coordinator::{
    ArrivalProcess, DecodeMode, FleetSpec, LenRange, policy_from_name, render_slo_frontier,
    render_sweep, run_traffic_events_mode, run_traffic_with_table, simulate, sweep_rates,
    sweep_rates_threaded, TrafficConfig, WearConfig, Workload, WorkloadMix,
};
use crate::exp;
use crate::fault::FaultConfig;
use crate::gpu::rtx4090x4_vllm;
use crate::kv::lifetime::{lifetime_years, lifetime_years_system};
use crate::llm::LatencyTable;
use crate::llm::model_config::OptModel;
use crate::runtime::{ArtifactBundle, ByteTokenizer, DecodeExecutor};
use anyhow::{anyhow, bail, Context, Result};

const COMMANDS: &[&str] = &[
    "help", "fig1", "fig5", "fig6", "fig9", "fig12", "fig14", "table2", "dse", "codesign",
    "tiling", "lifetime", "serve", "serve-sim", "campaign", "generate", "config", "energy", "all",
];

const HELP: &str = "\
repro — 3D NAND flash PIM for single-batch LLM token generation (CS.AR 2025 reproduction)

experiments (regenerate the paper's tables/figures):
  fig1                 memory wall + generation-vs-summarization gap
  fig5                 conventional vs proposed PIM TPOT (OPT-30B)
  fig6                 plane-size sweep: latency / energy / density
  fig9                 shared bus vs H-tree; Size A vs Size B
  fig12                sMVM tiling option breakdown
  fig14                TPOT across OPT models vs GPU baselines + breakdown
  table2               area breakdown and die budget

tools:
  dse                  design-space selection (paper §III-B)
  codesign [--rows LO:HI --cols LO:HI --stacks LO:HI]
                       SLO-frontier-driven co-design campaign: for every
                       plane geometry in the power-of-two grid (default:
                       the §III-B selection grid, 84 candidates) derive
                       the Table-I system, build its exact latency table,
                       sweep serving rates (--rates 2,4,8,16,32) for
                       --workload (default chat) under --policies
                       (default least-loaded,round-robin,slo-aware),
                       score each candidate by the max offered rate whose
                       worst class still attains its SLOs >=
                       --attainment (default 0.99), price die array area
                       (--budget-mm2 overrides the paper's 7.5 mm2
                       package budget) and decode energy per Mtok, and
                       Pareto-rank over {sustained rate up, die mm2 down,
                       J/Mtok down}. Prints the top --top N candidates
                       (default 12, frontier first); --json PATH writes
                       canonical codesign/<RxCxS>/<workload>/<metric>
                       keys; --seq runs candidates sequentially
                       (byte-identical to the parallel default). Also
                       --devices, --requests, --seed, --model. See
                       docs/CODESIGN.md
  tiling --m M --n N   search the best tiling for an MVM shape
  lifetime             SLC KV-region endurance projection
  energy [--model NAME --tokens L]
                       per-token energy rollup vs GPU baseline
  serve [--requests N --gen-frac F --model NAME]
                       simulated serving trace (router + offload)
  serve-sim --devices N --rate R --requests K
                       closed-loop Poisson traffic against a flash-PIM
                       device pool (TTFT/TPOT/latency p50/p95/p99 and
                       per-device utilization). Runs on the deterministic
                       event-driven simulator by default (bit-identical
                       reports per seed, prefill prices the PCIe KV
                       upload, decode coalesced to one event per request);
                       --per-token replays the per-token event chain (the
                       bit-identity oracle), --threaded selects the legacy
                       direct cross-check backend. --fleet
                       COUNTxTIER(+COUNTxTIER)* — e.g. 4xflash+1xgpu —
                       replaces --devices with a typed roster mixing
                       flash-PIM cards (tier `flash`) and tensor-parallel
                       GPU nodes (tier `gpu`, priced by the gpu roofline);
                       the report gains per-tier utilization and fleet
                       cost/energy per Mtok, and the tier-aware policy
                       (long prefills -> GPU, short chat -> flash) becomes
                       available. --wear PE enables endurance
                       accounting: every flash KV write is charged
                       against a per-device P/E erase budget
                       (--wear-blocks, default 64, sets erase-block
                       granularity; --spares N adds hot spares that
                       join the roster when a device exhausts its
                       budget, drains, and retires mid-trace). The
                       report gains a wear section (programs, erases,
                       retirements, projected lifetime) and the
                       wear-aware policy routes fresh sessions to the
                       least-worn feasible device. --arrival
                       DUR_S:MULT,... layers an open-loop diurnal /
                       bursty phase schedule over the Poisson rate
                       (e.g. 28800:0.4,43200:1.6,14400:0.7; a 1.0
                       multiplier reproduces the legacy stream
                       byte-for-byte). --faults SPEC enables seeded
                       deterministic fault injection: read-retry
                       storms dilating a device's service time,
                       hard device loss mid-trace with spare
                       activation, per-request retry with backoff,
                       KV-loss failover (re-prefill on a survivor),
                       and brownout shedding. SPEC is a comma list
                       of storm=RATE:MULTxDUR, fail=RATE,
                       fail_at=DEV@SECS, detect=S, retries=N,
                       backoff=S, spares=N, brownout=FRAC (see
                       docs/FAULTS.md); the report gains a
                       reliability section, and an absent or inert
                       spec keeps output byte-identical to
                       fault-free runs. Also --policy
                       round-robin|least-loaded|slo-aware|tier-aware|
                       wear-aware, --queue-cap, --input-min/max,
                       --output-min/max,
                       --followup, --model, --seed. --workload
                       chat|summarize-long|agentic-burst|batch-offline|
                       FILE.toml replaces the single token-range stream
                       with a multi-class mix (per-class TTFT/TPOT
                       percentiles and SLO attainment in the report; see
                       docs/WORKLOADS.md). With --sweep, runs every
                       arrival rate (--rates 2,4,8 or --rate-min/
                       --rate-max/--rate-steps) under ALL policies
                       against one shared latency table, fanning points
                       out across cores (deterministic: output is
                       byte-equal to the sequential loop), and prints the
                       throughput-latency curve — plus, with --workload,
                       the max rate sustaining >=99% SLO attainment per
                       class (--policy and --rate are ignored in sweep
                       mode)
  campaign [--filter EXPR] [--baseline PATH] [--update-baseline]
                       run the scenario campaign matrix (policies x
                       workload presets x backends x rate grid) and diff
                       deterministic per-scenario metrics against the
                       committed bench/BENCH_serving.baseline.json,
                       exiting non-zero on regression (the CI gate).
                       --filter selects a slice with a small expression
                       language: atoms policy(NAME), workload(NAME),
                       class(NAME), backend(event|threaded), tier(NAME),
                       rate CMP N, combined with & | ! and parens — e.g.
                       'policy(slo-aware) & class(chat) & rate > 5' or
                       'tier(gpu) | tier(flash)'. --fleets a,b (e.g.
                       8xflash,4xflash+1xgpu) adds an outermost
                       fleet-composition axis; fleet scenarios key as
                       campaign/FLEET/... and emit cost/energy per Mtok.
                       --wear PE charges every scenario's flash KV
                       writes against a per-device P/E erase budget and
                       adds wear_max_erases / wear_total_erases /
                       wear_retirements metric keys (absent, not zero,
                       in wear-blind runs, keeping legacy documents
                       byte-identical). --faults SPEC (same grammar
                       as serve-sim; docs/FAULTS.md) threads one
                       deterministic fault schedule into every
                       scenario and adds faults_availability /
                       faults_failed / faults_shed and friends as
                       gated metric keys — the chaos campaign gate.
                       Also --list (print the matrix, run nothing),
                       --out PATH (write the fresh metrics JSON),
                       --tol FRACTION (relative tolerance, default 0.02),
                       --verbose (list passing rows too), --requests,
                       --devices, --seed, --model, --rates a,b,c,
                       --policies, --workloads, --backends. Spelled
                       `serve-sim campaign ...` equally. Grammar and
                       baseline workflow: docs/CAMPAIGNS.md
  generate --prompt S [--max-new N]
                       functional generation via the PJRT runtime
                       (requires `make artifacts`)
  config               print the Table I preset
  all                  run every experiment
";

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    args.validate_command(COMMANDS)?;
    match args.command.as_str() {
        "help" => print!("{HELP}"),
        "fig1" => print!("{}", exp::fig1::render()),
        "fig5" => print!("{}", exp::fig5::render()),
        "fig6" => print!("{}", exp::fig6::render()),
        "fig9" => print!("{}", exp::fig9::render()),
        "fig12" => print!("{}", exp::fig12::render()),
        "fig14" => {
            let rows = exp::fig14::fig14a();
            print!("{}", exp::fig14::render_fig14a(&rows));
            println!();
            print!("{}", exp::fig14::render_fig14b(&exp::fig14::fig14b()));
        }
        "table2" => print!("{}", exp::table2::render()),
        "dse" => cmd_dse(),
        "codesign" => cmd_codesign(&args)?,
        "tiling" => cmd_tiling(&args)?,
        "lifetime" => cmd_lifetime(&args)?,
        "energy" => cmd_energy(&args)?,
        "serve" => cmd_serve(&args)?,
        "serve-sim" => cmd_serve_sim(&args)?,
        "campaign" => cmd_campaign(&args)?,
        "generate" => cmd_generate(&args)?,
        "config" => println!("{:#?}", table1_system()),
        "all" => {
            for c in ["fig1", "fig5", "fig6", "fig9", "fig12", "fig14", "table2"] {
                println!("==== {c} ====");
                run(vec![c.to_string()])?;
                println!();
            }
        }
        other => bail!("unhandled command {other}"),
    }
    Ok(())
}

fn cmd_dse() {
    let sel = exp::fig6::selection();
    println!(
        "selected plane: {} x {} x {} (T_PIM {}, density {:.2} Gb/mm2)",
        sel.plane.n_row,
        sel.plane.n_col,
        sel.plane.n_stack,
        crate::util::units::fmt_time(sel.t_pim),
        sel.density
    );
}

/// Parse a `--rows/--cols/--stacks` grid bound: `LO:HI`, both powers of
/// two, `LO <= HI`.
fn grid_bound(args: &Args, name: &str, default: (usize, usize)) -> Result<(usize, usize)> {
    let Some(spec) = args.flag(name) else {
        return Ok(default);
    };
    let Some((lo, hi)) = spec.split_once(':') else {
        bail!("--{name} expects LO:HI (e.g. 256:2048), got {spec:?}");
    };
    let lo: usize =
        lo.trim().parse().map_err(|_| anyhow!("bad --{name} low bound {lo:?} in {spec:?}"))?;
    let hi: usize =
        hi.trim().parse().map_err(|_| anyhow!("bad --{name} high bound {hi:?} in {spec:?}"))?;
    if !lo.is_power_of_two() || !hi.is_power_of_two() || lo > hi {
        bail!("--{name} needs power-of-two bounds with LO <= HI, got {lo}:{hi}");
    }
    Ok((lo, hi))
}

/// `repro codesign` — the SLO-frontier-driven co-design campaign
/// ([`crate::dse::codesign`]; see `docs/CODESIGN.md`).
fn cmd_codesign(args: &Args) -> Result<()> {
    let model = OptModel::from_name(&args.flag_or("model", "opt-6.7b"))
        .context("unknown model; use opt-{6.7b,13b,30b,66b,175b}")?;
    let mut spec = CodesignSpec::new(model.shape());
    spec.criteria.rows = grid_bound(args, "rows", spec.criteria.rows)?;
    spec.criteria.cols = grid_bound(args, "cols", spec.criteria.cols)?;
    spec.criteria.stacks = grid_bound(args, "stacks", spec.criteria.stacks)?;
    spec.workload = args.flag_or("workload", &spec.workload);
    if let Some(rates) = args.flag("rates") {
        spec.rates = rates
            .split(',')
            .map(|part| {
                part.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("--rates expects comma-separated numbers, got {part:?}"))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(policies) = args.flag("policies") {
        spec.policies =
            policies.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    }
    spec.attainment = args.f64_flag("attainment", spec.attainment)?;
    if let Some(b) = args.flag("budget-mm2") {
        spec.budget_mm2 =
            Some(b.parse().map_err(|_| anyhow!("--budget-mm2 expects a number, got {b:?}"))?);
    }
    spec.devices = args.usize_flag("devices", spec.devices)?;
    spec.requests = args.usize_flag("requests", spec.requests)?;
    spec.seed = args.usize_flag("seed", spec.seed as usize)? as u64;
    let top = args.usize_flag("top", 12)?;

    let tech = TechParams::default();
    let start = std::time::Instant::now();
    let report = if args.bool_flag("seq") {
        run_codesign_seq(&spec, &tech)?
    } else {
        run_codesign(&spec, &tech)?
    };
    let wall = start.elapsed().as_secs_f64();
    println!(
        "co-design campaign: rows {}:{} x cols {}:{} x stacks {}:{}, {}, {} requests/point, \
         seed {} ({:.2}s wall)",
        spec.criteria.rows.0,
        spec.criteria.rows.1,
        spec.criteria.cols.0,
        spec.criteria.cols.1,
        spec.criteria.stacks.0,
        spec.criteria.stacks.1,
        model.shape().name,
        spec.requests,
        spec.seed,
        wall,
    );
    print!("{}", render_codesign(&report, top));
    if let Some(out) = args.flag("json") {
        let json = codesign_metrics(&report);
        json.write(std::path::Path::new(out))?;
        println!("wrote {} codesign metrics to {out}", json.len());
    }
    Ok(())
}

fn cmd_tiling(args: &Args) -> Result<()> {
    let m = args.usize_flag("m", 7168)?;
    let n = args.usize_flag("n", 7168)?;
    let model = exp::fig12::model();
    let shape = crate::pim::op::MvmShape::new(m, n);
    let ranked = crate::tiling::search_best(&model, shape);
    println!("best tilings for (1,{m}) x ({m},{n}):");
    for r in ranked.iter().take(8) {
        let c = r.cost;
        println!(
            "  {:<28} inbound {:>10} pim {:>10} outbound {:>10} total {:>10}",
            r.scheme.notation_counts(),
            crate::util::units::fmt_time(c.inbound.secs()),
            crate::util::units::fmt_time(c.pim.secs()),
            crate::util::units::fmt_time(c.outbound.secs()),
            crate::util::units::fmt_time(c.total().secs()),
        );
    }
    Ok(())
}

fn cmd_lifetime(args: &Args) -> Result<()> {
    let model = OptModel::from_name(&args.flag_or("model", "opt-30b"))
        .context("unknown model; use opt-{6.7b,13b,30b,66b,175b}")?;
    let tpot = args.f64_flag("tpot", 7e-3)?;
    let shape = model.shape();
    let paper = lifetime_years(&shape, tpot);
    let sys = lifetime_years_system(&table1_system(), &shape, tpot);
    println!(
        "KV write rate {:.1} MB/s (per-token {} at TPOT {})",
        paper.write_rate / 1e6,
        crate::util::units::fmt_bytes(shape.kv_bytes_per_token(1.0)),
        crate::util::units::fmt_time(tpot)
    );
    println!("32 GiB region (paper): {:.1} years", paper.years);
    println!("Table-I SLC region ({}): {:.1} years", crate::util::units::fmt_bytes(sys.region_bytes), sys.years);
    println!("5-year warranty satisfied: {}", sys.years > 5.0);
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    use crate::llm::energy::EnergySchedule;
    let model = OptModel::from_name(&args.flag_or("model", "opt-30b"))
        .context("unknown model")?;
    let l = args.usize_flag("tokens", 1536)?;
    let s = EnergySchedule::new(&table1_system(), &TechParams::default(), model.shape());
    let e = s.token_energy(l);
    println!("{} per-token energy at L={l}:", model.shape().name);
    println!("  PIM arrays : {}", crate::util::units::fmt_energy(e.pim));
    println!("  buses      : {}", crate::util::units::fmt_energy(e.bus));
    println!("  RPUs       : {}", crate::util::units::fmt_energy(e.rpu));
    println!("  ARM cores  : {}", crate::util::units::fmt_energy(e.cores));
    println!("  total      : {}", crate::util::units::fmt_energy(e.total()));
    let gpu = s.gpu_energy_per_token(17e-3, 4.0 * 450.0);
    println!("4xRTX4090 estimate: {} -> flash saves {:.0}x",
        crate::util::units::fmt_energy(gpu), gpu / e.total());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.usize_flag("requests", 32)?;
    let gen_frac = args.f64_flag("gen-frac", 0.5)?;
    let model = OptModel::from_name(&args.flag_or("model", "opt-6.7b"))
        .context("unknown model")?;
    let input = args.usize_flag("input-tokens", 256)?;
    let output = args.usize_flag("output-tokens", 64)?;
    let seed = args.usize_flag("seed", 42)? as u64;
    let wl = Workload::synthetic(n, gen_frac, 0.5, input, output, seed);
    let report = simulate(&table1_system(), &model.shape(), &rtx4090x4_vllm(), &wl);
    print!("{}", report.render());
    Ok(())
}

fn cmd_serve_sim(args: &Args) -> Result<()> {
    // `serve-sim campaign ...` is the campaign runner's long spelling.
    if args.positional.first().map(String::as_str) == Some("campaign") {
        return cmd_campaign(args);
    }
    let model = OptModel::from_name(&args.flag_or("model", "opt-6.7b"))
        .context("unknown model; use opt-{6.7b,13b,30b,66b,175b}")?;
    // Defaults live in one place: TrafficConfig::default_for (whose
    // traffic shape is the `chat` workload-class preset).
    let fleet = match args.flag("fleet") {
        Some(spec) => {
            if args.flag("devices").is_some() {
                bail!("--fleet defines the device roster; it conflicts with --devices");
            }
            Some(FleetSpec::parse(spec)?)
        }
        None => None,
    };
    let devices = match &fleet {
        Some(f) => f.n_devices(),
        None => args.usize_flag("devices", 4)?,
    };
    let mut cfg = TrafficConfig::default_for(devices);
    cfg.fleet = fleet;
    cfg.rate = args.f64_flag("rate", cfg.rate)?;
    cfg.requests = args.usize_flag("requests", cfg.requests)?;
    if cfg.devices == 0 || cfg.rate <= 0.0 {
        bail!("--devices and --rate must be positive");
    }
    if let Some(spec) = args.flag("workload") {
        // A mix defines per-class shapes; the scalar shape flags would
        // silently fight it, so they are rejected outright.
        for flag in ["input-min", "input-max", "output-min", "output-max", "followup"] {
            if args.flag(flag).is_some() {
                bail!("--{flag} conflicts with --workload (the mix defines per-class shapes)");
            }
        }
        cfg.workload = Some(WorkloadMix::resolve(spec)?);
    } else {
        let (in_lo, in_hi) = (
            args.usize_flag("input-min", cfg.input_tokens.lo)?,
            args.usize_flag("input-max", cfg.input_tokens.hi)?,
        );
        let (out_lo, out_hi) = (
            args.usize_flag("output-min", cfg.output_tokens.lo)?,
            args.usize_flag("output-max", cfg.output_tokens.hi)?,
        );
        if in_lo < 1 || in_hi < in_lo || out_lo < 1 || out_hi < out_lo {
            bail!(
                "token ranges need 1 <= min <= max \
                 (input {in_lo}..{in_hi}, output {out_lo}..{out_hi})"
            );
        }
        cfg.input_tokens = LenRange::new(in_lo, in_hi);
        cfg.output_tokens = LenRange::new(out_lo, out_hi);
        cfg.followup = args.f64_flag("followup", cfg.followup)?;
        if !(0.0..=1.0).contains(&cfg.followup) {
            bail!("--followup is a probability; need 0 <= p <= 1, got {}", cfg.followup);
        }
    }
    cfg.queue_capacity = args.usize_flag("queue-cap", cfg.queue_capacity)?;
    if cfg.queue_capacity == 0 {
        bail!("--queue-cap must be at least 1");
    }
    cfg.seed = args.usize_flag("seed", cfg.seed as usize)? as u64;
    if let Some(pe) = args.flag("wear") {
        let pe: u64 = pe
            .parse()
            .map_err(|_| anyhow!("--wear expects a per-device P/E erase budget, got {pe:?}"))?;
        let mut wear = WearConfig::new(pe);
        wear.blocks_per_device = args.usize_flag("wear-blocks", wear.blocks_per_device)?;
        wear.spares = args.usize_flag("spares", wear.spares)?;
        if wear.blocks_per_device == 0 {
            bail!("--wear-blocks must be at least 1");
        }
        cfg.wear = Some(wear);
    } else {
        for flag in ["wear-blocks", "spares"] {
            if args.flag(flag).is_some() {
                bail!("--{flag} requires --wear (the per-device P/E erase budget)");
            }
        }
    }
    if let Some(spec) = args.flag("arrival") {
        cfg.arrival = Some(ArrivalProcess::parse(spec)?);
    }
    if let Some(spec) = args.flag("faults") {
        // An inert spec (e.g. `fail=0`) normalizes to None, so the run
        // stays byte-identical to one without the flag.
        cfg.faults = FaultConfig::parse(spec)?.active();
    }

    // Validate sweep/policy flags before paying for the table build.
    let threaded = args.bool_flag("threaded");
    let per_token = args.bool_flag("per-token");
    if per_token && threaded {
        bail!("--per-token is the event backend's oracle mode; it conflicts with --threaded");
    }
    let sweep = args.bool_flag("sweep");
    if per_token && sweep {
        bail!("--per-token applies to single runs (sweeps always run coalesced)");
    }
    let rates = if sweep { Some(sweep_rate_list(args)?) } else { None };
    let policy = if sweep {
        None // sweep mode runs every policy; --policy is ignored
    } else {
        let name = args.flag_or("policy", "least-loaded");
        Some(policy_from_name(&name).context(
            "unknown policy; use round-robin|least-loaded|slo-aware|tier-aware|wear-aware",
        )?)
    };
    // Flash-only sweeps keep the legacy policy list (byte-identical
    // output); a typed fleet adds the tier-aware policy to the sweep.
    let sweep_policies: &[&str] =
        if cfg.fleet.is_some() { TIERED_POLICY_NAMES } else { POLICY_NAMES };

    // One offline table build serves every run below (single run or the
    // whole rate sweep across all policies).
    let sys = table1_system();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.shape());
    if let Some(rates) = rates {
        let points = if threaded {
            sweep_rates_threaded(&sys, &model.shape(), &table, &cfg, &rates, sweep_policies)?
        } else {
            sweep_rates(&sys, &model.shape(), &table, &cfg, &rates, sweep_policies)?
        };
        println!(
            "rate sweep ({} backend): {} device(s), {} requests/point, {} ({} buckets, stride {})",
            if threaded { "threaded direct" } else { "event" },
            cfg.devices,
            cfg.requests,
            table.model_name(),
            table.max_context() / table.stride() + 1,
            table.stride(),
        );
        if let Some(f) = &cfg.fleet {
            println!("fleet: {}", f.name());
        }
        if let Some(mix) = &cfg.workload {
            println!("workload mix: {}", mix.name());
        }
        print!("{}", render_sweep(&points));
        if cfg.workload.is_some() {
            println!();
            print!("{}", render_slo_frontier(&points, 0.99));
        }
        return Ok(());
    }
    let policy =
        policy.ok_or_else(|| anyhow!("internal error: non-sweep path is missing a policy"))?;
    let report = if threaded {
        run_traffic_with_table(&sys, &model.shape(), &table, policy, &cfg)
    } else {
        let mode = if per_token { DecodeMode::PerToken } else { DecodeMode::Coalesced };
        run_traffic_events_mode(&sys, &model.shape(), &table, policy, &cfg, mode)
    };
    print!("{}", report.render());
    Ok(())
}

/// Default baseline path of `repro campaign`, relative to the invocation
/// directory (the Makefile and CI invoke from the repo root, where the
/// baseline is committed).
const CAMPAIGN_BASELINE: &str = "bench/BENCH_serving.baseline.json";

/// `repro campaign` — expand the scenario matrix, run the (optionally
/// filtered) selection, and gate against the committed baseline. See
/// `docs/CAMPAIGNS.md` for the workflow and the filter grammar.
fn cmd_campaign(args: &Args) -> Result<()> {
    let model = OptModel::from_name(&args.flag_or("model", "opt-6.7b"))
        .context("unknown model; use opt-{6.7b,13b,30b,66b,175b}")?;
    let filter = match args.flag("filter") {
        Some(src) => Some(Expr::parse(src)?),
        None => None,
    };

    // Matrix axes: the committed-baseline defaults unless overridden.
    let mut spec = CampaignSpec::default();
    let list_flag = |name: &str| -> Option<Vec<String>> {
        args.flag(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    };
    if let Some(policies) = list_flag("policies") {
        spec.policies = policies;
    }
    if let Some(workloads) = list_flag("workloads") {
        spec.workloads = workloads;
    }
    if let Some(fleets) = list_flag("fleets") {
        spec.fleets =
            fleets.iter().map(|f| FleetSpec::parse(f)).collect::<Result<Vec<_>>>()?;
    }
    if let Some(backends) = list_flag("backends") {
        spec.backends = backends
            .iter()
            .map(|b| {
                Backend::from_name(b)
                    .ok_or_else(|| anyhow!("unknown backend {b:?}; use event|threaded"))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(rates) = args.flag("rates") {
        spec.rates = rates
            .split(',')
            .map(|part| {
                part.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("--rates expects comma-separated numbers, got {part:?}"))
            })
            .collect::<Result<_>>()?;
    }
    spec.devices = args.usize_flag("devices", spec.devices)?;
    // Budget knob: the same BENCH_* env override CI uses for benches,
    // still overridable per invocation with --requests.
    let env_requests = std::env::var("BENCH_SWEEP_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(spec.requests);
    spec.requests = args.usize_flag("requests", env_requests)?;
    spec.seed = args.usize_flag("seed", spec.seed as usize)? as u64;
    if let Some(pe) = args.flag("wear") {
        let pe: u64 = pe
            .parse()
            .map_err(|_| anyhow!("--wear expects a per-device P/E erase budget, got {pe:?}"))?;
        spec.wear = Some(pe);
    }
    if let Some(faults) = args.flag("faults") {
        spec.faults = FaultConfig::parse(faults)?.active();
    }
    let tol = args.f64_flag("tol", 0.02)?;
    if !tol.is_finite() || tol < 0.0 {
        bail!("--tol is a relative fraction; need a finite value >= 0, got {tol}");
    }

    if args.bool_flag("list") {
        let scenarios = spec.select(filter.as_ref())?;
        println!(
            "{} scenario(s){}:",
            scenarios.len(),
            filter.as_ref().map(|f| format!(" matching `{f}`")).unwrap_or_default()
        );
        for s in &scenarios {
            println!("  {}", crate::campaign::scenario_key(s));
        }
        return Ok(());
    }

    let sys = table1_system();
    let table = LatencyTable::build(&sys, &TechParams::default(), model.shape());
    let start = std::time::Instant::now();
    let outcomes = run_campaign(&sys, &model.shape(), &table, &spec, filter.as_ref())?;
    let wall = start.elapsed().as_secs_f64();
    println!(
        "campaign: {} scenario(s), {} requests each, seed {}, {} ({:.2}s wall)",
        outcomes.len(),
        spec.requests,
        spec.seed,
        table.model_name(),
        wall,
    );
    print!("{}", render_campaign(&outcomes));

    let baseline_path = std::path::PathBuf::from(args.flag_or("baseline", CAMPAIGN_BASELINE));
    if let Some(out) = args.flag("out") {
        let json = campaign_metrics(&outcomes, Some(wall));
        json.write(std::path::Path::new(out))?;
        println!("wrote {} campaign metrics to {out}", json.len());
    }
    if args.bool_flag("update-baseline") {
        // Baselines hold only deterministic metrics — no wall clock.
        let json = campaign_metrics(&outcomes, None);
        json.write(&baseline_path)?;
        println!("updated baseline {} ({} metrics)", baseline_path.display(), json.len());
        return Ok(());
    }
    if !baseline_path.exists() {
        if args.flag("baseline").is_some() {
            bail!(
                "baseline {} not found (create it with --update-baseline)",
                baseline_path.display()
            );
        }
        println!(
            "no baseline at {} — metrics not gated (commit one with `make \
             campaign-update-baseline`)",
            baseline_path.display()
        );
        return Ok(());
    }
    let baseline = crate::util::benchkit::read_metrics(&baseline_path)?;
    let current = campaign_metrics(&outcomes, None);
    // A filtered run deliberately re-measures a slice; the unmeasured
    // remainder of the baseline must not read as "missing".
    let diff = diff_metrics(current.metrics(), &baseline, tol, filter.is_some());
    println!();
    print!("{}", diff.render(args.bool_flag("verbose")));
    diff.gate()
}

/// Arrival rates for `serve-sim --sweep`: an explicit `--rates a,b,c`
/// list, or a linear `--rate-min`/`--rate-max`/`--rate-steps` span.
/// Fully validated here so bad flags fail before the table build.
fn sweep_rate_list(args: &Args) -> Result<Vec<f64>> {
    let rates: Vec<f64> = if let Some(spec) = args.flag("rates") {
        spec.split(',')
            .map(|part| {
                part.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("--rates expects comma-separated numbers, got {part:?}"))
            })
            .collect::<Result<_>>()?
    } else {
        let lo = args.f64_flag("rate-min", 2.0)?;
        let hi = args.f64_flag("rate-max", 32.0)?;
        let steps = args.usize_flag("rate-steps", 6)?;
        let ok = lo.is_finite() && hi.is_finite() && lo > 0.0 && hi >= lo && steps >= 2;
        if !ok {
            bail!(
                "need 0 < --rate-min <= --rate-max and --rate-steps >= 2 (got {lo}, {hi}, {steps})"
            );
        }
        (0..steps).map(|i| lo + (hi - lo) * i as f64 / (steps - 1) as f64).collect()
    };
    crate::coordinator::sweep::validate_rates(&rates)?;
    Ok(rates)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let dir = ArtifactBundle::default_dir();
    if !dir.join("manifest.txt").exists() {
        bail!("artifacts not found at {} — run `make artifacts` first", dir.display());
    }
    let prompt_text = args.require_flag("prompt")?.to_string();
    let max_new = args.usize_flag("max-new", 64)?;
    let tok = ByteTokenizer;
    let mut exec = DecodeExecutor::load(&dir)?;
    println!("model {} (vocab {}, d_model {}, layers {}, max_seq {})",
        exec.bundle.name, exec.bundle.vocab, exec.bundle.d_model, exec.bundle.layers, exec.bundle.max_seq);
    let prompt = tok.encode(&prompt_text);
    let start = std::time::Instant::now();
    let out = crate::coordinator::serve::Engine::generate(&mut exec, &prompt, max_new, &mut |_| {})?;
    let wall = start.elapsed().as_secs_f64();
    println!("prompt: {prompt_text:?}");
    println!("output: {:?}", tok.decode(&out));
    println!("tokens: {} in {:.3}s ({:.1} tok/s wall)", out.len(), wall, out.len() as f64 / wall);
    // Simulated flash-PIM timing for the same token count on OPT-30B.
    let table =
        LatencyTable::build(&table1_system(), &TechParams::default(), OptModel::Opt30b.shape());
    let sim = table.decode_time(prompt.len(), out.len());
    println!("simulated flash-PIM time (OPT-30B scale): {}", sim);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_runs() {
        run(vec!["help".into()]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["bogus".into()]).is_err());
    }

    #[test]
    fn dse_command_runs() {
        run(vec!["dse".into()]).unwrap();
    }

    fn codesign_tiny(extra: &[&str]) -> Vec<String> {
        let mut argv: Vec<String> = vec![
            "codesign".into(),
            "--rows".into(),
            "256:256".into(),
            "--cols".into(),
            "1024:2048".into(),
            "--stacks".into(),
            "128:128".into(),
            "--rates".into(),
            "8".into(),
            "--policies".into(),
            "least-loaded".into(),
            "--devices".into(),
            "2".into(),
            "--requests".into(),
            "20".into(),
            "--top".into(),
            "4".into(),
        ];
        argv.extend(extra.iter().map(|s| s.to_string()));
        argv
    }

    #[test]
    fn codesign_command_runs_parallel_and_sequential() {
        run(codesign_tiny(&[])).unwrap();
        run(codesign_tiny(&["--seq"])).unwrap();
    }

    #[test]
    fn codesign_writes_json_metrics() {
        let out = std::env::temp_dir().join("repro-codesign-cli-test.json");
        let path = out.to_str().unwrap().to_string();
        run(codesign_tiny(&["--json", &path])).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        std::fs::remove_file(&out).ok();
        assert!(text.contains("codesign/256x1024x128/chat/sustained_rate_req_s"), "{text}");
        assert!(text.contains("codesign/256x2048x128/chat/die_mm2"), "{text}");
        assert!(text.contains("codesign_frontier_size"), "{text}");
    }

    #[test]
    fn codesign_rejects_bad_flags() {
        assert!(run(vec!["codesign".into(), "--rows".into(), "256".into()]).is_err());
        assert!(run(vec!["codesign".into(), "--rows".into(), "300:600".into()]).is_err());
        assert!(run(vec!["codesign".into(), "--rows".into(), "512:256".into()]).is_err());
        assert!(run(codesign_tiny(&["--rates", "abc"])).is_err());
        assert!(run(codesign_tiny(&["--attainment", "1.5"])).is_err());
        assert!(run(codesign_tiny(&["--budget-mm2", "-2"])).is_err());
        assert!(run(codesign_tiny(&["--workload", "bogus-mix"])).is_err());
        assert!(run(codesign_tiny(&["--policies", "fifo"])).is_err());
        assert!(run(codesign_tiny(&["--model", "gpt-9"])).is_err());
    }

    #[test]
    fn lifetime_command_runs() {
        run(vec!["lifetime".into()]).unwrap();
    }

    #[test]
    fn serve_sim_command_runs() {
        run(vec![
            "serve-sim".into(),
            "--devices".into(),
            "2".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "12".into(),
            "--output-min".into(),
            "4".into(),
            "--output-max".into(),
            "8".into(),
        ])
        .unwrap();
    }

    #[test]
    fn serve_sim_threaded_backend_runs() {
        run(vec![
            "serve-sim".into(),
            "--threaded".into(),
            "--devices".into(),
            "2".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "12".into(),
            "--output-min".into(),
            "4".into(),
            "--output-max".into(),
            "8".into(),
        ])
        .unwrap();
    }

    #[test]
    fn serve_sim_per_token_oracle_runs_and_rejects_conflicts() {
        run(vec![
            "serve-sim".into(),
            "--per-token".into(),
            "--devices".into(),
            "2".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "12".into(),
            "--output-min".into(),
            "4".into(),
            "--output-max".into(),
            "8".into(),
        ])
        .unwrap();
        assert!(run(vec!["serve-sim".into(), "--per-token".into(), "--threaded".into()]).is_err());
        assert!(run(vec!["serve-sim".into(), "--per-token".into(), "--sweep".into()]).is_err());
    }

    #[test]
    fn serve_sim_rejects_unknown_policy() {
        let err = run(vec!["serve-sim".into(), "--policy".into(), "fifo".into()]);
        assert!(err.is_err());
    }

    #[test]
    fn serve_sim_sweep_runs() {
        run(vec![
            "serve-sim".into(),
            "--sweep".into(),
            "--devices".into(),
            "2".into(),
            "--requests".into(),
            "30".into(),
            "--rates".into(),
            "20,40".into(),
            "--input-min".into(),
            "16".into(),
            "--input-max".into(),
            "32".into(),
            "--output-min".into(),
            "2".into(),
            "--output-max".into(),
            "4".into(),
        ])
        .unwrap();
    }

    #[test]
    fn serve_sim_sweep_rejects_bad_rates() {
        assert!(run(vec!["serve-sim".into(), "--sweep".into(), "--rates".into(), "abc".into()])
            .is_err());
        assert!(run(vec!["serve-sim".into(), "--sweep".into(), "--rates".into(), "-4".into()])
            .is_err());
        assert!(run(vec![
            "serve-sim".into(),
            "--sweep".into(),
            "--rate-steps".into(),
            "1".into(),
        ])
        .is_err());
    }

    #[test]
    fn serve_sim_rejects_bad_flag_values() {
        assert!(run(vec!["serve-sim".into(), "--input-min".into(), "0".into()]).is_err());
        assert!(run(vec![
            "serve-sim".into(),
            "--output-min".into(),
            "50".into(),
            "--output-max".into(),
            "4".into(),
        ])
        .is_err());
        assert!(run(vec!["serve-sim".into(), "--devices".into(), "0".into()]).is_err());
        assert!(run(vec!["serve-sim".into(), "--queue-cap".into(), "0".into()]).is_err());
    }

    #[test]
    fn serve_sim_workload_preset_runs() {
        for policy in ["round-robin", "least-loaded", "slo-aware"] {
            run(vec![
                "serve-sim".into(),
                "--workload".into(),
                "chat".into(),
                "--policy".into(),
                policy.into(),
                "--devices".into(),
                "2".into(),
                "--rate".into(),
                "40".into(),
                "--requests".into(),
                "12".into(),
            ])
            .unwrap();
        }
    }

    #[test]
    fn serve_sim_workload_rejects_conflicts_and_unknowns() {
        assert!(run(vec![
            "serve-sim".into(),
            "--workload".into(),
            "chat".into(),
            "--input-min".into(),
            "8".into(),
        ])
        .is_err());
        assert!(run(vec!["serve-sim".into(), "--workload".into(), "bogus-mix".into()]).is_err());
    }

    #[test]
    fn serve_sim_wear_and_arrival_run_and_reject_bad_flags() {
        run(vec![
            "serve-sim".into(),
            "--wear".into(),
            "50".into(),
            "--wear-blocks".into(),
            "8".into(),
            "--spares".into(),
            "1".into(),
            "--policy".into(),
            "wear-aware".into(),
            "--arrival".into(),
            "60:0.5,60:1.5".into(),
            "--devices".into(),
            "2".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "12".into(),
            "--output-min".into(),
            "4".into(),
            "--output-max".into(),
            "8".into(),
        ])
        .unwrap();
        // Wear shape flags without the budget are silent no-ops otherwise.
        assert!(run(vec!["serve-sim".into(), "--spares".into(), "1".into()]).is_err());
        assert!(run(vec!["serve-sim".into(), "--wear-blocks".into(), "8".into()]).is_err());
        assert!(run(vec!["serve-sim".into(), "--wear".into(), "lots".into()]).is_err());
        assert!(run(vec![
            "serve-sim".into(),
            "--wear".into(),
            "50".into(),
            "--wear-blocks".into(),
            "0".into(),
        ])
        .is_err());
        assert!(run(vec!["serve-sim".into(), "--arrival".into(), "60:-1".into()]).is_err());
    }

    #[test]
    fn serve_sim_fleet_runs_and_rejects_conflicts() {
        run(vec![
            "serve-sim".into(),
            "--fleet".into(),
            "1xflash+1xgpu".into(),
            "--policy".into(),
            "tier-aware".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "8".into(),
            "--output-min".into(),
            "2".into(),
            "--output-max".into(),
            "4".into(),
        ])
        .unwrap();
        // --fleet owns the roster; an explicit --devices contradicts it.
        assert!(run(vec![
            "serve-sim".into(),
            "--fleet".into(),
            "2xflash".into(),
            "--devices".into(),
            "2".into(),
        ])
        .is_err());
        assert!(run(vec!["serve-sim".into(), "--fleet".into(), "3xtpu".into()]).is_err());
    }

    #[test]
    fn campaign_fleets_list_expands_the_fleet_axis() {
        run(vec![
            "campaign".into(),
            "--list".into(),
            "--fleets".into(),
            "4xflash+1xgpu".into(),
            "--policies".into(),
            "tier-aware".into(),
            "--filter".into(),
            "tier(gpu)".into(),
        ])
        .unwrap();
        assert!(run(vec![
            "campaign".into(),
            "--list".into(),
            "--fleets".into(),
            "9xtpu".into(),
        ])
        .is_err());
    }

    #[test]
    fn serve_sim_faults_run_and_reject_bad_specs() {
        run(vec![
            "serve-sim".into(),
            "--faults".into(),
            "storm=0.1:4x1,fail_at=0@5,detect=0.5,retries=2,backoff=0.2,spares=1,brownout=0.5"
                .into(),
            "--devices".into(),
            "2".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "12".into(),
            "--output-min".into(),
            "4".into(),
            "--output-max".into(),
            "8".into(),
        ])
        .unwrap();
        // An inert spec normalizes away and still runs.
        run(vec![
            "serve-sim".into(),
            "--faults".into(),
            "fail=0".into(),
            "--devices".into(),
            "2".into(),
            "--rate".into(),
            "40".into(),
            "--requests".into(),
            "8".into(),
            "--output-min".into(),
            "2".into(),
            "--output-max".into(),
            "4".into(),
        ])
        .unwrap();
        assert!(run(vec!["serve-sim".into(), "--faults".into(), "storm=lots".into()]).is_err());
        assert!(run(vec!["serve-sim".into(), "--faults".into(), "bogus=1".into()]).is_err());
    }

    #[test]
    fn campaign_faults_flag_parses_and_rejects_garbage() {
        run(vec![
            "campaign".into(),
            "--list".into(),
            "--faults".into(),
            "fail_at=0@20,retries=2,spares=1".into(),
            "--filter".into(),
            "backend(event)".into(),
        ])
        .unwrap();
        assert!(run(vec![
            "campaign".into(),
            "--list".into(),
            "--faults".into(),
            "fail_at=0".into(),
        ])
        .is_err());
    }

    #[test]
    fn campaign_wear_flag_parses_and_rejects_garbage() {
        run(vec![
            "campaign".into(),
            "--list".into(),
            "--wear".into(),
            "1000".into(),
            "--policies".into(),
            "wear-aware".into(),
            "--filter".into(),
            "backend(event)".into(),
        ])
        .unwrap();
        assert!(run(vec!["campaign".into(), "--list".into(), "--wear".into(), "many".into()])
            .is_err());
    }

    #[test]
    fn campaign_list_selects_without_running() {
        run(vec![
            "campaign".into(),
            "--list".into(),
            "--filter".into(),
            "policy(slo-aware) & class(chat)".into(),
        ])
        .unwrap();
    }

    #[test]
    fn campaign_rejects_bad_flags_before_simulating() {
        assert!(run(vec!["campaign".into(), "--list".into(), "--filter".into(), "polcy(x)".into()])
            .is_err());
        assert!(run(vec!["campaign".into(), "--list".into(), "--filter".into(), "rate>99".into()])
            .is_err());
        assert!(run(vec!["campaign".into(), "--backends".into(), "bogus".into(), "--list".into()])
            .is_err());
        assert!(run(vec!["campaign".into(), "--tol".into(), "-0.5".into(), "--list".into()])
            .is_err());
    }

    #[test]
    fn generate_without_artifacts_errors_cleanly() {
        if !ArtifactBundle::available() {
            let err = run(vec!["generate".into(), "--prompt".into(), "hi".into()]);
            assert!(err.is_err());
        }
    }
}
