//! Minimal argument parser: `repro <command> [--flag value]...`.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: Vec<String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        out.command = it.next().unwrap_or_else(|| "help".to_string());
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--flag value` or boolean `--flag`.
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.flags.insert(name.to_string(), value);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn positional_usize(&self, idx: usize) -> Result<usize> {
        let v = self
            .positional
            .get(idx)
            .ok_or_else(|| anyhow!("missing positional argument {idx}"))?;
        v.parse().map_err(|_| anyhow!("positional {idx} expects an integer, got {v:?}"))
    }

    pub fn require_flag(&self, name: &str) -> Result<&str> {
        self.flag(name).ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    pub fn validate_command(&self, known: &[&str]) -> Result<()> {
        if !known.contains(&self.command.as_str()) {
            bail!("unknown command {:?}; try `repro help`", self.command);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()).collect()).unwrap()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse("fig14 --model opt-30b --tokens 1024 extra");
        assert_eq!(a.command, "fig14");
        assert_eq!(a.flag("model"), Some("opt-30b"));
        assert_eq!(a.usize_flag("tokens", 0).unwrap(), 1024);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("serve --verbose --n 5");
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.usize_flag("n", 0).unwrap(), 5);
    }

    #[test]
    fn defaults() {
        let a = parse("fig6");
        assert_eq!(a.flag_or("axis", "all"), "all");
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(vec![]).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn bad_int_errors() {
        let a = parse("x --n abc");
        assert!(a.usize_flag("n", 0).is_err());
    }
}
