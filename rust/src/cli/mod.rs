//! `repro` CLI — hand-rolled argument parsing (no clap in the offline
//! registry). One subcommand per experiment plus utility commands.

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::run;
