//! Scenario filter expressions — the small set-algebra language behind
//! `repro campaign --filter`.
//!
//! Campaign matrices multiply fast (policies × workloads × backends ×
//! rate grids); selecting slices through ever more CLI flags does not
//! scale. Instead a filter is one expression over scenario attributes,
//! in the spirit of the tytanic test-filter design (small AST, hand
//! lexer, recursive-descent parser, set-algebra evaluation):
//!
//! ```text
//! policy(slo-aware) & class(chat) & rate > 5
//! workload(summarize-long) | backend(threaded)
//! !(policy(round-robin) | rate >= 16)
//! ```
//!
//! Grammar (precedence low → high: `|`, `&`, `!`):
//!
//! ```text
//! expr    := or
//! or      := and ('|' and)*
//! and     := unary ('&' unary)*
//! unary   := '!' unary | primary
//! primary := '(' expr ')' | 'all' | 'none' | atom
//! atom    := key '(' value ')'        key ∈ {policy, workload, class, backend, tier}
//!          | 'rate' cmp number        cmp ∈ {<, <=, >, >=, =, !=}
//! ```
//!
//! `workload(x)` matches the mix *name*; `class(x)` matches mixes that
//! *contain* a class named `x` (the `summarize-long` preset contains a
//! `chat` class, for example); `tier(x)` matches scenarios whose fleet
//! *contains* a device of tier `x` (`flash` or `gpu` — a hybrid
//! `4xflash+1xgpu` scenario matches both). Parse errors carry byte spans
//! and render with a caret under the offending input — see
//! [`ParseError`].

use anyhow::{anyhow, Result};
use std::fmt;

/// Comparison operator of a `rate` atom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    fn apply(self, lhs: f64, rhs: f64) -> bool {
        match self {
            CmpOp::Lt => lhs < rhs,
            CmpOp::Le => lhs <= rhs,
            CmpOp::Gt => lhs > rhs,
            CmpOp::Ge => lhs >= rhs,
            CmpOp::Eq => lhs == rhs,
            CmpOp::Ne => lhs != rhs,
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Eq => "=",
            CmpOp::Ne => "!=",
        }
    }
}

/// String-valued scenario attributes an atom can test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AtomKey {
    Policy,
    Workload,
    Class,
    Backend,
    Tier,
}

impl AtomKey {
    fn from_name(name: &str) -> Option<AtomKey> {
        match name {
            "policy" => Some(AtomKey::Policy),
            "workload" => Some(AtomKey::Workload),
            "class" => Some(AtomKey::Class),
            "backend" => Some(AtomKey::Backend),
            "tier" => Some(AtomKey::Tier),
            _ => None,
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            AtomKey::Policy => "policy",
            AtomKey::Workload => "workload",
            AtomKey::Class => "class",
            AtomKey::Backend => "backend",
            AtomKey::Tier => "tier",
        }
    }
}

/// Parsed filter expression. Evaluation is pure set algebra over the
/// scenario attributes in a [`ScenarioView`].
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// `all` — matches every scenario (the identity filter).
    All,
    /// `none` — matches nothing.
    None,
    /// `key(value)` membership test.
    Atom(AtomKey, String),
    /// `rate CMP number`.
    Rate(CmpOp, f64),
    Not(Box<Expr>),
    And(Box<Expr>, Box<Expr>),
    Or(Box<Expr>, Box<Expr>),
}

/// The attributes of one scenario a filter can see — a borrowed view so
/// the evaluator does not depend on the runner's concrete type.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioView<'a> {
    pub policy: &'a str,
    pub workload: &'a str,
    /// Names of the classes inside the scenario's workload mix.
    pub classes: &'a [String],
    pub backend: &'a str,
    pub rate: f64,
    /// Names of the device tiers in the scenario's fleet (`"flash"`,
    /// `"gpu"`); legacy flash-only scenarios carry `["flash"]`.
    pub tiers: &'a [String],
}

impl Expr {
    /// Parse a filter expression; errors render with a caret span.
    pub fn parse(src: &str) -> Result<Expr> {
        let tokens = lex(src).map_err(|e| anyhow!("{}", e.render(src)))?;
        let mut p = Parser { tokens: &tokens, pos: 0, src_len: src.len() };
        let expr = p.or_expr().map_err(|e| anyhow!("{}", e.render(src)))?;
        if let Some(t) = p.peek() {
            let err = ParseError::new("expected `&`, `|`, or end of filter", t.span);
            return Err(anyhow!("{}", err.render(src)));
        }
        Ok(expr)
    }

    /// Does this expression select the scenario?
    pub fn matches(&self, s: &ScenarioView) -> bool {
        match self {
            Expr::All => true,
            Expr::None => false,
            Expr::Atom(key, value) => match key {
                AtomKey::Policy => s.policy == value,
                AtomKey::Workload => s.workload == value,
                AtomKey::Class => s.classes.iter().any(|c| c == value),
                AtomKey::Backend => s.backend == value,
                AtomKey::Tier => s.tiers.iter().any(|t| t == value),
            },
            Expr::Rate(op, rhs) => op.apply(s.rate, *rhs),
            Expr::Not(e) => !e.matches(s),
            Expr::And(a, b) => a.matches(s) && b.matches(s),
            Expr::Or(a, b) => a.matches(s) || b.matches(s),
        }
    }
}

impl fmt::Display for Expr {
    /// Canonical fully-parenthesized rendering (handy in tests and logs).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::All => write!(f, "all"),
            Expr::None => write!(f, "none"),
            Expr::Atom(key, value) => write!(f, "{}({})", key.as_str(), value),
            Expr::Rate(op, rhs) => write!(f, "rate {} {}", op.as_str(), rhs),
            Expr::Not(e) => write!(f, "!({e})"),
            Expr::And(a, b) => write!(f, "({a} & {b})"),
            Expr::Or(a, b) => write!(f, "({a} | {b})"),
        }
    }
}

/// A lex or parse failure: message plus the byte span it points at.
/// [`ParseError::render`] draws the offending source with a caret line:
///
/// ```text
/// filter error: unknown atom `polcy` (expected policy, workload, class, backend, tier, rate, all, none)
///   polcy(x) & rate > 5
///   ^^^^^
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    /// Byte range `[start, end)` into the source expression.
    pub span: (usize, usize),
}

impl ParseError {
    fn new(msg: impl Into<String>, span: (usize, usize)) -> ParseError {
        ParseError { msg: msg.into(), span }
    }

    /// Render the message with the source line and a caret underline.
    pub fn render(&self, src: &str) -> String {
        let (start, end) = self.span;
        let width = end.saturating_sub(start).max(1);
        format!(
            "filter error: {}\n  {}\n  {}{}",
            self.msg,
            src,
            " ".repeat(start),
            "^".repeat(width)
        )
    }
}

#[derive(Debug, Clone, PartialEq)]
enum TokenKind {
    Ident(String),
    Number(f64),
    And,
    Or,
    Not,
    LParen,
    RParen,
    Cmp(CmpOp),
}

#[derive(Debug, Clone, PartialEq)]
struct Token {
    kind: TokenKind,
    span: (usize, usize),
}

fn ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | '/')
}

fn lex(src: &str) -> Result<Vec<Token>, ParseError> {
    let bytes = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        let start = i;
        match c {
            ' ' | '\t' | '\n' | '\r' => {
                i += 1;
                continue;
            }
            '&' => out.push(Token { kind: TokenKind::And, span: (start, i + 1) }),
            '|' => out.push(Token { kind: TokenKind::Or, span: (start, i + 1) }),
            '(' => out.push(Token { kind: TokenKind::LParen, span: (start, i + 1) }),
            ')' => out.push(Token { kind: TokenKind::RParen, span: (start, i + 1) }),
            '!' | '<' | '>' | '=' => {
                let two = bytes.get(i + 1) == Some(&b'=');
                let kind = match (c, two) {
                    ('!', true) => TokenKind::Cmp(CmpOp::Ne),
                    ('!', false) => TokenKind::Not,
                    ('<', true) => TokenKind::Cmp(CmpOp::Le),
                    ('<', false) => TokenKind::Cmp(CmpOp::Lt),
                    ('>', true) => TokenKind::Cmp(CmpOp::Ge),
                    ('>', false) => TokenKind::Cmp(CmpOp::Gt),
                    ('=', _) => TokenKind::Cmp(CmpOp::Eq),
                    _ => unreachable!(),
                };
                let len = if two { 2 } else { 1 };
                i += len - 1;
                out.push(Token { kind, span: (start, i + 1) });
            }
            c if c.is_ascii_digit() => {
                while i < bytes.len() && matches!(bytes[i] as char, '0'..='9' | '.') {
                    i += 1;
                }
                let text = &src[start..i];
                let n = text.parse::<f64>().map_err(|_| {
                    ParseError::new(format!("invalid number `{text}`"), (start, i))
                })?;
                out.push(Token { kind: TokenKind::Number(n), span: (start, i) });
                continue;
            }
            c if c.is_ascii_alphabetic() => {
                while i < bytes.len() && ident_char(bytes[i] as char) {
                    i += 1;
                }
                out.push(Token {
                    kind: TokenKind::Ident(src[start..i].to_string()),
                    span: (start, i),
                });
                continue;
            }
            other => {
                return Err(ParseError::new(
                    format!("unexpected character `{other}`"),
                    (start, start + other.len_utf8()),
                ));
            }
        }
        i += 1;
    }
    Ok(out)
}

struct Parser<'a> {
    tokens: &'a [Token],
    pos: usize,
    src_len: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<&Token> {
        self.tokens.get(self.pos)
    }

    fn eof_span(&self) -> (usize, usize) {
        (self.src_len, self.src_len + 1)
    }

    fn or_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.and_expr()?;
        while matches!(self.peek().map(|t| &t.kind), Some(TokenKind::Or)) {
            self.pos += 1;
            let rhs = self.and_expr()?;
            lhs = Expr::Or(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.unary()?;
        while matches!(self.peek().map(|t| &t.kind), Some(TokenKind::And)) {
            self.pos += 1;
            let rhs = self.unary()?;
            lhs = Expr::And(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary(&mut self) -> Result<Expr, ParseError> {
        if matches!(self.peek().map(|t| &t.kind), Some(TokenKind::Not)) {
            self.pos += 1;
            return Ok(Expr::Not(Box::new(self.unary()?)));
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<Expr, ParseError> {
        let Some(tok) = self.peek().cloned() else {
            return Err(ParseError::new("expected an atom, `!`, or `(`", self.eof_span()));
        };
        match tok.kind {
            TokenKind::LParen => {
                self.pos += 1;
                let inner = self.or_expr()?;
                match self.peek() {
                    Some(t) if t.kind == TokenKind::RParen => {
                        self.pos += 1;
                        Ok(inner)
                    }
                    Some(t) => Err(ParseError::new("expected `)`", t.span)),
                    None => Err(ParseError::new("unclosed `(`", tok.span)),
                }
            }
            TokenKind::Ident(name) => {
                self.pos += 1;
                self.atom(&name, tok.span)
            }
            _ => Err(ParseError::new("expected an atom, `!`, or `(`", tok.span)),
        }
    }

    /// An identifier was consumed; finish the atom it starts.
    fn atom(&mut self, name: &str, span: (usize, usize)) -> Result<Expr, ParseError> {
        match name {
            "all" => return Ok(Expr::All),
            "none" => return Ok(Expr::None),
            "rate" => {
                let op = match self.peek() {
                    Some(Token { kind: TokenKind::Cmp(op), .. }) => *op,
                    Some(t) => {
                        return Err(ParseError::new(
                            "`rate` needs a comparison (one of < <= > >= = !=)",
                            t.span,
                        ))
                    }
                    None => {
                        return Err(ParseError::new(
                            "`rate` needs a comparison (one of < <= > >= = !=)",
                            self.eof_span(),
                        ))
                    }
                };
                self.pos += 1;
                let rhs = match self.peek() {
                    Some(Token { kind: TokenKind::Number(n), .. }) => *n,
                    Some(t) => return Err(ParseError::new("expected a number", t.span)),
                    None => return Err(ParseError::new("expected a number", self.eof_span())),
                };
                self.pos += 1;
                return Ok(Expr::Rate(op, rhs));
            }
            _ => {}
        }
        let Some(key) = AtomKey::from_name(name) else {
            return Err(ParseError::new(
                format!(
                    "unknown atom `{name}` (expected policy, workload, class, backend, tier, \
                     rate, all, none)"
                ),
                span,
            ));
        };
        match self.peek() {
            Some(t) if t.kind == TokenKind::LParen => self.pos += 1,
            Some(t) => {
                return Err(ParseError::new(format!("`{name}` needs `({name} NAME)`"), t.span))
            }
            None => {
                return Err(ParseError::new(
                    format!("`{name}(...)` needs a parenthesized value"),
                    self.eof_span(),
                ))
            }
        }
        let value = match self.peek().cloned() {
            Some(Token { kind: TokenKind::Ident(v), .. }) => {
                self.pos += 1;
                v
            }
            Some(t) => return Err(ParseError::new("expected a value name", t.span)),
            None => return Err(ParseError::new("expected a value name", self.eof_span())),
        };
        match self.peek() {
            Some(t) if t.kind == TokenKind::RParen => {
                self.pos += 1;
                Ok(Expr::Atom(key, value))
            }
            Some(t) => Err(ParseError::new("expected `)`", t.span)),
            None => Err(ParseError::new("unclosed `(`", self.eof_span())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLASH_ONLY: &[String] = &[];

    fn view<'a>(
        policy: &'a str,
        workload: &'a str,
        classes: &'a [String],
        backend: &'a str,
        rate: f64,
    ) -> ScenarioView<'a> {
        ScenarioView { policy, workload, classes, backend, rate, tiers: FLASH_ONLY }
    }

    fn classes(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn atoms_match_their_attributes() {
        let cs = classes(&["chat", "summarize"]);
        let tiers = classes(&["flash", "gpu"]);
        let mut s = view("slo-aware", "summarize-long", &cs, "event", 8.0);
        s.tiers = &tiers;
        for (src, expect) in [
            ("policy(slo-aware)", true),
            ("policy(round-robin)", false),
            ("workload(summarize-long)", true),
            ("workload(chat)", false),
            ("class(chat)", true),
            ("class(batch)", false),
            ("backend(event)", true),
            ("backend(threaded)", false),
            ("tier(flash)", true),
            ("tier(gpu)", true),
            ("tier(tpu)", false),
            ("rate > 5", true),
            ("rate >= 8", true),
            ("rate < 8", false),
            ("rate <= 8", true),
            ("rate = 8", true),
            ("rate != 8", false),
            ("all", true),
            ("none", false),
        ] {
            assert_eq!(Expr::parse(src).unwrap().matches(&s), expect, "{src}");
        }
        // A flash-only scenario matches tier(flash) but not tier(gpu).
        let flash = classes(&["flash"]);
        let mut f = view("slo-aware", "chat", &cs, "event", 8.0);
        f.tiers = &flash;
        assert!(Expr::parse("tier(flash)").unwrap().matches(&f));
        assert!(!Expr::parse("tier(gpu)").unwrap().matches(&f));
    }

    #[test]
    fn precedence_not_over_and_over_or() {
        // `a & b | c` parses as `(a & b) | c`.
        let e = Expr::parse("policy(a) & backend(b) | rate > 1").unwrap();
        assert_eq!(e.to_string(), "((policy(a) & backend(b)) | rate > 1)");
        // `!` binds tighter than `&`.
        let e = Expr::parse("!policy(a) & backend(b)").unwrap();
        assert_eq!(e.to_string(), "(!(policy(a)) & backend(b))");
        // `a | b & c` keeps `&` inside the right arm.
        let e = Expr::parse("policy(a) | backend(b) & rate > 1").unwrap();
        assert_eq!(e.to_string(), "(policy(a) | (backend(b) & rate > 1))");
    }

    #[test]
    fn parens_override_precedence() {
        let e = Expr::parse("policy(a) & (backend(b) | rate > 1)").unwrap();
        assert_eq!(e.to_string(), "(policy(a) & (backend(b) | rate > 1))");
        let cs = classes(&["x"]);
        let s = view("a", "w", &cs, "c", 0.5);
        // Without parens the `&` grabs backend(b): policy a, backend c → false | false.
        assert!(!Expr::parse("policy(a) & backend(b) | rate > 1").unwrap().matches(&s));
        // With parens: policy(a) & (false | false) is false; flip rate to check true path.
        let s2 = view("a", "w", &cs, "c", 2.0);
        assert!(Expr::parse("policy(a) & (backend(b) | rate > 1)").unwrap().matches(&s2));
    }

    #[test]
    fn negation_and_nesting() {
        let cs = classes(&["chat"]);
        let s = view("slo-aware", "chat", &cs, "event", 4.0);
        assert!(!Expr::parse("!(policy(slo-aware) | rate >= 16)").unwrap().matches(&s));
        assert!(Expr::parse("!!policy(slo-aware)").unwrap().matches(&s));
        assert!(Expr::parse("!rate != 4").unwrap().matches(&s), "! applies to the whole atom");
    }

    #[test]
    fn unknown_atom_renders_caret_span() {
        let err = Expr::parse("policy(slo-aware) & polcy(x)").unwrap_err().to_string();
        assert!(err.contains("unknown atom `polcy`"), "{err}");
        // Caret sits under `polcy` (column 20, width 5).
        let caret_line = err.lines().last().unwrap();
        assert_eq!(caret_line, format!("  {}^^^^^", " ".repeat(20)), "{err}");
    }

    #[test]
    fn trailing_garbage_is_an_error() {
        let err = Expr::parse("policy(a) backend(b)").unwrap_err().to_string();
        assert!(err.contains("expected `&`, `|`, or end of filter"), "{err}");
        assert!(err.lines().last().unwrap().contains('^'), "{err}");
    }

    #[test]
    fn malformed_expressions_error_cleanly() {
        for src in [
            "",
            "rate",
            "rate >",
            "rate > x",
            "rate(5)",
            "policy",
            "policy(",
            "policy()",
            "policy(a",
            "(policy(a)",
            "policy(a) &",
            "& policy(a)",
            "policy(a) @ backend(b)",
            "rate > 1.2.3",
        ] {
            let err = Expr::parse(src);
            assert!(err.is_err(), "{src:?} should not parse");
        }
    }

    #[test]
    fn lexer_handles_tight_spacing() {
        let e = Expr::parse("rate>5&policy(x)|rate<=2").unwrap();
        assert_eq!(e.to_string(), "((rate > 5 & policy(x)) | rate <= 2)");
    }
}
