//! Campaign expansion and execution: a scenario matrix → selected
//! scenarios → one [`SweepPoint`] each, fanned out on scoped threads.
//!
//! A [`CampaignSpec`] names the axes (policies × workloads × backends ×
//! rate grid) plus the per-scenario traffic budget; [`CampaignSpec::expand`]
//! multiplies them into a canonically-ordered scenario list, an optional
//! [`Expr`] filter selects the slice to run, and [`run_campaign`]
//! executes every selected scenario over the shared worker scaffold
//! ([`fan_out_indexed`][crate::coordinator::sweep]) with one prebuilt
//! [`LatencyTable`]. Every scenario is an independent deterministic
//! computation (own RNG from the fixed seed), so a campaign's results —
//! and the `BENCH_serving.json` rendered from them by
//! [`super::report`] — are bit-reproducible for a given spec.

use super::filter::{Expr, ScenarioView};
use crate::config::SystemConfig;
use crate::coordinator::device::{FleetSpec, Tier};
use crate::coordinator::event_sim::run_traffic_point;
use crate::coordinator::loadgen::{run_traffic_with_table, TrafficConfig, WearConfig};
use crate::coordinator::router::{policy_from_name, POLICY_NAMES, TIERED_POLICY_NAMES};
use crate::coordinator::sweep::{fan_out_indexed, SweepPoint, validate_rates};
use crate::coordinator::workload::WorkloadMix;
use crate::fault::FaultConfig;
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use anyhow::{bail, Result};

/// Which serving backend a scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Backend {
    /// Deterministic event-driven simulator with coalesced decode and a
    /// streaming sink — the serving default.
    Event,
    /// Direct-replay cross-check backend (`serve-sim --threaded`).
    Threaded,
}

impl Backend {
    pub const ALL: &'static [Backend] = &[Backend::Event, Backend::Threaded];

    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Event => "event",
            Backend::Threaded => "threaded",
        }
    }

    pub fn from_name(name: &str) -> Option<Backend> {
        match name {
            "event" => Some(Backend::Event),
            "threaded" => Some(Backend::Threaded),
            _ => None,
        }
    }
}

/// One point of the campaign matrix, fully resolved (the workload mix is
/// materialized so filters can see class names).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub policy: String,
    /// Mix name (preset name, or the name inside a custom TOML).
    pub workload: String,
    pub backend: Backend,
    pub rate: f64,
    pub mix: WorkloadMix,
    /// Class names of `mix`, cached for filter matching.
    pub class_names: Vec<String>,
    /// Fleet composition when the campaign sweeps a fleet axis; `None`
    /// for legacy flash-only campaigns (whose scenario keys and metric
    /// names stay byte-identical to pre-fleet builds).
    pub fleet: Option<FleetSpec>,
    /// Tier names of `fleet` (legacy scenarios read `["flash"]`), cached
    /// for `tier(...)` filter matching.
    pub tier_names: Vec<String>,
}

impl Scenario {
    /// The borrowed attribute view filters evaluate against.
    pub fn view(&self) -> ScenarioView<'_> {
        ScenarioView {
            policy: &self.policy,
            workload: &self.workload,
            classes: &self.class_names,
            backend: self.backend.as_str(),
            rate: self.rate,
            tiers: &self.tier_names,
        }
    }
}

/// Tier names present in a fleet (canonical flash-then-gpu order);
/// legacy (`None`) scenarios are all-flash pools.
fn tier_names_of(fleet: Option<&FleetSpec>) -> Vec<String> {
    match fleet {
        None => vec![Tier::Flash.as_str().to_string()],
        Some(spec) => [Tier::Flash, Tier::Gpu]
            .iter()
            .filter(|&&t| spec.has_tier(t))
            .map(|t| t.as_str().to_string())
            .collect(),
    }
}

/// The axes and budget of a campaign. `expand` turns this into the
/// canonical scenario list; the default spec is the committed-baseline
/// matrix CI gates on.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Scheduler policy names ([`policy_from_name`] must accept each).
    pub policies: Vec<String>,
    /// Workload preset names or TOML paths ([`WorkloadMix::resolve`]).
    pub workloads: Vec<String>,
    pub backends: Vec<Backend>,
    /// Offered arrival rates (requests/second).
    pub rates: Vec<f64>,
    /// Fleet compositions to sweep (the outermost axis when non-empty,
    /// e.g. `8xflash` vs `4xflash+1xgpu`). Empty = legacy flash-only
    /// campaign: no fleet axis, `devices` homogeneous flash devices, and
    /// scenario keys without a fleet segment.
    pub fleets: Vec<FleetSpec>,
    /// Devices in the pool of every scenario (ignored when `fleets` is
    /// non-empty — each fleet spec fixes its own device count).
    pub devices: usize,
    /// Closed-loop arrivals per scenario.
    pub requests: usize,
    /// RNG seed every scenario derives its stream from.
    pub seed: u64,
    /// Per-device P/E erase budget. `None` (the default matrix) leaves
    /// wear accounting off and every scenario byte-identical to
    /// wear-unaware builds; `Some(budget)` charges every scenario's KV
    /// writes against [`WearConfig::new`]-shaped meters and adds
    /// `wear_*` metric keys to the rendered document.
    pub wear: Option<u64>,
    /// Deterministic fault injection. `None` (the default matrix) leaves
    /// faults off and every scenario byte-identical to fault-unaware
    /// builds; `Some(spec)` threads the same fault schedule (seeded from
    /// the campaign seed) into every scenario and adds `faults_*` metric
    /// keys to the rendered document.
    pub faults: Option<FaultConfig>,
}

/// Default rate grid of the campaign matrix (requests/second).
pub const DEFAULT_RATES: &[f64] = &[4.0, 8.0, 16.0, 32.0];

impl Default for CampaignSpec {
    /// The committed-baseline matrix: every policy × every workload
    /// preset × [`DEFAULT_RATES`] × both backends, 2000 requests per
    /// scenario, fixed seed. `bench/BENCH_serving.baseline.json` and the
    /// CI campaign gate both come from this spec.
    fn default() -> CampaignSpec {
        CampaignSpec {
            policies: POLICY_NAMES.iter().map(|p| p.to_string()).collect(),
            workloads: WorkloadMix::preset_names().iter().map(|w| w.to_string()).collect(),
            backends: Backend::ALL.to_vec(),
            rates: DEFAULT_RATES.to_vec(),
            fleets: Vec::new(),
            devices: 4,
            requests: 2000,
            seed: 7,
            wear: None,
            faults: None,
        }
    }
}

impl CampaignSpec {
    /// Validate the axes and multiply them into scenarios in canonical
    /// order: fleet (name ascending, when the axis is present), then
    /// workload ascending, then policy, backend, rate — the order every
    /// rendering (table, JSON, baseline) uses, so re-runs are
    /// byte-comparable.
    pub fn expand(&self) -> Result<Vec<Scenario>> {
        if self.policies.is_empty()
            || self.workloads.is_empty()
            || self.backends.is_empty()
            || self.rates.is_empty()
        {
            bail!("campaign needs at least one policy, workload, backend, and rate");
        }
        if self.devices == 0 || self.requests == 0 {
            bail!("campaign needs positive --devices and --requests");
        }
        validate_rates(&self.rates)?;
        for p in &self.policies {
            if policy_from_name(p).is_none() {
                bail!("unknown policy {p:?}; use {}", TIERED_POLICY_NAMES.join("|"));
            }
        }
        let mut rates = self.rates.clone();
        rates.sort_by(f64::total_cmp);
        rates.dedup();

        let mut policies = self.policies.clone();
        policies.sort();
        policies.dedup();
        let mut backends = self.backends.clone();
        backends.sort();
        backends.dedup();

        // The fleet axis: `None` alone for legacy flash-only campaigns,
        // otherwise the deduplicated specs in canonical-name order.
        let fleets: Vec<Option<FleetSpec>> = if self.fleets.is_empty() {
            vec![None]
        } else {
            let mut f = self.fleets.clone();
            f.sort_by(|a, b| a.name().cmp(&b.name()));
            f.dedup();
            f.into_iter().map(Some).collect()
        };

        // Resolve each workload once; order mixes by resolved name.
        let mut mixes = Vec::with_capacity(self.workloads.len());
        for w in &self.workloads {
            mixes.push(WorkloadMix::resolve(w)?);
        }
        mixes.sort_by(|a, b| a.name().cmp(b.name()));
        mixes.dedup_by(|a, b| a.name() == b.name());

        let points = fleets.len() * mixes.len() * policies.len() * backends.len() * rates.len();
        let mut out = Vec::with_capacity(points);
        for fleet in &fleets {
            let tier_names = tier_names_of(fleet.as_ref());
            for mix in &mixes {
                let class_names: Vec<String> =
                    mix.classes().iter().map(|c| c.name.clone()).collect();
                for policy in &policies {
                    for backend in &backends {
                        for &rate in &rates {
                            out.push(Scenario {
                                policy: policy.clone(),
                                workload: mix.name().to_string(),
                                backend: *backend,
                                rate,
                                mix: mix.clone(),
                                class_names: class_names.clone(),
                                fleet: fleet.clone(),
                                tier_names: tier_names.clone(),
                            });
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Expand and select: the scenarios a filter keeps, in canonical
    /// order. Errors when the filter matches nothing (a silent empty
    /// campaign would read as "everything passed").
    pub fn select(&self, filter: Option<&Expr>) -> Result<Vec<Scenario>> {
        let all = self.expand()?;
        let total = all.len();
        let selected: Vec<Scenario> = match filter {
            None => all,
            Some(f) => all.into_iter().filter(|s| f.matches(&s.view())).collect(),
        };
        if selected.is_empty() {
            bail!(
                "filter selects none of the {total} scenarios; try `repro campaign --list` to \
                 see the matrix"
            );
        }
        Ok(selected)
    }

    /// The traffic configuration of one scenario. Fleet scenarios size
    /// the pool from their spec and carry it into the simulators.
    fn traffic(&self, s: &Scenario) -> TrafficConfig {
        let devices = s.fleet.as_ref().map_or(self.devices, |f| f.n_devices());
        let mut cfg = TrafficConfig::default_for(devices);
        cfg.rate = s.rate;
        cfg.requests = self.requests;
        cfg.seed = self.seed;
        cfg.workload = Some(s.mix.clone());
        cfg.fleet = s.fleet.clone();
        cfg.wear = self.wear.map(WearConfig::new);
        cfg.faults = self.faults.clone();
        cfg
    }
}

/// One executed scenario: the point metrics its backend produced.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    pub scenario: Scenario,
    pub point: SweepPoint,
}

/// Execute the selected scenarios against one shared latency table,
/// fanned out over scoped worker threads (results land by index, so the
/// output order is the canonical scenario order regardless of thread
/// scheduling). Event-backend scenarios stream through a
/// [`StreamingSink`][crate::coordinator::sink::StreamingSink]; threaded
/// ones reduce a materialized report — both yield the same
/// [`SweepPoint`] shape.
pub fn run_campaign(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    spec: &CampaignSpec,
    filter: Option<&Expr>,
) -> Result<Vec<CampaignOutcome>> {
    let scenarios = spec.select(filter)?;
    let outcomes = fan_out_indexed(&scenarios, |s| {
        let cfg = spec.traffic(s);
        let policy = policy_from_name(&s.policy).expect("policy validated in expand");
        match s.backend {
            Backend::Event => run_traffic_point(sys, model, table, policy, &cfg),
            Backend::Threaded => {
                SweepPoint::of(&run_traffic_with_table(sys, model, table, policy, &cfg))
            }
        }
    });
    Ok(scenarios
        .into_iter()
        .zip(outcomes)
        .map(|(scenario, point)| CampaignOutcome { scenario, point })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> CampaignSpec {
        CampaignSpec {
            policies: vec!["slo-aware".into(), "round-robin".into()],
            workloads: vec!["chat".into(), "summarize-long".into()],
            backends: Backend::ALL.to_vec(),
            rates: vec![20.0, 5.0],
            fleets: Vec::new(),
            devices: 2,
            requests: 20,
            seed: 3,
            wear: None,
            faults: None,
        }
    }

    #[test]
    fn expansion_is_canonically_ordered() {
        let scenarios = tiny_spec().expand().unwrap();
        assert_eq!(scenarios.len(), 2 * 2 * 2 * 2);
        // Workloads ascend, then policies, then backends, then rates.
        assert_eq!(scenarios[0].workload, "chat");
        assert_eq!(scenarios[0].policy, "round-robin");
        assert_eq!(scenarios[0].backend, Backend::Event);
        assert_eq!(scenarios[0].rate, 5.0, "rates sorted ascending");
        assert_eq!(scenarios[1].rate, 20.0);
        assert_eq!(scenarios[2].backend, Backend::Threaded);
        assert_eq!(scenarios[4].policy, "slo-aware");
        assert_eq!(scenarios[8].workload, "summarize-long");
        // The summarize-long preset carries both class names for filters.
        assert!(scenarios[8].class_names.contains(&"chat".to_string()));
        assert!(scenarios[8].class_names.contains(&"summarize-long".to_string()));
    }

    #[test]
    fn default_spec_expands_the_full_matrix() {
        let scenarios = CampaignSpec::default().expand().unwrap();
        assert_eq!(scenarios.len(), 3 * 4 * 2 * DEFAULT_RATES.len());
    }

    #[test]
    fn filter_selects_the_exact_subset() {
        let spec = tiny_spec();
        let f = Expr::parse("policy(slo-aware) & workload(chat) & backend(event)").unwrap();
        let sel = spec.select(Some(&f)).unwrap();
        assert_eq!(sel.len(), 2, "one per rate");
        for s in &sel {
            assert_eq!(s.policy, "slo-aware");
            assert_eq!(s.workload, "chat");
            assert_eq!(s.backend, Backend::Event);
        }

        // class(chat) also matches the summarize-long mix (it contains a
        // chat class); workload(chat) does not.
        let f = Expr::parse("class(chat) & backend(event) & policy(round-robin)").unwrap();
        assert_eq!(spec.select(Some(&f)).unwrap().len(), 4, "both mixes contain a chat class");
        let f = Expr::parse("rate > 10").unwrap();
        assert_eq!(spec.select(Some(&f)).unwrap().len(), 8);

        let none = Expr::parse("policy(least-loaded)").unwrap();
        assert!(spec.select(Some(&none)).is_err(), "empty selection is an error");
    }

    #[test]
    fn fleet_axis_expands_outermost_and_filters_by_tier() {
        let mut spec = tiny_spec();
        spec.fleets = vec![
            FleetSpec::parse("4xflash").unwrap(),
            FleetSpec::parse("1xflash+1xgpu").unwrap(),
        ];
        let scenarios = spec.expand().unwrap();
        assert_eq!(scenarios.len(), 2 * 2 * 2 * 2 * 2, "fleet doubles the matrix");
        // Fleets order by canonical name: 1xflash+1xgpu < 4xflash.
        assert_eq!(scenarios[0].fleet.as_ref().unwrap().name(), "1xflash+1xgpu");
        assert_eq!(scenarios[0].tier_names, vec!["flash", "gpu"]);
        assert_eq!(scenarios[16].fleet.as_ref().unwrap().name(), "4xflash");
        assert_eq!(scenarios[16].tier_names, vec!["flash"]);
        // Inner order is unchanged: workload, then policy, backend, rate.
        assert_eq!(scenarios[0].workload, "chat");
        assert_eq!(scenarios[0].policy, "round-robin");
        assert_eq!(scenarios[0].rate, 5.0);
        // tier(gpu) keeps only the hybrid half; tier(flash) keeps all.
        let gpu = Expr::parse("tier(gpu)").unwrap();
        assert_eq!(spec.select(Some(&gpu)).unwrap().len(), 16);
        let flash = Expr::parse("tier(flash)").unwrap();
        assert_eq!(spec.select(Some(&flash)).unwrap().len(), 32);
        // Fleet scenarios size their pool from the spec, not --devices.
        let hybrid = &scenarios[0];
        let cfg = spec.traffic(hybrid);
        assert_eq!(cfg.devices, 2);
        assert_eq!(cfg.fleet.as_ref().unwrap().name(), "1xflash+1xgpu");
        let legacy = tiny_spec();
        let cfg = legacy.traffic(&legacy.expand().unwrap()[0]);
        assert_eq!(cfg.devices, 2);
        assert!(cfg.fleet.is_none(), "legacy campaigns carry no fleet");
        // tier-aware is a valid campaign policy.
        let mut spec = tiny_spec();
        spec.policies = vec!["tier-aware".into()];
        assert!(spec.expand().is_ok());
    }

    #[test]
    fn wear_knob_threads_into_every_scenario() {
        let spec = tiny_spec();
        let scenarios = spec.expand().unwrap();
        assert!(spec.traffic(&scenarios[0]).wear.is_none(), "default campaigns are wear-blind");
        let mut spec = tiny_spec();
        spec.wear = Some(500);
        let cfg = spec.traffic(&scenarios[0]);
        assert_eq!(cfg.wear, Some(WearConfig::new(500)));
        // wear-aware is a valid campaign policy (opt-in by name).
        spec.policies = vec!["wear-aware".into()];
        assert!(spec.expand().is_ok());
    }

    #[test]
    fn faults_knob_threads_into_every_scenario() {
        let spec = tiny_spec();
        let scenarios = spec.expand().unwrap();
        assert!(
            spec.traffic(&scenarios[0]).faults.is_none(),
            "default campaigns are fault-free"
        );
        let mut spec = tiny_spec();
        let parsed = FaultConfig::parse("fail_at=0@20,retries=2,spares=1").unwrap();
        spec.faults = parsed.clone().active();
        for s in &scenarios {
            let cfg = spec.traffic(s);
            assert_eq!(cfg.faults.as_ref(), Some(&parsed), "same spec in every scenario");
        }
        // An inert spec normalizes away and leaves scenarios fault-free.
        let mut spec = tiny_spec();
        spec.faults = FaultConfig::parse("fail=0").unwrap().active();
        assert!(spec.traffic(&scenarios[0]).faults.is_none());
    }

    #[test]
    fn expansion_rejects_bad_axes() {
        let mut spec = tiny_spec();
        spec.policies = vec!["fifo".into()];
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.rates = vec![-1.0];
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.workloads = vec!["no-such-preset".into()];
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.requests = 0;
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.backends.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn campaign_runs_deterministically() {
        use crate::circuit::TechParams;
        use crate::config::presets::table1_system;
        use crate::llm::model_config::OptModel;

        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let spec = CampaignSpec {
            policies: vec!["least-loaded".into()],
            workloads: vec!["chat".into()],
            backends: Backend::ALL.to_vec(),
            rates: vec![30.0],
            fleets: Vec::new(),
            devices: 2,
            requests: 25,
            seed: 11,
            wear: None,
            faults: None,
        };
        let a = run_campaign(&sys, &model, &table, &spec, None).unwrap();
        let b = run_campaign(&sys, &model, &table, &spec, None).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.point, y.point, "same spec, same bytes");
            assert_eq!(x.point.accepted + x.point.rejected, 25);
        }
        assert_eq!(a[0].scenario.backend, Backend::Event);
        assert_eq!(a[1].scenario.backend, Backend::Threaded);
    }
}
