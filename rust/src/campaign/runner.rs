//! Campaign expansion and execution: a scenario matrix → selected
//! scenarios → one [`SweepPoint`] each, fanned out on scoped threads.
//!
//! A [`CampaignSpec`] names the axes (policies × workloads × backends ×
//! rate grid) plus the per-scenario traffic budget; [`CampaignSpec::expand`]
//! multiplies them into a canonically-ordered scenario list, an optional
//! [`Expr`] filter selects the slice to run, and [`run_campaign`]
//! executes every selected scenario over the shared worker scaffold
//! ([`fan_out_indexed`][crate::coordinator::sweep]) with one prebuilt
//! [`LatencyTable`]. Every scenario is an independent deterministic
//! computation (own RNG from the fixed seed), so a campaign's results —
//! and the `BENCH_serving.json` rendered from them by
//! [`super::report`] — are bit-reproducible for a given spec.

use super::filter::{Expr, ScenarioView};
use crate::config::SystemConfig;
use crate::coordinator::event_sim::run_traffic_point;
use crate::coordinator::loadgen::{run_traffic_with_table, TrafficConfig};
use crate::coordinator::router::{policy_from_name, POLICY_NAMES};
use crate::coordinator::sweep::{fan_out_indexed, SweepPoint, validate_rates};
use crate::coordinator::workload::WorkloadMix;
use crate::llm::latency_table::LatencyTable;
use crate::llm::model_config::ModelShape;
use anyhow::{bail, Result};

/// Which serving backend a scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Backend {
    /// Deterministic event-driven simulator with coalesced decode and a
    /// streaming sink — the serving default.
    Event,
    /// Direct-replay cross-check backend (`serve-sim --threaded`).
    Threaded,
}

impl Backend {
    pub const ALL: &'static [Backend] = &[Backend::Event, Backend::Threaded];

    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Event => "event",
            Backend::Threaded => "threaded",
        }
    }

    pub fn from_name(name: &str) -> Option<Backend> {
        match name {
            "event" => Some(Backend::Event),
            "threaded" => Some(Backend::Threaded),
            _ => None,
        }
    }
}

/// One point of the campaign matrix, fully resolved (the workload mix is
/// materialized so filters can see class names).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub policy: String,
    /// Mix name (preset name, or the name inside a custom TOML).
    pub workload: String,
    pub backend: Backend,
    pub rate: f64,
    pub mix: WorkloadMix,
    /// Class names of `mix`, cached for filter matching.
    pub class_names: Vec<String>,
}

impl Scenario {
    /// The borrowed attribute view filters evaluate against.
    pub fn view(&self) -> ScenarioView<'_> {
        ScenarioView {
            policy: &self.policy,
            workload: &self.workload,
            classes: &self.class_names,
            backend: self.backend.as_str(),
            rate: self.rate,
        }
    }
}

/// The axes and budget of a campaign. `expand` turns this into the
/// canonical scenario list; the default spec is the committed-baseline
/// matrix CI gates on.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Scheduler policy names ([`policy_from_name`] must accept each).
    pub policies: Vec<String>,
    /// Workload preset names or TOML paths ([`WorkloadMix::resolve`]).
    pub workloads: Vec<String>,
    pub backends: Vec<Backend>,
    /// Offered arrival rates (requests/second).
    pub rates: Vec<f64>,
    /// Devices in the pool of every scenario.
    pub devices: usize,
    /// Closed-loop arrivals per scenario.
    pub requests: usize,
    /// RNG seed every scenario derives its stream from.
    pub seed: u64,
}

/// Default rate grid of the campaign matrix (requests/second).
pub const DEFAULT_RATES: &[f64] = &[4.0, 8.0, 16.0, 32.0];

impl Default for CampaignSpec {
    /// The committed-baseline matrix: every policy × every workload
    /// preset × [`DEFAULT_RATES`] × both backends, 2000 requests per
    /// scenario, fixed seed. `bench/BENCH_serving.baseline.json` and the
    /// CI campaign gate both come from this spec.
    fn default() -> CampaignSpec {
        CampaignSpec {
            policies: POLICY_NAMES.iter().map(|p| p.to_string()).collect(),
            workloads: WorkloadMix::preset_names().iter().map(|w| w.to_string()).collect(),
            backends: Backend::ALL.to_vec(),
            rates: DEFAULT_RATES.to_vec(),
            devices: 4,
            requests: 2000,
            seed: 7,
        }
    }
}

impl CampaignSpec {
    /// Validate the axes and multiply them into scenarios in canonical
    /// order: workload ascending, then policy, backend, rate — the order
    /// every rendering (table, JSON, baseline) uses, so re-runs are
    /// byte-comparable.
    pub fn expand(&self) -> Result<Vec<Scenario>> {
        if self.policies.is_empty()
            || self.workloads.is_empty()
            || self.backends.is_empty()
            || self.rates.is_empty()
        {
            bail!("campaign needs at least one policy, workload, backend, and rate");
        }
        if self.devices == 0 || self.requests == 0 {
            bail!("campaign needs positive --devices and --requests");
        }
        validate_rates(&self.rates)?;
        for p in &self.policies {
            if policy_from_name(p).is_none() {
                bail!("unknown policy {p:?}; use {}", POLICY_NAMES.join("|"));
            }
        }
        let mut rates = self.rates.clone();
        rates.sort_by(f64::total_cmp);
        rates.dedup();

        let mut policies = self.policies.clone();
        policies.sort();
        policies.dedup();
        let mut backends = self.backends.clone();
        backends.sort();
        backends.dedup();

        // Resolve each workload once; order mixes by resolved name.
        let mut mixes = Vec::with_capacity(self.workloads.len());
        for w in &self.workloads {
            mixes.push(WorkloadMix::resolve(w)?);
        }
        mixes.sort_by(|a, b| a.name().cmp(b.name()));
        mixes.dedup_by(|a, b| a.name() == b.name());

        let points = mixes.len() * policies.len() * backends.len() * rates.len();
        let mut out = Vec::with_capacity(points);
        for mix in &mixes {
            let class_names: Vec<String> =
                mix.classes().iter().map(|c| c.name.clone()).collect();
            for policy in &policies {
                for backend in &backends {
                    for &rate in &rates {
                        out.push(Scenario {
                            policy: policy.clone(),
                            workload: mix.name().to_string(),
                            backend: *backend,
                            rate,
                            mix: mix.clone(),
                            class_names: class_names.clone(),
                        });
                    }
                }
            }
        }
        Ok(out)
    }

    /// Expand and select: the scenarios a filter keeps, in canonical
    /// order. Errors when the filter matches nothing (a silent empty
    /// campaign would read as "everything passed").
    pub fn select(&self, filter: Option<&Expr>) -> Result<Vec<Scenario>> {
        let all = self.expand()?;
        let total = all.len();
        let selected: Vec<Scenario> = match filter {
            None => all,
            Some(f) => all.into_iter().filter(|s| f.matches(&s.view())).collect(),
        };
        if selected.is_empty() {
            bail!(
                "filter selects none of the {total} scenarios; try `repro campaign --list` to \
                 see the matrix"
            );
        }
        Ok(selected)
    }

    /// The traffic configuration of one scenario.
    fn traffic(&self, s: &Scenario) -> TrafficConfig {
        let mut cfg = TrafficConfig::default_for(self.devices);
        cfg.rate = s.rate;
        cfg.requests = self.requests;
        cfg.seed = self.seed;
        cfg.workload = Some(s.mix.clone());
        cfg
    }
}

/// One executed scenario: the point metrics its backend produced.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    pub scenario: Scenario,
    pub point: SweepPoint,
}

/// Execute the selected scenarios against one shared latency table,
/// fanned out over scoped worker threads (results land by index, so the
/// output order is the canonical scenario order regardless of thread
/// scheduling). Event-backend scenarios stream through a
/// [`StreamingSink`][crate::coordinator::sink::StreamingSink]; threaded
/// ones reduce a materialized report — both yield the same
/// [`SweepPoint`] shape.
pub fn run_campaign(
    sys: &SystemConfig,
    model: &ModelShape,
    table: &LatencyTable,
    spec: &CampaignSpec,
    filter: Option<&Expr>,
) -> Result<Vec<CampaignOutcome>> {
    let scenarios = spec.select(filter)?;
    let outcomes = fan_out_indexed(&scenarios, |s| {
        let cfg = spec.traffic(s);
        let policy = policy_from_name(&s.policy).expect("policy validated in expand");
        match s.backend {
            Backend::Event => run_traffic_point(sys, model, table, policy, &cfg),
            Backend::Threaded => {
                SweepPoint::of(&run_traffic_with_table(sys, model, table, policy, &cfg))
            }
        }
    });
    Ok(scenarios
        .into_iter()
        .zip(outcomes)
        .map(|(scenario, point)| CampaignOutcome { scenario, point })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> CampaignSpec {
        CampaignSpec {
            policies: vec!["slo-aware".into(), "round-robin".into()],
            workloads: vec!["chat".into(), "summarize-long".into()],
            backends: Backend::ALL.to_vec(),
            rates: vec![20.0, 5.0],
            devices: 2,
            requests: 20,
            seed: 3,
        }
    }

    #[test]
    fn expansion_is_canonically_ordered() {
        let scenarios = tiny_spec().expand().unwrap();
        assert_eq!(scenarios.len(), 2 * 2 * 2 * 2);
        // Workloads ascend, then policies, then backends, then rates.
        assert_eq!(scenarios[0].workload, "chat");
        assert_eq!(scenarios[0].policy, "round-robin");
        assert_eq!(scenarios[0].backend, Backend::Event);
        assert_eq!(scenarios[0].rate, 5.0, "rates sorted ascending");
        assert_eq!(scenarios[1].rate, 20.0);
        assert_eq!(scenarios[2].backend, Backend::Threaded);
        assert_eq!(scenarios[4].policy, "slo-aware");
        assert_eq!(scenarios[8].workload, "summarize-long");
        // The summarize-long preset carries both class names for filters.
        assert!(scenarios[8].class_names.contains(&"chat".to_string()));
        assert!(scenarios[8].class_names.contains(&"summarize-long".to_string()));
    }

    #[test]
    fn default_spec_expands_the_full_matrix() {
        let scenarios = CampaignSpec::default().expand().unwrap();
        assert_eq!(scenarios.len(), 3 * 4 * 2 * DEFAULT_RATES.len());
    }

    #[test]
    fn filter_selects_the_exact_subset() {
        let spec = tiny_spec();
        let f = Expr::parse("policy(slo-aware) & workload(chat) & backend(event)").unwrap();
        let sel = spec.select(Some(&f)).unwrap();
        assert_eq!(sel.len(), 2, "one per rate");
        for s in &sel {
            assert_eq!(s.policy, "slo-aware");
            assert_eq!(s.workload, "chat");
            assert_eq!(s.backend, Backend::Event);
        }

        // class(chat) also matches the summarize-long mix (it contains a
        // chat class); workload(chat) does not.
        let f = Expr::parse("class(chat) & backend(event) & policy(round-robin)").unwrap();
        assert_eq!(spec.select(Some(&f)).unwrap().len(), 4, "both mixes contain a chat class");
        let f = Expr::parse("rate > 10").unwrap();
        assert_eq!(spec.select(Some(&f)).unwrap().len(), 8);

        let none = Expr::parse("policy(least-loaded)").unwrap();
        assert!(spec.select(Some(&none)).is_err(), "empty selection is an error");
    }

    #[test]
    fn expansion_rejects_bad_axes() {
        let mut spec = tiny_spec();
        spec.policies = vec!["fifo".into()];
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.rates = vec![-1.0];
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.workloads = vec!["no-such-preset".into()];
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.requests = 0;
        assert!(spec.expand().is_err());
        let mut spec = tiny_spec();
        spec.backends.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn campaign_runs_deterministically() {
        use crate::circuit::TechParams;
        use crate::config::presets::table1_system;
        use crate::llm::model_config::OptModel;

        let sys = table1_system();
        let model = OptModel::Opt6_7b.shape();
        let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
        let spec = CampaignSpec {
            policies: vec!["least-loaded".into()],
            workloads: vec!["chat".into()],
            backends: Backend::ALL.to_vec(),
            rates: vec![30.0],
            devices: 2,
            requests: 25,
            seed: 11,
        };
        let a = run_campaign(&sys, &model, &table, &spec, None).unwrap();
        let b = run_campaign(&sys, &model, &table, &spec, None).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.point, y.point, "same spec, same bytes");
            assert_eq!(x.point.accepted + x.point.rejected, 25);
        }
        assert_eq!(a[0].scenario.backend, Backend::Event);
        assert_eq!(a[1].scenario.backend, Backend::Threaded);
    }
}
