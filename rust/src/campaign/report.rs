//! Campaign rendering: the human table and the canonical
//! `BENCH_serving.json` metrics document (report/render split — the
//! runner produces [`CampaignOutcome`]s, this module turns them into
//! output, the way the tytanic runner separates execution from report
//! rendering).
//!
//! Metric names are hierarchical and deterministic:
//!
//! ```text
//! campaign/<workload>/<policy>/<backend>/r<rate>/<metric>           (legacy)
//! campaign/<fleet>/<workload>/<policy>/<backend>/r<rate>/<metric>   (fleet axis)
//! ```
//!
//! e.g. `campaign/chat/slo-aware/event/r8/ttft_p95_s`, or
//! `campaign/4xflash+1xgpu/chat/tier-aware/event/r8/cost_per_mtok_usd`
//! for a fleet campaign. The fleet segment appears **only** when the
//! campaign swept a fleet axis, so legacy flash-only documents are
//! byte-identical to pre-fleet builds. Outcomes arrive in the runner's
//! canonical scenario order, so two runs of the same spec render
//! byte-identical documents — the property the committed baseline and
//! the CI determinism guard rely on.

use super::runner::{CampaignOutcome, Scenario};
use crate::util::benchkit::JsonEmitter;
use crate::util::table::Table;
use crate::util::units::fmt_time;

/// Canonical metric-name prefix of one scenario. The rate renders via
/// `f64`'s shortest-round-trip `Display` (`r8`, `r2.5`), which is
/// deterministic across platforms. Fleet scenarios gain a fleet segment
/// right after `campaign/`; legacy scenarios keep the pre-fleet shape.
pub fn scenario_key(s: &Scenario) -> String {
    match &s.fleet {
        Some(f) => format!(
            "campaign/{}/{}/{}/{}/r{}",
            f.name(),
            s.workload,
            s.policy,
            s.backend.as_str(),
            s.rate
        ),
        None => {
            format!("campaign/{}/{}/{}/r{}", s.workload, s.policy, s.backend.as_str(), s.rate)
        }
    }
}

/// Append one scenario's deterministic metrics to the emitter, under
/// [`scenario_key`]. Per-class SLO attainment lands as
/// `<key>/slo/<class>`; fleet scenarios add `cost_per_mtok_usd` and
/// `energy_per_mtok_j`; wear-enabled scenarios add `wear_max_erases`,
/// `wear_total_erases`, and `wear_retirements`; fault-injected scenarios
/// add the `faults_*` reliability keys. Each group is absent — not zero —
/// when its accounting is off, so legacy documents stay byte-identical.
pub fn emit_outcome(json: &mut JsonEmitter, o: &CampaignOutcome) {
    let key = scenario_key(&o.scenario);
    let p = &o.point;
    json.metric(&format!("{key}/accepted"), p.accepted as f64, "requests");
    json.metric(&format!("{key}/rejected"), p.rejected as f64, "requests");
    json.metric(&format!("{key}/throughput_tok_s"), p.throughput, "tokens/s");
    json.metric(&format!("{key}/ttft_p95_s"), p.ttft_p95, "s");
    json.metric(&format!("{key}/lat_p50_s"), p.latency_p50, "s");
    json.metric(&format!("{key}/lat_p95_s"), p.latency_p95, "s");
    json.metric(&format!("{key}/lat_p99_s"), p.latency_p99, "s");
    if let Some(c) = p.cost_per_mtok {
        json.metric(&format!("{key}/cost_per_mtok_usd"), c, "usd/Mtok");
    }
    if let Some(e) = p.energy_per_mtok {
        json.metric(&format!("{key}/energy_per_mtok_j"), e, "J/Mtok");
    }
    if let Some(e) = p.wear_max_erases {
        json.metric(&format!("{key}/wear_max_erases"), e as f64, "erases");
    }
    if let Some(e) = p.wear_total_erases {
        json.metric(&format!("{key}/wear_total_erases"), e as f64, "erases");
    }
    if let Some(r) = p.wear_retirements {
        json.metric(&format!("{key}/wear_retirements"), r as f64, "devices");
    }
    if let Some(a) = p.faults_availability {
        json.metric(&format!("{key}/faults_availability"), a, "fraction");
    }
    if let Some(n) = p.faults_failed {
        json.metric(&format!("{key}/faults_failed"), n as f64, "requests");
    }
    if let Some(n) = p.faults_retries {
        json.metric(&format!("{key}/faults_retries"), n as f64, "attempts");
    }
    if let Some(n) = p.faults_failovers {
        json.metric(&format!("{key}/faults_failovers"), n as f64, "requests");
    }
    if let Some(n) = p.faults_shed {
        json.metric(&format!("{key}/faults_shed"), n as f64, "requests");
    }
    if let Some(n) = p.faults_reprefill_tok {
        json.metric(&format!("{key}/faults_reprefill_tok"), n as f64, "tokens");
    }
    if let Some(s) = p.faults_degraded_s {
        json.metric(&format!("{key}/faults_degraded_s"), s, "s");
    }
    for c in &p.class_attainment {
        json.metric(&format!("{key}/slo/{}", c.class), c.attainment, "fraction");
    }
}

/// Render the whole campaign as one metrics document. `wall_s`, when
/// given, is appended as `campaign_wall_s` — a wall-clock metric the
/// baseline differ treats as informational (CI runners are noisy), so it
/// belongs in the uploaded artifact but never in a committed baseline
/// (pass `None` there; see [`super::baseline`]).
pub fn campaign_metrics(outcomes: &[CampaignOutcome], wall_s: Option<f64>) -> JsonEmitter {
    let mut json = JsonEmitter::new();
    for o in outcomes {
        emit_outcome(&mut json, o);
    }
    json.metric("campaign_scenarios", outcomes.len() as f64, "scenarios");
    if let Some(w) = wall_s {
        json.metric("campaign_wall_s", w, "s-wall");
    }
    json
}

/// ASCII table of campaign results, one row per scenario in canonical
/// order — the interactive face of the same data the JSON carries.
/// Fleet campaigns lead with a fleet column and append `$/Mtok`; legacy
/// campaigns render byte-identically to pre-fleet builds.
pub fn render_campaign(outcomes: &[CampaignOutcome]) -> String {
    let fleeted = outcomes.iter().any(|o| o.scenario.fleet.is_some());
    let weared = outcomes.iter().any(|o| o.point.wear_max_erases.is_some());
    let faulted = outcomes.iter().any(|o| o.point.faults_availability.is_some());
    let mut headers: Vec<&str> = Vec::new();
    if fleeted {
        headers.push("fleet");
    }
    headers.extend([
        "workload",
        "policy",
        "backend",
        "rate req/s",
        "accepted",
        "rejected",
        "tok/s",
        "TTFT p95",
        "lat p50",
        "lat p95",
        "lat p99",
    ]);
    if fleeted {
        headers.push("$/Mtok");
    }
    if weared {
        headers.push("max erases");
        headers.push("retired");
    }
    if faulted {
        headers.push("avail");
        headers.push("failed");
        headers.push("shed");
    }
    headers.push("min SLO");
    let mut t = Table::new(&headers);
    for o in outcomes {
        let p = &o.point;
        let mut cells: Vec<String> = Vec::new();
        if fleeted {
            cells.push(
                o.scenario.fleet.as_ref().map_or_else(|| "-".to_string(), |f| f.name()),
            );
        }
        cells.extend([
            o.scenario.workload.clone(),
            o.scenario.policy.clone(),
            o.scenario.backend.as_str().to_string(),
            format!("{:.1}", o.scenario.rate),
            p.accepted.to_string(),
            p.rejected.to_string(),
            format!("{:.1}", p.throughput),
            fmt_time(p.ttft_p95),
            fmt_time(p.latency_p50),
            fmt_time(p.latency_p95),
            fmt_time(p.latency_p99),
        ]);
        if fleeted {
            cells.push(match p.cost_per_mtok {
                Some(c) => format!("{c:.2}"),
                None => "-".to_string(),
            });
        }
        if weared {
            cells.push(match p.wear_max_erases {
                Some(e) => e.to_string(),
                None => "-".to_string(),
            });
            cells.push(match p.wear_retirements {
                Some(r) => r.to_string(),
                None => "-".to_string(),
            });
        }
        if faulted {
            cells.push(match p.faults_availability {
                Some(a) => format!("{a:.4}"),
                None => "-".to_string(),
            });
            cells.push(match p.faults_failed {
                Some(n) => n.to_string(),
                None => "-".to_string(),
            });
            cells.push(match p.faults_shed {
                Some(n) => n.to_string(),
                None => "-".to_string(),
            });
        }
        cells.push(match p.min_attainment() {
            Some(a) => format!("{:.1}%", a * 100.0),
            None => "-".to_string(),
        });
        t.row(&cells);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::runner::{Backend, CampaignSpec};
    use crate::coordinator::sweep::{ClassAttainment, SweepPoint};
    use crate::coordinator::WorkloadMix;
    use crate::util::benchkit::parse_metrics;

    fn outcome(workload: &str, policy: &str, backend: Backend, rate: f64) -> CampaignOutcome {
        let mix = WorkloadMix::preset(workload).expect("preset");
        let class_names = mix.classes().iter().map(|c| c.name.clone()).collect();
        CampaignOutcome {
            scenario: Scenario {
                policy: policy.to_string(),
                workload: workload.to_string(),
                backend,
                rate,
                mix,
                class_names,
                fleet: None,
                tier_names: vec!["flash".to_string()],
            },
            point: SweepPoint {
                policy: policy.to_string(),
                rate,
                accepted: 90,
                rejected: 10,
                throughput: 123.4,
                ttft_p95: 0.05,
                latency_p50: 0.1,
                latency_p95: 0.2,
                latency_p99: 0.3,
                cost_per_mtok: None,
                energy_per_mtok: None,
                wear_max_erases: None,
                wear_total_erases: None,
                wear_retirements: None,
                faults_availability: None,
                faults_failed: None,
                faults_retries: None,
                faults_failovers: None,
                faults_shed: None,
                faults_reprefill_tok: None,
                faults_degraded_s: None,
                class_attainment: vec![ClassAttainment {
                    class: "chat".into(),
                    attainment: 0.995,
                }],
            },
        }
    }

    /// A hybrid-fleet variant of [`outcome`] with priced columns.
    fn fleet_outcome(policy: &str, rate: f64) -> CampaignOutcome {
        use crate::coordinator::device::FleetSpec;
        let mut o = outcome("chat", policy, Backend::Event, rate);
        let spec = FleetSpec::parse("4xflash+1xgpu").unwrap();
        o.scenario.tier_names = vec!["flash".to_string(), "gpu".to_string()];
        o.scenario.fleet = Some(spec);
        o.point.cost_per_mtok = Some(1.75);
        o.point.energy_per_mtok = Some(420.5);
        o
    }

    #[test]
    fn scenario_keys_are_canonical() {
        let o = outcome("chat", "slo-aware", Backend::Event, 8.0);
        assert_eq!(scenario_key(&o.scenario), "campaign/chat/slo-aware/event/r8");
        let o = outcome("chat", "slo-aware", Backend::Threaded, 2.5);
        assert_eq!(scenario_key(&o.scenario), "campaign/chat/slo-aware/threaded/r2.5");
        let o = fleet_outcome("tier-aware", 8.0);
        assert_eq!(
            scenario_key(&o.scenario),
            "campaign/4xflash+1xgpu/chat/tier-aware/event/r8",
            "fleet campaigns key under their fleet segment"
        );
    }

    #[test]
    fn fleet_outcomes_emit_priced_metrics_and_column() {
        let outcomes = vec![fleet_outcome("tier-aware", 8.0)];
        let doc = campaign_metrics(&outcomes, None).render();
        let metrics = parse_metrics(&doc).unwrap();
        let cost = metrics
            .iter()
            .find(|m| {
                m.name == "campaign/4xflash+1xgpu/chat/tier-aware/event/r8/cost_per_mtok_usd"
            })
            .expect("cost metric emitted");
        assert_eq!(cost.value, 1.75);
        assert_eq!(cost.unit, "usd/Mtok");
        assert!(metrics.iter().any(|m| m.name.ends_with("/energy_per_mtok_j")));
        let s = render_campaign(&outcomes);
        assert!(s.contains("4xflash+1xgpu") && s.contains("$/Mtok") && s.contains("1.75"), "{s}");
        // Legacy outcomes render without the fleet columns.
        let legacy = render_campaign(&[outcome("chat", "slo-aware", Backend::Event, 8.0)]);
        assert!(!legacy.contains("$/Mtok") && !legacy.contains("fleet"), "{legacy}");
    }

    #[test]
    fn wear_outcomes_emit_gated_metrics_and_columns() {
        let mut o = outcome("chat", "wear-aware", Backend::Event, 8.0);
        o.point.wear_max_erases = Some(37);
        o.point.wear_total_erases = Some(120);
        o.point.wear_retirements = Some(1);
        let doc = campaign_metrics(&[o.clone()], None).render();
        let metrics = parse_metrics(&doc).unwrap();
        let max = metrics
            .iter()
            .find(|m| m.name == "campaign/chat/wear-aware/event/r8/wear_max_erases")
            .expect("wear metric emitted");
        assert_eq!(max.value, 37.0);
        assert_eq!(max.unit, "erases");
        assert!(metrics.iter().any(|m| m.name.ends_with("/wear_total_erases")));
        assert!(metrics.iter().any(|m| m.name.ends_with("/wear_retirements")));
        let s = render_campaign(&[o]);
        assert!(s.contains("max erases") && s.contains("retired") && s.contains("37"), "{s}");
        // Wear-blind outcomes emit no wear keys and no wear columns.
        let legacy = outcome("chat", "slo-aware", Backend::Event, 8.0);
        let doc = campaign_metrics(&[legacy.clone()], None).render();
        assert!(!doc.contains("wear_"), "{doc}");
        assert!(!render_campaign(&[legacy]).contains("max erases"));
    }

    #[test]
    fn fault_outcomes_emit_gated_metrics_and_columns() {
        let mut o = outcome("chat", "least-loaded", Backend::Event, 8.0);
        o.point.faults_availability = Some(0.9375);
        o.point.faults_failed = Some(2);
        o.point.faults_retries = Some(5);
        o.point.faults_failovers = Some(3);
        o.point.faults_shed = Some(7);
        o.point.faults_reprefill_tok = Some(640);
        o.point.faults_degraded_s = Some(12.5);
        let doc = campaign_metrics(&[o.clone()], None).render();
        let metrics = parse_metrics(&doc).unwrap();
        let avail = metrics
            .iter()
            .find(|m| m.name == "campaign/chat/least-loaded/event/r8/faults_availability")
            .expect("availability metric emitted");
        assert_eq!(avail.value, 0.9375);
        assert_eq!(avail.unit, "fraction");
        for suffix in [
            "/faults_failed",
            "/faults_retries",
            "/faults_failovers",
            "/faults_shed",
            "/faults_reprefill_tok",
            "/faults_degraded_s",
        ] {
            assert!(metrics.iter().any(|m| m.name.ends_with(suffix)), "missing {suffix}");
        }
        let s = render_campaign(&[o]);
        assert!(s.contains("avail") && s.contains("0.9375") && s.contains("shed"), "{s}");
        // Fault-free outcomes emit no fault keys and no fault columns.
        let legacy = outcome("chat", "slo-aware", Backend::Event, 8.0);
        let doc = campaign_metrics(&[legacy.clone()], None).render();
        assert!(!doc.contains("faults_"), "{doc}");
        assert!(!render_campaign(&[legacy]).contains("avail"));
    }

    #[test]
    fn metrics_document_round_trips_and_orders_deterministically() {
        let outcomes =
            vec![outcome("chat", "slo-aware", Backend::Event, 8.0), {
                let mut o = outcome("chat", "round-robin", Backend::Event, 16.0);
                o.point.rejected = 0;
                o
            }];
        let doc = campaign_metrics(&outcomes, Some(1.25)).render();
        assert_eq!(doc, campaign_metrics(&outcomes, Some(1.25)).render(), "byte-stable");
        let metrics = parse_metrics(&doc).unwrap();
        // 8 metrics per scenario (7 point + 1 class) + count + wall.
        assert_eq!(metrics.len(), 2 * 8 + 2);
        assert_eq!(metrics[0].name, "campaign/chat/slo-aware/event/r8/accepted");
        assert_eq!(metrics[0].value, 90.0);
        assert!(metrics.iter().any(|m| m.name == "campaign/chat/slo-aware/event/r8/slo/chat"));
        assert_eq!(metrics.last().unwrap().name, "campaign_wall_s");
        assert_eq!(metrics.last().unwrap().unit, "s-wall");
        // Without a wall clock (baseline mode) the document is wall-free.
        let base = campaign_metrics(&outcomes, None).render();
        assert!(!base.contains("campaign_wall_s"));
    }

    #[test]
    fn table_renders_every_scenario_row() {
        let outcomes = vec![outcome("chat", "slo-aware", Backend::Event, 8.0)];
        let s = render_campaign(&outcomes);
        assert!(s.contains("slo-aware") && s.contains("event") && s.contains("99.5%"), "{s}");
    }

    #[test]
    fn emitted_names_match_the_expanded_matrix() {
        // Every expanded scenario gets a unique key.
        let spec = CampaignSpec::default();
        let scenarios = spec.expand().unwrap();
        let keys: std::collections::BTreeSet<String> =
            scenarios.iter().map(scenario_key).collect();
        assert_eq!(keys.len(), scenarios.len(), "scenario keys must be unique");
    }
}
