//! Sweep campaigns: scenario filtering, matrix execution, and perf
//! baselines — the machinery behind `repro campaign` and the CI
//! `campaign-gate` job.
//!
//! The serving stack sweeps a (policy × workload × backend × rate) grid;
//! this module turns those sweeps from ad-hoc CLI flag combinations into
//! a tracked perf trajectory:
//!
//! * [`filter`] — a small scenario-filter expression language (`&`, `|`,
//!   `!`, parens; atoms like `policy(slo-aware)`, `class(chat)`,
//!   `backend(event)`, `tier(gpu)`, `rate > 5`), lexed and parsed by
//!   hand into an AST evaluated as set algebra over scenario attributes.
//! * [`runner`] — [`CampaignSpec`] expands the matrix in canonical order
//!   (optionally over a fleet axis, e.g. `8xflash` vs `4xflash+1xgpu`)
//!   and [`run_campaign`] executes the filtered selection on the shared
//!   scoped-thread scaffold, one deterministic [`SweepPoint`] per
//!   scenario.
//! * [`report`] — renders outcomes as the human table and as the
//!   canonical, deterministically-ordered `BENCH_serving.json` metrics
//!   document (names like `campaign/chat/slo-aware/event/r8/ttft_p95_s`;
//!   fleet campaigns key as
//!   `campaign/4xflash+1xgpu/chat/tier-aware/event/r8/cost_per_mtok_usd`).
//! * [`baseline`] — diffs a fresh document against the committed
//!   `bench/BENCH_serving.baseline.json` under direction-aware relative
//!   tolerances and gates: any regression makes the CLI exit non-zero,
//!   which is the CI regression gate.
//!
//! The workflow (details in `docs/CAMPAIGNS.md`): run
//! `repro campaign --filter '<expr>'` locally to measure a slice; CI runs
//! the full matrix with a fixed seed and compares against the committed
//! baseline; intentional perf changes refresh it via
//! `make campaign-update-baseline`.
//!
//! [`SweepPoint`]: crate::coordinator::SweepPoint

pub mod baseline;
pub mod filter;
pub mod report;
pub mod runner;

pub use baseline::{BaselineDiff, diff_metrics, DiffRow, Direction, direction_of, Verdict};
pub use filter::{AtomKey, CmpOp, Expr, ParseError, ScenarioView};
pub use report::{campaign_metrics, render_campaign, scenario_key};
pub use runner::{Backend, CampaignOutcome, CampaignSpec, DEFAULT_RATES, run_campaign, Scenario};
