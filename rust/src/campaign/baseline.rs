//! Baseline comparison: diff a fresh campaign metrics document against
//! the committed `bench/BENCH_serving.baseline.json` and gate on
//! regressions.
//!
//! Every metric name maps to a [`Direction`] — whether bigger is better
//! (throughput, SLO attainment, accepted), worse (latency percentiles,
//! rejections), or neither (wall-clock and other informational metrics,
//! which never gate: CI runners are noisy, the simulation is not). A
//! relative tolerance absorbs cross-platform float-ulp drift; beyond it,
//! a change in the bad direction is a [`Verdict::Regress`] and
//! [`BaselineDiff::gate`] returns an error, which is what makes
//! `repro campaign` exit non-zero and the CI `campaign-gate` job fail.

use crate::util::benchkit::Metric;
use crate::util::table::Table;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Which way a metric is allowed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherBetter,
    LowerBetter,
    /// Never gates (wall-clock timings, counters without a preference).
    Info,
}

/// Classify a metric by its name. Unknown names are informational — a
/// new metric kind must be classified here before it can gate.
pub fn direction_of(name: &str) -> Direction {
    if name.ends_with("_wall_s") || name == "campaign_scenarios" {
        return Direction::Info;
    }
    if name.contains("/slo/")
        || name.ends_with("/accepted")
        || name.ends_with("/throughput_tok_s")
        || name.ends_with("/faults_availability")
    {
        return Direction::HigherBetter;
    }
    if name.ends_with("/rejected")
        || name.ends_with("/ttft_p95_s")
        || name.ends_with("/lat_p50_s")
        || name.ends_with("/lat_p95_s")
        || name.ends_with("/lat_p99_s")
        || name.ends_with("/cost_per_mtok_usd")
        || name.ends_with("/energy_per_mtok_j")
        || name.ends_with("/wear_max_erases")
        || name.ends_with("/wear_total_erases")
        || name.ends_with("/wear_retirements")
        || name.ends_with("/faults_failed")
        || name.ends_with("/faults_shed")
        || name.ends_with("/faults_degraded_s")
    {
        return Direction::LowerBetter;
    }
    Direction::Info
}

/// Outcome of comparing one metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within tolerance (or an informational metric present on both sides).
    Pass,
    /// Beyond tolerance in the bad direction.
    Regress,
    /// Beyond tolerance in the good direction.
    Improve,
    /// In the current run but not the baseline (does not gate).
    New,
    /// In the baseline but not the current run — a scenario vanished.
    Missing,
}

impl Verdict {
    pub fn as_str(self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Regress => "REGRESS",
            Verdict::Improve => "improve",
            Verdict::New => "new",
            Verdict::Missing => "MISSING",
        }
    }
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub name: String,
    pub baseline: Option<f64>,
    pub current: Option<f64>,
    /// Signed relative change `(cur - base) / |base|`; `None` when either
    /// side is absent or non-finite.
    pub rel: Option<f64>,
    pub verdict: Verdict,
}

/// The full comparison of a campaign run against a baseline.
#[derive(Debug, Clone)]
pub struct BaselineDiff {
    pub rows: Vec<DiffRow>,
    /// Relative tolerance the verdicts were computed under.
    pub rel_tol: f64,
}

/// Compare `current` against `baseline` under a relative tolerance.
/// Rows come out in current-document order (the canonical scenario
/// order), with baseline-only metrics appended as [`Verdict::Missing`].
/// When `ignore_missing` is set, baseline-only metrics pass instead — the
/// right semantics for a `--filter`ed partial run, where most of the
/// baseline is deliberately not re-measured.
pub fn diff_metrics(
    current: &[Metric],
    baseline: &[Metric],
    rel_tol: f64,
    ignore_missing: bool,
) -> BaselineDiff {
    let base_by_name: BTreeMap<&str, f64> =
        baseline.iter().map(|m| (m.name.as_str(), m.value)).collect();
    let mut seen: std::collections::BTreeSet<&str> = Default::default();
    let mut rows = Vec::with_capacity(current.len());
    for m in current {
        seen.insert(m.name.as_str());
        let base = base_by_name.get(m.name.as_str()).copied();
        rows.push(compare(&m.name, base, Some(m.value), rel_tol));
    }
    for m in baseline {
        if !seen.contains(m.name.as_str()) {
            let verdict = if ignore_missing || direction_of(&m.name) == Direction::Info {
                Verdict::Pass
            } else {
                Verdict::Missing
            };
            rows.push(DiffRow {
                name: m.name.clone(),
                baseline: Some(m.value),
                current: None,
                rel: None,
                verdict,
            });
        }
    }
    BaselineDiff { rows, rel_tol }
}

fn compare(name: &str, base: Option<f64>, cur: Option<f64>, rel_tol: f64) -> DiffRow {
    let direction = direction_of(name);
    let (verdict, rel) = match (base, cur) {
        (None, Some(_)) => (Verdict::New, None),
        (Some(b), Some(c)) => {
            if direction == Direction::Info {
                (Verdict::Pass, rel_change(b, c))
            } else {
                match rel_change(b, c) {
                    // Non-finite on either side: only an exact bitwise
                    // match (e.g. NaN == NaN encodings both null) passes.
                    None => {
                        let same = b.to_bits() == c.to_bits() || (b.is_nan() && c.is_nan());
                        let v = if same { Verdict::Pass } else { Verdict::Regress };
                        (v, None)
                    }
                    Some(r) if r.abs() <= rel_tol => (Verdict::Pass, Some(r)),
                    Some(r) => {
                        let worse = match direction {
                            Direction::HigherBetter => r < 0.0,
                            Direction::LowerBetter => r > 0.0,
                            Direction::Info => unreachable!("handled above"),
                        };
                        let v = if worse { Verdict::Regress } else { Verdict::Improve };
                        (v, Some(r))
                    }
                }
            }
        }
        // `compare` is only called with a current value; (_, None) rows
        // are built by the caller.
        (_, None) => (Verdict::Missing, None),
    };
    DiffRow { name: name.to_string(), baseline: base, current: cur, rel, verdict }
}

/// Signed relative change, `None` when it cannot be computed finitely.
/// A zero baseline with a zero current is 0; zero → nonzero is infinite
/// change and comes back as `None` only if non-finite — here it returns
/// a large sentinel via division by a tiny floor instead, so appearing
/// rejections (0 → n) still register as a real change.
fn rel_change(base: f64, cur: f64) -> Option<f64> {
    if !base.is_finite() || !cur.is_finite() {
        return None;
    }
    if base == cur {
        return Some(0.0);
    }
    Some((cur - base) / base.abs().max(1e-12))
}

impl BaselineDiff {
    pub fn regressions(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| matches!(r.verdict, Verdict::Regress | Verdict::Missing))
            .count()
    }

    pub fn improvements(&self) -> usize {
        self.rows.iter().filter(|r| r.verdict == Verdict::Improve).count()
    }

    /// Error (→ non-zero process exit) when any metric regressed.
    pub fn gate(&self) -> Result<()> {
        let n = self.regressions();
        if n > 0 {
            bail!(
                "{n} metric(s) regressed beyond {:.2}% relative tolerance (see table above); \
                 if intentional, refresh the baseline with `make campaign-update-baseline`",
                self.rel_tol * 100.0
            );
        }
        Ok(())
    }

    /// Render the pass/regress/improve table. `verbose` includes every
    /// row; otherwise pass and new rows are summarized in the header
    /// line and only regressions, improvements, and missing metrics are
    /// listed (a fresh-bootstrap comparison would otherwise print one
    /// `new` row per metric).
    pub fn render(&self, verbose: bool) -> String {
        let count = |v: Verdict| self.rows.iter().filter(|r| r.verdict == v).count();
        let mut t = Table::new(&["metric", "baseline", "current", "change", "verdict"]);
        let mut listed = 0usize;
        for r in &self.rows {
            if !verbose && matches!(r.verdict, Verdict::Pass | Verdict::New) {
                continue;
            }
            listed += 1;
            let fmt = |v: Option<f64>| match v {
                Some(x) => format!("{x:.6e}"),
                None => "-".to_string(),
            };
            t.row(&[
                r.name.clone(),
                fmt(r.baseline),
                fmt(r.current),
                match r.rel {
                    Some(rel) => format!("{:+.2}%", rel * 100.0),
                    None => "-".to_string(),
                },
                r.verdict.as_str().to_string(),
            ]);
        }
        let mut out = format!(
            "baseline comparison (relative tolerance {:.2}%): {} pass, {} regressed, {} \
             improved, {} new, {} missing\n",
            self.rel_tol * 100.0,
            count(Verdict::Pass),
            count(Verdict::Regress),
            count(Verdict::Improve),
            count(Verdict::New),
            count(Verdict::Missing),
        );
        if listed > 0 {
            out.push_str(&t.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(name: &str, value: f64) -> Metric {
        Metric { name: name.to_string(), value, unit: "x".to_string() }
    }

    #[test]
    fn directions_classify_by_suffix() {
        let up = Direction::HigherBetter;
        let down = Direction::LowerBetter;
        assert_eq!(direction_of("campaign/chat/slo-aware/event/r8/slo/chat"), up);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/throughput_tok_s"), up);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/accepted"), up);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/ttft_p95_s"), down);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/lat_p99_s"), down);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/rejected"), down);
        assert_eq!(
            direction_of("campaign/4xflash+1xgpu/chat/tier-aware/event/r8/cost_per_mtok_usd"),
            down
        );
        assert_eq!(
            direction_of("campaign/4xflash+1xgpu/chat/tier-aware/event/r8/energy_per_mtok_j"),
            down
        );
        assert_eq!(direction_of("campaign/chat/wear-aware/event/r8/wear_max_erases"), down);
        assert_eq!(direction_of("campaign/chat/wear-aware/event/r8/wear_total_erases"), down);
        assert_eq!(direction_of("campaign/chat/wear-aware/event/r8/wear_retirements"), down);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/faults_availability"), up);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/faults_failed"), down);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/faults_shed"), down);
        assert_eq!(direction_of("campaign/chat/ll/event/r8/faults_degraded_s"), down);
        assert_eq!(
            direction_of("campaign/chat/ll/event/r8/faults_retries"),
            Direction::Info,
            "retry counts are informational, not gated"
        );
        assert_eq!(direction_of("campaign_wall_s"), Direction::Info);
        assert_eq!(direction_of("sweep_frontier_wall_s"), Direction::Info);
        assert_eq!(direction_of("campaign_scenarios"), Direction::Info);
        assert_eq!(direction_of("something_else_entirely"), Direction::Info);
    }

    #[test]
    fn identical_documents_diff_clean() {
        let cur = vec![m("a/ttft_p95_s", 0.5), m("a/slo/chat", 0.99), m("campaign_wall_s", 3.0)];
        let d = diff_metrics(&cur, &cur.clone(), 0.01, false);
        assert_eq!(d.regressions(), 0);
        assert_eq!(d.improvements(), 0);
        assert!(d.gate().is_ok());
        assert!(d.rows.iter().all(|r| r.verdict == Verdict::Pass));
        let brief = d.render(false);
        assert!(brief.contains("3 pass, 0 regressed"), "{brief}");
        assert!(!brief.contains("a/ttft_p95_s"), "passing rows stay out of the table: {brief}");
        assert!(d.render(true).contains("a/ttft_p95_s"));
    }

    #[test]
    fn regressions_respect_direction_and_tolerance() {
        let base =
            vec![m("a/ttft_p95_s", 1.0), m("a/slo/chat", 1.0), m("a/throughput_tok_s", 100.0)];
        // Latency up 5%, attainment down 5%, throughput up 5%.
        let cur =
            vec![m("a/ttft_p95_s", 1.05), m("a/slo/chat", 0.95), m("a/throughput_tok_s", 105.0)];
        let d = diff_metrics(&cur, &base, 0.02, false);
        assert_eq!(d.regressions(), 2, "latency up and attainment down regress");
        assert_eq!(d.improvements(), 1, "throughput up improves");
        assert!(d.gate().is_err());
        let msg = d.gate().unwrap_err().to_string();
        assert!(msg.contains("campaign-update-baseline"), "{msg}");
        let table = d.render(false);
        assert!(table.contains("REGRESS") && table.contains("improve"), "{table}");

        // The same deltas inside a 10% tolerance all pass.
        let d = diff_metrics(&cur, &base, 0.10, false);
        assert_eq!(d.regressions(), 0);
        assert!(d.gate().is_ok());
    }

    #[test]
    fn wall_clock_metrics_never_gate() {
        let base = vec![m("campaign_wall_s", 1.0)];
        let cur = vec![m("campaign_wall_s", 50.0)];
        let d = diff_metrics(&cur, &base, 0.01, false);
        assert_eq!(d.regressions(), 0, "wall-clock is informational");
        assert!(d.gate().is_ok());
    }

    #[test]
    fn missing_and_new_metrics() {
        let base = vec![m("a/ttft_p95_s", 1.0), m("b/ttft_p95_s", 1.0), m("old_wall_s", 2.0)];
        let cur = vec![m("a/ttft_p95_s", 1.0), m("c/ttft_p95_s", 1.0)];
        let d = diff_metrics(&cur, &base, 0.01, false);
        let verdict = |name: &str| d.rows.iter().find(|r| r.name == name).unwrap().verdict;
        assert_eq!(verdict("b/ttft_p95_s"), Verdict::Missing, "vanished scenarios gate");
        assert_eq!(verdict("c/ttft_p95_s"), Verdict::New, "new metrics do not gate");
        assert_eq!(verdict("old_wall_s"), Verdict::Pass, "info metrics may vanish freely");
        assert_eq!(d.regressions(), 1);
        assert!(d.gate().is_err());

        // A filtered partial run ignores the unmeasured remainder.
        let d = diff_metrics(&cur, &base, 0.01, true);
        assert_eq!(d.regressions(), 0);
        assert!(d.gate().is_ok());
    }

    #[test]
    fn zero_baselines_still_register_change() {
        let base = vec![m("a/rejected", 0.0)];
        let cur = vec![m("a/rejected", 3.0)];
        let d = diff_metrics(&cur, &base, 0.05, false);
        assert_eq!(d.regressions(), 1, "rejections appearing from zero is a regression");
        let d = diff_metrics(&base, &base.clone(), 0.05, false);
        assert_eq!(d.regressions(), 0, "0 == 0 passes");
    }

    #[test]
    fn non_finite_values_only_pass_when_identical() {
        let nan = || vec![m("a/ttft_p95_s", f64::NAN)];
        let d = diff_metrics(&nan(), &nan(), 0.01, false);
        assert_eq!(d.regressions(), 0);
        let d = diff_metrics(&nan(), &[m("a/ttft_p95_s", 1.0)], 0.01, false);
        assert_eq!(d.regressions(), 1);
    }
}
