//! Initial KV-cache write overhead and break-even analysis (paper §IV-B):
//! moving the GPU-computed KV of the input tokens into the SLC region
//! costs ~120 ms for W8A8 OPT-30B at 1K tokens; generating ≥12 tokens
//! amortizes it against the per-token win over 4×RTX4090.

use crate::config::SystemConfig;
use crate::llm::model_config::ModelShape;

/// Sustained SLC sequential-write bandwidth of the device (bytes/s).
/// Paper [19]: commercial SLC NAND sustains 4.8–6 GB/s.
pub const SLC_SEQ_WRITE_BW: f64 = 5.87e9;

/// Time to land the initial KV cache of `tokens` input tokens, limited by
/// the lesser of the channel-aggregate bus and SLC program throughput.
pub fn initial_kv_write_time(sys: &SystemConfig, model: &ModelShape, tokens: usize) -> f64 {
    let bytes = model.kv_bytes(tokens, 1.0);
    let channel_bw = sys.org.channels as f64 * sys.ctrl.channel_bus_bw;
    let bw = channel_bw.min(SLC_SEQ_WRITE_BW);
    bytes / bw
}

/// Tokens needed to amortize the initial write given the per-token
/// advantage over the GPU baseline.
pub fn break_even_tokens(write_time: f64, tpot_gpu: f64, tpot_flash: f64) -> usize {
    assert!(tpot_gpu > tpot_flash, "flash must win per-token to break even");
    (write_time / (tpot_gpu - tpot_flash)).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    #[test]
    fn opt30b_1k_write_near_120ms() {
        // Paper §IV-B: "the initial KV cache write for W8A8 OPT-30B with
        // 1K input tokens can be completed in 120 ms".
        let t = initial_kv_write_time(&table1_system(), &OptModel::Opt30b.shape(), 1024);
        assert!((0.10..=0.14).contains(&t), "write time = {t:.3} s");
    }

    #[test]
    fn break_even_near_12_tokens() {
        // Paper §IV-B: 10 ms per-token win → >12 tokens amortize 120 ms.
        let n = break_even_tokens(0.120, 17.0e-3, 7.0e-3);
        assert_eq!(n, 12);
    }

    #[test]
    fn write_time_scales_with_tokens() {
        let sys = table1_system();
        let m = OptModel::Opt30b.shape();
        let t1 = initial_kv_write_time(&sys, &m, 1024);
        let t2 = initial_kv_write_time(&sys, &m, 2048);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "flash must win")]
    fn break_even_requires_advantage() {
        break_even_tokens(0.1, 5e-3, 7e-3);
    }
}
