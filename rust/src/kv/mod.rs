//! KV-cache management in the SLC region (paper §IV-B, Fig. 10d): layout
//! and append path, the initial-KV write-overhead analysis, and the
//! endurance / lifetime projection under retention-relaxed management.

pub mod cache;
pub mod lifetime;
pub mod wear;
pub mod write_overhead;

pub use cache::KvCacheManager;
pub use lifetime::{lifetime_years, LifetimeReport};
pub use wear::WearLeveler;
pub use write_overhead::{break_even_tokens, initial_kv_write_time};
