//! Wear-leveling allocator for the SLC KV region (paper §IV-B relies on
//! WARM-style retention-relaxed management [17]; this is the block
//! allocator that spreads the KV append stream across the region so the
//! 50×-relaxed P/E budget is consumed evenly).

use crate::sim::SimTime;

/// One erase block's wear state.
#[derive(Debug, Clone, Copy, Default)]
struct BlockWear {
    erases: u64,
    /// Allocation epoch of the current data (for retention checks).
    written_at: SimTime,
    live: bool,
}

/// Round-robin wear-leveling allocator over `blocks` erase blocks.
#[derive(Debug)]
pub struct WearLeveler {
    blocks: Vec<BlockWear>,
    cursor: usize,
    /// Endurance budget per block (relaxed P/E cycles).
    pub pe_budget: u64,
    /// Maximum retention age before data must be refreshed.
    pub retention: SimTime,
}

impl WearLeveler {
    pub fn new(blocks: usize, pe_budget: u64, retention: SimTime) -> WearLeveler {
        assert!(blocks > 0);
        WearLeveler {
            blocks: vec![BlockWear::default(); blocks],
            cursor: 0,
            pe_budget,
            retention,
        }
    }

    /// Allocate the next block for writing at time `now`; erases it if it
    /// held stale data. Returns `None` when every block exhausted its
    /// budget (end of device life).
    pub fn allocate(&mut self, now: SimTime) -> Option<usize> {
        for _ in 0..self.blocks.len() {
            let idx = self.cursor;
            self.cursor = (self.cursor + 1) % self.blocks.len();
            let b = &mut self.blocks[idx];
            if b.erases < self.pe_budget {
                if b.live {
                    b.erases += 1; // erase-before-write
                }
                b.live = true;
                b.written_at = now;
                return Some(idx);
            }
        }
        None
    }

    /// Free a block (sequence finished; its KV is dropped). Releasing an
    /// index that was never allocated (or is out of range) is a no-op —
    /// callers fold eviction streams through here without tracking which
    /// allocations are still live.
    pub fn release(&mut self, idx: usize) {
        if let Some(b) = self.blocks.get_mut(idx) {
            b.live = false;
        }
    }

    /// Blocks whose data exceeded the relaxed retention window and must
    /// be refreshed (re-written elsewhere) — the WARM management action.
    pub fn stale_blocks(&self, now: SimTime) -> Vec<usize> {
        self.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.live && now.saturating_sub(b.written_at) > self.retention)
            .map(|(i, _)| i)
            .collect()
    }

    /// Max / min erase counts — the wear-leveling quality metric.
    pub fn wear_spread(&self) -> (u64, u64) {
        let max = self.blocks.iter().map(|b| b.erases).max().unwrap_or(0);
        let min = self.blocks.iter().map(|b| b.erases).min().unwrap_or(0);
        (min, max)
    }

    /// Total erases performed.
    pub fn total_erases(&self) -> u64 {
        self.blocks.iter().map(|b| b.erases).sum()
    }

    pub fn exhausted(&self) -> bool {
        self.blocks.iter().all(|b| b.erases >= self.pe_budget)
    }
}

/// Analytic erase count for `allocations` round-robin allocations over
/// `blocks` blocks of `pe_budget` erases each when no block is ever
/// released (the serving append stream): the first pass programs free
/// blocks without erasing, every later allocation erases exactly one
/// block, and the total saturates at the device's erase capacity — the
/// conservation law the wear test suite checks fleet totals against.
pub fn expected_erases(allocations: u64, blocks: u64, pe_budget: u64) -> u64 {
    allocations.saturating_sub(blocks).min(blocks * pe_budget)
}

/// Per-device serving wear meter: KV token programs and bytes written,
/// folded into erases through a [`WearLeveler`] at erase-block
/// granularity. Both serving backends charge the same meter from the
/// same admission bookkeeping, so fleet wear totals agree bit-for-bit
/// across the event and direct backends.
#[derive(Debug)]
pub struct DeviceWear {
    leveler: WearLeveler,
    /// Bytes per erase block (device KV capacity / block count).
    pub block_bytes: u64,
    /// Bytes written but not yet amounting to a full block.
    carry: u64,
    /// KV token programs charged (one per token written).
    pub programs: u64,
    /// Total KV bytes written.
    pub bytes_written: u64,
    /// Idle-session evictions charged against this device.
    pub evictions: u64,
    /// Simulated time at which the P/E budget exhausted, if it did.
    pub retired_at: Option<SimTime>,
}

impl DeviceWear {
    /// Retention plays no role in the serving wear meter, so the leveler
    /// gets one it can never exceed.
    pub fn new(blocks: usize, pe_budget: u64, block_bytes: u64) -> DeviceWear {
        DeviceWear {
            leveler: WearLeveler::new(blocks.max(1), pe_budget, SimTime(u64::MAX)),
            block_bytes: block_bytes.max(1),
            carry: 0,
            programs: 0,
            bytes_written: 0,
            evictions: 0,
            retired_at: None,
        }
    }

    /// Charge `tokens` KV token writes totalling `bytes` at time `now`.
    /// Whole erase blocks' worth of bytes are allocated through the
    /// leveler (erase-before-write past the first pass); the remainder
    /// carries to the next charge. Returns `true` when this charge
    /// exhausted the device's erase budget.
    pub fn charge(&mut self, tokens: u64, bytes: u64, now: SimTime) -> bool {
        let was_exhausted = self.exhausted();
        self.programs += tokens;
        self.bytes_written += bytes;
        self.carry += bytes;
        while self.carry >= self.block_bytes {
            self.carry -= self.block_bytes;
            let _ = self.leveler.allocate(now);
        }
        !was_exhausted && self.exhausted()
    }

    /// Record an idle-session KV eviction. The freed blocks are erased
    /// lazily on reallocation (the leveler's erase-before-write), so no
    /// budget is charged here — evictions are reported, not priced.
    pub fn note_eviction(&mut self) {
        self.evictions += 1;
    }

    /// Total erases charged so far.
    pub fn erases(&self) -> u64 {
        self.leveler.total_erases()
    }

    /// Mark the device retired at `now` (first retirement wins — a
    /// device leaves the pool once, whether by wear or by fault).
    pub fn retire(&mut self, now: SimTime) {
        if self.retired_at.is_none() {
            self.retired_at = Some(now);
        }
    }

    pub fn exhausted(&self) -> bool {
        self.leveler.exhausted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_levels_wear() {
        let mut w = WearLeveler::new(8, 1000, SimTime::from_secs(259_200.0));
        for i in 0..8_000 {
            let idx = w.allocate(SimTime(i)).unwrap();
            // Immediately release so blocks recycle.
            w.release(idx);
        }
        let (min, max) = w.wear_spread();
        assert!(max - min <= 1, "uneven wear: {min}..{max}");
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut w = WearLeveler::new(2, 3, SimTime::from_secs(1.0));
        let mut allocs = 0;
        while w.allocate(SimTime(allocs)).is_some() {
            allocs += 1;
            assert!(allocs < 100);
        }
        assert!(w.exhausted());
        // 2 blocks × 3 P/E (+ the first free write per block).
        assert!(allocs >= 6);
    }

    #[test]
    fn retention_flags_stale_blocks() {
        let retention = SimTime::from_secs(3.0 * 24.0 * 3600.0); // 3 days
        let mut w = WearLeveler::new(4, 1000, retention);
        let b0 = w.allocate(SimTime::ZERO).unwrap();
        let _b1 = w.allocate(SimTime::from_secs(200_000.0)).unwrap();
        let now = SimTime::from_secs(300_000.0); // b0 is 3.47 days old
        let stale = w.stale_blocks(now);
        assert_eq!(stale, vec![b0]);
    }

    #[test]
    fn zero_pe_budget_is_exhausted_from_birth() {
        let mut w = WearLeveler::new(4, 0, SimTime::from_secs(1.0));
        assert!(w.exhausted(), "no budget means no usable blocks");
        assert_eq!(w.allocate(SimTime::ZERO), None, "even the first free write is refused");
        assert_eq!(w.total_erases(), 0);
    }

    #[test]
    fn allocate_after_exhaustion_stays_none_and_charges_nothing() {
        let mut w = WearLeveler::new(2, 1, SimTime::from_secs(1.0));
        while w.allocate(SimTime::ZERO).is_some() {}
        assert!(w.exhausted());
        let before = w.total_erases();
        for _ in 0..10 {
            assert_eq!(w.allocate(SimTime(5)), None);
        }
        assert_eq!(w.total_erases(), before, "post-exhaustion attempts never erase");
    }

    #[test]
    fn release_of_never_allocated_block_is_a_no_op() {
        let mut w = WearLeveler::new(2, 10, SimTime::from_secs(1.0));
        w.release(0); // in range, never allocated
        w.release(99); // out of range entirely
        let idx = w.allocate(SimTime::ZERO).unwrap();
        w.release(idx);
        assert_eq!(w.total_erases(), 0);
        // The released block recycles without an erase (it is not live).
        w.release(idx);
        assert_eq!(w.total_erases(), 0);
    }

    #[test]
    fn retention_boundary_is_exclusive() {
        let retention = SimTime::from_secs(3.0);
        let mut w = WearLeveler::new(2, 10, retention);
        let b = w.allocate(SimTime::ZERO).unwrap();
        // Exactly at the retention age: not yet stale (strict `>`).
        assert!(w.stale_blocks(SimTime::from_secs(3.0)).is_empty());
        // One picosecond past it: stale.
        assert_eq!(w.stale_blocks(SimTime(SimTime::from_secs(3.0).0 + 1)), vec![b]);
    }

    #[test]
    fn device_wear_charges_block_granular_erases() {
        let mut d = DeviceWear::new(4, 1000, 100);
        // 250 bytes = 2 whole blocks + 50 carried.
        assert!(!d.charge(25, 250, SimTime::ZERO));
        assert_eq!(d.programs, 25);
        assert_eq!(d.bytes_written, 250);
        assert_eq!(d.erases(), expected_erases(2, 4, 1000));
        // 350 more: carry reaches 400 total → 4 blocks allocated overall.
        assert!(!d.charge(35, 350, SimTime::ZERO));
        assert_eq!(d.erases(), expected_erases(6, 4, 1000));
        assert_eq!(d.erases(), 2, "first pass over 4 blocks is erase-free");
        assert!(!d.exhausted());
        d.note_eviction();
        assert_eq!(d.evictions, 1);
    }

    #[test]
    fn device_wear_reports_exhaustion_exactly_once() {
        let mut d = DeviceWear::new(2, 2, 10);
        // Capacity: 2 blocks × 2 P/E + 2 free first writes = 6 allocations.
        assert!(!d.charge(1, 50, SimTime::ZERO)); // 5 allocations
        assert!(d.charge(1, 10, SimTime::ZERO), "6th allocation exhausts");
        assert!(d.exhausted());
        assert!(!d.charge(1, 10, SimTime::ZERO), "already exhausted: not newly so");
        assert_eq!(d.erases(), expected_erases(7, 2, 2));
        assert_eq!(d.erases(), 4, "erases saturate at blocks × budget");
    }

    #[test]
    fn expected_erases_formula_edges() {
        assert_eq!(expected_erases(0, 4, 10), 0);
        assert_eq!(expected_erases(4, 4, 10), 0, "first pass is free");
        assert_eq!(expected_erases(5, 4, 10), 1);
        assert_eq!(expected_erases(1000, 4, 10), 40, "caps at capacity");
        assert_eq!(expected_erases(1000, 4, 0), 0, "zero budget never erases");
    }

    #[test]
    fn fresh_blocks_dont_erase() {
        let mut w = WearLeveler::new(4, 10, SimTime::from_secs(1e6));
        for _ in 0..4 {
            w.allocate(SimTime::ZERO).unwrap();
        }
        // First write to each block needs no erase.
        assert_eq!(w.total_erases(), 0);
        // Second round erases.
        w.allocate(SimTime(1)).unwrap();
        assert_eq!(w.total_erases(), 1);
    }
}
