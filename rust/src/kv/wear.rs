//! Wear-leveling allocator for the SLC KV region (paper §IV-B relies on
//! WARM-style retention-relaxed management [17]; this is the block
//! allocator that spreads the KV append stream across the region so the
//! 50×-relaxed P/E budget is consumed evenly).

use crate::sim::SimTime;

/// One erase block's wear state.
#[derive(Debug, Clone, Copy, Default)]
struct BlockWear {
    erases: u64,
    /// Allocation epoch of the current data (for retention checks).
    written_at: SimTime,
    live: bool,
}

/// Round-robin wear-leveling allocator over `blocks` erase blocks.
#[derive(Debug)]
pub struct WearLeveler {
    blocks: Vec<BlockWear>,
    cursor: usize,
    /// Endurance budget per block (relaxed P/E cycles).
    pub pe_budget: u64,
    /// Maximum retention age before data must be refreshed.
    pub retention: SimTime,
}

impl WearLeveler {
    pub fn new(blocks: usize, pe_budget: u64, retention: SimTime) -> WearLeveler {
        assert!(blocks > 0);
        WearLeveler {
            blocks: vec![BlockWear::default(); blocks],
            cursor: 0,
            pe_budget,
            retention,
        }
    }

    /// Allocate the next block for writing at time `now`; erases it if it
    /// held stale data. Returns `None` when every block exhausted its
    /// budget (end of device life).
    pub fn allocate(&mut self, now: SimTime) -> Option<usize> {
        for _ in 0..self.blocks.len() {
            let idx = self.cursor;
            self.cursor = (self.cursor + 1) % self.blocks.len();
            let b = &mut self.blocks[idx];
            if b.erases < self.pe_budget {
                if b.live {
                    b.erases += 1; // erase-before-write
                }
                b.live = true;
                b.written_at = now;
                return Some(idx);
            }
        }
        None
    }

    /// Free a block (sequence finished; its KV is dropped).
    pub fn release(&mut self, idx: usize) {
        self.blocks[idx].live = false;
    }

    /// Blocks whose data exceeded the relaxed retention window and must
    /// be refreshed (re-written elsewhere) — the WARM management action.
    pub fn stale_blocks(&self, now: SimTime) -> Vec<usize> {
        self.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.live && now.saturating_sub(b.written_at) > self.retention)
            .map(|(i, _)| i)
            .collect()
    }

    /// Max / min erase counts — the wear-leveling quality metric.
    pub fn wear_spread(&self) -> (u64, u64) {
        let max = self.blocks.iter().map(|b| b.erases).max().unwrap_or(0);
        let min = self.blocks.iter().map(|b| b.erases).min().unwrap_or(0);
        (min, max)
    }

    /// Total erases performed.
    pub fn total_erases(&self) -> u64 {
        self.blocks.iter().map(|b| b.erases).sum()
    }

    pub fn exhausted(&self) -> bool {
        self.blocks.iter().all(|b| b.erases >= self.pe_budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_levels_wear() {
        let mut w = WearLeveler::new(8, 1000, SimTime::from_secs(259_200.0));
        for i in 0..8_000 {
            let idx = w.allocate(SimTime(i)).unwrap();
            // Immediately release so blocks recycle.
            w.release(idx);
        }
        let (min, max) = w.wear_spread();
        assert!(max - min <= 1, "uneven wear: {min}..{max}");
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut w = WearLeveler::new(2, 3, SimTime::from_secs(1.0));
        let mut allocs = 0;
        while w.allocate(SimTime(allocs)).is_some() {
            allocs += 1;
            assert!(allocs < 100);
        }
        assert!(w.exhausted());
        // 2 blocks × 3 P/E (+ the first free write per block).
        assert!(allocs >= 6);
    }

    #[test]
    fn retention_flags_stale_blocks() {
        let retention = SimTime::from_secs(3.0 * 24.0 * 3600.0); // 3 days
        let mut w = WearLeveler::new(4, 1000, retention);
        let b0 = w.allocate(SimTime::ZERO).unwrap();
        let _b1 = w.allocate(SimTime::from_secs(200_000.0)).unwrap();
        let now = SimTime::from_secs(300_000.0); // b0 is 3.47 days old
        let stale = w.stale_blocks(now);
        assert_eq!(stale, vec![b0]);
    }

    #[test]
    fn fresh_blocks_dont_erase() {
        let mut w = WearLeveler::new(4, 10, SimTime::from_secs(1e6));
        for _ in 0..4 {
            w.allocate(SimTime::ZERO).unwrap();
        }
        // First write to each block needs no erase.
        assert_eq!(w.total_erases(), 0);
        // Second round erases.
        w.allocate(SimTime(1)).unwrap();
        assert_eq!(w.total_erases(), 1);
    }
}
