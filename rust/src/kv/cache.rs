//! KV-cache layout and append path in the SLC region (paper Fig. 10d):
//! the initial KV arrives from the GPU over PCIe, per-token `k`/`v`
//! vectors append during generation, and reads stripe across the SLC
//! planes for dMVM.

use crate::config::SystemConfig;
use crate::llm::model_config::ModelShape;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// One sequence's cache state.
#[derive(Debug, Clone)]
pub struct SequenceCache {
    pub seq_id: u64,
    /// Tokens currently cached.
    pub tokens: usize,
    /// Bytes consumed in the SLC region.
    pub bytes: u64,
}

/// Manager for the SLC KV region. Sequences are indexed by id so the
/// serving simulator's per-turn admit/append/evict traffic stays O(1)
/// even with thousands of resident sessions.
pub struct KvCacheManager {
    /// Usable SLC capacity (bytes).
    pub capacity: u64,
    /// KV bytes per token for the bound model.
    pub per_token: u64,
    used: u64,
    sequences: HashMap<u64, SequenceCache>,
    /// Cumulative bytes ever written (endurance accounting).
    total_written: u64,
}

impl KvCacheManager {
    pub fn new(sys: &SystemConfig, model: &ModelShape) -> KvCacheManager {
        let slc_dies =
            (sys.org.channels * sys.org.ways_per_channel * sys.org.slc_dies_per_way) as u64;
        let plane_bytes = (sys.plane.n_row * sys.plane.n_col * sys.plane.n_stack) as u64 / 8; // SLC: 1 bit/cell
        let capacity = slc_dies * sys.org.planes_per_die as u64 * plane_bytes;
        KvCacheManager {
            capacity,
            per_token: model.kv_bytes_per_token(1.0) as u64,
            used: 0,
            sequences: HashMap::new(),
            total_written: 0,
        }
    }

    /// Manager over an explicit byte budget — used for non-flash tiers
    /// (a GPU device's KV pool is whatever VRAM is left after weights
    /// and workspace) where the SLC geometry math does not apply.
    pub fn with_capacity(capacity: u64, per_token: u64) -> KvCacheManager {
        KvCacheManager {
            capacity,
            per_token,
            used: 0,
            sequences: HashMap::new(),
            total_written: 0,
        }
    }

    /// Admit a sequence with `initial_tokens` of prefilled KV.
    pub fn admit(&mut self, seq_id: u64, initial_tokens: usize) -> Result<()> {
        let bytes = self.per_token * initial_tokens as u64;
        if self.used + bytes > self.capacity {
            bail!("KV region full: {} + {} > {}", self.used, bytes, self.capacity);
        }
        if self.sequences.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        self.used += bytes;
        self.total_written += bytes;
        self.sequences.insert(seq_id, SequenceCache { seq_id, tokens: initial_tokens, bytes });
        Ok(())
    }

    /// Append one generated token's k/v.
    pub fn append(&mut self, seq_id: u64) -> Result<()> {
        self.append_n(seq_id, 1)
    }

    /// Append `n` tokens' k/v in one reservation — the serving simulator
    /// books a whole turn (prompt extension + generated tokens) at once.
    pub fn append_n(&mut self, seq_id: u64, n: usize) -> Result<()> {
        let bytes = self.per_token * n as u64;
        if self.used + bytes > self.capacity {
            bail!("KV region full on append");
        }
        let seq = self
            .sequences
            .get_mut(&seq_id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq_id}"))?;
        seq.tokens += n;
        seq.bytes += bytes;
        self.used += bytes;
        self.total_written += bytes;
        Ok(())
    }

    /// Release a finished sequence, reclaiming its space.
    pub fn release(&mut self, seq_id: u64) -> Result<()> {
        let seq = self
            .sequences
            .remove(&seq_id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq_id}"))?;
        self.used -= seq.bytes;
        Ok(())
    }

    pub fn context_len(&self, seq_id: u64) -> Option<usize> {
        self.sequences.get(&seq_id).map(|s| s.tokens)
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn total_written(&self) -> u64 {
        self.total_written
    }

    pub fn active_sequences(&self) -> usize {
        self.sequences.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    fn mgr() -> KvCacheManager {
        KvCacheManager::new(&table1_system(), &OptModel::Opt30b.shape())
    }

    #[test]
    fn admit_append_release_conserves_space() {
        let mut m = mgr();
        assert_eq!(m.used(), 0);
        m.admit(1, 1024).unwrap();
        let after_admit = m.used();
        assert_eq!(after_admit, 1024 * m.per_token);
        for _ in 0..10 {
            m.append(1).unwrap();
        }
        assert_eq!(m.context_len(1), Some(1034));
        m.release(1).unwrap();
        assert_eq!(m.used(), 0);
        assert_eq!(m.active_sequences(), 0);
    }

    #[test]
    fn capacity_enforced() {
        let mut m = mgr();
        let max_tokens = (m.capacity / m.per_token) as usize;
        assert!(m.admit(1, max_tokens + 1).is_err());
        m.admit(2, max_tokens).unwrap();
        assert!(m.append(2).is_err());
    }

    #[test]
    fn append_n_books_a_whole_turn() {
        let mut m = mgr();
        m.admit(1, 100).unwrap();
        m.append_n(1, 25).unwrap();
        assert_eq!(m.context_len(1), Some(125));
        assert_eq!(m.used(), 125 * m.per_token);
        assert!(m.append_n(2, 1).is_err(), "unknown sequence must error");
        let room = ((m.capacity - m.used()) / m.per_token) as usize;
        assert!(m.append_n(1, room + 1).is_err(), "over-capacity bulk append must error");
    }

    #[test]
    fn double_admit_rejected() {
        let mut m = mgr();
        m.admit(1, 10).unwrap();
        assert!(m.admit(1, 10).is_err());
    }

    #[test]
    fn written_bytes_accumulate_past_release() {
        let mut m = mgr();
        m.admit(1, 100).unwrap();
        m.release(1).unwrap();
        m.admit(2, 100).unwrap();
        assert_eq!(m.total_written(), 200 * m.per_token);
    }

    #[test]
    fn slc_capacity_holds_long_contexts() {
        // The Table-I SLC region holds far more than one 2K-token context.
        let m = mgr();
        assert!(m.capacity / m.per_token > 10_000);
    }
}
