//! SLC endurance / lifetime projection (paper §IV-B, method of [18]):
//! with retention relaxed to ~3 days (the KV cache is write-hot — WARM
//! [17]), SLC P/E endurance rises ~50×, so continuous token generation
//! wears the region out only after decades — beyond the 5-year SSD
//! warranty.

use crate::config::{CellKind, SystemConfig};
use crate::llm::model_config::ModelShape;
use crate::nand::cell::CellParams;

/// Lifetime projection result.
#[derive(Debug, Clone, Copy)]
pub struct LifetimeReport {
    /// KV region capacity used for wear levelling (bytes).
    pub region_bytes: f64,
    /// Effective P/E cycles after retention relaxation.
    pub effective_pe: f64,
    /// Bytes written per second of continuous generation.
    pub write_rate: f64,
    /// Projected lifetime in years.
    pub years: f64,
}

/// Continuous-generation lifetime of a KV region of `region_bytes`.
pub fn lifetime_of_region(
    region_bytes: f64,
    model: &ModelShape,
    tpot: f64,
) -> LifetimeReport {
    let slc = CellParams::of(CellKind::Slc);
    let effective_pe = slc.relaxed_pe_cycles();
    let write_rate = model.kv_bytes_per_token(1.0) / tpot;
    let total_endurance_bytes = region_bytes * effective_pe;
    let seconds = total_endurance_bytes / write_rate;
    LifetimeReport { region_bytes, effective_pe, write_rate, years: seconds / (365.25 * 24.0 * 3600.0) }
}

/// Lifetime projection from an observed serving trace rather than a
/// continuous-generation assumption: `capacity_bytes × pe_budget` total
/// endurance divided by the trace's measured write rate. Returns
/// `f64::INFINITY` when the trace wrote nothing (an idle fleet never
/// wears out).
pub fn lifetime_years_at_rate(
    capacity_bytes: u64,
    pe_budget: u64,
    write_rate_bytes_per_s: f64,
) -> f64 {
    if write_rate_bytes_per_s <= 0.0 {
        return f64::INFINITY;
    }
    let endurance_bytes = capacity_bytes as f64 * pe_budget as f64;
    endurance_bytes / write_rate_bytes_per_s / (365.25 * 24.0 * 3600.0)
}

/// Lifetime using the paper's quoted 32 GiB KV region.
pub fn lifetime_years(model: &ModelShape, tpot: f64) -> LifetimeReport {
    lifetime_of_region(32.0 * (1u64 << 30) as f64, model, tpot)
}

/// Lifetime using the full Table-I SLC region capacity.
pub fn lifetime_years_system(sys: &SystemConfig, model: &ModelShape, tpot: f64) -> LifetimeReport {
    let slc_dies = (sys.org.channels * sys.org.ways_per_channel * sys.org.slc_dies_per_way) as f64;
    let plane_bytes = (sys.plane.n_row * sys.plane.n_col * sys.plane.n_stack) as f64 / 8.0;
    let region = slc_dies * sys.org.planes_per_die as f64 * plane_bytes;
    lifetime_of_region(region, model, tpot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;
    use crate::llm::model_config::OptModel;

    #[test]
    fn outlives_5_year_warranty() {
        // The actionable §IV-B claim: the KV region outlives the 5-year
        // SSD warranty under continuous OPT-30B generation at ~7 ms TPOT.
        let r = lifetime_years(&OptModel::Opt30b.shape(), 7.0e-3);
        assert!(r.years > 5.0, "lifetime = {:.1} years", r.years);
    }

    #[test]
    fn table1_region_lifetime_decades() {
        // With the full 128-GiB Table-I SLC region the projection reaches
        // the paper's "32 years" order of magnitude.
        let r = lifetime_years_system(&table1_system(), &OptModel::Opt30b.shape(), 7.0e-3);
        assert!(r.years > 15.0 && r.years < 100.0, "lifetime = {:.1} years", r.years);
    }

    #[test]
    fn effective_pe_is_500k() {
        let r = lifetime_years(&OptModel::Opt30b.shape(), 7.0e-3);
        assert!((r.effective_pe - 500_000.0).abs() < 1.0);
    }

    #[test]
    fn trace_rate_projection_matches_hand_math() {
        // 1 GiB region, 100 P/E, writing 1 GiB/day → 100 days ≈ 0.274 yr.
        let gib = 1u64 << 30;
        let rate = gib as f64 / (24.0 * 3600.0);
        let years = lifetime_years_at_rate(gib, 100, rate);
        assert!((years - 100.0 / 365.25).abs() < 1e-9, "{years}");
        assert_eq!(lifetime_years_at_rate(gib, 100, 0.0), f64::INFINITY);
    }

    #[test]
    fn faster_generation_wears_faster() {
        let m = OptModel::Opt30b.shape();
        let slow = lifetime_years(&m, 10e-3);
        let fast = lifetime_years(&m, 5e-3);
        assert!(fast.years < slow.years);
    }
}
