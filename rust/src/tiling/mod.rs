//! Tiling and mapping of sMVM operations across the flash hierarchy
//! (paper §IV-B, Figs. 11–12): row-wise vs column-wise tiling at each of
//! the four levels (channel / way / die / plane), the latency cost model
//! (inbound I/O, PIM, outbound I/O), and the search for the best scheme.

pub mod cost;
pub mod enumerate;
pub mod scheme;
pub mod search;

pub use cost::{TilingCost, TilingCostModel};
pub use enumerate::enumerate_schemes;
pub use scheme::{Level, Method, TilingScheme};
pub use search::{search_best, search_min};
