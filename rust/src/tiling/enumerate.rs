//! Enumeration of legal tiling schemes: 2 tiling methods per level →
//! `2^4 = 16` method combinations (paper §IV-B), crossed with the count
//! assignments that cover the tile grid within each level's resources.

use super::scheme::{Level, Method, TilingScheme};
use crate::config::FlashOrgConfig;

/// Divisors of `n` (ascending).
fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// All ways to split `total` into 4 ordered factors bounded per level.
fn factor_splits(total: usize, caps: [usize; 4]) -> Vec<[usize; 4]> {
    let mut out = Vec::new();
    for a in divisors(total) {
        if a > caps[0] {
            continue;
        }
        let ra = total / a;
        for b in divisors(ra) {
            if b > caps[1] {
                continue;
            }
            let rb = ra / b;
            for c in divisors(rb) {
                if c > caps[2] {
                    continue;
                }
                let d = rb / c;
                if d <= caps[3] {
                    out.push([a, b, c, d]);
                }
            }
        }
    }
    out
}

/// Enumerate all valid schemes for a `row_tiles × col_tiles` grid under
/// `org`. Every level is assigned Row or Col (a count of 1 renders the
/// method `None`, matching the paper's notation).
pub fn enumerate_schemes(
    org: &FlashOrgConfig,
    row_tiles: usize,
    col_tiles: usize,
) -> Vec<TilingScheme> {
    let caps = [
        Level::Channel.resources(org),
        Level::Way.resources(org),
        Level::Die.resources(org),
        Level::Plane.resources(org),
    ];
    let mut out = Vec::new();
    // Method mask: bit l set → level l is Row, else Col.
    for mask in 0u32..16 {
        let is_row = |l: usize| mask & (1 << l) != 0;
        let row_caps = std::array::from_fn(|l| if is_row(l) { caps[l] } else { 1 });
        let col_caps = std::array::from_fn(|l| if is_row(l) { 1 } else { caps[l] });
        for rs in factor_splits(row_tiles, row_caps) {
            for cs in factor_splits(col_tiles, col_caps) {
                let levels = std::array::from_fn(|l| {
                    let count = if is_row(l) { rs[l] } else { cs[l] };
                    let method = if count == 1 {
                        Method::None
                    } else if is_row(l) {
                        Method::Row
                    } else {
                        Method::Col
                    };
                    (method, count)
                });
                let s = TilingScheme::new(levels);
                if s.validate(org, row_tiles, col_tiles).is_ok() && !out.contains(&s) {
                    out.push(s);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;

    #[test]
    fn divisors_of_56() {
        assert_eq!(divisors(56), vec![1, 2, 4, 7, 8, 14, 28, 56]);
    }

    #[test]
    fn factor_splits_cover_total() {
        for s in factor_splits(56, [8, 4, 6, 256]) {
            assert_eq!(s.iter().product::<usize>(), 56);
        }
    }

    #[test]
    fn enumerate_yields_valid_unique_schemes() {
        let org = table1_system().org;
        let schemes = enumerate_schemes(&org, 56, 14);
        assert!(!schemes.is_empty());
        for s in &schemes {
            s.validate(&org, 56, 14).unwrap();
        }
        // Uniqueness.
        for (i, a) in schemes.iter().enumerate() {
            for b in &schemes[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn enumerate_contains_paper_cases() {
        let org = table1_system().org;
        let schemes = enumerate_schemes(&org, 56, 14);
        let notations: Vec<String> = schemes.iter().map(|s| s.notation()).collect();
        // The concentrated case C/C/N/R and spread case C/C/R/R both occur
        // (col tiles 14 = 2 × 7 fits 8 channels × 4 ways... 14 = 7 × 2 or
        // 2 × 7; with caps 8/4 the split 7/2 works at channel/way).
        assert!(notations.iter().any(|n| n == "C/C/N/R"), "have: {notations:?}");
        assert!(notations.iter().any(|n| n == "C/C/R/R"));
        assert!(notations.iter().any(|n| n == "N/C/C/R") || notations.iter().any(|n| n.starts_with("N/C")));
    }

    #[test]
    fn small_grid_enumerates_quickly() {
        let org = table1_system().org;
        let schemes = enumerate_schemes(&org, 8, 2);
        assert!(schemes.len() < 2000);
    }
}
