//! Best-mapping search: evaluate every legal scheme with the cost model
//! and keep the lowest pipelined total (the "in-house simulator" search
//! of paper §V-A).

use super::cost::{TilingCost, TilingCostModel};
use super::enumerate::enumerate_schemes;
use super::scheme::TilingScheme;
use crate::pim::op::MvmShape;

/// A scored scheme.
#[derive(Debug, Clone)]
pub struct Ranked {
    pub scheme: TilingScheme,
    pub cost: TilingCost,
}

/// Exhaustive search; returns schemes sorted by total latency (best
/// first). Empty result only if the shape cannot be covered at all.
///
/// Callers that only need the winner should use [`search_min`]: sorting
/// the full scheme set is wasted work on the TPOT hot path.
pub fn search_best(model: &TilingCostModel, shape: MvmShape) -> Vec<Ranked> {
    let (rt, ct) = model.grid(shape);
    let mut ranked: Vec<Ranked> = enumerate_schemes(&model.sys.org, rt, ct)
        .into_iter()
        .map(|scheme| Ranked { cost: model.cost(&scheme, shape), scheme })
        .collect();
    ranked.sort_by(|a, b| a.cost.total().cmp(&b.cost.total()));
    ranked
}

/// Fast path: the single cheapest scheme, found in one O(n) pass instead
/// of ranking every legal scheme. Ties resolve to the first scheme in
/// enumeration order — the same winner `search_best`'s stable sort puts
/// first. `None` only if the shape cannot be covered at all.
pub fn search_min(model: &TilingCostModel, shape: MvmShape) -> Option<Ranked> {
    let (rt, ct) = model.grid(shape);
    enumerate_schemes(&model.sys.org, rt, ct)
        .into_iter()
        .map(|scheme| Ranked { cost: model.cost(&scheme, shape), scheme })
        .min_by(|a, b| a.cost.total().cmp(&b.cost.total()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::config::presets::table1_system;
    use crate::nand::NandTiming;

    fn model() -> TilingCostModel {
        let sys = table1_system();
        let timing = NandTiming::of_system(&sys, &TechParams::default());
        TilingCostModel::new(&sys, timing)
    }

    #[test]
    fn search_returns_sorted_results() {
        let m = model();
        let r = search_best(&m, MvmShape::new(7168, 7168));
        assert!(r.len() > 10);
        for w in r.windows(2) {
            assert!(w[0].cost.total() <= w[1].cost.total());
        }
    }

    #[test]
    fn best_scheme_uses_channel_col() {
        // The Fig. 12 conclusion: channel-level column tiling wins.
        let m = model();
        let r = search_best(&m, MvmShape::new(7168, 7168));
        let best = &r[0];
        assert_eq!(
            best.scheme.method(super::super::scheme::Level::Channel),
            super::super::scheme::Method::Col,
            "best scheme {}",
            best.scheme.notation_counts()
        );
    }

    #[test]
    fn best_beats_naive_single_channel() {
        let m = model();
        let r = search_best(&m, MvmShape::new(7168, 7168));
        let best = r.first().unwrap();
        let worst = r.last().unwrap();
        assert!(best.cost.total().secs() < worst.cost.total().secs());
    }

    #[test]
    fn search_min_agrees_with_full_ranking() {
        let m = model();
        for s in [MvmShape::new(7168, 7168), MvmShape::new(7168, 28672)] {
            let ranked = search_best(&m, s);
            let min = search_min(&m, s).expect("coverable shape");
            assert_eq!(min.cost.total(), ranked[0].cost.total(), "{s:?}");
            assert_eq!(min.scheme, ranked[0].scheme, "{s:?}");
        }
    }

    #[test]
    fn search_handles_non_square_shapes() {
        let m = model();
        // FFN shapes of OPT-30B: 7168 × 28672 and back.
        for s in [MvmShape::new(7168, 28672), MvmShape::new(28672, 7168)] {
            let r = search_best(&m, s);
            assert!(!r.is_empty(), "no scheme for {s:?}");
        }
    }
}
