//! Tiling scheme descriptors.
//!
//! At every hierarchy level (channel, way, die, plane) a scheme picks a
//! tiling method and a resource count (paper Fig. 11):
//!
//! * **Row** — the input dimension is scattered across `count` units;
//!   their partial sums must later be accumulated.
//! * **Col** — the output dimension is split across `count` units; the
//!   input vector is broadcast and results concatenate.
//! * **None** — the level is not tiled (count 1); work concentrates in a
//!   single unit of that level, which with the H-tree enables in-die
//!   accumulation of everything below.
//!
//! Schemes print in the paper's `ch/way/die/plane` notation, e.g.
//! `C/C/N/R`.

use crate::config::FlashOrgConfig;
use anyhow::{bail, Result};

/// Hierarchy level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Channel = 0,
    Way = 1,
    Die = 2,
    Plane = 3,
}

impl Level {
    pub const ALL: [Level; 4] = [Level::Channel, Level::Way, Level::Die, Level::Plane];

    /// Resource population of this level in the organization.
    ///
    /// Note: the die level exposes all dies per way — the paper's Fig. 12
    /// evaluation states "8 channels, 4 ways, 8 dies, and 256 planes"
    /// even though Table I reserves 2 dies/way as SLC; we follow Fig. 12.
    pub fn resources(self, org: &FlashOrgConfig) -> usize {
        match self {
            Level::Channel => org.channels,
            Level::Way => org.ways_per_channel,
            Level::Die => org.dies_per_way,
            Level::Plane => org.planes_per_die,
        }
    }
}

/// Tiling method at one level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    None,
    Row,
    Col,
}

impl Method {
    pub fn letter(self) -> char {
        match self {
            Method::None => 'N',
            Method::Row => 'R',
            Method::Col => 'C',
        }
    }
}

/// A complete scheme: method + count per level, in
/// channel/way/die/plane order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilingScheme {
    pub levels: [(Method, usize); 4],
}

impl TilingScheme {
    pub fn new(levels: [(Method, usize); 4]) -> TilingScheme {
        TilingScheme { levels }
    }

    pub fn method(&self, l: Level) -> Method {
        self.levels[l as usize].0
    }

    pub fn count(&self, l: Level) -> usize {
        self.levels[l as usize].1
    }

    /// Product of counts over levels using `m`.
    pub fn product(&self, m: Method) -> usize {
        self.levels.iter().filter(|(mm, _)| *mm == m).map(|(_, c)| c).product()
    }

    /// Total positions (units assigned a tile).
    pub fn positions(&self) -> usize {
        self.levels.iter().map(|(_, c)| c).product()
    }

    /// Validate against an organization and a tile grid
    /// (`row_tiles × col_tiles`).
    pub fn validate(&self, org: &FlashOrgConfig, row_tiles: usize, col_tiles: usize) -> Result<()> {
        for l in Level::ALL {
            let (m, c) = self.levels[l as usize];
            if m == Method::None && c != 1 {
                bail!("None level must have count 1");
            }
            if c == 0 || c > l.resources(org) {
                bail!("count {c} at {l:?} exceeds resources {}", l.resources(org));
            }
        }
        if self.product(Method::Row) < row_tiles {
            bail!("row coverage {} < {row_tiles}", self.product(Method::Row));
        }
        if self.product(Method::Col) < col_tiles {
            bail!("col coverage {} < {col_tiles}", self.product(Method::Col));
        }
        Ok(())
    }

    /// Paper notation: `C/C/N/R`.
    pub fn notation(&self) -> String {
        self.levels.iter().map(|(m, _)| m.letter()).collect::<Vec<_>>().iter().map(|c| c.to_string()).collect::<Vec<_>>().join("/")
    }

    /// Notation with counts: `C(2)/C(4)/N(1)/R(56)`.
    pub fn notation_counts(&self) -> String {
        self.levels
            .iter()
            .map(|(m, c)| format!("{}({})", m.letter(), c))
            .collect::<Vec<_>>()
            .join("/")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;

    fn org() -> FlashOrgConfig {
        table1_system().org
    }

    #[test]
    fn notation_matches_paper_style() {
        let s = TilingScheme::new([
            (Method::Col, 2),
            (Method::Col, 4),
            (Method::None, 1),
            (Method::Row, 56),
        ]);
        assert_eq!(s.notation(), "C/C/N/R");
        assert_eq!(s.notation_counts(), "C(2)/C(4)/N(1)/R(56)");
    }

    #[test]
    fn products() {
        let s = TilingScheme::new([
            (Method::Col, 2),
            (Method::Col, 7),
            (Method::Row, 8),
            (Method::Row, 7),
        ]);
        assert_eq!(s.product(Method::Col), 14);
        assert_eq!(s.product(Method::Row), 56);
        assert_eq!(s.positions(), 2 * 7 * 8 * 7);
    }

    #[test]
    fn validate_coverage() {
        let s = TilingScheme::new([
            (Method::Col, 2),
            (Method::Col, 4),
            (Method::None, 1),
            (Method::Row, 56),
        ]);
        // 2×4 = 8 col positions covers 8 col tiles, not 14.
        assert!(s.validate(&org(), 56, 8).is_ok());
        assert!(s.validate(&org(), 56, 14).is_err());
    }

    #[test]
    fn validate_resource_bounds() {
        let s = TilingScheme::new([
            (Method::Col, 16), // > 8 channels
            (Method::None, 1),
            (Method::None, 1),
            (Method::Row, 56),
        ]);
        assert!(s.validate(&org(), 56, 1).is_err());
    }

    #[test]
    fn die_level_uses_fig12_population() {
        assert_eq!(Level::Die.resources(&org()), 8);
    }
}
