//! Latency cost model for a tiling scheme (paper Fig. 12): inbound I/O,
//! PIM, and outbound I/O per sMVM, with the three stages pipelined
//! (inbound overlaps PIM; outbound begins as reductions complete).
//!
//! Semantics (see DESIGN.md):
//! * Inbound — each used channel bus carries its input slice once
//!   (multi-drop broadcast reaches every way/die below), so channel-level
//!   Row tiling shrinks inbound and Col/None leave it at `M/bw`.
//! * PIM — tile positions work in parallel; a position holding several
//!   unit tiles runs them back-to-back.
//! * Outbound — with the H-tree, everything below the die level reduces
//!   in-die to one partial vector; die-level Row tiling spreads row tiles
//!   over `k_d` dies, so `k_d` partial vectors exit per way position
//!   (accumulated at the controller). With a shared intra-die bus, every
//!   plane's tile vector exits individually.

use super::scheme::{Level, Method, TilingScheme};
use crate::bus::Rpu;
use crate::config::{BusTopology, SystemConfig};
use crate::nand::NandTiming;
use crate::pim::op::MvmShape;
use crate::pim::smvm::OUT_ELEM_BYTES;
use crate::sim::SimTime;

/// Cost breakdown of one sMVM under a scheme.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TilingCost {
    pub inbound: SimTime,
    pub pim: SimTime,
    pub outbound: SimTime,
}

impl TilingCost {
    /// Pipelined end-to-end latency: inbound overlaps PIM (the paper
    /// overlaps the first two stages), outbound follows the PIM stage.
    pub fn total(&self) -> SimTime {
        self.inbound.max(self.pim) + self.outbound
    }
}

/// Evaluator bound to a system configuration.
pub struct TilingCostModel {
    pub sys: SystemConfig,
    pub timing: NandTiming,
}

impl TilingCostModel {
    pub fn new(sys: &SystemConfig, timing: NandTiming) -> TilingCostModel {
        TilingCostModel { sys: sys.clone(), timing }
    }

    /// Tile grid of a shape under the system's unit tile.
    pub fn grid(&self, shape: MvmShape) -> (usize, usize) {
        (shape.row_tiles(self.sys.tile_rows()), shape.col_tiles(self.sys.tile_cols()))
    }

    /// Evaluate a scheme for a shape. The scheme must be valid.
    pub fn cost(&self, scheme: &TilingScheme, shape: MvmShape) -> TilingCost {
        let (rt, ct) = self.grid(shape);
        debug_assert!(scheme.validate(&self.sys.org, rt, ct).is_ok());
        let bw = self.sys.ctrl.channel_bus_bw;

        // ---- inbound ----
        let (ch_method, ch_count) = scheme.levels[Level::Channel as usize];
        let in_bytes_per_channel = match ch_method {
            Method::Row => shape.m.div_ceil(ch_count), // INT8 activations
            Method::Col | Method::None => shape.m,
        };
        let inbound = SimTime::from_secs(in_bytes_per_channel as f64 / bw);

        // ---- PIM ----
        let total_tiles = rt * ct;
        let tiles_per_pos = total_tiles.div_ceil(scheme.positions().min(total_tiles));
        let pim = SimTime::from_secs(tiles_per_pos as f64 * self.timing.t_pim.secs());

        // ---- outbound ----
        // Output slice carried per channel.
        let n_slice = match ch_method {
            Method::Col => shape.n.div_ceil(ch_count),
            Method::Row | Method::None => shape.n,
        };
        // Partial-vector multiplicity exiting per channel.
        let way_mult = match scheme.method(Level::Way) {
            Method::Row => scheme.count(Level::Way),
            _ => 1,
        };
        let die_plane_mult = match self.sys.bus {
            BusTopology::Shared => {
                // No in-die accumulation: every plane-level row tile exits.
                let die_mult = match scheme.method(Level::Die) {
                    Method::Row => scheme.count(Level::Die),
                    _ => 1,
                };
                let plane_mult = match scheme.method(Level::Plane) {
                    Method::Row => scheme.count(Level::Plane),
                    _ => 1,
                };
                die_mult * plane_mult
            }
            BusTopology::HTree => {
                // Plane-level rows reduce in-die; die-level Row still
                // produces one partial per die.
                match scheme.method(Level::Die) {
                    Method::Row => scheme.count(Level::Die),
                    _ => 1,
                }
            }
        };
        let out_bytes_per_channel = n_slice * OUT_ELEM_BYTES * way_mult * die_plane_mult;
        let transfer = SimTime::from_secs(out_bytes_per_channel as f64 / bw);

        // In-die H-tree reduction latency before the reduced vector can
        // exit. RPU work and data transfer are pipelined (paper §V-A), so
        // only the ALU merge levels are exposed — on-die hop wires are
        // wide and fast relative to the channel bus.
        let tree_latency = match self.sys.bus {
            BusTopology::HTree => {
                let plane_rows = match scheme.method(Level::Plane) {
                    Method::Row => scheme.count(Level::Plane),
                    _ => 1,
                };
                if plane_rows > 1 {
                    let rpu = Rpu::new(self.sys.rpu);
                    let merge_levels = (plane_rows as f64).log2().ceil() as u32;
                    let per_level = rpu.alu_time(self.sys.tile_cols());
                    SimTime::from_secs(merge_levels as f64 * per_level.secs())
                } else {
                    SimTime::ZERO
                }
            }
            BusTopology::Shared => SimTime::ZERO,
        };

        TilingCost { inbound, pim, outbound: tree_latency + transfer }
    }
}

/// The paper's three Fig. 12 cases for a `d_m × d_m` sMVM, with counts
/// resolved for the Table-I organization (8 ch, 4 way, 6 QLC dies,
/// 256 planes).
pub fn fig12_cases(model: &TilingCostModel, shape: MvmShape) -> Vec<(String, TilingScheme)> {
    let (rt, ct) = model.grid(shape);
    let org = model.sys.org;
    // N/C/C/R — no channel tiling; cols across ways and dies; rows in-plane.
    let a = TilingScheme::new([
        (Method::None, 1),
        (Method::Col, org.ways_per_channel.min(ct)),
        (Method::Col, org.dies_per_way.min(ct.div_ceil(org.ways_per_channel)).max(1)),
        (Method::Row, rt),
    ]);
    // C/C/N/R — cols across channels and ways; one die per position holds
    // all row tiles (the H-tree reduces them in-die).
    let c_ch = org.channels.min(ct);
    let c_way = ct.div_ceil(c_ch).min(org.ways_per_channel).max(1);
    let b = TilingScheme::new([
        (Method::Col, c_ch),
        (Method::Col, c_way),
        (Method::None, 1),
        (Method::Row, rt),
    ]);
    // C/C/R/R — cols as above, rows split across dies then planes. Half
    // the dies take row tiles (headroom for double-buffering the next op).
    let k_d = smallest_factor_cover(rt, (org.dies_per_way / 2).max(2));
    let c = TilingScheme::new([
        (Method::Col, c_ch),
        (Method::Col, c_way),
        (Method::Row, k_d),
        (Method::Row, rt.div_ceil(k_d)),
    ]);
    vec![("N/C/C/R".into(), a), ("C/C/N/R".into(), b), ("C/C/R/R".into(), c)]
}

/// Largest divisor-ish factor of `n` not exceeding `cap` (falls back to
/// `cap` with ceil coverage).
fn smallest_factor_cover(n: usize, cap: usize) -> usize {
    for k in (1..=cap).rev() {
        if n % k == 0 {
            return k;
        }
    }
    cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::config::presets::table1_system;

    fn model() -> TilingCostModel {
        let sys = table1_system();
        let timing = NandTiming::of_system(&sys, &TechParams::default());
        TilingCostModel::new(&sys, timing)
    }

    /// OPT-30B projection shape of Fig. 12.
    fn shape() -> MvmShape {
        MvmShape::new(7168, 7168)
    }

    #[test]
    fn fig12_inbound_and_pim_identical_across_cases() {
        // Paper: "Since the tile count exploiting the row-wise tiling is
        // equal in all cases (56), both inbound I/O and PIM latencies are
        // identical."
        let m = model();
        let costs: Vec<TilingCost> =
            fig12_cases(&m, shape()).iter().map(|(_, s)| m.cost(s, shape())).collect();
        for c in &costs[1..] {
            assert_eq!(c.pim, costs[0].pim);
        }
        // Inbound identical for the two C/C cases; N at channel also
        // carries the full input once (broadcast), so all three match.
        for c in &costs[1..] {
            assert_eq!(c.inbound, costs[0].inbound);
        }
    }

    #[test]
    fn fig12_channel_col_cuts_outbound_dramatically() {
        // Paper: column-wise tiling at the channel level dramatically
        // reduces outbound ('N/C/C/R' vs the other two).
        let m = model();
        let cases = fig12_cases(&m, shape());
        let nccr = m.cost(&cases[0].1, shape());
        let ccnr = m.cost(&cases[1].1, shape());
        assert!(
            nccr.outbound.secs() > 2.0 * ccnr.outbound.secs(),
            "N/C/C/R outbound {} not ≫ C/C/N/R {}",
            nccr.outbound,
            ccnr.outbound
        );
    }

    #[test]
    fn fig12_htree_concentration_cuts_outbound_near_47pct() {
        // Paper: the in-die H-tree accumulation cuts outbound ~47 %
        // (C/C/N/R, enabled by the H-tree, vs C/C/R/R which spreads row
        // tiles across dies and ships their partials). Tolerance ±15 pp.
        let m = model();
        let cases = fig12_cases(&m, shape());
        let ccnr = m.cost(&cases[1].1, shape());
        let ccrr = m.cost(&cases[2].1, shape());
        let reduction = 1.0 - ccnr.outbound.secs() / ccrr.outbound.secs();
        assert!(
            (0.32..=0.62).contains(&reduction),
            "outbound reduction {:.1}% (C/C/N/R {} vs C/C/R/R {})",
            reduction * 100.0,
            ccnr.outbound,
            ccrr.outbound
        );
    }

    #[test]
    fn shared_bus_outbound_explodes() {
        // Without the H-tree every plane partial exits individually.
        let mut sys = table1_system();
        sys.bus = BusTopology::Shared;
        let timing = NandTiming::of_system(&sys, &TechParams::default());
        let shared = TilingCostModel::new(&sys, timing);
        let m = model();
        let cases = fig12_cases(&m, shape());
        let h = m.cost(&cases[1].1, shape());
        let s = shared.cost(&cases[1].1, shape());
        assert!(s.outbound.secs() > 5.0 * h.outbound.secs());
    }

    #[test]
    fn total_pipelines_inbound_with_pim() {
        let m = model();
        let cases = fig12_cases(&m, shape());
        let c = m.cost(&cases[1].1, shape());
        assert_eq!(c.total(), c.inbound.max(c.pim) + c.outbound);
    }
}
