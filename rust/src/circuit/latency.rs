//! Plane latency model — paper Eqs. (1), (3), (5a–c).
//!
//! `T_PIM = t_decWL + (max(t_decBLS, t_pre) + t_sense + t_accum + t_dis) × B_input`
//! `T_read = t_decWL + max(t_decBLS, t_pre) + t_sense + t_dis`

use super::geometry::PlaneGeometry;
use super::tech::TechParams;
use crate::config::{CellKind, PlaneConfig};

/// Which read operation a latency query refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadKind {
    /// Regular page read (Eq. 1). QLC multi-level sensing repeats the
    /// sense phase `qlc_sense_levels` times.
    PageRead,
    /// One PIM dot-product cycle per input bit (Eq. 3 inner term).
    Pim,
}

/// Latency breakdown of one plane operation (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlaneLatency {
    /// WL decode + drive — Eq. (5c), paid once per operation.
    pub t_decwl: f64,
    /// BLS decode — Eq. (5b), per input bit.
    pub t_decbls: f64,
    /// BL precharge — Eq. (5a), per input bit.
    pub t_pre: f64,
    /// Sense + ADC conversion, per input bit.
    pub t_sense: f64,
    /// Shift-adder accumulation, per input bit (PIM only).
    pub t_accum: f64,
    /// BL/BLS discharge, per input bit.
    pub t_dis: f64,
}

impl PlaneLatency {
    /// Evaluate the breakdown for a plane under the given technology.
    pub fn of(plane: &PlaneConfig, tech: &TechParams) -> PlaneLatency {
        let g = PlaneGeometry::of(plane, tech);
        let h = &tech.horowitz;

        // Eq. (5a): switch drives N_col precharge gates, then the BL wire
        // charges (distributed line: C/2) plus the string junction load.
        let tau_switch = tech.r_switch_pre * (plane.n_col as f64 * tech.c_inv);
        let tau_bl = g.r_bl * (g.c_bl / 2.0 + tech.c_string);
        let t_pre = h.delay(tau_switch) + h.delay(tau_bl);

        // Eq. (5b): distributed BLS line.
        let t_decbls = h.delay(g.r_bls * g.c_bls / 2.0);

        // Eq. (5c): HV pass transistor drives the WL comb (cell + staircase).
        let t_decwl = h.delay(tech.r_switch_wl * (g.c_cell + g.c_stair));

        // Sense: the cell current settles through the vertical string
        // (longer strings — more stacks — settle slower), then the SAR
        // converts one bit per ADC clock.
        let tau_string = tech.r_string_per_stack * plane.n_stack as f64 * (g.c_bl / 2.0);
        let t_sense = tau_string + tech.adc_bits as f64 / tech.adc_freq;

        // Accumulate: one shift-add pass per column-mux phase.
        let t_accum = 4.0 / tech.accum_freq;

        let t_dis = tech.t_dis_frac * t_pre;

        PlaneLatency { t_decwl, t_decbls, t_pre, t_sense, t_accum, t_dis }
    }

    /// Per-input-bit PIM cycle time (the parenthesized term of Eq. 3).
    pub fn pim_cycle(&self) -> f64 {
        self.t_decbls.max(self.t_pre) + self.t_sense + self.t_accum + self.t_dis
    }

    /// Total PIM latency for a `b_input`-bit input — Eq. (3).
    pub fn t_pim(&self, b_input: usize) -> f64 {
        self.t_decwl + self.pim_cycle() * b_input as f64
    }

    /// Regular page-read latency — Eq. (1). QLC pages repeat the sense
    /// phase for each threshold level.
    pub fn t_read(&self, cell: CellKind, tech: &TechParams) -> f64 {
        let senses = match cell {
            CellKind::Slc => 1.0,
            CellKind::Qlc => tech.qlc_sense_levels as f64,
        };
        self.t_decwl + self.t_decbls.max(self.t_pre) + senses * self.t_sense + self.t_dis
    }
}

/// Convenience: `T_PIM` for a plane with default paper inputs (8-bit).
pub fn t_pim_8b(plane: &PlaneConfig, tech: &TechParams) -> f64 {
    PlaneLatency::of(plane, tech).t_pim(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{conventional_plane, size_a_plane, size_b_plane};

    #[test]
    fn size_a_hits_2us_anchor() {
        // Paper §III-B: ~2 µs PIM latency at 256×2048×128 with 8-bit I/O.
        let t = TechParams::default();
        let lat = t_pim_8b(&size_a_plane(), &t);
        assert!(
            (1.7e-6..=2.3e-6).contains(&lat),
            "T_PIM(Size A) = {} outside [1.7, 2.3] µs",
            crate::util::units::fmt_time(lat)
        );
    }

    #[test]
    fn size_b_is_faster_than_a() {
        let t = TechParams::default();
        assert!(t_pim_8b(&size_b_plane(), &t) < t_pim_8b(&size_a_plane(), &t));
    }

    #[test]
    fn conventional_read_20_to_50_us() {
        // Paper §III-A: conventional planes read in 20–50 µs.
        let t = TechParams::default();
        let p = conventional_plane();
        let lat = PlaneLatency::of(&p, &t).t_read(CellKind::Qlc, &t);
        assert!(
            (20e-6..=50e-6).contains(&lat),
            "T_read(conventional) = {} outside [20, 50] µs",
            crate::util::units::fmt_time(lat)
        );
    }

    #[test]
    fn latency_monotone_in_each_dim() {
        // Fig. 6a: PIM latency increases with each of N_row, N_col, N_stack.
        let t = TechParams::default();
        let base = size_a_plane();
        let l0 = t_pim_8b(&base, &t);
        for grow in [
            PlaneConfig { n_row: base.n_row * 2, ..base },
            PlaneConfig { n_col: base.n_col * 2, ..base },
            PlaneConfig { n_stack: base.n_stack * 2, ..base },
        ] {
            assert!(t_pim_8b(&grow, &t) > l0, "growing {grow:?} did not increase latency");
        }
    }

    #[test]
    fn decwl_independent_of_rows() {
        // Paper: "t_decWL remains the same even with increased N_row".
        let t = TechParams::default();
        let a = PlaneLatency::of(&size_a_plane(), &t);
        let b = PlaneLatency::of(&PlaneConfig { n_row: 2048, ..size_a_plane() }, &t);
        assert!((a.t_decwl - b.t_decwl).abs() < 1e-15);
    }

    #[test]
    fn bls_decode_below_precharge_in_sweep_range() {
        // Paper: t_decBLS is a small portion; max(t_decBLS, t_pre) = t_pre
        // for the simulated configurations (BLS dominates only at ≥16K cols).
        let t = TechParams::default();
        for n_col in [512usize, 1024, 2048, 4096] {
            let p = PlaneConfig { n_col, ..size_a_plane() };
            let l = PlaneLatency::of(&p, &t);
            assert!(l.t_decbls < l.t_pre, "n_col={n_col}: decBLS {} >= pre {}", l.t_decbls, l.t_pre);
        }
    }

    #[test]
    fn pim_scales_linearly_with_input_bits() {
        let t = TechParams::default();
        let l = PlaneLatency::of(&size_a_plane(), &t);
        let d4 = l.t_pim(4) - l.t_decwl;
        let d8 = l.t_pim(8) - l.t_decwl;
        assert!((d8 / d4 - 2.0).abs() < 1e-12);
    }
}
