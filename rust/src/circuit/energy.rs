//! Per-operation PIM energy — paper Eqs. (6a–c) plus sensing/accumulation.

use super::geometry::PlaneGeometry;
use super::tech::TechParams;
use crate::config::PlaneConfig;

/// Energy breakdown of one PIM dot-product cycle (joules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PimEnergy {
    /// BL precharge — Eq. (6a).
    pub e_pre: f64,
    /// BLS decode/drive — Eq. (6b).
    pub e_decbls: f64,
    /// WL decode/drive — Eq. (6c).
    pub e_decwl: f64,
    /// ADC conversions across the active columns.
    pub e_sense: f64,
    /// Shift-add + mux drive — grows with `N_col` (paper: "accum sharply
    /// increases with higher N_col as the controller drives higher MUX loads").
    pub e_accum: f64,
}

impl PimEnergy {
    /// Evaluate for one PIM cycle with `rows_active` simultaneously
    /// activated rows and input-bit sparsity `alpha` (paper: 128 rows,
    /// α ≈ 0.5 for LLM activations).
    pub fn of(plane: &PlaneConfig, tech: &TechParams, rows_active: usize, alpha: f64) -> PimEnergy {
        let g = PlaneGeometry::of(plane, tech);
        let n_col = plane.n_col as f64;
        let n_act = rows_active as f64;

        // Eq. (6a): every BL charges its wire plus the strings whose BLS
        // was driven by a 1-bit (fraction 1-α of active rows).
        let e_pre = n_col * tech.v_pre * tech.v_pre * (g.c_bl + tech.c_string * n_act * (1.0 - alpha));

        // Eq. (6b): each activated row's BLS line swings to V_pass.
        let e_decbls = n_act * tech.v_pass * tech.v_pass * g.c_bls * (1.0 - alpha);

        // Eq. (6c): selected WL at V_read + unselected comb at V_pass.
        let c_wl = g.c_cell + g.c_stair;
        let e_decwl = tech.v_read * tech.v_read * c_wl + tech.v_pass * tech.v_pass * c_wl;

        // One ADC conversion per active column-mux output.
        let active_cols = n_col / 4.0;
        let e_sense = active_cols * tech.e_adc_conv;

        // Mux/shift-add drive grows with the full column count.
        let e_accum = n_col * tech.e_accum_per_col;

        PimEnergy { e_pre, e_decbls, e_decwl, e_sense, e_accum }
    }

    /// Total energy of one PIM cycle.
    pub fn total(&self) -> f64 {
        self.e_pre + self.e_decbls + self.e_decwl + self.e_sense + self.e_accum
    }

    /// Total for a `b_input`-bit operation (WL decode paid once).
    pub fn total_op(&self, b_input: usize) -> f64 {
        self.e_decwl + (self.total() - self.e_decwl) * b_input as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::size_a_plane;
    use crate::config::PlaneConfig;

    const ROWS: usize = 128;
    const ALPHA: f64 = 0.5;

    #[test]
    fn energy_in_nanojoule_range() {
        // Fig. 6b reports nJ-scale energies.
        let t = TechParams::default();
        let e = PimEnergy::of(&size_a_plane(), &t, ROWS, ALPHA);
        let tot = e.total();
        assert!((0.1e-9..=100e-9).contains(&tot), "total = {}", crate::util::units::fmt_energy(tot));
    }

    #[test]
    fn energy_monotone_in_each_dim() {
        // Fig. 6b: energy increases with N_row, N_col, N_stack.
        // (N_row enters via BL length through the geometry.)
        let t = TechParams::default();
        let base = size_a_plane();
        let e0 = PimEnergy::of(&base, &t, ROWS, ALPHA).total();
        for grow in [
            PlaneConfig { n_row: base.n_row * 2, ..base },
            PlaneConfig { n_col: base.n_col * 2, ..base },
            PlaneConfig { n_stack: base.n_stack * 2, ..base },
        ] {
            assert!(PimEnergy::of(&grow, &t, ROWS, ALPHA).total() > e0);
        }
    }

    #[test]
    fn decbls_energy_independent_of_rows() {
        // Eq. (6b): N*_row is fixed at 128, so E_decBLS is irrelevant to N_row.
        let t = TechParams::default();
        let a = PimEnergy::of(&size_a_plane(), &t, ROWS, ALPHA);
        let b = PimEnergy::of(&PlaneConfig { n_row: 1024, ..size_a_plane() }, &t, ROWS, ALPHA);
        assert!((a.e_decbls - b.e_decbls).abs() < 1e-18);
    }

    #[test]
    fn sparsity_reduces_precharge_energy() {
        let t = TechParams::default();
        let dense = PimEnergy::of(&size_a_plane(), &t, ROWS, 0.0);
        let sparse = PimEnergy::of(&size_a_plane(), &t, ROWS, 0.9);
        assert!(sparse.e_pre < dense.e_pre);
    }

    #[test]
    fn accum_scales_with_cols() {
        let t = TechParams::default();
        let a = PimEnergy::of(&size_a_plane(), &t, ROWS, ALPHA);
        let b = PimEnergy::of(&PlaneConfig { n_col: 4096, ..size_a_plane() }, &t, ROWS, ALPHA);
        assert!((b.e_accum / a.e_accum - 2.0).abs() < 1e-12);
    }

    #[test]
    fn multi_bit_op_pays_wl_once() {
        let t = TechParams::default();
        let e = PimEnergy::of(&size_a_plane(), &t, ROWS, ALPHA);
        let op8 = e.total_op(8);
        assert!((op8 - (e.e_decwl + 8.0 * (e.total() - e.e_decwl))).abs() < 1e-18);
    }
}
