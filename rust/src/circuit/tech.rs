//! Technology parameters of the modelled 3D NAND process.
//!
//! These play the role of the extracted netlist constants the paper pulled
//! from the modified 3D-FPIM + NeuroSim simulators. Absolute values are
//! calibrated to the paper's published operating points (DESIGN.md
//! "Acceptance anchors"); the *functional forms* — which dimension each
//! R/C scales with — follow Eqs. (4)–(6) exactly, so the Fig. 6 trends are
//! structural, not fitted.

use super::horowitz::Horowitz;

/// Process/electrical constants for the plane model.
#[derive(Debug, Clone, PartialEq)]
pub struct TechParams {
    // ---- geometry pitches ----
    /// Bitline (column) pitch along the wordline direction (m). Sets
    /// `L_cell = n_col × pitch_col`.
    pub pitch_col: f64,
    /// Staircase length per stacked wordline layer (m). Sets
    /// `L_stair = n_stack × pitch_stair`.
    pub pitch_stair: f64,
    /// Row (BLS) pitch along the bitline direction (m). Sets
    /// `W = n_row × pitch_row`.
    pub pitch_row: f64,
    /// Fraction of the staircase length that contributes to the die
    /// footprint after comb-style WL sharing between mirrored block pairs.
    /// Calibrated so 256 Size-A planes total 4.98 mm² (paper §V-C) while
    /// Eq. (4) density (full staircase) is 12.84 Gb/mm².
    pub staircase_share: f64,

    // ---- bitline (copper) ----
    /// BL resistance per metre (Ω/m).
    pub r_bl_per_m: f64,
    /// BL capacitance per metre (F/m).
    pub c_bl_per_m: f64,
    /// Capacitance of one NAND string hanging off the BL (F).
    pub c_string: f64,

    // ---- bitline-select line (tungsten) ----
    /// BLS resistance per metre (Ω/m).
    pub r_bls_per_m: f64,
    /// BLS capacitance per metre (F/m).
    pub c_bls_per_m: f64,

    // ---- wordline ----
    /// WL capacitance per metre over the cell region (F/m).
    pub c_wl_cell_per_m: f64,
    /// WL capacitance per metre over the staircase region (F/m).
    pub c_wl_stair_per_m: f64,

    // ---- drivers / switches ----
    /// High-voltage WL pass-transistor resistance (Ω) — `R_s` in Eq. 5c.
    pub r_switch_wl: f64,
    /// Low-voltage precharge switch resistance (Ω) — `R_s` in Eq. 5a.
    pub r_switch_pre: f64,
    /// Gate capacitance of one precharge transistor (F) — `C_INV` in Eq. 5a.
    pub c_inv: f64,
    /// Per-stack-layer string channel resistance (Ω) — more stacks mean a
    /// longer vertical string, slowing the sense settle.
    pub r_string_per_stack: f64,

    // ---- voltages ----
    /// BL precharge voltage (V).
    pub v_pre: f64,
    /// Pass voltage applied to unselected WLs / driven BLSs (V).
    pub v_pass: f64,
    /// Read voltage on the selected WL (V).
    pub v_read: f64,

    // ---- sensing / accumulation ----
    /// SAR ADC resolution in the PIM read path (bits; paper: 9).
    pub adc_bits: usize,
    /// SAR ADC conversion clock (Hz).
    pub adc_freq: f64,
    /// Shift-adder clock (Hz) — matches the RPU clock domain.
    pub accum_freq: f64,
    /// Energy per ADC conversion (J).
    pub e_adc_conv: f64,
    /// Accumulation (shift-add + mux drive) energy per active column (J).
    pub e_accum_per_col: f64,
    /// Fraction of `t_pre` spent discharging BLs/BLSs after an op.
    pub t_dis_frac: f64,
    /// Conventional-read sense levels for QLC (multi-level sensing makes
    /// a regular QLC page read slower than the single-shot PIM sense).
    pub qlc_sense_levels: usize,

    /// Horowitz delay parameters.
    pub horowitz: Horowitz,
}

impl Default for TechParams {
    fn default() -> Self {
        TechParams {
            // Geometry — calibrated to Eq.(4) density 12.84 Gb/mm² at Size A
            // and 4.98 mm² for the 256-plane die (see density.rs tests).
            pitch_col: 40e-9,
            pitch_stair: 400e-9,
            pitch_row: 613.5e-9,
            staircase_share: 0.82,

            // BL: long thin copper line, dominated by wire RC. τ_BL ∝ n_row².
            r_bl_per_m: 2.0e9,  // 2 kΩ/µm
            c_bl_per_m: 0.8e-9, // 0.8 fF/µm
            c_string: 10e-15,

            // BLS: tungsten select line along the columns; lower effective
            // RC load than the BL in the simulated range (paper §III-B).
            r_bls_per_m: 0.5e9,  // 0.5 kΩ/µm
            c_bls_per_m: 0.5e-9, // 0.5 fF/µm

            // WL: the decoder drives the cell region + staircase comb.
            c_wl_cell_per_m: 4.0e-9, // 4 fF/µm
            c_wl_stair_per_m: 3.0e-9, // 3 fF/µm (stair contact comb)

            r_switch_wl: 100e3,
            r_switch_pre: 5e3,
            c_inv: 0.2e-15,
            r_string_per_stack: 3e3,

            v_pre: 1.0,
            v_pass: 6.0,
            v_read: 1.0,

            adc_bits: 9,
            adc_freq: 200e6,
            accum_freq: 250e6,
            e_adc_conv: 2.0e-12,
            e_accum_per_col: 0.05e-12,
            t_dis_frac: 0.4,
            qlc_sense_levels: 8,

            horowitz: Horowitz::default(),
        }
    }
}

impl TechParams {
    /// Convenience: the default technology.
    pub fn paper() -> TechParams {
        TechParams::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_physical() {
        let t = TechParams::default();
        assert!(t.pitch_col > 0.0 && t.pitch_col < 1e-6);
        assert!(t.staircase_share > 0.0 && t.staircase_share <= 1.0);
        assert!(t.adc_bits == 9, "paper uses 9-bit SAR ADCs");
        assert!(t.v_pass > t.v_read, "pass voltage exceeds read voltage");
    }
}
