//! Circuit-level model of a 3D NAND flash PIM plane.
//!
//! Implements the paper's analytic model directly:
//! * read / PIM latency — Eqs. (1), (3), (5a–c) via the Horowitz delay
//!   ([`horowitz`]),
//! * per-operation energy — Eqs. (6a–c) ([`energy`]),
//! * cell density — Eq. (4) ([`density`]),
//! * the 9-bit SAR ADC in the PIM read path ([`adc`]).
//!
//! All constants live in [`tech::TechParams`] and are calibrated to the
//! paper's published operating points (see DESIGN.md "Acceptance anchors"):
//! `T_PIM(Size A) ≈ 2 µs`, conventional-plane read in 20–50 µs, Size-A
//! density 12.84 Gb/mm².

pub mod adc;
pub mod density;
pub mod energy;
pub mod geometry;
pub mod horowitz;
pub mod latency;
pub mod tech;

pub use adc::SarAdc;
pub use density::cell_density_gb_mm2;
pub use energy::PimEnergy;
pub use geometry::PlaneGeometry;
pub use latency::{PlaneLatency, ReadKind};
pub use tech::TechParams;
