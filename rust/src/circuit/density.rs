//! Cell density — paper Eq. (4):
//!
//! `D_cell = (N_col × N_stack × B_cell) / (L_cell + L_staircase) × N_row / W`
//!
//! Since `W ∝ N_row`, density is independent of the row count; it trades
//! off against PIM latency through `N_col` and `N_stack`.

use super::geometry::PlaneGeometry;
use super::tech::TechParams;
use crate::config::PlaneConfig;

/// Cell density in bits/m².
pub fn cell_density_bits_m2(plane: &PlaneConfig, tech: &TechParams) -> f64 {
    let g = PlaneGeometry::of(plane, tech);
    plane.capacity_bits() as f64 / g.area_full()
}

/// Cell density in Gb/mm² (the unit of Fig. 6c).
pub fn cell_density_gb_mm2(plane: &PlaneConfig, tech: &TechParams) -> f64 {
    cell_density_bits_m2(plane, tech) / 1e9 * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{size_a_plane, size_b_plane};
    use crate::config::{CellKind, PlaneConfig};

    #[test]
    fn size_a_density_anchor() {
        // Paper §III-C: 12.84 Gb/mm² for Size A.
        let d = cell_density_gb_mm2(&size_a_plane(), &TechParams::default());
        assert!((d - 12.84).abs() / 12.84 < 0.05, "Size A density = {d} Gb/mm²");
    }

    #[test]
    fn size_a_is_twice_size_b() {
        // Paper Fig. 9b: Size A has 2× the density of Size B.
        let t = TechParams::default();
        let a = cell_density_gb_mm2(&size_a_plane(), &t);
        let b = cell_density_gb_mm2(&size_b_plane(), &t);
        assert!((a / b - 2.0).abs() < 1e-9, "A/B = {}", a / b);
    }

    #[test]
    fn density_independent_of_rows() {
        // Eq. (4): W ∝ N_row cancels the N_row in the numerator.
        let t = TechParams::default();
        let a = cell_density_gb_mm2(&size_a_plane(), &t);
        let b = cell_density_gb_mm2(&PlaneConfig { n_row: 4096, ..size_a_plane() }, &t);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn density_more_sensitive_to_cols_than_stacks_at_base() {
        // Paper: with the simulated configurations (L_cell < L_stair at the
        // sweep base N_col=1K, N_stack=128), density is more sensitive to
        // N_col than N_stack.
        let t = TechParams::default();
        let base = PlaneConfig { n_col: 1024, ..size_a_plane() };
        let d0 = cell_density_gb_mm2(&base, &t);
        let d_col = cell_density_gb_mm2(&PlaneConfig { n_col: 2048, ..base }, &t);
        let d_stack = cell_density_gb_mm2(&PlaneConfig { n_stack: 256, ..base }, &t);
        let gain_col = d_col / d0;
        let gain_stack = d_stack / d0;
        assert!(
            gain_col > gain_stack,
            "doubling cols gains {gain_col}, doubling stacks gains {gain_stack}"
        );
    }

    #[test]
    fn slc_density_quarter_of_qlc() {
        let t = TechParams::default();
        let qlc = size_a_plane();
        let slc = PlaneConfig { cell: CellKind::Slc, ..qlc };
        let r = cell_density_gb_mm2(&qlc, &t) / cell_density_gb_mm2(&slc, &t);
        assert!((r - 4.0).abs() < 1e-9);
    }
}
