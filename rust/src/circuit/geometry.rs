//! Plane geometry: wire lengths, region lengths, areas, and the lumped
//! R/C values derived from them. Everything downstream (latency, energy,
//! density, area) reads these.

use super::tech::TechParams;
use crate::config::PlaneConfig;

/// Derived geometry + lumped electrical values of one plane.
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneGeometry {
    /// Cell-region length along the WL direction (m): `n_col × pitch_col`.
    pub l_cell: f64,
    /// Staircase length (m): `n_stack × pitch_stair`.
    pub l_stair: f64,
    /// Plane width (m): `n_row × pitch_row`.
    pub width: f64,
    /// Bitline length (m): runs across the rows.
    pub l_bl: f64,
    /// BLS line length (m): runs across the columns.
    pub l_bls: f64,

    /// Lumped BL resistance (Ω).
    pub r_bl: f64,
    /// Lumped BL capacitance (F).
    pub c_bl: f64,
    /// Lumped BLS resistance (Ω).
    pub r_bls: f64,
    /// Lumped BLS capacitance (F).
    pub c_bls: f64,
    /// WL capacitance over the cell region (F) — `C_cell` in Eq. 5c.
    pub c_cell: f64,
    /// WL capacitance over the staircase (F) — `C_stair` in Eq. 5c.
    pub c_stair: f64,
}

impl PlaneGeometry {
    pub fn of(plane: &PlaneConfig, tech: &TechParams) -> PlaneGeometry {
        let l_cell = plane.n_col as f64 * tech.pitch_col;
        let l_stair = plane.n_stack as f64 * tech.pitch_stair;
        let width = plane.n_row as f64 * tech.pitch_row;
        let l_bl = width;
        let l_bls = l_cell;
        PlaneGeometry {
            l_cell,
            l_stair,
            width,
            l_bl,
            l_bls,
            r_bl: tech.r_bl_per_m * l_bl,
            c_bl: tech.c_bl_per_m * l_bl,
            r_bls: tech.r_bls_per_m * l_bls,
            c_bls: tech.c_bls_per_m * l_bls,
            c_cell: tech.c_wl_cell_per_m * l_cell,
            c_stair: tech.c_wl_stair_per_m * l_stair,
        }
    }

    /// Full plane footprint (m²) with the complete staircase — the
    /// denominator of the Eq. (4) density definition.
    pub fn area_full(&self) -> f64 {
        (self.l_cell + self.l_stair) * self.width
    }

    /// Die-floorplan footprint (m²) with staircase sharing between
    /// mirrored block pairs (paper §V-C die-area accounting).
    pub fn area_floorplan(&self, tech: &TechParams) -> f64 {
        (self.l_cell + tech.staircase_share * self.l_stair) * self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{size_a_plane, size_b_plane};

    #[test]
    fn lengths_scale_with_dims() {
        let t = TechParams::default();
        let a = PlaneGeometry::of(&size_a_plane(), &t);
        let b = PlaneGeometry::of(&size_b_plane(), &t);
        assert!((a.l_cell / b.l_cell - 2.0).abs() < 1e-12); // 2048 vs 1024 cols
        assert!((a.l_stair / b.l_stair - 2.0).abs() < 1e-12); // 128 vs 64 stacks
        assert!((a.width - b.width).abs() < 1e-18); // both 256 rows
    }

    #[test]
    fn bl_tau_scales_quadratically_with_rows() {
        // Paper §III-B: τ_BL ∝ N_row².
        let t = TechParams::default();
        let mut p = size_a_plane();
        let g1 = PlaneGeometry::of(&p, &t);
        p.n_row *= 4;
        let g2 = PlaneGeometry::of(&p, &t);
        let tau1 = g1.r_bl * g1.c_bl;
        let tau2 = g2.r_bl * g2.c_bl;
        assert!((tau2 / tau1 - 16.0).abs() < 1e-9);
    }

    #[test]
    fn floorplan_smaller_than_full() {
        let t = TechParams::default();
        let g = PlaneGeometry::of(&size_a_plane(), &t);
        assert!(g.area_floorplan(&t) < g.area_full());
    }

    #[test]
    fn stair_cap_comparable_to_cell_cap_at_512_cols() {
        // Paper: "For N_stack = 128, C_stair is comparable to C_cell with
        // N_col = 512."
        let t = TechParams::default();
        let p = PlaneConfig { n_col: 512, ..size_a_plane() };
        let g = PlaneGeometry::of(&p, &t);
        let ratio = g.c_stair / g.c_cell;
        assert!(ratio > 0.3 && ratio < 3.0, "C_stair/C_cell = {ratio}");
    }
}
