//! 9-bit SAR ADC model for the PIM read path (paper §III-B: the modified
//! 3D-FPIM simulator incorporates 4:1 column muxes, 9-bit SAR ADCs, and
//! shift adders). Latency/energy feed the plane model; area feeds Table II.

use super::tech::TechParams;

/// Successive-approximation ADC.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SarAdc {
    pub bits: usize,
    pub freq_hz: f64,
    /// Energy per conversion (J).
    pub e_conv: f64,
}

impl SarAdc {
    pub fn from_tech(t: &TechParams) -> SarAdc {
        SarAdc { bits: t.adc_bits, freq_hz: t.adc_freq, e_conv: t.e_adc_conv }
    }

    /// One conversion: one clock per bit decision.
    pub fn conversion_time(&self) -> f64 {
        self.bits as f64 / self.freq_hz
    }

    /// Digitize an analog accumulation value: clip to the signed range the
    /// resolution supports. This is the quantization the Pallas kernel and
    /// its jnp oracle replicate bit-exactly (python/compile/kernels).
    pub fn quantize(&self, acc: i64) -> i64 {
        let max = (1i64 << (self.bits - 1)) - 1;
        let min = -(1i64 << (self.bits - 1));
        acc.clamp(min, max)
    }

    /// The signed full-scale range `[min, max]`.
    pub fn range(&self) -> (i64, i64) {
        ((-(1i64 << (self.bits - 1))), (1i64 << (self.bits - 1)) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adc9() -> SarAdc {
        SarAdc::from_tech(&TechParams::default())
    }

    #[test]
    fn nine_bit_range() {
        let a = adc9();
        assert_eq!(a.range(), (-256, 255));
    }

    #[test]
    fn quantize_passes_in_range() {
        let a = adc9();
        for v in [-256i64, -1, 0, 1, 255] {
            assert_eq!(a.quantize(v), v);
        }
    }

    #[test]
    fn quantize_clips_out_of_range() {
        let a = adc9();
        assert_eq!(a.quantize(300), 255);
        assert_eq!(a.quantize(-300), -256);
    }

    #[test]
    fn conversion_time_is_bits_over_freq() {
        let a = adc9();
        assert!((a.conversion_time() - 9.0 / 200e6).abs() < 1e-18);
    }
}
