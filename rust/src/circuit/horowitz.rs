//! Horowitz delay model.
//!
//! The paper (Eq. 5) uses `h(τ) ∝ τ^1.5` for the RC stages of the PIM read
//! path. A pure 1.5-power law diverges for the millimetre-length bitlines
//! of conventional planes, so past `tau_sat` the model continues with the
//! tangent line (C¹-continuous), recovering the classic linear `~0.69·RC`
//! regime for strongly-driven long lines.

/// Horowitz delay parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Horowitz {
    /// Dimensionless gain applied to the power law.
    pub k: f64,
    /// Normalization time constant (s) so `h` has time units.
    pub tau_ref: f64,
    /// Saturation point (s) beyond which the delay grows linearly.
    pub tau_sat: f64,
    /// Linear-regime slope (delay per unit τ) beyond `tau_sat` —
    /// the distributed-line limit for very long bitlines.
    pub k_lin: f64,
}

impl Default for Horowitz {
    fn default() -> Self {
        Horowitz { k: 2.2, tau_ref: 10e-9, tau_sat: 100e-9, k_lin: 3.0 }
    }
}

impl Horowitz {
    /// Delay for RC time constant `tau` (seconds).
    pub fn delay(&self, tau: f64) -> f64 {
        assert!(tau >= 0.0, "negative tau {tau}");
        if tau <= self.tau_sat {
            self.k * tau * (tau / self.tau_ref).sqrt()
        } else {
            let h_sat = self.k * self.tau_sat * (self.tau_sat / self.tau_ref).sqrt();
            h_sat + self.k_lin * (tau - self.tau_sat)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_below_saturation() {
        let h = Horowitz::default();
        // h(4τ) = 8 h(τ) in the τ^1.5 regime.
        let a = h.delay(1e-9);
        let b = h.delay(4e-9);
        assert!((b / a - 8.0).abs() < 1e-9, "ratio {}", b / a);
    }

    #[test]
    fn continuous_at_saturation() {
        let h = Horowitz::default();
        let eps = 1e-15;
        let below = h.delay(h.tau_sat - eps);
        let above = h.delay(h.tau_sat + eps);
        assert!((below - above).abs() < 1e-12);
    }

    #[test]
    fn linear_slope_matches_k_lin() {
        let h = Horowitz::default();
        let d1 = h.delay(1e-6);
        let d2 = h.delay(2e-6);
        assert!(((d2 - d1) / 1e-6 - h.k_lin).abs() < 1e-9);
    }

    #[test]
    fn linear_beyond_saturation() {
        let h = Horowitz::default();
        let d1 = h.delay(h.tau_sat * 10.0);
        let d2 = h.delay(h.tau_sat * 20.0);
        let slope1 = d2 - d1;
        let d3 = h.delay(h.tau_sat * 30.0);
        let slope2 = d3 - d2;
        assert!((slope1 - slope2).abs() / slope1 < 1e-9);
    }

    #[test]
    fn monotone() {
        let h = Horowitz::default();
        let mut prev = 0.0;
        for i in 1..1000 {
            let d = h.delay(i as f64 * 1e-9);
            assert!(d > prev);
            prev = d;
        }
    }

    #[test]
    fn zero_tau_zero_delay() {
        assert_eq!(Horowitz::default().delay(0.0), 0.0);
    }
}
