//! Plane-size selection (paper §III-B conclusion): among all plane
//! configurations meeting the PIM-latency budget, pick the densest; break
//! ties by per-plane capacity (density is row-independent, so the largest
//! feasible row count wins), then by latency.
//!
//! With the default technology this selects the paper's Size A,
//! `256 × 2048 × 128`.

use super::sweep::{sweep_grid, DsePoint};
use crate::circuit::TechParams;

/// Selection constraints.
///
/// The grid bounds encode the paper's process and architecture envelope:
/// * `stacks ≤ 128` — the Table-I device is a 128-WL-layer part (the
///   sweep itself, Fig. 6, explores up to 512 to show the trend).
/// * `rows ≥ 256` — 64 blocks × 4 BLS per block (Table I) is the minimum
///   block population for erase-unit management and tile double-buffering
///   (two independent 128-row PIM groups per plane).
/// * `cols ≤ 16K` — the largest page size in commercial parts.
#[derive(Debug, Clone, Copy)]
pub struct SelectionCriteria {
    /// Hard budget on the 8-bit T_PIM (s). Paper: ~2 µs.
    pub max_t_pim: f64,
    /// Grid bounds (inclusive, powers of two).
    pub rows: (usize, usize),
    pub cols: (usize, usize),
    pub stacks: (usize, usize),
}

impl Default for SelectionCriteria {
    fn default() -> Self {
        SelectionCriteria {
            max_t_pim: 2.0e-6,
            rows: (256, 2048),
            cols: (256, 16384),
            stacks: (32, 128),
        }
    }
}

/// Run the selection. Returns the winner and all feasible points
/// (for reporting), or `None` when nothing meets the budget.
pub fn select_plane(criteria: &SelectionCriteria, tech: &TechParams) -> Option<(DsePoint, Vec<DsePoint>)> {
    let grid = sweep_grid(criteria.rows, criteria.cols, criteria.stacks, tech);
    let feasible: Vec<DsePoint> = grid.into_iter().filter(|p| p.t_pim <= criteria.max_t_pim).collect();
    let winner = feasible
        .iter()
        .max_by(|a, b| {
            a.density
                .total_cmp(&b.density)
                .then_with(|| a.plane.capacity_bits().cmp(&b.plane.capacity_bits()))
                .then_with(|| b.t_pim.total_cmp(&a.t_pim))
        })?
        .clone();
    Some((winner, feasible))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::size_a_plane;

    #[test]
    fn selects_paper_size_a() {
        // The headline DSE result of §III-B: 256 × 2048 × 128.
        let tech = TechParams::default();
        let (winner, feasible) = select_plane(&SelectionCriteria::default(), &tech).unwrap();
        assert!(!feasible.is_empty());
        assert_eq!(
            winner.plane,
            size_a_plane(),
            "DSE selected {:?} (density {:.2} Gb/mm², T_PIM {})",
            winner.plane,
            winner.density,
            crate::util::units::fmt_time(winner.t_pim)
        );
    }

    #[test]
    fn all_feasible_meet_budget() {
        let tech = TechParams::default();
        let crit = SelectionCriteria::default();
        let (_, feasible) = select_plane(&crit, &tech).unwrap();
        for p in &feasible {
            assert!(p.t_pim <= crit.max_t_pim);
        }
    }

    #[test]
    fn impossible_budget_yields_none() {
        let tech = TechParams::default();
        let crit = SelectionCriteria { max_t_pim: 1e-12, ..Default::default() };
        assert!(select_plane(&crit, &tech).is_none());
    }

    #[test]
    fn winner_dominates_feasible_on_density() {
        let tech = TechParams::default();
        let (winner, feasible) = select_plane(&SelectionCriteria::default(), &tech).unwrap();
        for p in &feasible {
            assert!(p.density <= winner.density + 1e-12);
        }
    }
}
