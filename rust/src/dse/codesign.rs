//! SLO-frontier-driven co-design: close the loop between the plane-size
//! DSE (paper §III-B) and the serving stack.
//!
//! The classic selection in [`super::select`] ranks candidate geometries
//! by a kernel-latency proxy (`t_pim` under a budget, then density). The
//! co-design campaign evaluates each candidate by what the paper's
//! deployment actually cares about: for every plane geometry in a
//! [`SelectionCriteria`] grid it derives a full [`SystemConfig`], builds
//! the exact [`LatencyTable`], runs the serving rate sweep for a workload
//! mix, reduces it with [`max_sustained_rates`] to the *max offered rate
//! sustaining ≥ X% SLO attainment*, prices die area through
//! [`AreaModel::die_array_mm2`] / [`DieBudget`], prices energy through
//! the per-token [`EnergySchedule`], and Pareto-ranks the candidates
//! over {sustained rate ↑, die mm² ↓, J/Mtok ↓} with the generic
//! k-objective frontier in [`super::frontier`].
//!
//! `criteria.max_t_pim` is deliberately **not** applied here: a slow
//! plane already pays for its latency through the latency table (it
//! sustains a lower rate or misses its TPOT SLOs outright), so pruning
//! by the kernel proxy would beg the question the campaign exists to
//! answer.
//!
//! Candidates are embarrassingly parallel, so [`run_codesign`] fans them
//! out on the shared [`fan_out_indexed`] scoped-thread scaffold with
//! results landed by grid index; each candidate's internal rate sweep
//! runs sequentially ([`sweep_rates_seq`]) so parallelism lives at
//! exactly one level. The output is byte-equal to the sequential
//! [`run_codesign_seq`] (asserted in `tests/codesign.rs`). Exposed as
//! `repro codesign`; see `docs/CODESIGN.md`.

use super::frontier::pareto_indices;
use super::select::SelectionCriteria;
use super::sweep::{sweep_grid, DsePoint};
use crate::area::{AreaModel, DieBudget};
use crate::circuit::TechParams;
use crate::config::presets::table1_system;
use crate::config::{PlaneConfig, SystemConfig};
use crate::coordinator::router::{policy_from_name, POLICY_NAMES};
use crate::coordinator::sweep::{
    fan_out_indexed, max_sustained_rates, sweep_rates_seq, validate_rates, SloFrontier,
};
use crate::coordinator::{TrafficConfig, WorkloadMix};
use crate::llm::{EnergySchedule, LatencyTable, ModelShape};
use crate::util::benchkit::JsonEmitter;
use crate::util::table::Table;
use crate::util::units::fmt_time;
use anyhow::{bail, Result};

/// One co-design campaign: the candidate grid plus the serving scenario
/// every candidate is judged under.
#[derive(Debug, Clone)]
pub struct CodesignSpec {
    /// Grid bounds (the `max_t_pim` field is ignored — see module docs).
    pub criteria: SelectionCriteria,
    /// Workload preset name or TOML path ([`WorkloadMix::resolve`]).
    pub workload: String,
    /// Offered arrival rates swept per candidate (requests/s).
    pub rates: Vec<f64>,
    /// Scheduling policies swept per candidate.
    pub policies: Vec<String>,
    /// Minimum per-class SLO attainment defining "sustained" (e.g. 0.99).
    pub attainment: f64,
    /// Die-area budget in mm²; `None` uses the paper's package budget
    /// ([`DieBudget::default`], high end ≈ 7.5 mm²).
    pub budget_mm2: Option<f64>,
    pub devices: usize,
    /// Requests simulated per (policy, rate) point.
    pub requests: usize,
    pub seed: u64,
    pub model: ModelShape,
}

impl CodesignSpec {
    /// Defaults mirroring `serve-sim --sweep`: the full §III-B grid, the
    /// chat preset, all flash policies, 99% attainment, the paper budget.
    pub fn new(model: ModelShape) -> CodesignSpec {
        CodesignSpec {
            criteria: SelectionCriteria::default(),
            workload: "chat".to_string(),
            rates: vec![2.0, 4.0, 8.0, 16.0, 32.0],
            policies: POLICY_NAMES.iter().map(|p| p.to_string()).collect(),
            attainment: 0.99,
            budget_mm2: None,
            devices: 4,
            requests: 400,
            seed: 42,
            model,
        }
    }

    /// Effective budget threshold in mm².
    pub fn budget(&self) -> f64 {
        self.budget_mm2.unwrap_or(DieBudget::default().per_die_mm2().1)
    }

    fn validate(&self) -> Result<()> {
        validate_rates(&self.rates)?;
        if self.policies.is_empty() {
            bail!("codesign needs at least one policy");
        }
        for p in &self.policies {
            if policy_from_name(p).is_none() {
                bail!("unknown policy {p:?}");
            }
        }
        if !(self.attainment > 0.0 && self.attainment <= 1.0) {
            bail!("--attainment is a fraction; need 0 < a <= 1, got {}", self.attainment);
        }
        if let Some(b) = self.budget_mm2 {
            if !(b.is_finite() && b > 0.0) {
                bail!("--budget-mm2 must be positive and finite, got {b}");
            }
        }
        if self.devices == 0 || self.requests == 0 {
            bail!("--devices and --requests must be positive");
        }
        Ok(())
    }
}

/// One evaluated candidate geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct CodesignPoint {
    pub plane: PlaneConfig,
    /// Kernel-latency proxy (s), kept for comparison with the §III-B
    /// ranking — not an objective here.
    pub t_pim: f64,
    /// Cell density (Gb/mm²).
    pub density: f64,
    /// Objective ↓: array area of one die at this geometry (mm²).
    pub die_mm2: f64,
    pub fits_budget: bool,
    /// Objective ↓: decode energy per million tokens (J/Mtok) at the
    /// mix's mean decode context.
    pub energy_per_mtok: f64,
    /// Objective ↑: best policy's worst-class max sustained rate
    /// (requests/s); 0.0 when no swept rate sustains the attainment.
    pub sustained_rate: f64,
    /// Policy achieving `sustained_rate` (first in spec order on ties);
    /// `"-"` when nothing sustains.
    pub best_policy: String,
    /// Full per-(policy, class) reduction of the candidate's sweep — the
    /// same rows `serve-sim --sweep` prints as its SLO frontier.
    pub frontiers: Vec<SloFrontier>,
    /// Member of the {rate ↑, mm² ↓, J/Mtok ↓} Pareto frontier.
    pub on_frontier: bool,
}

impl CodesignPoint {
    /// Canonical `RxCxS` geometry key (e.g. `256x2048x128`).
    pub fn geometry(&self) -> String {
        format!("{}x{}x{}", self.plane.n_row, self.plane.n_col, self.plane.n_stack)
    }
}

/// Campaign result: every candidate in grid order plus the frontier.
#[derive(Debug, Clone, PartialEq)]
pub struct CodesignReport {
    /// Resolved mix name (preset or TOML `name`).
    pub workload: String,
    pub attainment: f64,
    pub budget_mm2: f64,
    /// Candidates in canonical grid order (rows ↑, cols ↑, stacks ↑).
    pub points: Vec<CodesignPoint>,
    /// Ascending indices into `points` of the Pareto frontier.
    pub frontier: Vec<usize>,
}

/// Derive the candidate's full system: the Table-I organization with its
/// plane swapped — the same organization every candidate shares, so the
/// geometry is the only moving part.
pub fn derive_system(plane: PlaneConfig) -> SystemConfig {
    SystemConfig {
        name: format!("codesign-{}x{}x{}", plane.n_row, plane.n_col, plane.n_stack),
        plane,
        ..table1_system()
    }
}

/// Share-weighted mean decode context of a mix (mean prompt plus half
/// the mean output), the context the energy objective is priced at.
pub fn representative_context(mix: &WorkloadMix) -> usize {
    let total: f64 = mix.classes().iter().map(|c| c.share).sum();
    let l = mix
        .classes()
        .iter()
        .map(|c| {
            let l_in = (c.input_tokens.lo + c.input_tokens.hi) as f64 / 2.0;
            let l_out = (c.output_tokens.lo + c.output_tokens.hi) as f64 / 2.0;
            c.share * (l_in + l_out / 2.0)
        })
        .sum::<f64>()
        / total;
    l.round() as usize
}

/// Evaluate one candidate end to end: latency table → rate sweep → SLO
/// frontier → area and energy pricing.
fn evaluate(dse: &DsePoint, spec: &CodesignSpec, tech: &TechParams, mix: &WorkloadMix) -> CodesignPoint {
    let sys = derive_system(dse.plane);
    let table = LatencyTable::build(&sys, tech, spec.model.clone());
    let mut cfg = TrafficConfig::default_for(spec.devices);
    cfg.requests = spec.requests;
    cfg.seed = spec.seed;
    cfg.workload = Some(mix.clone());
    let policies: Vec<&str> = spec.policies.iter().map(String::as_str).collect();
    let points = sweep_rates_seq(&sys, &spec.model, &table, &cfg, &spec.rates, &policies)
        .expect("spec validated before the campaign ran");
    let frontiers = max_sustained_rates(&points, spec.attainment);

    // A policy sustains the rate its *worst* class still attains at;
    // the candidate scores its best policy (first in spec order on ties).
    let mut sustained_rate = 0.0;
    let mut best_policy = "-".to_string();
    for p in &spec.policies {
        let worst = frontiers
            .iter()
            .filter(|f| f.policy == *p)
            .map(|f| f.max_rate.unwrap_or(0.0))
            .min_by(f64::total_cmp)
            .unwrap_or(0.0);
        if worst > sustained_rate {
            sustained_rate = worst;
            best_policy = p.clone();
        }
    }

    let die_mm2 = AreaModel::new(tech).die_array_mm2(&sys);
    let energy = EnergySchedule::new(&sys, tech, spec.model.clone());
    let energy_per_mtok = energy.token_energy(representative_context(mix)).total() * 1e6;
    CodesignPoint {
        plane: dse.plane,
        t_pim: dse.t_pim,
        density: dse.density,
        die_mm2,
        fits_budget: die_mm2 <= spec.budget(),
        energy_per_mtok,
        sustained_rate,
        best_policy,
        frontiers,
        on_frontier: false, // ranked below, over the whole grid
    }
}

/// Pareto-rank evaluated candidates over {rate ↑, mm² ↓, J/Mtok ↓} and
/// assemble the report.
fn rank(spec: &CodesignSpec, mix_name: &str, mut points: Vec<CodesignPoint>) -> Result<CodesignReport> {
    let objectives: Vec<[f64; 3]> =
        points.iter().map(|p| [-p.sustained_rate, p.die_mm2, p.energy_per_mtok]).collect();
    let frontier = pareto_indices(&objectives)?;
    for &i in &frontier {
        points[i].on_frontier = true;
    }
    Ok(CodesignReport {
        workload: mix_name.to_string(),
        attainment: spec.attainment,
        budget_mm2: spec.budget(),
        points,
        frontier,
    })
}

fn candidates(spec: &CodesignSpec, tech: &TechParams) -> Result<(Vec<DsePoint>, WorkloadMix)> {
    spec.validate()?;
    let mix = WorkloadMix::resolve(&spec.workload)?;
    let c = &spec.criteria;
    let grid = sweep_grid(c.rows, c.cols, c.stacks, tech);
    if grid.is_empty() {
        bail!(
            "empty candidate grid for rows {:?} cols {:?} stacks {:?} (bounds must be powers of two)",
            c.rows,
            c.cols,
            c.stacks
        );
    }
    Ok((grid, mix))
}

/// Run the campaign, candidates fanned out over scoped threads with
/// results landed by grid index — byte-equal to [`run_codesign_seq`].
pub fn run_codesign(spec: &CodesignSpec, tech: &TechParams) -> Result<CodesignReport> {
    let (grid, mix) = candidates(spec, tech)?;
    let points = fan_out_indexed(&grid, |d| evaluate(d, spec, tech, &mix));
    rank(spec, mix.name(), points)
}

/// Sequential twin of [`run_codesign`] — the determinism oracle.
pub fn run_codesign_seq(spec: &CodesignSpec, tech: &TechParams) -> Result<CodesignReport> {
    let (grid, mix) = candidates(spec, tech)?;
    let points = grid.iter().map(|d| evaluate(d, spec, tech, &mix)).collect();
    rank(spec, mix.name(), points)
}

/// Display order of the human table: frontier first, then sustained rate
/// ↓, area ↑, energy ↑, geometry key — a deterministic total order.
fn display_order(points: &[CodesignPoint]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&i, &j| {
        let (a, b) = (&points[i], &points[j]);
        b.on_frontier
            .cmp(&a.on_frontier)
            .then(b.sustained_rate.total_cmp(&a.sustained_rate))
            .then(a.die_mm2.total_cmp(&b.die_mm2))
            .then(a.energy_per_mtok.total_cmp(&b.energy_per_mtok))
            .then(a.geometry().cmp(&b.geometry()))
    });
    order
}

/// Render the campaign as an ASCII table of the top `top` candidates in
/// [`display_order`], with a one-line summary header.
pub fn render_codesign(report: &CodesignReport, top: usize) -> String {
    let mut out = format!(
        "codesign: {} candidate(s), {} on the {{rate, mm2, J/Mtok}} frontier \
         (workload {}, >= {:.0}% SLO attainment, budget {:.2} mm2)\n",
        report.points.len(),
        report.frontier.len(),
        report.workload,
        report.attainment * 100.0,
        report.budget_mm2,
    );
    let mut t = Table::new(&[
        "geometry",
        "frontier",
        "rate req/s",
        "policy",
        "die mm2",
        "fits",
        "J/Mtok",
        "T_PIM",
        "Gb/mm2",
    ]);
    for &i in display_order(&report.points).iter().take(top) {
        let p = &report.points[i];
        t.row(&[
            p.geometry(),
            if p.on_frontier { "*".to_string() } else { "".to_string() },
            if p.sustained_rate > 0.0 { format!("{:.1}", p.sustained_rate) } else { "none".into() },
            p.best_policy.clone(),
            format!("{:.2}", p.die_mm2),
            if p.fits_budget { "yes".to_string() } else { "no".to_string() },
            format!("{:.1}", p.energy_per_mtok),
            fmt_time(p.t_pim),
            format!("{:.2}", p.density),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Canonical metrics document: per candidate, in grid order,
/// `codesign/<RxCxS>/<workload>/<metric>` keys, followed by the campaign
/// summary counts — deterministic byte-for-byte for a given spec (the CI
/// codesign-smoke guard `cmp`s two runs).
pub fn codesign_metrics(report: &CodesignReport) -> JsonEmitter {
    let mut json = JsonEmitter::new();
    for p in &report.points {
        let key = format!("codesign/{}/{}", p.geometry(), report.workload);
        json.metric(&format!("{key}/sustained_rate_req_s"), p.sustained_rate, "requests/s");
        json.metric(&format!("{key}/die_mm2"), p.die_mm2, "mm2");
        json.metric(&format!("{key}/energy_per_mtok_j"), p.energy_per_mtok, "J/Mtok");
        json.metric(&format!("{key}/t_pim_s"), p.t_pim, "s");
        json.metric(&format!("{key}/density_gb_mm2"), p.density, "Gb/mm2");
        json.metric(&format!("{key}/fits_budget"), if p.fits_budget { 1.0 } else { 0.0 }, "bool");
        json.metric(&format!("{key}/on_frontier"), if p.on_frontier { 1.0 } else { 0.0 }, "bool");
    }
    json.metric("codesign_candidates", report.points.len() as f64, "geometries");
    json.metric("codesign_frontier_size", report.frontier.len() as f64, "geometries");
    json
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::size_a_plane;
    use crate::llm::OptModel;

    /// A one-geometry, one-policy spec small enough for unit tests; the
    /// cross-grid properties live in `tests/codesign.rs`.
    fn tiny_spec() -> CodesignSpec {
        CodesignSpec {
            criteria: SelectionCriteria {
                rows: (256, 256),
                cols: (2048, 2048),
                stacks: (128, 128),
                ..Default::default()
            },
            rates: vec![8.0],
            policies: vec!["least-loaded".to_string()],
            devices: 2,
            requests: 30,
            ..CodesignSpec::new(OptModel::Opt6_7b.shape())
        }
    }

    #[test]
    fn single_candidate_campaign_is_its_own_frontier() {
        let report = run_codesign(&tiny_spec(), &TechParams::default()).unwrap();
        assert_eq!(report.points.len(), 1);
        assert_eq!(report.frontier, vec![0]);
        let p = &report.points[0];
        assert_eq!(p.plane, size_a_plane());
        assert_eq!(p.geometry(), "256x2048x128");
        assert!(p.on_frontier);
        assert!(p.die_mm2 > 0.0 && p.energy_per_mtok > 0.0 && p.t_pim > 0.0);
        assert!(p.fits_budget, "Size A must fit the paper budget, got {} mm2", p.die_mm2);
        assert_eq!(p.frontiers.len(), 1, "one policy x one chat class");
        let rendered = render_codesign(&report, 10);
        assert!(rendered.contains("256x2048x128") && rendered.contains("frontier"), "{rendered}");
        let json = codesign_metrics(&report);
        assert_eq!(json.len(), 7 + 2);
    }

    #[test]
    fn spec_validation_rejects_bad_input() {
        let tech = TechParams::default();
        let mut s = tiny_spec();
        s.rates.clear();
        assert!(run_codesign(&s, &tech).is_err());
        let mut s = tiny_spec();
        s.policies = vec!["fifo".to_string()];
        assert!(run_codesign(&s, &tech).is_err());
        let mut s = tiny_spec();
        s.attainment = 1.5;
        assert!(run_codesign(&s, &tech).is_err());
        let mut s = tiny_spec();
        s.budget_mm2 = Some(-1.0);
        assert!(run_codesign(&s, &tech).is_err());
        let mut s = tiny_spec();
        s.workload = "bogus-mix".to_string();
        assert!(run_codesign(&s, &tech).is_err());
        let mut s = tiny_spec();
        s.criteria.rows = (300, 300); // not a power of two -> empty grid
        assert!(run_codesign(&s, &tech).is_err());
    }

    #[test]
    fn representative_context_weights_by_share() {
        let mix = WorkloadMix::preset("chat").unwrap();
        // chat: mean input 192, mean output 48 -> 192 + 24 = 216.
        assert_eq!(representative_context(&mix), 216);
    }

    #[test]
    fn derived_system_keeps_the_table1_organization() {
        let sys = derive_system(size_a_plane());
        let base = table1_system();
        assert_eq!(sys.org, base.org);
        assert_eq!(sys.plane, base.plane);
        assert_eq!(sys.name, "codesign-256x2048x128");
        sys.validate().unwrap();
    }
}
