//! Pareto analysis of the latency/density trade-off (paper §III-A:
//! "there is a trade-off between the PIM latency and the cell density").

use super::sweep::DsePoint;

/// The (latency ↓, density ↑) Pareto frontier, sorted by latency.
/// A point is dominated if another point has both lower-or-equal latency
/// and higher-or-equal density (strictly better in at least one).
pub fn pareto_frontier(points: &[DsePoint]) -> Vec<DsePoint> {
    let mut frontier: Vec<DsePoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.t_pim <= p.t_pim && q.density > p.density) || (q.t_pim < p.t_pim && q.density >= p.density)
        });
        if !dominated {
            frontier.push(p.clone());
        }
    }
    frontier.sort_by(|a, b| a.t_pim.partial_cmp(&b.t_pim).unwrap());
    frontier.dedup_by(|a, b| a.plane == b.plane);
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::dse::sweep::sweep_grid;

    #[test]
    fn frontier_is_monotone() {
        let tech = TechParams::default();
        let grid = sweep_grid((64, 1024), (256, 4096), (32, 256), &tech);
        let f = pareto_frontier(&grid);
        assert!(!f.is_empty());
        // Along the frontier, higher latency must buy higher density.
        for w in f.windows(2) {
            assert!(w[1].t_pim >= w[0].t_pim);
            assert!(w[1].density >= w[0].density, "frontier not monotone in density");
        }
    }

    #[test]
    fn frontier_points_not_dominated() {
        let tech = TechParams::default();
        let grid = sweep_grid((64, 512), (512, 2048), (64, 256), &tech);
        let f = pareto_frontier(&grid);
        for p in &f {
            for q in &grid {
                let strictly_dominates =
                    q.t_pim < p.t_pim && q.density > p.density;
                assert!(!strictly_dominates, "frontier point {:?} dominated by {:?}", p.plane, q.plane);
            }
        }
    }
}
