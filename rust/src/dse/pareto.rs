//! Pareto analysis of the latency/density trade-off (paper §III-A:
//! "there is a trade-off between the PIM latency and the cell density").
//!
//! A thin wrapper over the generic k-objective frontier in
//! [`super::frontier`]: latency minimizes directly, density maximizes by
//! negation, and the 2-objective fast path (sort + scan) replaces the old
//! quadratic pairwise check. Points whose latency or density is NaN are
//! dropped up front — a NaN is never on the frontier and never dominates
//! anything (the old code's `partial_cmp(..).unwrap()` panicked on it).

use super::frontier::pareto_indices;
use super::sweep::DsePoint;

/// The (latency ↓, density ↑) Pareto frontier, sorted by latency, with
/// equal-plane duplicates collapsed. A point is dominated if another has
/// both lower-or-equal latency and higher-or-equal density (strictly
/// better in at least one). NaN-valued points are silently dropped.
pub fn pareto_frontier(points: &[DsePoint]) -> Vec<DsePoint> {
    let finite: Vec<&DsePoint> =
        points.iter().filter(|p| !p.t_pim.is_nan() && !p.density.is_nan()).collect();
    let objectives: Vec<[f64; 2]> = finite.iter().map(|p| [p.t_pim, -p.density]).collect();
    let keep = pareto_indices(&objectives).expect("NaN objectives filtered above");
    let mut frontier: Vec<DsePoint> = keep.into_iter().map(|i| finite[i].clone()).collect();
    frontier.sort_by(|a, b| a.t_pim.total_cmp(&b.t_pim));
    frontier.dedup_by(|a, b| a.plane == b.plane);
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::TechParams;
    use crate::dse::sweep::sweep_grid;

    #[test]
    fn frontier_is_monotone() {
        let tech = TechParams::default();
        let grid = sweep_grid((64, 1024), (256, 4096), (32, 256), &tech);
        let f = pareto_frontier(&grid);
        assert!(!f.is_empty());
        // Along the frontier, higher latency must buy higher density.
        for w in f.windows(2) {
            assert!(w[1].t_pim >= w[0].t_pim);
            assert!(w[1].density >= w[0].density, "frontier not monotone in density");
        }
    }

    #[test]
    fn frontier_points_not_dominated() {
        let tech = TechParams::default();
        let grid = sweep_grid((64, 512), (512, 2048), (64, 256), &tech);
        let f = pareto_frontier(&grid);
        for p in &f {
            for q in &grid {
                let strictly_dominates =
                    q.t_pim < p.t_pim && q.density > p.density;
                assert!(!strictly_dominates, "frontier point {:?} dominated by {:?}", p.plane, q.plane);
            }
        }
    }

    #[test]
    fn nan_points_are_dropped_not_panicked() {
        let tech = TechParams::default();
        let mut grid = sweep_grid((64, 256), (256, 1024), (32, 128), &tech);
        let clean = pareto_frontier(&grid);
        // Poison one copy of every point: NaN latency on the first, NaN
        // density on the second. The old implementation panicked here.
        let mut a = grid[0].clone();
        a.t_pim = f64::NAN;
        let mut b = grid[1].clone();
        b.density = f64::NAN;
        grid.push(a);
        grid.push(b);
        let f = pareto_frontier(&grid);
        assert!(f.iter().all(|p| !p.t_pim.is_nan() && !p.density.is_nan()));
        assert_eq!(f.len(), clean.len(), "NaN points must not displace real ones");
    }
}
