//! Design-space exploration of the 3D NAND plane size (paper §III-B,
//! Fig. 6): sweep `N_row × N_col × N_stack`, evaluate latency / energy /
//! density, and select the configuration that maximizes cell density under
//! the PIM-latency budget.

pub mod pareto;
pub mod select;
pub mod sweep;

pub use pareto::pareto_frontier;
pub use select::{select_plane, SelectionCriteria};
pub use sweep::{fig6_sweeps, sweep_grid, DsePoint, SweepAxis};
