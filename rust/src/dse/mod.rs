//! Design-space exploration of the 3D NAND plane size (paper §III-B,
//! Fig. 6): sweep `N_row × N_col × N_stack`, evaluate latency / energy /
//! density, and select the configuration that maximizes cell density under
//! the PIM-latency budget. The [`codesign`] campaign closes the loop with
//! the serving stack: candidates are Pareto-ranked ([`frontier`]) by the
//! SLO frontier they sustain, the die area they cost, and their energy
//! per token — not by the kernel-latency proxy alone.

pub mod codesign;
pub mod frontier;
pub mod pareto;
pub mod select;
pub mod sweep;

pub use codesign::{
    codesign_metrics, render_codesign, run_codesign, run_codesign_seq, CodesignPoint,
    CodesignReport, CodesignSpec,
};
pub use frontier::{dominates, pareto_indices};
pub use pareto::pareto_frontier;
pub use select::{select_plane, SelectionCriteria};
pub use sweep::{fig6_sweeps, sweep_grid, DsePoint, SweepAxis};
