//! Sweep the plane configuration space and evaluate each point with the
//! circuit model. `fig6_sweeps` reproduces the three 1-D sweeps of Fig. 6
//! (one dimension varied, the others fixed at the paper's base point
//! `N_row = 256, N_col = 1K, N_stack = 128`).

use crate::circuit::{cell_density_gb_mm2, PimEnergy, PlaneLatency, TechParams};
use crate::config::{CellKind, PlaneConfig};

/// Rows simultaneously activated per PIM dot product (paper: 128 BLSs).
pub const PIM_ACTIVE_ROWS: usize = 128;
/// LLM activation input-bit sparsity (paper: ≈ 0.5).
pub const INPUT_SPARSITY: f64 = 0.5;
/// Input bit-width of the Fig. 6 evaluation (8-bit activations).
pub const INPUT_BITS: usize = 8;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub plane: PlaneConfig,
    /// T_PIM for an 8-bit input (s) — Fig. 6a.
    pub t_pim: f64,
    /// Latency breakdown for reporting.
    pub latency: PlaneLatency,
    /// Energy of one full 8-bit PIM op (J) — Fig. 6b.
    pub energy: f64,
    /// Energy breakdown for reporting.
    pub energy_parts: PimEnergy,
    /// Cell density (Gb/mm²) — Fig. 6c.
    pub density: f64,
}

impl DsePoint {
    pub fn evaluate(plane: PlaneConfig, tech: &TechParams) -> DsePoint {
        let latency = PlaneLatency::of(&plane, tech);
        let energy_parts = PimEnergy::of(&plane, tech, PIM_ACTIVE_ROWS, INPUT_SPARSITY);
        DsePoint {
            plane,
            t_pim: latency.t_pim(INPUT_BITS),
            latency,
            energy: energy_parts.total_op(INPUT_BITS),
            energy_parts,
            density: cell_density_gb_mm2(&plane, tech),
        }
    }
}

/// Which plane dimension a 1-D sweep varies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepAxis {
    Rows,
    Cols,
    Stacks,
}

impl SweepAxis {
    pub fn label(self) -> &'static str {
        match self {
            SweepAxis::Rows => "N_row",
            SweepAxis::Cols => "N_col",
            SweepAxis::Stacks => "N_stack",
        }
    }
}

/// The Fig. 6 base point: `N_row=256, N_col=1K, N_stack=128` QLC.
pub fn fig6_base() -> PlaneConfig {
    PlaneConfig::new(256, 1024, 128, CellKind::Qlc)
}

/// Sweep values used for each axis (powers of two, the paper's plotted range).
pub fn axis_values(axis: SweepAxis) -> Vec<usize> {
    match axis {
        SweepAxis::Rows => vec![64, 128, 256, 512, 1024, 2048],
        SweepAxis::Cols => vec![256, 512, 1024, 2048, 4096, 8192, 16384],
        SweepAxis::Stacks => vec![32, 64, 128, 256, 512],
    }
}

/// One 1-D sweep of Fig. 6.
pub fn sweep_axis(axis: SweepAxis, tech: &TechParams) -> Vec<DsePoint> {
    let base = fig6_base();
    axis_values(axis)
        .into_iter()
        .map(|v| {
            let plane = match axis {
                SweepAxis::Rows => PlaneConfig { n_row: v, ..base },
                SweepAxis::Cols => PlaneConfig { n_col: v, ..base },
                SweepAxis::Stacks => PlaneConfig { n_stack: v, ..base },
            };
            DsePoint::evaluate(plane, tech)
        })
        .collect()
}

/// All three Fig. 6 sweeps.
pub fn fig6_sweeps(tech: &TechParams) -> Vec<(SweepAxis, Vec<DsePoint>)> {
    [SweepAxis::Rows, SweepAxis::Cols, SweepAxis::Stacks]
        .into_iter()
        .map(|a| (a, sweep_axis(a, tech)))
        .collect()
}

/// Full 3-D grid over the given power-of-two ranges (inclusive).
pub fn sweep_grid(
    rows: (usize, usize),
    cols: (usize, usize),
    stacks: (usize, usize),
    tech: &TechParams,
) -> Vec<DsePoint> {
    let mut out = Vec::new();
    let mut r = rows.0;
    while r <= rows.1 {
        let mut c = cols.0;
        while c <= cols.1 {
            let mut s = stacks.0;
            while s <= stacks.1 {
                let plane = PlaneConfig::new(r, c, s, CellKind::Qlc);
                if plane.validate().is_ok() {
                    out.push(DsePoint::evaluate(plane, tech));
                }
                s *= 2;
            }
            c *= 2;
        }
        r *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_latency_monotone_along_each_axis() {
        let tech = TechParams::default();
        for (axis, points) in fig6_sweeps(&tech) {
            for w in points.windows(2) {
                assert!(
                    w[1].t_pim > w[0].t_pim,
                    "{} sweep not monotone: {:?} -> {:?}",
                    axis.label(),
                    w[0].t_pim,
                    w[1].t_pim
                );
            }
        }
    }

    #[test]
    fn fig6b_energy_monotone_along_each_axis() {
        let tech = TechParams::default();
        for (axis, points) in fig6_sweeps(&tech) {
            for w in points.windows(2) {
                assert!(w[1].energy > w[0].energy, "{} energy sweep not monotone", axis.label());
            }
        }
    }

    #[test]
    fn fig6c_density_flat_in_rows_rising_in_cols_stacks() {
        let tech = TechParams::default();
        let rows = sweep_axis(SweepAxis::Rows, &tech);
        for w in rows.windows(2) {
            assert!((w[1].density - w[0].density).abs() < 1e-9, "density must not depend on rows");
        }
        for axis in [SweepAxis::Cols, SweepAxis::Stacks] {
            let pts = sweep_axis(axis, &tech);
            for w in pts.windows(2) {
                assert!(w[1].density > w[0].density, "{} density sweep not rising", axis.label());
            }
        }
    }

    #[test]
    fn grid_covers_expected_count() {
        let tech = TechParams::default();
        let g = sweep_grid((64, 256), (256, 1024), (32, 128), &tech);
        assert_eq!(g.len(), 3 * 3 * 3);
    }

    #[test]
    fn precharge_dominates_row_growth() {
        // Paper: t_pre sharply increases with N_row (τ_BL ∝ N_row²).
        let tech = TechParams::default();
        let pts = sweep_axis(SweepAxis::Rows, &tech);
        let first = &pts[0];
        let last = &pts[pts.len() - 1];
        let pre_growth = last.latency.t_pre / first.latency.t_pre;
        let wl_growth = last.latency.t_decwl / first.latency.t_decwl;
        assert!(pre_growth > 10.0, "t_pre grew only {pre_growth}x over the row sweep");
        assert!(wl_growth < 1.01, "t_decWL should not grow with rows");
    }
}
