//! Generic k-objective Pareto dominance frontier.
//!
//! Every objective is **minimized**; callers negate maximize objectives
//! (the co-design campaign ranks {sustained rate ↑, die mm² ↓, J/Mtok ↓}
//! as `[-rate, mm2, j_per_mtok]`). Two entry points:
//!
//! * [`dominates`] — weak Pareto dominance of one vector over another.
//! * [`pareto_indices`] — indices of the non-dominated points of a set,
//!   in ascending input order. Two-objective inputs take an
//!   O(n log n) sort + scan; higher dimensions fall back to the pairwise
//!   check (the grids here are tens-to-hundreds of candidates).
//!
//! NaN objectives are rejected with an error rather than ordered
//! arbitrarily: dominance is not meaningful against NaN, and the legacy
//! frontier's `partial_cmp(..).unwrap()` panic is exactly the failure
//! mode this module replaces. Infinities are legal and compare by IEEE
//! order (an unattainable objective simply never dominates there).
//!
//! The result is a pure function of the *multiset* of points: it is
//! invariant under input permutation (up to the index relabeling), and
//! duplicate points are all kept — equal vectors never dominate each
//! other, since dominance requires strict improvement somewhere
//! (`tests/codesign.rs` holds both properties under seeded random
//! vectors).

use anyhow::{bail, Result};

/// Weak Pareto dominance under minimization: `a` is no worse than `b` in
/// every objective and strictly better in at least one. `false` for
/// vectors of unequal length and for `a == b` (never self-dominating).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x <= y)
        && a.iter().zip(b).any(|(x, y)| x < y)
}

/// Validate a point set: every vector the same arity, no NaN.
fn validate<P: AsRef<[f64]>>(points: &[P]) -> Result<()> {
    let Some(first) = points.first() else {
        return Ok(());
    };
    let k = first.as_ref().len();
    for (i, p) in points.iter().enumerate() {
        let p = p.as_ref();
        if p.len() != k {
            bail!("objective vector {i} has {} objectives, expected {k}", p.len());
        }
        if p.iter().any(|v| v.is_nan()) {
            bail!("objective vector {i} contains NaN: {p:?}");
        }
    }
    Ok(())
}

/// Indices of the non-dominated points, ascending. Errors on NaN
/// objectives or mismatched vector lengths; the empty set yields an
/// empty frontier.
pub fn pareto_indices<P: AsRef<[f64]>>(points: &[P]) -> Result<Vec<usize>> {
    validate(points)?;
    let k = points.first().map_or(0, |p| p.as_ref().len());
    Ok(if k == 2 { frontier_2d(points) } else { frontier_kd(points) })
}

/// Two-objective sort + scan. Sorting by (x ↑, y ↑) puts every possible
/// dominator of a point before it, so one pass suffices: a point is
/// dominated iff some strictly-smaller-x point has y ≤ its own, or an
/// equal-x point has strictly smaller y (the head of its run).
fn frontier_2d<P: AsRef<[f64]>>(points: &[P]) -> Vec<usize> {
    // `+ 0.0` canonicalizes -0.0 to 0.0 so `total_cmp` order, run
    // grouping, and IEEE dominance comparisons all agree.
    let pts: Vec<[f64; 2]> = points
        .iter()
        .map(|p| {
            let p = p.as_ref();
            [p[0] + 0.0, p[1] + 0.0]
        })
        .collect();
    let mut order: Vec<usize> = (0..pts.len()).collect();
    order.sort_by(|&i, &j| pts[i][0].total_cmp(&pts[j][0]).then(pts[i][1].total_cmp(&pts[j][1])));
    let mut keep = Vec::new();
    // Min y among points with strictly smaller x than the current run.
    let mut best_prev = f64::INFINITY;
    let mut i = 0;
    while i < order.len() {
        let x = pts[order[i]][0];
        let run_min_y = pts[order[i]][1];
        let mut j = i;
        while j < order.len() && pts[order[j]][0] == x {
            let y = pts[order[j]][1];
            if best_prev > y && run_min_y >= y {
                keep.push(order[j]);
            }
            j += 1;
        }
        best_prev = best_prev.min(run_min_y);
        i = j;
    }
    keep.sort_unstable();
    keep
}

/// General-k pairwise scan (validated input, so plain `<`/`<=` are total
/// here). Quadratic, which is fine at campaign scale.
fn frontier_kd<P: AsRef<[f64]>>(points: &[P]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| !points.iter().any(|q| dominates(q.as_ref(), points[i].as_ref())))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_requires_strict_improvement() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(dominates(&[0.5, 3.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[1.0, 3.0]), "equal vectors never dominate");
        assert!(!dominates(&[0.5, 4.0], &[1.0, 3.0]), "trade-offs are incomparable");
        assert!(!dominates(&[1.0], &[1.0, 2.0]), "arity mismatch");
        assert!(dominates(&[-0.0, 1.0], &[0.0, 2.0]), "-0.0 compares equal to 0.0");
    }

    #[test]
    fn two_objective_frontier_matches_pairwise_reference() {
        // A grid with ties, duplicates, and an inf: the fast path must
        // agree with the brute-force definition exactly.
        let pts: Vec<[f64; 2]> = vec![
            [1.0, 5.0],
            [2.0, 3.0],
            [2.0, 3.0], // duplicate — both survive
            [2.0, 4.0], // equal-x, worse y — dominated by the run head
            [3.0, 3.0], // dominated by [2,3]
            [4.0, 1.0],
            [5.0, f64::INFINITY],
            [0.0, 9.0],
        ];
        let got = pareto_indices(&pts).unwrap();
        let want = frontier_kd(&pts);
        assert_eq!(got, want);
        assert_eq!(got, vec![0, 1, 2, 5, 7]);
    }

    #[test]
    fn three_objective_frontier_keeps_trade_offs() {
        let pts: Vec<[f64; 3]> = vec![
            [1.0, 9.0, 9.0],
            [9.0, 1.0, 9.0],
            [9.0, 9.0, 1.0],
            [2.0, 9.0, 9.0], // dominated by the first
            [1.0, 9.0, 9.0], // duplicate of the first — kept
        ];
        assert_eq!(pareto_indices(&pts).unwrap(), vec![0, 1, 2, 4]);
    }

    #[test]
    fn degenerate_inputs() {
        let empty: Vec<[f64; 2]> = Vec::new();
        assert!(pareto_indices(&empty).unwrap().is_empty());
        assert_eq!(pareto_indices(&[[3.0]]).unwrap(), vec![0]);
        assert_eq!(pareto_indices(&[[2.0], [1.0], [1.0]]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn nan_and_arity_mismatch_are_errors() {
        assert!(pareto_indices(&[[1.0, f64::NAN]]).is_err());
        assert!(pareto_indices(&[vec![1.0, 2.0], vec![1.0]]).is_err());
        assert!(pareto_indices(&[vec![1.0, 2.0, 3.0], vec![1.0, 2.0, f64::NAN]]).is_err());
    }
}
