//! Area model (paper §V-C, Table II): peri-under-array accounting of the
//! HV wordline drivers, the LV read path (BLS decoder, precharger, mux,
//! ADC, page buffer, shift adder), and the RPU + H-tree wiring — all
//! normalized per plane and checked against the die-area budget.

pub mod budget;
pub mod peri;

pub use budget::{die_budget_mm2, DieBudget};
pub use peri::{AreaBreakdown, AreaModel};
