//! Die-area budget from the package constraints (paper §V-C): a BGA316
//! package (14 mm × 18 mm) holds up to 32 stacked dies; with four dies
//! stacked at 60 % overlap occupying 30–40 % of the package, the budget
//! per die is 5.6–7.5 mm².

/// Package/die budget parameters.
#[derive(Debug, Clone, Copy)]
pub struct DieBudget {
    pub package_mm: (f64, f64),
    /// Fraction of package area the die stack may occupy (range).
    pub occupancy: (f64, f64),
    /// Dies stacked with this overlap fraction.
    pub stack: usize,
    pub overlap: f64,
}

impl Default for DieBudget {
    fn default() -> Self {
        // 32 dies stacked shingle-style at 60 % overlap (the paper's
        // "four dies are stacked" refers to groups; the budget math uses
        // the full 32-die population → 5.6–7.5 mm² per die).
        DieBudget { package_mm: (14.0, 18.0), occupancy: (0.30, 0.40), stack: 32, overlap: 0.60 }
    }
}

impl DieBudget {
    /// Budget area per die in mm², (low, high).
    ///
    /// With `n` dies stacked at overlap `v`, the stack footprint is
    /// `die × (1 + (n-1)(1-v))`; the footprint may use `occupancy` of the
    /// package.
    pub fn per_die_mm2(&self) -> (f64, f64) {
        let pkg = self.package_mm.0 * self.package_mm.1;
        let spread = 1.0 + (self.stack as f64 - 1.0) * (1.0 - self.overlap);
        (pkg * self.occupancy.0 / spread, pkg * self.occupancy.1 / spread)
    }

    /// Does a die of `area_mm2` fit the budget?
    pub fn fits(&self, area_mm2: f64) -> bool {
        area_mm2 <= self.per_die_mm2().1
    }
}

/// The paper's quoted budget range.
pub fn die_budget_mm2() -> (f64, f64) {
    DieBudget::default().per_die_mm2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_range_matches_paper() {
        // Paper §V-C: "the estimated budget area per die ranges 5.6–7.5 mm²".
        let (lo, hi) = die_budget_mm2();
        assert!((lo - 5.6).abs() < 0.4, "low = {lo:.2}");
        assert!((hi - 7.5).abs() < 0.4, "high = {hi:.2}");
    }

    #[test]
    fn proposed_die_fits_budget() {
        // 4.98 mm² of PIM arrays fit within the 5.6–7.5 mm² budget.
        assert!(DieBudget::default().fits(4.98));
        assert!(!DieBudget::default().fits(50.0));
    }
}
